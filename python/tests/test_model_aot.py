"""L2 + AOT tests: model graphs, shape handling, HLO-text emission."""

import os

import numpy as np
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import pairwise_sq_l2_ref, tile_sq_l2_ref


def rand(shape, seed):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape).astype(np.float32))


# ---------------------------------------------------------------------------
# model graphs
# ---------------------------------------------------------------------------

def test_candidate_block_matches_ref():
    x = rand((64, 192), 0)
    (got,) = model.candidate_block(x)
    want = pairwise_sq_l2_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3, rtol=1e-4)


def test_tile_scan_matches_ref():
    q = rand((32, 64), 1)
    x = rand((256, 64), 2)
    (got,) = model.tile_scan(q, x)
    want = tile_sq_l2_ref(q, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3, rtol=1e-4)


def test_chunk_divisor_logic():
    assert model._chunk(784) == 196  # largest divisor of 784 <= 256
    assert model._chunk(256) == 256
    assert model._chunk(8) == 8
    assert model._chunk(192) == 192
    for extent in [8, 24, 192, 784, 3144]:
        c = model._chunk(extent)
        assert extent % c == 0 and 1 <= c <= 256


# ---------------------------------------------------------------------------
# AOT emission
# ---------------------------------------------------------------------------

def test_hlo_text_emission_roundtrip(tmp_path):
    lowered = model.lower_candidate_block(8, 16)
    text = aot.to_hlo_text(lowered)
    # structural sanity of the interchange format
    assert "HloModule" in text
    assert "f32[8,16]" in text, "parameter shape present"
    assert "f32[8,8]" in text, "result shape present"
    # tuple-wrapped single result (rust side unwraps with to_tuple1)
    assert "(f32[8,8]{1,0}) tuple" in text


def test_emit_writes_manifest_and_files(tmp_path):
    out = str(tmp_path / "artifacts")
    lines = aot.emit(out, pairwise=[(8, 16)], tilescan=[(4, 8, 16)], quiet=True)
    assert len(lines) == 2
    manifest = open(os.path.join(out, "manifest.tsv")).read().strip().split("\n")
    assert manifest[0].split("\t") == ["pairwise", "8", "16", "pairwise_b8_d16.hlo.txt"]
    assert manifest[1].split("\t") == [
        "tilescan", "4", "8", "16", "tilescan_m4_n8_d16.hlo.txt",
    ]
    for line in manifest:
        fname = line.split("\t")[-1]
        path = os.path.join(out, fname)
        assert os.path.exists(path)
        assert "HloModule" in open(path).read()


def test_parse_shape_list():
    assert aot.parse_shape_list("64x128,64x256", 2) == [(64, 128), (64, 256)]
    assert aot.parse_shape_list("128x1024x64", 3) == [(128, 1024, 64)]
    try:
        aot.parse_shape_list("64", 2)
        assert False, "should reject wrong arity"
    except ValueError:
        pass


def test_default_shapes_cover_bench_dims():
    # every dimensionality used by the rust benches must have a pairwise
    # artifact (padded-to-8 dims; see rust/benches/*)
    dims = {d for (_, d) in aot.DEFAULT_PAIRWISE}
    for needed in [8, 64, 192, 256, 784]:
        assert needed in dims, f"missing pairwise artifact for d={needed}"
