"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (and the block_d chunking knob); fixed-seed
numpy provides the data. Tolerances account for the float32
norm-decomposition error, which is bounded separately by comparing the
oracle against the decomposed-jnp formulation.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import (
    pairwise_sq_l2,
    pairwise_sq_l2_decomposed,
    pairwise_sq_l2_ref,
    tile_sq_l2,
    tile_sq_l2_ref,
)


def rand(shape, seed, scale=3.0):
    return jnp.asarray(
        np.random.RandomState(seed).randn(*shape).astype(np.float32) * scale
    )


def assert_close(got, want, scale):
    got = np.asarray(got)
    want = np.asarray(want)
    tol = 2e-3 * max(1.0, scale)
    np.testing.assert_allclose(got, want, atol=tol, rtol=1e-4)


# ---------------------------------------------------------------------------
# pairwise (self-set) kernel
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([4, 16, 64, 128]),
    dchunks=st.integers(1, 4),
    chunk=st.sampled_from([8, 64, 128, 256]),
    seed=st.integers(0, 2**16),
)
def test_pairwise_matches_ref(b, dchunks, chunk, seed):
    d = dchunks * chunk
    x = rand((b, d), seed)
    got = pairwise_sq_l2(x, block_d=chunk)
    want = pairwise_sq_l2_ref(x)
    assert_close(got, want, float(jnp.max(want)))


def test_pairwise_diagonal_zero_and_symmetric():
    x = rand((32, 64), 7)
    d = np.asarray(pairwise_sq_l2(x, block_d=64))
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-3)
    np.testing.assert_allclose(d, d.T, atol=1e-4)
    assert (d >= 0).all(), "clamped nonnegative"


def test_pairwise_zero_padding_rows_are_inert():
    # zero rows (batch padding) must not disturb real entries
    x = rand((16, 32), 3)
    xp = jnp.concatenate([x, jnp.zeros((16, 32), jnp.float32)], axis=0)
    full = np.asarray(pairwise_sq_l2(xp, block_d=32))
    small = np.asarray(pairwise_sq_l2(x, block_d=32))
    np.testing.assert_allclose(full[:16, :16], small, atol=1e-3)


def test_pairwise_known_values():
    x = jnp.array([[0.0] * 8, [3.0] + [0.0] * 7, [0.0, 4.0] + [0.0] * 6], jnp.float32)
    d = np.asarray(pairwise_sq_l2(x, block_d=8))
    np.testing.assert_allclose(d[0, 1], 9.0, atol=1e-5)
    np.testing.assert_allclose(d[0, 2], 16.0, atol=1e-5)
    np.testing.assert_allclose(d[1, 2], 25.0, atol=1e-5)


def test_pairwise_rejects_bad_chunking():
    x = rand((8, 24), 0)
    with pytest.raises(ValueError):
        pairwise_sq_l2(x, block_d=16)  # 24 % 16 != 0


def test_decomposition_error_is_small():
    # bound the intrinsic fp32 error of |x|^2+|y|^2-2xy vs direct diff
    x = rand((64, 256), 11)
    a = np.asarray(pairwise_sq_l2_decomposed(x))
    b = np.asarray(pairwise_sq_l2_ref(x))
    scale = float(np.max(b))
    assert np.max(np.abs(a - b)) < 1e-3 * scale


# ---------------------------------------------------------------------------
# tile-scan (cross-set) kernel
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([8, 32, 128]),
    ntiles=st.integers(1, 3),
    bn=st.sampled_from([32, 128]),
    d=st.sampled_from([64, 256]),
    seed=st.integers(0, 2**16),
)
def test_tile_scan_matches_ref(m, ntiles, bn, d, seed):
    n = ntiles * bn
    q = rand((m, d), seed)
    x = rand((n, d), seed + 1)
    got = tile_sq_l2(q, x, block_n=bn, block_d=min(128, d))
    want = tile_sq_l2_ref(q, x)
    assert_close(got, want, float(jnp.max(want)))


def test_tile_scan_agrees_with_pairwise_on_same_set():
    x = rand((64, 128), 5)
    cross = np.asarray(tile_sq_l2(x, x, block_n=64, block_d=128))
    self_ = np.asarray(pairwise_sq_l2(x, block_d=128))
    np.testing.assert_allclose(cross, self_, atol=2e-3)


def test_tile_scan_rejects_mismatched_dims():
    q = rand((8, 64), 1)
    x = rand((16, 128), 2)
    with pytest.raises(ValueError):
        tile_sq_l2(q, x)
