"""L2: the JAX compute graphs that get AOT-lowered for the rust runtime.

The paper's "model" is not a neural net — its compute hot-spot is the
blocked mutual-distance evaluation of a candidate set (SS3.3). Two graphs
are exported, both calling the L1 Pallas kernels:

* `candidate_block`  — (B, D) -> (B, B): all mutual squared-L2 distances
  of one padded candidate set. The rust compute step gathers candidate
  rows into a fixed (B, D) buffer, executes this, and applies heap
  updates. Padding rows are zero; their pairs are ignored on the rust
  side (and cost nothing extra — the block is fixed-shape anyway,
  exactly like the paper's "flexible but slower function" remainder
  handling, but in reverse).
* `tile_scan` — (M, D) x (N, D) -> (M, N): cross-set distances used for
  brute-force ground truth / bulk scoring through the same runtime.

Keeping these as jitted-jax functions (rather than raw pallas_calls)
means XLA still owns layout/fusion around the kernel — this is where L2
optimization happens (see EXPERIMENTS.md SSPerf: the lowered module fuses
the gather-side transposes away).
"""

import jax
import jax.numpy as jnp

from .kernels import pairwise_sq_l2, tile_sq_l2


def candidate_block(x: jnp.ndarray) -> tuple[jnp.ndarray]:
    """All-pairs distances of one candidate block; tuple-wrapped for AOT."""
    return (pairwise_sq_l2(x, block_d=_chunk(x.shape[1])),)


def tile_scan(q: jnp.ndarray, x: jnp.ndarray) -> tuple[jnp.ndarray]:
    """Query-tile vs corpus-tile distances; tuple-wrapped for AOT."""
    bn = _chunk(x.shape[0])
    return (tile_sq_l2(q, x, block_n=bn, block_d=_chunk(q.shape[1])),)


def _chunk(extent: int, target: int = 256) -> int:
    """Largest divisor of `extent` that is <= target (VMEM chunk knob)."""
    c = min(target, extent)
    while extent % c != 0:
        c -= 1
    return c


def lower_candidate_block(b: int, d: int):
    """`jax.jit(...).lower` for a concrete (B, D)."""
    spec = jax.ShapeDtypeStruct((b, d), jnp.float32)
    return jax.jit(candidate_block).lower(spec)


def lower_tile_scan(m: int, n: int, d: int):
    """`jax.jit(...).lower` for a concrete (M, N, D)."""
    qs = jax.ShapeDtypeStruct((m, d), jnp.float32)
    xs = jax.ShapeDtypeStruct((n, d), jnp.float32)
    return jax.jit(tile_scan).lower(qs, xs)
