"""L1: Pallas kernels for the paper's compute hot-spot (blocked squared-L2)."""

from .pairwise_l2 import pairwise_sq_l2, tile_sq_l2  # noqa: F401
from .ref import (  # noqa: F401
    pairwise_sq_l2_decomposed,
    pairwise_sq_l2_ref,
    tile_sq_l2_ref,
)
