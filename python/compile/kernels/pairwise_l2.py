"""L1: blocked pairwise squared-L2 Pallas kernels.

Hardware adaptation of the paper's 5x5 AVX2 register blocking (SS3.3) to
the TPU model (DESIGN.md SSHardware-Adaptation):

* The paper amortizes *register loads*: one 8-float load of a candidate
  vector feeds 5 FMA streams, so a 5x5 block does 10 loads for 25
  distances. On TPU the analogous resource is **VMEM residency**: a
  (block, d-chunk) tile of candidate vectors is staged HBM->VMEM once per
  grid step and feeds block^2 distance accumulations.
* The paper's FMA accumulators become the **MXU**: within a tile,
  `-2 * X @ X_chunk.T` is a systolic matmul; squared norms are VPU
  row-reductions. The d axis is processed in VMEM-sized chunks with a
  float32 scratch accumulator, double-buffered by the Pallas pipeline
  (`dimension_semantics=("arbitrary",)` on the reduction axis).
* The paper pads d to a multiple of 8 for AVX2; we pad the lane axis to
  128 (TPU lane width) at the caller (aot.py emits only such shapes; the
  rust batcher zero-pads rows, and zero lanes contribute nothing to
  squared-L2, same trick as the paper's `mem-align`).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO with
identical semantics. Real-TPU perf is *estimated* in DESIGN.md SS8.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# self-pairwise: one candidate set against itself (the compute step's shape)
# ---------------------------------------------------------------------------

def _pairwise_kernel(x_ref, o_ref, acc_ref, *, nsteps: int):
    """One (d-chunk) grid step of the self-pairwise distance kernel.

    x_ref:   (B, BD) VMEM tile — all B candidate rows, one d-chunk.
    o_ref:   (B, B) output tile (written on the last step).
    acc_ref: (B, B) float32 VMEM scratch accumulating -2<x,y> + |x|^2+|y|^2
             contributions chunk by chunk.
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    # MXU: cross-term for this chunk; VPU: per-row squared norms.
    gram = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    sq = jnp.sum(x * x, axis=1)
    acc_ref[...] += sq[:, None] + sq[None, :] - 2.0 * gram

    @pl.when(step == nsteps - 1)
    def _done():
        # clamp tiny negative float32 residue (diagonal, near-duplicates)
        o_ref[...] = jnp.maximum(acc_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("block_d",))
def pairwise_sq_l2(x: jnp.ndarray, *, block_d: int = 256) -> jnp.ndarray:
    """All-pairs squared-L2 of one set: (B, D) -> (B, B).

    B is expected to be the (padded) candidate-set size (<= a few
    hundred); D the padded dimensionality. The d axis is chunked by
    `block_d` (the VMEM budget knob; see DESIGN.md SS8 for the footprint
    arithmetic).
    """
    b, d = x.shape
    bd = min(block_d, d)
    if d % bd != 0:
        raise ValueError(f"d={d} not divisible by block_d={bd}")
    nsteps = d // bd
    return pl.pallas_call(
        functools.partial(_pairwise_kernel, nsteps=nsteps),
        grid=(nsteps,),
        in_specs=[pl.BlockSpec((b, bd), lambda i: (0, i))],
        out_specs=pl.BlockSpec((b, b), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, b), jnp.float32),
        scratch_shapes=[pltpu_scratch((b, b))],
        interpret=True,
    )(x)


# ---------------------------------------------------------------------------
# cross-set tile scan: queries x corpus (ground-truth / bulk distance shape)
# ---------------------------------------------------------------------------

def _tile_kernel(q_ref, x_ref, o_ref, acc_ref, *, nsteps: int):
    """Grid (n-tile, d-chunk); accumulates one (M, BN) output tile."""
    dstep = pl.program_id(1)

    @pl.when(dstep == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]
    x = x_ref[...]
    gram = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    qsq = jnp.sum(q * q, axis=1)
    xsq = jnp.sum(x * x, axis=1)
    acc_ref[...] += qsq[:, None] + xsq[None, :] - 2.0 * gram

    @pl.when(dstep == nsteps - 1)
    def _done():
        o_ref[...] = jnp.maximum(acc_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("block_n", "block_d"))
def tile_sq_l2(
    q: jnp.ndarray, x: jnp.ndarray, *, block_n: int = 256, block_d: int = 256
) -> jnp.ndarray:
    """Cross-set squared-L2: (M, D) x (N, D) -> (M, N), tiled over N and D."""
    m, d = q.shape
    n, d2 = x.shape
    if d != d2:
        raise ValueError(f"dim mismatch {d} vs {d2}")
    bn = min(block_n, n)
    bd = min(block_d, d)
    if n % bn != 0 or d % bd != 0:
        raise ValueError(f"(n={n}, d={d}) not divisible by blocks ({bn}, {bd})")
    nsteps = d // bd
    return pl.pallas_call(
        functools.partial(_tile_kernel, nsteps=nsteps),
        grid=(n // bn, nsteps),
        in_specs=[
            pl.BlockSpec((m, bd), lambda j, i: (0, i)),
            pl.BlockSpec((bn, bd), lambda j, i: (j, i)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j, i: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu_scratch((m, bn))],
        interpret=True,
    )(q, x)


def pltpu_scratch(shape):
    """float32 VMEM scratch spec, import-guarded for interpret mode.

    On real TPU this is `pltpu.VMEM(shape, jnp.float32)`; interpret mode
    accepts the generic `pl.pallas_call` scratch ANY/memory-space form.
    """
    try:  # pragma: no cover - depends on installed jaxlib flavor
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.VMEM(shape, jnp.float32)
    except Exception:  # pragma: no cover
        return pl.MemorySpace.ANY(shape, jnp.float32)  # type: ignore[attr-defined]
