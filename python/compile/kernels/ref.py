"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must match its oracle to float32 tolerance under pytest/hypothesis sweeps
(python/tests/test_kernel.py). They are also what the kernels would look
like without any tiling — XLA is free to fuse them however it likes, which
makes them a useful L2 performance baseline, but they give the compiler no
explicit VMEM/MXU schedule.
"""

import jax.numpy as jnp


def pairwise_sq_l2_ref(x: jnp.ndarray) -> jnp.ndarray:
    """All-pairs squared-L2 distances of one set: (B, D) -> (B, B).

    Direct subtraction formulation: numerically the most robust (no
    catastrophic cancellation for close points), O(B^2 D) intermediate if
    materialized — which is exactly why the kernel uses the norm/MXU
    decomposition instead.
    """
    diff = x[:, None, :] - x[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def tile_sq_l2_ref(q: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Cross-set squared-L2 distances: (M, D) x (N, D) -> (M, N)."""
    diff = q[:, None, :] - x[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def pairwise_sq_l2_decomposed(x: jnp.ndarray) -> jnp.ndarray:
    """The |x|^2 + |y|^2 - 2<x,y> decomposition (what the kernel computes).

    Used by tests to bound the decomposition's intrinsic float32 error
    separately from any Pallas-introduced error.
    """
    sq = jnp.sum(x * x, axis=-1)
    g = x @ x.T
    d = sq[:, None] + sq[None, :] - 2.0 * g
    return jnp.maximum(d, 0.0)
