"""AOT export: lower the L2 graphs to HLO *text* artifacts + manifest.

Interchange format is HLO text, NOT `.serialize()`d HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser on the rust side (`HloModuleProto::from_text_file`) reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Artifacts written to --out-dir:

    pairwise_b{B}_d{D}.hlo.txt    candidate_block for each (B, D)
    tilescan_m{M}_n{N}_d{D}.hlo.txt
    manifest.tsv                  one line per artifact:
                                  kind<TAB>shape-args...<TAB>filename

The shape set covers every dimensionality the benchmarks use (all padded
to a multiple of 8, matching the rust AlignedMatrix contract). Build is
incremental: `make artifacts` regenerates only when compile/ sources are
newer than the manifest.
"""

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model

# Candidate-block shapes: B = padded candidate-set size (the paper caps
# candidate sets at 50 -> new+old <= 100; 64 covers the default rho*k=10
# new + 10 old = 20 padded generously, 128 covers stress configs).
DEFAULT_PAIRWISE = [
    (64, 8),
    (64, 16),
    (64, 32),
    (64, 64),
    (64, 128),
    (64, 192),
    (64, 256),
    (64, 512),
    (64, 784),
    (128, 256),
]

# Tile-scan shapes for PJRT-side brute force (M queries x N corpus rows).
DEFAULT_TILESCAN = [
    (128, 1024, 64),
    (128, 1024, 256),
    (128, 1024, 784),
]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(out_dir: str, pairwise, tilescan, quiet: bool = False) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    lines = []

    for b, d in pairwise:
        name = f"pairwise_b{b}_d{d}.hlo.txt"
        text = to_hlo_text(model.lower_candidate_block(b, d))
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        lines.append(f"pairwise\t{b}\t{d}\t{name}")
        if not quiet:
            print(f"[aot] {name}: {len(text)} chars", file=sys.stderr)

    for m, n, d in tilescan:
        name = f"tilescan_m{m}_n{n}_d{d}.hlo.txt"
        text = to_hlo_text(model.lower_tile_scan(m, n, d))
        with open(os.path.join(out_dir, name), "w") as f:
            f.write(text)
        lines.append(f"tilescan\t{m}\t{n}\t{d}\t{name}")
        if not quiet:
            print(f"[aot] {name}: {len(text)} chars", file=sys.stderr)

    manifest = os.path.join(out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("\n".join(lines) + "\n")
    if not quiet:
        print(f"[aot] wrote {manifest} ({len(lines)} artifacts)", file=sys.stderr)
    return lines


def parse_shape_list(spec: str, arity: int) -> list[tuple]:
    """Parse "64x128,64x256" style shape lists."""
    out = []
    for part in spec.split(","):
        dims = tuple(int(x) for x in part.strip().split("x"))
        if len(dims) != arity:
            raise ValueError(f"shape {part!r}: expected {arity} dims")
        out.append(dims)
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--pairwise", help="BxD[,BxD...] override", default=None)
    ap.add_argument("--tilescan", help="MxNxD[,MxNxD...] override", default=None)
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    pairwise = (
        parse_shape_list(args.pairwise, 2) if args.pairwise else DEFAULT_PAIRWISE
    )
    tilescan = (
        parse_shape_list(args.tilescan, 3) if args.tilescan else DEFAULT_TILESCAN
    )
    # determinism / no accelerator surprises in the compile path
    jax.config.update("jax_platforms", "cpu")
    emit(args.out_dir, pairwise, tilescan, quiet=args.quiet)


if __name__ == "__main__":
    main()
