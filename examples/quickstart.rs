//! Quickstart: build a K-NN graph on a small synthetic dataset with the
//! fully optimized pipeline and validate recall against brute force.
//!
//! Run: `cargo run --release --example quickstart`

use knng::baseline::brute::brute_force_knn;
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::dataset::clustered::SynthClustered;
use knng::metrics::recall::recall_against_truth;
use knng::nndescent::{NnDescent, Params};

fn main() {
    // 1. Data: 4096 points in 16 dimensions, 8 well-separated clusters.
    let (data, _labels) = SynthClustered::new(4096, 16, 8, 0x5eed).generate_labeled();
    println!("dataset: {} × {} (padded to {})", data.n(), data.dim(), data.dim_pad());

    // 2. Build: turbosampling selection + 5×5 blocked distances + greedy
    //    memory reordering — the paper's full optimization stack.
    let params = Params::default()
        .with_k(20)
        .with_seed(42)
        .with_selection(SelectionKind::Turbo)
        .with_compute(ComputeKind::Blocked)
        .with_reorder(true);
    let result = NnDescent::new(params).build(&data).expect("native build");

    println!(
        "built in {} iterations / {:.3}s — {} distance evaluations ({:.2e} flops)",
        result.iterations,
        result.total_secs,
        result.stats.dist_evals,
        result.stats.flops() as f64,
    );
    for it in &result.per_iter {
        println!(
            "  iter {}: select {:.1}ms, compute {:.1}ms{}, {} updates",
            it.iter,
            it.select_secs * 1e3,
            it.compute_secs * 1e3,
            if it.reorder_secs > 0.0 { format!(", reorder {:.1}ms", it.reorder_secs * 1e3) } else { String::new() },
            it.updates,
        );
    }

    // 3. Inspect: the ten nearest neighbors of point 0 (original ids,
    //    even though the graph was physically reordered).
    println!("\nneighbors of node 0:");
    for (v, d) in result.neighbors_original(0).iter().take(10) {
        println!("  node {v:<6} squared-L2 {d:.3}");
    }

    // 4. Validate: exact recall vs brute force over all nodes.
    let truth = brute_force_knn(&data, 20);
    let recall = recall_against_truth(&result, &truth);
    println!("\nrecall vs exact ground truth: {recall:.4} (paper reports ≥ 0.99)");
    assert!(recall > 0.98, "quickstart should achieve near-perfect recall");
    println!("quickstart OK");
}
