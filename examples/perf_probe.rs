//! Perf probe: per-phase time breakdown for representative builds.
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::dataset::synth::SynthGaussian;
use knng::nndescent::{NnDescent, Params};

fn main() {
    for (n, d) in [(16_384usize, 8usize), (16_384, 256)] {
        let data = SynthGaussian::single(n, d, 3).generate();
        let params = Params::default().with_k(20).with_seed(3)
            .with_selection(SelectionKind::Turbo).with_compute(ComputeKind::Blocked);
        let r = NnDescent::new(params).build(&data).expect("native build");
        let sel: f64 = r.per_iter.iter().map(|s| s.select_secs).sum();
        let comp: f64 = r.per_iter.iter().map(|s| s.compute_secs).sum();
        let evals: u64 = r.stats.dist_evals;
        println!("n={n} d={d}: total {:.3}s = select {:.3}s ({:.0}%) + compute {:.3}s ({:.0}%) + init {:.3}s; {} evals, {:.2} f/c",
            r.total_secs, sel, sel/r.total_secs*100.0, comp, comp/r.total_secs*100.0,
            r.total_secs - sel - comp, evals,
            r.stats.flops() as f64 / (r.total_secs * 3.6e9));
    }
}
