//! Dimensionality sweep (Fig 7 in miniature): how each compute backend
//! scales as d grows, on the Synthetic Single Gaussian dataset — the
//! paper's core "which optimization matters when" story.
//!
//! Run: `cargo run --release --example dim_sweep`

use knng::config::schema::{ComputeKind, SelectionKind};
use knng::dataset::synth::SynthGaussian;
use knng::nndescent::{NnDescent, Params};
use knng::util::timer::DEFAULT_NOMINAL_HZ;

fn main() {
    let n = 4096;
    let k = 20;
    println!("dim sweep on Synthetic Single Gaussian, n={n}, k={k}\n");
    println!(
        "{:<6} {:>14} {:>14} {:>14}   {}",
        "dim", "scalar", "unrolled", "blocked", "blocked flops/cycle"
    );

    for dim in [8usize, 32, 128, 256, 784] {
        let data = SynthGaussian::single(n, dim, 0xD1E).generate();
        let mut row = format!("{dim:<6}");
        let mut blocked_fpc = 0.0;
        for kind in [ComputeKind::Scalar, ComputeKind::Unrolled, ComputeKind::Blocked] {
            let params = Params::default()
                .with_k(k)
                .with_seed(1)
                .with_selection(SelectionKind::Turbo)
                .with_compute(kind);
            let result = NnDescent::new(params).build(&data).expect("native build");
            row.push_str(&format!(" {:>12.3}s ", result.total_secs));
            if kind == ComputeKind::Blocked {
                blocked_fpc =
                    result.stats.flops() as f64 / (result.total_secs * DEFAULT_NOMINAL_HZ);
            }
        }
        println!("{row}  {blocked_fpc:>8.2}");
    }

    println!(
        "\nexpected shape (paper Fig 7): at d=8 the backends tie (selection-bound); \
         as d grows, unrolled pulls ahead of scalar and blocked ahead of unrolled"
    );
}
