//! Serve-style example: build a K-NN graph index, persist it, reload,
//! and answer a batch of held-out queries with the beam search —
//! reporting latency percentiles, per-query distance evaluations, and
//! recall (the downstream-consumer workflow the paper's intro
//! motivates: UMAP-style pipelines query the graph, they don't just
//! build it).
//!
//! Run: `cargo run --release --example graph_search [-- n]`

use knng::baseline::brute::GroundTruth;
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::distance::sq_l2_unrolled;
use knng::graph::{load_graph, save_graph};
use knng::nndescent::{NnDescent, Params};
use knng::search::{GraphIndex, SearchParams};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let n_queries = 1000;
    let (dim, k) = (64, 20);

    // ---- corpus + held-out query set from the same distribution --------
    let (all, _) = SynthClustered::new(n + n_queries, dim, 32, 0x9E4).generate_labeled();
    let corpus = {
        let rows: Vec<f32> = (0..n).flat_map(|i| all.row_logical(i).to_vec()).collect();
        AlignedMatrix::from_rows(n, dim, &rows)
    };
    println!("corpus {n} × {dim}, {n_queries} held-out queries, k={k}");

    // ---- build + persist + reload (exercises graph/io) -----------------
    let t0 = Instant::now();
    let built = NnDescent::new(Params::default().with_k(k).with_seed(4).with_reorder(false))
        .build(&corpus);
    println!("graph built in {:.2}s ({} iterations)", t0.elapsed().as_secs_f64(), built.iterations);

    let path = std::env::temp_dir().join("knng_graph_search.knng");
    save_graph(&path, &built.graph)?;
    let graph = load_graph(&path)?;
    println!("persisted + reloaded graph: {} bytes", std::fs::metadata(&path)?.len());
    let index = GraphIndex::new(corpus, graph);

    // ---- exact truth for recall (brute force per query) ----------------
    let truth: GroundTruth = {
        let mut queries = Vec::with_capacity(n_queries);
        for qi in 0..n_queries {
            let mut qp = vec![0f32; index.data().dim_pad()];
            qp[..dim].copy_from_slice(all.row_logical(n + qi));
            let mut d: Vec<(u32, f32)> = (0..n as u32)
                .map(|v| (v, sq_l2_unrolled(&qp, index.data().row(v as usize))))
                .collect();
            d.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            d.truncate(k);
            queries.push((qi as u32, d));
        }
        GroundTruth { k, queries }
    };

    // ---- serve the batch ------------------------------------------------
    let params = SearchParams::default();
    let mut latencies = Vec::with_capacity(n_queries);
    let mut evals = 0u64;
    let mut hits = 0usize;
    for qi in 0..n_queries {
        let q = all.row_logical(n + qi);
        let t = Instant::now();
        let (res, stats) = index.search(q, k, &params);
        latencies.push(t.elapsed().as_secs_f64());
        evals += stats.dist_evals;
        let exact = truth.get(qi as u32).unwrap();
        hits += exact.iter().filter(|(v, _)| res.iter().any(|(r, _)| r == v)).count();
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies[((latencies.len() as f64 * p) as usize).min(latencies.len() - 1)];
    let recall = hits as f64 / (n_queries * k) as f64;
    let qps = n_queries as f64 / latencies.iter().sum::<f64>();

    println!("\nserved {n_queries} queries (ef={}):", params.ef);
    println!("  recall@{k}     : {recall:.4}");
    println!("  latency p50    : {:.1} µs", pct(0.50) * 1e6);
    println!("  latency p99    : {:.1} µs", pct(0.99) * 1e6);
    println!("  throughput     : {qps:.0} queries/s (single core)");
    println!("  evals/query    : {:.0} of {n} corpus points ({:.2}%)",
        evals as f64 / n_queries as f64,
        evals as f64 / n_queries as f64 / n as f64 * 100.0);
    assert!(recall > 0.9, "search recall {recall}");
    println!("graph_search OK");
    Ok(())
}
