//! Serve-style example: build a K-NN graph index, persist it as a
//! KNNIv1 bundle, reload, and answer a batch of held-out queries with
//! the beam search — reporting latency percentiles, per-query distance
//! evaluations, recall, and the batched-path throughput (the
//! downstream-consumer workflow the paper's intro motivates: UMAP-style
//! pipelines query the graph, they don't just build it).
//!
//! Run: `cargo run --release --example graph_search [-- n]`

use knng::baseline::brute::GroundTruth;
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::distance::sq_l2_unrolled;
use knng::nndescent::{NnDescent, Params};
use knng::search::{load_index, save_index, IndexBundle, SearchParams};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let n_queries = 1000;
    let (dim, k) = (64, 20);

    // ---- corpus + held-out query set from the same distribution --------
    let (all, _) = SynthClustered::new(n + n_queries, dim, 32, 0x9E4).generate_labeled();
    let corpus = {
        let rows: Vec<f32> = (0..n).flat_map(|i| all.row_logical(i).to_vec()).collect();
        AlignedMatrix::from_rows(n, dim, &rows)
    };
    println!("corpus {n} × {dim}, {n_queries} held-out queries, k={k}");

    // ---- build + persist + reload (exercises search::bundle) -----------
    let t0 = Instant::now();
    let params = Params::default().with_k(k).with_seed(4).with_reorder(false);
    let built = NnDescent::new(params.clone()).build(&corpus);
    println!("graph built in {:.2}s ({} iterations)", t0.elapsed().as_secs_f64(), built.iterations);

    let path = std::env::temp_dir().join("knng_graph_search.knni");
    save_index(&path, &IndexBundle::from_build(&corpus, &built, &params))?;
    let (index, _reordering, _) = load_index(&path)?.into_index();
    println!("persisted + reloaded index bundle: {} bytes", std::fs::metadata(&path)?.len());

    // ---- exact truth for recall (brute force per query) ----------------
    let truth: GroundTruth = {
        let mut queries = Vec::with_capacity(n_queries);
        for qi in 0..n_queries {
            let mut qp = vec![0f32; index.data().dim_pad()];
            qp[..dim].copy_from_slice(all.row_logical(n + qi));
            let mut d: Vec<(u32, f32)> = (0..n as u32)
                .map(|v| (v, sq_l2_unrolled(&qp, index.data().row(v as usize))))
                .collect();
            d.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            d.truncate(k);
            queries.push((qi as u32, d));
        }
        GroundTruth { k, queries }
    };

    // ---- serve the batch, one query at a time ---------------------------
    let params = SearchParams::default();
    let mut latencies = Vec::with_capacity(n_queries);
    let mut seq_results = Vec::with_capacity(n_queries);
    let mut evals = 0u64;
    let mut hits = 0usize;
    for qi in 0..n_queries {
        let q = all.row_logical(n + qi);
        let t = Instant::now();
        let (res, stats) = index.search(q, k, &params);
        latencies.push(t.elapsed().as_secs_f64());
        evals += stats.dist_evals;
        let exact = truth.get(qi as u32).unwrap();
        hits += exact.iter().filter(|(v, _)| res.iter().any(|(r, _)| r == v)).count();
        seq_results.push(res);
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies[((latencies.len() as f64 * p) as usize).min(latencies.len() - 1)];
    let recall = hits as f64 / (n_queries * k) as f64;
    let qps = n_queries as f64 / latencies.iter().sum::<f64>();

    println!("\nserved {n_queries} queries sequentially (ef={}):", params.ef);
    println!("  recall@{k}     : {recall:.4}");
    println!("  latency p50    : {:.1} µs", pct(0.50) * 1e6);
    println!("  latency p99    : {:.1} µs", pct(0.99) * 1e6);
    println!("  throughput     : {qps:.0} queries/s (single core)");
    println!("  evals/query    : {:.0} of {n} corpus points ({:.2}%)",
        evals as f64 / n_queries as f64,
        evals as f64 / n_queries as f64 / n as f64 * 100.0);
    assert!(recall > 0.9, "search recall {recall}");

    // ---- same batch through the batched path ----------------------------
    let qmat = {
        let rows: Vec<f32> =
            (0..n_queries).flat_map(|qi| all.row_logical(n + qi).to_vec()).collect();
        AlignedMatrix::from_rows(n_queries, dim, &rows)
    };
    let (batch_results, bstats) = index.search_batch(&qmat, k, &params);
    for qi in 0..n_queries {
        assert_eq!(batch_results[qi], seq_results[qi], "batch/sequential diverged at {qi}");
    }
    println!("\nbatched path (search_batch, {} queries in one call):", bstats.queries);
    println!("  throughput     : {:.0} queries/s ({:.2}× sequential)", bstats.qps(), bstats.qps() / qps);
    println!("  evals/query    : {:.0}", bstats.dist_evals_per_query());
    println!("  expansions/qry : {:.1}", bstats.expansions_per_query());
    println!("  results        : identical to sequential (verified)");
    println!("graph_search OK");
    Ok(())
}
