//! Serve-style example on the `api` facade: build an index with
//! `IndexBuilder`, persist it as a KNNIv1 bundle, reload, and answer a
//! batch of held-out queries through the `Searcher` trait — reporting
//! latency percentiles, per-query distance evaluations, recall, the
//! batched-path throughput, and a sharded-serving comparison (the
//! downstream-consumer workflow the paper's intro motivates: UMAP-style
//! pipelines query the graph, they don't just build it).
//!
//! All result ids are `OriginalId`-typed: the facade owns the reorder
//! permutation, so this example never touches σ.
//!
//! Run: `cargo run --release --example graph_search [-- n]`

use knng::api::{Index, IndexBuilder, Searcher, ShardedSearcher};
use knng::baseline::brute::GroundTruth;
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::distance::sq_l2_unrolled;
use knng::nndescent::Params;
use knng::search::SearchParams;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let n_queries = 1000;
    let (dim, k) = (64, 20);

    // ---- corpus + held-out query set from the same distribution --------
    let (all, _) = SynthClustered::new(n + n_queries, dim, 32, 0x9E4).generate_labeled();
    let corpus = {
        let rows: Vec<f32> = (0..n).flat_map(|i| all.row_logical(i).to_vec()).collect();
        AlignedMatrix::from_rows(n, dim, &rows)
    };
    println!("corpus {n} × {dim}, {n_queries} held-out queries, k={k}");

    // ---- build + persist + reload (builder → Index → bundle) -----------
    let t0 = Instant::now();
    let params = Params::default().with_k(k).with_seed(4).with_reorder(false);
    let built = IndexBuilder::new()
        .data_named(corpus.clone(), "clustered")
        .params(params.clone())
        .build()?;
    println!(
        "graph built in {:.2}s ({} iterations)",
        t0.elapsed().as_secs_f64(),
        built.telemetry().expect("fresh build carries telemetry").iterations
    );

    let path = std::env::temp_dir().join("knng_graph_search.knni");
    built.save(&path)?;
    let index = Index::load(&path)?;
    println!("persisted + reloaded index bundle: {} bytes", std::fs::metadata(&path)?.len());

    // ---- exact truth for recall (brute force per query) ----------------
    let truth: GroundTruth = {
        let mut queries = Vec::with_capacity(n_queries);
        for qi in 0..n_queries {
            let mut qp = vec![0f32; index.data().dim_pad()];
            qp[..dim].copy_from_slice(all.row_logical(n + qi));
            let mut d: Vec<(u32, f32)> = (0..n as u32)
                .map(|v| (v, sq_l2_unrolled(&qp, index.data().row(v as usize))))
                .collect();
            d.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            d.truncate(k);
            queries.push((qi as u32, d));
        }
        GroundTruth { k, queries }
    };

    // ---- serve the batch, one query at a time ---------------------------
    let sp = SearchParams::default();
    let mut latencies = Vec::with_capacity(n_queries);
    let mut seq_results = Vec::with_capacity(n_queries);
    let mut evals = 0u64;
    let mut hits = 0usize;
    for qi in 0..n_queries {
        let q = all.row_logical(n + qi);
        let t = Instant::now();
        let (res, stats) = index.search(q, k, &sp);
        latencies.push(t.elapsed().as_secs_f64());
        evals += stats.dist_evals;
        let exact = truth.get(qi as u32).unwrap();
        hits += exact.iter().filter(|(v, _)| res.iter().any(|nb| nb.id.get() == *v)).count();
        seq_results.push(res);
    }
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| latencies[((latencies.len() as f64 * p) as usize).min(latencies.len() - 1)];
    let recall = hits as f64 / (n_queries * k) as f64;
    let qps = n_queries as f64 / latencies.iter().sum::<f64>();

    println!("\nserved {n_queries} queries sequentially (ef={}):", sp.ef);
    println!("  recall@{k}     : {recall:.4}");
    println!("  latency p50    : {:.1} µs", pct(0.50) * 1e6);
    println!("  latency p99    : {:.1} µs", pct(0.99) * 1e6);
    println!("  throughput     : {qps:.0} queries/s (single core)");
    println!("  evals/query    : {:.0} of {n} corpus points ({:.2}%)",
        evals as f64 / n_queries as f64,
        evals as f64 / n_queries as f64 / n as f64 * 100.0);
    assert!(recall > 0.9, "search recall {recall}");

    // ---- same batch through the batched path ----------------------------
    let qmat = {
        let rows: Vec<f32> =
            (0..n_queries).flat_map(|qi| all.row_logical(n + qi).to_vec()).collect();
        AlignedMatrix::from_rows(n_queries, dim, &rows)
    };
    let (batch_results, bstats) = index.search_batch(&qmat, k, &sp);
    for qi in 0..n_queries {
        assert_eq!(batch_results[qi], seq_results[qi], "batch/sequential diverged at {qi}");
    }
    println!("\nbatched path (search_batch, {} queries in one call):", bstats.queries);
    println!("  throughput     : {:.0} queries/s ({:.2}× sequential)", bstats.qps(), bstats.qps() / qps);
    println!("  evals/query    : {:.0}", bstats.dist_evals_per_query());
    println!("  expansions/qry : {:.1}", bstats.expansions_per_query());
    println!("  results        : identical to sequential (verified)");

    // ---- sharded serving: same corpus, 4 independent shards -------------
    let t0 = Instant::now();
    let sharded = ShardedSearcher::build(&corpus, 4, &params)?;
    println!(
        "\nsharded searcher: {} shards of {:?} points, built in {:.2}s",
        sharded.shard_count(),
        sharded.shard_sizes(),
        t0.elapsed().as_secs_f64()
    );
    let (shard_results, sstats) = sharded.search_batch(&qmat, k, &sp);
    let mut shard_hits = 0usize;
    for qi in 0..n_queries {
        let exact = truth.get(qi as u32).unwrap();
        shard_hits += exact
            .iter()
            .filter(|(v, _)| shard_results[qi].iter().any(|nb| nb.id.get() == *v))
            .count();
    }
    let shard_recall = shard_hits as f64 / (n_queries * k) as f64;
    println!("  recall@{k}     : {shard_recall:.4} (single-index {recall:.4})");
    println!("  throughput     : {:.0} queries/s", sstats.qps());
    println!("  evals/query    : {:.0}", sstats.dist_evals_per_query());
    assert!(shard_recall >= recall - 0.02, "sharded recall {shard_recall} vs single {recall}");
    println!("graph_search OK");
    Ok(())
}
