//! Config-driven pipeline: run a batch of experiment configs through the
//! `api` facade (builder → index → evaluate) and emit a TSV report — the
//! "framework" entry point a downstream user would script against.
//!
//! Run: `cargo run --release --example pipeline_report [-- config.toml ...]`
//! With no arguments it runs the bundled configs in `configs/`.

use knng::api::{EvalOptions, IndexBuilder};
use knng::config::ExperimentConfig;
use knng::pipeline::RunReport;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let configs: Vec<std::path::PathBuf> = if args.is_empty() {
        let mut v: Vec<_> = std::fs::read_dir("configs")?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|x| x == "toml"))
            .collect();
        v.sort();
        v
    } else {
        args.iter().map(Into::into).collect()
    };
    anyhow::ensure!(!configs.is_empty(), "no configs found (looked in configs/)");

    let eval = EvalOptions::new().with_recall_queries(300).with_seed(11);
    println!("{}", RunReport::tsv_header());
    for path in &configs {
        let cfg = ExperimentConfig::load(path)?;
        let index = IndexBuilder::from_config(&cfg).log_progress().build()?;
        println!("{}", index.evaluate(&eval).tsv_row());
    }
    Ok(())
}
