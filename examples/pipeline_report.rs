//! Config-driven pipeline: run a batch of experiment configs through the
//! shared pipeline layer and emit a TSV report — the "framework" entry
//! point a downstream user would script against.
//!
//! Run: `cargo run --release --example pipeline_report [-- config.toml ...]`
//! With no arguments it runs the bundled configs in `configs/`.

use knng::config::ExperimentConfig;
use knng::pipeline::{run_experiment, EvalOptions, RunReport};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let configs: Vec<std::path::PathBuf> = if args.is_empty() {
        let mut v: Vec<_> = std::fs::read_dir("configs")?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|x| x == "toml"))
            .collect();
        v.sort();
        v
    } else {
        args.iter().map(Into::into).collect()
    };
    anyhow::ensure!(!configs.is_empty(), "no configs found (looked in configs/)");

    println!("{}", RunReport::tsv_header());
    for path in &configs {
        let cfg = ExperimentConfig::load(path)?;
        let report = run_experiment(&cfg, EvalOptions { recall_queries: 300, seed: 11 })?;
        println!("{}", report.tsv_row());
    }
    Ok(())
}
