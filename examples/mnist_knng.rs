//! End-to-end driver — the full three-layer system on a real small
//! workload (Table 2's MNIST experiment, shrunk to example scale).
//!
//! This is the repo's proof that all layers compose:
//!
//! * **L1** (Pallas pairwise-L2 kernel) and **L2** (JAX candidate-block
//!   graph) were AOT-lowered to `artifacts/*.hlo.txt` by
//!   `make artifacts` — Python never runs here.
//! * **L3** (this binary) loads them through PJRT and drives NN-Descent
//!   with the compute step offloaded to the compiled kernel, then runs
//!   the same workload on the native blocked kernel and on the
//!   PyNNDescent-profile baseline, reporting the paper's headline
//!   metric (runtime + recall).
//!
//! Uses real MNIST from `data/` when present, else the documented
//! MNIST-like substitute (DESIGN.md §4).
//!
//! Run: `make artifacts && cargo run --release --example mnist_knng [-- n]`

use knng::baseline::brute::brute_force_knn_sampled;
use knng::baseline::pynnd::PyNndBaseline;
use knng::cachesim::trace::NoTracer;
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::config::DatasetSpec;
use knng::dataset::from_spec;
use knng::metrics::recall::recall_against_truth;
use knng::nndescent::{NnDescent, Params};
use knng::runtime::PjrtEngine;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(10_000);
    let k = 20;

    let ds = from_spec(&DatasetSpec::Mnist { n, path: None, seed: 0x3A15 })?;
    println!("dataset: {} — {} × {} (padded {})", ds.name, ds.n(), ds.dim(), ds.data.dim_pad());
    let truth = brute_force_knn_sampled(&ds.data, k, 500, 7);

    let base = Params::default().with_k(k).with_seed(3).with_selection(SelectionKind::Turbo);

    // --- variant 1: fully native, blocked + greedy reorder (paper's best)
    let p = base.clone().with_compute(ComputeKind::Blocked).with_reorder(true);
    let native = NnDescent::new(p).build(&ds.data).expect("native build");
    let native_recall = recall_against_truth(&native, &truth);
    println!(
        "\n[native blocked+greedy] {:.2}s, {} iters, {} evals, recall {:.4}",
        native.total_secs, native.iterations, native.stats.dist_evals, native_recall
    );

    // --- variant 2: compute step offloaded to the AOT Pallas kernel (PJRT)
    match PjrtEngine::open("artifacts") {
        Ok(mut engine) => {
            let p = base.clone().with_compute(ComputeKind::Pjrt);
            let pjrt = NnDescent::new(p).build_with_engine(&ds.data, &mut engine, &mut NoTracer);
            let pjrt_recall = recall_against_truth(&pjrt, &truth);
            println!(
                "[pjrt pallas kernel  ] {:.2}s, {} iters, {} kernel executions, recall {:.4}",
                pjrt.total_secs, pjrt.iterations, engine.executions, pjrt_recall
            );
            assert!(pjrt_recall > 0.90, "pjrt path must reach comparable recall");
        }
        Err(e) => println!("[pjrt] skipped: {e:#} — run `make artifacts`"),
    }

    // --- variant 3: PyNNDescent-profile baseline (Table 2 comparator)
    let baseline = PyNndBaseline::default().with_k(k).with_seed(3).build(&ds.data);
    let baseline_recall = recall_against_truth(&baseline, &truth);
    println!(
        "[pynnd baseline      ] {:.2}s, {} iters, {} evals, recall {:.4}",
        baseline.total_secs, baseline.iterations, baseline.stats.dist_evals, baseline_recall
    );

    println!(
        "\nheadline (paper Table 2 shape): optimized {:.2}s vs baseline {:.2}s → {:.2}× faster",
        native.total_secs,
        baseline.total_secs,
        baseline.total_secs / native.total_secs
    );
    assert!(native_recall > 0.97, "main variant recall");
    assert!(
        native.total_secs < baseline.total_secs,
        "optimized implementation must beat the baseline"
    );
    println!("mnist_knng OK");
    Ok(())
}
