//! # `api` — the crate's public face
//!
//! One coherent surface over the paper's pipeline (NN-Descent build →
//! greedy reorder → blocked serving), replacing the three historical
//! entry points (`pipeline::run_experiment_full`'s bare tuple, the
//! panicking `NnDescent::build`, and a `GraphIndex` that answered in
//! working ids):
//!
//! * [`IndexBuilder`] — typed, fallible construction from a
//!   [`DatasetSpec`](crate::config::DatasetSpec) or an owned
//!   [`AlignedMatrix`](crate::dataset::AlignedMatrix), with progress as
//!   typed [`BuildEvent`]s through a [`BuildObserver`].
//! * [`Index`] — the sealed build product: graph + working-layout data
//!   + σ + telemetry, persistable as a `KNNIv1` bundle.
//! * [`Searcher`] — the serving trait (`search`, `search_batch`, stats)
//!   implemented by [`Index`], by the underlying
//!   [`GraphIndex`](crate::search::GraphIndex), by [`ShardedSearcher`],
//!   and by the thread-per-shard [`ShardPool`].
//! * [`ShardPool`] / [`ServeFront`] — the concurrent serving runtime:
//!   worker threads owning one shard group each (bit-identical to the
//!   inline fan-out), fronted by a micro-batching queue that coalesces
//!   individual queries (and exact duplicates) into batched windows.
//!
//! ## Id-space safety
//!
//! A reordered build permutes memory, so node ids exist in two spaces;
//! [`OriginalId`] and [`WorkingId`] make the distinction a type. The
//! rule: everything that crosses the `api` boundary (search results,
//! [`Index::neighbors`]) is `OriginalId`; `KnnGraph`/`BuildResult`
//! internals stay in working space. Conversions go through
//! [`Index::to_original`]/[`Index::to_working`], which own σ.
//!
//! ## End to end
//!
//! ```
//! use knng::api::{EvalOptions, IndexBuilder, OriginalId, Searcher, ShardedSearcher};
//! use knng::dataset::clustered::SynthClustered;
//! use knng::nndescent::Params;
//!
//! let (corpus, _labels) = SynthClustered::new(400, 8, 4, 42).generate_labeled();
//! let params = Params::default().with_k(8).with_seed(42).with_reorder(true);
//! let index = IndexBuilder::new()
//!     .data_named(corpus.clone(), "clustered")
//!     .params(params.clone())
//!     .build()?;
//!
//! // Serve: results are OriginalId even though the build reordered —
//! // corpus row 17's nearest neighbor is row 17 itself.
//! let query = corpus.row_logical(17).to_vec();
//! let (hits, stats) = index.search(&query, 5, &Default::default());
//! assert_eq!(hits[0].id, OriginalId(17));
//! assert!(stats.dist_evals > 0);
//!
//! // Evaluate: recall vs sampled brute force, as a standard report.
//! let report = index.evaluate(&EvalOptions::new().with_recall_queries(50).with_seed(1));
//! assert!(report.recall.unwrap() > 0.9);
//!
//! // Scale out: two independently-built shards over the same corpus.
//! // Shard from the ORIGINAL row order (a shard's input order defines
//! // its id space) — never from a reordered index's working layout.
//! let sharded = ShardedSearcher::build(&corpus, 2, &params)?;
//! let (shard_hits, _) = sharded.search(&query, 5, &Default::default());
//! assert_eq!(shard_hits[0].id, OriginalId(17));
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod builder;
pub mod front;
pub mod ids;
pub mod index;
pub mod partition;
pub mod searcher;
pub mod serve;
pub mod sharded;

pub use builder::IndexBuilder;
pub use front::{FrontConfig, FrontStats, KMismatch, QueryTicket, Served, ServeFront, WindowInfo};
pub use ids::{Neighbor, OriginalId, WorkingId};
pub use index::{BuildTelemetry, Index};
pub use partition::{Contiguous, KMeans, PartitionPlan, Partitioner, ShardPlan};
pub use searcher::{DegradeCause, Degradation, Searcher};
pub use serve::{HealthWatch, PoolConfig, PoolStats, ShardPool, ShardState};
pub use sharded::ShardedSearcher;

// The observer types live beside the driver that emits them
// (`nndescent::observer`) so the engine layer stays facade-independent;
// this is their public spelling.
pub use crate::nndescent::observer::{
    BuildEvent, BuildObserver, FnObserver, LoggingObserver, NoopObserver,
};

// Re-exported so facade users need no second import path for the
// types that flow through builder/searcher signatures.
pub use crate::pipeline::EvalOptions;
pub use crate::search::{BatchStats, QueryStats, SearchParams};
