//! Typed, fallible index construction — the facade's entry point.

use super::index::Index;
use super::sharded::ShardedSearcher;
use crate::config::schema::ComputeKind;
use crate::config::{DatasetSpec, ExperimentConfig};
use crate::dataset::AlignedMatrix;
use crate::nndescent::observer::{BuildObserver, LoggingObserver, NoopObserver};
use crate::nndescent::{BuildResult, NnDescent, Params};

/// Where the corpus comes from.
enum Source {
    /// Materialize from a dataset description at build time.
    Spec(DatasetSpec),
    /// An owned, already-materialized matrix.
    Data { data: AlignedMatrix, dataset: String },
}

/// Builds an [`Index`] (or a [`ShardedSearcher`]) from a dataset
/// description or an owned matrix. `build()` is fallible — dataset
/// materialization errors, degenerate inputs, and the `pjrt` backend
/// being unavailable all surface as `Err`, never as panics.
///
/// # Examples
///
/// ```
/// use knng::api::{IndexBuilder, Searcher};
/// use knng::config::DatasetSpec;
/// use knng::nndescent::Params;
///
/// let index = IndexBuilder::new()
///     .dataset(DatasetSpec::Clustered { n: 300, dim: 8, clusters: 4, seed: 7 })
///     .params(Params::default().with_k(8).with_seed(7))
///     .build()?;
///
/// // Results are typed OriginalId: a corpus row's nearest neighbor is itself.
/// let query = index.data().row_logical(0).to_vec();
/// let (hits, _stats) = index.search(&query, 3, &Default::default());
/// assert_eq!(hits[0].id.get(), 0);
/// # Ok::<(), anyhow::Error>(())
/// ```
///
/// Progress can be observed as typed events instead of log lines:
///
/// ```
/// use knng::api::{BuildEvent, FnObserver, IndexBuilder};
/// use knng::config::DatasetSpec;
///
/// let mut iterations = 0usize;
/// let index = IndexBuilder::new()
///     .dataset(DatasetSpec::Gaussian { n: 200, dim: 8, single: true, seed: 1 })
///     .observer(FnObserver(|e: &BuildEvent| {
///         if matches!(e, BuildEvent::Iteration { .. }) {
///             iterations += 1;
///         }
///     }))
///     .build()?;
/// assert!(iterations >= 1);
/// assert_eq!(index.len(), 200);
/// # Ok::<(), anyhow::Error>(())
/// ```
pub struct IndexBuilder<'a> {
    name: String,
    params: Params,
    artifacts_dir: String,
    source: Option<Source>,
    observer: Option<Box<dyn BuildObserver + 'a>>,
}

impl Default for IndexBuilder<'_> {
    fn default() -> Self {
        Self::new()
    }
}

impl<'a> IndexBuilder<'a> {
    /// A builder with default [`Params`] and no corpus yet.
    pub fn new() -> Self {
        Self {
            name: "api".into(),
            params: Params::default(),
            artifacts_dir: "artifacts".into(),
            source: None,
            observer: None,
        }
    }

    /// A builder preloaded from an experiment config (dataset spec,
    /// run parameters, name, artifact dir) — the CLI's path.
    pub fn from_config(cfg: &ExperimentConfig) -> Self {
        Self {
            name: cfg.name.clone(),
            params: Params::from(&cfg.run),
            artifacts_dir: cfg.run.artifacts_dir.clone(),
            source: Some(Source::Spec(cfg.dataset.clone())),
            observer: None,
        }
    }

    /// Use a dataset description, materialized at build time.
    pub fn dataset(mut self, spec: DatasetSpec) -> Self {
        self.source = Some(Source::Spec(spec));
        self
    }

    /// Use an owned, already-materialized matrix as the corpus.
    pub fn data(self, data: AlignedMatrix) -> Self {
        self.data_named(data, "matrix")
    }

    /// Like [`data`](Self::data) with an explicit dataset name for
    /// reports.
    pub fn data_named(mut self, data: AlignedMatrix, dataset: &str) -> Self {
        self.source = Some(Source::Data { data, dataset: dataset.to_string() });
        self
    }

    /// Set the build parameters (k, ρ, δ, selection, compute, reorder…).
    pub fn params(mut self, params: Params) -> Self {
        self.params = params;
        self
    }

    /// Build worker threads (shorthand for
    /// [`Params::threads`](crate::nndescent::Params::threads)): an
    /// explicit value here wins over the `PALLAS_BUILD_THREADS`
    /// environment variable; 1 pins the bit-exact sequential engine;
    /// `> 1` runs the deterministic phased parallel engine. For
    /// [`build_sharded`](Self::build_sharded) the same budget is spent
    /// across shards instead: up to `t` whole-shard builds run
    /// concurrently, each sequential inside.
    pub fn threads(mut self, t: usize) -> Self {
        self.params.threads = t;
        self
    }

    /// Name used in reports (defaults to `"api"`).
    pub fn name(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }

    /// Artifact directory for the `pjrt` compute backend.
    pub fn artifacts_dir(mut self, dir: &str) -> Self {
        self.artifacts_dir = dir.to_string();
        self
    }

    /// Install a progress observer receiving
    /// [`BuildEvent`](super::BuildEvent)s.
    pub fn observer(mut self, observer: impl BuildObserver + 'a) -> Self {
        self.observer = Some(Box::new(observer));
        self
    }

    /// Report progress through the crate logger (the CLI default).
    pub fn log_progress(self) -> Self {
        self.observer(LoggingObserver)
    }

    /// Materialize the corpus, run NN-Descent, and seal the result into
    /// an [`Index`].
    pub fn build(self) -> crate::Result<Index> {
        let Self { name, params, artifacts_dir, source, observer } = self;
        let (data, dataset) = materialize(source)?;
        anyhow::ensure!(data.n() >= 2, "need at least two points to build an index");
        let mut observer: Box<dyn BuildObserver + 'a> = match observer {
            Some(o) => o,
            None => Box::new(NoopObserver),
        };
        let result = run_build(&params, &data, &artifacts_dir, &mut *observer)?;
        Ok(Index::from_build(data, result, params, name, dataset))
    }

    /// Partition the corpus into `shards` contiguous slices, build each
    /// independently with the same parameters, and return the fanning
    /// [`ShardedSearcher`]. See [`ShardedSearcher::build`].
    pub fn build_sharded(self, shards: usize) -> crate::Result<ShardedSearcher> {
        self.build_sharded_with(shards, &crate::api::partition::Contiguous)
    }

    /// [`build_sharded`](Self::build_sharded) with an explicit
    /// [`Partitioner`](crate::api::partition::Partitioner) — e.g.
    /// [`KMeans`](crate::api::partition::KMeans) for cluster-aware
    /// shards whose queries can be centroid-routed. See
    /// [`ShardedSearcher::build_planned`].
    pub fn build_sharded_with(
        self,
        shards: usize,
        partitioner: &dyn crate::api::partition::Partitioner,
    ) -> crate::Result<ShardedSearcher> {
        let Self { name: _, params, artifacts_dir, source, observer } = self;
        let (data, _dataset) = materialize(source)?;
        let mut observer: Box<dyn BuildObserver + 'a> = match observer {
            Some(o) => o,
            None => Box::new(NoopObserver),
        };
        ShardedSearcher::build_planned(
            &data,
            shards,
            &params,
            partitioner,
            &artifacts_dir,
            &mut *observer,
        )
    }
}

fn materialize(source: Option<Source>) -> crate::Result<(AlignedMatrix, String)> {
    match source {
        None => anyhow::bail!(
            "no corpus configured: call IndexBuilder::dataset(spec) or IndexBuilder::data(matrix)"
        ),
        Some(Source::Data { data, dataset }) => Ok((data, dataset)),
        Some(Source::Spec(spec)) => {
            let ds = crate::dataset::from_spec(&spec)?;
            Ok((ds.data, ds.name))
        }
    }
}

/// Dispatch one build over the configured compute backend, absorbing
/// the historical pjrt panic into a `Result`.
pub(crate) fn run_build(
    params: &Params,
    data: &AlignedMatrix,
    artifacts_dir: &str,
    observer: &mut dyn BuildObserver,
) -> crate::Result<BuildResult> {
    let nnd = NnDescent::new(params.clone());
    if params.compute == ComputeKind::Pjrt {
        build_pjrt(&nnd, data, artifacts_dir, observer)
    } else {
        nnd.build_observed(data, observer)
    }
}

/// Build through the PJRT engine (pjrt feature on).
#[cfg(feature = "pjrt")]
fn build_pjrt(
    nnd: &NnDescent,
    data: &AlignedMatrix,
    artifacts_dir: &str,
    observer: &mut dyn BuildObserver,
) -> crate::Result<BuildResult> {
    let mut engine = crate::runtime::PjrtEngine::open(artifacts_dir)?;
    let r = nnd.build_with_engine_observed(
        data,
        &mut engine,
        &mut crate::cachesim::trace::NoTracer,
        observer,
    );
    crate::log_info!(
        "pjrt engine: {} executions, {} rows gathered",
        engine.executions,
        engine.rows_gathered
    );
    Ok(r)
}

/// The pjrt feature is off: fail with an actionable message instead of
/// a missing-module compile error.
#[cfg(not(feature = "pjrt"))]
fn build_pjrt(
    _nnd: &NnDescent,
    _data: &AlignedMatrix,
    _artifacts_dir: &str,
    _observer: &mut dyn BuildObserver,
) -> crate::Result<BuildResult> {
    anyhow::bail!(
        "compute backend `pjrt` requires the `pjrt` cargo feature \
         (rebuild with `--features pjrt` and vendor the `xla` crate); \
         the native backends are scalar|unrolled|blocked"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{BuildEvent, FnObserver};

    #[test]
    fn build_without_a_corpus_is_an_error() {
        let err = IndexBuilder::new().build().unwrap_err().to_string();
        assert!(err.contains("no corpus"), "unexpected error: {err}");
    }

    #[test]
    fn build_rejects_degenerate_corpora() {
        let data = AlignedMatrix::zeroed(1, 8);
        let err = IndexBuilder::new().data(data).build().unwrap_err().to_string();
        assert!(err.contains("two points"), "unexpected error: {err}");
    }

    #[test]
    fn pjrt_backend_fails_cleanly_without_the_feature() {
        // absorbing the historical assert: Err, not panic
        let spec = DatasetSpec::Gaussian { n: 64, dim: 8, single: true, seed: 1 };
        let params = Params::default().with_k(4).with_compute(ComputeKind::Pjrt);
        let res = IndexBuilder::new().dataset(spec).params(params).build();
        if cfg!(feature = "pjrt") {
            // artifacts are absent in tests either way; only the message differs
            assert!(res.is_err());
        } else {
            let err = res.unwrap_err().to_string();
            assert!(err.contains("pjrt"), "unexpected error: {err}");
        }
    }

    #[test]
    fn from_config_carries_name_and_params() {
        let cfg = ExperimentConfig {
            name: "cfg-name".into(),
            dataset: DatasetSpec::Clustered { n: 300, dim: 8, clusters: 4, seed: 3 },
            run: crate::config::RunConfig { k: 6, ..Default::default() },
        };
        let index = IndexBuilder::from_config(&cfg).build().unwrap();
        assert_eq!(index.name(), "cfg-name");
        assert_eq!(index.params().k, 6);
        assert_eq!(index.len(), 300);
        assert!(index.dataset().contains("clustered"));
    }

    #[test]
    fn observer_and_telemetry_agree() {
        let mut events = Vec::new();
        let index = IndexBuilder::new()
            .dataset(DatasetSpec::Gaussian { n: 250, dim: 8, single: true, seed: 5 })
            .params(Params::default().with_k(6).with_seed(5))
            .observer(FnObserver(|e: &BuildEvent| events.push(*e)))
            .build()
            .unwrap();
        let t = index.telemetry().expect("built indexes carry telemetry");
        let iter_events = events.iter().filter(|e| matches!(e, BuildEvent::Iteration { .. }));
        assert_eq!(iter_events.count(), t.iterations);
    }
}
