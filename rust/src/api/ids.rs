//! Newtype node ids enforcing the reorder permutation at the type level.
//!
//! A build with the greedy reordering heuristic (paper §3.2) physically
//! permutes the data matrix and graph, so every node lives in **two** id
//! spaces at once:
//!
//! * [`OriginalId`] — the row index in the dataset as the caller supplied
//!   it. This is the only id space that crosses the `api` boundary:
//!   every [`Searcher`](super::Searcher) result is an `OriginalId`.
//! * [`WorkingId`] — the position after the reorder permutation σ, i.e.
//!   the id space `KnnGraph`, `BuildResult`, and the bundled data matrix
//!   use internally (and the layout the blocked kernels iterate over).
//!
//! Keeping the two as distinct types means "forgot to map through σ" is
//! a compile error instead of a silently-wrong neighbor list. Convert
//! only through [`Index::to_original`](super::Index::to_original) /
//! [`Index::to_working`](super::Index::to_working), which own σ.

use std::fmt;

/// Node id in the caller's original dataset order (row index as fed to
/// the builder). The only id space exposed by `api` search results.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct OriginalId(pub u32);

/// Node id in the build's *working* layout (after the reorder
/// permutation σ; identical to [`OriginalId`] when no reorder ran).
/// Internal to `KnnGraph`/`BuildResult`; never returned by a
/// [`Searcher`](super::Searcher).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct WorkingId(pub u32);

impl OriginalId {
    /// The raw index value.
    #[inline]
    pub fn get(self) -> u32 {
        self.0
    }
    /// The raw index as a usize (for slice indexing).
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl WorkingId {
    /// The raw index value.
    #[inline]
    pub fn get(self) -> u32 {
        self.0
    }
    /// The raw index as a usize (for slice indexing).
    #[inline]
    pub fn index(self) -> usize {
        self.0 as usize
    }
}

impl fmt::Display for OriginalId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for WorkingId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// One search result at the `api` boundary: a neighbor in the caller's
/// original id space plus its squared-L2 distance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Neighbor {
    /// Neighbor id, always original dataset order.
    pub id: OriginalId,
    /// Squared-L2 distance to the query.
    pub dist: f32,
}

impl Neighbor {
    /// Construct from a raw (id, distance) pair already in original space.
    #[inline]
    pub fn new(id: u32, dist: f32) -> Self {
        Self { id: OriginalId(id), dist }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_distinct_types_with_raw_access() {
        let o = OriginalId(7);
        let w = WorkingId(7);
        assert_eq!(o.get(), w.get());
        assert_eq!(o.index(), 7);
        assert_eq!(format!("{o}/{w}"), "7/7");
    }

    #[test]
    fn neighbor_orders_naturally() {
        let a = Neighbor::new(3, 1.5);
        assert_eq!(a.id, OriginalId(3));
        assert_eq!(a, Neighbor { id: OriginalId(3), dist: 1.5 });
    }
}
