//! The serving abstraction: anything that can answer K-NN queries in
//! the caller's original id space.
//!
//! Implementations in this crate:
//!
//! * [`GraphIndex`] — a single in-memory graph over one corpus. A bare
//!   `GraphIndex` has no reorder permutation, so its working ids *are*
//!   the row ids of the data it was constructed with; results pass
//!   through unmapped.
//! * [`Index`](super::Index) — a built (possibly reordered) index; maps
//!   every result back through σ⁻¹ before it crosses the boundary.
//! * [`ShardedSearcher`](super::ShardedSearcher) — S independently-built
//!   shards with per-shard offset mapping and a global top-k merge.

use super::ids::{Neighbor, OriginalId};
use crate::dataset::AlignedMatrix;
use crate::search::{BatchStats, GraphIndex, QueryStats, SearchParams};
use std::time::Instant;

/// Why a degraded answer is missing shards, ordered by severity
/// (ascending): a deadline miss is transient by nature, a lost reply
/// or contained panic is a one-off fault, a dead shard is permanent
/// until the pool is rebuilt. When several causes apply to one answer,
/// the record carries the most severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum DegradeCause {
    /// The deadline budget expired before every shard replied; the
    /// missing shards were alive but late.
    DeadlineExpired,
    /// A shard's reply was lost in flight (its worker stayed alive).
    ReplyLost,
    /// A shard's search panicked; the worker contained it and answered
    /// with a typed failure instead of results.
    ShardPanicked,
    /// The shard's worker died and its respawn budget is exhausted —
    /// the shard is permanently out of the fan-out.
    ShardDead,
}

impl DegradeCause {
    /// Wire byte for this cause (`KNNQv1` degraded-results frames).
    pub fn as_u8(self) -> u8 {
        match self {
            Self::DeadlineExpired => 1,
            Self::ReplyLost => 2,
            Self::ShardPanicked => 3,
            Self::ShardDead => 4,
        }
    }

    /// Decode a wire byte; `None` for bytes this build does not know.
    pub fn from_u8(byte: u8) -> Option<Self> {
        match byte {
            1 => Some(Self::DeadlineExpired),
            2 => Some(Self::ReplyLost),
            3 => Some(Self::ShardPanicked),
            4 => Some(Self::ShardDead),
            _ => None,
        }
    }
}

impl std::fmt::Display for DegradeCause {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::DeadlineExpired => "deadline expired",
            Self::ReplyLost => "shard reply lost",
            Self::ShardPanicked => "shard search panicked",
            Self::ShardDead => "shard permanently dead",
        })
    }
}

/// A typed record that an answer was served from a *partial* fan-out:
/// the listed shards contributed nothing to the merge. The neighbors
/// returned alongside it are exactly the honest reduced fan-out over
/// the surviving shards (see
/// [`ShardedSearcher::search_batch_subset`](super::ShardedSearcher::search_batch_subset),
/// which defines that reference semantics) — degraded answers are
/// principled, not best-effort garbage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Degradation {
    /// Shard slots missing from the merge, ascending, deduplicated.
    pub shards_missing: Vec<u32>,
    /// How many replicas of each missing shard were dispatched to
    /// before giving up, parallel to
    /// [`shards_missing`](Self::shards_missing). `0` means the shard
    /// was never dispatchable at all (every copy already dead, or the
    /// deadline expired before dispatch); with a replicated pool a
    /// value equal to R says the whole replica set was exhausted.
    /// Unreplicated searchers report `1` per missing shard.
    pub replicas_tried: Vec<u32>,
    /// The most severe reason among the missing shards.
    pub cause: DegradeCause,
}

impl std::fmt::Display for Degradation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "degraded: {} (missing shards {:?})", self.cause, self.shards_missing)
    }
}

/// An ANN query server over a fixed corpus. All results are
/// [`OriginalId`]-typed: implementations own whatever id mapping their
/// internal layout requires, so callers never see working ids.
pub trait Searcher {
    /// Number of points this searcher can return.
    fn len(&self) -> usize;

    /// True when the searcher holds no points.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `k` nearest neighbors of `query` (logical or padded row),
    /// ascending by distance, ids in the original dataset order.
    fn search(
        &self,
        query: &[f32],
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Neighbor>, QueryStats);

    /// Serve a batch of queries (rows of `queries`) through the blocked
    /// kernels; per-query results plus aggregate stats.
    fn search_batch(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats);

    /// [`search_batch`](Self::search_batch) with a shared, owned tile.
    /// The default just borrows the tile — results are identical by
    /// construction. Implementations that hand the batch to worker
    /// threads (the thread-per-shard [`ShardPool`](super::ShardPool))
    /// override this to share the `Arc` directly instead of cloning the
    /// tile to make it `'static`, which removes the second copy from
    /// the front-end → pool hot path.
    fn search_batch_owned(
        &self,
        queries: std::sync::Arc<AlignedMatrix>,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        self.search_batch(&queries, k, params)
    }

    /// [`search_batch`](Self::search_batch) with centroid routing: fan
    /// each query out to at most `top_m` shards (nearest partition
    /// centroids first). Searchers without a shard/routing structure —
    /// a single [`GraphIndex`] or [`Index`](super::Index) — have
    /// nothing to route over, so the default ignores `top_m` and serves
    /// the full batch; sharded implementations
    /// ([`ShardedSearcher`](super::ShardedSearcher),
    /// [`ShardPool`](super::ShardPool)) override it. `top_m ≥ S` is
    /// always exactly [`search_batch`](Self::search_batch).
    fn search_batch_routed(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
        top_m: usize,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        let _ = top_m;
        self.search_batch(queries, k, params)
    }

    /// [`search_batch_routed`](Self::search_batch_routed) with a
    /// shared, owned tile (the micro-batching front-end's routed entry
    /// point). Same override contract as
    /// [`search_batch_owned`](Self::search_batch_owned).
    fn search_batch_routed_owned(
        &self,
        queries: std::sync::Arc<AlignedMatrix>,
        k: usize,
        params: &SearchParams,
        top_m: usize,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        self.search_batch_routed(&queries, k, params, top_m)
    }

    /// Deadline-bounded batch entry point (the micro-batching front's
    /// one call site): serve the tile like
    /// [`search_batch_owned`](Self::search_batch_owned) /
    /// [`search_batch_routed_owned`](Self::search_batch_routed_owned),
    /// but give up on shards that have not answered by `deadline` and
    /// report what was dropped as a typed [`Degradation`].
    ///
    /// The default implementation cannot preempt anything — an inline
    /// searcher runs on the calling thread — so it ignores the deadline
    /// and always returns a full, never-degraded answer, bit-identical
    /// to the plain entry points. The thread-per-shard
    /// [`ShardPool`](super::ShardPool) overrides this with bounded
    /// reply collection; with `deadline = None` and a healthy pool its
    /// answers remain bit-identical to the plain path too (asserted by
    /// the chaos suite).
    fn search_batch_deadline_owned(
        &self,
        queries: std::sync::Arc<AlignedMatrix>,
        k: usize,
        params: &SearchParams,
        route_top_m: Option<usize>,
        deadline: Option<Instant>,
    ) -> (Vec<Vec<Neighbor>>, BatchStats, Option<Degradation>) {
        let _ = deadline;
        let (results, stats) = match route_top_m {
            Some(m) => self.search_batch_routed_owned(queries, k, params, m),
            None => self.search_batch_owned(queries, k, params),
        };
        (results, stats, None)
    }

    /// A live handle onto this searcher's worker-pool health, if it
    /// has one. The default is `None`: inline searchers have no
    /// workers to supervise. [`ShardPool`](super::ShardPool) returns a
    /// watch that stays valid after the pool moves onto a front's
    /// dispatcher thread, which is how the serving edge (and the
    /// `KNNQv1` health frame) reads per-shard liveness.
    fn health_watch(&self) -> Option<super::serve::HealthWatch> {
        None
    }

    /// A monotone epoch that advances whenever this searcher's answers
    /// may change. `None` (the default) means the corpus is immutable
    /// — cached answers never go stale. Mutable searchers
    /// ([`SharedMutableIndex`](crate::store::SharedMutableIndex))
    /// return `Some(epoch)` bumped on every applied insert, delete,
    /// and compaction; the micro-batching front's answer cache flushes
    /// itself whenever the epoch moves, which is what makes caching
    /// safe over a mutating store.
    fn cache_epoch(&self) -> Option<u64> {
        None
    }
}

/// Map a raw working-space result list into the boundary type without
/// remapping (identity id spaces).
pub(crate) fn neighbors_identity(raw: Vec<(u32, f32)>) -> Vec<Neighbor> {
    raw.into_iter().map(|(v, d)| Neighbor { id: OriginalId(v), dist: d }).collect()
}

impl Searcher for GraphIndex {
    fn len(&self) -> usize {
        self.n()
    }

    fn search(
        &self,
        query: &[f32],
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Neighbor>, QueryStats) {
        // A bare GraphIndex carries no permutation: its graph/data id
        // space is the caller's row space, so the mapping is identity.
        let (raw, stats) = GraphIndex::search(self, query, k, params);
        (neighbors_identity(raw), stats)
    }

    fn search_batch(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        let (raw, stats) = GraphIndex::search_batch(self, queries, k, params);
        (raw.into_iter().map(neighbors_identity).collect(), stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::clustered::SynthClustered;
    use crate::nndescent::{NnDescent, Params};

    #[test]
    fn graph_index_results_pass_through_as_original_ids() {
        let (data, _) = SynthClustered::new(400, 8, 4, 5).generate_labeled();
        let result = NnDescent::new(Params::default().with_k(8).with_seed(5)).build(&data).unwrap();
        let idx = GraphIndex::new(data.clone(), result.graph);

        let sp = SearchParams::default();
        for qi in (0..400).step_by(67) {
            // the trait result must be the inherent result, retyped
            let (raw, raw_stats) = GraphIndex::search(&idx, data.row_logical(qi), 5, &sp);
            let (typed, typed_stats) = Searcher::search(&idx, data.row_logical(qi), 5, &sp);
            assert_eq!(raw_stats, typed_stats);
            assert_eq!(raw.len(), typed.len());
            for (r, t) in raw.iter().zip(&typed) {
                assert_eq!(t.id, OriginalId(r.0));
                assert_eq!(t.dist.to_bits(), r.1.to_bits());
            }
            assert_eq!(typed[0].id, OriginalId(qi as u32), "self is the top hit");
        }
        assert_eq!(Searcher::len(&idx), 400);
        assert!(!idx.is_empty());
    }

    #[test]
    fn degrade_cause_round_trips_and_orders_by_severity() {
        for cause in [
            DegradeCause::DeadlineExpired,
            DegradeCause::ReplyLost,
            DegradeCause::ShardPanicked,
            DegradeCause::ShardDead,
        ] {
            assert_eq!(DegradeCause::from_u8(cause.as_u8()), Some(cause));
        }
        assert_eq!(DegradeCause::from_u8(0), None);
        assert_eq!(DegradeCause::from_u8(9), None);
        // severity ordering is what the single `cause` field of a mixed
        // degradation reports (the max)
        assert!(DegradeCause::DeadlineExpired < DegradeCause::ReplyLost);
        assert!(DegradeCause::ReplyLost < DegradeCause::ShardPanicked);
        assert!(DegradeCause::ShardPanicked < DegradeCause::ShardDead);
    }

    #[test]
    fn default_deadline_entry_point_is_the_plain_path() {
        use std::sync::Arc;
        use std::time::{Duration, Instant};
        let (data, _) = SynthClustered::new(300, 8, 4, 9).generate_labeled();
        let result = NnDescent::new(Params::default().with_k(8).with_seed(9)).build(&data).unwrap();
        let idx = GraphIndex::new(data.clone(), result.graph);
        let sp = SearchParams::default();
        let rows: Vec<f32> = (0..3).flat_map(|i| data.row_logical(i * 50).to_vec()).collect();
        let tile = Arc::new(AlignedMatrix::from_rows(3, data.dim(), &rows));
        let (expect, _) = idx.search_batch_owned(Arc::clone(&tile), 4, &sp);
        // an already-expired deadline cannot degrade an inline searcher
        let past = Instant::now() - Duration::from_secs(1);
        let (got, _, degr) =
            idx.search_batch_deadline_owned(tile, 4, &sp, None, Some(past));
        assert!(degr.is_none(), "inline searchers never degrade");
        crate::testing::assert_neighbors_bitwise_eq(&expect, &got, "default deadline path");
        assert!(Searcher::health_watch(&idx).is_none());
    }
}
