//! The serving abstraction: anything that can answer K-NN queries in
//! the caller's original id space.
//!
//! Implementations in this crate:
//!
//! * [`GraphIndex`] — a single in-memory graph over one corpus. A bare
//!   `GraphIndex` has no reorder permutation, so its working ids *are*
//!   the row ids of the data it was constructed with; results pass
//!   through unmapped.
//! * [`Index`](super::Index) — a built (possibly reordered) index; maps
//!   every result back through σ⁻¹ before it crosses the boundary.
//! * [`ShardedSearcher`](super::ShardedSearcher) — S independently-built
//!   shards with per-shard offset mapping and a global top-k merge.

use super::ids::{Neighbor, OriginalId};
use crate::dataset::AlignedMatrix;
use crate::search::{BatchStats, GraphIndex, QueryStats, SearchParams};

/// An ANN query server over a fixed corpus. All results are
/// [`OriginalId`]-typed: implementations own whatever id mapping their
/// internal layout requires, so callers never see working ids.
pub trait Searcher {
    /// Number of points this searcher can return.
    fn len(&self) -> usize;

    /// True when the searcher holds no points.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The `k` nearest neighbors of `query` (logical or padded row),
    /// ascending by distance, ids in the original dataset order.
    fn search(
        &self,
        query: &[f32],
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Neighbor>, QueryStats);

    /// Serve a batch of queries (rows of `queries`) through the blocked
    /// kernels; per-query results plus aggregate stats.
    fn search_batch(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats);

    /// [`search_batch`](Self::search_batch) with a shared, owned tile.
    /// The default just borrows the tile — results are identical by
    /// construction. Implementations that hand the batch to worker
    /// threads (the thread-per-shard [`ShardPool`](super::ShardPool))
    /// override this to share the `Arc` directly instead of cloning the
    /// tile to make it `'static`, which removes the second copy from
    /// the front-end → pool hot path.
    fn search_batch_owned(
        &self,
        queries: std::sync::Arc<AlignedMatrix>,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        self.search_batch(&queries, k, params)
    }

    /// [`search_batch`](Self::search_batch) with centroid routing: fan
    /// each query out to at most `top_m` shards (nearest partition
    /// centroids first). Searchers without a shard/routing structure —
    /// a single [`GraphIndex`] or [`Index`](super::Index) — have
    /// nothing to route over, so the default ignores `top_m` and serves
    /// the full batch; sharded implementations
    /// ([`ShardedSearcher`](super::ShardedSearcher),
    /// [`ShardPool`](super::ShardPool)) override it. `top_m ≥ S` is
    /// always exactly [`search_batch`](Self::search_batch).
    fn search_batch_routed(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
        top_m: usize,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        let _ = top_m;
        self.search_batch(queries, k, params)
    }

    /// [`search_batch_routed`](Self::search_batch_routed) with a
    /// shared, owned tile (the micro-batching front-end's routed entry
    /// point). Same override contract as
    /// [`search_batch_owned`](Self::search_batch_owned).
    fn search_batch_routed_owned(
        &self,
        queries: std::sync::Arc<AlignedMatrix>,
        k: usize,
        params: &SearchParams,
        top_m: usize,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        self.search_batch_routed(&queries, k, params, top_m)
    }
}

/// Map a raw working-space result list into the boundary type without
/// remapping (identity id spaces).
pub(crate) fn neighbors_identity(raw: Vec<(u32, f32)>) -> Vec<Neighbor> {
    raw.into_iter().map(|(v, d)| Neighbor { id: OriginalId(v), dist: d }).collect()
}

impl Searcher for GraphIndex {
    fn len(&self) -> usize {
        self.n()
    }

    fn search(
        &self,
        query: &[f32],
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Neighbor>, QueryStats) {
        // A bare GraphIndex carries no permutation: its graph/data id
        // space is the caller's row space, so the mapping is identity.
        let (raw, stats) = GraphIndex::search(self, query, k, params);
        (neighbors_identity(raw), stats)
    }

    fn search_batch(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        let (raw, stats) = GraphIndex::search_batch(self, queries, k, params);
        (raw.into_iter().map(neighbors_identity).collect(), stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::clustered::SynthClustered;
    use crate::nndescent::{NnDescent, Params};

    #[test]
    fn graph_index_results_pass_through_as_original_ids() {
        let (data, _) = SynthClustered::new(400, 8, 4, 5).generate_labeled();
        let result = NnDescent::new(Params::default().with_k(8).with_seed(5)).build(&data).unwrap();
        let idx = GraphIndex::new(data.clone(), result.graph);

        let sp = SearchParams::default();
        for qi in (0..400).step_by(67) {
            // the trait result must be the inherent result, retyped
            let (raw, raw_stats) = GraphIndex::search(&idx, data.row_logical(qi), 5, &sp);
            let (typed, typed_stats) = Searcher::search(&idx, data.row_logical(qi), 5, &sp);
            assert_eq!(raw_stats, typed_stats);
            assert_eq!(raw.len(), typed.len());
            for (r, t) in raw.iter().zip(&typed) {
                assert_eq!(t.id, OriginalId(r.0));
                assert_eq!(t.dist.to_bits(), r.1.to_bits());
            }
            assert_eq!(typed[0].id, OriginalId(qi as u32), "self is the top hit");
        }
        assert_eq!(Searcher::len(&idx), 400);
        assert!(!idx.is_empty());
    }
}
