//! The sealed product of a build: a servable index that owns the
//! working-layout data, the graph, the reorder permutation, and the
//! build telemetry — and never leaks a working id.

use super::ids::{Neighbor, OriginalId, WorkingId};
use super::searcher::Searcher;
use crate::dataset::AlignedMatrix;
use crate::graph::KnnGraph;
use crate::nndescent::reorder::Reordering;
use crate::nndescent::{BuildResult, Params};
use crate::pipeline::{EvalOptions, RunReport};
use crate::search::{BatchStats, GraphIndex, QueryStats, SearchParams};
use crate::util::counters::{FlopCounter, IterStats};
use std::path::Path;

/// What the build loop recorded (absent on indexes reloaded from a
/// `KNNIv1` bundle, which is a finished artifact, not a resumable run).
#[derive(Debug, Clone, Default)]
pub struct BuildTelemetry {
    /// NN-Descent iterations executed.
    pub iterations: usize,
    /// Per-iteration timing/work breakdown.
    pub per_iter: Vec<IterStats>,
    /// Total distance-evaluation / flop accounting.
    pub stats: FlopCounter,
    /// Wall time of the whole build, seconds.
    pub total_secs: f64,
}

/// A built (or reloaded) K-NN index: the crate's primary serving object.
///
/// Internally the graph and data live in the *working* id space — the
/// layout the greedy reorder produced, which is also the layout the
/// blocked kernels want. Externally every neighbor id is an
/// [`OriginalId`]: the [`Searcher`] impl maps results through σ⁻¹, and
/// [`Index::to_original`]/[`Index::to_working`] are the only doors
/// between the two spaces.
pub struct Index {
    core: GraphIndex,
    reordering: Option<Reordering>,
    params: Params,
    name: String,
    dataset: String,
    telemetry: Option<BuildTelemetry>,
    /// Partition centroids carried by a sharded bundle (one row per
    /// shard of the sharded index this bundle belongs to); `None` for
    /// plain single-index builds and legacy bundles.
    centroids: Option<AlignedMatrix>,
}

impl Index {
    /// Seal a finished build into an index. `data_original` is the
    /// dataset in the caller's row order; it is permuted into the
    /// working layout here when the build reordered.
    pub(crate) fn from_build(
        data_original: AlignedMatrix,
        result: BuildResult,
        params: Params,
        name: String,
        dataset: String,
    ) -> Self {
        let working = result.working_data(data_original);
        let BuildResult { graph, iterations, per_iter, stats, reordering, total_secs } = result;
        Self {
            core: GraphIndex::new(working, graph),
            reordering,
            params,
            name,
            dataset,
            telemetry: Some(BuildTelemetry { iterations, per_iter, stats, total_secs }),
            centroids: None,
        }
    }

    /// Reload an index from a `KNNIv1` bundle written by [`Index::save`]
    /// (or the CLI's `build --save-index`). Bundles without a persisted
    /// norms section (pre-norms artifacts) stay loadable — the corpus
    /// norms for the norm-trick serving path are recomputed from the
    /// data section.
    pub fn load(path: &Path) -> crate::Result<Self> {
        let mut bundle = crate::search::load_index(path)?;
        let name = path.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default();
        let centroids = bundle.centroids.take();
        let (core, reordering, params) = bundle.into_index();
        Ok(Self {
            core,
            reordering,
            params,
            dataset: name.clone(),
            name,
            telemetry: None,
            centroids,
        })
    }

    /// Persist as a checksummed `KNNIv1` bundle (graph + working-layout
    /// data + σ + corpus norms + build params).
    pub fn save(&self, path: &Path) -> crate::Result<()> {
        crate::search::bundle::save_index_parts(
            path,
            self.core.data(),
            self.core.graph(),
            self.reordering.as_ref(),
            &self.params,
            Some((self.core.norms(), self.core.norm_lanes())),
            self.centroids.as_ref(),
        )
    }

    /// Persist as a zero-copy-servable `KNNIv2` segment (the storage
    /// engine's format — see [`crate::store`]): padded data rows,
    /// 64-byte-aligned sections, and the reorder σ⁻¹ flattened into an
    /// idmap. Open it with
    /// [`MutableIndex::open`](crate::store::MutableIndex::open) or
    /// `knng store`.
    pub fn save_segment(&self, path: &Path) -> crate::Result<()> {
        let idmap = self.reordering.as_ref().map(|r| r.inv.clone());
        crate::store::format::write_segment(
            path,
            &crate::store::SegmentSpec {
                data: self.core.data(),
                ids: self.core.graph().flat_ids(),
                dists: self.core.graph().flat_dists(),
                k: self.core.graph().k(),
                params: &self.params,
                norms: Some((self.core.norms(), self.core.norm_lanes())),
                idmap: idmap.as_deref(),
                centroids: self.centroids.as_ref(),
                generation: 0,
            },
        )
    }

    /// Persist just the graph, in the *original* id space (undoes any
    /// reordering) — the legacy `KNNGv1` artifact.
    pub fn save_graph(&self, path: &Path) -> crate::Result<()> {
        let graph = match &self.reordering {
            Some(r) => self.core.graph().apply_permutation(&r.inv),
            None => self.core.graph().clone(),
        };
        crate::graph::save_graph(path, &graph)
    }

    /// Number of indexed points.
    pub fn len(&self) -> usize {
        self.core.n()
    }

    /// True when the index holds no points (never, in practice: builds
    /// require at least two).
    pub fn is_empty(&self) -> bool {
        self.core.n() == 0
    }

    /// Logical dimensionality of the indexed vectors.
    pub fn dim(&self) -> usize {
        self.core.data().dim()
    }

    /// Neighbors per node in the stored graph.
    pub fn graph_k(&self) -> usize {
        self.core.graph().k()
    }

    /// Parameters the graph was built with.
    pub fn params(&self) -> &Params {
        &self.params
    }

    /// True when the build ran the greedy reorder (σ present).
    pub fn is_reordered(&self) -> bool {
        self.reordering.is_some()
    }

    /// Run name (config name, or file stem for loaded bundles).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Dataset name the index was built from.
    pub fn dataset(&self) -> &str {
        &self.dataset
    }

    /// Build telemetry (None for indexes reloaded from a bundle).
    pub fn telemetry(&self) -> Option<&BuildTelemetry> {
        self.telemetry.as_ref()
    }

    /// Partition centroids carried by a sharded bundle (`None` for
    /// plain builds and legacy bundles).
    pub fn centroids(&self) -> Option<&AlignedMatrix> {
        self.centroids.as_ref()
    }

    /// Attach the partition centroids of the sharded index this bundle
    /// belongs to (persisted by [`save`](Self::save)).
    pub(crate) fn set_centroids(&mut self, centroids: AlignedMatrix) {
        self.centroids = Some(centroids);
    }

    /// The data matrix in the working layout (row `w` is working id `w`).
    pub fn data(&self) -> &AlignedMatrix {
        self.core.data()
    }

    /// Recompute the corpus norms at the current active kernel width
    /// (see [`GraphIndex::refresh_norms`]); needed after
    /// `distance::dispatch::force` switches widths mid-process.
    pub fn refresh_norms(&mut self) {
        self.core.refresh_norms();
    }

    /// The underlying graph (working id space — see [`WorkingId`]).
    pub fn graph(&self) -> &KnnGraph {
        self.core.graph()
    }

    /// Map a working id to the caller's original id (σ⁻¹).
    #[inline]
    pub fn to_original(&self, w: WorkingId) -> OriginalId {
        match &self.reordering {
            Some(r) => OriginalId(r.inv[w.index()]),
            None => OriginalId(w.get()),
        }
    }

    /// Map an original id to its working position (σ).
    #[inline]
    pub fn to_working(&self, o: OriginalId) -> WorkingId {
        match &self.reordering {
            Some(r) => WorkingId(r.sigma[o.index()]),
            None => WorkingId(o.get()),
        }
    }

    /// Graph neighbors of original node `u`, mapped back to original
    /// ids, ascending by distance.
    pub fn neighbors(&self, u: OriginalId) -> Vec<Neighbor> {
        let w = self.to_working(u);
        self.core
            .graph()
            .sorted(w.index())
            .into_iter()
            .map(|(v, d)| Neighbor { id: self.to_original(WorkingId(v)), dist: d })
            .collect()
    }

    /// Score the index against sampled brute-force ground truth and
    /// assemble the standard [`RunReport`] (the facade replacement for
    /// `pipeline::run_experiment`). With `eval.recall_queries == 0` the
    /// recall stage is skipped.
    ///
    /// Indexes reloaded from a bundle carry no build telemetry
    /// ([`telemetry`](Self::telemetry) is `None`), so their reports
    /// render the build metrics (iterations, seconds, evals, flops,
    /// updates) as zero; recall is still measured live.
    pub fn evaluate(&self, eval: &EvalOptions) -> RunReport {
        let recall = if eval.recall_queries > 0 {
            let truth = crate::baseline::brute::brute_force_knn_sampled(
                self.core.data(),
                self.graph_k(),
                eval.recall_queries,
                eval.seed,
            );
            Some(crate::metrics::recall::recall_of_graph(self.core.graph(), &truth))
        } else {
            None
        };
        let t = self.telemetry.clone().unwrap_or_default();
        // Builds record the width their counters ran on; PJRT builds
        // ran on the PJRT backend regardless of the native width, and
        // reloaded bundles carry no telemetry — report the serving
        // width for those.
        let kernel = if self.params.compute == crate::config::schema::ComputeKind::Pjrt {
            "pjrt"
        } else if t.stats.kernel.is_empty() {
            crate::distance::dispatch::active_width().name()
        } else {
            t.stats.kernel
        };
        RunReport {
            name: self.name.clone(),
            dataset: self.dataset.clone(),
            n: self.len(),
            dim: self.dim(),
            k: self.params.k,
            selection: self.params.selection.name(),
            compute: self.params.compute.name(),
            kernel,
            reordered: self.is_reordered(),
            iterations: t.iterations,
            total_secs: t.total_secs,
            dist_evals: t.stats.dist_evals,
            flops: t.stats.flops(),
            updates: t.per_iter.iter().map(|s| s.updates).sum(),
            recall,
            per_iter: t.per_iter,
        }
    }

    /// Decompose into the serving core + σ — what
    /// [`ShardedSearcher::from_index`](super::ShardedSearcher::from_index)
    /// uses to re-wrap a loaded bundle as a single shard (name, dataset,
    /// and telemetry are presentation-only and dropped; the centroids —
    /// if the bundle carried any — ride along for routed serving).
    pub(crate) fn into_core_parts(self) -> (GraphIndex, Option<Reordering>, Option<AlignedMatrix>) {
        (self.core, self.reordering, self.centroids)
    }

    /// Decompose back into a [`BuildResult`] (graph in working space +
    /// σ + telemetry), dropping the data matrix. Exists for the
    /// deprecated `pipeline` shims; facade users should not need it.
    pub fn into_build_result(self) -> BuildResult {
        let t = self.telemetry.unwrap_or_default();
        let (_data, graph) = self.core.into_parts();
        BuildResult {
            graph,
            iterations: t.iterations,
            per_iter: t.per_iter,
            stats: t.stats,
            reordering: self.reordering,
            total_secs: t.total_secs,
        }
    }
}

impl Searcher for Index {
    fn len(&self) -> usize {
        Index::len(self)
    }

    fn search(
        &self,
        query: &[f32],
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Neighbor>, QueryStats) {
        let (raw, stats) = self.core.search(query, k, params);
        (self.map_results(raw), stats)
    }

    fn search_batch(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        let (raw, stats) = self.core.search_batch(queries, k, params);
        (raw.into_iter().map(|r| self.map_results(r)).collect(), stats)
    }
}

impl Index {
    fn map_results(&self, raw: Vec<(u32, f32)>) -> Vec<Neighbor> {
        let mut out: Vec<Neighbor> = raw
            .into_iter()
            .map(|(v, d)| Neighbor { id: self.to_original(WorkingId(v)), dist: d })
            .collect();
        // Canonical boundary order is (distance, original id). The beam
        // core breaks distance ties by *working* id — an internal
        // artifact of σ — so a reordered index must re-sort after the
        // id mapping or tied neighbors would surface in layout order
        // (and diverge from the sharded/threaded serving paths, which
        // all merge by original id). Without σ the spaces coincide and
        // the list is already in canonical order.
        if self.reordering.is_some() {
            out.sort_unstable_by(|a, b| {
                a.dist.total_cmp(&b.dist).then(a.id.get().cmp(&b.id.get()))
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::clustered::SynthClustered;

    fn built(n: usize, reorder: bool, seed: u64) -> (Index, AlignedMatrix) {
        let (data, _) = SynthClustered::new(n, 8, 4, seed).generate_labeled();
        let params = Params::default().with_k(8).with_seed(seed).with_reorder(reorder);
        let result = crate::nndescent::NnDescent::new(params.clone()).build(&data).unwrap();
        (
            Index::from_build(data.clone(), result, params, "t".into(), "clustered".into()),
            data,
        )
    }

    #[test]
    fn id_mapping_roundtrips_and_results_are_original_space() {
        let (idx, data) = built(500, true, 9);
        assert!(idx.is_reordered());
        for u in (0..500u32).step_by(41) {
            let o = OriginalId(u);
            assert_eq!(idx.to_original(idx.to_working(o)), o, "σ⁻¹∘σ = id");
            // searching with an original row must find that row as top hit
            let (res, _) = idx.search(data.row_logical(u as usize), 3, &SearchParams::default());
            assert_eq!(res[0].id, o, "top hit is the query row, in original ids");
            assert!(res[0].dist < 1e-6);
        }
    }

    #[test]
    fn neighbors_match_build_result_original_mapping() {
        let (data, _) = SynthClustered::new(400, 8, 4, 3).generate_labeled();
        let params = Params::default().with_k(8).with_seed(3).with_reorder(true);
        let result = crate::nndescent::NnDescent::new(params.clone()).build(&data).unwrap();
        let expect: Vec<Vec<(u32, f32)>> =
            (0..400).map(|u| result.neighbors_original(u)).collect();
        let idx = Index::from_build(data, result, params, "t".into(), "d".into());
        for u in (0..400).step_by(29) {
            let got = idx.neighbors(OriginalId(u as u32));
            assert_eq!(got.len(), expect[u].len());
            for (g, e) in got.iter().zip(&expect[u]) {
                assert_eq!((g.id.get(), g.dist.to_bits()), (e.0, e.1.to_bits()), "node {u}");
            }
        }
    }

    #[test]
    fn reordered_index_breaks_distance_ties_by_original_id() {
        // two copies of each base point: every query has an exact-tie
        // pair. A reordered build must still answer ties in original-id
        // order (the canonical boundary order every serving path —
        // Index, ShardedSearcher, ShardPool — shares), not in σ's
        // working-layout order.
        let dim = 8;
        let rows: Vec<f32> = (0..20)
            .flat_map(|i| {
                let j = (i % 10) as f32;
                (0..dim).map(move |c| j * 10.0 + c as f32)
            })
            .collect();
        let data = AlignedMatrix::from_rows(20, dim, &rows);
        let params = Params::default().with_k(4).with_seed(5).with_reorder(true);
        let result = crate::nndescent::NnDescent::new(params.clone()).build(&data).unwrap();
        let idx = Index::from_build(data.clone(), result, params, "t".into(), "dup".into());
        assert!(idx.is_reordered());

        // exhaustive search (probe everything, pool holds everything)
        let sp = SearchParams { ef: 20, probes: 20, ..Default::default() };
        for j in 0..10u32 {
            let (res, _) = idx.search(data.row_logical(j as usize), 2, &sp);
            assert_eq!(res[0], Neighbor::new(j, 0.0), "query {j}: lower original id first");
            assert_eq!(res[1], Neighbor::new(j + 10, 0.0), "query {j}: its twin second");
        }
    }

    #[test]
    fn evaluate_produces_a_coherent_report() {
        let (idx, _) = built(600, false, 21);
        let report = idx.evaluate(&EvalOptions::new().with_recall_queries(60).with_seed(1));
        assert_eq!(report.n, 600);
        assert_eq!(report.dim, 8);
        assert!(report.iterations >= 2);
        assert!(report.recall.unwrap() > 0.9, "recall {:?}", report.recall);
        let skipped = idx.evaluate(&EvalOptions::skip_recall());
        assert!(skipped.recall.is_none());
    }

    #[test]
    fn save_load_roundtrip_serves_identically() {
        let dir = std::env::temp_dir().join("knng_api_index_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.knni");
        let (idx, data) = built(500, true, 13);
        idx.save(&path).unwrap();
        let loaded = Index::load(&path).unwrap();
        assert!(loaded.telemetry().is_none());
        assert_eq!(loaded.len(), idx.len());
        assert_eq!(loaded.params(), idx.params());
        let sp = SearchParams::default();
        for qi in (0..500).step_by(71) {
            let (a, _) = idx.search(data.row_logical(qi), 5, &sp);
            let (b, _) = loaded.search(data.row_logical(qi), 5, &sp);
            assert_eq!(a, b, "query {qi}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
