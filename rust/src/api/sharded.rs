//! Sharded serving: partition the corpus into S independently-built
//! subsets, fan every query (or batch) out through the existing blocked
//! kernels, and merge the per-shard pools into one global top-k.
//!
//! Sharding trades one global graph for S smaller ones. Each shard's
//! NN-Descent build is cheaper (the paper's cost is ~n^1.14, so S
//! builds over n/S points do less total work) and the per-shard beam
//! searches are independent, which is what later multi-core/multi-node
//! fan-out needs. The price is recall at shard boundaries: a query's
//! true neighbors all live in *some* shard, so the merged exact top-k
//! is a superset union — but the per-shard *approximate* searches can
//! each miss locally. On clustered data (the paper's core assumption)
//! the loss is small; the facade's tests gate it at ≤ 0.02 vs a single
//! index.
//!
//! **Which rows land in which shard is a pluggable decision** — a
//! [`Partitioner`](super::partition::Partitioner) plan. The default
//! [`Contiguous`] split preserves the historical behavior bit for bit;
//! the [`KMeans`](super::partition::KMeans) partitioner groups rows by
//! nearest centroid (plus bounded boundary-ghost stitching) and unlocks
//! **routed search**: [`Router`] scores query-to-centroid distances
//! with the norm-trick kernels and fans out only to the top-m shards.
//! With `m = S` routing degenerates to the full fan-out — same
//! results, same evaluation counts — a contract the serve-stack tests
//! pin bitwise.
//!
//! With S = 1 the single shard sees the whole corpus and the merge is
//! the identity, so results are bit-identical to
//! [`GraphIndex::search_batch`] — a property the integration tests pin
//! down exactly.
//!
//! [`GraphIndex::search_batch`]: crate::search::GraphIndex::search_batch

use super::ids::{Neighbor, OriginalId, WorkingId};
use super::partition::{Contiguous, PartitionPlan, Partitioner};
use super::searcher::Searcher;
use crate::config::schema::ComputeKind;
use crate::dataset::AlignedMatrix;
use crate::distance::{dispatch, sq_norm};
use crate::nndescent::observer::{BuildEvent, BuildObserver, FnObserver, NoopObserver};
use crate::nndescent::reorder::Reordering;
use crate::nndescent::{BuildResult, NnDescent, Params};
use crate::search::{BatchStats, GraphIndex, QueryStats, SearchParams};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

/// One shard: a graph over a subset of the corpus, plus the bookkeeping
/// to map its working ids back to global original ids. Shards are held
/// behind `Arc` so the thread-per-shard pool (`api::serve`) can hand
/// each worker thread shared ownership of its shard without rebuilding
/// or cloning the graph.
pub(crate) struct Shard {
    pub(crate) core: GraphIndex,
    /// Shard-local reorder permutation (iff the build reordered).
    pub(crate) reordering: Option<Reordering>,
    /// First global row id of a contiguous shard's slice (0 when `rows`
    /// carries an explicit map).
    pub(crate) offset: u32,
    /// Explicit local→global row map for scattered (cluster) shards:
    /// `rows[local]` is the global id of shard-local row `local`,
    /// including any ghost rows at the tail. `None` for contiguous
    /// shards, where global = `offset + local`.
    pub(crate) rows: Option<Vec<u32>>,
}

impl Shard {
    /// Map a shard-working id to the global original id: undo the
    /// shard-local σ, then apply the shard's row mapping.
    #[inline]
    fn to_global(&self, w: WorkingId) -> OriginalId {
        let local = match &self.reordering {
            Some(r) => r.inv[w.index()],
            None => w.get(),
        };
        match &self.rows {
            Some(rows) => OriginalId(rows[local as usize]),
            None => OriginalId(self.offset + local),
        }
    }

    pub(crate) fn map_results(&self, raw: Vec<(u32, f32)>) -> Vec<Neighbor> {
        raw.into_iter()
            .map(|(v, d)| Neighbor { id: self.to_global(WorkingId(v)), dist: d })
            .collect()
    }
}

/// Query-to-shard routing table: one centroid per shard, scored through
/// the same norm-trick kernels the probe stage uses (centroid norms are
/// precomputed here, ‖q‖² once per query). Shared by the inline
/// fan-out and the thread-per-shard pool via `Arc`, so both serving
/// layers route identically.
pub(crate) struct Router {
    centroids: AlignedMatrix,
    /// ‖centroid‖² per shard, at the active kernel width.
    norms: Vec<f32>,
    /// `[0, S)` — the id list the one-to-many kernels iterate.
    ids: Vec<u32>,
}

impl Router {
    pub(crate) fn new(centroids: AlignedMatrix) -> Self {
        let norms = (0..centroids.n()).map(|i| sq_norm(centroids.row(i))).collect();
        let ids = (0..centroids.n() as u32).collect();
        Self { centroids, norms, ids }
    }

    /// The routing table itself (persisted into per-shard bundles).
    pub(crate) fn centroids(&self) -> &AlignedMatrix {
        &self.centroids
    }

    /// The `m` nearest shards (ties toward the lower shard id),
    /// ascending by shard id so the fan-out loop visits shards in slice
    /// order — the same order the full fan-out uses. Returns the
    /// centroid evaluations spent. **`m ≥ S` selects every shard
    /// without scoring anything** (zero routing evaluations), which is
    /// what makes `m = S` routed search reproduce the full fan-out
    /// exactly, evaluation counts included.
    pub(crate) fn route(&self, query: &[f32], m: usize) -> (Vec<u32>, u64) {
        let s = self.centroids.n();
        if m >= s {
            return (self.ids.clone(), 0);
        }
        let dp = self.centroids.dim_pad();
        let mut q = vec![0f32; dp];
        let take = query.len().min(dp);
        q[..take].copy_from_slice(&query[..take]);
        let q2 = sq_norm(&q);
        let mut dists = Vec::new();
        let evals =
            dispatch::one_to_many_norms(&q, q2, &self.centroids, &self.norms, &self.ids, &mut dists);
        (Self::top_m(&dists, m), evals)
    }

    /// Per-shard query buckets for a batch: `buckets[s]` lists the
    /// query indices routed to shard `s`, ascending. The query×centroid
    /// tile runs through the GEMM-style cross kernel; `m ≥ S` skips the
    /// scoring (every bucket holds every query).
    pub(crate) fn bucket(&self, queries: &AlignedMatrix, m: usize) -> (Vec<Vec<u32>>, u64) {
        let s = self.centroids.n();
        let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); s];
        if m >= s {
            for b in buckets.iter_mut() {
                *b = (0..queries.n() as u32).collect();
            }
            return (buckets, 0);
        }
        let qnorms: Vec<f32> = (0..queries.n()).map(|qi| sq_norm(queries.row(qi))).collect();
        let mut dists = vec![0f32; queries.n() * s];
        let evals = dispatch::cross_norms(
            queries,
            &qnorms,
            &self.centroids,
            &self.norms,
            &self.ids,
            &mut dists,
        );
        for qi in 0..queries.n() {
            for pick in Self::top_m(&dists[qi * s..(qi + 1) * s], m) {
                buckets[pick as usize].push(qi as u32);
            }
        }
        (buckets, evals)
    }

    /// Indices of the `m` smallest distances, ties toward the lower
    /// index, returned ascending by index.
    fn top_m(dists: &[f32], m: usize) -> Vec<u32> {
        let mut order: Vec<u32> = (0..dists.len() as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            dists[a as usize].total_cmp(&dists[b as usize]).then(a.cmp(&b))
        });
        order.truncate(m);
        order.sort_unstable();
        order
    }
}

/// Rows `qids` of `queries` gathered into a fresh tile (the per-shard
/// sub-batch of routed search). Row content is copied logically, so a
/// gather of *all* rows in order reproduces the original tile's
/// logical content exactly — which is why routed `m = S` search is
/// bit-identical to the full fan-out.
pub(crate) fn gather_rows(queries: &AlignedMatrix, qids: &[u32]) -> AlignedMatrix {
    let flat: Vec<f32> =
        qids.iter().flat_map(|&qi| queries.row_logical(qi as usize).to_vec()).collect();
    AlignedMatrix::from_rows(qids.len(), queries.dim(), &flat)
}

/// Per-shard mean rows of `mats` — the fallback routing table when no
/// partition plan or persisted centroids exist (f64 accumulation).
fn data_means(mats: &[&AlignedMatrix]) -> AlignedMatrix {
    let dim = mats[0].dim();
    let mut out = AlignedMatrix::zeroed(mats.len(), dim);
    for (s, m) in mats.iter().enumerate() {
        let mut acc = vec![0.0f64; dim];
        for i in 0..m.n() {
            for (a, &x) in acc.iter_mut().zip(m.row_logical(i)) {
                *a += x as f64;
            }
        }
        let inv = 1.0 / m.n().max(1) as f64;
        for (c, a) in out.row_mut(s).iter_mut().zip(&acc) {
            *c = (a * inv) as f32;
        }
    }
    out
}

/// A [`Searcher`] over S independently-built shards.
pub struct ShardedSearcher {
    shards: Vec<Arc<Shard>>,
    router: Arc<Router>,
    params: Params,
    n: usize,
    dim: usize,
}

impl ShardedSearcher {
    /// Partition `data` into `shards` contiguous slices, build a graph
    /// over each with the same `params`, and assemble the searcher.
    ///
    /// `data`'s row order **defines the original id space** of every
    /// result: pass the corpus as the caller ordered it, never a
    /// reordered index's working-layout matrix (per-shard reorder
    /// permutations are handled internally). Each shard must end up
    /// with at least two points. With `shards == 1` the searcher is
    /// equivalent (bit-identical results) to a single [`GraphIndex`]
    /// built with the same parameters.
    pub fn build(data: &AlignedMatrix, shards: usize, params: &Params) -> crate::Result<Self> {
        Self::build_observed(data, shards, params, &mut NoopObserver)
    }

    /// Like [`build`](Self::build), forwarding each shard build's
    /// events to `observer` (shards are announced by their `Started`
    /// events, in slice order).
    pub fn build_observed(
        data: &AlignedMatrix,
        shards: usize,
        params: &Params,
        observer: &mut dyn BuildObserver,
    ) -> crate::Result<Self> {
        Self::build_with(data, shards, params, "artifacts", observer)
    }

    /// Like [`build`](Self::build) with an explicit
    /// [`Partitioner`](super::partition::Partitioner) — e.g.
    /// [`KMeans`](super::partition::KMeans) for cluster-aware shards
    /// whose queries can be centroid-routed
    /// ([`search_batch_routed`](Searcher::search_batch_routed)).
    pub fn build_partitioned(
        data: &AlignedMatrix,
        shards: usize,
        params: &Params,
        partitioner: &dyn Partitioner,
    ) -> crate::Result<Self> {
        Self::build_planned(data, shards, params, partitioner, "artifacts", &mut NoopObserver)
    }

    /// Contiguous-partitioned entry point with artifacts/observer
    /// plumbing (kept for the historical callers; the partitioning
    /// decision itself now lives in
    /// [`build_planned`](Self::build_planned)).
    pub fn build_with(
        data: &AlignedMatrix,
        shards: usize,
        params: &Params,
        artifacts_dir: &str,
        observer: &mut dyn BuildObserver,
    ) -> crate::Result<Self> {
        Self::build_planned(data, shards, params, &Contiguous, artifacts_dir, observer)
    }

    /// Fully-configured entry point: partition `data` with
    /// `partitioner`, build every shard's subgraph, and assemble the
    /// routing table from the plan's centroids. `artifacts_dir` feeds
    /// the `pjrt` backend when `params.compute` asks for it.
    ///
    /// With a resolved [`Params::threads`] budget `T > 1` (explicit or
    /// via `PALLAS_BUILD_THREADS`) and `S > 1` native-backend shards,
    /// the S independent shard builds run concurrently on
    /// `min(T, S)` workers — one whole-shard build per worker,
    /// contiguous groups, each inner build pinned to a single thread —
    /// and the assembled searcher is **bit-identical** to the
    /// sequential shard loop (shard builds share no state; the plan is
    /// computed once, single-threaded, before any worker spawns;
    /// observers see each shard's events replayed in slice order,
    /// tagged by [`BuildEvent::ShardStarted`]). With `S = 1` the thread
    /// budget flows into the single shard's build instead.
    pub fn build_planned(
        data: &AlignedMatrix,
        shards: usize,
        params: &Params,
        partitioner: &dyn Partitioner,
        artifacts_dir: &str,
        observer: &mut dyn BuildObserver,
    ) -> crate::Result<Self> {
        let n = data.n();
        let plan = partitioner.plan(data, shards)?;
        let workers = crate::nndescent::resolve_build_threads(params.threads).min(shards);
        let built = if workers > 1 && params.compute != ComputeKind::Pjrt {
            Self::build_shards_parallel(data, &plan, params, workers, observer)?
        } else {
            Self::build_shards_sequential(data, &plan, params, artifacts_dir, observer)?
        };
        Ok(Self {
            shards: built,
            router: Arc::new(Router::new(plan.centroids)),
            params: params.clone(),
            n,
            dim: data.dim(),
        })
    }

    /// One shard's rows copied out of the corpus (primaries then
    /// ghosts, in plan order). Tiles are cut lazily — one at a time
    /// sequentially, one per in-flight build in the worker pool — so a
    /// sharded build never holds a second full corpus copy beyond the
    /// shards it is actively building.
    fn cut_plan_rows(data: &AlignedMatrix, rows: &[u32]) -> AlignedMatrix {
        let flat: Vec<f32> =
            rows.iter().flat_map(|&r| data.row_logical(r as usize).to_vec()).collect();
        AlignedMatrix::from_rows(rows.len(), data.dim(), &flat)
    }

    /// The shard's id-mapping representation: contiguous row runs keep
    /// the compact offset form (and stay exportable as per-shard
    /// bundles); anything else carries the explicit map.
    fn shard_mapping(rows: &[u32]) -> (u32, Option<Vec<u32>>) {
        if rows.windows(2).all(|w| w[1] == w[0] + 1) {
            (rows[0], None)
        } else {
            (0, Some(rows.to_vec()))
        }
    }

    /// The sequential shard loop (also the `pjrt` path: that engine is
    /// exclusive state). Events stream through directly, tagged per
    /// shard.
    fn build_shards_sequential(
        data: &AlignedMatrix,
        plan: &PartitionPlan,
        params: &Params,
        artifacts_dir: &str,
        observer: &mut dyn BuildObserver,
    ) -> crate::Result<Vec<Arc<Shard>>> {
        let mut built = Vec::with_capacity(plan.shards.len());
        for (idx, sp) in plan.shards.iter().enumerate() {
            let shard_data = Self::cut_plan_rows(data, &sp.rows);
            observer.on_event(&BuildEvent::ShardStarted { shard: idx, n: shard_data.n() });
            let result = super::builder::run_build(params, &shard_data, artifacts_dir, observer)?;
            let working = result.working_data(shard_data);
            let BuildResult { graph, reordering, .. } = result;
            let (offset, rows) = Self::shard_mapping(&sp.rows);
            built.push(Arc::new(Shard {
                core: GraphIndex::new(working, graph),
                reordering,
                offset,
                rows,
            }));
        }
        Ok(built)
    }

    /// Build the shards concurrently: `workers` scoped threads own
    /// contiguous shard groups (the `api::serve` distribution idiom),
    /// each running whole-shard builds pinned to `threads = 1` — the
    /// parallelism budget is spent *across* shards. Builds share no
    /// state, so the result is bit-identical to the sequential loop.
    /// Observer events are buffered per shard and replayed in slice
    /// order afterwards (a `&mut dyn` observer cannot be shared across
    /// workers, and interleaved progress would be useless anyway); on a
    /// build error, the first failing shard in slice order wins.
    fn build_shards_parallel(
        data: &AlignedMatrix,
        plan: &PartitionPlan,
        params: &Params,
        workers: usize,
        observer: &mut dyn BuildObserver,
    ) -> crate::Result<Vec<Arc<Shard>>> {
        let shards = plan.shards.len();
        let inner = Params { threads: 1, ..params.clone() };
        let mut groups: Vec<Vec<usize>> = (0..workers).map(|_| Vec::new()).collect();
        for idx in 0..shards {
            groups[idx * workers / shards].push(idx);
        }

        type ShardOut = (usize, usize, crate::Result<Shard>, Vec<BuildEvent>);
        let results: Vec<ShardOut> = std::thread::scope(|scope| {
            let handles: Vec<_> = groups
                .into_iter()
                .map(|group| {
                    let inner = &inner;
                    scope.spawn(move || {
                        group
                            .into_iter()
                            .map(|idx| {
                                // each worker cuts its own tile just in
                                // time: at most one in-flight tile per
                                // worker, never a full corpus copy
                                let sp = &plan.shards[idx];
                                let shard_data = Self::cut_plan_rows(data, &sp.rows);
                                let sn = shard_data.n();
                                let mut events: Vec<BuildEvent> = Vec::new();
                                let built = NnDescent::new(inner.clone()).build_observed(
                                    &shard_data,
                                    &mut FnObserver(|e: &BuildEvent| events.push(*e)),
                                );
                                let shard = built.map(|result| {
                                    let working = result.working_data(shard_data);
                                    let BuildResult { graph, reordering, .. } = result;
                                    let (offset, rows) = Self::shard_mapping(&sp.rows);
                                    Shard {
                                        core: GraphIndex::new(working, graph),
                                        reordering,
                                        offset,
                                        rows,
                                    }
                                });
                                (idx, sn, shard, events)
                            })
                            .collect::<Vec<ShardOut>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("shard build worker panicked"))
                .collect()
        });

        let mut slots: Vec<Option<ShardOut>> = Vec::new();
        slots.resize_with(shards, || None);
        for out in results {
            slots[out.0] = Some(out);
        }
        let mut built = Vec::with_capacity(shards);
        for slot in slots {
            let (idx, sn, shard, events) = slot.expect("every shard is built exactly once");
            observer.on_event(&BuildEvent::ShardStarted { shard: idx, n: sn });
            for e in &events {
                observer.on_event(e);
            }
            built.push(Arc::new(shard?));
        }
        Ok(built)
    }

    /// Wrap one built (or bundle-loaded) [`Index`](super::Index) as a
    /// single-shard searcher. Serving is bit-identical to the `Index`
    /// itself (the shard's id mapping is exactly the index's σ⁻¹ with a
    /// zero offset) — this is the bridge that lets the CLI put a loaded
    /// `KNNIv1` bundle behind the thread-per-shard pool and the
    /// micro-batching front-end.
    pub fn from_index(index: super::Index) -> Self {
        let n = index.len();
        let dim = index.dim();
        let params = index.params().clone();
        let (core, reordering, centroids) = index.into_core_parts();
        let router = Router::new(match centroids {
            // a single-shard bundle's own centroid, if it carried one
            Some(c) if c.n() == 1 && c.dim() == dim => c,
            _ => data_means(&[core.data()]),
        });
        Self {
            shards: vec![Arc::new(Shard { core, reordering, offset: 0, rows: None })],
            router: Arc::new(router),
            params,
            n,
            dim,
        }
    }

    /// Assemble several loaded bundles into one sharded searcher —
    /// bundle `i` becomes shard `i`, and global ids are the
    /// **concatenation order**: bundle 0's rows first, then bundle 1's,
    /// and so on (exactly undoing [`save_shards`](Self::save_shards)).
    ///
    /// The routing table prefers the centroids persisted in the first
    /// bundle when they are consistent (one centroid per bundle, same
    /// dimensionality); otherwise it falls back to per-shard data
    /// means, which routes reasonably for naturally-clustered bundles.
    pub fn from_indexes(indexes: Vec<super::Index>) -> crate::Result<Self> {
        anyhow::ensure!(!indexes.is_empty(), "need at least one index bundle");
        let s = indexes.len();
        let dim = indexes[0].dim();
        let params = indexes[0].params().clone();
        let mut stored: Option<AlignedMatrix> = None;
        let mut shards = Vec::with_capacity(s);
        let mut offset = 0u64;
        for (i, index) in indexes.into_iter().enumerate() {
            anyhow::ensure!(
                index.dim() == dim,
                "bundle {i} dimensionality {} does not match bundle 0's {dim}",
                index.dim()
            );
            let len = index.len() as u64;
            let (core, reordering, centroids) = index.into_core_parts();
            if i == 0 {
                stored = centroids.filter(|c| c.n() == s && c.dim() == dim);
            }
            shards.push(Arc::new(Shard { core, reordering, offset: offset as u32, rows: None }));
            offset += len;
        }
        anyhow::ensure!(offset <= u32::MAX as u64, "combined corpus exceeds the u32 id space");
        let router = Router::new(match stored {
            Some(c) => c,
            None => {
                let mats: Vec<&AlignedMatrix> = shards.iter().map(|sh| sh.core.data()).collect();
                data_means(&mats)
            }
        });
        Ok(Self { shards, router: Arc::new(router), params, n: offset as usize, dim })
    }

    /// Persist every shard as its own `KNNIv1` bundle:
    /// `base = out.knni` writes `out-shard0.knni`, `out-shard1.knni`, …
    /// each carrying the **full** S-row routing table, so any one
    /// bundle (or all of them through
    /// [`from_indexes`](Self::from_indexes)) can reconstruct routing.
    ///
    /// Only contiguous (offset-mapped) shards are exportable: the
    /// bundle format stores no per-row id map, so reloading recovers
    /// global ids from concatenation order alone. K-means-partitioned
    /// searchers (scattered row maps, ghost rows) are rejected.
    pub fn save_shards(&self, base: &Path) -> crate::Result<Vec<PathBuf>> {
        let mut out = Vec::with_capacity(self.shards.len());
        for (i, shard) in self.shards.iter().enumerate() {
            anyhow::ensure!(
                shard.rows.is_none(),
                "per-shard bundles require contiguous shards (shard {i} has a scattered row \
                 map); rebuild with the contiguous partitioner to export"
            );
            let path = Self::shard_bundle_path(base, i);
            crate::search::bundle::save_index_parts(
                &path,
                shard.core.data(),
                shard.core.graph(),
                shard.reordering.as_ref(),
                &self.params,
                Some((shard.core.norms(), shard.core.norm_lanes())),
                Some(self.router.centroids()),
            )?;
            out.push(path);
        }
        Ok(out)
    }

    /// `out.knni` → `out-shard{i}.knni` (extension preserved).
    fn shard_bundle_path(base: &Path, i: usize) -> PathBuf {
        let stem = base
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "index".into());
        let name = match base.extension() {
            Some(ext) => format!("{stem}-shard{i}.{}", ext.to_string_lossy()),
            None => format!("{stem}-shard{i}"),
        };
        base.with_file_name(name)
    }

    /// Shared handles to the shards, in slice order — what
    /// [`ShardPool`](super::ShardPool) distributes over its workers.
    pub(crate) fn shards(&self) -> &[Arc<Shard>] {
        &self.shards
    }

    /// Shared handle to the routing table — the pool routes through the
    /// exact same centroids and kernels as the inline fan-out.
    pub(crate) fn router_arc(&self) -> Arc<Router> {
        Arc::clone(&self.router)
    }

    /// The partition centroids queries are routed by (one row per
    /// shard).
    pub fn centroids(&self) -> &AlignedMatrix {
        self.router.centroids()
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Logical dimensionality of the corpus.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Shard sizes (including any ghost rows), in slice order.
    pub fn shard_sizes(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.core.n()).collect()
    }

    /// Single-query routed search: fan out only to the `top_m` shards
    /// nearest the query (clamped to `[1, S]`). The centroid scoring
    /// evaluations are included in the returned stats; with
    /// `top_m ≥ S` this is exactly [`search`](Searcher::search).
    pub fn search_routed(
        &self,
        query: &[f32],
        k: usize,
        params: &SearchParams,
        top_m: usize,
    ) -> (Vec<Neighbor>, QueryStats) {
        let m = top_m.clamp(1, self.shards.len());
        let (picks, route_evals) = self.router.route(query, m);
        let mut stats = QueryStats { dist_evals: route_evals, expansions: 0 };
        let mut all = Vec::with_capacity(k * picks.len());
        for &si in &picks {
            let shard = &self.shards[si as usize];
            let (raw, s) = shard.core.search(query, k, params);
            stats.dist_evals += s.dist_evals;
            stats.expansions += s.expansions;
            all.extend(shard.map_results(raw));
        }
        (Self::merge(all, k), stats)
    }

    /// Full fan-out over a *subset* of the shards: the reference
    /// semantics for a degraded answer. When a pool drops shards (dead
    /// worker, missed deadline), what it returns for each query is by
    /// contract exactly this honest reduced fan-out over the survivors
    /// — the chaos suite asserts the equality bit for bit. Shard
    /// indices are slice-order, deduplicated here; out-of-range indices
    /// panic (caller bug, not a serving-path input).
    pub fn search_batch_subset(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
        shard_ids: &[usize],
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        let t0 = Instant::now();
        let mut picks: Vec<usize> = shard_ids.to_vec();
        picks.sort_unstable();
        picks.dedup();
        let mut agg = BatchStats {
            queries: queries.n(),
            kernel: crate::distance::dispatch::active_width().name(),
            shard_visits: (queries.n() * picks.len()) as u64,
            ..Default::default()
        };
        let mut merged: Vec<Vec<Neighbor>> = Vec::new();
        merged.resize_with(queries.n(), || Vec::with_capacity(k * picks.len()));
        for &si in &picks {
            let shard = &self.shards[si];
            let (raw, s) = shard.core.search_batch(queries, k, params);
            agg.dist_evals += s.dist_evals;
            agg.expansions += s.expansions;
            for (qi, r) in raw.into_iter().enumerate() {
                merged[qi].extend(shard.map_results(r));
            }
        }
        let results = merged.into_iter().map(|all| Self::merge(all, k)).collect();
        agg.secs = t0.elapsed().as_secs_f64();
        (results, agg)
    }

    /// Merge per-shard candidate lists into the global top-k: drop
    /// ghost duplicates, sort by (distance, global id), truncate.
    ///
    /// Ghost rows (k-means boundary stitching) can surface the *same
    /// global row* from two shards, possibly with different distance
    /// bits (one shard may have scored it on the norm-trick probe path,
    /// the other on the direct expansion strip). The first pass groups
    /// by id and keeps each id's nearest copy; with unique ids — every
    /// contiguous-partitioned searcher — it keeps everything, and the
    /// final order equals the historical single sort.
    ///
    /// The comparator is **total** (`f32::total_cmp`, so a corrupt NaN
    /// cannot panic the serving path; squared-L2 distances are never
    /// `-0.0`, for which `total_cmp` would differ from `==`) and its
    /// final key is unique per entry, so the output is a pure function
    /// of the candidate *set*: equal distances break by global id,
    /// never by fan-out or arrival order. This is the invariant that
    /// lets the thread-per-shard pool merge replies in whatever order
    /// workers finish and still match the single-threaded fan-out bit
    /// for bit.
    pub(crate) fn merge(mut all: Vec<Neighbor>, k: usize) -> Vec<Neighbor> {
        all.sort_unstable_by(|a, b| {
            a.id.get().cmp(&b.id.get()).then(a.dist.total_cmp(&b.dist))
        });
        all.dedup_by(|a, b| a.id == b.id);
        all.sort_unstable_by(|a, b| {
            a.dist.total_cmp(&b.dist).then(a.id.get().cmp(&b.id.get()))
        });
        all.truncate(k);
        all
    }
}

impl Searcher for ShardedSearcher {
    fn len(&self) -> usize {
        self.n
    }

    fn search(
        &self,
        query: &[f32],
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Neighbor>, QueryStats) {
        let mut stats = QueryStats::default();
        let mut all = Vec::with_capacity(k * self.shards.len());
        for shard in &self.shards {
            let (raw, s) = shard.core.search(query, k, params);
            stats.dist_evals += s.dist_evals;
            stats.expansions += s.expansions;
            all.extend(shard.map_results(raw));
        }
        (Self::merge(all, k), stats)
    }

    fn search_batch(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        let t0 = Instant::now();
        let mut agg = BatchStats {
            queries: queries.n(),
            kernel: crate::distance::dispatch::active_width().name(),
            shard_visits: (queries.n() * self.shards.len()) as u64,
            ..Default::default()
        };
        let mut merged: Vec<Vec<Neighbor>> = Vec::new();
        merged.resize_with(queries.n(), || Vec::with_capacity(k * self.shards.len()));
        for shard in &self.shards {
            let (raw, s) = shard.core.search_batch(queries, k, params);
            agg.dist_evals += s.dist_evals;
            agg.expansions += s.expansions;
            for (qi, r) in raw.into_iter().enumerate() {
                merged[qi].extend(shard.map_results(r));
            }
        }
        let results = merged.into_iter().map(|all| Self::merge(all, k)).collect();
        agg.secs = t0.elapsed().as_secs_f64();
        (results, agg)
    }

    fn search_batch_routed(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
        top_m: usize,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        let t0 = Instant::now();
        let m = top_m.clamp(1, self.shards.len());
        let (buckets, route_evals) = self.router.bucket(queries, m);
        let mut agg = BatchStats {
            queries: queries.n(),
            kernel: crate::distance::dispatch::active_width().name(),
            dist_evals: route_evals,
            ..Default::default()
        };
        let mut merged: Vec<Vec<Neighbor>> = Vec::new();
        merged.resize_with(queries.n(), || Vec::with_capacity(k * m));
        for (si, shard) in self.shards.iter().enumerate() {
            let qids = &buckets[si];
            if qids.is_empty() {
                continue;
            }
            agg.shard_visits += qids.len() as u64;
            let tile = gather_rows(queries, qids);
            let (raw, s) = shard.core.search_batch(&tile, k, params);
            agg.dist_evals += s.dist_evals;
            agg.expansions += s.expansions;
            for (pos, r) in raw.into_iter().enumerate() {
                merged[qids[pos] as usize].extend(shard.map_results(r));
            }
        }
        let results = merged.into_iter().map(|all| Self::merge(all, k)).collect();
        agg.secs = t0.elapsed().as_secs_f64();
        (results, agg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::partition::KMeans;
    use crate::dataset::clustered::SynthClustered;
    use crate::testing::assert_neighbors_bitwise_eq;

    fn corpus(n: usize, seed: u64) -> AlignedMatrix {
        let (data, _) = SynthClustered::new(n, 8, 4, seed).generate_labeled();
        data
    }

    #[test]
    fn rejects_degenerate_partitions() {
        let data = corpus(40, 1);
        assert!(ShardedSearcher::build(&data, 0, &Params::default()).is_err());
        assert!(ShardedSearcher::build(&data, 21, &Params::default()).is_err(), "shards of <2");
        let ok = ShardedSearcher::build(&data, 8, &Params::default().with_k(3)).unwrap();
        assert_eq!(ok.shard_count(), 8);
        assert_eq!(ok.shard_sizes(), vec![5, 5, 5, 5, 5, 5, 5, 5]);
    }

    #[test]
    fn shards_cover_the_corpus_and_map_to_global_ids() {
        let data = corpus(603, 7); // non-divisible on purpose
        let params = Params::default().with_k(6).with_seed(7).with_reorder(true);
        let sharded = ShardedSearcher::build(&data, 4, &params).unwrap();
        assert_eq!(sharded.shard_sizes().iter().sum::<usize>(), 603);
        assert_eq!(Searcher::len(&sharded), 603);

        // querying any corpus row must return that global row as top hit
        let sp = SearchParams::default();
        for qi in (0..603).step_by(83) {
            let (res, _) = sharded.search(data.row_logical(qi), 3, &sp);
            assert_eq!(res[0].id, OriginalId(qi as u32), "self hit in global ids");
            assert!(res[0].dist < 1e-6);
        }
    }

    #[test]
    fn subset_fanout_over_all_shards_is_the_full_fanout() {
        let data = corpus(400, 23);
        let params = Params::default().with_k(6).with_seed(23);
        let sharded = ShardedSearcher::build(&data, 3, &params).unwrap();
        let sp = SearchParams::default();
        let rows: Vec<f32> = (0..15).flat_map(|i| data.row_logical(i * 19).to_vec()).collect();
        let queries = AlignedMatrix::from_rows(15, data.dim(), &rows);
        let (full, fstats) = sharded.search_batch(&queries, 5, &sp);
        // all shards (any order, with duplicates) == the plain fan-out
        let (all, astats) = sharded.search_batch_subset(&queries, 5, &sp, &[2, 0, 1, 0]);
        assert_neighbors_bitwise_eq(&full, &all, "subset=all");
        assert_eq!(fstats.dist_evals, astats.dist_evals);
        assert_eq!(fstats.shard_visits, astats.shard_visits);
        // a strict subset still self-hits for rows that live in it
        let (sub, sstats) = sharded.search_batch_subset(&queries, 5, &sp, &[0, 1]);
        assert_eq!(sub.len(), 15);
        assert_eq!(sstats.shard_visits, 30);
        let (empty, estats) = sharded.search_batch_subset(&queries, 5, &sp, &[]);
        assert!(empty.iter().all(|r| r.is_empty()), "no shards, no answers");
        assert_eq!(estats.dist_evals, 0);
    }

    #[test]
    fn parallel_shard_builds_match_sequential_bitwise() {
        let data = corpus(600, 13);
        let seq_params = Params::default().with_k(6).with_seed(13).with_threads(1);
        let par_params = seq_params.clone().with_threads(4);
        let seq = ShardedSearcher::build(&data, 4, &seq_params).unwrap();
        let par = ShardedSearcher::build(&data, 4, &par_params).unwrap();
        assert_eq!(seq.shard_sizes(), par.shard_sizes());
        let sp = SearchParams::default();
        for qi in (0..600).step_by(29) {
            let (a, sa) = seq.search(data.row_logical(qi), 5, &sp);
            let (b, sb) = par.search(data.row_logical(qi), 5, &sp);
            assert_eq!(sa, sb, "query {qi} stats");
            assert_eq!(a.len(), b.len(), "query {qi}");
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id, "query {qi}");
                assert_eq!(x.dist.to_bits(), y.dist.to_bits(), "query {qi}");
            }
        }
    }

    #[test]
    fn observer_events_are_tagged_and_in_shard_order() {
        use crate::nndescent::observer::FnObserver;
        let data = corpus(400, 17);
        // exercise both the concurrent (threads=4) and sequential paths
        for threads in [1usize, 4] {
            let params = Params::default().with_k(5).with_seed(17).with_threads(threads);
            let mut events: Vec<BuildEvent> = Vec::new();
            let built = ShardedSearcher::build_observed(
                &data,
                4,
                &params,
                &mut FnObserver(|e: &BuildEvent| events.push(*e)),
            )
            .unwrap();
            assert_eq!(built.shard_count(), 4);
            let tags: Vec<(usize, usize)> = events
                .iter()
                .filter_map(|e| match e {
                    BuildEvent::ShardStarted { shard, n } => Some((*shard, *n)),
                    _ => None,
                })
                .collect();
            assert_eq!(
                tags,
                vec![(0, 100), (1, 100), (2, 100), (3, 100)],
                "threads={threads}: one tag per shard, slice order"
            );
            // every shard segment carries a full build lifecycle
            assert_eq!(
                events.iter().filter(|e| matches!(e, BuildEvent::Started { .. })).count(),
                4,
                "threads={threads}"
            );
            assert_eq!(
                events.iter().filter(|e| matches!(e, BuildEvent::Finished { .. })).count(),
                4,
                "threads={threads}"
            );
            // tags precede their shard's Started event
            let first_started =
                events.iter().position(|e| matches!(e, BuildEvent::Started { .. })).unwrap();
            let first_tag = events
                .iter()
                .position(|e| matches!(e, BuildEvent::ShardStarted { .. }))
                .unwrap();
            assert!(first_tag < first_started, "threads={threads}");
        }
    }

    #[test]
    fn merge_sorts_by_distance_then_id_and_truncates() {
        let all = vec![
            Neighbor::new(9, 2.0),
            Neighbor::new(1, 1.0),
            Neighbor::new(4, 1.0),
            Neighbor::new(2, 3.0),
        ];
        let m = ShardedSearcher::merge(all, 3);
        assert_eq!(
            m,
            vec![Neighbor::new(1, 1.0), Neighbor::new(4, 1.0), Neighbor::new(9, 2.0)]
        );
    }

    #[test]
    fn merge_is_independent_of_fanout_concatenation_order() {
        // equal distances from different shards: the output depends only
        // on the candidate set, never on which shard replied first
        let base = vec![
            Neighbor::new(9, 1.0),
            Neighbor::new(1, 1.0),
            Neighbor::new(5, 0.5),
            Neighbor::new(3, 1.0),
            Neighbor::new(7, 0.5),
        ];
        let expect = vec![Neighbor::new(5, 0.5), Neighbor::new(7, 0.5), Neighbor::new(1, 1.0)];
        assert_eq!(ShardedSearcher::merge(base.clone(), 3), expect);
        let mut reversed = base.clone();
        reversed.reverse();
        assert_eq!(ShardedSearcher::merge(reversed, 3), expect);
        let mut rotated = base.clone();
        rotated.rotate_left(2);
        assert_eq!(ShardedSearcher::merge(rotated, 3), expect);
    }

    #[test]
    fn merge_deduplicates_ghost_copies_keeping_the_nearest() {
        // the same global row from two shards (a ghost copy), slightly
        // different distance bits: one survivor, at the nearer distance
        let all = vec![
            Neighbor::new(4, 2.0),
            Neighbor::new(7, 1.0000001),
            Neighbor::new(7, 1.0),
            Neighbor::new(2, 0.5),
        ];
        let m = ShardedSearcher::merge(all, 4);
        assert_eq!(
            m,
            vec![Neighbor::new(2, 0.5), Neighbor::new(7, 1.0), Neighbor::new(4, 2.0)]
        );
    }

    /// 4 copies of 10 distinct points, one copy per shard — so every
    /// query has exact-tie answers in *every* shard.
    fn duplicated_corpus() -> AlignedMatrix {
        let dim = 8;
        let rows: Vec<f32> = (0..40)
            .flat_map(|i| {
                let j = (i % 10) as f32;
                (0..dim).map(move |c| j * 10.0 + c as f32)
            })
            .collect();
        AlignedMatrix::from_rows(40, dim, &rows)
    }

    #[test]
    fn cross_shard_ties_break_by_global_id() {
        let data = duplicated_corpus();
        let params = Params::default().with_k(4).with_seed(11);
        let sharded = ShardedSearcher::build(&data, 4, &params).unwrap();
        assert_eq!(sharded.shard_sizes(), vec![10, 10, 10, 10]);

        // exhaustive search per shard (probe every point, pool holds
        // all), so each shard answers its zero-distance copy exactly
        let sp = SearchParams { ef: 40, probes: 40, ..Default::default() };
        for j in 0..10u32 {
            let (res, _) = sharded.search(data.row_logical(j as usize), 4, &sp);
            let expect: Vec<Neighbor> =
                (0..4).map(|s| Neighbor::new(s * 10 + j, 0.0)).collect();
            assert_eq!(res, expect, "query {j}: ties must order by global id");
            // batch path agrees bit for bit
            let qm = AlignedMatrix::from_rows(1, data.dim(), data.row_logical(j as usize));
            let (bres, _) = sharded.search_batch(&qm, 4, &sp);
            assert_eq!(bres[0], expect, "query {j} batch path");
        }
    }

    fn query_tile(data: &AlignedMatrix, from: usize, count: usize) -> AlignedMatrix {
        let rows: Vec<f32> =
            (from..from + count).flat_map(|i| data.row_logical(i).to_vec()).collect();
        AlignedMatrix::from_rows(count, data.dim(), &rows)
    }

    #[test]
    fn kmeans_build_covers_the_corpus_and_serves_global_ids() {
        let data = corpus(600, 19);
        let params = Params::default().with_k(8).with_seed(19).with_reorder(true);
        let sharded =
            ShardedSearcher::build_partitioned(&data, 4, &params, &KMeans::default()).unwrap();
        assert_eq!(Searcher::len(&sharded), 600);
        // shard sizes include ghosts, so they sum to ≥ n
        assert!(sharded.shard_sizes().iter().sum::<usize>() >= 600);
        let sp = SearchParams::default();
        for qi in (0..600).step_by(53) {
            let (res, _) = sharded.search(data.row_logical(qi), 3, &sp);
            assert_eq!(res[0].id, OriginalId(qi as u32), "self hit in global ids");
            assert!(res[0].dist < 1e-6);
            // ghost duplicates never surface twice
            let mut ids: Vec<u32> = res.iter().map(|r| r.id.get()).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), res.len(), "query {qi}: duplicate ids in results");
        }
    }

    #[test]
    fn routed_full_fanout_is_bit_identical_for_both_partitioners() {
        let data = corpus(600, 23);
        let queries = query_tile(&data, 0, 60);
        let params = Params::default().with_k(8).with_seed(23);
        let sp = SearchParams::default();
        for (name, sharded) in [
            ("contiguous", ShardedSearcher::build(&data, 4, &params).unwrap()),
            (
                "kmeans",
                ShardedSearcher::build_partitioned(&data, 4, &params, &KMeans::default())
                    .unwrap(),
            ),
        ] {
            let (expect, estats) = sharded.search_batch(&queries, 5, &sp);
            // m = S (and anything larger) routes to every shard with
            // zero scoring overhead: identical results AND eval counts
            for m in [4usize, 9] {
                let (got, gstats) = sharded.search_batch_routed(&queries, 5, &sp, m);
                assert_neighbors_bitwise_eq(&expect, &got, &format!("{name} m={m}"));
                assert_eq!(estats.dist_evals, gstats.dist_evals, "{name} m={m}");
                assert_eq!(estats.expansions, gstats.expansions, "{name} m={m}");
                assert_eq!(estats.shard_visits, gstats.shard_visits, "{name} m={m}");
            }
            // single-query routed path agrees with Searcher::search
            for qi in (0..60).step_by(13) {
                let (a, sa) = sharded.search(queries.row_logical(qi), 5, &sp);
                let (b, sb) = sharded.search_routed(queries.row_logical(qi), 5, &sp, 4);
                assert_neighbors_bitwise_eq(
                    std::slice::from_ref(&a),
                    std::slice::from_ref(&b),
                    &format!("{name} single {qi}"),
                );
                assert_eq!(sa, sb, "{name} single {qi} stats");
            }
        }
    }

    #[test]
    fn routed_search_visits_fewer_shards_and_counts_them() {
        let data = corpus(800, 29);
        let queries = query_tile(&data, 0, 50);
        let params = Params::default().with_k(8).with_seed(29);
        let sharded =
            ShardedSearcher::build_partitioned(&data, 4, &params, &KMeans::default()).unwrap();
        let sp = SearchParams::default();
        let (_, full) = sharded.search_batch(&queries, 5, &sp);
        assert_eq!(full.shard_visits, 50 * 4);
        let (res, routed) = sharded.search_batch_routed(&queries, 5, &sp, 2);
        assert_eq!(routed.shard_visits, 50 * 2, "m=2 visits exactly 2 shards per query");
        assert!(routed.dist_evals < full.dist_evals, "routing must cut work");
        assert_eq!(res.len(), 50);
        // self-queries still find themselves through the routed path
        for (qi, r) in res.iter().enumerate() {
            assert_eq!(r[0].id, OriginalId(qi as u32), "query {qi}");
        }
    }

    #[test]
    fn save_shards_roundtrips_through_from_indexes() {
        let dir = std::env::temp_dir().join("knng_shard_export_test");
        std::fs::create_dir_all(&dir).unwrap();
        let base = dir.join("out.knni");
        let data = corpus(400, 31);
        let params = Params::default().with_k(6).with_seed(31).with_reorder(true);
        let sharded = ShardedSearcher::build(&data, 2, &params).unwrap();
        let paths = sharded.save_shards(&base).unwrap();
        assert_eq!(paths.len(), 2);
        assert!(paths[0].to_string_lossy().ends_with("out-shard0.knni"));

        let loaded: Vec<super::super::Index> =
            paths.iter().map(|p| super::super::Index::load(p).unwrap()).collect();
        // every shard bundle carries the full routing table
        for idx in &loaded {
            let c = idx.centroids().expect("shard bundles persist centroids");
            assert_eq!((c.n(), c.dim()), (2, data.dim()));
        }
        let rebuilt = ShardedSearcher::from_indexes(loaded).unwrap();
        assert_eq!(rebuilt.shard_count(), 2);
        assert_eq!(Searcher::len(&rebuilt), 400);
        assert_eq!(rebuilt.centroids().as_slice(), sharded.centroids().as_slice());

        let queries = query_tile(&data, 0, 40);
        let sp = SearchParams::default();
        let (expect, estats) = sharded.search_batch(&queries, 5, &sp);
        let (got, gstats) = rebuilt.search_batch(&queries, 5, &sp);
        assert_neighbors_bitwise_eq(&expect, &got, "reloaded shard bundles");
        assert_eq!(estats.dist_evals, gstats.dist_evals);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_shards_rejects_scattered_kmeans_shards() {
        let data = corpus(300, 37);
        let params = Params::default().with_k(6).with_seed(37);
        let sharded =
            ShardedSearcher::build_partitioned(&data, 3, &params, &KMeans::default()).unwrap();
        let dir = std::env::temp_dir().join("knng_shard_export_reject");
        std::fs::create_dir_all(&dir).unwrap();
        let err = sharded.save_shards(&dir.join("out.knni")).unwrap_err().to_string();
        assert!(err.contains("contiguous"), "unexpected error: {err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn from_indexes_rejects_empty_and_mismatched_dims() {
        assert!(ShardedSearcher::from_indexes(Vec::new()).is_err());
        let a = super::super::IndexBuilder::new()
            .data(corpus(100, 41))
            .params(Params::default().with_k(5).with_seed(41))
            .build()
            .unwrap();
        let (wide, _) = SynthClustered::new(100, 16, 4, 41).generate_labeled();
        let b = super::super::IndexBuilder::new()
            .data(wide)
            .params(Params::default().with_k(5).with_seed(41))
            .build()
            .unwrap();
        let err = ShardedSearcher::from_indexes(vec![a, b]).unwrap_err().to_string();
        assert!(err.contains("dimensionality"), "unexpected error: {err}");
    }
}
