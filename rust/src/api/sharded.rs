//! Sharded serving: partition the corpus into S independently-built
//! slices, fan every query (or batch) out to each shard through the
//! existing blocked kernels, and merge the per-shard pools into one
//! global top-k — the first concrete step on the ROADMAP sharding item.
//!
//! Sharding trades one global graph for S smaller ones. Each shard's
//! NN-Descent build is cheaper (the paper's cost is ~n^1.14, so S
//! builds over n/S points do less total work) and the per-shard beam
//! searches are independent, which is what later multi-core/multi-node
//! fan-out needs. The price is recall at shard boundaries: a query's
//! true neighbors all live in *some* shard, so the merged exact top-k
//! is a superset union — but the per-shard *approximate* searches can
//! each miss locally. On clustered data (the paper's core assumption)
//! the loss is small; the facade's tests gate it at ≤ 0.02 vs a single
//! index.
//!
//! With S = 1 the single shard sees the whole corpus and the merge is
//! the identity, so results are bit-identical to
//! [`GraphIndex::search_batch`] — a property the integration tests pin
//! down exactly.
//!
//! [`GraphIndex::search_batch`]: crate::search::GraphIndex::search_batch

use super::ids::{Neighbor, OriginalId, WorkingId};
use super::searcher::Searcher;
use crate::config::schema::ComputeKind;
use crate::dataset::AlignedMatrix;
use crate::nndescent::observer::{BuildEvent, BuildObserver, FnObserver, NoopObserver};
use crate::nndescent::reorder::Reordering;
use crate::nndescent::{BuildResult, NnDescent, Params};
use crate::search::{BatchStats, GraphIndex, QueryStats, SearchParams};
use std::sync::Arc;
use std::time::Instant;

/// One shard: a graph over a contiguous slice of the corpus, plus the
/// bookkeeping to map its working ids back to global original ids.
/// Shards are held behind `Arc` so the thread-per-shard pool
/// (`api::serve`) can hand each worker thread shared ownership of its
/// shard without rebuilding or cloning the graph.
pub(crate) struct Shard {
    pub(crate) core: GraphIndex,
    /// Shard-local reorder permutation (iff the build reordered).
    pub(crate) reordering: Option<Reordering>,
    /// First global row id of this shard's slice.
    pub(crate) offset: u32,
}

impl Shard {
    /// Map a shard-working id to the global original id: undo the
    /// shard-local σ, then add the slice offset.
    #[inline]
    fn to_global(&self, w: WorkingId) -> OriginalId {
        let local = match &self.reordering {
            Some(r) => r.inv[w.index()],
            None => w.get(),
        };
        OriginalId(self.offset + local)
    }

    pub(crate) fn map_results(&self, raw: Vec<(u32, f32)>) -> Vec<Neighbor> {
        raw.into_iter()
            .map(|(v, d)| Neighbor { id: self.to_global(WorkingId(v)), dist: d })
            .collect()
    }
}

/// A [`Searcher`] over S independently-built shards.
pub struct ShardedSearcher {
    shards: Vec<Arc<Shard>>,
    n: usize,
    dim: usize,
}

impl ShardedSearcher {
    /// Partition `data` into `shards` contiguous slices, build a graph
    /// over each with the same `params`, and assemble the searcher.
    ///
    /// `data`'s row order **defines the original id space** of every
    /// result: pass the corpus as the caller ordered it, never a
    /// reordered index's working-layout matrix (per-shard reorder
    /// permutations are handled internally). Each shard must end up
    /// with at least two points. With `shards == 1` the searcher is
    /// equivalent (bit-identical results) to a single [`GraphIndex`]
    /// built with the same parameters.
    pub fn build(data: &AlignedMatrix, shards: usize, params: &Params) -> crate::Result<Self> {
        Self::build_observed(data, shards, params, &mut NoopObserver)
    }

    /// Like [`build`](Self::build), forwarding each shard build's
    /// events to `observer` (shards are announced by their `Started`
    /// events, in slice order).
    pub fn build_observed(
        data: &AlignedMatrix,
        shards: usize,
        params: &Params,
        observer: &mut dyn BuildObserver,
    ) -> crate::Result<Self> {
        Self::build_with(data, shards, params, "artifacts", observer)
    }

    /// Fully-configured entry point: `artifacts_dir` feeds the `pjrt`
    /// backend when `params.compute` asks for it
    /// ([`IndexBuilder::build_sharded`](super::IndexBuilder::build_sharded)
    /// routes its configured directory through here).
    ///
    /// With a resolved [`Params::threads`] budget `T > 1` (explicit or
    /// via `PALLAS_BUILD_THREADS`) and `S > 1` native-backend shards,
    /// the S independent shard builds run concurrently on
    /// `min(T, S)` workers — one whole-shard build per worker,
    /// contiguous groups, each inner build pinned to a single thread —
    /// and the assembled searcher is **bit-identical** to the
    /// sequential shard loop (shard builds share no state; observers
    /// see each shard's events replayed in slice order, tagged by
    /// [`BuildEvent::ShardStarted`]). With `S = 1` the thread budget
    /// flows into the single shard's build instead.
    pub fn build_with(
        data: &AlignedMatrix,
        shards: usize,
        params: &Params,
        artifacts_dir: &str,
        observer: &mut dyn BuildObserver,
    ) -> crate::Result<Self> {
        let n = data.n();
        anyhow::ensure!(shards >= 1, "need at least one shard");
        anyhow::ensure!(
            n / shards >= 2,
            "corpus of {n} points cannot fill {shards} shards (each needs ≥ 2 points)"
        );
        let workers = crate::nndescent::resolve_build_threads(params.threads).min(shards);
        let built = if workers > 1 && params.compute != ComputeKind::Pjrt {
            Self::build_shards_parallel(data, shards, params, workers, observer)?
        } else {
            Self::build_shards_sequential(data, shards, params, artifacts_dir, observer)?
        };
        Ok(Self { shards: built, n, dim: data.dim() })
    }

    /// One shard's contiguous slice copied out of the corpus. Slices
    /// are cut lazily — one at a time sequentially, one per in-flight
    /// build in the worker pool — so a sharded build never holds a
    /// second full corpus copy beyond the shards it is actively
    /// building (the finished shards own their working-layout data
    /// either way).
    fn cut_slice(data: &AlignedMatrix, shards: usize, idx: usize) -> (usize, AlignedMatrix) {
        let n = data.n();
        let lo = idx * n / shards;
        let hi = (idx + 1) * n / shards;
        let rows: Vec<f32> = (lo..hi).flat_map(|i| data.row_logical(i).to_vec()).collect();
        (lo, AlignedMatrix::from_rows(hi - lo, data.dim(), &rows))
    }

    /// The sequential shard loop (also the `pjrt` path: that engine is
    /// exclusive state). Events stream through directly, tagged per
    /// shard.
    fn build_shards_sequential(
        data: &AlignedMatrix,
        shards: usize,
        params: &Params,
        artifacts_dir: &str,
        observer: &mut dyn BuildObserver,
    ) -> crate::Result<Vec<Arc<Shard>>> {
        let mut built = Vec::with_capacity(shards);
        for idx in 0..shards {
            let (lo, shard_data) = Self::cut_slice(data, shards, idx);
            observer.on_event(&BuildEvent::ShardStarted { shard: idx, n: shard_data.n() });
            let result = super::builder::run_build(params, &shard_data, artifacts_dir, observer)?;
            let working = result.working_data(shard_data);
            let BuildResult { graph, reordering, .. } = result;
            built.push(Arc::new(Shard {
                core: GraphIndex::new(working, graph),
                reordering,
                offset: lo as u32,
            }));
        }
        Ok(built)
    }

    /// Build the shards concurrently: `workers` scoped threads own
    /// contiguous shard groups (the `api::serve` distribution idiom),
    /// each running whole-shard builds pinned to `threads = 1` — the
    /// parallelism budget is spent *across* shards. Builds share no
    /// state, so the result is bit-identical to the sequential loop.
    /// Observer events are buffered per shard and replayed in slice
    /// order afterwards (a `&mut dyn` observer cannot be shared across
    /// workers, and interleaved progress would be useless anyway); on a
    /// build error, the first failing shard in slice order wins.
    fn build_shards_parallel(
        data: &AlignedMatrix,
        shards: usize,
        params: &Params,
        workers: usize,
        observer: &mut dyn BuildObserver,
    ) -> crate::Result<Vec<Arc<Shard>>> {
        let inner = Params { threads: 1, ..params.clone() };
        let mut groups: Vec<Vec<usize>> = (0..workers).map(|_| Vec::new()).collect();
        for idx in 0..shards {
            groups[idx * workers / shards].push(idx);
        }

        type ShardOut = (usize, usize, crate::Result<Shard>, Vec<BuildEvent>);
        let results: Vec<ShardOut> = std::thread::scope(|scope| {
            let handles: Vec<_> = groups
                .into_iter()
                .map(|group| {
                    let inner = &inner;
                    scope.spawn(move || {
                        group
                            .into_iter()
                            .map(|idx| {
                                // each worker cuts its own slice just
                                // in time: at most one in-flight slice
                                // per worker, never a full corpus copy
                                let (lo, shard_data) = Self::cut_slice(data, shards, idx);
                                let sn = shard_data.n();
                                let mut events: Vec<BuildEvent> = Vec::new();
                                let built = NnDescent::new(inner.clone()).build_observed(
                                    &shard_data,
                                    &mut FnObserver(|e: &BuildEvent| events.push(*e)),
                                );
                                let shard = built.map(|result| {
                                    let working = result.working_data(shard_data);
                                    let BuildResult { graph, reordering, .. } = result;
                                    Shard {
                                        core: GraphIndex::new(working, graph),
                                        reordering,
                                        offset: lo as u32,
                                    }
                                });
                                (idx, sn, shard, events)
                            })
                            .collect::<Vec<ShardOut>>()
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("shard build worker panicked"))
                .collect()
        });

        let mut slots: Vec<Option<ShardOut>> = Vec::new();
        slots.resize_with(shards, || None);
        for out in results {
            slots[out.0] = Some(out);
        }
        let mut built = Vec::with_capacity(shards);
        for slot in slots {
            let (idx, sn, shard, events) = slot.expect("every shard is built exactly once");
            observer.on_event(&BuildEvent::ShardStarted { shard: idx, n: sn });
            for e in &events {
                observer.on_event(e);
            }
            built.push(Arc::new(shard?));
        }
        Ok(built)
    }

    /// Wrap one built (or bundle-loaded) [`Index`](super::Index) as a
    /// single-shard searcher. Serving is bit-identical to the `Index`
    /// itself (the shard's id mapping is exactly the index's σ⁻¹ with a
    /// zero offset) — this is the bridge that lets the CLI put a loaded
    /// `KNNIv1` bundle behind the thread-per-shard pool and the
    /// micro-batching front-end.
    pub fn from_index(index: super::Index) -> Self {
        let n = index.len();
        let dim = index.dim();
        let (core, reordering) = index.into_core_parts();
        Self { shards: vec![Arc::new(Shard { core, reordering, offset: 0 })], n, dim }
    }

    /// Shared handles to the shards, in slice order — what
    /// [`ShardPool`](super::ShardPool) distributes over its workers.
    pub(crate) fn shards(&self) -> &[Arc<Shard>] {
        &self.shards
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Logical dimensionality of the corpus.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Shard slice sizes, in slice order.
    pub fn shard_sizes(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.core.n()).collect()
    }

    /// Merge per-shard candidate lists into the global top-k: sort by
    /// (distance, global id) and truncate.
    ///
    /// The comparator is **total** (`f32::total_cmp`, so a corrupt NaN
    /// cannot panic the serving path; squared-L2 distances are never
    /// `-0.0`, for which `total_cmp` would differ from `==`) and its key
    /// is unique per entry (global ids never repeat across shards), so
    /// the output is a pure function of the candidate *set*: equal
    /// distances from different shards break by global id, never by
    /// fan-out or arrival order. This is the invariant that lets the
    /// thread-per-shard pool merge replies in whatever order workers
    /// finish and still match the single-threaded fan-out bit for bit.
    pub(crate) fn merge(mut all: Vec<Neighbor>, k: usize) -> Vec<Neighbor> {
        all.sort_unstable_by(|a, b| {
            a.dist.total_cmp(&b.dist).then(a.id.get().cmp(&b.id.get()))
        });
        all.truncate(k);
        all
    }
}

impl Searcher for ShardedSearcher {
    fn len(&self) -> usize {
        self.n
    }

    fn search(
        &self,
        query: &[f32],
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Neighbor>, QueryStats) {
        let mut stats = QueryStats::default();
        let mut all = Vec::with_capacity(k * self.shards.len());
        for shard in &self.shards {
            let (raw, s) = shard.core.search(query, k, params);
            stats.dist_evals += s.dist_evals;
            stats.expansions += s.expansions;
            all.extend(shard.map_results(raw));
        }
        (Self::merge(all, k), stats)
    }

    fn search_batch(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        let t0 = Instant::now();
        let mut agg = BatchStats {
            queries: queries.n(),
            kernel: crate::distance::dispatch::active_width().name(),
            ..Default::default()
        };
        let mut merged: Vec<Vec<Neighbor>> = Vec::new();
        merged.resize_with(queries.n(), || Vec::with_capacity(k * self.shards.len()));
        for shard in &self.shards {
            let (raw, s) = shard.core.search_batch(queries, k, params);
            agg.dist_evals += s.dist_evals;
            agg.expansions += s.expansions;
            for (qi, r) in raw.into_iter().enumerate() {
                merged[qi].extend(shard.map_results(r));
            }
        }
        let results = merged.into_iter().map(|all| Self::merge(all, k)).collect();
        agg.secs = t0.elapsed().as_secs_f64();
        (results, agg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::clustered::SynthClustered;

    fn corpus(n: usize, seed: u64) -> AlignedMatrix {
        let (data, _) = SynthClustered::new(n, 8, 4, seed).generate_labeled();
        data
    }

    #[test]
    fn rejects_degenerate_partitions() {
        let data = corpus(40, 1);
        assert!(ShardedSearcher::build(&data, 0, &Params::default()).is_err());
        assert!(ShardedSearcher::build(&data, 21, &Params::default()).is_err(), "shards of <2");
        let ok = ShardedSearcher::build(&data, 8, &Params::default().with_k(3)).unwrap();
        assert_eq!(ok.shard_count(), 8);
        assert_eq!(ok.shard_sizes(), vec![5, 5, 5, 5, 5, 5, 5, 5]);
    }

    #[test]
    fn shards_cover_the_corpus_and_map_to_global_ids() {
        let data = corpus(603, 7); // non-divisible on purpose
        let params = Params::default().with_k(6).with_seed(7).with_reorder(true);
        let sharded = ShardedSearcher::build(&data, 4, &params).unwrap();
        assert_eq!(sharded.shard_sizes().iter().sum::<usize>(), 603);
        assert_eq!(Searcher::len(&sharded), 603);

        // querying any corpus row must return that global row as top hit
        let sp = SearchParams::default();
        for qi in (0..603).step_by(83) {
            let (res, _) = sharded.search(data.row_logical(qi), 3, &sp);
            assert_eq!(res[0].id, OriginalId(qi as u32), "self hit in global ids");
            assert!(res[0].dist < 1e-6);
        }
    }

    #[test]
    fn parallel_shard_builds_match_sequential_bitwise() {
        let data = corpus(600, 13);
        let seq_params = Params::default().with_k(6).with_seed(13).with_threads(1);
        let par_params = seq_params.clone().with_threads(4);
        let seq = ShardedSearcher::build(&data, 4, &seq_params).unwrap();
        let par = ShardedSearcher::build(&data, 4, &par_params).unwrap();
        assert_eq!(seq.shard_sizes(), par.shard_sizes());
        let sp = SearchParams::default();
        for qi in (0..600).step_by(29) {
            let (a, sa) = seq.search(data.row_logical(qi), 5, &sp);
            let (b, sb) = par.search(data.row_logical(qi), 5, &sp);
            assert_eq!(sa, sb, "query {qi} stats");
            assert_eq!(a.len(), b.len(), "query {qi}");
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.id, y.id, "query {qi}");
                assert_eq!(x.dist.to_bits(), y.dist.to_bits(), "query {qi}");
            }
        }
    }

    #[test]
    fn observer_events_are_tagged_and_in_shard_order() {
        use crate::nndescent::observer::FnObserver;
        let data = corpus(400, 17);
        // exercise both the concurrent (threads=4) and sequential paths
        for threads in [1usize, 4] {
            let params = Params::default().with_k(5).with_seed(17).with_threads(threads);
            let mut events: Vec<BuildEvent> = Vec::new();
            let built = ShardedSearcher::build_observed(
                &data,
                4,
                &params,
                &mut FnObserver(|e: &BuildEvent| events.push(*e)),
            )
            .unwrap();
            assert_eq!(built.shard_count(), 4);
            let tags: Vec<(usize, usize)> = events
                .iter()
                .filter_map(|e| match e {
                    BuildEvent::ShardStarted { shard, n } => Some((*shard, *n)),
                    _ => None,
                })
                .collect();
            assert_eq!(
                tags,
                vec![(0, 100), (1, 100), (2, 100), (3, 100)],
                "threads={threads}: one tag per shard, slice order"
            );
            // every shard segment carries a full build lifecycle
            assert_eq!(
                events.iter().filter(|e| matches!(e, BuildEvent::Started { .. })).count(),
                4,
                "threads={threads}"
            );
            assert_eq!(
                events.iter().filter(|e| matches!(e, BuildEvent::Finished { .. })).count(),
                4,
                "threads={threads}"
            );
            // tags precede their shard's Started event
            let first_started =
                events.iter().position(|e| matches!(e, BuildEvent::Started { .. })).unwrap();
            let first_tag = events
                .iter()
                .position(|e| matches!(e, BuildEvent::ShardStarted { .. }))
                .unwrap();
            assert!(first_tag < first_started, "threads={threads}");
        }
    }

    #[test]
    fn merge_sorts_by_distance_then_id_and_truncates() {
        let all = vec![
            Neighbor::new(9, 2.0),
            Neighbor::new(1, 1.0),
            Neighbor::new(4, 1.0),
            Neighbor::new(2, 3.0),
        ];
        let m = ShardedSearcher::merge(all, 3);
        assert_eq!(
            m,
            vec![Neighbor::new(1, 1.0), Neighbor::new(4, 1.0), Neighbor::new(9, 2.0)]
        );
    }

    #[test]
    fn merge_is_independent_of_fanout_concatenation_order() {
        // equal distances from different shards: the output depends only
        // on the candidate set, never on which shard replied first
        let base = vec![
            Neighbor::new(9, 1.0),
            Neighbor::new(1, 1.0),
            Neighbor::new(5, 0.5),
            Neighbor::new(3, 1.0),
            Neighbor::new(7, 0.5),
        ];
        let expect = vec![Neighbor::new(5, 0.5), Neighbor::new(7, 0.5), Neighbor::new(1, 1.0)];
        assert_eq!(ShardedSearcher::merge(base.clone(), 3), expect);
        let mut reversed = base.clone();
        reversed.reverse();
        assert_eq!(ShardedSearcher::merge(reversed, 3), expect);
        let mut rotated = base.clone();
        rotated.rotate_left(2);
        assert_eq!(ShardedSearcher::merge(rotated, 3), expect);
    }

    /// 4 copies of 10 distinct points, one copy per shard — so every
    /// query has exact-tie answers in *every* shard.
    fn duplicated_corpus() -> AlignedMatrix {
        let dim = 8;
        let rows: Vec<f32> = (0..40)
            .flat_map(|i| {
                let j = (i % 10) as f32;
                (0..dim).map(move |c| j * 10.0 + c as f32)
            })
            .collect();
        AlignedMatrix::from_rows(40, dim, &rows)
    }

    #[test]
    fn cross_shard_ties_break_by_global_id() {
        let data = duplicated_corpus();
        let params = Params::default().with_k(4).with_seed(11);
        let sharded = ShardedSearcher::build(&data, 4, &params).unwrap();
        assert_eq!(sharded.shard_sizes(), vec![10, 10, 10, 10]);

        // exhaustive search per shard (probe every point, pool holds
        // all), so each shard answers its zero-distance copy exactly
        let sp = SearchParams { ef: 40, probes: 40, ..Default::default() };
        for j in 0..10u32 {
            let (res, _) = sharded.search(data.row_logical(j as usize), 4, &sp);
            let expect: Vec<Neighbor> =
                (0..4).map(|s| Neighbor::new(s * 10 + j, 0.0)).collect();
            assert_eq!(res, expect, "query {j}: ties must order by global id");
            // batch path agrees bit for bit
            let qm = AlignedMatrix::from_rows(1, data.dim(), data.row_logical(j as usize));
            let (bres, _) = sharded.search_batch(&qm, 4, &sp);
            assert_eq!(bres[0], expect, "query {j} batch path");
        }
    }
}
