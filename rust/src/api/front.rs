//! Micro-batching query front-end: individual queries in, batched
//! execution underneath — the serving edge for the "heavy traffic"
//! north star.
//!
//! [`ServeFront`] owns a [`Searcher`] (typically a
//! [`ShardPool`](super::ShardPool)) on a dispatcher thread behind a
//! **bounded** submission queue. Callers [`submit`](ServeFront::submit)
//! one query at a time and get a [`QueryTicket`] to wait on; the
//! dispatcher coalesces arrivals into windows:
//!
//! * a window opens when the first request arrives and closes after
//!   [`FrontConfig::max_wait`] or once [`FrontConfig::max_batch`]
//!   requests are queued, whichever comes first — the batch-amortization
//!   trade (a bounded latency tax buys the batch path's tile kernels);
//! * requests with **identical query bytes** (`f32` bit patterns) in
//!   one window are answered by a single execution and the result is
//!   fanned back to every submitter (duplicate-query coalescing);
//! * the window's unique queries run through one
//!   [`Searcher::search_batch_owned`] call (an `Arc`'d tile, so a
//!   thread-per-shard pool underneath shares it with its workers
//!   without another copy — each window's queries are copied exactly
//!   once, flat buffer → aligned tile).
//!
//! Because the batch path is bit-equal to the sequential path per query
//! (and per-query results never depend on what else shares the batch),
//! **window composition cannot change any caller's answer**: a query
//! returns the same neighbors whether it rode alone, shared a window
//! with 63 strangers, or was deduplicated against an identical twin.
//! That invariant is what makes micro-batching transparent, and it is
//! pinned by the serve-stack integration tests.
//!
//! ## Deadlines and degradation
//!
//! [`submit_with_deadline`](ServeFront::submit_with_deadline) attaches
//! a latency budget to a query. The dispatcher forwards the **earliest**
//! deadline among a window's members into the searcher
//! ([`Searcher::search_batch_deadline_owned`]); a pool underneath drops
//! shards that miss it and the answer comes back tagged with a typed
//! [`Degradation`] shared by every member of the window (coalescing
//! means one execution serves them all — a deadline-free request that
//! rides with a tight-deadline one can therefore see a degraded
//! answer; segregate traffic onto separate fronts if that matters).
//! Fronts that never see a deadline never pass one down, so their
//! behavior — and their bits — are unchanged. Degraded answers are
//! **never** inserted into the answer cache: a partial answer must not
//! be replayed after the pool recovers.

use super::ids::Neighbor;
use super::searcher::{Degradation, Searcher};
use super::serve::{HealthWatch, PoolStats};
use crate::dataset::AlignedMatrix;
use crate::search::SearchParams;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batching-window and queue knobs for a [`ServeFront`]. `k` and
/// `params` are fixed per front: every query in a window shares one
/// `search_batch` call, so they must agree on the search configuration.
#[derive(Debug, Clone)]
pub struct FrontConfig {
    /// Neighbors returned per query.
    pub k: usize,
    /// Search parameters applied to every query.
    pub params: SearchParams,
    /// Maximum requests coalesced into one window (≥ 1).
    pub max_batch: usize,
    /// Maximum time a window stays open after its first request.
    pub max_wait: Duration,
    /// Capacity of the bounded submission queue; a full queue makes
    /// [`ServeFront::submit`] block (backpressure, not unbounded memory).
    pub queue_depth: usize,
    /// Centroid routing: serve each window through
    /// [`Searcher::search_batch_routed_owned`] with this fan-out bound
    /// (each query visits at most `m` shards, nearest centroids first —
    /// after [`plan_window`] dedup, the searcher's bucketing groups the
    /// window's queries by routed shard). `None` (the default) keeps
    /// the full fan-out, bit-identical to the historical behavior; so
    /// does any `m ≥ S`.
    pub route_top_m: Option<usize>,
    /// Capacity of the cross-window LRU answer cache (distinct query
    /// vectors retained); `0` (the default) disables it. The cache is
    /// keyed by exact `f32` bit patterns — the same key
    /// [`plan_window`] coalesces on — and stores final [`Neighbor`]
    /// lists only, so with the front's `k`/`params`/`route_top_m`
    /// fixed for its lifetime, cache-on and cache-off answers are
    /// bit-identical: a hit replays a previous window's exact result.
    /// Over a *mutable* searcher the cache additionally flushes itself
    /// whenever [`Searcher::cache_epoch`] advances (an applied insert,
    /// delete, or compaction), so the bit-identity contract holds
    /// across mutations too.
    pub answer_cache: usize,
    /// Shard replica sets the serving stack runs with (R ≥ 1; the
    /// [`PoolConfig::replicas`](super::serve::PoolConfig::replicas)
    /// knob). The front does not build the pool itself — the value is
    /// carried here so the one config the serving edge (CLI, `knng
    /// serve`) assembles names the whole stack, and so introspection
    /// of a front reports the replication it was configured for.
    pub replicas: usize,
    /// Hedge delay in microseconds for straggling shards
    /// ([`PoolConfig::hedge_us`](super::serve::PoolConfig::hedge_us));
    /// `0` disables hedging. Carried for the same reason as
    /// [`replicas`](Self::replicas).
    pub hedge_us: u64,
}

impl Default for FrontConfig {
    fn default() -> Self {
        Self {
            k: 10,
            params: SearchParams::default(),
            max_batch: 64,
            max_wait: Duration::from_micros(200),
            queue_depth: 1024,
            route_top_m: None,
            answer_cache: 0,
            replicas: 1,
            hedge_us: 0,
        }
    }
}

/// Typed rejection for a per-request `k` that does not match the
/// front's configured [`FrontConfig::k`]. Every query in a window
/// shares one `search_batch` call, so `k` is fixed per front; callers
/// that carry their own `k` (notably the `KNNQv1` wire protocol) get
/// this error from [`ServeFront::submit_with_k`] instead of a silently
/// different answer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KMismatch {
    /// The `k` the caller asked for.
    pub requested: usize,
    /// The `k` this front serves.
    pub serving: usize,
}

impl std::fmt::Display for KMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "requested k={} but this front serves k={}", self.requested, self.serving)
    }
}

impl std::error::Error for KMismatch {}

/// One submitted query awaiting dispatch.
struct Request {
    query: Vec<f32>,
    /// Absolute latency deadline, fixed at submission time (`None` =
    /// unbounded). The window it lands in honors the earliest deadline
    /// among its members.
    deadline: Option<Instant>,
    reply: mpsc::Sender<Served>,
}

/// A served answer: the neighbors plus how the window treated the query.
#[derive(Debug, Clone)]
pub struct Served {
    /// The k nearest neighbors, ascending by (distance, original id).
    pub neighbors: Vec<Neighbor>,
    /// Shape of the window this query rode in.
    pub window: WindowInfo,
    /// `Some` when the window's execution dropped shards (deadline
    /// missed, worker dead): the neighbors are the honest merge over
    /// the shards that did answer. Shared by every member of the
    /// window, since one execution served them all.
    pub degradation: Option<Degradation>,
}

/// Diagnostics about one batching window, from a caller's perspective.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WindowInfo {
    /// Requests coalesced into the window (including this one).
    pub requests: usize,
    /// Unique query vectors actually executed.
    pub unique: usize,
    /// True when this query shared its execution with an identical
    /// twin (duplicate-query coalescing fired for it).
    pub coalesced: bool,
}

/// Running totals across a front's lifetime (monotonic counters).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FrontStats {
    /// Batching windows executed.
    pub windows: u64,
    /// Requests answered.
    pub queries: u64,
    /// Requests answered from another request's execution
    /// (`queries - coalesced` executions actually hit the searcher).
    pub coalesced: u64,
    /// Shard visits reported by the searcher across all windows:
    /// `unique queries × S` under full fan-out, fewer under centroid
    /// routing ([`FrontConfig::route_top_m`]). Zero over unsharded
    /// searchers, which report no fan-out.
    pub shard_visits: u64,
    /// Unique window queries answered from the cross-window LRU answer
    /// cache ([`FrontConfig::answer_cache`]) without touching the
    /// searcher. Always zero with the cache disabled.
    pub cache_hits: u64,
    /// Windows whose execution came back degraded (shards dropped by a
    /// deadline or a dead worker). Always zero for deadline-free
    /// traffic over a healthy searcher.
    pub degraded: u64,
}

#[derive(Default)]
struct Counters {
    windows: AtomicU64,
    queries: AtomicU64,
    coalesced: AtomicU64,
    shard_visits: AtomicU64,
    cache_hits: AtomicU64,
    degraded: AtomicU64,
}

/// Handle for one submitted query; [`wait`](QueryTicket::wait) blocks
/// until the window it lands in has been served.
pub struct QueryTicket {
    rx: mpsc::Receiver<Served>,
}

impl QueryTicket {
    /// Block until the answer arrives. Errors only if the front shut
    /// down before serving this query.
    pub fn wait(self) -> crate::Result<Served> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("serve front shut down before answering"))
    }
}

/// The micro-batching front-end. Dropping it (or calling
/// [`shutdown`](ServeFront::shutdown)) drains the dispatcher and joins
/// its thread; already-queued queries are still served.
pub struct ServeFront {
    tx: Option<mpsc::SyncSender<Request>>,
    handle: Option<JoinHandle<()>>,
    dim: usize,
    k: usize,
    route_top_m: Option<usize>,
    corpus_len: usize,
    counters: Arc<Counters>,
    /// Captured from the searcher before it moved onto the dispatcher
    /// thread; `None` over searchers without supervised workers.
    health: Option<HealthWatch>,
}

impl ServeFront {
    /// Move `searcher` onto a dispatcher thread serving queries of
    /// logical dimensionality `dim` under `cfg`.
    pub fn spawn<S: Searcher + Send + 'static>(
        searcher: S,
        dim: usize,
        cfg: FrontConfig,
    ) -> crate::Result<Self> {
        anyhow::ensure!(dim >= 1, "queries must have at least one dimension");
        anyhow::ensure!(cfg.max_batch >= 1, "max_batch must be at least 1");
        anyhow::ensure!(cfg.queue_depth >= 1, "queue_depth must be at least 1");
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_depth);
        let counters = Arc::new(Counters::default());
        let thread_counters = Arc::clone(&counters);
        let (k, route_top_m, corpus_len) = (cfg.k, cfg.route_top_m, searcher.len());
        let health = searcher.health_watch();
        let handle = std::thread::Builder::new()
            .name("knng-serve-front".into())
            .spawn(move || dispatch_loop(searcher, dim, cfg, rx, thread_counters))?;
        Ok(Self {
            tx: Some(tx),
            handle: Some(handle),
            dim,
            k,
            route_top_m,
            corpus_len,
            counters,
            health,
        })
    }

    /// Enqueue one query (length must equal the front's logical `dim`).
    /// Blocks while the submission queue is full; errors if the query
    /// has the wrong arity or the dispatcher is gone.
    pub fn submit(&self, query: Vec<f32>) -> crate::Result<QueryTicket> {
        self.submit_opts(query, None)
    }

    /// Enqueue one query with a latency budget. The deadline is fixed
    /// *now* (submission time), so queue wait and window wait spend it
    /// too — it is an end-to-end budget, not a search-only one. If the
    /// budget expires before every shard answers, the reply carries the
    /// honest partial merge plus a typed [`Degradation`]; over a
    /// searcher that ignores deadlines (anything but a pool) the budget
    /// is a no-op.
    pub fn submit_with_deadline(
        &self,
        query: Vec<f32>,
        budget: Duration,
    ) -> crate::Result<QueryTicket> {
        self.submit_opts(query, Some(Instant::now() + budget))
    }

    fn submit_opts(
        &self,
        query: Vec<f32>,
        deadline: Option<Instant>,
    ) -> crate::Result<QueryTicket> {
        anyhow::ensure!(
            query.len() == self.dim,
            "query length {} does not match front dim {}",
            query.len(),
            self.dim
        );
        // typed check instead of unwrapping the sender: `close` only
        // runs from shutdown/Drop, but a submit racing a shutdown
        // should degrade into an error, not a panic
        let Some(tx) = self.tx.as_ref() else {
            anyhow::bail!("serve front is shut down");
        };
        let (reply, rx) = mpsc::channel();
        tx.send(Request { query, deadline, reply })
            .map_err(|_| anyhow::anyhow!("serve front dispatcher is gone"))?;
        Ok(QueryTicket { rx })
    }

    /// Enqueue one query that carries its own `k`. The front's `k` is
    /// fixed for its lifetime (every query in a window shares one
    /// `search_batch` call, and the answer cache replays whole
    /// results), so a mismatched `k` is **rejected** with a typed
    /// [`KMismatch`] error rather than re-bucketed into a separate
    /// window; `k == serving_k()` behaves exactly like
    /// [`submit`](ServeFront::submit).
    pub fn submit_with_k(&self, query: Vec<f32>, k: usize) -> crate::Result<QueryTicket> {
        if k != self.k {
            return Err(anyhow::Error::new(KMismatch { requested: k, serving: self.k }));
        }
        self.submit(query)
    }

    /// [`submit_with_k`](Self::submit_with_k) with a latency budget —
    /// what the `KNNQv1` server calls for frames that carry both their
    /// own `k` and a `deadline_us`.
    pub fn submit_with_k_deadline(
        &self,
        query: Vec<f32>,
        k: usize,
        budget: Duration,
    ) -> crate::Result<QueryTicket> {
        if k != self.k {
            return Err(anyhow::Error::new(KMismatch { requested: k, serving: self.k }));
        }
        self.submit_with_deadline(query, budget)
    }

    /// The fixed `k` this front serves ([`FrontConfig::k`]).
    pub fn serving_k(&self) -> usize {
        self.k
    }

    /// Logical dimensionality of accepted queries.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Rows in the served corpus (the searcher's `len` at spawn time).
    pub fn corpus_len(&self) -> usize {
        self.corpus_len
    }

    /// Centroid-routing fan-out bound ([`FrontConfig::route_top_m`]);
    /// `None` means full fan-out.
    pub fn route_top_m(&self) -> Option<usize> {
        self.route_top_m
    }

    /// Snapshot of the running totals.
    pub fn stats(&self) -> FrontStats {
        FrontStats {
            windows: self.counters.windows.load(Ordering::Relaxed),
            queries: self.counters.queries.load(Ordering::Relaxed),
            coalesced: self.counters.coalesced.load(Ordering::Relaxed),
            shard_visits: self.counters.shard_visits.load(Ordering::Relaxed),
            cache_hits: self.counters.cache_hits.load(Ordering::Relaxed),
            degraded: self.counters.degraded.load(Ordering::Relaxed),
        }
    }

    /// Live health of the searcher underneath (per-shard liveness and
    /// fault counters), when it exposes any — a
    /// [`ShardPool`](super::ShardPool) does; plain searchers return
    /// `None`. This is what the `KNNQv1` health frame reports.
    pub fn health(&self) -> Option<PoolStats> {
        self.health.as_ref().map(HealthWatch::snapshot)
    }

    /// Stop accepting queries, drain what is queued, join the
    /// dispatcher, and return the final totals.
    pub fn shutdown(mut self) -> FrontStats {
        self.close();
        self.stats()
    }

    fn close(&mut self) {
        self.tx = None; // disconnects the queue → dispatcher drains and exits
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for ServeFront {
    fn drop(&mut self) {
        self.close();
    }
}

/// Dispatcher body: open a window on the first arrival, close it on
/// `max_wait`/`max_batch`, serve, repeat until the queue disconnects.
fn dispatch_loop<S: Searcher>(
    searcher: S,
    dim: usize,
    cfg: FrontConfig,
    rx: mpsc::Receiver<Request>,
    counters: Arc<Counters>,
) {
    let mut cache = AnswerCache::new(cfg.answer_cache);
    let mut cache_epoch = searcher.cache_epoch();
    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // queue disconnected and empty: shutdown
        };
        let mut window = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while window.len() < cfg.max_batch {
            let Some(left) = deadline.checked_duration_since(Instant::now()) else { break };
            match rx.recv_timeout(left) {
                Ok(r) => window.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // a mutable searcher's answers change when its epoch moves
        // (insert/delete/compaction applied): flush before consulting
        // the cache, so every hit replays an answer from the *current*
        // epoch. Any mutation that happens-before this window's first
        // query is seen here — which is exactly the ordering the wire
        // protocol's mutate-then-ack gives a client.
        let epoch = searcher.cache_epoch();
        if epoch != cache_epoch {
            cache.clear();
            cache_epoch = epoch;
        }
        serve_window(&searcher, dim, &cfg, window, &counters, &mut cache);
    }
}

/// The exact-bytes identity of a query vector: its `f32` bit patterns,
/// so `-0.0`/`0.0` and NaN payloads stay distinct (byte semantics, not
/// float semantics). Shared by [`plan_window`]'s in-window coalescing
/// and the cross-window [`AnswerCache`].
fn query_key(row: &[f32]) -> Vec<u32> {
    row.iter().map(|x| x.to_bits()).collect()
}

/// Bounded cross-window LRU answer cache. Lives on the dispatcher
/// thread (no locking); stores final [`Neighbor`] lists only, never
/// partial search state, so a hit replays a previous window's exact
/// answer — with `k`/`params`/`route_top_m` fixed per front, cache-on
/// and cache-off results are bit-identical.
struct AnswerCache {
    cap: usize,
    tick: u64,
    map: HashMap<Vec<u32>, (u64, Vec<Neighbor>)>,
}

impl AnswerCache {
    fn new(cap: usize) -> Self {
        Self { cap, tick: 0, map: HashMap::new() }
    }

    fn get(&mut self, row: &[f32]) -> Option<Vec<Neighbor>> {
        if self.cap == 0 {
            return None;
        }
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(&query_key(row)).map(|slot| {
            slot.0 = tick; // refresh recency
            slot.1.clone()
        })
    }

    /// Drop every cached answer (the mutation-epoch flush): the next
    /// window re-executes everything it would otherwise have replayed.
    fn clear(&mut self) {
        self.map.clear();
    }

    fn insert(&mut self, row: &[f32], neighbors: &[Neighbor]) {
        if self.cap == 0 {
            return;
        }
        self.tick += 1;
        self.map.insert(query_key(row), (self.tick, neighbors.to_vec()));
        while self.map.len() > self.cap {
            // capacity is a small knob; an O(cap) eviction scan beats
            // carrying a linked order structure for it
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, (t, _))| *t)
                .map(|(key, _)| key.clone())
                .expect("map is non-empty while over capacity");
            self.map.remove(&oldest);
        }
    }
}

/// The window plan: `assign[i]` is the index into `unique` answering
/// request `i`; `unique` holds request indices in first-arrival order.
struct WindowPlan {
    assign: Vec<usize>,
    unique: Vec<usize>,
}

/// Deduplicate a window by exact query bytes (`f32` bit patterns, so
/// `-0.0`/`0.0` and NaN payloads are distinct — byte semantics, not
/// float semantics). Pure, deterministic: first arrival of each
/// distinct query executes, later twins coalesce onto it.
fn plan_window(rows: &[&[f32]]) -> WindowPlan {
    let mut seen: HashMap<Vec<u32>, usize> = HashMap::with_capacity(rows.len());
    let mut assign = Vec::with_capacity(rows.len());
    let mut unique = Vec::new();
    for (i, row) in rows.iter().enumerate() {
        match seen.entry(query_key(row)) {
            Entry::Occupied(e) => assign.push(*e.get()),
            Entry::Vacant(e) => {
                e.insert(unique.len());
                assign.push(unique.len());
                unique.push(i);
            }
        }
    }
    WindowPlan { assign, unique }
}

fn serve_window<S: Searcher>(
    searcher: &S,
    dim: usize,
    cfg: &FrontConfig,
    window: Vec<Request>,
    counters: &Counters,
    cache: &mut AnswerCache,
) {
    let rows: Vec<&[f32]> = window.iter().map(|r| r.query.as_slice()).collect();
    let plan = plan_window(&rows);

    // Each unique query is answered from the cross-window cache (hit)
    // or marked for execution (miss). With the cache disabled every
    // unique is a miss and this is the historical single-tile path.
    let mut answers: Vec<Option<Vec<Neighbor>>> = vec![None; plan.unique.len()];
    let mut misses: Vec<usize> = Vec::new(); // indices into plan.unique
    for (u, &req_i) in plan.unique.iter().enumerate() {
        match cache.get(rows[req_i]) {
            Some(hit) => answers[u] = Some(hit),
            None => misses.push(u),
        }
    }
    let hits = (plan.unique.len() - misses.len()) as u64;

    // the window honors the *earliest* deadline among its members; a
    // window with no deadlines forwards None, which is the historical
    // (bit-identical) path through the searcher
    let deadline = window.iter().filter_map(|r| r.deadline).min();

    let mut shard_visits = 0u64;
    let mut degradation: Option<Degradation> = None;
    if !misses.is_empty() {
        let flat: Vec<f32> = misses
            .iter()
            .flat_map(|&u| window[plan.unique[u]].query.iter().copied())
            .collect();
        // the one copy on this path: flat queries → aligned tile.
        // Handing the tile over as an Arc lets a thread-per-shard pool
        // share it with its workers directly instead of re-cloning it
        // 'static.
        let tile = Arc::new(AlignedMatrix::from_rows(misses.len(), dim, &flat));
        let (results, stats, degr) = searcher.search_batch_deadline_owned(
            tile,
            cfg.k,
            &cfg.params,
            cfg.route_top_m,
            deadline,
        );
        shard_visits = stats.shard_visits;
        for (&u, neighbors) in misses.iter().zip(results) {
            if degr.is_none() {
                // degraded answers are never cached: a partial merge
                // must not be replayed after the pool recovers
                cache.insert(rows[plan.unique[u]], &neighbors);
            }
            answers[u] = Some(neighbors);
        }
        degradation = degr;
    }
    let answers: Vec<Vec<Neighbor>> = answers
        .into_iter()
        // infallible by construction: every unique index went into
        // either the cache-hit arm or `misses`, and the searcher
        // returns one (possibly empty) list per tile row
        .map(|a| a.expect("every unique answered"))
        .collect();

    let mut fanout = vec![0usize; plan.unique.len()];
    for &u in &plan.assign {
        fanout[u] += 1;
    }
    counters.windows.fetch_add(1, Ordering::Relaxed);
    counters.queries.fetch_add(window.len() as u64, Ordering::Relaxed);
    counters
        .coalesced
        .fetch_add((window.len() - plan.unique.len()) as u64, Ordering::Relaxed);
    counters.shard_visits.fetch_add(shard_visits, Ordering::Relaxed);
    counters.cache_hits.fetch_add(hits, Ordering::Relaxed);
    if degradation.is_some() {
        counters.degraded.fetch_add(1, Ordering::Relaxed);
    }

    let info_base = (window.len(), plan.unique.len());
    for (req, u) in window.into_iter().zip(plan.assign) {
        // a dead receiver just means the caller stopped waiting
        let _ = req.reply.send(Served {
            neighbors: answers[u].clone(),
            window: WindowInfo {
                requests: info_base.0,
                unique: info_base.1,
                coalesced: fanout[u] > 1,
            },
            degradation: degradation.clone(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_window_coalesces_exact_duplicates_only() {
        let a = [1.0f32, 2.0];
        let a2 = [1.0f32, 2.0];
        let b = [1.0f32, 2.5];
        let c = [-0.0f32, 2.0];
        let d = [0.0f32, 2.0];
        let plan = plan_window(&[&a, &b, &a2, &c, &d, &b]);
        // uniques in first-arrival order: a, b, c, d
        assert_eq!(plan.unique, vec![0, 1, 3, 4]);
        // a2 coalesces onto a, the second b onto the first; -0.0 ≠ 0.0
        // under byte semantics
        assert_eq!(plan.assign, vec![0, 1, 0, 2, 3, 1]);
    }

    #[test]
    fn plan_window_identity_when_all_distinct() {
        let rows: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32, 1.0]).collect();
        let slices: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let plan = plan_window(&slices);
        assert_eq!(plan.unique, vec![0, 1, 2, 3, 4]);
        assert_eq!(plan.assign, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn config_defaults_are_sane() {
        let cfg = FrontConfig::default();
        assert!(cfg.max_batch >= 1);
        assert!(cfg.queue_depth >= 1);
        assert!(cfg.max_wait > Duration::ZERO);
        // cache off by default: the historical behavior is the default
        assert_eq!(cfg.answer_cache, 0);
        // replication and hedging are opt-in too
        assert_eq!(cfg.replicas, 1);
        assert_eq!(cfg.hedge_us, 0);
    }

    #[test]
    fn answer_cache_clear_drops_everything() {
        let mut cache = AnswerCache::new(4);
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        cache.insert(&a, &[Neighbor::new(1, 0.1)]);
        cache.insert(&b, &[Neighbor::new(2, 0.2)]);
        cache.clear();
        assert!(cache.get(&a).is_none(), "epoch flush must drop every entry");
        assert!(cache.get(&b).is_none());
        // the cache stays usable after a flush
        cache.insert(&a, &[Neighbor::new(3, 0.3)]);
        assert_eq!(cache.get(&a).unwrap()[0].id.0, 3);
    }

    #[test]
    fn answer_cache_zero_capacity_is_inert() {
        let mut cache = AnswerCache::new(0);
        let row = [1.0f32, 2.0];
        cache.insert(&row, &[Neighbor::new(7, 0.5)]);
        assert!(cache.get(&row).is_none());
        assert!(cache.map.is_empty());
    }

    #[test]
    fn answer_cache_hits_exact_bits_and_evicts_lru() {
        let mut cache = AnswerCache::new(2);
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        let c = [5.0f32, 6.0];
        cache.insert(&a, &[Neighbor::new(1, 0.1)]);
        cache.insert(&b, &[Neighbor::new(2, 0.2)]);
        // touch `a` so `b` is the least recently used entry
        assert_eq!(cache.get(&a).unwrap()[0].id.0, 1);
        cache.insert(&c, &[Neighbor::new(3, 0.3)]);
        assert_eq!(cache.map.len(), 2);
        assert!(cache.get(&b).is_none(), "LRU entry should have been evicted");
        assert_eq!(cache.get(&a).unwrap()[0].id.0, 1);
        assert_eq!(cache.get(&c).unwrap()[0].id.0, 3);
        // byte semantics: -0.0 is not a hit for 0.0
        cache.insert(&[0.0f32, 0.0], &[Neighbor::new(4, 0.4)]);
        assert!(cache.get(&[-0.0f32, 0.0]).is_none());
    }

    #[test]
    fn k_mismatch_is_typed_and_displayable() {
        let err = KMismatch { requested: 5, serving: 10 };
        let msg = err.to_string();
        assert!(msg.contains("k=5") && msg.contains("k=10"), "unhelpful message: {msg}");
        // the anyhow wrapper used by submit_with_k must stay downcastable
        let any = anyhow::Error::new(err);
        assert_eq!(any.downcast_ref::<KMismatch>(), Some(&err));
    }
}
