//! Thread-per-shard serving: the concurrent execution layer under the
//! facade — the first multi-threaded code path in the crate.
//!
//! [`ShardPool`] takes the S independently-built shards of a
//! [`ShardedSearcher`] and pins them to `T ≤ S` long-lived worker
//! threads (contiguous groups, so worker `w` owns shards
//! `[w·S/T, (w+1)·S/T)`). Each worker has **exclusive ownership** of
//! its shards' search scratch ([`GraphIndex::scratch`]) — the probe
//! path's buffers are per-worker state, never shared — so workers need
//! no locks: a query batch is fanned out over per-worker channels, each
//! worker runs its shards' batch searches back to back, and the pool
//! merges the per-shard top-k lists into the global top-k.
//!
//! ## Bit-equality with the single-threaded fan-out
//!
//! The pool's results are **bit-identical** to
//! `ShardedSearcher::search_batch` for every (S, T) combination:
//!
//! * each shard runs the *same* computation it runs in the sequential
//!   fan-out (same probe sequence, same scratch-reset discipline, same
//!   kernels at the same width);
//! * per-shard replies are keyed by shard index and re-assembled in
//!   slice order before merging, so arrival order is irrelevant;
//! * the merge comparator (`ShardedSearcher::merge`) is a total order
//!   on (distance, global id), which never repeats across shards.
//!
//! Aggregate `dist_evals`/`expansions` are exact sums and match the
//! sequential fan-out too; only wall-clock (`secs`) differs. This is
//! the parallel-streams decomposition of NN-Descent serving: shard
//! searches share no state, so threading them changes nothing but
//! latency.
//!
//! [`GraphIndex::scratch`]: crate::search::GraphIndex::scratch

use super::ids::Neighbor;
use super::searcher::Searcher;
use super::sharded::{gather_rows, Router, Shard, ShardedSearcher};
use crate::dataset::AlignedMatrix;
use crate::distance::dispatch;
use crate::search::{BatchStats, QueryStats, SearchParams};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

/// One fan-out request to a worker: a shared query tile plus the reply
/// channel the worker posts its per-shard answers to.
struct Job {
    queries: Arc<AlignedMatrix>,
    k: usize,
    params: SearchParams,
    /// Centroid-routing buckets (`routes[s]` = query indices bound for
    /// shard `s`, ascending): `None` fans the whole tile out to every
    /// shard. Computed once by the pool, shared read-only with every
    /// worker.
    routes: Option<Arc<Vec<Vec<u32>>>>,
    reply: mpsc::Sender<ShardReply>,
}

/// One shard's answer to a [`Job`], already mapped to global ids.
struct ShardReply {
    /// Index of the shard in slice order (the merge key).
    shard: usize,
    /// Per-query top-k candidates from this shard.
    results: Vec<Vec<Neighbor>>,
    dist_evals: u64,
    expansions: u64,
}

/// A [`Searcher`] that executes shard fan-out on worker threads.
/// Created over a borrowed [`ShardedSearcher`] (shards are shared via
/// `Arc`, so the original stays usable — handy for A/B comparisons);
/// dropping the pool shuts the workers down and joins them.
pub struct ShardPool {
    senders: Vec<mpsc::Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    /// Shared with the source `ShardedSearcher`: the pool routes
    /// through the exact same centroids and kernels as the inline
    /// fan-out, so routed results are bit-identical too.
    router: Arc<Router>,
    n: usize,
    dim: usize,
    dim_pad: usize,
    shard_count: usize,
}

impl ShardPool {
    /// Spawn `threads` workers (clamped to the shard count — a worker
    /// with nothing to own would be pure overhead) over `sharded`'s
    /// shards. `threads == 1` is a valid degenerate pool: one worker
    /// owning every shard, still bit-identical to the inline fan-out.
    pub fn new(sharded: &ShardedSearcher, threads: usize) -> crate::Result<Self> {
        anyhow::ensure!(threads >= 1, "need at least one worker thread");
        let s = sharded.shard_count();
        let t = threads.min(s);
        let mut senders = Vec::with_capacity(t);
        let mut handles = Vec::with_capacity(t);
        for w in 0..t {
            let lo = w * s / t;
            let hi = (w + 1) * s / t;
            let owned: Vec<(usize, Arc<Shard>)> =
                (lo..hi).map(|i| (i, Arc::clone(&sharded.shards()[i]))).collect();
            let (tx, rx) = mpsc::channel::<Job>();
            let handle = std::thread::Builder::new()
                .name(format!("knng-shard-{w}"))
                .spawn(move || worker_loop(owned, rx))?;
            senders.push(tx);
            handles.push(handle);
        }
        let dim_pad = sharded.shards()[0].core.data().dim_pad();
        Ok(Self {
            senders,
            handles,
            router: sharded.router_arc(),
            n: Searcher::len(sharded),
            dim: sharded.dim(),
            dim_pad,
            shard_count: s,
        })
    }

    /// Number of worker threads actually running (≤ the requested
    /// count, clamped to the shard count).
    pub fn threads(&self) -> usize {
        self.senders.len()
    }

    /// Number of shards served by the pool.
    pub fn shard_count(&self) -> usize {
        self.shard_count
    }

    /// Logical dimensionality of the corpus.
    pub fn dim(&self) -> usize {
        self.dim
    }
}

/// Worker body: serve jobs until every sender is gone. Each owned shard
/// gets its own persistent scratch — allocated once here, reused for
/// every batch this worker ever serves.
fn worker_loop(owned: Vec<(usize, Arc<Shard>)>, rx: mpsc::Receiver<Job>) {
    let mut scratch: Vec<_> = owned.iter().map(|(_, sh)| sh.core.scratch()).collect();
    while let Ok(job) = rx.recv() {
        for ((slot, shard), scr) in owned.iter().zip(scratch.iter_mut()) {
            // a send error means the caller dropped its reply channel
            // (e.g. panicked mid-collect); nothing useful to do but
            // move on to the next job
            let _ = job.reply.send(match &job.routes {
                None => {
                    let (raw, stats) =
                        shard.core.search_batch_with(&job.queries, job.k, &job.params, scr);
                    ShardReply {
                        shard: *slot,
                        results: raw.into_iter().map(|r| shard.map_results(r)).collect(),
                        dist_evals: stats.dist_evals,
                        expansions: stats.expansions,
                    }
                }
                Some(routes) => {
                    // routed: serve only this shard's bucket. The pool
                    // collects exactly one reply per shard, so an
                    // unrouted shard still replies — just empty.
                    let qids = &routes[*slot];
                    if qids.is_empty() {
                        ShardReply {
                            shard: *slot,
                            results: Vec::new(),
                            dist_evals: 0,
                            expansions: 0,
                        }
                    } else {
                        let tile = gather_rows(&job.queries, qids);
                        let (raw, stats) =
                            shard.core.search_batch_with(&tile, job.k, &job.params, scr);
                        ShardReply {
                            shard: *slot,
                            results: raw.into_iter().map(|r| shard.map_results(r)).collect(),
                            dist_evals: stats.dist_evals,
                            expansions: stats.expansions,
                        }
                    }
                }
            });
        }
    }
}

impl Searcher for ShardPool {
    fn len(&self) -> usize {
        self.n
    }

    fn search(
        &self,
        query: &[f32],
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Neighbor>, QueryStats) {
        assert!(
            query.len() == self.dim || query.len() == self.dim_pad,
            "query length {} matches neither dim {} nor padded {}",
            query.len(),
            self.dim,
            self.dim_pad
        );
        // a 1-row tile through the batch path: per-pair bit-equal to the
        // sequential probe kernels, so this matches
        // ShardedSearcher::search exactly (ids, distance bits, stats)
        let qm = AlignedMatrix::from_rows(1, self.dim, &query[..self.dim]);
        let (mut results, agg) = self.search_batch(&qm, k, params);
        let only = results.pop().unwrap_or_default();
        (only, QueryStats { dist_evals: agg.dist_evals, expansions: agg.expansions })
    }

    fn search_batch(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        // the borrowed entry point has to copy once: workers need a
        // 'static tile. Callers that already own the tile (the
        // micro-batching front) use search_batch_owned and skip this.
        self.search_batch_owned(Arc::new(queries.clone()), k, params)
    }

    fn search_batch_owned(
        &self,
        queries: Arc<AlignedMatrix>,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        // validate before fan-out: a bad tile must fail *this* call
        // with the same message the inline path gives, not panic a
        // worker thread and poison the pool for every other caller
        assert_eq!(
            queries.dim(),
            self.dim,
            "query batch dim {} does not match index dim {}",
            queries.dim(),
            self.dim
        );
        let t0 = Instant::now();
        // the Arc is shared as-is with every worker: zero tile copies
        // on this path
        let (tx, rx) = mpsc::channel::<ShardReply>();
        for sender in &self.senders {
            sender
                .send(Job {
                    queries: Arc::clone(&queries),
                    k,
                    params: *params,
                    routes: None,
                    reply: tx.clone(),
                })
                .expect("shard worker exited before the pool was dropped");
        }
        drop(tx);

        // collect exactly one reply per shard, slotted by shard index so
        // arrival order cannot influence anything downstream
        let mut per_shard: Vec<Option<ShardReply>> = Vec::new();
        per_shard.resize_with(self.shard_count, || None);
        for _ in 0..self.shard_count {
            let reply = rx.recv().expect("shard worker died mid-batch");
            per_shard[reply.shard] = Some(reply);
        }

        let mut agg = BatchStats {
            queries: queries.n(),
            kernel: dispatch::active_width().name(),
            shard_visits: (queries.n() * self.shard_count) as u64,
            ..Default::default()
        };
        let mut merged: Vec<Vec<Neighbor>> = Vec::new();
        merged.resize_with(queries.n(), || Vec::with_capacity(k * self.shard_count));
        for slot in per_shard {
            let reply = slot.expect("a shard never replied");
            agg.dist_evals += reply.dist_evals;
            agg.expansions += reply.expansions;
            for (qi, r) in reply.results.into_iter().enumerate() {
                merged[qi].extend(r);
            }
        }
        let results = merged.into_iter().map(|all| ShardedSearcher::merge(all, k)).collect();
        agg.secs = t0.elapsed().as_secs_f64();
        (results, agg)
    }

    fn search_batch_routed(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
        top_m: usize,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        self.search_batch_routed_owned(Arc::new(queries.clone()), k, params, top_m)
    }

    fn search_batch_routed_owned(
        &self,
        queries: Arc<AlignedMatrix>,
        k: usize,
        params: &SearchParams,
        top_m: usize,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        assert_eq!(
            queries.dim(),
            self.dim,
            "query batch dim {} does not match index dim {}",
            queries.dim(),
            self.dim
        );
        let t0 = Instant::now();
        // route on the calling thread (one pass over the query×centroid
        // tile), then share the buckets read-only with every worker —
        // identical code path to ShardedSearcher::search_batch_routed,
        // so the pool's routed results are bit-identical to the inline
        // routed fan-out
        let m = top_m.clamp(1, self.shard_count);
        let (buckets, route_evals) = self.router.bucket(&queries, m);
        let buckets = Arc::new(buckets);
        let (tx, rx) = mpsc::channel::<ShardReply>();
        for sender in &self.senders {
            sender
                .send(Job {
                    queries: Arc::clone(&queries),
                    k,
                    params: *params,
                    routes: Some(Arc::clone(&buckets)),
                    reply: tx.clone(),
                })
                .expect("shard worker exited before the pool was dropped");
        }
        drop(tx);

        let mut per_shard: Vec<Option<ShardReply>> = Vec::new();
        per_shard.resize_with(self.shard_count, || None);
        for _ in 0..self.shard_count {
            let reply = rx.recv().expect("shard worker died mid-batch");
            per_shard[reply.shard] = Some(reply);
        }

        let mut agg = BatchStats {
            queries: queries.n(),
            kernel: dispatch::active_width().name(),
            dist_evals: route_evals,
            ..Default::default()
        };
        let mut merged: Vec<Vec<Neighbor>> = Vec::new();
        merged.resize_with(queries.n(), || Vec::with_capacity(k * m));
        for slot in per_shard {
            let reply = slot.expect("a shard never replied");
            agg.dist_evals += reply.dist_evals;
            agg.expansions += reply.expansions;
            let qids = &buckets[reply.shard];
            agg.shard_visits += qids.len() as u64;
            for (pos, r) in reply.results.into_iter().enumerate() {
                merged[qids[pos] as usize].extend(r);
            }
        }
        let results = merged.into_iter().map(|all| ShardedSearcher::merge(all, k)).collect();
        agg.secs = t0.elapsed().as_secs_f64();
        (results, agg)
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // disconnect every job channel, then join: workers exit their
        // recv loop as soon as the senders are gone
        self.senders.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::clustered::SynthClustered;
    use crate::nndescent::Params;
    use crate::testing::assert_neighbors_bitwise_eq;

    fn corpus(n: usize, seed: u64) -> AlignedMatrix {
        let (data, _) = SynthClustered::new(n, 8, 4, seed).generate_labeled();
        data
    }

    #[test]
    fn pool_matches_inline_fanout_bitwise() {
        let data = corpus(400, 3);
        let params = Params::default().with_k(8).with_seed(3);
        let sharded = ShardedSearcher::build(&data, 4, &params).unwrap();
        let sp = SearchParams::default();
        let queries = AlignedMatrix::from_rows(
            30,
            data.dim(),
            &(0..30).flat_map(|i| data.row_logical(i * 13).to_vec()).collect::<Vec<f32>>(),
        );
        let (expect, estats) = sharded.search_batch(&queries, 5, &sp);
        for threads in [1usize, 2, 4, 9] {
            let pool = ShardPool::new(&sharded, threads).unwrap();
            assert_eq!(pool.threads(), threads.min(4));
            assert_eq!(pool.shard_count(), 4);
            assert_eq!(Searcher::len(&pool), 400);
            let (got, gstats) = pool.search_batch(&queries, 5, &sp);
            assert_neighbors_bitwise_eq(&expect, &got, &format!("threads={threads}"));
            assert_eq!(estats.dist_evals, gstats.dist_evals);
            assert_eq!(estats.expansions, gstats.expansions);
        }
    }

    #[test]
    fn pool_single_query_matches_sharded_search() {
        let data = corpus(300, 5);
        let params = Params::default().with_k(8).with_seed(5);
        let sharded = ShardedSearcher::build(&data, 3, &params).unwrap();
        let pool = ShardPool::new(&sharded, 2).unwrap();
        let sp = SearchParams::default();
        for qi in (0..300).step_by(37) {
            let (a, sa) = sharded.search(data.row_logical(qi), 4, &sp);
            let (b, sb) = pool.search(data.row_logical(qi), 4, &sp);
            assert_neighbors_bitwise_eq(
                std::slice::from_ref(&a),
                std::slice::from_ref(&b),
                &format!("query {qi}"),
            );
            assert_eq!(sa, sb, "query {qi} stats");
        }
    }

    #[test]
    fn owned_tile_entry_point_matches_borrowed() {
        // the Arc handoff (no tile clone) must not change anything:
        // same results, same stats, for both the pool and — through the
        // trait default — the inline sharded searcher
        let data = corpus(300, 11);
        let params = Params::default().with_k(8).with_seed(11);
        let sharded = ShardedSearcher::build(&data, 3, &params).unwrap();
        let pool = ShardPool::new(&sharded, 2).unwrap();
        let sp = SearchParams::default();
        let queries = AlignedMatrix::from_rows(
            12,
            data.dim(),
            &(0..12).flat_map(|i| data.row_logical(i * 23).to_vec()).collect::<Vec<f32>>(),
        );
        let (expect, estats) = pool.search_batch(&queries, 4, &sp);
        let tile = std::sync::Arc::new(queries.clone());
        let (got, gstats) = pool.search_batch_owned(std::sync::Arc::clone(&tile), 4, &sp);
        assert_neighbors_bitwise_eq(&expect, &got, "owned vs borrowed");
        assert_eq!(estats.dist_evals, gstats.dist_evals);
        let (inline, _) = sharded.search_batch_owned(tile, 4, &sp);
        assert_neighbors_bitwise_eq(&expect, &inline, "trait default");
    }

    #[test]
    fn pool_routed_matches_inline_routed_bitwise() {
        use crate::api::partition::KMeans;
        let data = corpus(600, 15);
        let params = Params::default().with_k(8).with_seed(15);
        let sharded =
            ShardedSearcher::build_partitioned(&data, 4, &params, &KMeans::default()).unwrap();
        let sp = SearchParams::default();
        let queries = AlignedMatrix::from_rows(
            40,
            data.dim(),
            &(0..40).flat_map(|i| data.row_logical(i * 11).to_vec()).collect::<Vec<f32>>(),
        );
        for threads in [1usize, 3] {
            let pool = ShardPool::new(&sharded, threads).unwrap();
            for m in [1usize, 2, 4] {
                let (expect, estats) = sharded.search_batch_routed(&queries, 5, &sp, m);
                let (got, gstats) = pool.search_batch_routed(&queries, 5, &sp, m);
                assert_neighbors_bitwise_eq(&expect, &got, &format!("threads={threads} m={m}"));
                assert_eq!(estats.dist_evals, gstats.dist_evals, "threads={threads} m={m}");
                assert_eq!(estats.expansions, gstats.expansions, "threads={threads} m={m}");
                assert_eq!(
                    estats.shard_visits, gstats.shard_visits,
                    "threads={threads} m={m}"
                );
            }
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let data = corpus(120, 7);
        let sharded =
            ShardedSearcher::build(&data, 2, &Params::default().with_k(6).with_seed(7)).unwrap();
        let pool = ShardPool::new(&sharded, 2).unwrap();
        let queries = AlignedMatrix::zeroed(0, data.dim());
        let (res, agg) = pool.search_batch(&queries, 5, &SearchParams::default());
        assert!(res.is_empty());
        assert_eq!(agg.queries, 0);
        assert_eq!(agg.kernel, dispatch::active_width().name());
    }

    #[test]
    fn rejects_zero_threads() {
        let data = corpus(100, 9);
        let sharded =
            ShardedSearcher::build(&data, 2, &Params::default().with_k(6).with_seed(9)).unwrap();
        assert!(ShardPool::new(&sharded, 0).is_err());
    }
}
