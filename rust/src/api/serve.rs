//! Thread-per-shard serving: the concurrent execution layer under the
//! facade — the first multi-threaded code path in the crate.
//!
//! [`ShardPool`] takes the S independently-built shards of a
//! [`ShardedSearcher`] and pins them to `T ≤ S` long-lived worker
//! threads (contiguous groups, so worker `w` owns shards
//! `[w·S/T, (w+1)·S/T)`). Each worker has **exclusive ownership** of
//! its shards' search scratch ([`GraphIndex::scratch`]) — the probe
//! path's buffers are per-worker state, never shared — so workers need
//! no locks: a query batch is fanned out over per-worker channels, each
//! worker runs its shards' batch searches back to back, and the pool
//! merges the per-shard top-k lists into the global top-k.
//!
//! ## Bit-equality with the single-threaded fan-out
//!
//! A healthy pool's results are **bit-identical** to
//! `ShardedSearcher::search_batch` for every (S, T) combination:
//!
//! * each shard runs the *same* computation it runs in the sequential
//!   fan-out (same probe sequence, same scratch-reset discipline, same
//!   kernels at the same width);
//! * per-shard replies are keyed by shard index and re-assembled in
//!   slice order before merging, so arrival order is irrelevant;
//! * the merge comparator (`ShardedSearcher::merge`) is a total order
//!   on (distance, global id), which never repeats across shards.
//!
//! Aggregate `dist_evals`/`expansions` are exact sums and match the
//! sequential fan-out too; only wall-clock (`secs`) differs. This is
//! the parallel-streams decomposition of NN-Descent serving: shard
//! searches share no state, so threading them changes nothing but
//! latency.
//!
//! ## Fault tolerance
//!
//! Workers are mortal and the pool knows it:
//!
//! * **Panic containment** — each shard search runs under
//!   `catch_unwind`; a panic becomes a typed failure reply (and a
//!   fresh scratch, so the next batch is served from clean state)
//!   instead of a dead thread.
//! * **Supervision** — a worker that *does* die (thread exit) is
//!   detected at the next batch and respawned with fresh per-shard
//!   scratch, up to a bounded respawn budget
//!   ([`PoolConfig::respawn_budget`]); past the budget its shards are
//!   declared dead and the pool keeps serving from the survivors.
//! * **Deadlines** — [`Searcher::search_batch_deadline_owned`] bounds
//!   reply collection; shards that miss the deadline are dropped from
//!   the merge and reported in a typed
//!   [`Degradation`](super::searcher::Degradation).
//! * **Health** — per-shard liveness and fault counters are readable
//!   at any time through [`ShardPool::stats`] or a detachable
//!   [`HealthWatch`] that survives the pool moving onto a front's
//!   dispatcher thread.
//!
//! ## Replication, failover, hedging
//!
//! [`PoolConfig::replicas`] (R ≥ 1; default 1 = the unreplicated pool,
//! bit for bit) materializes R workers per shard group over the *same*
//! `Arc<Shard>`s — search scratch is per-worker, the corpus and graph
//! are shared, so a replica costs scratch memory, not a corpus copy.
//! Dispatch runs in **waves**: the first wave goes to the primary
//! (replica 0); a shard whose reply comes back as a typed panic, or
//! never comes back because its worker died, is re-dispatched in the
//! next wave to the next live replica it has not tried yet
//! (`failovers` counts those re-dispatches). With a hedge delay armed
//! ([`PoolConfig::hedge_us`] or
//! [`PoolConfig::hedge_deadline_fraction`]), a shard that is merely
//! *slow* gets its job re-sent mid-wave to the next untried replica
//! (`hedges_sent`); whichever copy answers first wins (`hedge_wins`)
//! and the duplicate is discarded by shard slot. A shard enters the
//! [`Degradation`] path only when **all** R replicas are gone or late.
//!
//! Replication preserves the determinism contract: every replica runs
//! the identical computation over the identical shard, so its reply is
//! bit-identical by the same T-invariance argument as above — which
//! replica wins a hedge race cannot change a single bit of the answer.
//! The chaos suite asserts this with one replica killed and with a
//! delayed primary losing to its hedge.
//!
//! A degraded answer is exactly the honest reduced fan-out over the
//! surviving shards ([`ShardedSearcher::search_batch_subset`] defines
//! that reference; the chaos suite asserts the equality bit for bit).
//!
//! [`GraphIndex::scratch`]: crate::search::GraphIndex::scratch
//! [`ShardedSearcher::search_batch_subset`]: super::ShardedSearcher::search_batch_subset

use super::ids::Neighbor;
use super::searcher::{DegradeCause, Degradation, Searcher};
use super::sharded::{gather_rows, Router, Shard, ShardedSearcher};
use crate::dataset::AlignedMatrix;
use crate::distance::dispatch;
use crate::search::{BatchStats, QueryStats, SearchParams};
use crate::testing::faults::{self, FaultAction};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Default [`PoolConfig::respawn_budget`]: how many times one worker
/// may die and be replaced before its shards are declared dead.
pub const DEFAULT_RESPAWN_BUDGET: u32 = 3;

/// Construction knobs for a [`ShardPool`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolConfig {
    /// Worker threads requested per replica (clamped to the shard
    /// count).
    pub threads: usize,
    /// Times each worker may be respawned after dying before its
    /// shards are declared permanently dead. `0` means a first death
    /// is final.
    pub respawn_budget: u32,
    /// Copies of each shard's serving state (R ≥ 1). `1` is exactly
    /// the unreplicated pool, bit for bit. Higher values spawn
    /// `R × threads` workers over the same `Arc<Shard>`s — per-worker
    /// search scratch is cloned, the corpus and graph are shared — so
    /// a dead, panicking, or straggling primary fails over to the next
    /// live replica instead of degrading the answer.
    pub replicas: usize,
    /// Fixed hedge delay in microseconds: when > 0 (and R > 1), a
    /// shard that has not replied this long after dispatch has its job
    /// re-sent to the next untried live replica; the first valid reply
    /// wins and duplicates are discarded by shard slot. `0` defers to
    /// [`hedge_deadline_fraction`](Self::hedge_deadline_fraction).
    pub hedge_us: u64,
    /// Hedge delay as a fraction of the batch's remaining deadline
    /// budget (clamped to `[0, 1]`), consulted when
    /// [`hedge_us`](Self::hedge_us) is `0` — so only batches that
    /// carry a deadline hedge through this knob. `0.0` disables
    /// hedging entirely.
    pub hedge_deadline_fraction: f64,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            threads: 1,
            respawn_budget: DEFAULT_RESPAWN_BUDGET,
            replicas: 1,
            hedge_us: 0,
            hedge_deadline_fraction: 0.0,
        }
    }
}

/// One fan-out request to a worker: a shared query tile plus the reply
/// channel the worker posts its per-shard answers to.
struct Job {
    queries: Arc<AlignedMatrix>,
    k: usize,
    params: SearchParams,
    /// Centroid-routing buckets (`routes[s]` = query indices bound for
    /// shard `s`, ascending): `None` fans the whole tile out to every
    /// shard. Computed once by the pool, shared read-only with every
    /// worker.
    routes: Option<Arc<Vec<Vec<u32>>>>,
    /// Which of the worker's owned shards to serve, ascending. A full
    /// first-wave dispatch lists every owned shard; failover and hedge
    /// re-dispatches list only the shards being retried.
    shards: Vec<usize>,
    reply: mpsc::Sender<ShardReply>,
}

/// What one shard made of a [`Job`].
enum ShardOutcome {
    /// The search ran; results are already mapped to global ids.
    Ok { results: Vec<Vec<Neighbor>>, dist_evals: u64, expansions: u64 },
    /// The search panicked; the worker contained it and stays alive.
    /// The message is the panic payload (for logs/diagnostics).
    Panicked { message: String },
}

fn is_ok(slot: &Option<ShardOutcome>) -> bool {
    matches!(slot, Some(ShardOutcome::Ok { .. }))
}

/// One shard's reply to a [`Job`], keyed by slice-order shard index
/// (the slot key that makes duplicate hedged replies discardable) plus
/// the replica that served it.
struct ShardReply {
    shard: usize,
    replica: usize,
    outcome: ShardOutcome,
}

/// One worker thread's supervision record.
struct WorkerSlot {
    /// Stable worker id within its replica set (names the thread
    /// across respawns).
    id: usize,
    /// Which replica set this worker belongs to (0 = primary).
    replica: usize,
    /// Job channel; `None` once the worker is permanently dead.
    sender: Option<mpsc::Sender<Job>>,
    handle: Option<JoinHandle<()>>,
    /// Slice-order shard indices this worker owns.
    owned: Vec<usize>,
    respawns_left: u32,
}

/// Liveness of one shard (or one replica of one shard) in a
/// [`ShardPool`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardState {
    /// Served by a live worker.
    Healthy,
    /// Its worker exhausted the respawn budget (or could not be
    /// respawned); this copy no longer participates in fan-out.
    Dead,
}

/// Snapshot of a pool's health: per-shard and per-replica liveness
/// plus monotonic fault counters (what [`HealthWatch::snapshot`]
/// returns and the `KNNQv1` health frame reports).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolStats {
    /// Worker threads per replica the pool was built with.
    pub threads: usize,
    /// Replica sets the pool was built with (R ≥ 1).
    pub replicas: usize,
    /// Per-shard liveness, slice order. A shard is [`ShardState::Dead`]
    /// only when **every** one of its replicas is dead — one live copy
    /// keeps it healthy (that copy serves the fan-out via failover).
    pub shards: Vec<ShardState>,
    /// Per-replica liveness: `replica_states[s][r]` is replica `r` of
    /// shard `s`.
    pub replica_states: Vec<Vec<ShardState>>,
    /// Workers respawned after dying.
    pub respawns: u64,
    /// Shard-search panics contained by `catch_unwind`.
    pub contained_panics: u64,
    /// Replies that never arrived from a worker that stayed alive.
    pub lost_replies: u64,
    /// Shards dropped from a merge because a deadline expired.
    pub deadline_misses: u64,
    /// Hedged re-dispatches sent to back up a slow shard.
    pub hedges_sent: u64,
    /// Hedged re-dispatches whose reply won the race (arrived before
    /// the straggling primary's).
    pub hedge_wins: u64,
    /// Shard dispatches that went to a non-primary replica because an
    /// earlier attempt failed or the primary was dead.
    pub failovers: u64,
}

impl PoolStats {
    /// True when every shard is [`ShardState::Healthy`] (at least one
    /// live replica).
    pub fn all_healthy(&self) -> bool {
        self.shards.iter().all(|s| *s == ShardState::Healthy)
    }

    /// Slice-order indices of dead shards (all replicas gone),
    /// ascending.
    pub fn dead_shards(&self) -> Vec<u32> {
        self.shards
            .iter()
            .enumerate()
            .filter(|(_, s)| **s == ShardState::Dead)
            .map(|(i, _)| i as u32)
            .collect()
    }

    /// Per-replica liveness flattened shard-major (`shards × replicas`
    /// bools, `true` = alive) — the layout the `KNNQv1` health frame
    /// carries.
    pub fn replicas_alive_flat(&self) -> Vec<bool> {
        self.replica_states
            .iter()
            .flat_map(|rs| rs.iter().map(|st| *st == ShardState::Healthy))
            .collect()
    }
}

/// Lock-free health storage shared between the pool, its workers, and
/// any detached [`HealthWatch`] handles.
struct HealthInner {
    threads: usize,
    replicas: usize,
    /// Shard-major per-replica death flags: replica `r` of shard `s`
    /// is slot `s * replicas + r`.
    replica_dead: Vec<AtomicBool>,
    respawns: AtomicU64,
    contained_panics: AtomicU64,
    lost_replies: AtomicU64,
    deadline_misses: AtomicU64,
    hedges_sent: AtomicU64,
    hedge_wins: AtomicU64,
    failovers: AtomicU64,
}

impl HealthInner {
    fn new(threads: usize, shard_count: usize, replicas: usize) -> Self {
        Self {
            threads,
            replicas,
            replica_dead: (0..shard_count * replicas).map(|_| AtomicBool::new(false)).collect(),
            respawns: AtomicU64::new(0),
            contained_panics: AtomicU64::new(0),
            lost_replies: AtomicU64::new(0),
            deadline_misses: AtomicU64::new(0),
            hedges_sent: AtomicU64::new(0),
            hedge_wins: AtomicU64::new(0),
            failovers: AtomicU64::new(0),
        }
    }

    fn bury(&self, shards: &[usize], replica: usize) {
        for &s in shards {
            self.replica_dead[s * self.replicas + replica].store(true, Ordering::Relaxed);
        }
    }
}

/// A cloneable, live view of a [`ShardPool`]'s health that stays valid
/// after the pool moves onto another thread (a
/// [`ServeFront`](super::ServeFront) dispatcher). This is what
/// [`Searcher::health_watch`] hands the serving edge.
#[derive(Clone)]
pub struct HealthWatch {
    inner: Arc<HealthInner>,
}

impl HealthWatch {
    /// Current health snapshot.
    pub fn snapshot(&self) -> PoolStats {
        let inner = &self.inner;
        let shard_count = inner.replica_dead.len() / inner.replicas;
        let replica_states: Vec<Vec<ShardState>> = (0..shard_count)
            .map(|s| {
                (0..inner.replicas)
                    .map(|r| {
                        if inner.replica_dead[s * inner.replicas + r].load(Ordering::Relaxed) {
                            ShardState::Dead
                        } else {
                            ShardState::Healthy
                        }
                    })
                    .collect()
            })
            .collect();
        let shards = replica_states
            .iter()
            .map(|rs| {
                if rs.iter().all(|st| *st == ShardState::Dead) {
                    ShardState::Dead
                } else {
                    ShardState::Healthy
                }
            })
            .collect();
        PoolStats {
            threads: inner.threads,
            replicas: inner.replicas,
            shards,
            replica_states,
            respawns: inner.respawns.load(Ordering::Relaxed),
            contained_panics: inner.contained_panics.load(Ordering::Relaxed),
            lost_replies: inner.lost_replies.load(Ordering::Relaxed),
            deadline_misses: inner.deadline_misses.load(Ordering::Relaxed),
            hedges_sent: inner.hedges_sent.load(Ordering::Relaxed),
            hedge_wins: inner.hedge_wins.load(Ordering::Relaxed),
            failovers: inner.failovers.load(Ordering::Relaxed),
        }
    }
}

impl std::fmt::Debug for HealthWatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HealthWatch").field("snapshot", &self.snapshot()).finish()
    }
}

/// A [`Searcher`] that executes shard fan-out on supervised worker
/// threads. Created over a borrowed [`ShardedSearcher`] (shards are
/// shared via `Arc`, so the original stays usable — handy for A/B
/// comparisons); dropping the pool shuts the workers down and joins
/// them.
pub struct ShardPool {
    /// The worker grid, replica-major: worker `w` of replica `r` is
    /// slot `r * threads + w`. Every replica set owns the identical
    /// contiguous shard groups.
    workers: Mutex<Vec<WorkerSlot>>,
    /// Retained for respawns: a replacement worker re-acquires its
    /// shard group (and fresh scratch) from here.
    shards: Vec<Arc<Shard>>,
    health: HealthWatch,
    /// Shared with the source `ShardedSearcher`: the pool routes
    /// through the exact same centroids and kernels as the inline
    /// fan-out, so routed results are bit-identical too.
    router: Arc<Router>,
    n: usize,
    dim: usize,
    dim_pad: usize,
    shard_count: usize,
    threads: usize,
    replicas: usize,
    hedge_us: u64,
    hedge_deadline_fraction: f64,
    /// Which worker (id within a replica set) owns each shard.
    worker_of_shard: Vec<usize>,
}

impl ShardPool {
    /// Spawn `threads` workers (clamped to the shard count — a worker
    /// with nothing to own would be pure overhead) over `sharded`'s
    /// shards, with the default respawn budget and no replication.
    /// `threads == 1` is a valid degenerate pool: one worker owning
    /// every shard, still bit-identical to the inline fan-out.
    pub fn new(sharded: &ShardedSearcher, threads: usize) -> crate::Result<Self> {
        Self::with_config(sharded, PoolConfig { threads, ..Default::default() })
    }

    /// [`new`](Self::new) with explicit supervision, replication, and
    /// hedging knobs.
    pub fn with_config(sharded: &ShardedSearcher, cfg: PoolConfig) -> crate::Result<Self> {
        anyhow::ensure!(cfg.threads >= 1, "need at least one worker thread");
        anyhow::ensure!(cfg.replicas >= 1, "need at least one replica of each shard");
        let s = sharded.shard_count();
        let t = cfg.threads.min(s);
        let r = cfg.replicas;
        let shards: Vec<Arc<Shard>> = sharded.shards().iter().map(Arc::clone).collect();
        let health = HealthWatch { inner: Arc::new(HealthInner::new(t, s, r)) };
        let mut worker_of_shard = vec![0usize; s];
        let mut workers = Vec::with_capacity(r * t);
        for replica in 0..r {
            for w in 0..t {
                let lo = w * s / t;
                let hi = (w + 1) * s / t;
                let owned: Vec<usize> = (lo..hi).collect();
                if replica == 0 {
                    for &i in &owned {
                        worker_of_shard[i] = w;
                    }
                }
                let owned_shards: Vec<(usize, Arc<Shard>)> =
                    owned.iter().map(|&i| (i, Arc::clone(&shards[i]))).collect();
                let (tx, handle) =
                    spawn_worker(w, replica, owned_shards, Arc::clone(&health.inner))?;
                workers.push(WorkerSlot {
                    id: w,
                    replica,
                    sender: Some(tx),
                    handle: Some(handle),
                    owned,
                    respawns_left: cfg.respawn_budget,
                });
            }
        }
        let dim_pad = shards[0].core.data().dim_pad();
        Ok(Self {
            workers: Mutex::new(workers),
            shards,
            health,
            router: sharded.router_arc(),
            n: Searcher::len(sharded),
            dim: sharded.dim(),
            dim_pad,
            shard_count: s,
            threads: t,
            replicas: r,
            hedge_us: cfg.hedge_us,
            hedge_deadline_fraction: cfg.hedge_deadline_fraction,
            worker_of_shard,
        })
    }

    /// Number of worker threads per replica the pool was built with
    /// (≤ the requested count, clamped to the shard count).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Replica sets the pool was built with (≥ 1).
    pub fn replicas(&self) -> usize {
        self.replicas
    }

    /// Number of shards served by the pool (live or dead).
    pub fn shard_count(&self) -> usize {
        self.shard_count
    }

    /// Logical dimensionality of the corpus.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Current health snapshot: per-shard/per-replica liveness and
    /// fault counters.
    pub fn stats(&self) -> PoolStats {
        self.health.snapshot()
    }

    fn workers_lock(&self) -> std::sync::MutexGuard<'_, Vec<WorkerSlot>> {
        // the slots are only mutated under this lock and every mutation
        // leaves them consistent, so a poisoned lock (a caller thread
        // panicked mid-batch) is safe to recover
        self.workers.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Join workers that died since the last batch and respawn them
    /// (budget permitting) — the supervision pass, run before dispatch
    /// and after collection.
    fn supervise(&self, workers: &mut [WorkerSlot]) {
        for slot in workers.iter_mut() {
            let died =
                slot.sender.is_some() && slot.handle.as_ref().is_some_and(|h| h.is_finished());
            if died {
                self.respawn_or_bury(slot);
            }
        }
    }

    /// Replace a dead worker with a fresh thread (fresh scratch) or,
    /// with the budget spent, declare its replica of its shards dead.
    fn respawn_or_bury(&self, slot: &mut WorkerSlot) {
        if let Some(h) = slot.handle.take() {
            let _ = h.join();
        }
        slot.sender = None;
        if slot.respawns_left == 0 {
            self.health.inner.bury(&slot.owned, slot.replica);
            return;
        }
        slot.respawns_left -= 1;
        self.health.inner.respawns.fetch_add(1, Ordering::Relaxed);
        let owned_shards: Vec<(usize, Arc<Shard>)> =
            slot.owned.iter().map(|&i| (i, Arc::clone(&self.shards[i]))).collect();
        match spawn_worker(slot.id, slot.replica, owned_shards, Arc::clone(&self.health.inner)) {
            Ok((tx, handle)) => {
                slot.sender = Some(tx);
                slot.handle = Some(handle);
            }
            Err(_) => self.health.inner.bury(&slot.owned, slot.replica),
        }
    }

    /// The hedge timer fired: re-send every still-unanswered shard of
    /// the current wave to its next untried live replica, on the same
    /// reply channel the wave is collecting from. The caller drops its
    /// spare sender right after, restoring disconnect-based
    /// termination.
    #[allow(clippy::too_many_arguments)]
    fn fire_hedges(
        &self,
        queries: &Arc<AlignedMatrix>,
        k: usize,
        params: &SearchParams,
        routes: &Option<Arc<Vec<Vec<u32>>>>,
        slots: &[Option<ShardOutcome>],
        tried: &mut [Vec<usize>],
        hedged_to: &mut [Option<usize>],
        wave_worker: &[Option<usize>],
        reply: &mpsc::Sender<ShardReply>,
        outstanding: &mut usize,
    ) {
        let mut workers = self.workers_lock();
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); workers.len()];
        for s in 0..self.shard_count {
            // hedge only genuine stragglers: dispatched this wave with
            // no reply of any kind yet (a typed panic is not slow — it
            // fails over on the next wave instead)
            if wave_worker[s].is_none() || slots[s].is_some() {
                continue;
            }
            let w = self.worker_of_shard[s];
            let Some(wi) = (0..self.replicas).map(|r| r * self.threads + w).find(|&wi| {
                !tried[s].contains(&workers[wi].replica) && workers[wi].sender.is_some()
            }) else {
                continue;
            };
            groups[wi].push(s);
        }
        for (wi, shard_set) in groups.into_iter().enumerate() {
            if shard_set.is_empty() {
                continue;
            }
            let replica = workers[wi].replica;
            let mut job = Job {
                queries: Arc::clone(queries),
                k,
                params: *params,
                routes: routes.clone(),
                shards: shard_set.clone(),
                reply: reply.clone(),
            };
            loop {
                let Some(sender) = workers[wi].sender.as_ref() else { break };
                match sender.send(job) {
                    Ok(()) => {
                        for &s in &shard_set {
                            tried[s].push(replica);
                            hedged_to[s] = Some(replica);
                            self.health.inner.hedges_sent.fetch_add(1, Ordering::Relaxed);
                            *outstanding += 1;
                        }
                        break;
                    }
                    Err(mpsc::SendError(back)) => {
                        self.respawn_or_bury(&mut workers[wi]);
                        job = back;
                    }
                }
            }
        }
    }

    /// The one fan-out path: dispatch to live workers (respawning dead
    /// ones first), collect replies until done or `deadline`, fail
    /// shards over to untried replicas (and hedge stragglers) while
    /// any remain, merge the survivors, and report anything still
    /// missing as a typed [`Degradation`]. With a healthy pool and no
    /// deadline this is bit-identical to the historical fan-out for
    /// every R.
    fn run_batch(
        &self,
        queries: Arc<AlignedMatrix>,
        k: usize,
        params: &SearchParams,
        top_m: Option<usize>,
        deadline: Option<Instant>,
    ) -> (Vec<Vec<Neighbor>>, BatchStats, Option<Degradation>) {
        assert_eq!(
            queries.dim(),
            self.dim,
            "query batch dim {} does not match index dim {}",
            queries.dim(),
            self.dim
        );
        let t0 = Instant::now();
        // route on the calling thread (one pass over the query×centroid
        // tile), then share the buckets read-only with every worker —
        // identical code path to ShardedSearcher::search_batch_routed,
        // so the pool's routed results are bit-identical to the inline
        // routed fan-out
        let (routes, route_evals, m) = match top_m {
            Some(m0) => {
                let m = m0.clamp(1, self.shard_count);
                let (buckets, evals) = self.router.bucket(&queries, m);
                (Some(Arc::new(buckets)), evals, m)
            }
            None => (None, 0, self.shard_count),
        };

        let r_count = self.replicas;
        let t_count = self.threads;
        // the hedge delay only means something with a replica to hedge
        // to; the fraction knob additionally needs a deadline to take a
        // fraction of
        let hedge_delay: Option<Duration> = if r_count > 1 {
            if self.hedge_us > 0 {
                Some(Duration::from_micros(self.hedge_us))
            } else if self.hedge_deadline_fraction > 0.0 {
                deadline
                    .and_then(|d| d.checked_duration_since(t0))
                    .map(|left| left.mul_f64(self.hedge_deadline_fraction.clamp(0.0, 1.0)))
            } else {
                None
            }
        } else {
            None
        };

        // final outcome per shard, slotted by shard index so arrival
        // order cannot influence anything downstream; an Ok is never
        // overwritten (first valid reply wins), a typed panic may be
        // superseded by a later replica's Ok
        let mut slots: Vec<Option<ShardOutcome>> = Vec::new();
        slots.resize_with(self.shard_count, || None);
        // replicas each shard has been dispatched to this batch (a
        // replica is tried at most once per batch, so waves terminate)
        let mut tried: Vec<Vec<usize>> = vec![Vec::new(); self.shard_count];
        // last classified failure per shard; discarded if a later
        // replica resolves it
        let mut fail_cause: Vec<Option<DegradeCause>> = vec![None; self.shard_count];
        let mut deadline_hit = false;

        'waves: loop {
            let (tx, rx) = mpsc::channel::<ShardReply>();
            let mut wave_worker: Vec<Option<usize>> = vec![None; self.shard_count];
            let mut hedged_to: Vec<Option<usize>> = vec![None; self.shard_count];
            let mut outstanding = 0usize; // replies still in flight
            let mut unresolved = 0usize; // wave shards without an Ok
            {
                let mut workers = self.workers_lock();
                self.supervise(&mut workers);
                if deadline.is_some_and(|d| Instant::now() >= d) {
                    deadline_hit = true;
                    drop(tx);
                    break 'waves;
                }
                // assign every unresolved shard to its lowest untried
                // live replica. A pass that buries a worker mid-send
                // leaves its shards unassigned and the next pass falls
                // through to the next replica; respawn budgets are
                // finite, so this terminates.
                loop {
                    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); workers.len()];
                    let mut any = false;
                    for s in 0..self.shard_count {
                        if is_ok(&slots[s]) || wave_worker[s].is_some() {
                            continue;
                        }
                        let w = self.worker_of_shard[s];
                        let Some(wi) = (0..r_count).map(|r| r * t_count + w).find(|&wi| {
                            !tried[s].contains(&workers[wi].replica)
                                && workers[wi].sender.is_some()
                        }) else {
                            continue;
                        };
                        groups[wi].push(s);
                        any = true;
                    }
                    if !any {
                        break;
                    }
                    for (wi, shard_set) in groups.into_iter().enumerate() {
                        if shard_set.is_empty() {
                            continue;
                        }
                        let replica = workers[wi].replica;
                        let mut job = Job {
                            queries: Arc::clone(&queries),
                            k,
                            params: *params,
                            routes: routes.clone(),
                            shards: shard_set.clone(),
                            reply: tx.clone(),
                        };
                        loop {
                            let Some(sender) = workers[wi].sender.as_ref() else { break };
                            match sender.send(job) {
                                Ok(()) => {
                                    for &s in &shard_set {
                                        // any dispatch past the primary's
                                        // first attempt is a failover
                                        if !tried[s].is_empty() || replica != 0 {
                                            self.health
                                                .inner
                                                .failovers
                                                .fetch_add(1, Ordering::Relaxed);
                                        }
                                        tried[s].push(replica);
                                        wave_worker[s] = Some(wi);
                                        outstanding += 1;
                                        unresolved += 1;
                                    }
                                    break;
                                }
                                Err(mpsc::SendError(back)) => {
                                    // the worker died between supervision
                                    // and this send: respawn (bounded) and
                                    // retry; each retry spends budget, so
                                    // the loop terminates
                                    self.respawn_or_bury(&mut workers[wi]);
                                    job = back;
                                }
                            }
                        }
                    }
                }
            }
            if outstanding == 0 {
                // nothing dispatchable: every unresolved shard is out
                // of replicas — classified below
                drop(tx);
                break 'waves;
            }

            let hedge_at = hedge_delay.map(|d| Instant::now() + d);
            // the spare sender keeps the channel open only until the
            // hedge fires (or is disarmed); after that, collection
            // terminates by disconnect exactly as without hedging
            let mut hedge_tx = if hedge_at.is_some() { Some(tx.clone()) } else { None };
            drop(tx);

            loop {
                if unresolved == 0 || outstanding == 0 {
                    // every wave shard has a valid answer (stragglers'
                    // duplicate replies go to a dropped receiver), or
                    // every in-flight reply has been accounted for
                    break;
                }
                let now = Instant::now();
                let hedge_left = match (&hedge_tx, hedge_at) {
                    (Some(_), Some(at)) => Some(at.saturating_duration_since(now)),
                    _ => None,
                };
                let deadline_left = match deadline {
                    Some(d) => match d.checked_duration_since(now) {
                        Some(left) => Some(left),
                        None => {
                            deadline_hit = true;
                            break;
                        }
                    },
                    None => None,
                };
                let reply = match (hedge_left, deadline_left) {
                    (None, None) => match rx.recv() {
                        Ok(r) => r,
                        Err(_) => break, // a worker died mid-batch or a reply was lost
                    },
                    (h, d) => {
                        let wait = match (h, d) {
                            (Some(h), Some(d)) => h.min(d),
                            (Some(h), None) => h,
                            (None, Some(d)) => d,
                            (None, None) => unreachable!(),
                        };
                        match rx.recv_timeout(wait) {
                            Ok(r) => r,
                            Err(mpsc::RecvTimeoutError::Timeout) => {
                                let now = Instant::now();
                                if deadline.is_some_and(|dl| now >= dl) {
                                    deadline_hit = true;
                                    break;
                                }
                                if let (Some(htx), Some(at)) = (hedge_tx.take(), hedge_at) {
                                    if now >= at {
                                        self.fire_hedges(
                                            &queries,
                                            k,
                                            params,
                                            &routes,
                                            &slots,
                                            &mut tried,
                                            &mut hedged_to,
                                            &wave_worker,
                                            &htx,
                                            &mut outstanding,
                                        );
                                        // htx drops here: termination is
                                        // disconnect-based again
                                    } else {
                                        hedge_tx = Some(htx); // spurious wake
                                    }
                                }
                                continue;
                            }
                            Err(mpsc::RecvTimeoutError::Disconnected) => break,
                        }
                    }
                };
                outstanding -= 1;
                match reply.outcome {
                    ShardOutcome::Ok { .. } => {
                        if is_ok(&slots[reply.shard]) {
                            // duplicate (a hedge raced its primary):
                            // identical payload by T-invariance, so
                            // discard by slot key — the race outcome
                            // cannot change a bit
                            continue;
                        }
                        if hedged_to[reply.shard] == Some(reply.replica) {
                            self.health.inner.hedge_wins.fetch_add(1, Ordering::Relaxed);
                        }
                        slots[reply.shard] = Some(reply.outcome);
                        unresolved -= 1;
                    }
                    ShardOutcome::Panicked { .. } => {
                        if !is_ok(&slots[reply.shard]) {
                            fail_cause[reply.shard] = Some(DegradeCause::ShardPanicked);
                            slots[reply.shard] = Some(reply.outcome);
                        }
                    }
                }
            }
            drop(rx);

            // classify this wave's unanswered shards now, before the
            // next supervision pass can respawn the evidence away
            {
                let workers = self.workers_lock();
                for s in 0..self.shard_count {
                    let Some(wi) = wave_worker[s] else { continue };
                    if slots[s].is_some() {
                        continue; // answered (Ok, or a typed panic)
                    }
                    let slot = &workers[wi];
                    let worker_dead = slot.sender.is_none()
                        || slot.handle.as_ref().is_some_and(|h| h.is_finished());
                    fail_cause[s] = Some(if worker_dead {
                        DegradeCause::ShardDead
                    } else if deadline_hit {
                        DegradeCause::DeadlineExpired
                    } else {
                        DegradeCause::ReplyLost
                    });
                }
            }
            if deadline_hit {
                break 'waves;
            }
        }

        // classify what is still missing (ascending shard order by
        // construction), then run supervision again so a worker that
        // died mid-batch is respawned before the next one
        let mut missing: Vec<(u32, u32, DegradeCause)> = Vec::new();
        {
            let mut workers = self.workers_lock();
            for s in 0..self.shard_count {
                if is_ok(&slots[s]) {
                    continue;
                }
                let cause = fail_cause[s].unwrap_or_else(|| {
                    // never classified: the shard was never dispatched
                    // (or never answered a wave that was cut short)
                    let w = self.worker_of_shard[s];
                    let all_dead = (0..r_count).all(|r| {
                        let slot = &workers[r * t_count + w];
                        slot.sender.is_none()
                            || slot.handle.as_ref().is_some_and(|h| h.is_finished())
                    });
                    if all_dead {
                        DegradeCause::ShardDead
                    } else if deadline_hit {
                        DegradeCause::DeadlineExpired
                    } else {
                        DegradeCause::ReplyLost
                    }
                });
                missing.push((s as u32, tried[s].len() as u32, cause));
            }
            self.supervise(&mut workers);
        }
        for &(_, _, cause) in &missing {
            match cause {
                DegradeCause::DeadlineExpired => {
                    self.health.inner.deadline_misses.fetch_add(1, Ordering::Relaxed);
                }
                DegradeCause::ReplyLost => {
                    self.health.inner.lost_replies.fetch_add(1, Ordering::Relaxed);
                }
                _ => {}
            }
        }

        // merge the survivors in shard slice order — with everything
        // present this is exactly the historical merge input
        let mut agg = BatchStats {
            queries: queries.n(),
            kernel: dispatch::active_width().name(),
            dist_evals: route_evals,
            ..Default::default()
        };
        let mut merged: Vec<Vec<Neighbor>> = Vec::new();
        merged.resize_with(queries.n(), || Vec::with_capacity(k * m));
        for (s, slot) in slots.into_iter().enumerate() {
            let Some(ShardOutcome::Ok { results, dist_evals, expansions }) = slot else {
                continue;
            };
            agg.dist_evals += dist_evals;
            agg.expansions += expansions;
            match &routes {
                None => {
                    agg.shard_visits += queries.n() as u64;
                    for (qi, r) in results.into_iter().enumerate() {
                        merged[qi].extend(r);
                    }
                }
                Some(buckets) => {
                    let qids = &buckets[s];
                    agg.shard_visits += qids.len() as u64;
                    for (pos, r) in results.into_iter().enumerate() {
                        merged[qids[pos] as usize].extend(r);
                    }
                }
            }
        }
        let results: Vec<Vec<Neighbor>> =
            merged.into_iter().map(|all| ShardedSearcher::merge(all, k)).collect();
        agg.secs = t0.elapsed().as_secs_f64();

        let degradation = if missing.is_empty() {
            None
        } else {
            let cause =
                missing.iter().map(|&(_, _, c)| c).max().unwrap_or(DegradeCause::ShardDead);
            Some(Degradation {
                shards_missing: missing.iter().map(|&(s, _, _)| s).collect(),
                replicas_tried: missing.iter().map(|&(_, t, _)| t).collect(),
                cause,
            })
        };
        (results, agg, degradation)
    }
}

/// Spawn one worker thread over its shard group; used for both initial
/// construction and respawns (a respawned worker allocates fresh
/// scratch, so whatever state a dying thread abandoned is gone).
/// Replica 0 keeps the historical thread names so R=1 pools are
/// indistinguishable from the unreplicated ones.
fn spawn_worker(
    id: usize,
    replica: usize,
    owned: Vec<(usize, Arc<Shard>)>,
    health: Arc<HealthInner>,
) -> std::io::Result<(mpsc::Sender<Job>, JoinHandle<()>)> {
    let (tx, rx) = mpsc::channel::<Job>();
    let name = if replica == 0 {
        format!("knng-shard-{id}")
    } else {
        format!("knng-shard-{id}r{replica}")
    };
    let handle = std::thread::Builder::new()
        .name(name)
        .spawn(move || worker_loop(id, replica, owned, rx, health))?;
    Ok((tx, handle))
}

/// Worker body: serve jobs until every sender is gone. Each owned shard
/// gets its own persistent scratch — allocated once here, reused for
/// every batch this worker ever serves. Each shard search runs under
/// `catch_unwind`: a panicking search becomes a typed failure reply
/// (plus a fresh scratch) and the worker keeps serving.
///
/// Fault sites: replica 0 answers to the legacy `pool.worker.*` sites
/// (so existing R=1 chaos plans behave bit for bit), higher replicas
/// answer to the `pool.replica.*` sites with
/// [`faults::replica_index`]-encoded indices, so a plan can kill
/// exactly one copy of a shard.
fn worker_loop(
    worker_id: usize,
    replica: usize,
    owned: Vec<(usize, Arc<Shard>)>,
    rx: mpsc::Receiver<Job>,
    health: Arc<HealthInner>,
) {
    let mut scratch: Vec<_> = owned.iter().map(|(_, sh)| sh.core.scratch()).collect();
    while let Ok(job) = rx.recv() {
        let job_fault = if replica == 0 {
            faults::check(faults::site::WORKER_JOB, worker_id as u64)
        } else {
            faults::check(
                faults::site::REPLICA_JOB,
                faults::replica_index(replica, worker_id as u64),
            )
        };
        if matches!(job_fault, Some(FaultAction::Die)) {
            return; // injected thread death: the supervisor takes over
        }
        for &shard_idx in &job.shards {
            let pos = owned
                .iter()
                .position(|(slot, _)| *slot == shard_idx)
                .expect("pool dispatched a shard this worker does not own");
            let (slot, shard) = &owned[pos];
            let scr = &mut scratch[pos];
            let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let search_fault = if replica == 0 {
                    faults::check(faults::site::WORKER_SEARCH, *slot as u64)
                } else {
                    faults::check(
                        faults::site::REPLICA_SEARCH,
                        faults::replica_index(replica, *slot as u64),
                    )
                };
                if matches!(search_fault, Some(FaultAction::Panic)) {
                    panic!("injected panic at shard {slot} (replica {replica})");
                }
                match &job.routes {
                    None => {
                        let (raw, stats) =
                            shard.core.search_batch_with(&job.queries, job.k, &job.params, scr);
                        ShardOutcome::Ok {
                            results: raw.into_iter().map(|r| shard.map_results(r)).collect(),
                            dist_evals: stats.dist_evals,
                            expansions: stats.expansions,
                        }
                    }
                    Some(routes) => {
                        // routed: serve only this shard's bucket. The
                        // pool expects one reply per shard, so an
                        // unrouted shard still replies — just empty.
                        let qids = &routes[*slot];
                        if qids.is_empty() {
                            ShardOutcome::Ok { results: Vec::new(), dist_evals: 0, expansions: 0 }
                        } else {
                            let tile = gather_rows(&job.queries, qids);
                            let (raw, stats) =
                                shard.core.search_batch_with(&tile, job.k, &job.params, scr);
                            ShardOutcome::Ok {
                                results: raw.into_iter().map(|r| shard.map_results(r)).collect(),
                                dist_evals: stats.dist_evals,
                                expansions: stats.expansions,
                            }
                        }
                    }
                }
            }));
            let outcome = match attempt {
                Ok(outcome) => outcome,
                Err(payload) => {
                    health.contained_panics.fetch_add(1, Ordering::Relaxed);
                    // the unwound search may have left the scratch
                    // buffers torn; fresh scratch restores the clean-
                    // state guarantee for every subsequent batch
                    *scr = shard.core.scratch();
                    ShardOutcome::Panicked { message: panic_message(&payload) }
                }
            };
            let reply_fault = if replica == 0 {
                faults::check(faults::site::WORKER_REPLY, *slot as u64)
            } else {
                faults::check(
                    faults::site::REPLICA_REPLY,
                    faults::replica_index(replica, *slot as u64),
                )
            };
            match reply_fault {
                Some(FaultAction::Drop) => continue, // reply lost in flight
                Some(FaultAction::Delay(d)) => std::thread::sleep(d),
                Some(FaultAction::Die) => return,
                _ => {}
            }
            // a send error means the caller stopped collecting (its
            // deadline expired, a hedge already answered, or it dropped
            // the batch); nothing useful to do but move on
            let _ = job.reply.send(ShardReply { shard: *slot, replica, outcome });
        }
    }
}

/// Best-effort human-readable text from a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

impl Searcher for ShardPool {
    fn len(&self) -> usize {
        self.n
    }

    fn search(
        &self,
        query: &[f32],
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Neighbor>, QueryStats) {
        assert!(
            query.len() == self.dim || query.len() == self.dim_pad,
            "query length {} matches neither dim {} nor padded {}",
            query.len(),
            self.dim,
            self.dim_pad
        );
        // a 1-row tile through the batch path: per-pair bit-equal to the
        // sequential probe kernels, so this matches
        // ShardedSearcher::search exactly (ids, distance bits, stats)
        let qm = AlignedMatrix::from_rows(1, self.dim, &query[..self.dim]);
        let (mut results, agg) = self.search_batch(&qm, k, params);
        let only = results.pop().unwrap_or_default();
        (only, QueryStats { dist_evals: agg.dist_evals, expansions: agg.expansions })
    }

    fn search_batch(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        // the borrowed entry point has to copy once: workers need a
        // 'static tile. Callers that already own the tile (the
        // micro-batching front) use search_batch_owned and skip this.
        self.search_batch_owned(Arc::new(queries.clone()), k, params)
    }

    fn search_batch_owned(
        &self,
        queries: Arc<AlignedMatrix>,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        // this signature cannot carry a degradation record; the pool
        // still serves from the survivors (never panics, never hangs)
        // and the event stays observable through stats()/health_watch.
        // Callers that need the typed record use
        // search_batch_deadline_owned — the serving front does.
        let (results, stats, _degradation) = self.run_batch(queries, k, params, None, None);
        (results, stats)
    }

    fn search_batch_routed(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
        top_m: usize,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        self.search_batch_routed_owned(Arc::new(queries.clone()), k, params, top_m)
    }

    fn search_batch_routed_owned(
        &self,
        queries: Arc<AlignedMatrix>,
        k: usize,
        params: &SearchParams,
        top_m: usize,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        let (results, stats, _degradation) = self.run_batch(queries, k, params, Some(top_m), None);
        (results, stats)
    }

    fn search_batch_deadline_owned(
        &self,
        queries: Arc<AlignedMatrix>,
        k: usize,
        params: &SearchParams,
        route_top_m: Option<usize>,
        deadline: Option<Instant>,
    ) -> (Vec<Vec<Neighbor>>, BatchStats, Option<Degradation>) {
        self.run_batch(queries, k, params, route_top_m, deadline)
    }

    fn health_watch(&self) -> Option<HealthWatch> {
        Some(self.health.clone())
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        // disconnect every job channel, then join: workers exit their
        // recv loop as soon as the senders are gone
        let mut workers = self.workers_lock();
        for slot in workers.iter_mut() {
            slot.sender = None;
        }
        for slot in workers.iter_mut() {
            if let Some(handle) = slot.handle.take() {
                let _ = handle.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::clustered::SynthClustered;
    use crate::nndescent::Params;
    use crate::testing::assert_neighbors_bitwise_eq;

    fn corpus(n: usize, seed: u64) -> AlignedMatrix {
        let (data, _) = SynthClustered::new(n, 8, 4, seed).generate_labeled();
        data
    }

    #[test]
    fn pool_matches_inline_fanout_bitwise() {
        let data = corpus(400, 3);
        let params = Params::default().with_k(8).with_seed(3);
        let sharded = ShardedSearcher::build(&data, 4, &params).unwrap();
        let sp = SearchParams::default();
        let queries = AlignedMatrix::from_rows(
            30,
            data.dim(),
            &(0..30).flat_map(|i| data.row_logical(i * 13).to_vec()).collect::<Vec<f32>>(),
        );
        let (expect, estats) = sharded.search_batch(&queries, 5, &sp);
        for threads in [1usize, 2, 4, 9] {
            let pool = ShardPool::new(&sharded, threads).unwrap();
            assert_eq!(pool.threads(), threads.min(4));
            assert_eq!(pool.shard_count(), 4);
            assert_eq!(pool.replicas(), 1);
            assert_eq!(Searcher::len(&pool), 400);
            let (got, gstats) = pool.search_batch(&queries, 5, &sp);
            assert_neighbors_bitwise_eq(&expect, &got, &format!("threads={threads}"));
            assert_eq!(estats.dist_evals, gstats.dist_evals);
            assert_eq!(estats.expansions, gstats.expansions);
            assert_eq!(estats.shard_visits, gstats.shard_visits);
            assert!(pool.stats().all_healthy(), "healthy run must stay healthy");
        }
    }

    #[test]
    fn replicated_pool_matches_inline_fanout_bitwise() {
        // the determinism contract of the tentpole: any R over a
        // healthy pool is bit-identical to the inline fan-out, stats
        // included, with zero failovers or hedges
        let data = corpus(400, 21);
        let params = Params::default().with_k(8).with_seed(21);
        let sharded = ShardedSearcher::build(&data, 4, &params).unwrap();
        let sp = SearchParams::default();
        let queries = AlignedMatrix::from_rows(
            25,
            data.dim(),
            &(0..25).flat_map(|i| data.row_logical(i * 7).to_vec()).collect::<Vec<f32>>(),
        );
        let (expect, estats) = sharded.search_batch(&queries, 5, &sp);
        for replicas in [2usize, 3] {
            let pool = ShardPool::with_config(
                &sharded,
                PoolConfig { threads: 2, replicas, ..Default::default() },
            )
            .unwrap();
            assert_eq!(pool.replicas(), replicas);
            let (got, gstats) = pool.search_batch(&queries, 5, &sp);
            assert_neighbors_bitwise_eq(&expect, &got, &format!("replicas={replicas}"));
            assert_eq!(estats.dist_evals, gstats.dist_evals);
            assert_eq!(estats.expansions, gstats.expansions);
            assert_eq!(estats.shard_visits, gstats.shard_visits);
            let stats = pool.stats();
            assert!(stats.all_healthy());
            assert_eq!(stats.failovers, 0, "healthy primaries never fail over");
            assert_eq!(stats.hedges_sent, 0, "hedging is off by default");
        }
    }

    #[test]
    fn hedging_on_healthy_pool_is_bitwise_clean() {
        // an aggressive 1 µs hedge delay makes hedges race real work;
        // whoever wins, the answer must not change by a single bit —
        // replies are identical by T-invariance and deduped by slot
        let data = corpus(300, 23);
        let params = Params::default().with_k(8).with_seed(23);
        let sharded = ShardedSearcher::build(&data, 3, &params).unwrap();
        let sp = SearchParams::default();
        let queries = AlignedMatrix::from_rows(
            20,
            data.dim(),
            &(0..20).flat_map(|i| data.row_logical(i * 11).to_vec()).collect::<Vec<f32>>(),
        );
        let (expect, estats) = sharded.search_batch(&queries, 4, &sp);
        let pool = ShardPool::with_config(
            &sharded,
            PoolConfig { threads: 3, replicas: 2, hedge_us: 1, ..Default::default() },
        )
        .unwrap();
        for round in 0..5 {
            let (got, gstats) = pool.search_batch(&queries, 4, &sp);
            assert_neighbors_bitwise_eq(&expect, &got, &format!("hedged round {round}"));
            assert_eq!(estats.dist_evals, gstats.dist_evals, "round {round}");
        }
        let stats = pool.stats();
        assert!(stats.all_healthy());
        assert_eq!(stats.failovers, 0, "hedges are not failovers");
        assert!(
            stats.hedge_wins <= stats.hedges_sent,
            "wins ⊆ sent: {} > {}",
            stats.hedge_wins,
            stats.hedges_sent
        );
    }

    #[test]
    fn pool_single_query_matches_sharded_search() {
        let data = corpus(300, 5);
        let params = Params::default().with_k(8).with_seed(5);
        let sharded = ShardedSearcher::build(&data, 3, &params).unwrap();
        let pool = ShardPool::new(&sharded, 2).unwrap();
        let sp = SearchParams::default();
        for qi in (0..300).step_by(37) {
            let (a, sa) = sharded.search(data.row_logical(qi), 4, &sp);
            let (b, sb) = pool.search(data.row_logical(qi), 4, &sp);
            assert_neighbors_bitwise_eq(
                std::slice::from_ref(&a),
                std::slice::from_ref(&b),
                &format!("query {qi}"),
            );
            assert_eq!(sa, sb, "query {qi} stats");
        }
    }

    #[test]
    fn owned_tile_entry_point_matches_borrowed() {
        // the Arc handoff (no tile clone) must not change anything:
        // same results, same stats, for both the pool and — through the
        // trait default — the inline sharded searcher
        let data = corpus(300, 11);
        let params = Params::default().with_k(8).with_seed(11);
        let sharded = ShardedSearcher::build(&data, 3, &params).unwrap();
        let pool = ShardPool::new(&sharded, 2).unwrap();
        let sp = SearchParams::default();
        let queries = AlignedMatrix::from_rows(
            12,
            data.dim(),
            &(0..12).flat_map(|i| data.row_logical(i * 23).to_vec()).collect::<Vec<f32>>(),
        );
        let (expect, estats) = pool.search_batch(&queries, 4, &sp);
        let tile = std::sync::Arc::new(queries.clone());
        let (got, gstats) = pool.search_batch_owned(std::sync::Arc::clone(&tile), 4, &sp);
        assert_neighbors_bitwise_eq(&expect, &got, "owned vs borrowed");
        assert_eq!(estats.dist_evals, gstats.dist_evals);
        let (inline, _) = sharded.search_batch_owned(tile, 4, &sp);
        assert_neighbors_bitwise_eq(&expect, &inline, "trait default");
    }

    #[test]
    fn pool_routed_matches_inline_routed_bitwise() {
        use crate::api::partition::KMeans;
        let data = corpus(600, 15);
        let params = Params::default().with_k(8).with_seed(15);
        let sharded =
            ShardedSearcher::build_partitioned(&data, 4, &params, &KMeans::default()).unwrap();
        let sp = SearchParams::default();
        let queries = AlignedMatrix::from_rows(
            40,
            data.dim(),
            &(0..40).flat_map(|i| data.row_logical(i * 11).to_vec()).collect::<Vec<f32>>(),
        );
        for (threads, replicas) in [(1usize, 1usize), (3, 1), (2, 2)] {
            let pool = ShardPool::with_config(
                &sharded,
                PoolConfig { threads, replicas, ..Default::default() },
            )
            .unwrap();
            for m in [1usize, 2, 4] {
                let (expect, estats) = sharded.search_batch_routed(&queries, 5, &sp, m);
                let (got, gstats) = pool.search_batch_routed(&queries, 5, &sp, m);
                assert_neighbors_bitwise_eq(
                    &expect,
                    &got,
                    &format!("threads={threads} replicas={replicas} m={m}"),
                );
                assert_eq!(estats.dist_evals, gstats.dist_evals, "t={threads} r={replicas} m={m}");
                assert_eq!(estats.expansions, gstats.expansions, "t={threads} r={replicas} m={m}");
                assert_eq!(
                    estats.shard_visits, gstats.shard_visits,
                    "t={threads} r={replicas} m={m}"
                );
            }
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let data = corpus(120, 7);
        let sharded =
            ShardedSearcher::build(&data, 2, &Params::default().with_k(6).with_seed(7)).unwrap();
        let pool = ShardPool::new(&sharded, 2).unwrap();
        let queries = AlignedMatrix::zeroed(0, data.dim());
        let (res, agg) = pool.search_batch(&queries, 5, &SearchParams::default());
        assert!(res.is_empty());
        assert_eq!(agg.queries, 0);
        assert_eq!(agg.kernel, dispatch::active_width().name());
    }

    #[test]
    fn rejects_zero_threads_and_zero_replicas() {
        let data = corpus(100, 9);
        let sharded =
            ShardedSearcher::build(&data, 2, &Params::default().with_k(6).with_seed(9)).unwrap();
        assert!(ShardPool::new(&sharded, 0).is_err());
        assert!(ShardPool::with_config(
            &sharded,
            PoolConfig { threads: 1, replicas: 0, ..Default::default() }
        )
        .is_err());
    }

    #[test]
    fn health_starts_clean_and_watch_outlives_moves() {
        let data = corpus(200, 13);
        let sharded =
            ShardedSearcher::build(&data, 2, &Params::default().with_k(6).with_seed(13)).unwrap();
        let pool = ShardPool::new(&sharded, 2).unwrap();
        let watch = Searcher::health_watch(&pool).expect("pools expose health");
        let stats = pool.stats();
        assert_eq!(stats.threads, 2);
        assert_eq!(stats.replicas, 1);
        assert_eq!(stats.shards, vec![ShardState::Healthy, ShardState::Healthy]);
        assert!(stats.all_healthy());
        assert!(stats.dead_shards().is_empty());
        assert_eq!(stats.respawns, 0);
        assert_eq!(stats.contained_panics, 0);
        // the watch reads the same storage, even after the pool is
        // moved (here: into a box on another thread)
        let handle = std::thread::spawn(move || {
            let boxed = Box::new(pool);
            let queries = AlignedMatrix::zeroed(0, 8);
            let _ = boxed.search_batch(&queries, 3, &SearchParams::default());
        });
        handle.join().unwrap();
        assert!(watch.snapshot().all_healthy());
    }

    #[test]
    fn replica_stats_have_the_documented_shape() {
        let data = corpus(200, 27);
        let sharded =
            ShardedSearcher::build(&data, 3, &Params::default().with_k(6).with_seed(27)).unwrap();
        let pool = ShardPool::with_config(
            &sharded,
            PoolConfig { threads: 2, replicas: 2, ..Default::default() },
        )
        .unwrap();
        let stats = pool.stats();
        assert_eq!(stats.replicas, 2);
        assert_eq!(stats.shards.len(), 3);
        assert_eq!(stats.replica_states.len(), 3, "one row per shard");
        assert!(stats.replica_states.iter().all(|rs| rs.len() == 2), "one column per replica");
        let flat = stats.replicas_alive_flat();
        assert_eq!(flat.len(), 6, "shards × replicas");
        assert!(flat.iter().all(|alive| *alive));
        assert_eq!(
            (stats.hedges_sent, stats.hedge_wins, stats.failovers),
            (0, 0, 0),
            "fresh pool, clean counters"
        );
    }

    #[test]
    fn deadline_entry_point_without_pressure_is_bitwise_clean() {
        use std::time::Duration;
        let data = corpus(300, 17);
        let params = Params::default().with_k(8).with_seed(17);
        let sharded = ShardedSearcher::build(&data, 3, &params).unwrap();
        let pool = ShardPool::new(&sharded, 3).unwrap();
        let sp = SearchParams::default();
        let rows: Vec<f32> = (0..10).flat_map(|i| data.row_logical(i * 29).to_vec()).collect();
        let tile = Arc::new(AlignedMatrix::from_rows(10, data.dim(), &rows));
        let (expect, _) = sharded.search_batch(&tile, 4, &sp);
        // a generous deadline on a healthy pool must not change a bit
        let deadline = Instant::now() + Duration::from_secs(30);
        let (got, _, degr) =
            pool.search_batch_deadline_owned(Arc::clone(&tile), 4, &sp, None, Some(deadline));
        assert!(degr.is_none(), "nothing should miss a 30 s deadline: {degr:?}");
        assert_neighbors_bitwise_eq(&expect, &got, "deadline-armed healthy pool");
        // and with no deadline at all, the same entry point is the
        // plain path exactly
        let (got2, _, degr2) = pool.search_batch_deadline_owned(tile, 4, &sp, None, None);
        assert!(degr2.is_none());
        assert_neighbors_bitwise_eq(&expect, &got2, "deadline entry, no deadline");
    }

    #[test]
    fn expired_deadline_degrades_immediately_not_hangs() {
        use std::time::Duration;
        let data = corpus(200, 19);
        let sharded =
            ShardedSearcher::build(&data, 2, &Params::default().with_k(6).with_seed(19)).unwrap();
        let pool = ShardPool::new(&sharded, 2).unwrap();
        let rows: Vec<f32> = data.row_logical(0).to_vec();
        let tile = Arc::new(AlignedMatrix::from_rows(1, data.dim(), &rows));
        let t0 = Instant::now();
        let past = Instant::now() - Duration::from_millis(1);
        let (res, _, degr) =
            pool.search_batch_deadline_owned(tile, 3, &SearchParams::default(), None, Some(past));
        assert!(t0.elapsed() < Duration::from_secs(5), "expired deadline must not hang");
        let degr = degr.expect("an already-expired deadline degrades everything");
        assert_eq!(degr.cause, DegradeCause::DeadlineExpired);
        assert_eq!(degr.shards_missing, vec![0, 1]);
        assert_eq!(degr.replicas_tried, vec![0, 0], "nothing was ever dispatched");
        assert_eq!(res.len(), 1);
        assert!(res[0].is_empty(), "no shard answered, so no neighbors");
        assert!(pool.stats().deadline_misses >= 2);
    }

    #[test]
    fn pool_config_defaults_are_sane() {
        let cfg = PoolConfig::default();
        assert!(cfg.threads >= 1);
        assert_eq!(cfg.respawn_budget, DEFAULT_RESPAWN_BUDGET);
        assert_eq!(cfg.replicas, 1, "replication is opt-in");
        assert_eq!(cfg.hedge_us, 0, "hedging is opt-in");
        assert_eq!(cfg.hedge_deadline_fraction, 0.0);
    }
}
