//! Pluggable corpus partitioning for sharded builds.
//!
//! [`ShardedSearcher`](super::ShardedSearcher) historically hard-coded
//! one partitioning decision — contiguous working-id slices — which
//! forces every query to fan out to all S shards. This module makes the
//! decision a first-class value: a [`Partitioner`] produces a
//! [`PartitionPlan`] (per-shard row sets plus one centroid per shard),
//! and the sharded build/serve layers consume the plan without knowing
//! which strategy produced it.
//!
//! Two implementations ship:
//!
//! * [`Contiguous`] — the historical `lo = idx·n/S` slice split,
//!   bit-for-bit. It remains the default, so every existing build and
//!   serve path is unchanged.
//! * [`KMeans`] — seeded, sample-based Lloyd iterations over the
//!   dispatched distance kernels. Rows are assigned to their nearest
//!   centroid, and each shard additionally receives a bounded set of
//!   *ghost* rows — boundary points whose runner-up centroid is that
//!   shard — which act as the cross-cluster stitch candidates of the
//!   divide-and-conquer scheme (Wang et al., arXiv:2103.15386): they
//!   join the shard's NN-Descent build, so boundary neighborhoods exist
//!   in both adjacent subgraphs, and the serve-time merge deduplicates
//!   the copies.
//!
//! Planning is single-threaded and all randomness flows from one seeded
//! [`Pcg64`] stream, so a plan is deterministic and — like the PR 5
//! build — invariant to the build thread count (the plan is computed
//! before any worker spawns).

use crate::dataset::AlignedMatrix;
use crate::distance::dispatch;
use crate::util::rng::Pcg64;
use anyhow::{bail, ensure};

/// One shard's row set: the global (original-corpus) ids it owns.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    /// Global row ids: `primaries` owned rows first (ascending), then
    /// the ghost rows (ascending). Ghosts are *copies* of rows owned by
    /// other shards, included in this shard's subgraph build as
    /// boundary-stitch candidates.
    pub rows: Vec<u32>,
    /// Number of owned rows at the head of `rows`.
    pub primaries: usize,
}

impl ShardPlan {
    /// The ghost (non-owned) tail of `rows`.
    pub fn ghosts(&self) -> &[u32] {
        &self.rows[self.primaries..]
    }
}

/// A complete partitioning decision: per-shard row sets plus one
/// centroid per shard (row `s` of `centroids` is shard `s`'s centroid,
/// used for query routing and persisted in `KNNIv1` bundles).
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    pub shards: Vec<ShardPlan>,
    pub centroids: AlignedMatrix,
}

impl PartitionPlan {
    /// Structural validity: every corpus row owned by exactly one
    /// shard, every shard non-degenerate, ghosts never self-owned.
    pub fn validate(&self, n: usize) -> crate::Result<()> {
        let mut owner = vec![u32::MAX; n];
        for (s, plan) in self.shards.iter().enumerate() {
            ensure!(plan.primaries >= 2, "shard {s} owns {} rows (needs ≥ 2)", plan.primaries);
            ensure!(plan.primaries <= plan.rows.len(), "shard {s}: primaries out of range");
            for &r in &plan.rows[..plan.primaries] {
                ensure!((r as usize) < n, "shard {s}: row {r} out of range");
                ensure!(owner[r as usize] == u32::MAX, "row {r} owned by two shards");
                owner[r as usize] = s as u32;
            }
        }
        ensure!(owner.iter().all(|&o| o != u32::MAX), "some rows unowned");
        for (s, plan) in self.shards.iter().enumerate() {
            for &g in plan.ghosts() {
                ensure!(owner[g as usize] != s as u32, "shard {s}: ghost {g} is self-owned");
            }
        }
        ensure!(self.centroids.n() == self.shards.len(), "one centroid per shard");
        Ok(())
    }
}

/// A partitioning strategy: split `data` into `shards` row sets.
pub trait Partitioner {
    /// Stable label (CLI value, bench rows).
    fn name(&self) -> &'static str;
    /// Compute the plan. Must be deterministic for fixed inputs.
    fn plan(&self, data: &AlignedMatrix, shards: usize) -> crate::Result<PartitionPlan>;
}

/// Mean of a set of rows, accumulated in f64 (order-stable: ascending
/// row id), written as the f32 centroid row `slot`.
fn write_mean(centroids: &mut AlignedMatrix, slot: usize, data: &AlignedMatrix, rows: &[u32]) {
    let dim = data.dim();
    let mut acc = vec![0.0f64; dim];
    for &r in rows {
        for (a, &x) in acc.iter_mut().zip(data.row_logical(r as usize)) {
            *a += x as f64;
        }
    }
    let inv = 1.0 / rows.len().max(1) as f64;
    for (c, a) in centroids.row_mut(slot).iter_mut().zip(&acc) {
        *c = (a * inv) as f32;
    }
}

/// The historical contiguous split: shard `idx` owns rows
/// `[idx·n/S, (idx+1)·n/S)` — exactly the arithmetic `api::sharded`
/// used before this module existed, so Contiguous-planned builds are
/// bit-identical to pre-plan builds. No ghosts; centroids are the
/// per-slice means (used only for routing).
#[derive(Debug, Clone, Copy, Default)]
pub struct Contiguous;

impl Partitioner for Contiguous {
    fn name(&self) -> &'static str {
        "contiguous"
    }

    fn plan(&self, data: &AlignedMatrix, shards: usize) -> crate::Result<PartitionPlan> {
        let n = data.n();
        ensure!(shards >= 1, "cannot partition into 0 shards");
        ensure!(
            n / shards >= 2,
            "corpus of {n} points cannot fill {shards} shards (each needs ≥ 2 points)"
        );
        let mut plans = Vec::with_capacity(shards);
        let mut centroids = AlignedMatrix::zeroed(shards, data.dim());
        for idx in 0..shards {
            let lo = idx * n / shards;
            let hi = (idx + 1) * n / shards;
            let rows: Vec<u32> = (lo as u32..hi as u32).collect();
            write_mean(&mut centroids, idx, data, &rows);
            plans.push(ShardPlan { primaries: rows.len(), rows });
        }
        Ok(PartitionPlan { shards: plans, centroids })
    }
}

/// Ghost budget per shard: `⌈primaries / GHOST_DENOM⌉` boundary rows.
const GHOST_DENOM: usize = 8;

/// Seeded, sample-based k-means (Lloyd) partitioner.
///
/// Centroids are fit on a bounded sample (`sample_cap` rows) with
/// `iters` Lloyd iterations over the dispatched pair kernel, then every
/// corpus row is assigned to its nearest centroid (ties break toward
/// the lowest centroid id). Shards that end up with fewer than two
/// owned rows steal their nearest rows from over-full shards, so every
/// shard can build a graph. Finally each shard receives up to
/// `⌈primaries/8⌉` ghost rows — the not-owned rows with the smallest
/// routing margin (distance to runner-up minus distance to owner)
/// whose runner-up centroid is that shard.
#[derive(Debug, Clone)]
pub struct KMeans {
    /// Seed for sampling, initialization, and reseeding.
    pub seed: u64,
    /// Lloyd iterations run on the sample.
    pub iters: usize,
    /// Upper bound on the Lloyd sample size.
    pub sample_cap: usize,
}

impl KMeans {
    pub fn new(seed: u64) -> Self {
        Self { seed, iters: 10, sample_cap: 4096 }
    }
}

impl Default for KMeans {
    fn default() -> Self {
        Self::new(0xC3A7)
    }
}

/// Nearest and runner-up centroids of one row (ties toward the lower
/// centroid id — iteration order is ascending and comparisons strict).
fn two_nearest(
    pair: fn(&[f32], &[f32]) -> f32,
    row: &[f32],
    centroids: &AlignedMatrix,
) -> (f32, u32, f32, u32) {
    let (mut d1, mut c1) = (f32::INFINITY, 0u32);
    let (mut d2, mut c2) = (f32::INFINITY, 0u32);
    for c in 0..centroids.n() {
        let d = pair(row, centroids.row(c));
        if d < d1 {
            (d2, c2) = (d1, c1);
            (d1, c1) = (d, c as u32);
        } else if d < d2 {
            (d2, c2) = (d, c as u32);
        }
    }
    (d1, c1, d2, c2)
}

impl Partitioner for KMeans {
    fn name(&self) -> &'static str {
        "kmeans"
    }

    fn plan(&self, data: &AlignedMatrix, shards: usize) -> crate::Result<PartitionPlan> {
        let n = data.n();
        ensure!(shards >= 1, "cannot partition into 0 shards");
        ensure!(
            n / shards >= 2,
            "corpus of {n} points cannot fill {shards} shards (each needs ≥ 2 points)"
        );
        ensure!(shards <= u16::MAX as usize, "at most {} shards", u16::MAX);
        let pair = dispatch::active().pair;
        let mut rng = Pcg64::new_stream(self.seed, 0x9AEA5);

        // Bounded Lloyd sample (sorted: reservoir order is unspecified,
        // ascending ids make every later step's iteration order obvious).
        let m = self.sample_cap.max(shards).min(n);
        let mut sample: Vec<u32> = Vec::new();
        rng.sample_indices(n, m, &mut sample);
        sample.sort_unstable();

        // Initial centroids: distinct-valued sample rows in shuffled
        // order (duplicate-heavy corpora fall back to repeats and rely
        // on the empty-cluster reseed below).
        let mut order = sample.clone();
        rng.shuffle(&mut order);
        let mut centroids = AlignedMatrix::zeroed(shards, data.dim());
        let mut chosen: Vec<u32> = Vec::with_capacity(shards);
        for &cand in &order {
            if chosen.len() == shards {
                break;
            }
            let row = data.row(cand as usize);
            if chosen.iter().all(|&c| data.row(c as usize) != row) {
                chosen.push(cand);
            }
        }
        let mut wrap = 0usize;
        while chosen.len() < shards {
            chosen.push(order[wrap % order.len()]);
            wrap += 1;
        }
        for (s, &cand) in chosen.iter().enumerate() {
            let dim = data.dim();
            centroids.row_mut(s)[..dim].copy_from_slice(data.row_logical(cand as usize));
        }

        // Lloyd iterations on the sample.
        let mut assign = vec![0u32; sample.len()];
        let mut dist = vec![0.0f32; sample.len()];
        for _ in 0..self.iters {
            for (i, &p) in sample.iter().enumerate() {
                let (d1, c1, _, _) = two_nearest(pair, data.row(p as usize), &centroids);
                assign[i] = c1;
                dist[i] = d1;
            }
            let mut counts = vec![0u64; shards];
            for &a in &assign {
                counts[a as usize] += 1;
            }
            // Empty clusters reseed deterministically to the sample
            // point farthest from its current centroid (ties: lowest
            // sample position), each stolen point used at most once.
            let mut stolen = vec![false; sample.len()];
            for s in 0..shards {
                if counts[s] > 0 {
                    continue;
                }
                let mut best: Option<usize> = None;
                for (i, (&st, &d)) in stolen.iter().zip(&dist).enumerate() {
                    if st || counts[assign[i] as usize] <= 1 {
                        continue;
                    }
                    let better = match best {
                        None => true,
                        Some(b) => d > dist[b],
                    };
                    if better {
                        best = Some(i);
                    }
                }
                let Some(i) = best else { continue };
                stolen[i] = true;
                counts[assign[i] as usize] -= 1;
                counts[s] += 1;
                assign[i] = s as u32;
            }
            // Means in f64, ascending sample order.
            let dim = data.dim();
            let mut sums = vec![0.0f64; shards * dim];
            for (i, &p) in sample.iter().enumerate() {
                let base = assign[i] as usize * dim;
                for (j, &x) in data.row_logical(p as usize).iter().enumerate() {
                    sums[base + j] += x as f64;
                }
            }
            for s in 0..shards {
                if counts[s] == 0 {
                    continue; // keep the previous centroid
                }
                let inv = 1.0 / counts[s] as f64;
                for (j, c) in centroids.row_mut(s).iter_mut().take(dim).enumerate() {
                    *c = (sums[s * dim + j] * inv) as f32;
                }
            }
        }

        // Full assignment: nearest + runner-up per corpus row.
        let mut owner = vec![0u32; n];
        let mut runner = vec![0u32; n];
        let mut margin = vec![0.0f32; n];
        let mut counts = vec![0usize; shards];
        for r in 0..n {
            let (d1, c1, d2, c2) = two_nearest(pair, data.row(r), &centroids);
            owner[r] = c1;
            runner[r] = if shards > 1 { c2 } else { c1 };
            margin[r] = if d2.is_finite() { d2 - d1 } else { 0.0 };
            counts[c1 as usize] += 1;
        }

        // Repair: every shard must own ≥ 2 rows to build a graph. Move
        // the globally nearest row (to the starving shard's centroid)
        // out of any shard that can spare one; ties break by row id.
        for s in 0..shards {
            while counts[s] < 2 {
                let mut best: Option<usize> = None;
                for r in 0..n {
                    if owner[r] as usize == s || counts[owner[r] as usize] <= 2 {
                        continue;
                    }
                    let d = pair(data.row(r), centroids.row(s));
                    let better = match best {
                        None => true,
                        Some(b) => d < pair(data.row(b), centroids.row(s)),
                    };
                    if better {
                        best = Some(r);
                    }
                }
                let Some(r) = best else {
                    bail!("k-means repair failed: no shard can spare a row for shard {s}")
                };
                counts[owner[r] as usize] -= 1;
                runner[r] = owner[r];
                owner[r] = s as u32;
                margin[r] = 0.0;
                counts[s] += 1;
            }
        }

        // Primaries, ascending by row id.
        let mut plans: Vec<ShardPlan> = (0..shards)
            .map(|s| ShardPlan { rows: Vec::with_capacity(counts[s]), primaries: 0 })
            .collect();
        for (r, &o) in owner.iter().enumerate() {
            plans[o as usize].rows.push(r as u32);
        }
        for plan in &mut plans {
            plan.primaries = plan.rows.len();
        }

        // Ghosts: per shard, the not-owned rows whose runner-up is this
        // shard, smallest routing margin first, capped at ⌈primaries/8⌉.
        let mut ghost_cands: Vec<Vec<u32>> = vec![Vec::new(); shards];
        for r in 0..n {
            let g = runner[r];
            if g != owner[r] {
                ghost_cands[g as usize].push(r as u32);
            }
        }
        for (s, plan) in plans.iter_mut().enumerate() {
            let cap = plan.primaries.div_ceil(GHOST_DENOM);
            let cands = &mut ghost_cands[s];
            cands.sort_unstable_by(|&a, &b| {
                margin[a as usize].total_cmp(&margin[b as usize]).then(a.cmp(&b))
            });
            cands.truncate(cap);
            cands.sort_unstable();
            plan.rows.extend_from_slice(cands);
        }

        let mut final_centroids = AlignedMatrix::zeroed(shards, data.dim());
        for (s, plan) in plans.iter().enumerate() {
            write_mean(&mut final_centroids, s, data, &plan.rows[..plan.primaries]);
        }
        let plan = PartitionPlan { shards: plans, centroids: final_centroids };
        plan.validate(n)?;
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::clustered::SynthClustered;

    fn corpus(n: usize, seed: u64) -> (AlignedMatrix, Vec<u32>) {
        SynthClustered::new(n, 8, 4, seed).generate_labeled()
    }

    #[test]
    fn contiguous_reproduces_the_historical_cut() {
        let (data, _) = corpus(403, 3);
        for shards in [1usize, 2, 5, 8] {
            let plan = Contiguous.plan(&data, shards).unwrap();
            plan.validate(data.n()).unwrap();
            assert_eq!(plan.shards.len(), shards);
            for (idx, sp) in plan.shards.iter().enumerate() {
                let lo = idx * data.n() / shards;
                let hi = (idx + 1) * data.n() / shards;
                assert_eq!(sp.rows, (lo as u32..hi as u32).collect::<Vec<_>>(), "shard {idx}");
                assert_eq!(sp.primaries, hi - lo);
                assert!(sp.ghosts().is_empty());
            }
        }
    }

    #[test]
    fn contiguous_rejects_degenerate_partitions() {
        let (data, _) = corpus(40, 1);
        assert!(Contiguous.plan(&data, 0).is_err());
        assert!(Contiguous.plan(&data, 21).is_err());
        assert!(KMeans::default().plan(&data, 21).is_err());
    }

    #[test]
    fn kmeans_plan_is_deterministic() {
        let (data, _) = corpus(600, 7);
        let a = KMeans::default().plan(&data, 4).unwrap();
        let b = KMeans::default().plan(&data, 4).unwrap();
        assert_eq!(a.shards.len(), b.shards.len());
        for (sa, sb) in a.shards.iter().zip(&b.shards) {
            assert_eq!(sa.rows, sb.rows);
            assert_eq!(sa.primaries, sb.primaries);
        }
        assert_eq!(a.centroids.as_slice(), b.centroids.as_slice());
    }

    #[test]
    fn kmeans_partitions_every_row_once_with_bounded_ghosts() {
        let (data, _) = corpus(600, 11);
        let plan = KMeans::default().plan(&data, 4).unwrap();
        plan.validate(data.n()).unwrap();
        let owned: usize = plan.shards.iter().map(|s| s.primaries).sum();
        assert_eq!(owned, data.n());
        for (s, sp) in plan.shards.iter().enumerate() {
            assert!(sp.primaries >= 2, "shard {s}");
            assert!(
                sp.ghosts().len() <= sp.primaries.div_ceil(GHOST_DENOM),
                "shard {s}: {} ghosts over budget",
                sp.ghosts().len()
            );
            // ghosts ascending and distinct
            assert!(sp.ghosts().windows(2).all(|w| w[0] < w[1]), "shard {s} ghost order");
        }
    }

    #[test]
    fn kmeans_recovers_well_separated_clusters() {
        // SynthClustered's separation ≫ spread, so a 4-way k-means over
        // a 4-cluster corpus should produce label-pure shards.
        let (data, labels) = corpus(800, 13);
        let plan = KMeans::default().plan(&data, 4).unwrap();
        let mut pure = 0usize;
        for sp in &plan.shards {
            let first = labels[sp.rows[0] as usize];
            if sp.rows[..sp.primaries].iter().all(|&r| labels[r as usize] == first) {
                pure += 1;
            }
        }
        assert!(pure >= 3, "only {pure}/4 shards label-pure");
    }

    #[test]
    fn kmeans_handles_single_shard() {
        let (data, _) = corpus(50, 17);
        let plan = KMeans::default().plan(&data, 1).unwrap();
        plan.validate(data.n()).unwrap();
        assert_eq!(plan.shards[0].primaries, 50);
        assert!(plan.shards[0].ghosts().is_empty(), "S=1 has no runner-up shard");
    }
}
