//! Benchmark harness utilities (offline substitute for `criterion`).
//!
//! Every `rust/benches/bench_*.rs` binary uses this module: repeated
//! measurement with warmup, median/min reporting, aligned table output,
//! and CSV emission (so figures can be re-plotted from the raw series).
//! Benches honor two env vars:
//!
//! * `KNNG_BENCH_FULL=1` — run paper-scale problem sizes (minutes), not
//!   the CI-scale defaults.
//! * `KNNG_BENCH_CSV=dir` — also write each table as `dir/<name>.csv`.

use crate::util::stats::Summary;
use std::io::Write;
use std::time::Instant;

/// True when paper-scale sizes were requested.
pub fn full_scale() -> bool {
    std::env::var("KNNG_BENCH_FULL").map(|v| v == "1" || v == "true").unwrap_or(false)
}

/// Number of measured repetitions (extra samples on top of the warmup).
pub fn default_reps() -> usize {
    if full_scale() {
        3
    } else {
        3
    }
}

/// Measure a closure `reps` times after one warmup run; returns seconds
/// per repetition (all samples).
pub fn measure<T>(reps: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    std::hint::black_box(f()); // warmup (also faults pages, fills caches)
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let out = f();
        samples.push(t0.elapsed().as_secs_f64());
        std::hint::black_box(out);
    }
    samples
}

/// Measure once (for long-running end-to-end cases).
pub fn measure_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// A results table with aligned console rendering and CSV output.
#[derive(Debug, Clone)]
pub struct Table {
    pub name: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(name: &str, headers: &[&str]) -> Self {
        Self {
            name: name.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render to stdout with aligned columns.
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.name);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }

    /// Write CSV into the `KNNG_BENCH_CSV` directory if set.
    pub fn maybe_csv(&self) {
        let Ok(dir) = std::env::var("KNNG_BENCH_CSV") else { return };
        if dir.is_empty() {
            return;
        }
        let _ = std::fs::create_dir_all(&dir);
        let path = std::path::Path::new(&dir).join(format!("{}.csv", self.name));
        if let Ok(mut f) = std::fs::File::create(&path) {
            let esc = |s: &str| {
                if s.contains([',', '"', '\n']) {
                    format!("\"{}\"", s.replace('"', "\"\""))
                } else {
                    s.to_string()
                }
            };
            let _ = writeln!(f, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
            for row in &self.rows {
                let _ = writeln!(f, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            }
            eprintln!("[bench] wrote {}", path.display());
        }
    }

    /// Print and optionally persist.
    pub fn finish(&self) {
        self.print();
        self.maybe_csv();
    }
}

/// Minimal JSON value for machine-readable bench artifacts
/// (`BENCH_*.json`) — no serde offline, so a tiny hand-rolled tree.
#[derive(Debug, Clone)]
pub enum Json {
    Str(String),
    Num(f64),
    Int(u64),
    Bool(bool),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// String value helper.
    pub fn s(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    /// Object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Render to compact JSON text (non-finite numbers become `null`).
    pub fn render(&self) -> String {
        match self {
            Json::Str(s) => {
                let mut out = String::with_capacity(s.len() + 2);
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
                out
            }
            Json::Num(x) if x.is_finite() => format!("{x}"),
            Json::Num(_) => "null".into(),
            Json::Int(x) => format!("{x}"),
            Json::Bool(b) => format!("{b}"),
            Json::Arr(xs) => {
                format!("[{}]", xs.iter().map(Json::render).collect::<Vec<_>>().join(","))
            }
            Json::Obj(kv) => format!(
                "{{{}}}",
                kv.iter()
                    .map(|(k, v)| format!("{}:{}", Json::s(k.clone()).render(), v.render()))
                    .collect::<Vec<_>>()
                    .join(",")
            ),
        }
    }
}

/// Write a machine-readable bench artifact (e.g. `BENCH_query.json`)
/// into the current directory so the perf trajectory can be tracked
/// across PRs. Best-effort: failures are reported, never fatal.
pub fn write_bench_json(file_name: &str, value: &Json) {
    let mut text = value.render();
    text.push('\n');
    match std::fs::write(file_name, &text) {
        Ok(()) => eprintln!("[bench] wrote {file_name}"),
        Err(e) => eprintln!("[bench] could not write {file_name}: {e}"),
    }
}

/// Format seconds with adaptive precision.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

/// Format a sample set as `median (±stddev)`.
pub fn fmt_samples(samples: &[f64]) -> String {
    let s = Summary::of(samples);
    format!("{} (±{})", fmt_secs(s.median), fmt_secs(s.stddev))
}

/// Format a large count with thousands separators (`131'072` paper style).
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push('\'');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_rendering_and_arity() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
        t.print(); // smoke: must not panic
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn measure_returns_reps_samples() {
        let samples = measure(5, || (0..100).sum::<u64>());
        assert_eq!(samples.len(), 5);
        assert!(samples.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_count(131072), "131'072");
        assert_eq!(fmt_count(7), "7");
        assert_eq!(fmt_count(1234567), "1'234'567");
        assert!(fmt_secs(2.5).contains('s'));
        assert!(fmt_secs(0.002).contains("ms"));
        assert!(fmt_secs(2e-6).contains("µs"));
        assert!(fmt_secs(5e-9).contains("ns"));
    }

    #[test]
    fn json_rendering_escapes_and_nests() {
        let v = Json::obj(vec![
            ("name", Json::s("a\"b\nc")),
            ("n", Json::Int(42)),
            ("qps", Json::Num(1.5)),
            ("nan", Json::Num(f64::NAN)),
            ("ok", Json::Bool(true)),
            ("rows", Json::Arr(vec![Json::obj(vec![("k", Json::s("w8"))])])),
        ]);
        let text = v.render();
        assert_eq!(
            text,
            "{\"name\":\"a\\\"b\\nc\",\"n\":42,\"qps\":1.5,\"nan\":null,\"ok\":true,\
             \"rows\":[{\"k\":\"w8\"}]}"
        );
    }

    #[test]
    fn json_written_to_disk() {
        let dir = std::env::temp_dir().join("knng_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let v = Json::obj(vec![("x", Json::Int(1))]);
        write_bench_json(path.to_str().unwrap(), &v);
        let content = std::fs::read_to_string(&path).unwrap();
        assert_eq!(content.trim(), "{\"x\":1}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn csv_written_when_env_set() {
        let dir = std::env::temp_dir().join("knng_bench_csv_test");
        std::env::set_var("KNNG_BENCH_CSV", dir.to_str().unwrap());
        let mut t = Table::new("csv_test", &["x", "y"]);
        t.row(&["1".into(), "a,b".into()]);
        t.maybe_csv();
        let content = std::fs::read_to_string(dir.join("csv_test.csv")).unwrap();
        assert!(content.contains("\"a,b\""));
        std::env::remove_var("KNNG_BENCH_CSV");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
