//! Typed experiment configuration mapped from parsed TOML tables.
//!
//! A config file fully describes one graph-build run:
//!
//! ```toml
//! name = "mnist-greedy"
//!
//! [dataset]
//! kind = "mnist"          # gaussian | clustered | mnist | audio | fvecs
//! n = 70000
//! dim = 784
//!
//! [run]
//! k = 20
//! rho = 0.5
//! delta = 0.001
//! selection = "turbo"     # naive | heap | turbo
//! compute = "blocked"     # scalar | unrolled | blocked | pjrt
//! reorder = true
//! seed = 42
//! ```

use super::parser::{ParseError, Table};

/// Which selection-step implementation to run (paper §3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SelectionKind {
    /// Three-pass reverse/union/sample straight from Dong et al. pseudocode.
    Naive,
    /// PyNNDescent-style fused one-pass with bounded random-weight heaps.
    Heap,
    /// Paper's "turbosampling": heap-free, reverse-degree-counter sampling.
    Turbo,
}

impl SelectionKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "naive" => Some(Self::Naive),
            "heap" => Some(Self::Heap),
            "turbo" => Some(Self::Turbo),
            _ => None,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            Self::Naive => "naive",
            Self::Heap => "heap",
            Self::Turbo => "turbo",
        }
    }
    /// Stable on-disk code (KNNIv1 index bundles).
    pub fn code(self) -> u8 {
        match self {
            Self::Naive => 0,
            Self::Heap => 1,
            Self::Turbo => 2,
        }
    }
    /// Inverse of [`code`](Self::code).
    pub fn from_code(c: u8) -> Option<Self> {
        match c {
            0 => Some(Self::Naive),
            1 => Some(Self::Heap),
            2 => Some(Self::Turbo),
            _ => None,
        }
    }
}

/// Which distance-evaluation backend the compute step uses (paper §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ComputeKind {
    /// Plain scalar loop (baseline; paper's `nndescent-full` compute).
    Scalar,
    /// 8-lane accumulator loop (paper's `l2intrinsics` + `mem-align`).
    Unrolled,
    /// 5×5-vector blocked mutual distances (paper's `blocked`).
    Blocked,
    /// Offload candidate blocks to the AOT-compiled Pallas/XLA executable.
    Pjrt,
}

impl ComputeKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "scalar" => Some(Self::Scalar),
            "unrolled" => Some(Self::Unrolled),
            "blocked" => Some(Self::Blocked),
            "pjrt" => Some(Self::Pjrt),
            _ => None,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Unrolled => "unrolled",
            Self::Blocked => "blocked",
            Self::Pjrt => "pjrt",
        }
    }
    /// Stable on-disk code (KNNIv1 index bundles).
    pub fn code(self) -> u8 {
        match self {
            Self::Scalar => 0,
            Self::Unrolled => 1,
            Self::Blocked => 2,
            Self::Pjrt => 3,
        }
    }
    /// Inverse of [`code`](Self::code).
    pub fn from_code(c: u8) -> Option<Self> {
        match c {
            0 => Some(Self::Scalar),
            1 => Some(Self::Unrolled),
            2 => Some(Self::Blocked),
            3 => Some(Self::Pjrt),
            _ => None,
        }
    }
}

/// Dataset description (generator parameters or file paths).
#[derive(Debug, Clone, PartialEq)]
pub enum DatasetSpec {
    /// Synthetic Gaussian (paper §4): `single` = one blob at the origin,
    /// otherwise one Gaussian per dimension centered on basis vectors.
    Gaussian { n: usize, dim: usize, single: bool, seed: u64 },
    /// Synthetic Clustered dataset satisfying the clustered assumption.
    Clustered { n: usize, dim: usize, clusters: usize, seed: u64 },
    /// MNIST 70k×784. Loads IDX(+gz) from `path` if given/found,
    /// otherwise generates the MNIST-like substitute (see DESIGN.md §4).
    Mnist { n: usize, path: Option<String>, seed: u64 },
    /// Audio-like dataset, 54387×192 by default (Dong et al. shape).
    Audio { n: usize, dim: usize, seed: u64 },
    /// Raw `.fvecs` file (TEXMEX format).
    Fvecs { path: String, limit: usize },
}

impl DatasetSpec {
    /// Human-readable dataset family name.
    pub fn kind(&self) -> &'static str {
        match self {
            Self::Gaussian { .. } => "gaussian",
            Self::Clustered { .. } => "clustered",
            Self::Mnist { .. } => "mnist",
            Self::Audio { .. } => "audio",
            Self::Fvecs { .. } => "fvecs",
        }
    }

    fn from_table(t: &Table) -> Result<Self, ParseError> {
        let kind = t.str_or("dataset.kind", "gaussian");
        let seed = t.int_or("dataset.seed", 0x5eed) as u64;
        match kind {
            "gaussian" => Ok(Self::Gaussian {
                n: t.usize_or("dataset.n", 16_384),
                dim: t.usize_or("dataset.dim", 8),
                single: t.bool_or("dataset.single", true),
                seed,
            }),
            "clustered" => Ok(Self::Clustered {
                n: t.usize_or("dataset.n", 16_384),
                dim: t.usize_or("dataset.dim", 8),
                clusters: t.usize_or("dataset.clusters", 16),
                seed,
            }),
            "mnist" => Ok(Self::Mnist {
                n: t.usize_or("dataset.n", 70_000),
                path: t.get("dataset.path").and_then(|v| v.as_str()).map(String::from),
                seed,
            }),
            "audio" => Ok(Self::Audio {
                n: t.usize_or("dataset.n", 54_387),
                dim: t.usize_or("dataset.dim", 192),
                seed,
            }),
            "fvecs" => Ok(Self::Fvecs {
                path: t.require_str("dataset.path")?.to_string(),
                limit: t.usize_or("dataset.limit", usize::MAX),
            }),
            other => Err(ParseError { line: 0, msg: format!("unknown dataset.kind `{other}`") }),
        }
    }
}

/// NN-Descent run parameters (paper defaults: k=20, ρ=0.5, δ=0.001).
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    pub k: usize,
    pub rho: f64,
    pub delta: f64,
    pub max_iters: usize,
    pub seed: u64,
    pub selection: SelectionKind,
    pub compute: ComputeKind,
    pub reorder: bool,
    /// Hard cap on candidate-set size (paper: 50).
    pub max_candidates: usize,
    /// Directory holding AOT artifacts (pjrt backend only).
    pub artifacts_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            k: 20,
            rho: 0.5,
            delta: 0.001,
            max_iters: 30,
            seed: 1,
            selection: SelectionKind::Turbo,
            compute: ComputeKind::Blocked,
            reorder: false,
            max_candidates: 50,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

impl RunConfig {
    fn from_table(t: &Table) -> Result<Self, ParseError> {
        let d = Self::default();
        let selection = {
            let s = t.str_or("run.selection", d.selection.name());
            SelectionKind::parse(s)
                .ok_or_else(|| ParseError { line: 0, msg: format!("unknown run.selection `{s}`") })?
        };
        let compute = {
            let s = t.str_or("run.compute", d.compute.name());
            ComputeKind::parse(s)
                .ok_or_else(|| ParseError { line: 0, msg: format!("unknown run.compute `{s}`") })?
        };
        let cfg = Self {
            k: t.usize_or("run.k", d.k),
            rho: t.float_or("run.rho", d.rho),
            delta: t.float_or("run.delta", d.delta),
            max_iters: t.usize_or("run.max_iters", d.max_iters),
            seed: t.int_or("run.seed", d.seed as i64) as u64,
            selection,
            compute,
            reorder: t.bool_or("run.reorder", d.reorder),
            max_candidates: t.usize_or("run.max_candidates", d.max_candidates),
            artifacts_dir: t.str_or("run.artifacts_dir", &d.artifacts_dir).to_string(),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity-check parameter ranges.
    pub fn validate(&self) -> Result<(), ParseError> {
        let bad = |msg: String| Err(ParseError { line: 0, msg });
        if self.k == 0 {
            return bad("run.k must be ≥ 1".into());
        }
        if !(0.0 < self.rho && self.rho <= 1.0) {
            return bad(format!("run.rho must be in (0,1], got {}", self.rho));
        }
        if !(0.0..1.0).contains(&self.delta) {
            return bad(format!("run.delta must be in [0,1), got {}", self.delta));
        }
        if self.max_candidates < self.k.min(50) / 2 {
            return bad(format!(
                "run.max_candidates ({}) too small for k={}",
                self.max_candidates, self.k
            ));
        }
        Ok(())
    }
}

/// A full experiment: name + dataset + run parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    pub name: String,
    pub dataset: DatasetSpec,
    pub run: RunConfig,
}

impl ExperimentConfig {
    /// Build from a parsed table.
    pub fn from_table(t: &Table) -> Result<Self, ParseError> {
        Ok(Self {
            name: t.str_or("name", "unnamed").to_string(),
            dataset: DatasetSpec::from_table(t)?,
            run: RunConfig::from_table(t)?,
        })
    }

    /// Parse a config file's contents.
    pub fn from_str(s: &str) -> Result<Self, ParseError> {
        Self::from_table(&super::parser::parse(s)?)
    }

    /// Load from a path.
    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading config {}: {e}", path.display()))?;
        Ok(Self::from_str(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"
        name = "mnist-greedy"
        [dataset]
        kind = "mnist"
        n = 70000
        [run]
        k = 20
        rho = 0.5
        delta = 0.001
        selection = "turbo"
        compute = "blocked"
        reorder = true
        seed = 42
    "#;

    #[test]
    fn full_roundtrip() {
        let c = ExperimentConfig::from_str(FULL).unwrap();
        assert_eq!(c.name, "mnist-greedy");
        assert!(matches!(c.dataset, DatasetSpec::Mnist { n: 70000, .. }));
        assert_eq!(c.run.k, 20);
        assert_eq!(c.run.selection, SelectionKind::Turbo);
        assert_eq!(c.run.compute, ComputeKind::Blocked);
        assert!(c.run.reorder);
        assert_eq!(c.run.seed, 42);
    }

    #[test]
    fn defaults_apply() {
        let c = ExperimentConfig::from_str("name = \"d\"").unwrap();
        assert_eq!(c.run.k, 20);
        assert_eq!(c.run.rho, 0.5);
        assert!(matches!(c.dataset, DatasetSpec::Gaussian { n: 16384, dim: 8, single: true, .. }));
    }

    #[test]
    fn dataset_kinds() {
        let c = ExperimentConfig::from_str("[dataset]\nkind = \"clustered\"\nclusters = 8").unwrap();
        assert!(matches!(c.dataset, DatasetSpec::Clustered { clusters: 8, .. }));
        let c = ExperimentConfig::from_str("[dataset]\nkind = \"audio\"").unwrap();
        assert!(matches!(c.dataset, DatasetSpec::Audio { n: 54_387, dim: 192, .. }));
        let e = ExperimentConfig::from_str("[dataset]\nkind = \"bogus\"");
        assert!(e.is_err());
        let e = ExperimentConfig::from_str("[dataset]\nkind = \"fvecs\"");
        assert!(e.is_err(), "fvecs requires a path");
    }

    #[test]
    fn validation_rejects_bad_params() {
        assert!(ExperimentConfig::from_str("[run]\nk = 0").is_err());
        assert!(ExperimentConfig::from_str("[run]\nrho = 0.0").is_err());
        assert!(ExperimentConfig::from_str("[run]\nrho = 1.5").is_err());
        assert!(ExperimentConfig::from_str("[run]\ndelta = 1.0").is_err());
        assert!(ExperimentConfig::from_str("[run]\nselection = \"magic\"").is_err());
        assert!(ExperimentConfig::from_str("[run]\ncompute = \"gpu\"").is_err());
    }

    #[test]
    fn kind_names_roundtrip() {
        for k in [SelectionKind::Naive, SelectionKind::Heap, SelectionKind::Turbo] {
            assert_eq!(SelectionKind::parse(k.name()), Some(k));
        }
        for c in [ComputeKind::Scalar, ComputeKind::Unrolled, ComputeKind::Blocked, ComputeKind::Pjrt] {
            assert_eq!(ComputeKind::parse(c.name()), Some(c));
        }
    }

    #[test]
    fn kind_codes_roundtrip() {
        for k in [SelectionKind::Naive, SelectionKind::Heap, SelectionKind::Turbo] {
            assert_eq!(SelectionKind::from_code(k.code()), Some(k));
        }
        for c in [ComputeKind::Scalar, ComputeKind::Unrolled, ComputeKind::Blocked, ComputeKind::Pjrt] {
            assert_eq!(ComputeKind::from_code(c.code()), Some(c));
        }
        assert_eq!(SelectionKind::from_code(9), None);
        assert_eq!(ComputeKind::from_code(9), None);
    }
}
