//! TOML-subset parser.
//!
//! Supported grammar (sufficient for `configs/*.toml`):
//!
//! ```text
//! file      := (line NEWLINE)*
//! line      := ws (comment | section | keyvalue)? ws
//! section   := '[' dotted-key ']'
//! keyvalue  := key ws '=' ws value
//! value     := string | float | int | bool | array
//! array     := '[' (value (',' value)* ','?)? ']'
//! string    := '"' escaped-chars '"'
//! comment   := '#' any*
//! ```
//!
//! Values in a `[a.b]` section are stored flat under the key `"a.b.key"`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Floats accept integer literals too (`k = 20` usable as f64).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Flat key → value map with typed accessors and defaults.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Table {
    map: BTreeMap<String, Value>,
}

impl Table {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, key: impl Into<String>, value: Value) {
        self.map.insert(key.into(), value);
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.map.get(key)
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.int_or(key, default as i64).max(0) as usize
    }

    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Required typed getters for schema validation.
    pub fn require_str(&self, key: &str) -> Result<&str, ParseError> {
        self.get(key).and_then(Value::as_str).ok_or_else(|| ParseError {
            line: 0,
            msg: format!("missing or non-string key `{key}`"),
        })
    }
}

/// Error with a 1-based line number (0 = semantic, not positional).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn err(line: usize, msg: impl Into<String>) -> ParseError {
    ParseError { line, msg: msg.into() }
}

/// Parse a TOML-subset document into a flat [`Table`].
pub fn parse(input: &str) -> Result<Table, ParseError> {
    let mut table = Table::new();
    let mut section = String::new();
    for (idx, raw) in input.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| err(lineno, "unterminated section header"))?
                .trim();
            if name.is_empty() {
                return Err(err(lineno, "empty section name"));
            }
            validate_key(name, lineno)?;
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(lineno, format!("expected `key = value`, got `{line}`")))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        validate_key(key, lineno)?;
        let value = parse_value(line[eq + 1..].trim(), lineno)?;
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        if table.contains(&full) {
            return Err(err(lineno, format!("duplicate key `{full}`")));
        }
        table.insert(full, value);
    }
    Ok(table)
}

fn validate_key(key: &str, lineno: usize) -> Result<(), ParseError> {
    let ok = key
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-' || c == '.');
    if ok {
        Ok(())
    } else {
        Err(err(lineno, format!("invalid key `{key}`")))
    }
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    let mut escaped = false;
    for (i, c) in line.char_indices() {
        match c {
            '\\' if in_str => escaped = !escaped,
            '"' if !escaped => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => escaped = false,
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value, ParseError> {
    if s.is_empty() {
        return Err(err(lineno, "empty value"));
    }
    if s.starts_with('"') {
        return parse_string(s, lineno);
    }
    if s.starts_with('[') {
        return parse_array(s, lineno);
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    // Numbers: underscores allowed as digit separators (TOML).
    let clean: String = s.chars().filter(|&c| c != '_').collect();
    if clean.contains(['.', 'e', 'E']) || clean == "inf" || clean == "-inf" || clean == "nan" {
        if let Ok(f) = clean.parse::<f64>() {
            return Ok(Value::Float(f));
        }
    } else if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    Err(err(lineno, format!("cannot parse value `{s}`")))
}

fn parse_string(s: &str, lineno: usize) -> Result<Value, ParseError> {
    let inner = &s[1..];
    let mut out = String::new();
    let mut chars = inner.chars();
    loop {
        match chars.next() {
            None => return Err(err(lineno, "unterminated string")),
            Some('"') => break,
            Some('\\') => match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('\\') => out.push('\\'),
                Some('"') => out.push('"'),
                other => return Err(err(lineno, format!("bad escape `\\{other:?}`"))),
            },
            Some(c) => out.push(c),
        }
    }
    let rest: String = chars.collect();
    if !rest.trim().is_empty() {
        return Err(err(lineno, format!("trailing characters after string: `{rest}`")));
    }
    Ok(Value::Str(out))
}

fn parse_array(s: &str, lineno: usize) -> Result<Value, ParseError> {
    let inner = s
        .strip_prefix('[')
        .and_then(|x| x.strip_suffix(']'))
        .ok_or_else(|| err(lineno, "unterminated array"))?;
    let mut items = Vec::new();
    // split on commas outside strings (nested arrays unsupported — subset)
    let mut depth_str = false;
    let mut start = 0usize;
    let bytes = inner.as_bytes();
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'"' => depth_str = !depth_str,
            b',' if !depth_str => {
                let part = inner[start..i].trim();
                if !part.is_empty() {
                    items.push(parse_value(part, lineno)?);
                }
                start = i + 1;
            }
            _ => {}
        }
    }
    let last = inner[start..].trim();
    if !last.is_empty() {
        items.push(parse_value(last, lineno)?);
    }
    Ok(Value::Array(items))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let t = parse(
            r#"
            # top comment
            name = "exp1"
            n = 16_384
            rho = 0.5
            verbose = true

            [dataset]
            kind = "clustered"   # inline comment
            clusters = 16

            [dataset.gen]
            sigma = 2.0
            "#,
        )
        .unwrap();
        assert_eq!(t.get("name").unwrap().as_str(), Some("exp1"));
        assert_eq!(t.get("n").unwrap().as_int(), Some(16384));
        assert_eq!(t.get("rho").unwrap().as_float(), Some(0.5));
        assert_eq!(t.get("verbose").unwrap().as_bool(), Some(true));
        assert_eq!(t.get("dataset.kind").unwrap().as_str(), Some("clustered"));
        assert_eq!(t.get("dataset.clusters").unwrap().as_int(), Some(16));
        assert_eq!(t.get("dataset.gen.sigma").unwrap().as_float(), Some(2.0));
    }

    #[test]
    fn parses_arrays() {
        let t = parse("dims = [8, 64, 256]\nnames = [\"a\", \"b\"]\nempty = []").unwrap();
        let dims = t.get("dims").unwrap().as_array().unwrap();
        assert_eq!(dims.iter().map(|v| v.as_int().unwrap()).collect::<Vec<_>>(), vec![8, 64, 256]);
        let names = t.get("names").unwrap().as_array().unwrap();
        assert_eq!(names[1].as_str(), Some("b"));
        assert_eq!(t.get("empty").unwrap().as_array().unwrap().len(), 0);
    }

    #[test]
    fn string_escapes_and_hash_inside_string() {
        let t = parse(r#"s = "a#b\n\"q\"""#).unwrap();
        assert_eq!(t.get("s").unwrap().as_str(), Some("a#b\n\"q\""));
    }

    #[test]
    fn int_as_float_coercion() {
        let t = parse("k = 20").unwrap();
        assert_eq!(t.get("k").unwrap().as_float(), Some(20.0));
    }

    #[test]
    fn errors_have_line_numbers() {
        let e = parse("ok = 1\nbad line").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse("x = ").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse("[unterminated").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse("a = 1\na = 2").unwrap_err();
        assert!(e.msg.contains("duplicate"));
    }

    #[test]
    fn rejects_bad_values() {
        assert!(parse("x = notaword").is_err());
        assert!(parse("x = \"unterminated").is_err());
        assert!(parse("x = \"done\" trailing").is_err());
        assert!(parse("bad key! = 1").is_err());
    }

    #[test]
    fn defaults_api() {
        let t = parse("present = 3").unwrap();
        assert_eq!(t.int_or("present", 0), 3);
        assert_eq!(t.int_or("absent", 7), 7);
        assert_eq!(t.float_or("absent", 0.5), 0.5);
        assert_eq!(t.str_or("absent", "d"), "d");
        assert!(t.bool_or("absent", true));
        assert_eq!(t.usize_or("present", 0), 3);
    }

    #[test]
    fn negative_and_float_formats() {
        let t = parse("a = -5\nb = -2.5\nc = 1e3\nd = 2.5E-2").unwrap();
        assert_eq!(t.get("a").unwrap().as_int(), Some(-5));
        assert_eq!(t.get("b").unwrap().as_float(), Some(-2.5));
        assert_eq!(t.get("c").unwrap().as_float(), Some(1000.0));
        assert_eq!(t.get("d").unwrap().as_float(), Some(0.025));
    }
}
