//! Configuration system.
//!
//! Experiments are described by TOML-subset files (see `parser`), mapped
//! onto typed configs (see `schema`). The subset supports everything the
//! repo's `configs/*.toml` use: `[section]` tables, string/int/float/bool
//! scalars, homogeneous scalar arrays, comments, and dotted sections.
//! Hand-rolled because `serde`/`toml` are unavailable offline.

pub mod parser;
pub mod schema;

pub use parser::{parse, ParseError, Table, Value};
pub use schema::{DatasetSpec, ExperimentConfig, RunConfig};
