//! Shared low-level utilities: deterministic PRNGs, timers, counters,
//! descriptive statistics, and a minimal logger.
//!
//! Everything here is hand-rolled because the build environment is
//! offline (no `rand`, no `log` backends); determinism is a feature —
//! every experiment in EXPERIMENTS.md is reproducible from a seed.

pub mod counters;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod timer;

pub use counters::FlopCounter;
pub use rng::{Pcg64, SplitMix64};
pub use stats::Summary;
pub use timer::Timer;

/// Round `d` up to the next multiple of `m` (m > 0).
#[inline]
pub fn round_up(d: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    d.div_ceil(m) * m
}

/// Integer ceil-div.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
        assert_eq!(round_up(784, 8), 784);
        assert_eq!(round_up(190, 8), 192);
    }

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 5), 0);
        assert_eq!(ceil_div(1, 5), 1);
        assert_eq!(ceil_div(5, 5), 1);
        assert_eq!(ceil_div(6, 5), 2);
    }
}
