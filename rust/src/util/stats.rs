//! Descriptive statistics for benchmark reporting (no external deps).

/// Five-number-plus summary of a sample of f64 measurements.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for empty input.
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self { n: 0, min: 0.0, max: 0.0, mean: 0.0, median: 0.0, stddev: 0.0 };
        }
        let n = xs.len();
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let min = sorted[0];
        let max = sorted[n - 1];
        let mean = xs.iter().sum::<f64>() / n as f64;
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Self { n, min, max, mean, median, stddev: var.sqrt() }
    }
}

/// Pearson correlation of two equal-length samples (0 for degenerate input).
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n as f64;
    let my = ys.iter().sum::<f64>() / n as f64;
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

/// Least-squares fit of log(y) = a + b·log(x); returns (exp(a), b).
/// Used to verify the paper's empirical O(n^1.14) distance-eval cost.
pub fn powerlaw_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let lx: Vec<f64> = xs.iter().map(|x| x.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|y| y.ln()).collect();
    let n = lx.len() as f64;
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let (mut sxy, mut sxx) = (0.0, 0.0);
    for i in 0..lx.len() {
        sxy += (lx[i] - mx) * (ly[i] - my);
        sxx += (lx[i] - mx) * (lx[i] - mx);
    }
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    (a.exp(), b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert!((s.stddev - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_even_median_and_empty() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.median, 2.5);
        let e = Summary::of(&[]);
        assert_eq!(e.n, 0);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let zs = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&[1.0], &[1.0]), 0.0);
    }

    #[test]
    fn powerlaw_recovers_exponent() {
        // y = 3 * x^1.14
        let xs: Vec<f64> = (1..=10).map(|i| (i * 1000) as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x.powf(1.14)).collect();
        let (c, b) = powerlaw_fit(&xs, &ys);
        assert!((b - 1.14).abs() < 1e-9, "exponent {b}");
        assert!((c - 3.0).abs() < 1e-6, "coefficient {c}");
    }
}
