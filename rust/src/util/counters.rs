//! Work accounting: flop and distance-evaluation counters.
//!
//! The paper (§2) computes W(n) from the number of distance evaluations:
//! one squared-L2 evaluation over d dimensions costs d subtractions,
//! d multiplications, and d−1 additions = 3d−1 flops. We count
//! *evaluations* on the hot path (a single add per candidate block) and
//! derive flops, so instrumentation cost is negligible.

/// Counts distance evaluations and derives flops for the roofline model.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FlopCounter {
    /// Number of squared-L2 distance evaluations performed.
    pub dist_evals: u64,
    /// Dimensionality used to convert evaluations to flops (logical d,
    /// not the padded width — padding lanes multiply zeros).
    pub dim: u64,
    /// Distance-kernel width the counted evaluations ran on (`scalar` /
    /// `w8` / `w16`; stamped at construction from the active dispatch,
    /// empty only for default-constructed counters). Surfaced by
    /// `RunReport` and the bench JSON artifacts so perf numbers always
    /// say which kernel produced them.
    pub kernel: &'static str,
}

impl FlopCounter {
    /// New counter for data of logical dimensionality `dim`, tagged
    /// with the active distance-kernel width.
    pub fn new(dim: usize) -> Self {
        Self {
            dist_evals: 0,
            dim: dim as u64,
            kernel: crate::distance::dispatch::active_width().name(),
        }
    }

    /// Record `k` distance evaluations.
    #[inline]
    pub fn add_evals(&mut self, k: u64) {
        self.dist_evals += k;
    }

    /// Flops per single evaluation: d subs + d muls + (d−1) adds.
    #[inline]
    pub fn flops_per_eval(&self) -> u64 {
        3 * self.dim - 1
    }

    /// Total flops W(n) represented by this counter.
    #[inline]
    pub fn flops(&self) -> u64 {
        self.dist_evals * self.flops_per_eval()
    }

    /// Merge another counter (same dim) into this one.
    pub fn merge(&mut self, other: &FlopCounter) {
        debug_assert_eq!(self.dim, other.dim);
        self.dist_evals += other.dist_evals;
    }
}

/// Per-iteration statistics emitted by the NN-Descent driver.
#[derive(Debug, Clone, Default)]
pub struct IterStats {
    /// Iteration index (0-based).
    pub iter: usize,
    /// Seconds spent in the selection step.
    pub select_secs: f64,
    /// Seconds spent in the compute/update step.
    pub compute_secs: f64,
    /// Seconds spent in the reorder heuristic (0 unless it ran).
    pub reorder_secs: f64,
    /// Distance evaluations this iteration.
    pub dist_evals: u64,
    /// Graph updates (heap replacements) this iteration.
    pub updates: u64,
}

impl IterStats {
    /// Total seconds for the iteration.
    pub fn total_secs(&self) -> f64 {
        self.select_secs + self.compute_secs + self.reorder_secs
    }

    /// Fold a worker's partial record for the *same* iteration into this
    /// one: work counts add, phase times take the max (parallel workers
    /// overlap in wall-clock, so summing their spans would double-count).
    /// With one worker this is plain accumulation, so the sequential and
    /// parallel drivers share the same aggregation path.
    pub fn merge(&mut self, other: &IterStats) {
        debug_assert_eq!(self.iter, other.iter, "merging stats across iterations");
        self.select_secs = self.select_secs.max(other.select_secs);
        self.compute_secs = self.compute_secs.max(other.compute_secs);
        self.reorder_secs = self.reorder_secs.max(other.reorder_secs);
        self.dist_evals += other.dist_evals;
        self.updates += other.updates;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_formula_matches_paper() {
        // d subs + d muls + (d-1) adds
        let mut c = FlopCounter::new(8);
        c.add_evals(10);
        assert_eq!(c.flops_per_eval(), 23);
        assert_eq!(c.flops(), 230);
        assert!(!c.kernel.is_empty(), "counters are tagged with the kernel width");

        let c = FlopCounter { dist_evals: 1, dim: 784, ..Default::default() };
        assert_eq!(c.flops(), 3 * 784 - 1);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = FlopCounter::new(16);
        a.add_evals(5);
        let mut b = FlopCounter::new(16);
        b.add_evals(7);
        a.merge(&b);
        assert_eq!(a.dist_evals, 12);
    }

    #[test]
    fn iter_stats_total() {
        let s = IterStats { select_secs: 1.0, compute_secs: 2.0, reorder_secs: 0.5, ..Default::default() };
        assert!((s.total_secs() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn iter_stats_merge_adds_counts_and_maxes_times() {
        let mut a = IterStats {
            iter: 3,
            select_secs: 0.1,
            compute_secs: 0.5,
            dist_evals: 10,
            updates: 2,
            ..Default::default()
        };
        let b = IterStats {
            iter: 3,
            select_secs: 0.3,
            compute_secs: 0.2,
            dist_evals: 7,
            updates: 5,
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.dist_evals, 17);
        assert_eq!(a.updates, 7);
        assert!((a.select_secs - 0.3).abs() < 1e-12, "overlapping spans take the max");
        assert!((a.compute_secs - 0.5).abs() < 1e-12);
    }
}
