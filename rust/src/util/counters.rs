//! Work accounting: flop and distance-evaluation counters.
//!
//! The paper (§2) computes W(n) from the number of distance evaluations:
//! one squared-L2 evaluation over d dimensions costs d subtractions,
//! d multiplications, and d−1 additions = 3d−1 flops. We count
//! *evaluations* on the hot path (a single add per candidate block) and
//! derive flops, so instrumentation cost is negligible.

/// Counts distance evaluations and derives flops for the roofline model.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FlopCounter {
    /// Number of squared-L2 distance evaluations performed.
    pub dist_evals: u64,
    /// Dimensionality used to convert evaluations to flops (logical d,
    /// not the padded width — padding lanes multiply zeros).
    pub dim: u64,
    /// Distance-kernel width the counted evaluations ran on (`scalar` /
    /// `w8` / `w16`; stamped at construction from the active dispatch,
    /// empty only for default-constructed counters). Surfaced by
    /// `RunReport` and the bench JSON artifacts so perf numbers always
    /// say which kernel produced them.
    pub kernel: &'static str,
}

impl FlopCounter {
    /// New counter for data of logical dimensionality `dim`, tagged
    /// with the active distance-kernel width.
    pub fn new(dim: usize) -> Self {
        Self {
            dist_evals: 0,
            dim: dim as u64,
            kernel: crate::distance::dispatch::active_width().name(),
        }
    }

    /// Record `k` distance evaluations.
    #[inline]
    pub fn add_evals(&mut self, k: u64) {
        self.dist_evals += k;
    }

    /// Flops per single evaluation: d subs + d muls + (d−1) adds.
    #[inline]
    pub fn flops_per_eval(&self) -> u64 {
        3 * self.dim - 1
    }

    /// Total flops W(n) represented by this counter.
    #[inline]
    pub fn flops(&self) -> u64 {
        self.dist_evals * self.flops_per_eval()
    }

    /// Merge another counter (same dim) into this one.
    pub fn merge(&mut self, other: &FlopCounter) {
        debug_assert_eq!(self.dim, other.dim);
        self.dist_evals += other.dist_evals;
    }
}

/// Per-iteration statistics emitted by the NN-Descent driver.
#[derive(Debug, Clone, Default)]
pub struct IterStats {
    /// Iteration index (0-based).
    pub iter: usize,
    /// Seconds spent in the selection step.
    pub select_secs: f64,
    /// Seconds spent in the compute/update step.
    pub compute_secs: f64,
    /// Seconds spent in the reorder heuristic (0 unless it ran).
    pub reorder_secs: f64,
    /// Distance evaluations this iteration.
    pub dist_evals: u64,
    /// Graph updates (heap replacements) this iteration.
    pub updates: u64,
}

impl IterStats {
    /// Total seconds for the iteration.
    pub fn total_secs(&self) -> f64 {
        self.select_secs + self.compute_secs + self.reorder_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_formula_matches_paper() {
        // d subs + d muls + (d-1) adds
        let mut c = FlopCounter::new(8);
        c.add_evals(10);
        assert_eq!(c.flops_per_eval(), 23);
        assert_eq!(c.flops(), 230);
        assert!(!c.kernel.is_empty(), "counters are tagged with the kernel width");

        let c = FlopCounter { dist_evals: 1, dim: 784, ..Default::default() };
        assert_eq!(c.flops(), 3 * 784 - 1);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = FlopCounter::new(16);
        a.add_evals(5);
        let mut b = FlopCounter::new(16);
        b.add_evals(7);
        a.merge(&b);
        assert_eq!(a.dist_evals, 12);
    }

    #[test]
    fn iter_stats_total() {
        let s = IterStats { select_secs: 1.0, compute_secs: 2.0, reorder_secs: 0.5, ..Default::default() };
        assert!((s.total_secs() - 3.5).abs() < 1e-12);
    }
}
