//! Wall-clock timing with cycle-count derivation for the roofline model.
//!
//! The paper reports performance in flops/cycle on a fixed-frequency
//! (turbo-disabled) i7-9700K @ 3.6 GHz. We cannot pin frequency here, so
//! cycles are *derived*: `cycles = seconds × nominal_hz`, with
//! `nominal_hz` configurable (default 3.6 GHz to match the paper's
//! plots). The relative shape of every figure is frequency-independent.

use std::time::{Duration, Instant};

/// Nominal clock used to convert seconds → cycles (paper's machine).
pub const DEFAULT_NOMINAL_HZ: f64 = 3.6e9;

/// A simple start/stop accumulating timer.
#[derive(Debug, Clone)]
pub struct Timer {
    started: Option<Instant>,
    accumulated: Duration,
    laps: Vec<Duration>,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    /// New, stopped timer with zero accumulated time.
    pub fn new() -> Self {
        Self { started: None, accumulated: Duration::ZERO, laps: Vec::new() }
    }

    /// Start (or restart) the running segment.
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    /// Stop the running segment, adding it to the accumulated total and
    /// recording it as a lap. No-op if not running.
    pub fn stop(&mut self) {
        if let Some(t0) = self.started.take() {
            let lap = t0.elapsed();
            self.accumulated += lap;
            self.laps.push(lap);
        }
    }

    /// Total accumulated time across all laps (excluding a running segment).
    pub fn total(&self) -> Duration {
        self.accumulated
    }

    /// Total in seconds.
    pub fn secs(&self) -> f64 {
        self.accumulated.as_secs_f64()
    }

    /// Individual lap durations.
    pub fn laps(&self) -> &[Duration] {
        &self.laps
    }

    /// Derived cycle count at the given nominal frequency.
    pub fn cycles(&self, nominal_hz: f64) -> f64 {
        self.secs() * nominal_hz
    }

    /// Reset to the zero state.
    pub fn reset(&mut self) {
        self.started = None;
        self.accumulated = Duration::ZERO;
        self.laps.clear();
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run `f` repeatedly until at least `min_secs` have elapsed *and*
/// `min_reps` repetitions were made; returns the minimum per-rep seconds
/// (the standard noise-robust microbenchmark estimator).
pub fn bench_min<T>(min_reps: usize, min_secs: f64, mut f: impl FnMut() -> T) -> f64 {
    let mut best = f64::INFINITY;
    let mut elapsed = 0.0;
    let mut reps = 0;
    while reps < min_reps || elapsed < min_secs {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(out);
        best = best.min(dt);
        elapsed += dt;
        reps += 1;
        if reps > 1_000_000 {
            break; // safety valve for pathologically fast closures
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_accumulates_laps() {
        let mut t = Timer::new();
        t.start();
        std::thread::sleep(Duration::from_millis(5));
        t.stop();
        t.start();
        std::thread::sleep(Duration::from_millis(5));
        t.stop();
        assert_eq!(t.laps().len(), 2);
        assert!(t.secs() >= 0.009, "accumulated {}", t.secs());
    }

    #[test]
    fn stop_without_start_is_noop() {
        let mut t = Timer::new();
        t.stop();
        assert_eq!(t.laps().len(), 0);
        assert_eq!(t.total(), Duration::ZERO);
    }

    #[test]
    fn cycles_derivation() {
        let mut t = Timer::new();
        t.start();
        std::thread::sleep(Duration::from_millis(10));
        t.stop();
        let c = t.cycles(1e9);
        assert!(c >= 9e6, "cycles {c}");
    }

    #[test]
    fn bench_min_returns_positive() {
        let dt = bench_min(3, 0.0, || (0..1000).sum::<u64>());
        assert!(dt >= 0.0 && dt.is_finite());
    }
}
