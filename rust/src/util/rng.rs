//! Deterministic pseudo-random number generators.
//!
//! NN-Descent is a randomized algorithm: the initial graph, the edge
//! sampling weights, and the turbosampling coin flips are all random.
//! The paper relies on `rand()`-style uniform draws; we use PCG64 (O'Neill
//! 2014, `pcg_xsl_rr_128_64`) for the algorithm and SplitMix64 for cheap
//! seeding/stream-splitting, both fully deterministic from a `u64` seed so
//! every benchmark row in EXPERIMENTS.md is reproducible.

/// SplitMix64 — tiny, fast generator used to expand seeds and to derive
/// independent streams (one per node, per iteration) without correlation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a raw seed.
    #[inline]
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Generator positioned at element `index` of the stream seeded by
    /// `seed` — O(1) random access into the SplitMix64 sequence (the
    /// state advances by a fixed increment per draw, so jumping is one
    /// multiply). This is what makes *counter-based* randomness cheap:
    /// the parallel build derives an independent draw per (seed, edge)
    /// pair, so every worker computes the same coins for the same edge
    /// no matter how the id ranges are partitioned.
    #[inline]
    pub fn at(seed: u64, index: u64) -> Self {
        Self { state: seed.wrapping_add(index.wrapping_mul(0x9E37_79B9_7F4A_7C15)) }
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-low + random
/// rotation output. Period 2^128, passes BigCrush; the main generator for
/// all algorithmic randomness in this crate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // odd stream selector
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Seed the generator; `stream` selects one of 2^127 independent
    /// sequences.
    pub fn new_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let mut sm2 = SplitMix64::new(stream ^ 0xDA3E_39CB_94B9_5BDB);
        let i0 = sm2.next_u64() as u128;
        let i1 = sm2.next_u64() as u128;
        let mut rng = Self {
            state: 0,
            inc: ((i0 << 64) | i1) | 1, // must be odd
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add((s0 << 64) | s1);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Seed with the default stream.
    pub fn new(seed: u64) -> Self {
        Self::new_stream(seed, 0)
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Next 32 bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift rejection
    /// (unbiased, one division in the slow path only).
    #[inline]
    pub fn gen_range(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64).wrapping_mul(bound as u64);
        let mut lo = m as u32;
        if lo < bound {
            let t = bound.wrapping_neg() % bound;
            while lo < t {
                x = self.next_u32();
                m = (x as u64).wrapping_mul(bound as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform in `[0, bound)` for `usize` bounds (≤ u32::MAX in practice).
    #[inline]
    pub fn gen_index(&mut self, bound: usize) -> usize {
        debug_assert!(bound <= u32::MAX as usize);
        self.gen_range(bound as u32) as usize
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)` with 24 bits of precision.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Bernoulli(p) coin flip.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box–Muller (pairs cached would complicate the
    /// borrow story; the generator is not on the request hot path).
    pub fn gen_normal(&mut self) -> f64 {
        loop {
            let u1 = self.gen_f64();
            if u1 > f64::MIN_POSITIVE {
                let u2 = self.gen_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Reservoir-sample `m` distinct items from `0..n` (order unspecified).
    pub fn sample_indices(&mut self, n: usize, m: usize, out: &mut Vec<u32>) {
        out.clear();
        if m >= n {
            out.extend(0..n as u32);
            return;
        }
        for i in 0..m {
            out.push(i as u32);
        }
        for i in m..n {
            let j = self.gen_index(i + 1);
            if j < m {
                out[j] = i as u32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_at_is_random_access_into_the_stream() {
        // `at(seed, i)` must produce exactly the (i+1)-th draw of the
        // sequentially-advanced generator — the property the parallel
        // build's counter-based edge coins rely on.
        let mut seq = SplitMix64::new(0xABCD);
        for i in 0..200u64 {
            let direct = SplitMix64::at(0xABCD, i).next_u64();
            assert_eq!(direct, seq.next_u64(), "index {i}");
        }
        // distinct indices give (near-)independent draws
        let a = SplitMix64::at(7, 1).next_u64();
        let b = SplitMix64::at(7, 2).next_u64();
        assert_ne!(a, b);
    }

    #[test]
    fn pcg_deterministic_and_stream_independent() {
        let mut a = Pcg64::new(7);
        let mut b = Pcg64::new(7);
        assert_eq!(
            (0..64).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..64).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
        let mut c = Pcg64::new_stream(7, 1);
        let mut d = Pcg64::new_stream(7, 2);
        let eq = (0..64).filter(|_| c.next_u64() == d.next_u64()).count();
        assert!(eq < 4, "streams should be (near-)disjoint, got {eq} collisions");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut rng = Pcg64::new(123);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.gen_range(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues reachable");
    }

    #[test]
    fn gen_f64_unit_interval_mean() {
        let mut rng = Pcg64::new(99);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = rng.gen_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} too far from 0.5");
    }

    #[test]
    fn gen_normal_moments() {
        let mut rng = Pcg64::new(2024);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.gen_normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(5);
        let mut xs: Vec<u32> = (0..1000).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(xs, (0..1000).collect::<Vec<_>>(), "astronomically unlikely identity");
    }

    #[test]
    fn sample_indices_distinct_in_range() {
        let mut rng = Pcg64::new(8);
        let mut out = Vec::new();
        rng.sample_indices(100, 20, &mut out);
        assert_eq!(out.len(), 20);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20, "indices must be distinct");
        assert!(out.iter().all(|&i| i < 100));

        // m >= n returns everything
        rng.sample_indices(5, 10, &mut out);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn gen_bool_probability() {
        let mut rng = Pcg64::new(17);
        let hits = (0..100_000).filter(|_| rng.gen_bool(0.25)).count();
        let p = hits as f64 / 100_000.0;
        assert!((p - 0.25).abs() < 0.01, "p {p}");
    }
}
