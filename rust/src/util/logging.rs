//! Minimal leveled logger writing to stderr (offline build: no `log`
//! crate backends available). Controlled by `KNNG_LOG` env var or
//! programmatically; default level is `Info`.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};

/// Log verbosity levels, ordered.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static INIT: std::sync::Once = std::sync::Once::new();

/// Parse a level name ("error".."trace"), case-insensitive.
pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" | "warning" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

/// Set the global level programmatically.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current global level, initializing from `KNNG_LOG` on first call.
pub fn level() -> Level {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("KNNG_LOG") {
            if let Some(l) = parse_level(&v) {
                LEVEL.store(l as u8, Ordering::Relaxed);
            }
        }
    });
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Emit a record if `lvl` is enabled.
pub fn log(lvl: Level, args: std::fmt::Arguments<'_>) {
    if lvl <= level() {
        let tag = match lvl {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[knng {tag}] {args}");
    }
}

/// `info!`-style macros.
#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("WARN"), Some(Level::Warn));
        assert_eq!(parse_level("Info"), Some(Level::Info));
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("trace"), Some(Level::Trace));
        assert_eq!(parse_level("bogus"), None);
    }

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn set_and_get() {
        let prev = level();
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(prev);
    }
}
