//! Deterministic fault injection for the serving runtime.
//!
//! The chaos suite (`tests/fault_injection.rs`) needs to make specific
//! workers panic, die, stall, or lose replies at specific moments —
//! *reproducibly*, so a failing run replays from its seed. This module
//! provides that as a process-global [`FaultPlan`]:
//!
//! * **Zero cost when disabled.** Every instrumented site guards on a
//!   single relaxed atomic load ([`check`] returns immediately when no
//!   plan is installed), so production serving pays one predictable
//!   branch per site and nothing else — no locks, no allocation.
//! * **Counter-based determinism.** Rules fire on the *n-th hit* of a
//!   `(site, index)` pair, or on a seeded coin computed as
//!   `SplitMix64::at(mix(seed, site, index), hit)` — the same
//!   counter-based discipline as the parallel build engine's edge
//!   coins, so a plan's behavior is a pure function of `(plan, call
//!   sequence)` and never of thread scheduling. Hit counters are kept
//!   per `(site, index)`, and the index is a *deterministic local
//!   identity* supplied by the call site (a shard slot, a worker id),
//!   so concurrent workers cannot race each other's counters.
//! * **Sites are data.** Instrumented code calls
//!   [`check`]`(site, index)` and interprets the returned
//!   [`FaultAction`]; the plan decides *whether*, the site decides
//!   *how*. The serving runtime's sites are named in [`site`].
//!
//! The `PALLAS_FAULT_SEED` environment knob ([`seed_from_env`]) lets CI
//! replay a failing chaos run from its logged seed.

use crate::util::SplitMix64;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Names of the instrumented sites in the serving runtime.
pub mod site {
    /// A pool worker at job receipt; `index` = worker id. `Die` here
    /// simulates thread death before any shard of the job is served.
    pub const WORKER_JOB: &str = "pool.worker.job";
    /// A pool worker about to run one shard's search; `index` = shard
    /// slot. `Panic` here is contained by the worker's `catch_unwind`.
    pub const WORKER_SEARCH: &str = "pool.worker.search";
    /// A pool worker about to post one shard's reply; `index` = shard
    /// slot. `Delay` stalls the reply, `Drop` loses it, `Die` kills the
    /// worker after the search but before the reply.
    pub const WORKER_REPLY: &str = "pool.worker.reply";
    /// [`WORKER_JOB`] for replica workers (replica ≥ 1); `index` =
    /// [`replica_index`](super::replica_index)`(replica, worker id)`.
    /// Replica 0 keeps answering to the legacy site, so R=1 chaos
    /// plans behave bit for bit — these sites exist so a plan can kill
    /// exactly one copy of a shard.
    pub const REPLICA_JOB: &str = "pool.replica.job";
    /// [`WORKER_SEARCH`] for replica workers; `index` =
    /// [`replica_index`](super::replica_index)`(replica, shard slot)`.
    pub const REPLICA_SEARCH: &str = "pool.replica.search";
    /// [`WORKER_REPLY`] for replica workers; `index` =
    /// [`replica_index`](super::replica_index)`(replica, shard slot)`.
    pub const REPLICA_REPLY: &str = "pool.replica.reply";
}

/// Deterministic site index for a replica-addressed fault: the replica
/// number in the high 32 bits, the local identity (worker id or shard
/// slot) in the low 32. Both the instrumented sites in the pool and
/// chaos plans build their indices through this one function, so they
/// can never disagree on the encoding.
pub fn replica_index(replica: usize, index: u64) -> u64 {
    ((replica as u64) << 32) | (index & 0xffff_ffff)
}

/// What an armed site does when its rule fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic inside the instrumented scope (the pool worker contains
    /// it with `catch_unwind` and answers with a typed failure).
    Panic,
    /// Simulate thread death: the instrumented loop returns, so the
    /// supervisor sees a dead worker and respawns it (budget
    /// permitting).
    Die,
    /// Stall for the given duration before proceeding (drives deadline
    /// expiry without wall-clock flakiness: the stall is much longer
    /// than the deadline under test).
    Delay(Duration),
    /// Lose the message the site was about to send (a reply that never
    /// arrives, from a worker that stays alive).
    Drop,
}

/// When a rule fires, evaluated against the per-`(site, index)` hit
/// counter (0-based).
#[derive(Debug, Clone, Copy)]
pub enum Trigger {
    /// Fire on exactly the `n`-th hit.
    Nth(u64),
    /// Fire on every hit.
    Always,
    /// Fire when the counter-based coin for this hit lands under
    /// `prob`: draw = `SplitMix64::at(mix(seed, site, index), hit)`.
    /// Deterministic per (seed, site, index, hit); independent of
    /// scheduling.
    Seeded {
        /// Chaos seed (log it; `PALLAS_FAULT_SEED` replays it).
        seed: u64,
        /// Probability in `[0, 1]` that a hit fires.
        prob: f64,
    },
}

#[derive(Debug, Clone)]
struct Rule {
    site: &'static str,
    /// `None` matches every index (counters stay per-index).
    index: Option<u64>,
    trigger: Trigger,
    action: FaultAction,
}

/// A set of injection rules, installed process-wide with [`install`].
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    rules: Vec<Rule>,
}

impl FaultPlan {
    /// An empty plan (no rule ever fires).
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a rule: at `site` (for `index`, or every index when `None`),
    /// perform `action` when `trigger` fires.
    pub fn rule(
        mut self,
        site: &'static str,
        index: Option<u64>,
        trigger: Trigger,
        action: FaultAction,
    ) -> Self {
        self.rules.push(Rule { site, index, trigger, action });
        self
    }

    /// Panic on the `nth` hit of `(site, index)`.
    pub fn panic_at(self, site: &'static str, index: u64, nth: u64) -> Self {
        self.rule(site, Some(index), Trigger::Nth(nth), FaultAction::Panic)
    }

    /// Kill the worker on every hit of `(site, index)` — with a
    /// bounded respawn budget this drives the shard permanently dead.
    pub fn die_always(self, site: &'static str, index: u64) -> Self {
        self.rule(site, Some(index), Trigger::Always, FaultAction::Die)
    }

    /// Stall every hit of `(site, index)` by `delay`.
    pub fn delay_always(self, site: &'static str, index: u64, delay: Duration) -> Self {
        self.rule(site, Some(index), Trigger::Always, FaultAction::Delay(delay))
    }

    /// Lose the message on the `nth` hit of `(site, index)`.
    pub fn drop_at(self, site: &'static str, index: u64, nth: u64) -> Self {
        self.rule(site, Some(index), Trigger::Nth(nth), FaultAction::Drop)
    }
}

struct Armed {
    plan: FaultPlan,
    hits: HashMap<(&'static str, u64), u64>,
    injected: u64,
}

/// The disabled-path guard: one relaxed load per instrumented site.
static ENABLED: AtomicBool = AtomicBool::new(false);
static ARMED: Mutex<Option<Armed>> = Mutex::new(None);

fn armed_lock() -> std::sync::MutexGuard<'static, Option<Armed>> {
    // a panicking instrumented thread may poison this lock by design
    // (Panic actions unwind through arbitrary code); the map itself is
    // always in a consistent state between operations, so recover
    ARMED.lock().unwrap_or_else(|p| p.into_inner())
}

/// Install `plan` process-wide, resetting all hit counters. Injection
/// stays active until [`clear`]. Tests sharing a process must
/// serialize installation (the chaos suite holds a lock per test).
pub fn install(plan: FaultPlan) {
    let mut guard = armed_lock();
    *guard = Some(Armed { plan, hits: HashMap::new(), injected: 0 });
    ENABLED.store(true, Ordering::SeqCst);
}

/// Remove the installed plan; [`check`] returns to its zero-cost path.
pub fn clear() {
    ENABLED.store(false, Ordering::SeqCst);
    *armed_lock() = None;
}

/// True while a plan is installed.
pub fn active() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Total faults fired since the current plan was installed.
pub fn injected() -> u64 {
    armed_lock().as_ref().map_or(0, |a| a.injected)
}

/// The instrumentation hook: did a rule fire for this hit of
/// `(site, index)`? Sites pass a deterministic local identity as
/// `index` (shard slot, worker id) so hit counters never race across
/// threads. Returns `None` immediately — one relaxed atomic load —
/// when no plan is installed.
#[inline]
pub fn check(site: &'static str, index: u64) -> Option<FaultAction> {
    if !ENABLED.load(Ordering::Relaxed) {
        return None;
    }
    check_armed(site, index)
}

#[cold]
fn check_armed(site: &'static str, index: u64) -> Option<FaultAction> {
    let mut guard = armed_lock();
    let armed = guard.as_mut()?;
    let hit = {
        let counter = armed.hits.entry((site, index)).or_insert(0);
        let hit = *counter;
        *counter += 1;
        hit
    };
    for rule in &armed.plan.rules {
        if rule.site != site || rule.index.is_some_and(|i| i != index) {
            continue;
        }
        let fired = match rule.trigger {
            Trigger::Nth(n) => hit == n,
            Trigger::Always => true,
            Trigger::Seeded { seed, prob } => coin(seed, site, index, hit) < prob,
        };
        if fired {
            armed.injected += 1;
            return Some(rule.action);
        }
    }
    None
}

/// Uniform draw in `[0, 1)` for hit `hit` of `(site, index)` under
/// `seed` — pure function of its arguments (counter-based, like the
/// build engine's edge coins).
fn coin(seed: u64, site: &str, index: u64, hit: u64) -> f64 {
    let mut fnv = crate::graph::io::Fnv::new();
    fnv.update(site.as_bytes());
    fnv.update(&index.to_le_bytes());
    let draw = SplitMix64::at(seed ^ fnv.0, hit).next_u64();
    (draw >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// The chaos seed: `PALLAS_FAULT_SEED` when set and parseable, else
/// `default`. The chaos suite logs the seed it runs with so a CI
/// failure is replayable.
pub fn seed_from_env(default: u64) -> u64 {
    match std::env::var("PALLAS_FAULT_SEED") {
        Ok(s) => s.trim().parse().unwrap_or(default),
        Err(_) => default,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The plan/counters are process-global; unit tests here serialize
    // on their own lock (the integration chaos suite does the same).
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn locked() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn disabled_is_none_and_cheap() {
        let _g = locked();
        clear();
        assert!(!active());
        assert_eq!(check(site::WORKER_SEARCH, 0), None);
        assert_eq!(injected(), 0);
    }

    #[test]
    fn nth_fires_exactly_once_per_index() {
        let _g = locked();
        install(FaultPlan::new().panic_at(site::WORKER_SEARCH, 2, 1));
        // index 2: hits 0, 1, 2 → only hit 1 fires
        assert_eq!(check(site::WORKER_SEARCH, 2), None);
        assert_eq!(check(site::WORKER_SEARCH, 2), Some(FaultAction::Panic));
        assert_eq!(check(site::WORKER_SEARCH, 2), None);
        // other indexes and sites never fire
        assert_eq!(check(site::WORKER_SEARCH, 3), None);
        assert_eq!(check(site::WORKER_SEARCH, 3), None);
        assert_eq!(check(site::WORKER_REPLY, 2), None);
        assert_eq!(injected(), 1);
        clear();
    }

    #[test]
    fn always_fires_and_reinstall_resets_counters() {
        let _g = locked();
        install(FaultPlan::new().die_always(site::WORKER_JOB, 0));
        assert_eq!(check(site::WORKER_JOB, 0), Some(FaultAction::Die));
        assert_eq!(check(site::WORKER_JOB, 0), Some(FaultAction::Die));
        assert_eq!(check(site::WORKER_JOB, 1), None);
        install(FaultPlan::new().panic_at(site::WORKER_JOB, 0, 0));
        // fresh counters: hit 0 again
        assert_eq!(check(site::WORKER_JOB, 0), Some(FaultAction::Panic));
        assert_eq!(injected(), 1, "reinstall resets the injected count");
        clear();
    }

    #[test]
    fn seeded_trigger_is_deterministic() {
        let _g = locked();
        let run = |seed: u64| -> Vec<bool> {
            install(FaultPlan::new().rule(
                site::WORKER_REPLY,
                None,
                Trigger::Seeded { seed, prob: 0.3 },
                FaultAction::Drop,
            ));
            let fired: Vec<bool> =
                (0..64).map(|i| check(site::WORKER_REPLY, i % 4).is_some()).collect();
            clear();
            fired
        };
        let a = run(7);
        let b = run(7);
        assert_eq!(a, b, "same seed, same call sequence, same faults");
        assert!(a.iter().any(|&f| f), "prob 0.3 over 64 hits should fire");
        assert!(!a.iter().all(|&f| f), "prob 0.3 should not always fire");
        let c = run(8);
        assert_ne!(a, c, "a different seed gives a different schedule");
    }

    #[test]
    fn coin_is_counter_based() {
        // pure function of (seed, site, index, hit) — no hidden state
        assert_eq!(coin(1, "s", 2, 3), coin(1, "s", 2, 3));
        assert_ne!(coin(1, "s", 2, 3), coin(1, "s", 2, 4));
        assert_ne!(coin(1, "s", 2, 3), coin(1, "s", 3, 3));
        assert_ne!(coin(1, "s", 2, 3), coin(2, "s", 2, 3));
        let c = coin(99, "x", 0, 0);
        assert!((0.0..1.0).contains(&c));
    }

    #[test]
    fn replica_index_separates_replicas_and_keeps_local_identity() {
        assert_eq!(replica_index(0, 3), 3, "replica 0 is the identity encoding");
        assert_eq!(replica_index(1, 3), (1 << 32) | 3);
        assert_ne!(replica_index(1, 3), replica_index(2, 3));
        assert_ne!(replica_index(1, 3), replica_index(1, 4));
        let _g = locked();
        // a rule armed for replica 1's shard 0 must not fire for
        // replica 2's shard 0 or for the legacy (replica-0) site
        install(FaultPlan::new().die_always(site::REPLICA_JOB, replica_index(1, 0)));
        assert_eq!(check(site::REPLICA_JOB, replica_index(1, 0)), Some(FaultAction::Die));
        assert_eq!(check(site::REPLICA_JOB, replica_index(2, 0)), None);
        assert_eq!(check(site::WORKER_JOB, 0), None);
        clear();
    }

    #[test]
    fn seed_from_env_parses_or_defaults() {
        let _g = locked();
        // no env set in the unit harness: default wins
        if std::env::var("PALLAS_FAULT_SEED").is_err() {
            assert_eq!(seed_from_env(42), 42);
        }
    }
}
