//! Property-check runner and input generator.

use crate::util::rng::Pcg64;
use std::ops::Range;

/// Runner configuration.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case `i` uses stream `i`.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 100, seed: 0x9E3779B97F4A7C15 }
    }
}

impl Config {
    /// Default config with a custom case count.
    pub fn cases(cases: usize) -> Self {
        Self { cases, ..Self::default() }
    }
    /// Override the base seed (for reproducing failures).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Structured-input generator handed to each property case.
pub struct Gen {
    rng: Pcg64,
    /// Size hint in `[0,1]`: early cases are small, later cases larger,
    /// so failures tend to be found at minimal sizes first.
    pub size: f64,
}

impl Gen {
    /// Standalone generator for ad-hoc use in unit tests (full size hint).
    pub fn new_for_test(seed: u64) -> Self {
        Self { rng: Pcg64::new(seed), size: 1.0 }
    }

    fn new(seed: u64, case: u64, cases: u64) -> Self {
        Self {
            rng: Pcg64::new_stream(seed, case),
            size: if cases <= 1 { 1.0 } else { (case as f64 + 1.0) / cases as f64 },
        }
    }

    /// Scale a maximum by the current size hint (≥ the range start).
    fn sized(&self, max: usize) -> usize {
        ((max as f64) * self.size).ceil() as usize
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Uniform usize in `range` (end-exclusive, nonempty).
    pub fn usize_in(&mut self, range: Range<usize>) -> usize {
        assert!(range.start < range.end, "empty range");
        range.start + self.rng.gen_index(range.end - range.start)
    }

    /// Size-scaled length in `range`: grows with case index.
    pub fn len_in(&mut self, range: Range<usize>) -> usize {
        assert!(range.start < range.end);
        let hi = range.start + self.sized(range.end - range.start - 1).max(1);
        self.usize_in(range.start..hi.min(range.end).max(range.start + 1))
    }

    pub fn u32_in(&mut self, range: Range<u32>) -> u32 {
        assert!(range.start < range.end);
        range.start + self.rng.gen_range(range.end - range.start)
    }

    pub fn f32_unit(&mut self) -> f32 {
        self.rng.gen_f32()
    }

    pub fn f64_unit(&mut self) -> f64 {
        self.rng.gen_f64()
    }

    /// f32 in [-scale, scale).
    pub fn f32_sym(&mut self, scale: f32) -> f32 {
        (self.rng.gen_f32() * 2.0 - 1.0) * scale
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.gen_bool(p)
    }

    /// Vector of u32 drawn from `range`, length ≤ `max_len` (size-scaled).
    pub fn vec_u32(&mut self, range: Range<u32>, max_len: usize) -> Vec<u32> {
        let len = self.len_in(0..max_len + 1);
        (0..len).map(|_| self.u32_in(range.clone())).collect()
    }

    /// Vector of f32 in [-scale, scale), exact length.
    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_sym(scale)).collect()
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.rng.shuffle(&mut p);
        p
    }

    /// Borrow the underlying PRNG for ad-hoc draws.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `prop` over `cfg.cases` generated inputs; panics with the failing
/// case's seed/stream on the first failure.
pub fn check(cfg: Config, name: &str, mut prop: impl FnMut(&mut Gen) -> bool) {
    for case in 0..cfg.cases as u64 {
        let mut g = Gen::new(cfg.seed, case, cfg.cases as u64);
        if !prop(&mut g) {
            panic!(
                "property `{name}` failed at case {case} (reproduce with \
                 Config {{ cases: 1, seed: {} }} + stream {case})",
                cfg.seed
            );
        }
    }
}

/// Like [`check`] but the property returns `Result` with a message.
pub fn check_result(
    cfg: Config,
    name: &str,
    mut prop: impl FnMut(&mut Gen) -> Result<(), String>,
) {
    for case in 0..cfg.cases as u64 {
        let mut g = Gen::new(cfg.seed, case, cfg.cases as u64);
        if let Err(msg) = prop(&mut g) {
            panic!("property `{name}` failed at case {case}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(Config::cases(50), "count", |_| {
            count += 1;
            true
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `always-false` failed")]
    fn failing_property_panics() {
        check(Config::cases(10), "always-false", |_| false);
    }

    #[test]
    fn generator_ranges_respected() {
        check(Config::cases(200), "ranges", |g| {
            let a = g.usize_in(3..10);
            let b = g.u32_in(100..101);
            let v = g.vec_u32(0..5, 20);
            (3..10).contains(&a) && b == 100 && v.len() <= 20 && v.iter().all(|&x| x < 5)
        });
    }

    #[test]
    fn sizes_grow_with_case_index() {
        let mut lens = Vec::new();
        check(Config::cases(100), "sizes", |g| {
            lens.push(g.len_in(0..1000));
            true
        });
        let early: f64 = lens[..20].iter().sum::<usize>() as f64 / 20.0;
        let late: f64 = lens[80..].iter().sum::<usize>() as f64 / 20.0;
        assert!(late > early, "late {late} should exceed early {early}");
    }

    #[test]
    fn permutation_is_valid() {
        check(Config::cases(50), "perm", |g| {
            let n = g.usize_in(1..200);
            let p = g.permutation(n);
            let mut s = p.clone();
            s.sort_unstable();
            s == (0..n as u32).collect::<Vec<_>>()
        });
    }

    #[test]
    fn deterministic_per_seed() {
        let mut first = Vec::new();
        check(Config::cases(10).with_seed(7), "collect1", |g| {
            first.push(g.u64());
            true
        });
        let mut second = Vec::new();
        check(Config::cases(10).with_seed(7), "collect2", |g| {
            second.push(g.u64());
            true
        });
        assert_eq!(first, second);
    }

    #[test]
    fn check_result_reports_message() {
        let r = std::panic::catch_unwind(|| {
            check_result(Config::cases(5), "msg", |_| Err("specific detail".to_string()));
        });
        let err = r.unwrap_err();
        let s = err.downcast_ref::<String>().unwrap();
        assert!(s.contains("specific detail"));
    }
}
