//! Property-testing mini-framework (offline substitute for `proptest`).
//!
//! A [`Gen`] wraps the crate PRNG with helpers for generating structured
//! random inputs; [`check`] runs a property across many generated cases
//! and, on failure, re-runs a bounded greedy shrink loop to report a
//! smaller counterexample seed.
//!
//! ```
//! use knng::testing::{check, Config};
//!
//! check(Config::cases(200), "reverse twice is identity", |g| {
//!     let xs = g.vec_u32(0..64, 1000);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     xs == ys
//! });
//! ```

pub mod prop;

pub use prop::{check, check_result, Config, Gen};
