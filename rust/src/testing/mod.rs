//! Property-testing mini-framework (offline substitute for `proptest`).
//!
//! A [`Gen`] wraps the crate PRNG with helpers for generating structured
//! random inputs; [`check`] runs a property across many generated cases
//! and, on failure, re-runs a bounded greedy shrink loop to report a
//! smaller counterexample seed.
//!
//! ```
//! use knng::testing::{check, Config};
//!
//! check(Config::cases(200), "reverse twice is identity", |g| {
//!     let xs = g.vec_u32(0..64, 1000);
//!     let mut ys = xs.clone();
//!     ys.reverse();
//!     ys.reverse();
//!     xs == ys
//! });
//! ```

pub mod faults;
pub mod prop;

pub use prop::{check, check_result, Config, Gen};

/// Assert two per-query result sets are **bitwise** identical: same
/// arity, same ids, same distance *bits* per rank. The one definition
/// of the serving layer's bit-equality acceptance check, shared by the
/// serve-stack unit/integration tests and `bench_query_throughput`
/// (equality on `f32` values would let `-0.0`/`0.0` or NaN drift pass).
pub fn assert_neighbors_bitwise_eq(
    a: &[Vec<crate::api::Neighbor>],
    b: &[Vec<crate::api::Neighbor>],
    ctx: &str,
) {
    assert_eq!(a.len(), b.len(), "{ctx}: result arity");
    for (qi, (ra, rb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ra.len(), rb.len(), "{ctx}: query {qi} arity");
        for (j, (na, nb)) in ra.iter().zip(rb).enumerate() {
            assert_eq!(na.id, nb.id, "{ctx}: query {qi} rank {j} id");
            assert_eq!(
                na.dist.to_bits(),
                nb.dist.to_bits(),
                "{ctx}: query {qi} rank {j} distance bits"
            );
        }
    }
}
