//! The write-ahead log: every mutation is made durable here *before*
//! it touches the in-memory delta, so a crash at any instant loses
//! nothing that was acknowledged.
//!
//! Record layout (little-endian):
//!
//! ```text
//! len   u32   body length in bytes
//! body  len B op u8 (1 = insert, 2 = delete) · ext_id u32
//!             · insert only: dim u32 · dim × f32 row
//! crc   u64   FNV-1a over the body
//! ```
//!
//! Replay walks records from the front and stops at the first
//! incomplete or checksum-failing one — a torn tail from a crash
//! mid-append — then truncates the file back to the last good record
//! so the next append starts from a clean boundary. Corruption is
//! never an error at open: the log's job is to recover what provably
//! committed, and a record that fails its checksum (and everything
//! after it, which a torn write makes unordered) provably did not.
//!
//! Durability is configurable via [`WalConfig::group_commit_us`]: `0`
//! (the default) fsyncs every append before acknowledging it; a
//! positive window batches fsyncs so a burst of inserts pays for one
//! `fdatasync` per window instead of one per record. Under group
//! commit a crash may lose up to one window of acknowledged-but-
//! unsynced records, but never *corrupts* anything: every record is
//! still checksummed and length-framed, so replay lands on the last
//! intact record boundary exactly as in the fsync-per-append mode.

use crate::graph::io::Fnv;
use anyhow::{bail, Context, Result};
use std::fs::{File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

const OP_INSERT: u8 = 1;
const OP_DELETE: u8 = 2;
/// Upper bound on one record body — a row would need a ~4M-dim vector
/// to hit this, so anything larger is corruption, not data.
const MAX_BODY: usize = 16 << 20;

/// One logged mutation.
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// Insert (or overwrite) the row for external id `id`.
    Insert { id: u32, row: Vec<f32> },
    /// Delete external id `id`.
    Delete { id: u32 },
}

impl WalRecord {
    fn encode(&self) -> Vec<u8> {
        let mut body = Vec::new();
        match self {
            WalRecord::Insert { id, row } => {
                body.push(OP_INSERT);
                body.extend_from_slice(&id.to_le_bytes());
                body.extend_from_slice(&(row.len() as u32).to_le_bytes());
                for &x in row {
                    body.extend_from_slice(&x.to_le_bytes());
                }
            }
            WalRecord::Delete { id } => {
                body.push(OP_DELETE);
                body.extend_from_slice(&id.to_le_bytes());
            }
        }
        let mut crc = Fnv::new();
        crc.update(&body);
        let mut out = Vec::with_capacity(12 + body.len());
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(&body);
        out.extend_from_slice(&crc.0.to_le_bytes());
        out
    }

    /// Decode one body (already checksum-verified). `None` = malformed.
    fn decode(body: &[u8]) -> Option<Self> {
        let (&op, rest) = body.split_first()?;
        match op {
            OP_INSERT => {
                if rest.len() < 8 {
                    return None;
                }
                let id = u32::from_le_bytes(rest[..4].try_into().unwrap());
                let dim = u32::from_le_bytes(rest[4..8].try_into().unwrap()) as usize;
                let tail = &rest[8..];
                if tail.len() != dim * 4 {
                    return None;
                }
                let row = tail
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Some(WalRecord::Insert { id, row })
            }
            OP_DELETE => {
                if rest.len() != 4 {
                    return None;
                }
                let id = u32::from_le_bytes(rest.try_into().unwrap());
                Some(WalRecord::Delete { id })
            }
            _ => None,
        }
    }
}

/// Durability knobs for the log.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalConfig {
    /// Group-commit window in microseconds. `0` (the default) fsyncs
    /// every append before returning. A positive value batches: an
    /// append within this window of the last fsync only buffers its
    /// bytes (via `write_all`, so they are visible to readers and to
    /// replay immediately); the first append *past* the window fsyncs
    /// everything accumulated. A crash can lose at most one window of
    /// acknowledged records — torn-tail recovery semantics are
    /// unchanged.
    pub group_commit_us: u64,
}

impl Default for WalConfig {
    fn default() -> Self {
        Self { group_commit_us: 0 }
    }
}

/// The open log file. Created empty when absent. With the default
/// config every append flushes and fsyncs before returning so an
/// acknowledged mutation survives a crash; see
/// [`WalConfig::group_commit_us`] for batched-fsync durability.
pub struct Wal {
    file: File,
    path: PathBuf,
    len: u64,
    cfg: WalConfig,
    /// Bytes written since the last fdatasync.
    dirty: bool,
    /// When the current group-commit window opened (the last sync).
    last_sync: Instant,
}

impl Wal {
    /// Open (or create) the log at `path` and replay every intact
    /// record. A torn or corrupt tail is truncated away with a
    /// warning, never an error.
    pub fn open(path: &Path) -> Result<(Self, Vec<WalRecord>)> {
        Self::open_with(path, WalConfig::default())
    }

    /// [`open`](Self::open) with explicit durability knobs.
    pub fn open_with(path: &Path, cfg: WalConfig) -> Result<(Self, Vec<WalRecord>)> {
        let mut file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)
            .with_context(|| format!("opening WAL {}", path.display()))?;
        let mut bytes = Vec::new();
        file.read_to_end(&mut bytes).context("reading WAL")?;

        let mut records = Vec::new();
        let mut good_end = 0usize;
        let mut off = 0usize;
        loop {
            if off + 4 > bytes.len() {
                break; // torn inside the length prefix (or clean EOF)
            }
            let body_len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
            if body_len == 0 || body_len > MAX_BODY {
                break; // implausible length — corrupt from here on
            }
            let body_start = off + 4;
            let crc_start = body_start + body_len;
            if crc_start + 8 > bytes.len() {
                break; // torn inside the body or checksum
            }
            let body = &bytes[body_start..crc_start];
            let mut crc = Fnv::new();
            crc.update(body);
            if u64::from_le_bytes(bytes[crc_start..crc_start + 8].try_into().unwrap()) != crc.0 {
                break; // checksum mismatch — record never fully committed
            }
            let Some(rec) = WalRecord::decode(body) else {
                break; // checksummed but structurally invalid
            };
            records.push(rec);
            off = crc_start + 8;
            good_end = off;
        }
        if good_end < bytes.len() {
            crate::log_warn!(
                "WAL {}: dropping {} torn/corrupt byte(s) after {} intact record(s)",
                path.display(),
                bytes.len() - good_end,
                records.len()
            );
            file.set_len(good_end as u64).context("truncating torn WAL tail")?;
        }
        file.seek(SeekFrom::Start(good_end as u64))?;
        Ok((
            Self {
                file,
                path: path.to_path_buf(),
                len: good_end as u64,
                cfg,
                dirty: false,
                last_sync: Instant::now(),
            },
            records,
        ))
    }

    /// Append one record: write + flush, then fdatasync per the
    /// group-commit policy (immediately by default; at window
    /// boundaries under [`WalConfig::group_commit_us`]).
    pub fn append(&mut self, rec: &WalRecord) -> Result<()> {
        if let WalRecord::Insert { row, .. } = rec {
            if row.len() * 4 + 9 > MAX_BODY {
                bail!("row too large for a WAL record ({} dims)", row.len());
            }
        }
        let frame = rec.encode();
        self.file.write_all(&frame).context("appending WAL record")?;
        self.len += frame.len() as u64;
        self.dirty = true;
        if self.cfg.group_commit_us == 0
            || self.last_sync.elapsed() >= Duration::from_micros(self.cfg.group_commit_us)
        {
            self.sync()?;
        }
        Ok(())
    }

    /// Force any buffered appends to stable storage now (a no-op when
    /// nothing is pending). Closes the current group-commit window.
    pub fn sync(&mut self) -> Result<()> {
        if self.dirty {
            self.file.sync_data().context("syncing WAL")?;
            self.dirty = false;
        }
        self.last_sync = Instant::now();
        Ok(())
    }

    /// Whether appends are buffered ahead of their fdatasync.
    pub fn has_pending_sync(&self) -> bool {
        self.dirty
    }

    /// Drop every record (after a compaction has folded them into the
    /// base segment).
    pub fn reset(&mut self) -> Result<()> {
        self.file.set_len(0).context("resetting WAL")?;
        self.file.seek(SeekFrom::Start(0))?;
        self.file.sync_data()?;
        self.len = 0;
        self.dirty = false;
        self.last_sync = Instant::now();
        Ok(())
    }

    /// Current log size in bytes.
    pub fn len_bytes(&self) -> u64 {
        self.len
    }

    /// Where the log lives.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for Wal {
    /// Best-effort close of an open group-commit window: a clean
    /// shutdown loses nothing even when the last window never filled.
    fn drop(&mut self) {
        if self.dirty {
            let _ = self.file.sync_data();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("knng_store_wal_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(name);
        let _ = std::fs::remove_file(&p);
        p
    }

    fn sample() -> Vec<WalRecord> {
        vec![
            WalRecord::Insert { id: 7, row: vec![1.0, -2.5, 3.25] },
            WalRecord::Delete { id: 3 },
            WalRecord::Insert { id: 8, row: vec![0.0; 17] },
            WalRecord::Delete { id: 7 },
        ]
    }

    #[test]
    fn roundtrip_replays_in_order() {
        let path = tmp("rt.wal");
        let (mut wal, replayed) = Wal::open(&path).unwrap();
        assert!(replayed.is_empty());
        for r in sample() {
            wal.append(&r).unwrap();
        }
        drop(wal);
        let (wal, replayed) = Wal::open(&path).unwrap();
        assert_eq!(replayed, sample());
        assert_eq!(wal.len_bytes(), std::fs::metadata(&path).unwrap().len());
    }

    #[test]
    fn torn_tail_is_truncated_at_every_cut() {
        let full = tmp("torn_src.wal");
        let (mut wal, _) = Wal::open(&full).unwrap();
        for r in sample() {
            wal.append(&r).unwrap();
        }
        drop(wal);
        let bytes = std::fs::read(&full).unwrap();
        let last_start = {
            // sum of the first three frame lengths
            sample()[..3].iter().map(|r| r.encode().len()).sum::<usize>()
        };
        // cut anywhere inside the fourth record: the first three survive
        for cut in last_start + 1..bytes.len() {
            let path = tmp("torn.wal");
            std::fs::write(&path, &bytes[..cut]).unwrap();
            let (mut wal, replayed) = Wal::open(&path).unwrap();
            assert_eq!(replayed, sample()[..3], "cut at {cut}");
            assert_eq!(
                std::fs::metadata(&path).unwrap().len(),
                last_start as u64,
                "cut at {cut} must truncate back to the last good record"
            );
            // and the log accepts appends again from the clean boundary
            wal.append(&WalRecord::Delete { id: 99 }).unwrap();
            drop(wal);
            let (_, replayed) = Wal::open(&path).unwrap();
            assert_eq!(replayed.len(), 4);
            assert_eq!(replayed[3], WalRecord::Delete { id: 99 });
        }
    }

    #[test]
    fn corrupt_record_drops_it_and_everything_after() {
        let path = tmp("corrupt.wal");
        let (mut wal, _) = Wal::open(&path).unwrap();
        for r in sample() {
            wal.append(&r).unwrap();
        }
        drop(wal);
        let mut bytes = std::fs::read(&path).unwrap();
        let second_start = sample()[0].encode().len();
        bytes[second_start + 6] ^= 0x40; // flip a bit in record 2's body
        std::fs::write(&path, &bytes).unwrap();
        let (_, replayed) = Wal::open(&path).unwrap();
        assert_eq!(replayed, sample()[..1], "only the record before the corruption survives");
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            second_start as u64
        );
    }

    #[test]
    fn implausible_length_prefix_is_treated_as_torn() {
        let path = tmp("hugelen.wal");
        let (mut wal, _) = Wal::open(&path).unwrap();
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        drop(wal);
        let mut bytes = std::fs::read(&path).unwrap();
        let good = bytes.len();
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // 4 GiB "record"
        bytes.extend_from_slice(&[0xAA; 32]);
        std::fs::write(&path, &bytes).unwrap();
        let (_, replayed) = Wal::open(&path).unwrap();
        assert_eq!(replayed.len(), 1);
        assert_eq!(std::fs::metadata(&path).unwrap().len(), good as u64);
    }

    #[test]
    fn group_commit_defers_fsync_but_replays_identically() {
        let path = tmp("group.wal");
        // a one-second window: nothing in this test outlasts it, so
        // every append after the first stays buffered
        let cfg = WalConfig { group_commit_us: 1_000_000 };
        let (mut wal, replayed) = Wal::open_with(&path, cfg).unwrap();
        assert!(replayed.is_empty());
        for r in sample() {
            wal.append(&r).unwrap();
        }
        assert!(wal.has_pending_sync(), "appends inside the window must defer their fsync");
        // the bytes are already written (page cache), so a re-open —
        // crash or not — replays every record
        let (other, replayed) = Wal::open_with(&path, cfg).unwrap();
        assert_eq!(replayed, sample());
        drop(other);
        // an explicit sync closes the window
        wal.sync().unwrap();
        assert!(!wal.has_pending_sync());
        drop(wal);
        let (_, replayed) = Wal::open_with(&path, cfg).unwrap();
        assert_eq!(replayed, sample());
    }

    #[test]
    fn group_commit_crash_replay_lands_on_a_record_boundary() {
        // build a group-committed log, then simulate a crash by
        // tearing the file at every byte position inside the last
        // record: replay must land exactly on the previous record
        // boundary, same contract as the fsync-per-append mode
        let full = tmp("group_torn_src.wal");
        let cfg = WalConfig { group_commit_us: 1_000_000 };
        let (mut wal, _) = Wal::open_with(&full, cfg).unwrap();
        for r in sample() {
            wal.append(&r).unwrap();
        }
        wal.sync().unwrap();
        drop(wal);
        let bytes = std::fs::read(&full).unwrap();
        let last_start = sample()[..3].iter().map(|r| r.encode().len()).sum::<usize>();
        for cut in last_start + 1..bytes.len() {
            let path = tmp("group_torn.wal");
            std::fs::write(&path, &bytes[..cut]).unwrap();
            let (mut wal, replayed) = Wal::open_with(&path, cfg).unwrap();
            assert_eq!(replayed, sample()[..3], "cut at {cut}");
            assert_eq!(
                std::fs::metadata(&path).unwrap().len(),
                last_start as u64,
                "cut at {cut} must truncate back to the last good record"
            );
            // appends resume cleanly from the truncated boundary
            wal.append(&WalRecord::Delete { id: 99 }).unwrap();
            wal.sync().unwrap();
            drop(wal);
            let (_, replayed) = Wal::open_with(&path, cfg).unwrap();
            assert_eq!(replayed.len(), 4);
            assert_eq!(replayed[3], WalRecord::Delete { id: 99 });
        }
    }

    #[test]
    fn zero_window_syncs_every_append() {
        let path = tmp("sync_each.wal");
        let (mut wal, _) = Wal::open_with(&path, WalConfig::default()).unwrap();
        for r in sample() {
            wal.append(&r).unwrap();
            assert!(!wal.has_pending_sync(), "default config fsyncs per append");
        }
    }

    #[test]
    fn reset_clears_the_log() {
        let path = tmp("reset.wal");
        let (mut wal, _) = Wal::open(&path).unwrap();
        for r in sample() {
            wal.append(&r).unwrap();
        }
        wal.reset().unwrap();
        assert_eq!(wal.len_bytes(), 0);
        wal.append(&WalRecord::Insert { id: 42, row: vec![5.0] }).unwrap();
        drop(wal);
        let (_, replayed) = Wal::open(&path).unwrap();
        assert_eq!(replayed, vec![WalRecord::Insert { id: 42, row: vec![5.0] }]);
    }
}
