//! The `KNNIv2` segment format: the storage engine's on-disk layout,
//! designed so a mapped file *is* the serving structure — no parse
//! step, no heap copy, no graph rebuild.
//!
//! Layout (little-endian; every section start is 64-byte aligned, with
//! zero padding between sections included in the checksum):
//!
//! ```text
//! off 0    magic     8 B  "KNNIv2\0\0"
//! off 8    n         8 B  u64  points
//! off 16   dim       8 B  u64  logical dimensionality
//! off 24   k         8 B  u64  neighbors per node
//! off 32   flags     8 B  u64  bit 1: norms present · bit 2: centroids
//!                          present · bit 3: idmap present
//!                          bits 8–15: norm lane count · bits 16–31:
//!                          centroid count
//! off 40   generation 8 B u64  compaction generation
//! off 48   dim_pad   8 B  u64  padded row width (must equal 8⌈dim/8⌉)
//! off 56   reserved  8 B  zero
//! off 64   params   64 B  build parameters (same block as KNNIv1)
//! off 128  ids       n·k·4 B    u32 neighbor ids, heap order
//!  ↑64     dists     n·k·4 B    f32 neighbor distances, heap order
//!  ↑64     data      n·dim_pad·4 B  f32 PADDED rows (tail lanes zero)
//!  ↑64     norms     n·4 B      f32 ‖row‖²            (iff bit 1)
//!  ↑64     idmap     n·4 B      u32 working → external (iff bit 3)
//!  ↑64     centroids c·dim_pad·4 B  f32 padded rows    (iff bit 2)
//!          crc       8 B  FNV-1a over everything above (padding incl.)
//! ```
//!
//! The two structural differences from `KNNIv1` are exactly what
//! zero-copy needs: **data rows are stored padded** to `dim_pad` (so
//! the mapped section satisfies [`AlignedMatrix`]'s layout as-is), and
//! **sections are 64-byte aligned** (so every section pointer meets the
//! kernels' alignment requirements straight out of the mapping). The
//! σ/σ⁻¹ pair of v1 is replaced by one `idmap` (working → external id):
//! after deletes and compactions external ids are sparse, so an inverse
//! table no longer makes sense.
//!
//! The format is little-endian on disk and read by reinterpretation,
//! so big-endian targets are rejected at open (the portable fallback
//! is the `KNNIv1` heap loader, which parses byte-by-byte).

use super::bytes::{SegmentBytes, StoreMode};
use crate::dataset::matrix::{LANE_PAD, ROW_ALIGN};
use crate::dataset::AlignedMatrix;
use crate::graph::heap::EMPTY_ID;
use crate::graph::io::Fnv;
use crate::nndescent::Params;
use crate::search::beam::IndexView;
use crate::search::{BatchStats, QueryStats, SearchParams, SearchScratch};
use crate::util::round_up;
use anyhow::{bail, Context, Result};
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::Arc;

/// Magic of the v2 segment format (recognized by the v1 loader for a
/// helpful cross-format error).
pub(crate) const MAGIC_V2: &[u8; 8] = b"KNNIv2\0\0";

const FLAG_NORMS: u64 = 2;
const FLAG_CENTROIDS: u64 = 4;
const FLAG_IDMAP: u64 = 8;
const FLAG_NORM_LANES_SHIFT: u64 = 8;
const FLAG_NORM_LANES_MASK: u64 = 0xFF << FLAG_NORM_LANES_SHIFT;
const FLAG_CENTROID_COUNT_SHIFT: u64 = 16;
const FLAG_CENTROID_COUNT_MASK: u64 = 0xFFFF << FLAG_CENTROID_COUNT_SHIFT;

/// Bytes before the first section (magic + header words + params).
const HEADER_BYTES: usize = 128;
/// Section starts are aligned to this many bytes.
const SECTION_ALIGN: usize = ROW_ALIGN;

/// Byte offsets of every section, derived purely from the header.
#[derive(Debug, Clone, Copy)]
struct SectionLayout {
    ids: usize,
    dists: usize,
    data: usize,
    norms: Option<usize>,
    idmap: Option<usize>,
    centroids: Option<usize>,
    /// Offset of the FNV trailer == total payload length.
    crc: usize,
}

impl SectionLayout {
    fn compute(
        n: usize,
        k: usize,
        dim_pad: usize,
        has_norms: bool,
        has_idmap: bool,
        cent_count: usize,
    ) -> Self {
        let mut off = HEADER_BYTES;
        let mut section = |len: usize| {
            off = round_up(off, SECTION_ALIGN);
            let start = off;
            off += len;
            start
        };
        let ids = section(n * k * 4);
        let dists = section(n * k * 4);
        let data = section(n * dim_pad * 4);
        let norms = has_norms.then(|| section(n * 4));
        let idmap = has_idmap.then(|| section(n * 4));
        let centroids = (cent_count > 0).then(|| section(cent_count * dim_pad * 4));
        Self { ids, dists, data, norms, idmap, centroids, crc: off }
    }

    fn file_len(&self) -> usize {
        self.crc + 8
    }
}

/// Everything [`write_segment`] needs, borrowed from the caller.
/// `ids`/`dists` are the flat `n·k` heap-order strips
/// ([`KnnGraph::flat_ids`](crate::graph::KnnGraph::flat_ids)); `norms`
/// pairs per-row squared norms with the lane count that computed them;
/// `idmap` maps working row → external id (identity when `None`).
pub struct SegmentSpec<'a> {
    pub data: &'a AlignedMatrix,
    pub ids: &'a [u32],
    pub dists: &'a [f32],
    pub k: usize,
    pub params: &'a Params,
    pub norms: Option<(&'a [f32], usize)>,
    pub idmap: Option<&'a [u32]>,
    pub centroids: Option<&'a AlignedMatrix>,
    pub generation: u64,
}

/// Write a `KNNIv2` segment. The file is flushed and fsync'd before
/// returning, so a follow-up atomic rename is durable.
pub fn write_segment(path: &Path, spec: &SegmentSpec<'_>) -> Result<()> {
    let (n, dim, dim_pad) = (spec.data.n(), spec.data.dim(), spec.data.dim_pad());
    assert!(n >= 2, "segments need at least two rows");
    assert!(spec.k >= 1 && spec.k <= u16::MAX as usize, "implausible k {}", spec.k);
    assert_eq!(spec.ids.len(), n * spec.k, "ids strip must be n·k");
    assert_eq!(spec.dists.len(), n * spec.k, "dists strip must be n·k");
    if let Some((ns, lanes)) = spec.norms {
        assert_eq!(ns.len(), n, "norms length mismatch");
        assert!(matches!(lanes, 1 | 8 | 16), "implausible norm lane count {lanes}");
    }
    if let Some(m) = spec.idmap {
        assert_eq!(m.len(), n, "idmap length mismatch");
    }
    if let Some(c) = spec.centroids {
        assert_eq!(c.dim(), dim, "centroid/data dim mismatch");
        assert!(c.n() >= 1 && c.n() <= u16::MAX as usize, "implausible centroid count {}", c.n());
    }
    let cent_count = spec.centroids.map_or(0, |c| c.n());
    let layout = SectionLayout::compute(
        n,
        spec.k,
        dim_pad,
        spec.norms.is_some(),
        spec.idmap.is_some(),
        cent_count,
    );

    let file =
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = Emitter { w: BufWriter::new(&file), crc: Fnv::new(), pos: 0 };

    w.emit(MAGIC_V2)?;
    w.emit(&(n as u64).to_le_bytes())?;
    w.emit(&(dim as u64).to_le_bytes())?;
    w.emit(&(spec.k as u64).to_le_bytes())?;
    let mut flags = 0u64;
    if let Some((_, lanes)) = spec.norms {
        flags |= FLAG_NORMS | ((lanes as u64) << FLAG_NORM_LANES_SHIFT);
    }
    if spec.idmap.is_some() {
        flags |= FLAG_IDMAP;
    }
    if cent_count > 0 {
        flags |= FLAG_CENTROIDS | ((cent_count as u64) << FLAG_CENTROID_COUNT_SHIFT);
    }
    w.emit(&flags.to_le_bytes())?;
    w.emit(&spec.generation.to_le_bytes())?;
    w.emit(&(dim_pad as u64).to_le_bytes())?;
    w.emit(&0u64.to_le_bytes())?; // reserved
    w.emit(&crate::search::bundle::encode_params(spec.params))?;

    w.pad_to(layout.ids)?;
    for &v in spec.ids {
        w.emit(&v.to_le_bytes())?;
    }
    w.pad_to(layout.dists)?;
    for &d in spec.dists {
        w.emit(&d.to_le_bytes())?;
    }
    w.pad_to(layout.data)?;
    // padded rows, exactly as the matrix lays them out in memory
    let mut row_buf = Vec::with_capacity(dim_pad * 4);
    for i in 0..n {
        row_buf.clear();
        for &x in spec.data.row(i) {
            row_buf.extend_from_slice(&x.to_le_bytes());
        }
        w.emit(&row_buf)?;
    }
    if let (Some(off), Some((ns, _))) = (layout.norms, spec.norms) {
        w.pad_to(off)?;
        for &x in ns {
            w.emit(&x.to_le_bytes())?;
        }
    }
    if let (Some(off), Some(m)) = (layout.idmap, spec.idmap) {
        w.pad_to(off)?;
        for &id in m {
            w.emit(&id.to_le_bytes())?;
        }
    }
    if let (Some(off), Some(c)) = (layout.centroids, spec.centroids) {
        w.pad_to(off)?;
        for i in 0..c.n() {
            row_buf.clear();
            for &x in c.row(i) {
                row_buf.extend_from_slice(&x.to_le_bytes());
            }
            w.emit(&row_buf)?;
        }
    }
    debug_assert_eq!(w.pos, layout.crc, "writer out of sync with the layout");
    let crc = w.crc.0;
    w.w.write_all(&crc.to_le_bytes())?;
    w.w.flush()?;
    file.sync_all().with_context(|| format!("fsync {}", path.display()))?;
    Ok(())
}

struct Emitter<'f> {
    w: BufWriter<&'f std::fs::File>,
    crc: Fnv,
    pos: usize,
}

impl Emitter<'_> {
    fn emit(&mut self, bytes: &[u8]) -> Result<()> {
        self.crc.update(bytes);
        self.w.write_all(bytes)?;
        self.pos += bytes.len();
        Ok(())
    }

    /// Zero-fill up to `off` (section alignment padding; checksummed).
    fn pad_to(&mut self, off: usize) -> Result<()> {
        debug_assert!(off >= self.pos && off - self.pos < SECTION_ALIGN);
        const ZEROS: [u8; 64] = [0u8; 64];
        let gap = off - self.pos;
        self.emit(&ZEROS[..gap])
    }
}

/// How the segment serves its per-row squared norms.
enum NormSource {
    /// Straight from the mapped norms section (stored lane width
    /// matches the active kernel width).
    Stored,
    /// Recomputed at open (section absent, or stored at another width —
    /// same discipline as the `KNNIv1` loader).
    Owned(Vec<f32>),
}

/// An opened, immutable `KNNIv2` segment: every section served in
/// place from one [`SegmentBytes`] region. The data matrix and
/// centroids are foreign-backed [`AlignedMatrix`] views into that
/// region; the search path runs on the same
/// [`IndexView`] core as [`GraphIndex`](crate::search::GraphIndex), so
/// segment-backed answers are bit-identical to the owned path.
pub struct Segment {
    bytes: Arc<SegmentBytes>,
    n: usize,
    dim: usize,
    dim_pad: usize,
    k: usize,
    generation: u64,
    params: Params,
    layout: SectionLayout,
    data: AlignedMatrix,
    centroids: Option<AlignedMatrix>,
    norms: NormSource,
    norm_lanes: usize,
}

impl Segment {
    /// Open under the resolved default mode (explicit `PALLAS_STORE`,
    /// else mmap where available).
    pub fn open(path: &Path) -> Result<Self> {
        Self::open_with(path, None)
    }

    /// Open under an explicit store mode (`None` = resolve default).
    pub fn open_with(path: &Path, mode: Option<StoreMode>) -> Result<Self> {
        if cfg!(target_endian = "big") {
            bail!(
                "KNNIv2 segments are little-endian and read by in-place reinterpretation; \
                 this target is big-endian — use a KNNIv1 bundle instead"
            );
        }
        let mode = StoreMode::resolve(mode);
        let file_len = std::fs::metadata(path)
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        if file_len < (HEADER_BYTES + 8) as u64 {
            bail!("file too small for a KNNIv2 segment ({file_len} bytes)");
        }
        let bytes = Arc::new(SegmentBytes::open(path, mode, file_len)?);
        let b = bytes.as_slice();

        if &b[..8] != MAGIC_V2 {
            if b.starts_with(b"KNNI") {
                bail!(
                    "unsupported segment version {:?} (this build reads KNNIv2; \
                     KNNIv1 bundles open through MutableIndex or api::Index::load)",
                    String::from_utf8_lossy(&b[..6])
                );
            }
            bail!("not a KNNIv2 segment (magic {:02x?})", &b[..8]);
        }
        let u64_at = |off: usize| u64::from_le_bytes(b[off..off + 8].try_into().unwrap());
        let n = u64_at(8) as usize;
        let dim = u64_at(16) as usize;
        let k = u64_at(24) as usize;
        let flags = u64_at(32);
        let generation = u64_at(40);
        let dim_pad = u64_at(48) as usize;
        if n < 2 || k < 1 || dim < 1 || dim > 1_000_000 {
            bail!("implausible segment header: n={n}, dim={dim}, k={k}");
        }
        if k > u16::MAX as usize || n > u32::MAX as usize - 1 {
            bail!("implausible segment header: n={n}, k={k}");
        }
        if dim_pad != round_up(dim, LANE_PAD) {
            bail!("dim_pad {dim_pad} does not match 8⌈dim/8⌉ for dim {dim}");
        }
        if n.checked_mul(k).is_none() || n * k > (1 << 34) {
            bail!("implausible graph size: n={n}, k={k}");
        }
        if n.checked_mul(dim_pad).is_none() || n * dim_pad > (1 << 36) {
            bail!("implausible data size: n={n}, dim_pad={dim_pad}");
        }
        if u64_at(56) != 0 {
            bail!("reserved header word is nonzero");
        }
        let known = FLAG_NORMS
            | FLAG_CENTROIDS
            | FLAG_IDMAP
            | FLAG_NORM_LANES_MASK
            | FLAG_CENTROID_COUNT_MASK;
        if flags & !known != 0 {
            bail!("unknown flag bits {flags:#x}");
        }
        let stored_lanes = ((flags & FLAG_NORM_LANES_MASK) >> FLAG_NORM_LANES_SHIFT) as usize;
        if flags & FLAG_NORMS != 0 {
            if !matches!(stored_lanes, 1 | 8 | 16) {
                bail!("implausible norm lane count {stored_lanes} (valid widths: 1, 8, 16)");
            }
        } else if stored_lanes != 0 {
            bail!("norm lane count {stored_lanes} recorded without a norms section");
        }
        let cent_count =
            ((flags & FLAG_CENTROID_COUNT_MASK) >> FLAG_CENTROID_COUNT_SHIFT) as usize;
        if flags & FLAG_CENTROIDS != 0 {
            if cent_count == 0 {
                bail!("centroids section recorded with a zero centroid count");
            }
        } else if cent_count != 0 {
            bail!("centroid count {cent_count} recorded without a centroids section");
        }

        let layout = SectionLayout::compute(
            n,
            k,
            dim_pad,
            flags & FLAG_NORMS != 0,
            flags & FLAG_IDMAP != 0,
            cent_count,
        );
        if b.len() != layout.file_len() {
            bail!(
                "segment size mismatch: file is {} bytes, header implies {} — truncated or \
                 corrupt",
                b.len(),
                layout.file_len()
            );
        }
        let mut crc = Fnv::new();
        crc.update(&b[..layout.crc]);
        if u64::from_le_bytes(b[layout.crc..layout.crc + 8].try_into().unwrap()) != crc.0 {
            bail!("checksum mismatch — segment corrupt");
        }

        let mut params_buf = [0u8; 64];
        params_buf.copy_from_slice(&b[64..128]);
        let params = crate::search::bundle::decode_params(&params_buf)?;

        // Section slices are reinterpreted in place, so validate the
        // parts the search core will index with *before* serving: edge
        // ids must be EMPTY or in-range non-self, external ids must not
        // collide with the EMPTY sentinel.
        let ids: &[u32] = slice_u32(b, layout.ids, n * k);
        for (slot, &v) in ids.iter().enumerate() {
            if v == EMPTY_ID {
                continue;
            }
            let u = slot / k;
            if v as usize >= n || v as usize == u {
                bail!("corrupt edge {u} → {v}");
            }
        }
        if let Some(off) = layout.idmap {
            let map: &[u32] = slice_u32(b, off, n);
            if map.iter().any(|&id| id == u32::MAX) {
                bail!("idmap contains the reserved id u32::MAX");
            }
        }

        // Safety: the data section holds n·dim_pad f32 values at a
        // 64-byte-aligned offset of a 64-byte-aligned region, alive as
        // long as the keepalive Arc — exactly from_foreign's contract.
        let data = unsafe {
            AlignedMatrix::from_foreign(
                b.as_ptr().add(layout.data) as *const f32,
                n,
                dim,
                bytes.clone() as Arc<dyn std::any::Any + Send + Sync>,
            )
        };
        let centroids = layout.centroids.map(|off| unsafe {
            AlignedMatrix::from_foreign(
                b.as_ptr().add(off) as *const f32,
                cent_count,
                dim,
                bytes.clone() as Arc<dyn std::any::Any + Send + Sync>,
            )
        });

        // Same width discipline as the v1 loader: stored norms are kept
        // only when their lane tag matches the active kernel width;
        // otherwise (or when absent) they are recomputed so the
        // norm-trick path keeps its exact-zero self-distance guarantee.
        let active_lanes = crate::distance::dispatch::active_width().lanes();
        let (norms, norm_lanes) = if layout.norms.is_some() && stored_lanes == active_lanes {
            (NormSource::Stored, stored_lanes)
        } else {
            let ns = (0..n).map(|i| crate::distance::sq_norm(data.row(i))).collect();
            (NormSource::Owned(ns), active_lanes)
        };

        Ok(Self {
            bytes,
            n,
            dim,
            dim_pad,
            k,
            generation,
            params,
            layout,
            data,
            centroids,
            norms,
            norm_lanes,
        })
    }

    /// Number of rows.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Logical dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Neighbors per node.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Compaction generation recorded in the header.
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Build parameters recorded in the header.
    pub fn params(&self) -> &Params {
        &self.params
    }

    /// How the bytes were brought in (mmap or heap copy).
    pub fn mode(&self) -> StoreMode {
        self.bytes.mode()
    }

    /// The corpus matrix — a foreign-backed view into the segment's
    /// bytes (never an owned copy; `data().is_owned()` is `false`).
    pub fn data(&self) -> &AlignedMatrix {
        &self.data
    }

    /// Partition centroids, when the segment carries them.
    pub fn centroids(&self) -> Option<&AlignedMatrix> {
        self.centroids.as_ref()
    }

    /// Flat `n·k` neighbor-id strip (heap order), in place.
    pub fn ids(&self) -> &[u32] {
        slice_u32(self.bytes.as_slice(), self.layout.ids, self.n * self.k)
    }

    /// Flat `n·k` neighbor-distance strip (heap order), in place.
    pub fn dists(&self) -> &[f32] {
        slice_f32(self.bytes.as_slice(), self.layout.dists, self.n * self.k)
    }

    /// Per-row squared norms at the active kernel width.
    pub fn norms(&self) -> &[f32] {
        match &self.norms {
            NormSource::Stored => {
                slice_f32(self.bytes.as_slice(), self.layout.norms.unwrap(), self.n)
            }
            NormSource::Owned(v) => v,
        }
    }

    /// Lane count of the width [`norms`](Self::norms) was computed at.
    pub fn norm_lanes(&self) -> usize {
        self.norm_lanes
    }

    /// Working row → external id table, when stored.
    pub fn idmap(&self) -> Option<&[u32]> {
        self.layout.idmap.map(|off| slice_u32(self.bytes.as_slice(), off, self.n))
    }

    /// External id of working row `w` (identity without an idmap).
    #[inline]
    pub fn external_id(&self, w: u32) -> u32 {
        match self.layout.idmap {
            Some(off) => slice_u32(self.bytes.as_slice(), off, self.n)[w as usize],
            None => w,
        }
    }

    /// The borrowed search view over the mapped sections — the *same*
    /// core [`GraphIndex`](crate::search::GraphIndex) runs on.
    pub(crate) fn view(&self) -> IndexView<'_> {
        IndexView::new(&self.data, self.ids(), self.k, self.norms())
    }

    /// Allocate a reusable search scratch sized for this segment.
    pub fn scratch(&self) -> SearchScratch {
        self.view().scratch()
    }

    /// Single-query beam search, results in *working* row ids.
    pub fn search_raw(
        &self,
        query: &[f32],
        k: usize,
        params: &SearchParams,
        scratch: &mut SearchScratch,
    ) -> (Vec<(u32, f32)>, QueryStats) {
        self.view().search_with(query, k, params, scratch)
    }

    /// Batched beam search, results in *working* row ids.
    pub fn search_batch_raw(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
        scratch: &mut SearchScratch,
    ) -> (Vec<Vec<(u32, f32)>>, BatchStats) {
        self.view().search_batch_with(queries, k, params, scratch)
    }
}

#[inline]
fn slice_u32(b: &[u8], off: usize, len: usize) -> &[u32] {
    debug_assert!(off % 4 == 0 && off + len * 4 <= b.len());
    // Safety: offset and length are layout-validated against the region;
    // section starts are 64-byte aligned, satisfying u32 alignment.
    unsafe { std::slice::from_raw_parts(b.as_ptr().add(off) as *const u32, len) }
}

#[inline]
fn slice_f32(b: &[u8], off: usize, len: usize) -> &[f32] {
    debug_assert!(off % 4 == 0 && off + len * 4 <= b.len());
    // Safety: as slice_u32; any bit pattern is a valid f32.
    unsafe { std::slice::from_raw_parts(b.as_ptr().add(off) as *const f32, len) }
}

/// Convert a legacy `KNNIv1` bundle into a `KNNIv2` segment. The
/// working-layout rows, edges, and distances carry over bit-exactly;
/// the v1 reordering's σ⁻¹ becomes the v2 idmap (working → original
/// id); norms are persisted at the width that will serve them.
pub fn convert_v1_to_v2(src: &Path, dst: &Path) -> Result<()> {
    let bundle = crate::search::load_index(src)?;
    let norms = match &bundle.norms {
        Some(ns) => ns.clone(),
        None => (0..bundle.data.n())
            .map(|i| crate::distance::sq_norm(bundle.data.row(i)))
            .collect(),
    };
    let lanes = if bundle.norms.is_some() {
        bundle.norm_lanes
    } else {
        crate::distance::dispatch::active_width().lanes()
    };
    let idmap = bundle.reordering.as_ref().map(|r| r.inv.clone());
    write_segment(
        dst,
        &SegmentSpec {
            data: &bundle.data,
            ids: bundle.graph.flat_ids(),
            dists: bundle.graph.flat_dists(),
            k: bundle.graph.k(),
            params: &bundle.params,
            norms: Some((&norms, lanes)),
            idmap: idmap.as_deref(),
            centroids: bundle.centroids.as_ref(),
            generation: 0,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::clustered::SynthClustered;
    use crate::nndescent::NnDescent;
    use crate::search::GraphIndex;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("knng_store_format_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn build(n: usize, dim: usize, seed: u64) -> (AlignedMatrix, crate::graph::KnnGraph, Params) {
        let (data, _) = SynthClustered::new(n, dim, 6, seed).generate_labeled();
        let params = Params::default().with_k(10).with_seed(seed);
        let result = NnDescent::new(params.clone()).build(&data).unwrap();
        (data, result.graph, params)
    }

    fn save(path: &std::path::Path, data: &AlignedMatrix, g: &crate::graph::KnnGraph, p: &Params) {
        let norms = GraphIndex::compute_norms(data);
        let lanes = crate::distance::dispatch::active_width().lanes();
        write_segment(
            path,
            &SegmentSpec {
                data,
                ids: g.flat_ids(),
                dists: g.flat_dists(),
                k: g.k(),
                params: p,
                norms: Some((&norms, lanes)),
                idmap: None,
                centroids: None,
                generation: 3,
            },
        )
        .unwrap();
    }

    #[test]
    fn roundtrip_is_bit_exact_and_zero_copy() {
        let (data, graph, params) = build(400, 12, 7);
        let path = tmp("rt.knni2");
        save(&path, &data, &graph, &params);
        for mode in [StoreMode::Copy, StoreMode::Mmap] {
            if mode == StoreMode::Mmap && !cfg!(unix) {
                continue;
            }
            let seg = Segment::open_with(&path, Some(mode)).unwrap();
            assert_eq!((seg.n(), seg.dim(), seg.k()), (400, 12, graph.k()));
            assert_eq!(seg.generation(), 3);
            assert_eq!(seg.params(), &params);
            assert!(!seg.data().is_owned(), "corpus must be served in place, not copied");
            assert_eq!(seg.ids(), graph.flat_ids());
            for (a, b) in seg.dists().iter().zip(graph.flat_dists()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            for i in 0..400 {
                assert_eq!(seg.data().row(i), data.row(i), "row {i}");
            }
            let want = GraphIndex::compute_norms(&data);
            for (a, b) in seg.norms().iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(seg.external_id(17), 17, "no idmap → identity");
        }
    }

    #[test]
    fn mmap_and_copy_serve_bitwise_identical_results() {
        if !cfg!(unix) {
            return;
        }
        let (data, graph, params) = build(600, 16, 11);
        let path = tmp("modes.knni2");
        save(&path, &data, &graph, &params);
        let a = Segment::open_with(&path, Some(StoreMode::Mmap)).unwrap();
        let b = Segment::open_with(&path, Some(StoreMode::Copy)).unwrap();
        assert_eq!(a.mode(), StoreMode::Mmap);
        assert_eq!(b.mode(), StoreMode::Copy);
        let sp = SearchParams::default();
        let (mut sa, mut sb) = (a.scratch(), b.scratch());
        for qi in (0..600).step_by(43) {
            let (ra, qa) = a.search_raw(data.row_logical(qi), 8, &sp, &mut sa);
            let (rb, qb) = b.search_raw(data.row_logical(qi), 8, &sp, &mut sb);
            assert_eq!(ra, rb, "query {qi}");
            assert_eq!(qa, qb);
        }
    }

    #[test]
    fn segment_search_matches_graph_index_bitwise() {
        // the tentpole identity: a segment answers exactly like the
        // owned GraphIndex over the same graph+data, stats included
        let (data, graph, params) = build(500, 16, 13);
        let path = tmp("parity.knni2");
        save(&path, &data, &graph, &params);
        let seg = Segment::open(&path).unwrap();
        let idx = GraphIndex::new(data.clone(), graph);
        let sp = SearchParams::default();
        let mut scratch = seg.scratch();
        for qi in (0..500).step_by(29) {
            let (want, wq) = idx.search(data.row_logical(qi), 10, &sp);
            let (got, gq) = seg.search_raw(data.row_logical(qi), 10, &sp, &mut scratch);
            assert_eq!(want, got, "query {qi}");
            assert_eq!(wq, gq, "query {qi} stats");
        }
        let queries = {
            let rows: Vec<f32> =
                (0..50).flat_map(|i| data.row_logical(i * 9).to_vec()).collect();
            AlignedMatrix::from_rows(50, 16, &rows)
        };
        let (want, _) = idx.search_batch(&queries, 10, &sp);
        let (got, _) = seg.search_batch_raw(&queries, 10, &sp, &mut scratch);
        assert_eq!(want, got);
    }

    #[test]
    fn v1_conversion_preserves_serving_and_idmap() {
        let (data, _) = SynthClustered::new(300, 8, 4, 17).generate_labeled();
        let params = Params::default().with_k(8).with_seed(17).with_reorder(true);
        let result = NnDescent::new(params.clone()).build(&data).unwrap();
        let bundle = crate::search::IndexBundle::from_build(&data, &result, &params);
        let v1 = tmp("conv.knni");
        let v2 = tmp("conv.knni2");
        crate::search::save_index(&v1, &bundle).unwrap();
        convert_v1_to_v2(&v1, &v2).unwrap();

        let seg = Segment::open(&v2).unwrap();
        let (idx, reord, _) = crate::search::load_index(&v1).unwrap().into_index();
        let r = reord.unwrap();
        // idmap must be σ⁻¹
        assert_eq!(seg.idmap().unwrap(), &r.inv[..]);
        let sp = SearchParams::default();
        let mut scratch = seg.scratch();
        for qi in (0..300).step_by(31) {
            let (want, _) = idx.search(data.row_logical(qi), 5, &sp);
            let (got, _) = seg.search_raw(data.row_logical(qi), 5, &sp, &mut scratch);
            assert_eq!(want, got, "query {qi} (working ids)");
            // and the idmap takes the self-hit back to the original id
            assert_eq!(seg.external_id(got[0].0) as usize, qi, "query {qi} external id");
        }
    }

    #[test]
    fn corruption_and_truncation_are_typed_errors() {
        let (data, graph, params) = build(200, 8, 19);
        let path = tmp("corrupt.knni2");
        save(&path, &data, &graph, &params);
        let good = std::fs::read(&path).unwrap();

        // flipped byte → checksum
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        let err = Segment::open_with(&path, Some(StoreMode::Copy)).unwrap_err().to_string();
        assert!(
            err.contains("checksum") || err.contains("corrupt"),
            "unexpected error: {err}"
        );

        // truncations at assorted cuts → size mismatch (or too-small)
        for keep in [0usize, 7, 8, 40, 127, 128, good.len() / 2, good.len() - 1] {
            std::fs::write(&path, &good[..keep]).unwrap();
            assert!(
                Segment::open_with(&path, Some(StoreMode::Copy)).is_err(),
                "truncated at {keep} bytes must fail"
            );
        }

        // wrong magic family
        let mut other = good.clone();
        other[..8].copy_from_slice(b"NOTADATA");
        std::fs::write(&path, &other).unwrap();
        let err = Segment::open_with(&path, Some(StoreMode::Copy)).unwrap_err().to_string();
        assert!(err.contains("not a KNNIv2"), "unexpected error: {err}");

        // v1 magic routed to a helpful cross-format message
        let mut v1 = good;
        v1[..8].copy_from_slice(b"KNNIv1\0\0");
        std::fs::write(&path, &v1).unwrap();
        let err = Segment::open_with(&path, Some(StoreMode::Copy)).unwrap_err().to_string();
        assert!(err.contains("KNNIv1"), "unexpected error: {err}");
    }

    #[test]
    fn v1_loader_names_the_store_engine_for_v2_files() {
        let (data, graph, params) = build(200, 8, 23);
        let path = tmp("crossload.knni2");
        save(&path, &data, &graph, &params);
        let err = crate::search::load_index(&path).unwrap_err().to_string();
        assert!(err.contains("KNNIv2") && err.contains("store"), "unexpected error: {err}");
    }

    #[test]
    fn sections_are_aligned_and_padding_is_checksummed() {
        // n·k·4 = 200·10·4 = 8000, not a multiple of 64 → real padding
        let (data, graph, params) = build(200, 9, 29);
        let path = tmp("align.knni2");
        save(&path, &data, &graph, &params);
        let seg = Segment::open_with(&path, Some(StoreMode::Copy)).unwrap();
        assert_eq!(seg.ids().as_ptr() as usize % SECTION_ALIGN, 0);
        assert_eq!(seg.dists().as_ptr() as usize % SECTION_ALIGN, 0);
        assert_eq!(seg.data().row(0).as_ptr() as usize % SECTION_ALIGN, 0);

        // corrupt one padding byte between ids and dists: CRC must fire
        let layout = seg.layout;
        drop(seg);
        let mut bytes = std::fs::read(&path).unwrap();
        let pad_at = layout.ids + 200 * graph.k() * 4; // first pad byte after ids
        assert!(pad_at < layout.dists, "this shape must produce inter-section padding");
        bytes[pad_at] = 0xAB;
        std::fs::write(&path, &bytes).unwrap();
        let err = Segment::open_with(&path, Some(StoreMode::Copy)).unwrap_err().to_string();
        assert!(err.contains("checksum"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_corrupt_edges_and_reserved_idmap_values() {
        let (data, graph, params) = build(200, 8, 31);
        let path = tmp("edges.knni2");
        save(&path, &data, &graph, &params);
        let mut bytes = std::fs::read(&path).unwrap();
        // first edge slot → out-of-range id (not EMPTY)
        let ids_off = {
            let seg = Segment::open_with(&path, Some(StoreMode::Copy)).unwrap();
            seg.layout.ids
        };
        bytes[ids_off..ids_off + 4].copy_from_slice(&500u32.to_le_bytes());
        let crc_off = bytes.len() - 8;
        let mut crc = Fnv::new();
        crc.update(&bytes[..crc_off]);
        bytes[crc_off..].copy_from_slice(&crc.0.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = Segment::open_with(&path, Some(StoreMode::Copy)).unwrap_err().to_string();
        assert!(err.contains("corrupt edge"), "unexpected error: {err}");
    }
}
