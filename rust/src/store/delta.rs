//! The in-memory mutable layer: rows inserted since the last
//! compaction, brute-force searched. The delta is expected to stay
//! small relative to the base segment (the auto-compaction policy
//! enforces that), so exact scan is both simpler and more accurate
//! than maintaining an incremental graph over a churning set.

use crate::dataset::matrix::LANE_PAD;
use crate::util::round_up;
use std::collections::HashMap;

/// Mutable row store keyed by external id. Slots are append-only;
/// deleting clears the live bit, re-inserting an id overwrites its
/// existing slot in place.
pub struct DeltaSegment {
    dim: usize,
    dim_pad: usize,
    /// Slot-major row storage, stride `dim_pad`, tail lanes zero.
    rows: Vec<f32>,
    /// External id per slot.
    ids: Vec<u32>,
    live: Vec<bool>,
    by_id: HashMap<u32, usize>,
    live_count: usize,
}

impl DeltaSegment {
    pub fn new(dim: usize) -> Self {
        assert!(dim >= 1, "dim must be positive");
        Self {
            dim,
            dim_pad: round_up(dim, LANE_PAD),
            rows: Vec::new(),
            ids: Vec::new(),
            live: Vec::new(),
            by_id: HashMap::new(),
            live_count: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Live (inserted and not since deleted) row count.
    pub fn live_count(&self) -> usize {
        self.live_count
    }

    pub fn is_empty(&self) -> bool {
        self.live_count == 0
    }

    /// Is `id` currently present in the delta?
    pub fn contains_live(&self, id: u32) -> bool {
        self.by_id.get(&id).is_some_and(|&s| self.live[s])
    }

    /// Insert (or overwrite) the row for `id`. Returns `true` when the
    /// id was not live before (a net addition).
    pub fn insert(&mut self, id: u32, row: &[f32]) -> bool {
        assert_eq!(row.len(), self.dim, "delta row dim mismatch");
        let slot = match self.by_id.get(&id) {
            Some(&s) => s,
            None => {
                let s = self.ids.len();
                self.ids.push(id);
                self.live.push(false);
                self.rows.resize(self.rows.len() + self.dim_pad, 0.0);
                self.by_id.insert(id, s);
                s
            }
        };
        let dst = &mut self.rows[slot * self.dim_pad..slot * self.dim_pad + self.dim_pad];
        dst[..self.dim].copy_from_slice(row);
        dst[self.dim..].fill(0.0);
        let was_live = std::mem::replace(&mut self.live[slot], true);
        if !was_live {
            self.live_count += 1;
        }
        !was_live
    }

    /// Remove `id` from the delta. Returns `true` when it was live.
    pub fn delete(&mut self, id: u32) -> bool {
        match self.by_id.get(&id) {
            Some(&s) if self.live[s] => {
                self.live[s] = false;
                self.live_count -= 1;
                true
            }
            _ => false,
        }
    }

    /// Padded row of `slot` (internal/compaction use).
    fn row(&self, slot: usize) -> &[f32] {
        &self.rows[slot * self.dim_pad..slot * self.dim_pad + self.dim_pad]
    }

    /// Live rows in slot (insertion) order: `(external id, logical row)`.
    pub fn live_rows(&self) -> impl Iterator<Item = (u32, &[f32])> {
        self.ids
            .iter()
            .enumerate()
            .filter(|&(s, _)| self.live[s])
            .map(|(s, &id)| (id, &self.row(s)[..self.dim]))
    }

    /// Exact k-NN over the live rows: distances via the active kernel
    /// (same code path the segments use), ties broken by external id,
    /// ascending — the crate-wide result ordering.
    pub fn search(&self, query: &[f32], k: usize) -> Vec<(u32, f32)> {
        assert_eq!(query.len(), self.dim, "delta query dim mismatch");
        if self.live_count == 0 || k == 0 {
            return Vec::new();
        }
        let mut padded = vec![0.0f32; self.dim_pad];
        padded[..self.dim].copy_from_slice(query);
        let pair = crate::distance::dispatch::active().pair;
        let mut hits: Vec<(u32, f32)> = self
            .ids
            .iter()
            .enumerate()
            .filter(|&(s, _)| self.live[s])
            .map(|(s, &id)| (id, pair(&padded, self.row(s))))
            .collect();
        hits.sort_unstable_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        hits.truncate(k);
        hits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_search_delete_reinsert() {
        let mut d = DeltaSegment::new(3);
        assert!(d.insert(10, &[0.0, 0.0, 0.0]));
        assert!(d.insert(11, &[1.0, 0.0, 0.0]));
        assert!(d.insert(12, &[5.0, 0.0, 0.0]));
        assert_eq!(d.live_count(), 3);

        let hits = d.search(&[0.9, 0.0, 0.0], 2);
        assert_eq!(hits.len(), 2);
        assert_eq!(hits[0].0, 11);
        assert_eq!(hits[1].0, 10);

        assert!(d.delete(11));
        assert!(!d.delete(11), "double delete is a no-op");
        assert!(!d.contains_live(11));
        assert_eq!(d.live_count(), 2);
        let hits = d.search(&[0.9, 0.0, 0.0], 3);
        assert_eq!(hits.iter().map(|h| h.0).collect::<Vec<_>>(), vec![10, 12]);

        // re-insert revives the same slot with a fresh row
        assert!(d.insert(11, &[0.8, 0.0, 0.0]));
        assert_eq!(d.live_count(), 3);
        let hits = d.search(&[0.9, 0.0, 0.0], 1);
        assert_eq!(hits[0].0, 11);
        assert!((hits[0].1 - 0.01).abs() < 1e-6);
    }

    #[test]
    fn overwrite_updates_in_place() {
        let mut d = DeltaSegment::new(2);
        assert!(d.insert(5, &[10.0, 0.0]));
        assert!(!d.insert(5, &[0.0, 0.0]), "overwrite is not a net addition");
        assert_eq!(d.live_count(), 1);
        let hits = d.search(&[0.0, 0.0], 1);
        assert_eq!(hits, vec![(5, 0.0)]);
    }

    #[test]
    fn ties_break_by_external_id() {
        let mut d = DeltaSegment::new(2);
        for id in [30u32, 9, 17] {
            d.insert(id, &[1.0, 1.0]);
        }
        let hits = d.search(&[0.0, 0.0], 3);
        assert_eq!(hits.iter().map(|h| h.0).collect::<Vec<_>>(), vec![9, 17, 30]);
    }

    #[test]
    fn live_rows_iterates_in_slot_order() {
        let mut d = DeltaSegment::new(2);
        d.insert(3, &[1.0, 2.0]);
        d.insert(1, &[3.0, 4.0]);
        d.insert(2, &[5.0, 6.0]);
        d.delete(1);
        let got: Vec<(u32, Vec<f32>)> =
            d.live_rows().map(|(id, r)| (id, r.to_vec())).collect();
        assert_eq!(got, vec![(3, vec![1.0, 2.0]), (2, vec![5.0, 6.0])]);
    }
}
