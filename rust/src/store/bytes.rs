//! The byte region behind a segment: an mmap'd file or one
//! 64-byte-aligned heap buffer, behind a single enum so every parser
//! and accessor upstack is mode-oblivious. Both variants expose the
//! identical `&[u8]` — same bytes, same offsets — which is what makes
//! mmap-vs-copy bitwise identity hold by construction.

use crate::dataset::matrix::ROW_ALIGN;
use anyhow::{bail, Context, Result};
use std::fs::File;
use std::io::Read;
use std::path::Path;

/// How a segment's bytes are brought into the address space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreMode {
    /// `mmap(2)` the file read-only and parse it in place (unix only).
    Mmap,
    /// Read the whole file into one 64-byte-aligned heap buffer — the
    /// safe fallback for platforms without mmap; parses the identical
    /// bytes at the identical offsets.
    Copy,
}

impl StoreMode {
    /// Parse a mode name (the `PALLAS_STORE` vocabulary).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "mmap" => Some(Self::Mmap),
            "copy" | "heap" => Some(Self::Copy),
            _ => None,
        }
    }

    /// The mode requested by the `PALLAS_STORE` environment variable,
    /// if set and valid (an invalid value is logged and ignored).
    pub fn from_env() -> Option<Self> {
        let raw = std::env::var("PALLAS_STORE").ok()?;
        match Self::parse(&raw) {
            Some(m) => Some(m),
            None => {
                crate::log_warn!("PALLAS_STORE={raw:?} is not a store mode (mmap|copy) — ignored");
                None
            }
        }
    }

    /// Resolve the effective mode: explicit choice, then `PALLAS_STORE`,
    /// then the platform default (mmap where available, copy elsewhere).
    /// An mmap request on a platform without mmap degrades to copy.
    pub fn resolve(explicit: Option<Self>) -> Self {
        let picked = explicit
            .or_else(Self::from_env)
            .unwrap_or(if cfg!(unix) { Self::Mmap } else { Self::Copy });
        if picked == Self::Mmap && !cfg!(unix) {
            crate::log_warn!("mmap store mode unavailable on this platform — using copy");
            return Self::Copy;
        }
        picked
    }

    /// Mode name (`mmap`/`copy`).
    pub fn name(self) -> &'static str {
        match self {
            Self::Mmap => "mmap",
            Self::Copy => "copy",
        }
    }
}

/// A read-only, file-backed memory mapping (raw `mmap(2)`, following
/// the crate's no-new-dependencies FFI discipline — see the `signal`
/// shim in `net::server`). Unmapped on drop.
#[cfg(unix)]
pub struct MapRegion {
    ptr: *mut std::ffi::c_void,
    len: usize,
}

#[cfg(unix)]
mod ffi {
    use std::ffi::c_void;
    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

#[cfg(unix)]
impl MapRegion {
    /// Map `len` bytes of `file` read-only.
    pub fn map(file: &File, len: usize) -> Result<Self> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            bail!("cannot map an empty file");
        }
        // Safety: fd is a valid open file; PROT_READ + MAP_PRIVATE asks
        // for a read-only private view the kernel fully controls.
        let ptr = unsafe {
            ffi::mmap(
                std::ptr::null_mut(),
                len,
                ffi::PROT_READ,
                ffi::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            bail!("mmap failed: {}", std::io::Error::last_os_error());
        }
        Ok(Self { ptr, len })
    }

    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        // Safety: the mapping covers exactly `len` bytes and stays
        // valid until drop; MAP_PRIVATE means nobody writes through it.
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }
}

#[cfg(unix)]
impl Drop for MapRegion {
    fn drop(&mut self) {
        // Safety: exactly the region map() created.
        unsafe { ffi::munmap(self.ptr, self.len) };
    }
}

// Safety: the mapping is read-only shared memory; the struct owns it
// exclusively until drop.
#[cfg(unix)]
unsafe impl Send for MapRegion {}
#[cfg(unix)]
unsafe impl Sync for MapRegion {}

/// A 64-byte-aligned owned byte buffer — the heap-copy counterpart of
/// [`MapRegion`], aligned like the mapping so section pointers satisfy
/// the same alignment invariants in both modes.
pub struct AlignedBytes {
    ptr: *mut u8,
    len: usize,
}

impl AlignedBytes {
    /// Read the whole of `file` (of known `len`) into a fresh buffer.
    pub fn read_from(file: &mut File, len: usize) -> Result<Self> {
        use std::alloc::{alloc_zeroed, handle_alloc_error, Layout};
        let layout = Layout::from_size_align(len.max(ROW_ALIGN), ROW_ALIGN)
            .context("segment buffer layout")?;
        // Safety: layout has nonzero size (max'd with ROW_ALIGN).
        let ptr = unsafe { alloc_zeroed(layout) };
        if ptr.is_null() {
            handle_alloc_error(layout);
        }
        let out = Self { ptr, len };
        // Safety: the allocation covers `len` bytes.
        let buf = unsafe { std::slice::from_raw_parts_mut(out.ptr, out.len) };
        file.read_exact(buf).context("reading segment into heap buffer")?;
        Ok(out)
    }

    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl Drop for AlignedBytes {
    fn drop(&mut self) {
        use std::alloc::{dealloc, Layout};
        let layout =
            Layout::from_size_align(self.len.max(ROW_ALIGN), ROW_ALIGN).expect("layout");
        unsafe { dealloc(self.ptr, layout) };
    }
}

// Safety: plain owned bytes, read-only after construction.
unsafe impl Send for AlignedBytes {}
unsafe impl Sync for AlignedBytes {}

/// The bytes of an opened segment, however they got here.
pub enum SegmentBytes {
    /// Zero-copy: the file mapped into the address space.
    #[cfg(unix)]
    Mapped(MapRegion),
    /// The file read into one aligned heap buffer.
    Heap(AlignedBytes),
}

impl SegmentBytes {
    /// Bring `path` into memory under `mode`. `expected_len` guards
    /// against the file changing size between stat and map.
    pub fn open(path: &Path, mode: StoreMode, expected_len: u64) -> Result<Self> {
        let mut file =
            File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let len = file.metadata()?.len();
        if len != expected_len {
            bail!("segment changed size while opening ({len} vs {expected_len} bytes)");
        }
        let len = len as usize;
        match mode {
            #[cfg(unix)]
            StoreMode::Mmap => Ok(Self::Mapped(MapRegion::map(&file, len)?)),
            #[cfg(not(unix))]
            StoreMode::Mmap => bail!("mmap store mode unavailable on this platform"),
            StoreMode::Copy => Ok(Self::Heap(AlignedBytes::read_from(&mut file, len)?)),
        }
    }

    /// The whole byte region. Same contents and offsets in both modes.
    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            Self::Mapped(m) => m.as_slice(),
            Self::Heap(h) => h.as_slice(),
        }
    }

    /// Which mode produced this region.
    pub fn mode(&self) -> StoreMode {
        match self {
            #[cfg(unix)]
            Self::Mapped(_) => StoreMode::Mmap,
            Self::Heap(_) => StoreMode::Copy,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("knng_store_bytes_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn both_modes_expose_identical_bytes() {
        let path = tmp("region.bin");
        let payload: Vec<u8> = (0..10_000u32).flat_map(|x| x.to_le_bytes()).collect();
        std::fs::write(&path, &payload).unwrap();
        let heap = SegmentBytes::open(&path, StoreMode::Copy, payload.len() as u64).unwrap();
        assert_eq!(heap.as_slice(), &payload[..]);
        assert_eq!(heap.mode(), StoreMode::Copy);
        assert_eq!(heap.as_slice().as_ptr() as usize % ROW_ALIGN, 0, "heap buffer aligned");
        #[cfg(unix)]
        {
            let mapped =
                SegmentBytes::open(&path, StoreMode::Mmap, payload.len() as u64).unwrap();
            assert_eq!(mapped.mode(), StoreMode::Mmap);
            assert_eq!(mapped.as_slice(), heap.as_slice(), "mmap and copy must agree bit for bit");
            assert_eq!(mapped.as_slice().as_ptr() as usize % ROW_ALIGN, 0, "mapping aligned");
        }
    }

    #[test]
    fn size_change_is_rejected() {
        let path = tmp("stale.bin");
        std::fs::write(&path, [0u8; 128]).unwrap();
        let err = SegmentBytes::open(&path, StoreMode::Copy, 64).unwrap_err().to_string();
        assert!(err.contains("changed size"), "unexpected error: {err}");
    }

    #[test]
    fn mode_parsing_and_resolution() {
        assert_eq!(StoreMode::parse("mmap"), Some(StoreMode::Mmap));
        assert_eq!(StoreMode::parse("COPY"), Some(StoreMode::Copy));
        assert_eq!(StoreMode::parse("heap"), Some(StoreMode::Copy));
        assert_eq!(StoreMode::parse("nvme"), None);
        assert_eq!(StoreMode::resolve(Some(StoreMode::Copy)), StoreMode::Copy);
        // the unset-env default is platform-dependent but never invalid
        let d = StoreMode::resolve(None);
        assert!(matches!(d, StoreMode::Mmap | StoreMode::Copy));
    }
}
