//! Compaction: fold the delta and the tombstones into a fresh
//! `KNNIv2` segment. The new graph is **repaired**, not rebuilt —
//! seeded from the surviving edges of the old graph (both directions),
//! topped up with per-node-stream random candidates for nodes that
//! lost neighbors or arrived from the delta, then run through a
//! bounded number of NN-Descent iterations
//! ([`NnDescent::repair`](crate::nndescent::NnDescent::repair)). For
//! the common case of a mostly-unchanged corpus this converges in a
//! fraction of a full build.
//!
//! Durability: the new segment is written to a sidecar temp file,
//! fsync'd, then atomically renamed over the base path; only after the
//! rename succeeds are the delta, tombstones, and WAL cleared. A crash
//! at any point leaves either the old segment + full WAL or the new
//! segment (+ a WAL whose records are all no-ops to re-apply or are
//! cleared on the next open's replay-and-compact cycle).

use super::format::{write_segment, Segment, SegmentSpec};
use super::mutable::{BaseSegment, MutableIndex};
use super::DeltaSegment;
use crate::api::WorkingId;
use crate::dataset::AlignedMatrix;
use crate::graph::heap::EMPTY_ID;
use crate::graph::KnnGraph;
use crate::nndescent::{NnDescent, RepairStats};
use crate::search::GraphIndex;
use crate::util::rng::Pcg64;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::time::Instant;

/// What one compaction did.
#[derive(Debug, Clone, Copy, Default)]
pub struct CompactionStats {
    /// Rows in the new segment.
    pub rows: usize,
    /// Delta rows folded into the base.
    pub folded: usize,
    /// Tombstoned base rows dropped.
    pub dropped: usize,
    /// Generation of the new segment.
    pub generation: u64,
    /// The bounded NN-Descent repair pass.
    pub repair: RepairStats,
    /// New segment size in bytes.
    pub bytes: u64,
    /// Wall time of the whole fold, seconds.
    pub secs: f64,
}

impl MutableIndex {
    /// Fold delta + tombstones into a fresh segment and swap it in
    /// atomically. No-op-ish when nothing changed (still rewrites and
    /// bumps the generation). After this returns, the in-memory state
    /// is exactly what a fresh [`MutableIndex::open`] of the path
    /// would produce.
    pub fn compact(&mut self) -> Result<CompactionStats> {
        let t0 = Instant::now();
        let dim = self.dim();

        // ---- gather (immutable reads of the old base) ----
        let (base_n, base_k, params) = match &self.base {
            BaseSegment::V2(s) => (s.n(), s.k(), s.params().clone()),
            BaseSegment::Legacy(i) => (i.len(), i.graph_k(), i.params().clone()),
        };
        let new_gen = self.base.generation() + 1;

        let mut ext_ids: Vec<u32> = Vec::with_capacity(base_n + self.delta.live_count());
        let mut rows: Vec<f32> = Vec::with_capacity((base_n + self.delta.live_count()) * dim);
        let mut old_to_new: HashMap<u32, u32> = HashMap::with_capacity(base_n);
        {
            let ext_of = |w: usize| -> u32 {
                match &self.base {
                    BaseSegment::V2(s) => s.external_id(w as u32),
                    BaseSegment::Legacy(i) => i.to_original(WorkingId(w as u32)).get(),
                }
            };
            let row_of = |w: usize| -> &[f32] {
                match &self.base {
                    BaseSegment::V2(s) => s.data().row_logical(w),
                    BaseSegment::Legacy(i) => i.data().row_logical(w),
                }
            };
            for w in 0..base_n {
                let ext = ext_of(w);
                if self.tombstones.contains(&ext) {
                    continue;
                }
                old_to_new.insert(w as u32, ext_ids.len() as u32);
                ext_ids.push(ext);
                rows.extend_from_slice(row_of(w));
            }
        }
        let dropped = base_n - ext_ids.len();
        let folded = self.delta.live_count();
        for (id, row) in self.delta.live_rows() {
            ext_ids.push(id);
            rows.extend_from_slice(row);
        }
        let n2 = ext_ids.len();
        if n2 < 2 {
            bail!("compaction needs at least 2 live rows, have {n2}");
        }
        let k2 = base_k.min(n2 - 1);
        let new_data = AlignedMatrix::from_rows(n2, dim, &rows);
        drop(rows);

        // ---- seed the new graph from surviving old edges ----
        let mut graph = KnnGraph::new(n2, k2);
        {
            let (flat_ids, flat_dists) = match &self.base {
                BaseSegment::V2(s) => (s.ids(), s.dists()),
                BaseSegment::Legacy(i) => (i.graph().flat_ids(), i.graph().flat_dists()),
            };
            for w in 0..base_n {
                let Some(&nu) = old_to_new.get(&(w as u32)) else { continue };
                for slot in 0..base_k {
                    let v = flat_ids[w * base_k + slot];
                    if v == EMPTY_ID {
                        continue;
                    }
                    if let Some(&nv) = old_to_new.get(&v) {
                        let d = flat_dists[w * base_k + slot];
                        // push both directions: a node that lost edges
                        // to tombstones still gets seeded through its
                        // surviving reverse neighbors
                        graph.push(nu as usize, nv, d, true);
                        graph.push(nv as usize, nu, d, true);
                    }
                }
            }
        }

        // ---- top up under-filled nodes (delta arrivals, heavy losers)
        // with per-node-stream randoms, the parallel-init discipline:
        // the fill is a pure function of (seed, generation, node) ----
        let pair = crate::distance::dispatch::active().pair;
        for u in 0..n2 {
            let filled = graph.ids(u).iter().filter(|&&v| v != EMPTY_ID).count();
            if filled >= k2 {
                continue;
            }
            let mut rng = Pcg64::new_stream(params.seed ^ new_gen, u as u64);
            let need = k2 - filled;
            let mut added = 0;
            // rejection with a generous cap; tiny corpora may leave a
            // slot EMPTY, which the graph and search tolerate
            let mut attempts = 0;
            let max_attempts = 20 * k2 + 64;
            while added < need && attempts < max_attempts {
                attempts += 1;
                let v = rng.gen_index(n2) as u32;
                if v as usize == u || graph.ids(u).contains(&v) {
                    continue;
                }
                let d = pair(new_data.row(u), new_data.row(v as usize));
                if graph.push(u, v, d, true) {
                    added += 1;
                }
            }
        }

        // ---- bounded NN-Descent repair ----
        let (graph, repair) =
            NnDescent::new(params.clone()).repair(&new_data, graph, self.cfg.repair_iters);

        // ---- write the new segment and swap it in atomically ----
        let norms = GraphIndex::compute_norms(&new_data);
        let lanes = crate::distance::dispatch::active_width().lanes();
        let centroids = match &self.base {
            BaseSegment::V2(s) => s.centroids(),
            BaseSegment::Legacy(i) => i.centroids(),
        };
        let tmp = {
            let mut os = self.path.as_os_str().to_os_string();
            os.push(".compact.tmp");
            std::path::PathBuf::from(os)
        };
        write_segment(
            &tmp,
            &SegmentSpec {
                data: &new_data,
                ids: graph.flat_ids(),
                dists: graph.flat_dists(),
                k: k2,
                params: &params,
                norms: Some((&norms, lanes)),
                idmap: Some(&ext_ids),
                centroids,
                generation: new_gen,
            },
        )?;
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("renaming {} into place", tmp.display()))?;

        // Reopen the renamed file: the serving state after compaction
        // IS a fresh open of the compacted segment, by construction.
        // (In-flight readers of the old mapping keep it alive through
        // their Arc until they finish.)
        let seg = Segment::open_with(&self.path, self.cfg.mode)?;
        let bytes = std::fs::metadata(&self.path).map(|m| m.len()).unwrap_or(0);
        self.base = BaseSegment::V2(seg);
        self.base_ids = ext_ids.into_iter().collect();
        self.tombstones.clear();
        self.delta = DeltaSegment::new(dim);
        self.wal.reset()?;
        // answers are unchanged by construction, but the swap is the
        // conservative moment to invalidate any cached ones
        self.epoch += 1;

        Ok(CompactionStats {
            rows: n2,
            folded,
            dropped,
            generation: new_gen,
            repair,
            bytes,
            secs: t0.elapsed().as_secs_f64(),
        })
    }
}
