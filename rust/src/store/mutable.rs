//! The storage engine's facade: one handle that serves a base segment
//! zero-copy, absorbs inserts/deletes through the WAL into the delta,
//! and folds the delta back into a fresh segment on compaction.
//!
//! Query semantics: the base and the delta are merged exactly like two
//! shards of a [`ShardedSearcher`](crate::api::ShardedSearcher) — same
//! comparator, same id-level dedup — with tombstoned base ids filtered
//! *before* the top-k cut (the base is over-fetched by the tombstone
//! count so masking never starves the result list).

use super::bytes::StoreMode;
use super::delta::DeltaSegment;
use super::format::Segment;
use super::wal::{Wal, WalConfig, WalRecord};
use crate::api::{Neighbor, OriginalId, Searcher, ShardedSearcher, WorkingId};
use crate::dataset::AlignedMatrix;
use crate::search::{BatchStats, QueryStats, SearchParams};
use anyhow::{bail, Context, Result};
use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};

/// Tuning knobs for a [`MutableIndex`].
#[derive(Debug, Clone)]
pub struct StoreConfig {
    /// How to bring segment bytes in (`None` = resolve `PALLAS_STORE`,
    /// then the platform default).
    pub mode: Option<StoreMode>,
    /// Auto-compact when the delta holds at least this fraction of the
    /// base's rows. `<= 0` disables the trigger entirely.
    pub auto_compact_ratio: f64,
    /// ...but never before the delta holds this many rows (keeps tiny
    /// indexes from compacting on every insert).
    pub auto_compact_min: usize,
    /// NN-Descent repair iterations budget per compaction.
    pub repair_iters: usize,
    /// WAL group-commit window, microseconds (see
    /// [`WalConfig::group_commit_us`]). `0` = fsync per append.
    pub group_commit_us: u64,
}

impl Default for StoreConfig {
    fn default() -> Self {
        Self {
            mode: None,
            auto_compact_ratio: 0.5,
            auto_compact_min: 64,
            repair_iters: 8,
            group_commit_us: 0,
        }
    }
}

/// The immutable layer under a [`MutableIndex`]: a zero-copy `KNNIv2`
/// segment, or a legacy `KNNIv1` bundle heap-loaded through the
/// existing [`Index`](crate::api::Index) path so old artifacts keep
/// serving bit-identically.
pub enum BaseSegment {
    V2(Segment),
    Legacy(crate::api::Index),
}

impl BaseSegment {
    pub fn n(&self) -> usize {
        match self {
            Self::V2(s) => s.n(),
            Self::Legacy(i) => i.len(),
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            Self::V2(s) => s.dim(),
            Self::Legacy(i) => i.dim(),
        }
    }

    /// Compaction generation (legacy bundles predate the counter: 0).
    pub fn generation(&self) -> u64 {
        match self {
            Self::V2(s) => s.generation(),
            Self::Legacy(_) => 0,
        }
    }

    /// Search the base, results in external ids, canonical
    /// `(distance, id)` order.
    fn search(&self, query: &[f32], k: usize, params: &SearchParams) -> (Vec<Neighbor>, QueryStats) {
        match self {
            Self::V2(s) => {
                let mut scratch = s.scratch();
                let (raw, stats) = s.search_raw(query, k, params, &mut scratch);
                (map_external(s, raw), stats)
            }
            Self::Legacy(i) => i.search(query, k, params),
        }
    }

    fn search_batch(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        match self {
            Self::V2(s) => {
                let mut scratch = s.scratch();
                let (raw, stats) = s.search_batch_raw(queries, k, params, &mut scratch);
                (raw.into_iter().map(|r| map_external(s, r)).collect(), stats)
            }
            Self::Legacy(i) => i.search_batch(queries, k, params),
        }
    }
}

/// Map working-id results to external ids. A segment with an idmap can
/// surface distance ties in working-layout order, so re-sort into the
/// canonical boundary order (same rule as `Index::map_results`).
fn map_external(seg: &Segment, raw: Vec<(u32, f32)>) -> Vec<Neighbor> {
    let mut out: Vec<Neighbor> = raw
        .into_iter()
        .map(|(w, d)| Neighbor { id: OriginalId(seg.external_id(w)), dist: d })
        .collect();
    if seg.idmap().is_some() {
        out.sort_unstable_by(|a, b| a.dist.total_cmp(&b.dist).then(a.id.get().cmp(&b.id.get())));
    }
    out
}

/// A mutable K-NN index over one on-disk base segment: zero-copy
/// reads, WAL-durable writes, LSM-style compaction.
pub struct MutableIndex {
    pub(super) path: PathBuf,
    pub(super) cfg: StoreConfig,
    pub(super) base: BaseSegment,
    pub(super) delta: DeltaSegment,
    /// External ids present in the base but deleted (or re-inserted —
    /// the delta then carries the fresh row and the stale base copy
    /// stays masked). Invariant: every member is in `base_ids`.
    pub(super) tombstones: HashSet<u32>,
    /// External ids the base can return.
    pub(super) base_ids: HashSet<u32>,
    pub(super) wal: Wal,
    /// Monotone mutation counter: bumped on every applied
    /// insert/delete and on every compaction. Unlike
    /// [`generation`](Self::generation) (which only moves at
    /// compaction) this moves the moment an answer could change, so it
    /// is the correct key for answer caches (see
    /// [`Searcher::cache_epoch`]). In-process only — not persisted.
    pub(super) epoch: u64,
}

impl MutableIndex {
    /// Open `path` (a `KNNIv2` segment or legacy `KNNIv1` bundle) and
    /// replay its WAL sidecar, if any.
    pub fn open(path: &Path) -> Result<Self> {
        Self::open_with(path, StoreConfig::default())
    }

    /// [`open`](Self::open) with explicit configuration.
    pub fn open_with(path: &Path, cfg: StoreConfig) -> Result<Self> {
        let mut magic = [0u8; 8];
        {
            use std::io::Read;
            let mut f = std::fs::File::open(path)
                .with_context(|| format!("opening {}", path.display()))?;
            f.read_exact(&mut magic)
                .with_context(|| format!("{} is too small to be an index", path.display()))?;
        }
        let base = if &magic == super::format::MAGIC_V2 {
            BaseSegment::V2(Segment::open_with(path, cfg.mode)?)
        } else if magic.starts_with(b"KNNI") {
            BaseSegment::Legacy(crate::api::Index::load(path)?)
        } else {
            bail!("{} is neither a KNNIv2 segment nor a KNNIv1 bundle", path.display());
        };

        let base_ids: HashSet<u32> = match &base {
            BaseSegment::V2(s) => match s.idmap() {
                Some(map) => map.iter().copied().collect(),
                None => (0..s.n() as u32).collect(),
            },
            BaseSegment::Legacy(i) => {
                (0..i.len() as u32).map(|w| i.to_original(WorkingId(w)).get()).collect()
            }
        };
        if base_ids.len() != base.n() {
            bail!("base segment external ids are not unique");
        }

        let wal_cfg = WalConfig { group_commit_us: cfg.group_commit_us };
        let (wal, records) = Wal::open_with(&wal_path(path), wal_cfg)?;
        let mut me = Self {
            path: path.to_path_buf(),
            delta: DeltaSegment::new(base.dim()),
            cfg,
            base,
            tombstones: HashSet::new(),
            base_ids,
            wal,
            epoch: 0,
        };
        for rec in records {
            me.apply(&rec)?;
        }
        if me.delta.live_count() > 0 || !me.tombstones.is_empty() {
            crate::log_info!(
                "{}: WAL replay restored {} delta row(s), {} tombstone(s)",
                path.display(),
                me.delta.live_count(),
                me.tombstones.len()
            );
        }
        Ok(me)
    }

    /// Apply one (already logged or replayed) mutation to in-memory
    /// state. Never touches the WAL.
    fn apply(&mut self, rec: &WalRecord) -> Result<()> {
        match rec {
            WalRecord::Insert { id, row } => {
                if row.len() != self.delta.dim() {
                    bail!(
                        "WAL row for id {id} has dim {}, index has dim {} — log belongs to \
                         another index",
                        row.len(),
                        self.delta.dim()
                    );
                }
                if self.base_ids.contains(id) {
                    self.tombstones.insert(*id);
                }
                self.delta.insert(*id, row);
            }
            WalRecord::Delete { id } => {
                self.delta.delete(*id);
                if self.base_ids.contains(id) {
                    self.tombstones.insert(*id);
                }
            }
        }
        self.epoch += 1;
        Ok(())
    }

    /// Insert (or overwrite) the row for external id `id`. Durable in
    /// the WAL before it is visible; visible to the next query after.
    /// May trigger auto-compaction on the way out.
    pub fn insert(&mut self, id: u32, row: &[f32]) -> Result<()> {
        if row.len() != self.delta.dim() {
            bail!("row has dim {}, index has dim {}", row.len(), self.delta.dim());
        }
        if id == u32::MAX {
            bail!("id u32::MAX is reserved");
        }
        let rec = WalRecord::Insert { id, row: row.to_vec() };
        self.wal.append(&rec)?;
        self.apply(&rec)?;
        self.maybe_auto_compact()
    }

    /// Delete external id `id`. Returns `false` (and logs nothing)
    /// when the id is not live.
    pub fn delete(&mut self, id: u32) -> Result<bool> {
        let live = self.delta.contains_live(id)
            || (self.base_ids.contains(&id) && !self.tombstones.contains(&id));
        if !live {
            return Ok(false);
        }
        let rec = WalRecord::Delete { id };
        self.wal.append(&rec)?;
        self.apply(&rec)?;
        Ok(true)
    }

    fn maybe_auto_compact(&mut self) -> Result<()> {
        if self.cfg.auto_compact_ratio <= 0.0 {
            return Ok(());
        }
        let live = self.delta.live_count();
        if live >= self.cfg.auto_compact_min
            && live as f64 >= self.cfg.auto_compact_ratio * self.base.n() as f64
        {
            let stats = self.compact()?;
            crate::log_info!(
                "auto-compacted {}: {} rows folded in {:.3}s (generation {})",
                self.path.display(),
                stats.rows,
                stats.secs,
                stats.generation
            );
        }
        Ok(())
    }

    /// Number of live points (base minus tombstones plus delta).
    pub fn len(&self) -> usize {
        self.base.n() - self.tombstones.len() + self.delta.live_count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Logical dimensionality.
    pub fn dim(&self) -> usize {
        self.delta.dim()
    }

    /// The base layer (segment or legacy bundle).
    pub fn base(&self) -> &BaseSegment {
        &self.base
    }

    /// Rows currently in the mutable delta.
    pub fn delta_len(&self) -> usize {
        self.delta.live_count()
    }

    /// Base ids currently masked.
    pub fn tombstone_count(&self) -> usize {
        self.tombstones.len()
    }

    /// Compaction generation of the base layer.
    pub fn generation(&self) -> u64 {
        self.base.generation()
    }

    /// Monotone in-process mutation counter: moves on every applied
    /// insert/delete and on every compaction. The answer-cache epoch.
    pub fn mutation_epoch(&self) -> u64 {
        self.epoch
    }

    /// The segment path this index serves.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Bytes currently pending in the WAL.
    pub fn wal_bytes(&self) -> u64 {
        self.wal.len_bytes()
    }

    /// How many nearest to ask the base for so that tombstone masking
    /// still leaves `k` candidates.
    fn base_k(&self, k: usize) -> usize {
        (k + self.tombstones.len()).min(self.base.n())
    }

    fn merge_with_delta(&self, base_hits: Vec<Neighbor>, query: &[f32], k: usize) -> Vec<Neighbor> {
        let mut all: Vec<Neighbor> = base_hits
            .into_iter()
            .filter(|nb| !self.tombstones.contains(&nb.id.get()))
            .collect();
        all.extend(
            self.delta
                .search(query, k)
                .into_iter()
                .map(|(id, dist)| Neighbor { id: OriginalId(id), dist }),
        );
        ShardedSearcher::merge(all, k)
    }

    /// The `k` nearest live neighbors of `query` (external ids). Stats
    /// cover the base graph search; the delta scan adds no beam stats.
    pub fn search(&self, query: &[f32], k: usize, params: &SearchParams) -> (Vec<Neighbor>, QueryStats) {
        let (base_hits, stats) = self.base.search(query, self.base_k(k), params);
        (self.merge_with_delta(base_hits, query, k), stats)
    }

    /// Batched [`search`](Self::search).
    pub fn search_batch(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        let (base_hits, stats) = self.base.search_batch(queries, self.base_k(k), params);
        let merged = base_hits
            .into_iter()
            .enumerate()
            .map(|(qi, hits)| self.merge_with_delta(hits, queries.row_logical(qi), k))
            .collect();
        (merged, stats)
    }
}

impl Searcher for MutableIndex {
    fn len(&self) -> usize {
        MutableIndex::len(self)
    }

    fn search(&self, query: &[f32], k: usize, params: &SearchParams) -> (Vec<Neighbor>, QueryStats) {
        MutableIndex::search(self, query, k, params)
    }

    fn search_batch(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        MutableIndex::search_batch(self, queries, k, params)
    }

    fn cache_epoch(&self) -> Option<u64> {
        Some(self.mutation_epoch())
    }
}

/// A shareable, lock-guarded [`MutableIndex`] — the shape the serving
/// stack wants: readers take the read lock (concurrent), mutations and
/// compaction take the write lock. Implements [`Searcher`], so it
/// flows through [`ServeFront`](crate::api::ServeFront) and the
/// network server unchanged.
#[derive(Clone)]
pub struct SharedMutableIndex(Arc<RwLock<MutableIndex>>);

impl SharedMutableIndex {
    pub fn new(index: MutableIndex) -> Self {
        Self(Arc::new(RwLock::new(index)))
    }

    /// Open via [`MutableIndex::open_with`].
    pub fn open_with(path: &Path, cfg: StoreConfig) -> Result<Self> {
        Ok(Self::new(MutableIndex::open_with(path, cfg)?))
    }

    pub fn insert(&self, id: u32, row: &[f32]) -> Result<()> {
        self.0.write().expect("store lock poisoned").insert(id, row)
    }

    pub fn delete(&self, id: u32) -> Result<bool> {
        self.0.write().expect("store lock poisoned").delete(id)
    }

    pub fn compact(&self) -> Result<super::CompactionStats> {
        self.0.write().expect("store lock poisoned").compact()
    }

    pub fn generation(&self) -> u64 {
        self.0.read().expect("store lock poisoned").generation()
    }

    /// The store's mutation epoch (see
    /// [`MutableIndex::mutation_epoch`]).
    pub fn mutation_epoch(&self) -> u64 {
        self.0.read().expect("store lock poisoned").mutation_epoch()
    }

    pub fn live_len(&self) -> usize {
        self.0.read().expect("store lock poisoned").len()
    }

    pub fn dim(&self) -> usize {
        self.0.read().expect("store lock poisoned").dim()
    }
}

impl Searcher for SharedMutableIndex {
    fn len(&self) -> usize {
        self.live_len()
    }

    fn search(&self, query: &[f32], k: usize, params: &SearchParams) -> (Vec<Neighbor>, QueryStats) {
        self.0.read().expect("store lock poisoned").search(query, k, params)
    }

    fn search_batch(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<Neighbor>>, BatchStats) {
        self.0.read().expect("store lock poisoned").search_batch(queries, k, params)
    }

    /// The mutation epoch: the serving front flushes its answer cache
    /// whenever this moves, so a cached answer never outlives the rows
    /// it names.
    fn cache_epoch(&self) -> Option<u64> {
        Some(self.mutation_epoch())
    }
}

/// The WAL sidecar path for a segment: `<file>.wal` next to it.
pub(super) fn wal_path(segment: &Path) -> PathBuf {
    let mut os = segment.as_os_str().to_os_string();
    os.push(".wal");
    PathBuf::from(os)
}
