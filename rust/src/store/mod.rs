//! The storage engine: zero-copy `KNNIv2` segments, a WAL-backed
//! mutable delta, and compaction — the LSM-style layer that takes the
//! paper's locality story past process exit.
//!
//! The read path is a [`Segment`]: a 64-byte-aligned, section-padded
//! `KNNIv2` bundle whose graph/data/norms/centroid sections are
//! reinterpreted **in place** from an mmap'd file (or, behind the same
//! enum, from one 64-byte-aligned heap buffer on platforms without
//! mmap). Because the on-disk data section stores rows padded exactly
//! like [`AlignedMatrix`](crate::dataset::AlignedMatrix) lays them out
//! in memory, the mapped bytes back the matrix directly — opening an
//! index never copies the corpus, and mmap and heap-copy modes parse
//! identical bytes at identical offsets, so they are bitwise
//! interchangeable.
//!
//! The write path is a [`MutableIndex`]: inserts and deletes go to a
//! checksummed write-ahead log first ([`Wal`], FNV-trailer records,
//! replay-on-open with torn-tail truncation), then into an in-memory
//! [`DeltaSegment`] (brute-force searched) and a tombstone set masking
//! base-segment ids. Queries merge base + delta like two shards of a
//! [`ShardedSearcher`](crate::api::ShardedSearcher) — same comparator,
//! same dedup — with tombstones filtered before the top-k.
//!
//! Compaction ([`MutableIndex::compact`], auto-triggered by a
//! size-ratio policy) folds delta + tombstones into a fresh `KNNIv2`
//! segment using bounded NN-Descent *repair* iterations
//! ([`NnDescent::repair`](crate::nndescent::NnDescent::repair)) seeded
//! from the surviving edges of the old graph — not a full rebuild —
//! then atomically renames the new segment into place and bumps its
//! generation counter. In-flight readers keep the old mapping alive
//! through its `Arc` until they finish.
//!
//! Legacy `KNNIv1` bundles open through the same [`MutableIndex`]
//! facade (heap-loaded, exactly as before) so every existing artifact
//! keeps serving bit-identically.

pub mod bytes;
pub mod compact;
pub mod delta;
pub mod format;
pub mod mutable;
pub mod wal;

pub use bytes::{SegmentBytes, StoreMode};
pub use compact::CompactionStats;
pub use delta::DeltaSegment;
pub use format::{convert_v1_to_v2, write_segment, Segment, SegmentSpec};
pub use mutable::{BaseSegment, MutableIndex, SharedMutableIndex, StoreConfig};
pub use wal::{Wal, WalConfig, WalRecord};
