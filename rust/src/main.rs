//! `knng` — CLI launcher for the K-NN graph pipeline.
//!
//! Subcommands:
//!   build     build a K-NN graph (config file or flags), report stats
//!   gen       generate a dataset and write it as .fvecs
//!   query     serve ANN queries — batched from a KNNIv1 index bundle,
//!             over the wire from a running server (--connect), or one
//!             at a time from a bare graph + corpus
//!   serve     run the KNNQv1 network server over KNNIv1 bundle(s)
//!   store     the mutable storage engine: convert KNNIv1 bundles to
//!             zero-copy KNNIv2 segments, inspect/query them, apply
//!             WAL-backed inserts/deletes, compact, and serve with
//!             the wire mutation surface enabled
//!   check     verify AOT artifacts load and the PJRT engine matches
//!             the native kernels (requires --features pjrt)
//!   info      print version, defaults, artifact inventory
//!
//! Examples:
//!   knng build --config configs/mnist.toml
//!   knng build --dataset clustered --n 16k --dim 8 --clusters 16 \
//!              --selection turbo --compute blocked --reorder
//!   knng build --dataset clustered --n 131k --dim 8 --threads 4
//!   knng build --dataset fvecs --path corpus.fvecs --n 100k --reorder \
//!              --save-index corpus.knni
//!   knng build --dataset clustered --n 64k --dim 8 --shards 4 \
//!              --partitioner kmeans
//!   knng query --index corpus.knni --batch queries.fvecs --k 10 --ef 64
//!   knng query --index a.knni --index b.knni --batch queries.fvecs \
//!              --route-top-m 1
//!   knng query --index corpus.knni --batch queries.fvecs --kernel w16
//!   knng query --index corpus.knni --batch queries.fvecs --serve \
//!              --threads 4 --max-batch 128 --batch-window 500
//!   knng serve --listen 127.0.0.1:7070 --index corpus.knni --k 10 \
//!              --threads 4 --answer-cache 4096
//!   knng query --connect 127.0.0.1:7070 --batch queries.fvecs --k 10
//!   knng gen --dataset gaussian --n 4096 --dim 64 --out /tmp/g.fvecs
//!   knng check --artifacts artifacts

use knng::api::{EvalOptions, Index, IndexBuilder, Searcher};
use knng::cli::{apply_kernel_override, parse_args, ArgSpec, KERNEL_FLAG, KERNEL_HELP};
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::config::{DatasetSpec, ExperimentConfig, RunConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("build") => cmd_build(&args[1..]),
        Some("gen") => cmd_gen(&args[1..]),
        Some("query") => cmd_query(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("store") => cmd_store(&args[1..]),
        Some("check") => cmd_check(&args[1..]),
        Some("info") => cmd_info(&args[1..]),
        Some("--help") | Some("-h") | Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => Err(anyhow::anyhow!("unknown subcommand `{other}` (see `knng help`)")),
    }
    .map_or_else(
        |e| {
            eprintln!("error: {e:#}");
            1
        },
        |_| 0,
    );
    std::process::exit(code);
}

fn print_help() {
    println!(
        "knng {} — fast single-core K-NN graph computation (NN-Descent)\n\n\
         subcommands:\n  \
         build   build a K-NN graph and report stats/recall\n  \
         gen     generate a synthetic dataset to .fvecs\n  \
         query   serve ANN queries (batched via --index bundle, --connect, or --graph)\n  \
         serve   run the KNNQv1 network server over KNNIv1 bundle(s)\n  \
         store   mutable storage engine: convert|info|query|insert|delete|compact|serve\n  \
         check   validate AOT artifacts + PJRT numerics\n  \
         info    version, defaults, artifact inventory\n\n\
         run `knng <cmd> --help` for flags",
        knng::VERSION
    );
}

fn build_spec() -> ArgSpec {
    ArgSpec::new()
        .value("config", "TOML config file (flags below override)")
        .value("dataset", "gaussian|clustered|mnist|audio|fvecs")
        .value("n", "number of points (k/m suffixes ok)")
        .value("dim", "dimensionality")
        .value("clusters", "clusters (clustered dataset)")
        .value("path", "dataset file path (mnist/fvecs)")
        .value("k", "neighbors per node (default 20)")
        .value("rho", "sample rate (default 0.5)")
        .value("delta", "convergence threshold (default 0.001)")
        .value("selection", "naive|heap|turbo (default turbo)")
        .value("compute", "scalar|unrolled|blocked|pjrt (default blocked)")
        .value("threads", "build worker threads; 1 = exact sequential engine (default: PALLAS_BUILD_THREADS env, else 1)")
        .value("shards", "partition the corpus and build S independent shard subgraphs (default 1 = single index)")
        .value("partitioner", "shard partitioner: contiguous|kmeans (with --shards; default contiguous)")
        .value(KERNEL_FLAG, KERNEL_HELP)
        .flag("reorder", "enable the greedy reordering heuristic")
        .value("seed", "PRNG seed (default 1)")
        .value("max-iters", "iteration cap (default 40)")
        .value("recall-queries", "sampled ground-truth queries (default 500, 0=off)")
        .value("artifacts", "artifact dir for --compute pjrt (default artifacts)")
        .value("save", "write the built graph (original id space) to this path")
        .value("save-index", "write a KNNIv1 index bundle (graph+data+params) to this path")
        .flag("tsv", "emit a TSV row instead of the human report")
        .flag("help", "show this help")
}

fn cmd_build(argv: &[String]) -> anyhow::Result<()> {
    let spec = build_spec();
    let m = parse_args(&spec, argv)?;
    if m.has("help") {
        print!("{}", spec.usage("build"));
        return Ok(());
    }
    apply_kernel_override(&m)?;

    let mut cfg = match m.get("config") {
        Some(path) => ExperimentConfig::load(std::path::Path::new(path))?,
        None => ExperimentConfig {
            name: "cli".into(),
            dataset: DatasetSpec::Gaussian { n: 16_384, dim: 8, single: true, seed: 0x5eed },
            run: RunConfig::default(),
        },
    };

    // flag overrides
    if let Some(kind) = m.get("dataset") {
        let n = m.usize_or("n", 16_384)?;
        let dim = m.usize_or("dim", 8)?;
        let seed = m.u64_or("seed", 0x5eed)?;
        cfg.dataset = match kind {
            "gaussian" => DatasetSpec::Gaussian { n, dim, single: true, seed },
            "gaussian-multi" => DatasetSpec::Gaussian { n, dim, single: false, seed },
            "clustered" => DatasetSpec::Clustered { n, dim, clusters: m.usize_or("clusters", 16)?, seed },
            "mnist" => DatasetSpec::Mnist { n: m.usize_or("n", 70_000)?, path: m.get("path").map(String::from), seed },
            "audio" => DatasetSpec::Audio { n: m.usize_or("n", 54_387)?, dim: m.usize_or("dim", 192)?, seed },
            "fvecs" => DatasetSpec::Fvecs {
                path: m.get("path").ok_or_else(|| anyhow::anyhow!("--path required for fvecs"))?.to_string(),
                limit: n,
            },
            other => anyhow::bail!("unknown --dataset `{other}`"),
        };
    }
    cfg.run.k = m.usize_or("k", cfg.run.k)?;
    cfg.run.rho = m.f64_or("rho", cfg.run.rho)?;
    cfg.run.delta = m.f64_or("delta", cfg.run.delta)?;
    cfg.run.seed = m.u64_or("seed", cfg.run.seed)?;
    cfg.run.max_iters = m.usize_or("max-iters", cfg.run.max_iters)?;
    if let Some(s) = m.get("selection") {
        cfg.run.selection =
            SelectionKind::parse(s).ok_or_else(|| anyhow::anyhow!("bad --selection `{s}`"))?;
    }
    if let Some(s) = m.get("compute") {
        cfg.run.compute =
            ComputeKind::parse(s).ok_or_else(|| anyhow::anyhow!("bad --compute `{s}`"))?;
    }
    if m.has("reorder") {
        cfg.run.reorder = true;
    }
    cfg.run.artifacts_dir = m.str_or("artifacts", &cfg.run.artifacts_dir).to_string();

    let eval = EvalOptions::new()
        .with_recall_queries(m.usize_or("recall-queries", 500)?)
        .with_seed(cfg.run.seed);
    let mut builder = IndexBuilder::from_config(&cfg).log_progress();
    // knob precedence: --threads > PALLAS_BUILD_THREADS env > 1
    // (0 = "not given here", which leaves the env/default resolution on)
    let threads = m.usize_or("threads", 0)?;
    if threads > 0 {
        builder = builder.threads(threads);
    }
    // --shards S > 1 diverts into the sharded build path (no recall
    // report there: the RunReport machinery evaluates single indexes)
    let shards = m.usize_or("shards", 1)?;
    if m.has("partitioner") && shards <= 1 {
        anyhow::bail!("--partitioner requires --shards > 1");
    }
    if shards > 1 {
        return build_sharded(builder, shards, cfg.run.seed, &m);
    }
    let index = builder.build()?;
    let report = index.evaluate(&eval);
    if let Some(path) = m.get("save") {
        // persist in the *original* id space (undo any reordering)
        index.save_graph(std::path::Path::new(path))?;
        eprintln!("saved graph to {path}");
    }
    if let Some(path) = m.get("save-index") {
        // persist the full serving bundle: graph + data in the *working*
        // layout (keeps reorder locality) + σ to map ids back + params
        index.save(std::path::Path::new(path))?;
        eprintln!("saved index bundle to {path}");
    }
    if m.has("tsv") {
        println!("{}", knng::pipeline::RunReport::tsv_header());
        println!("{}", report.tsv_row());
    } else {
        print!("{}", report.render());
    }
    Ok(())
}

/// The `build --shards S` path: partition the corpus, build every
/// shard's subgraph (concurrently when `--threads` allows), and
/// optionally persist one KNNIv1 bundle per shard via `--save-index`.
/// Bundles can only express contiguous row ranges, so `--save-index`
/// pairs with the contiguous partitioner; k-means shards serve
/// in-process.
fn build_sharded(
    builder: IndexBuilder<'_>,
    shards: usize,
    seed: u64,
    m: &knng::cli::ArgMatches,
) -> anyhow::Result<()> {
    use knng::api::partition::{Contiguous, KMeans, Partitioner};
    if m.get("save").is_some() {
        anyhow::bail!("--save (bare graph) is not available with --shards; use --save-index");
    }
    let kind = m.str_or("partitioner", "contiguous");
    let partitioner: Box<dyn Partitioner> = match kind {
        "contiguous" => Box::new(Contiguous),
        "kmeans" => Box::new(KMeans::new(seed)),
        other => anyhow::bail!("unknown --partitioner `{other}` (contiguous|kmeans)"),
    };
    let t0 = std::time::Instant::now();
    let sharded = builder.build_sharded_with(shards, &*partitioner)?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "built {} {kind} shard(s) over {} points (dim {}) in {secs:.3}s — sizes {:?}",
        sharded.shard_count(),
        sharded.len(),
        sharded.dim(),
        sharded.shard_sizes(),
    );
    if let Some(path) = m.get("save-index") {
        let paths = sharded.save_shards(std::path::Path::new(path))?;
        for p in &paths {
            eprintln!("saved shard bundle to {}", p.display());
        }
        let flags: Vec<String> =
            paths.iter().map(|p| format!("--index {}", p.display())).collect();
        eprintln!("serve them together: knng query {} --batch <fvecs>", flags.join(" "));
    }
    Ok(())
}

fn cmd_query(argv: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new()
        .multi("index", "KNNIv1 index bundle from `build --save-index`; repeat to serve several bundles as shards")
        .value("batch", ".fvecs query vectors, served through the batched path (with --index)")
        .value("graph", "saved graph file from `build --save` (legacy; pairs with --data)")
        .value("data", ".fvecs corpus the graph was built on (with --graph)")
        .value("queries", ".fvecs query vectors, served one at a time (with --graph)")
        .value("connect", "query a running `knng serve` server at this address instead of loading bundles")
        .value("net-timeout", "wire read/write timeout for --connect, seconds (default 30, 0 = none)")
        .value("deadline-us", "per-query latency budget for --connect, microseconds (default 0 = none; late shards are dropped and the answer tagged degraded)")
        .value("net-retries", "attempts per wire operation for --connect on transient transport failures (default 3)")
        .value("k", "neighbors per query (default 10)")
        .value("ef", "beam width (default 64)")
        .value("route-top-m", "centroid-route each query to its m nearest shards (default: full fan-out)")
        .value(KERNEL_FLAG, KERNEL_HELP)
        .flag("serve", "serve via the threaded micro-batching runtime (with --index)")
        .value("threads", "worker threads for --serve (clamped to the shard count; default 1)")
        .value("replicas", "copies of each shard's serving state for --serve; a shard degrades only when all copies are gone (default 1)")
        .value("hedge-us", "hedge delay for --serve, microseconds: re-send a straggling shard's job to the next replica after this long (default 0 = off; needs --replicas > 1)")
        .value("max-batch", "max queries coalesced per window for --serve (default 64)")
        .value("batch-window", "batching window for --serve, microseconds (default 200)")
        .flag("stats", "print the aggregate QueryStats breakdown to stderr")
        .flag("help", "show this help");
    let m = parse_args(&spec, argv)?;
    if m.has("help") {
        print!("{}", spec.usage("query"));
        return Ok(());
    }
    apply_kernel_override(&m)?;
    let k = m.usize_or("k", 10)?;
    let params = knng::search::SearchParams {
        ef: m.usize_or("ef", 64)?,
        ..Default::default()
    };

    if let Some(addr) = m.get("connect") {
        // ---- wire path: query a running `knng serve` server -------------
        return query_connect(addr, k, &m);
    }

    let index_paths = m.get_all("index");
    if !index_paths.is_empty() {
        use knng::api::ShardedSearcher;
        // ---- batched serving from KNNIv1 bundle(s) ----------------------
        let qpath = m
            .get("batch")
            .or_else(|| m.get("queries"))
            .ok_or_else(|| anyhow::anyhow!("--batch <fvecs> is required with --index"))?;
        let queries = knng::dataset::fvecs::read_fvecs(std::path::Path::new(qpath), usize::MAX)?;
        let route_top_m = parse_route_top_m(&m)?;

        if index_paths.len() == 1 && route_top_m.is_none() {
            // single bundle, full fan-out: the historical serving path
            let index = Index::load(std::path::Path::new(&index_paths[0]))?;
            anyhow::ensure!(
                queries.dim() == index.dim(),
                "query dim {} does not match index dim {}",
                queries.dim(),
                index.dim()
            );
            if m.has("serve") {
                let label = (index.len(), index.graph_k());
                let sharded = ShardedSearcher::from_index(index);
                return serve_queries(sharded, queries, k, params, None, label, &m);
            }
            // Searcher results are OriginalId — no σ bookkeeping here.
            let (results, stats) = index.search_batch(&queries, k, &params);
            print_result_rows(&results);
            eprintln!(
                "{} queries in {:.3}s ({:.0} qps), {:.0} evals/query, {:.1} expansions/query \
                 [kernel {}; index n={}, graph k={}, built {}/{}{}]",
                stats.queries,
                stats.secs,
                stats.qps(),
                stats.dist_evals_per_query(),
                stats.expansions_per_query(),
                stats.kernel,
                index.len(),
                index.graph_k(),
                index.params().selection.name(),
                index.params().compute.name(),
                if index.is_reordered() { "+reorder" } else { "" },
            );
            if m.has("stats") {
                eprintln!(
                    "totals: {} distance evaluations, {} expansions, ef={}, k={k}",
                    stats.dist_evals, stats.expansions, params.ef
                );
            }
            return Ok(());
        }

        // ---- several bundles as shards, and/or centroid routing ---------
        let mut indexes = Vec::with_capacity(index_paths.len());
        for p in index_paths {
            indexes.push(Index::load(std::path::Path::new(p))?);
        }
        let graph_k = indexes[0].graph_k();
        let sharded = match indexes.len() {
            1 => ShardedSearcher::from_index(indexes.pop().expect("one bundle")),
            _ => ShardedSearcher::from_indexes(indexes)?,
        };
        anyhow::ensure!(
            queries.dim() == sharded.dim(),
            "query dim {} does not match index dim {}",
            queries.dim(),
            sharded.dim()
        );
        if m.has("serve") {
            let label = (sharded.len(), graph_k);
            return serve_queries(sharded, queries, k, params, route_top_m, label, &m);
        }
        let (results, stats) = match route_top_m {
            Some(top_m) => sharded.search_batch_routed(&queries, k, &params, top_m),
            None => sharded.search_batch(&queries, k, &params),
        };
        print_result_rows(&results);
        let fanout = match route_top_m {
            Some(v) => format!("top-{}", v.min(sharded.shard_count())),
            None => "full".to_string(),
        };
        eprintln!(
            "{} queries in {:.3}s ({:.0} qps), {:.0} evals/query, {:.1} expansions/query, \
             {:.2} shard visit(s)/query [kernel {}; {} shard(s), n={}, graph k={graph_k}, \
             fan-out {fanout}]",
            stats.queries,
            stats.secs,
            stats.qps(),
            stats.dist_evals_per_query(),
            stats.expansions_per_query(),
            stats.shard_visits as f64 / stats.queries.max(1) as f64,
            stats.kernel,
            sharded.shard_count(),
            sharded.len(),
        );
        if m.has("stats") {
            eprintln!(
                "totals: {} distance evaluations, {} expansions, {} shard visits, ef={}, k={k}",
                stats.dist_evals, stats.expansions, stats.shard_visits, params.ef
            );
        }
        return Ok(());
    }

    // ---- legacy path: bare graph + corpus, one query at a time ----------
    let need = |k: &str| {
        m.get(k).map(String::from).ok_or_else(|| anyhow::anyhow!("--{k} is required"))
    };
    let graph = knng::graph::load_graph(std::path::Path::new(&need("graph")?))?;
    let data = knng::dataset::fvecs::read_fvecs(std::path::Path::new(&need("data")?), usize::MAX)?;
    let queries =
        knng::dataset::fvecs::read_fvecs(std::path::Path::new(&need("queries")?), usize::MAX)?;
    anyhow::ensure!(queries.dim() == data.dim(), "query/corpus dim mismatch");
    // a bare graph has no σ, so GraphIndex's id space is already the
    // caller's original row space; the Searcher impl types it as such
    let index: &dyn Searcher = &knng::search::GraphIndex::new(data, graph);
    let t0 = std::time::Instant::now();
    let mut total_evals = 0u64;
    for qi in 0..queries.n() {
        let (res, stats) = index.search(queries.row_logical(qi), k, &params);
        total_evals += stats.dist_evals;
        let row: Vec<String> = res.iter().map(|nb| format!("{}:{:.4}", nb.id, nb.dist)).collect();
        println!("{qi}\t{}", row.join("\t"));
    }
    let secs = t0.elapsed().as_secs_f64();
    eprintln!(
        "{} queries in {:.3}s ({:.0} qps), {:.0} evals/query",
        queries.n(),
        secs,
        queries.n() as f64 / secs,
        total_evals as f64 / queries.n() as f64
    );
    Ok(())
}

/// Shared `--route-top-m` parsing: absent = full fan-out, present
/// must be ≥ 1.
fn parse_route_top_m(m: &knng::cli::ArgMatches) -> anyhow::Result<Option<usize>> {
    match m.get("route-top-m") {
        None => Ok(None),
        Some(_) => {
            let v = m.usize_or("route-top-m", 0)?;
            anyhow::ensure!(v >= 1, "--route-top-m must be at least 1");
            Ok(Some(v))
        }
    }
}

/// The `query --connect` path: ship the batch to a running
/// `knng serve` server over the KNNQv1 wire protocol. Same stdout
/// contract as every other `query` serving path — and the same
/// neighbors, bit for bit (the loopback bit-equality guarantee).
fn query_connect(addr: &str, k: usize, m: &knng::cli::ArgMatches) -> anyhow::Result<()> {
    use knng::net::{RetryPolicy, RetryingClient};
    let qpath = m
        .get("batch")
        .or_else(|| m.get("queries"))
        .ok_or_else(|| anyhow::anyhow!("--batch <fvecs> is required with --connect"))?;
    let queries = knng::dataset::fvecs::read_fvecs(std::path::Path::new(qpath), usize::MAX)?;
    let route_top_m = parse_route_top_m(m)?;
    let timeout_s = m.u64_or("net-timeout", 30)?;
    let timeout = (timeout_s > 0).then(|| std::time::Duration::from_secs(timeout_s));
    let deadline_us = m.u64_or("deadline-us", 0)?;
    let attempts = m.u64_or("net-retries", 3)?.max(1) as u32;
    let policy = RetryPolicy { max_attempts: attempts, ..Default::default() };
    let mut client = RetryingClient::connect(addr, policy)?.io_timeout(timeout);
    let info = client.ping()?;
    anyhow::ensure!(
        queries.dim() == info.dim as usize,
        "query dim {} does not match served dim {}",
        queries.dim(),
        info.dim
    );
    let t0 = std::time::Instant::now();
    let (results, windows, degradation) =
        client.query_batch_deadline(&queries, k, route_top_m, deadline_us)?;
    let secs = t0.elapsed().as_secs_f64();
    print_result_rows(&results);
    let coalesced = windows.iter().filter(|w| w.coalesced).count();
    eprintln!(
        "{} queries over the wire in {secs:.3}s ({:.0} qps) \
         [server {addr}: n={}, dim={}, k={}; {coalesced} coalesced; {} retr(ies)]",
        results.len(),
        results.len() as f64 / secs.max(1e-12),
        info.n,
        info.dim,
        info.k,
        client.retries(),
    );
    if let Some(d) = degradation {
        eprintln!("WARNING: degraded answers: {d}");
    }
    Ok(())
}

/// The `serve` subcommand: KNNIv1 bundle(s) → `ShardedSearcher` →
/// thread-per-shard `ShardPool` → micro-batching `ServeFront` →
/// `NetServer` speaking KNNQv1 on a TCP listener. Runs until SIGINT
/// or a wire shutdown frame, then drains in-flight windows.
fn cmd_serve(argv: &[String]) -> anyhow::Result<()> {
    use knng::api::{FrontConfig, ServeFront, ShardPool, ShardedSearcher};
    use knng::net::{install_sigint_handler, NetServer, ServerConfig};

    let spec = ArgSpec::new()
        .value("listen", "address to listen on, e.g. 127.0.0.1:7070 (required; port 0 = ephemeral)")
        .multi("index", "KNNIv1 index bundle from `build --save-index`; repeat to serve several bundles as shards")
        .value("k", "neighbors served per query; wire requests must match (default 10)")
        .value("ef", "beam width (default 64)")
        .value("route-top-m", "centroid-route each query to its m nearest shards; wire requests must match (default: full fan-out)")
        .value("threads", "shard-pool worker threads (clamped to the shard count; default 1)")
        .value("replicas", "copies of each shard's serving state; a shard degrades only when all copies are gone (default 1)")
        .value("hedge-us", "hedge delay, microseconds: re-send a straggling shard's job to the next replica after this long (default 0 = off; needs --replicas > 1)")
        .value("max-batch", "max queries coalesced per window (default 64)")
        .value("batch-window", "batching window, microseconds (default 200)")
        .value("answer-cache", "cross-window LRU answer cache capacity, distinct queries (default 0 = off)")
        .value("net-workers", "connection-handler threads (default 4)")
        .value("net-timeout", "per-connection read timeout, seconds (default 30)")
        .value("max-frame", "largest accepted wire frame payload, bytes (default 16M)")
        .value(KERNEL_FLAG, KERNEL_HELP)
        .flag("help", "show this help");
    let m = parse_args(&spec, argv)?;
    if m.has("help") {
        print!("{}", spec.usage("serve"));
        return Ok(());
    }
    apply_kernel_override(&m)?;
    let listen = m.get("listen").ok_or_else(|| anyhow::anyhow!("--listen <addr> is required"))?;
    let index_paths = m.get_all("index");
    anyhow::ensure!(
        !index_paths.is_empty(),
        "--index <bundle> is required (repeat the flag to serve several bundles as shards)"
    );

    let mut indexes = Vec::with_capacity(index_paths.len());
    for p in index_paths {
        indexes.push(Index::load(std::path::Path::new(p))?);
    }
    let graph_k = indexes[0].graph_k();
    let sharded = match indexes.len() {
        1 => ShardedSearcher::from_index(indexes.pop().expect("one bundle")),
        _ => ShardedSearcher::from_indexes(indexes)?,
    };
    let (n, dim, shards) = (sharded.len(), sharded.dim(), sharded.shard_count());

    let k = m.usize_or("k", 10)?;
    let params = knng::search::SearchParams {
        ef: m.usize_or("ef", 64)?,
        ..Default::default()
    };
    let route_top_m = parse_route_top_m(&m)?;
    let threads = m.usize_or("threads", 1)?;
    let replicas = m.usize_or("replicas", 1)?.max(1);
    let hedge_us = m.u64_or("hedge-us", 0)?;
    let pool = ShardPool::with_config(
        &sharded,
        knng::api::PoolConfig { threads, replicas, hedge_us, ..Default::default() },
    )?;
    let workers = pool.threads();
    let cfg = FrontConfig {
        k,
        params,
        max_batch: m.usize_or("max-batch", 64)?,
        max_wait: std::time::Duration::from_micros(m.u64_or("batch-window", 200)?),
        route_top_m,
        answer_cache: m.usize_or("answer-cache", 0)?,
        replicas,
        hedge_us,
        ..Default::default()
    };
    let cache = cfg.answer_cache;
    let front = ServeFront::spawn(pool, dim, cfg)?;

    let net_timeout = std::time::Duration::from_secs(m.u64_or("net-timeout", 30)?.max(1));
    let server_cfg = ServerConfig {
        workers: m.usize_or("net-workers", 4)?,
        read_timeout: net_timeout,
        write_timeout: net_timeout,
        max_frame: m.usize_or("max-frame", knng::net::wire::DEFAULT_MAX_FRAME)?,
    };
    let server = NetServer::bind(listen, front, server_cfg)?;
    let addr = server.local_addr()?;
    install_sigint_handler();
    eprintln!(
        "serving n={n} dim={dim} (graph k={graph_k}) on {addr} — {shards} shard(s) × \
         {replicas} replica(s), {workers} pool worker(s), k={k}, route {}, \
         answer cache {cache}, hedge {hedge_us}µs; Ctrl-C drains",
        match route_top_m {
            Some(v) => format!("top-{v}"),
            None => "full".to_string(),
        },
    );
    let (net, totals) = server.run()?;
    eprintln!(
        "drained: {} connection(s), {} frame(s), {} wire quer(ies), {} protocol error(s); \
         {} window(s), {} coalesced, {} cache hit(s)",
        net.connections,
        net.frames,
        net.queries,
        net.protocol_errors,
        totals.windows,
        totals.coalesced,
        totals.cache_hits,
    );
    Ok(())
}

/// `knng store <action>` — the storage-engine surface. Local actions
/// open the segment in this process (WAL replay included); `insert`,
/// `delete`, and `compact` also work against a running
/// `store serve --listen` server via `--connect`.
fn cmd_store(argv: &[String]) -> anyhow::Result<()> {
    let action = argv.first().map(|s| s.as_str());
    let rest = if argv.is_empty() { argv } else { &argv[1..] };
    match action {
        Some("convert") => store_convert(rest),
        Some("info") => store_info(rest),
        Some("query") => store_query(rest),
        Some("insert") => store_insert(rest),
        Some("delete") => store_delete(rest),
        Some("compact") => store_compact(rest),
        Some("serve") => store_serve(rest),
        Some("--help") | Some("-h") | Some("help") | None => {
            println!(
                "usage: knng store <action> [options]\n\n\
                 actions:\n  \
                 convert  KNNIv1 bundle → zero-copy KNNIv2 segment (--index, --out)\n  \
                 info     header, sections, delta/WAL state (--segment)\n  \
                 query    batched queries against a segment (--segment, --batch, --k)\n  \
                 insert   WAL-backed row insert (--segment|--connect, --id, --vec)\n  \
                 delete   WAL-backed tombstone (--segment|--connect, --id)\n  \
                 compact  fold delta+tombstones into a fresh segment (--segment|--connect)\n  \
                 serve    KNNQv2 server with the mutation surface (--segment, --listen)\n\n\
                 run `knng store <action> --help` for flags"
            );
            Ok(())
        }
        Some(other) => {
            Err(anyhow::anyhow!("unknown store action `{other}` (see `knng store help`)"))
        }
    }
}

/// Shared `--mode mmap|copy` parsing (absent = `PALLAS_STORE` env,
/// then the platform default).
fn parse_store_mode(m: &knng::cli::ArgMatches) -> anyhow::Result<Option<knng::store::StoreMode>> {
    match m.get("mode") {
        None => Ok(None),
        Some(s) => knng::store::StoreMode::parse(s)
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("--mode: unknown store mode `{s}` (mmap|copy)")),
    }
}

/// Shared store-engine knobs → [`knng::store::StoreConfig`].
fn parse_store_cfg(m: &knng::cli::ArgMatches) -> anyhow::Result<knng::store::StoreConfig> {
    let d = knng::store::StoreConfig::default();
    Ok(knng::store::StoreConfig {
        mode: parse_store_mode(m)?,
        auto_compact_ratio: m.f64_or("auto-compact-ratio", d.auto_compact_ratio)?,
        auto_compact_min: m.usize_or("auto-compact-min", d.auto_compact_min)?,
        repair_iters: m.usize_or("repair-iters", d.repair_iters)?,
        group_commit_us: m.u64_or("group-commit-us", d.group_commit_us)?,
    })
}

fn store_segment_flag(spec: ArgSpec) -> ArgSpec {
    spec.value("segment", "KNNIv2 segment path (KNNIv1 bundles open too, heap-loaded)")
        .value("mode", "segment byte source: mmap|copy (default: PALLAS_STORE env, else platform)")
        .value("auto-compact-ratio", "auto-compact when delta/base exceeds this (default 0.5; 0 = off)")
        .value("auto-compact-min", "…but only once the delta holds this many rows (default 64)")
        .value("repair-iters", "NN-Descent repair iteration budget per compaction (default 8)")
        .value("group-commit-us", "WAL group-commit window, microseconds: batch fsyncs within this window (default 0 = fsync per append)")
        .flag("help", "show this help")
}

/// Open the `--segment` path as a [`knng::store::MutableIndex`].
fn open_store(m: &knng::cli::ArgMatches) -> anyhow::Result<knng::store::MutableIndex> {
    let path = m
        .get("segment")
        .ok_or_else(|| anyhow::anyhow!("--segment <path> is required"))?;
    knng::store::MutableIndex::open_with(std::path::Path::new(path), parse_store_cfg(m)?)
}

fn store_convert(argv: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new()
        .value("index", "source KNNIv1 bundle from `build --save-index`")
        .value("out", "destination KNNIv2 segment path")
        .flag("help", "show this help");
    let m = parse_args(&spec, argv)?;
    if m.has("help") {
        print!("{}", spec.usage("store convert"));
        return Ok(());
    }
    let src = m.get("index").ok_or_else(|| anyhow::anyhow!("--index <bundle> is required"))?;
    let dst = m.get("out").ok_or_else(|| anyhow::anyhow!("--out <segment> is required"))?;
    knng::store::convert_v1_to_v2(std::path::Path::new(src), std::path::Path::new(dst))?;
    let bytes = std::fs::metadata(dst).map(|md| md.len()).unwrap_or(0);
    println!("converted {src} → {dst} ({bytes} bytes, KNNIv2 generation 0)");
    Ok(())
}

fn store_info(argv: &[String]) -> anyhow::Result<()> {
    let spec = store_segment_flag(ArgSpec::new());
    let m = parse_args(&spec, argv)?;
    if m.has("help") {
        print!("{}", spec.usage("store info"));
        return Ok(());
    }
    let store = open_store(&m)?;
    let (base_n, base_k, layout) = match store.base() {
        knng::store::BaseSegment::V2(s) => (s.n(), s.k(), format!("KNNIv2/{}", s.mode().name())),
        knng::store::BaseSegment::Legacy(i) => (i.len(), i.graph_k(), "KNNIv1/heap".to_string()),
    };
    println!(
        "{}: {layout}, generation {}, dim {}\n\
         base: {base_n} row(s), graph k={base_k}\n\
         delta: {} live row(s), {} tombstone(s), WAL {} byte(s)\n\
         live total: {} row(s)",
        store.path().display(),
        store.generation(),
        store.dim(),
        store.delta_len(),
        store.tombstone_count(),
        store.wal_bytes(),
        store.len(),
    );
    Ok(())
}

fn store_query(argv: &[String]) -> anyhow::Result<()> {
    let spec = store_segment_flag(
        ArgSpec::new()
            .value("batch", ".fvecs query vectors (required)")
            .value("k", "neighbors per query (default 10)")
            .value("ef", "beam width (default 64)")
            .value(KERNEL_FLAG, KERNEL_HELP),
    );
    let m = parse_args(&spec, argv)?;
    if m.has("help") {
        print!("{}", spec.usage("store query"));
        return Ok(());
    }
    apply_kernel_override(&m)?;
    let store = open_store(&m)?;
    let qpath = m.get("batch").ok_or_else(|| anyhow::anyhow!("--batch <fvecs> is required"))?;
    let queries = knng::dataset::fvecs::read_fvecs(std::path::Path::new(qpath), usize::MAX)?;
    anyhow::ensure!(
        queries.dim() == store.dim(),
        "query dim {} does not match segment dim {}",
        queries.dim(),
        store.dim()
    );
    let k = m.usize_or("k", 10)?;
    let params =
        knng::search::SearchParams { ef: m.usize_or("ef", 64)?, ..Default::default() };
    let (results, stats) = store.search_batch(&queries, k, &params);
    print_result_rows(&results);
    eprintln!(
        "{} queries in {:.3}s ({:.0} qps), {:.0} evals/query [kernel {}; {} live row(s), \
         generation {}, {} delta row(s), {} tombstone(s)]",
        stats.queries,
        stats.secs,
        stats.qps(),
        stats.dist_evals_per_query(),
        stats.kernel,
        store.len(),
        store.generation(),
        store.delta_len(),
        store.tombstone_count(),
    );
    Ok(())
}

/// Shared tail for local mutations: print the post-mutation state.
fn store_report(store: &knng::store::MutableIndex, what: &str) {
    println!(
        "{what}: {} live row(s), {} delta row(s), {} tombstone(s), generation {}, WAL {} byte(s)",
        store.len(),
        store.delta_len(),
        store.tombstone_count(),
        store.generation(),
        store.wal_bytes(),
    );
}

fn store_insert(argv: &[String]) -> anyhow::Result<()> {
    let spec = store_segment_flag(
        ArgSpec::new()
            .value("connect", "apply over the wire to a running `store serve` server")
            .value("id", "external row id (required)")
            .multi("vec", "row values, comma-separated (required; repeat to append)"),
    );
    let m = parse_args(&spec, argv)?;
    if m.has("help") {
        print!("{}", spec.usage("store insert"));
        return Ok(());
    }
    anyhow::ensure!(m.has("id"), "--id <u32> is required");
    let id = u32::try_from(m.u64_or("id", u64::MAX)?)
        .map_err(|_| anyhow::anyhow!("--id must fit in u32"))?;
    let row = m.f32_list("vec")?;
    anyhow::ensure!(!row.is_empty(), "--vec <f32,...> is required");
    if let Some(addr) = m.get("connect") {
        let mut client = knng::net::NetClient::connect(addr)?;
        let (generation, live) = client.insert(id, &row)?;
        println!("inserted id {id} over the wire: {live} live row(s), generation {generation}");
        return Ok(());
    }
    let mut store = open_store(&m)?;
    store.insert(id, &row)?;
    store_report(&store, &format!("inserted id {id}"));
    Ok(())
}

fn store_delete(argv: &[String]) -> anyhow::Result<()> {
    let spec = store_segment_flag(
        ArgSpec::new()
            .value("connect", "apply over the wire to a running `store serve` server")
            .value("id", "external row id (required)"),
    );
    let m = parse_args(&spec, argv)?;
    if m.has("help") {
        print!("{}", spec.usage("store delete"));
        return Ok(());
    }
    anyhow::ensure!(m.has("id"), "--id <u32> is required");
    let id = u32::try_from(m.u64_or("id", u64::MAX)?)
        .map_err(|_| anyhow::anyhow!("--id must fit in u32"))?;
    if let Some(addr) = m.get("connect") {
        let mut client = knng::net::NetClient::connect(addr)?;
        let (was_live, generation, live) = client.delete(id)?;
        println!(
            "delete id {id} over the wire: {} — {live} live row(s), generation {generation}",
            if was_live { "removed" } else { "was not live (no-op)" },
        );
        return Ok(());
    }
    let mut store = open_store(&m)?;
    let was_live = store.delete(id)?;
    store_report(
        &store,
        &format!("delete id {id} ({})", if was_live { "removed" } else { "was not live" }),
    );
    Ok(())
}

fn store_compact(argv: &[String]) -> anyhow::Result<()> {
    let spec = store_segment_flag(
        ArgSpec::new()
            .value("connect", "apply over the wire to a running `store serve` server")
            .value(KERNEL_FLAG, KERNEL_HELP),
    );
    let m = parse_args(&spec, argv)?;
    if m.has("help") {
        print!("{}", spec.usage("store compact"));
        return Ok(());
    }
    apply_kernel_override(&m)?;
    if let Some(addr) = m.get("connect") {
        let mut client = knng::net::NetClient::connect(addr)?;
        let (generation, live) = client.compact()?;
        println!("compacted over the wire: {live} live row(s), generation {generation}");
        return Ok(());
    }
    let mut store = open_store(&m)?;
    let stats = store.compact()?;
    println!(
        "compacted to generation {}: {} row(s) ({} folded from delta, {} dropped) in {:.3}s, \
         {} bytes; repair: {} iteration(s), {} update(s)",
        stats.generation,
        stats.rows,
        stats.folded,
        stats.dropped,
        stats.secs,
        stats.bytes,
        stats.repair.iterations,
        stats.repair.updates,
    );
    Ok(())
}

/// `knng store serve`: the KNNQv2 server over a mutable store — the
/// front searches through a clone of the shared handle, the server
/// applies `insert`/`delete`/`compact` frames to the same handle, so
/// a mutation is visible to the next query. The answer cache is safe
/// here: it is keyed on the store's mutation epoch and flushed the
/// moment an insert/delete/compaction lands, so a cached answer never
/// outlives the rows it names.
fn store_serve(argv: &[String]) -> anyhow::Result<()> {
    use knng::api::{FrontConfig, ServeFront};
    use knng::net::{install_sigint_handler, NetServer, ServerConfig};
    let spec = store_segment_flag(
        ArgSpec::new()
            .value("listen", "address to listen on, e.g. 127.0.0.1:7070 (required; port 0 = ephemeral)")
            .value("k", "neighbors served per query; wire requests must match (default 10)")
            .value("ef", "beam width (default 64)")
            .value("max-batch", "max queries coalesced per window (default 64)")
            .value("batch-window", "batching window, microseconds (default 200)")
            .value("answer-cache", "cross-window LRU answer cache capacity, distinct queries; flushed on every mutation (default 0 = off)")
            .value("net-workers", "connection-handler threads (default 4)")
            .value("net-timeout", "per-connection read timeout, seconds (default 30)")
            .value("max-frame", "largest accepted wire frame payload, bytes (default 16M)")
            .value(KERNEL_FLAG, KERNEL_HELP),
    );
    let m = parse_args(&spec, argv)?;
    if m.has("help") {
        print!("{}", spec.usage("store serve"));
        return Ok(());
    }
    apply_kernel_override(&m)?;
    let listen = m.get("listen").ok_or_else(|| anyhow::anyhow!("--listen <addr> is required"))?;
    let path = m
        .get("segment")
        .ok_or_else(|| anyhow::anyhow!("--segment <path> is required"))?;
    let shared = knng::store::SharedMutableIndex::open_with(
        std::path::Path::new(path),
        parse_store_cfg(&m)?,
    )?;
    let (dim, live, generation) = (shared.dim(), shared.live_len(), shared.generation());

    let k = m.usize_or("k", 10)?;
    let params =
        knng::search::SearchParams { ef: m.usize_or("ef", 64)?, ..Default::default() };
    let cfg = FrontConfig {
        k,
        params,
        max_batch: m.usize_or("max-batch", 64)?,
        max_wait: std::time::Duration::from_micros(m.u64_or("batch-window", 200)?),
        // safe over a mutable corpus: the cache is epoch-keyed and
        // flushed whenever the store mutates
        answer_cache: m.usize_or("answer-cache", 0)?,
        ..Default::default()
    };
    let front = ServeFront::spawn(shared.clone(), dim, cfg)?;

    let net_timeout = std::time::Duration::from_secs(m.u64_or("net-timeout", 30)?.max(1));
    let server_cfg = ServerConfig {
        workers: m.usize_or("net-workers", 4)?,
        read_timeout: net_timeout,
        write_timeout: net_timeout,
        max_frame: m.usize_or("max-frame", knng::net::wire::DEFAULT_MAX_FRAME)?,
    };
    let server = NetServer::bind(listen, front, server_cfg)?.with_store(shared);
    let addr = server.local_addr()?;
    install_sigint_handler();
    eprintln!(
        "serving mutable store {path} on {addr} — {live} live row(s), generation {generation}, \
         dim {dim}, k={k}; insert/delete/compact enabled; Ctrl-C drains"
    );
    let (net, totals) = server.run()?;
    eprintln!(
        "drained: {} connection(s), {} frame(s), {} wire quer(ies), {} protocol error(s); \
         {} window(s), {} coalesced",
        net.connections,
        net.frames,
        net.queries,
        net.protocol_errors,
        totals.windows,
        totals.coalesced,
    );
    Ok(())
}

/// Emit one tab-separated `qi\tid:dist...` line per query (the stable
/// stdout contract shared by every `query` serving path).
fn print_result_rows(results: &[Vec<knng::api::Neighbor>]) {
    for (qi, res) in results.iter().enumerate() {
        let row: Vec<String> =
            res.iter().map(|nb| format!("{}:{:.4}", nb.id, nb.dist)).collect();
        println!("{qi}\t{}", row.join("\t"));
    }
}

/// The `query --serve` path: spawn the thread-per-shard pool over the
/// (possibly multi-bundle) sharded searcher and stream each query
/// through the micro-batching front-end individually — the full
/// serving runtime, end to end, with results identical to the plain
/// batched path (and, with `route_top_m`, to the inline routed path).
fn serve_queries(
    sharded: knng::api::ShardedSearcher,
    queries: knng::dataset::AlignedMatrix,
    k: usize,
    params: knng::search::SearchParams,
    route_top_m: Option<usize>,
    label: (usize, usize),
    m: &knng::cli::ArgMatches,
) -> anyhow::Result<()> {
    use knng::api::{FrontConfig, ServeFront, ShardPool};

    let threads = m.usize_or("threads", 1)?;
    let replicas = m.usize_or("replicas", 1)?.max(1);
    let hedge_us = m.u64_or("hedge-us", 0)?;
    let max_batch = m.usize_or("max-batch", 64)?;
    let window_us = m.u64_or("batch-window", 200)?;
    let dim = sharded.dim();
    let shard_count = sharded.shard_count();
    let (index_n, graph_k) = label;

    let pool = ShardPool::with_config(
        &sharded,
        knng::api::PoolConfig { threads, replicas, hedge_us, ..Default::default() },
    )?;
    let workers = pool.threads();
    if workers < threads {
        eprintln!("note: --threads {threads} clamped to {workers} (one worker per shard)");
    }
    let cfg = FrontConfig {
        k,
        params,
        max_batch,
        max_wait: std::time::Duration::from_micros(window_us),
        route_top_m,
        replicas,
        hedge_us,
        ..Default::default()
    };
    let front = ServeFront::spawn(pool, dim, cfg)?;

    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = (0..queries.n())
        .map(|qi| front.submit(queries.row_logical(qi).to_vec()))
        .collect::<anyhow::Result<_>>()?;
    for (qi, ticket) in tickets.into_iter().enumerate() {
        let served = ticket.wait()?;
        let row: Vec<String> =
            served.neighbors.iter().map(|nb| format!("{}:{:.4}", nb.id, nb.dist)).collect();
        println!("{qi}\t{}", row.join("\t"));
    }
    let secs = t0.elapsed().as_secs_f64();
    let totals = front.shutdown();
    eprintln!(
        "served {} queries in {secs:.3}s ({:.0} qps) — {} worker(s), {} window(s) \
         (max {max_batch}/{window_us}µs), {} duplicate(s) coalesced \
         [index n={index_n}, graph k={graph_k}]",
        totals.queries,
        totals.queries as f64 / secs.max(1e-12),
        workers,
        totals.windows,
        totals.coalesced,
    );
    if let Some(top_m) = route_top_m {
        eprintln!(
            "routing: fan-out top-{} of {shard_count} shard(s), {} shard visit(s) \
             ({:.2}/query)",
            top_m.min(shard_count),
            totals.shard_visits,
            totals.shard_visits as f64 / totals.queries.max(1) as f64,
        );
    }
    Ok(())
}

fn cmd_gen(argv: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new()
        .value("dataset", "gaussian|gaussian-multi|clustered|mnist|audio")
        .value("n", "number of points")
        .value("dim", "dimensionality")
        .value("clusters", "clusters (clustered)")
        .value("seed", "PRNG seed")
        .value("out", "output .fvecs path (required)")
        .flag("help", "show this help");
    let m = parse_args(&spec, argv)?;
    if m.has("help") {
        print!("{}", spec.usage("gen"));
        return Ok(());
    }
    let out = m.get("out").ok_or_else(|| anyhow::anyhow!("--out required"))?;
    let n = m.usize_or("n", 16_384)?;
    let dim = m.usize_or("dim", 8)?;
    let seed = m.u64_or("seed", 0x5eed)?;
    let ds_spec = match m.str_or("dataset", "gaussian") {
        "gaussian" => DatasetSpec::Gaussian { n, dim, single: true, seed },
        "gaussian-multi" => DatasetSpec::Gaussian { n, dim, single: false, seed },
        "clustered" => DatasetSpec::Clustered { n, dim, clusters: m.usize_or("clusters", 16)?, seed },
        "mnist" => DatasetSpec::Mnist { n, path: None, seed },
        "audio" => DatasetSpec::Audio { n, dim, seed },
        other => anyhow::bail!("unknown --dataset `{other}`"),
    };
    let ds = knng::dataset::from_spec(&ds_spec)?;
    knng::dataset::fvecs::write_fvecs(std::path::Path::new(out), &ds.data)?;
    println!("wrote {} ({} × {}) to {out}", ds.name, ds.n(), ds.dim());
    Ok(())
}

fn check_spec() -> ArgSpec {
    ArgSpec::new()
        .value("artifacts", "artifact dir (default artifacts)")
        .flag("help", "show this help")
}

#[cfg(not(feature = "pjrt"))]
fn cmd_check(argv: &[String]) -> anyhow::Result<()> {
    let spec = check_spec();
    let m = parse_args(&spec, argv)?;
    if m.has("help") {
        print!("{}", spec.usage("check"));
        return Ok(());
    }
    anyhow::bail!(
        "`knng check` validates PJRT artifacts and requires the `pjrt` cargo feature \
         (rebuild with `--features pjrt`)"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_check(argv: &[String]) -> anyhow::Result<()> {
    let spec = check_spec();
    let m = parse_args(&spec, argv)?;
    if m.has("help") {
        print!("{}", spec.usage("check"));
        return Ok(());
    }
    let dir = m.str_or("artifacts", "artifacts");

    use knng::dataset::synth::SynthGaussian;
    use knng::distance::blocked::{pairwise_flat, PairwiseBuf};
    use knng::runtime::PjrtEngine;

    let mut engine = PjrtEngine::open(dir)?;
    println!(
        "artifact store: {} entries, platform={}",
        engine.store().entries().len(),
        engine.store().client().platform_name()
    );
    let shapes = engine.store().pairwise_shapes();
    anyhow::ensure!(!shapes.is_empty(), "no pairwise artifacts found");
    let mut failures = 0;
    for (b, d) in shapes {
        let data = SynthGaussian::single(b, d, 7).generate();
        anyhow::ensure!(data.dim_pad() == d, "artifact d={d} not a multiple of 8?");
        let ids: Vec<u32> = (0..b as u32).collect();
        let mut pjrt = PairwiseBuf::with_capacity(b);
        let mut native = PairwiseBuf::with_capacity(b);
        engine.pairwise_checked(&data, &ids, &mut pjrt)?;
        pairwise_flat(&data, &ids, &mut native, true);
        let mut max_err = 0f32;
        let mut scale = 0f32;
        for i in 0..b {
            for j in (i + 1)..b {
                max_err = max_err.max((pjrt.get(i, j) - native.get(i, j)).abs());
                scale = scale.max(native.get(i, j).abs());
            }
        }
        let ok = max_err <= 2e-3 * scale.max(1.0);
        println!(
            "  pairwise b={b:<4} d={d:<4} max_err={max_err:.3e} scale={scale:.3e} {}",
            if ok { "OK" } else { "FAIL" }
        );
        if !ok {
            failures += 1;
        }
    }
    anyhow::ensure!(failures == 0, "{failures} artifact(s) disagree with native kernels");
    println!("check OK — pjrt results match native kernels");
    Ok(())
}

fn cmd_info(argv: &[String]) -> anyhow::Result<()> {
    let spec = ArgSpec::new()
        .value("artifacts", "artifact dir to inventory (default artifacts)")
        .flag("help", "show this help");
    let m = parse_args(&spec, argv)?;
    if m.has("help") {
        print!("{}", spec.usage("info"));
        return Ok(());
    }
    println!("knng {}", knng::VERSION);
    let d = RunConfig::default();
    println!(
        "defaults: k={} rho={} delta={} selection={} compute={} max_candidates={}",
        d.k,
        d.rho,
        d.delta,
        d.selection.name(),
        d.compute.name(),
        d.max_candidates
    );
    println!("kernel dispatch: {}", knng::distance::dispatch::describe());
    let dir = m.str_or("artifacts", "artifacts");
    artifact_inventory(dir);
    Ok(())
}

#[cfg(feature = "pjrt")]
fn artifact_inventory(dir: &str) {
    match knng::runtime::ArtifactStore::open(dir) {
        Ok(store) => {
            println!("artifacts in {dir}:");
            for e in store.entries() {
                println!("  {} {:?} → {}", e.kind, e.dims, e.file);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn artifact_inventory(dir: &str) {
    println!("artifacts in {dir}: unavailable (built without the `pjrt` cargo feature)");
}
