//! 8-lane SIMD squared-L2 kernel — the paper's `l2intrinsics` +
//! `mem-align` adaptation (§3.3).
//!
//! The paper keeps one AVX2 register of accumulators and processes 8
//! single-precision components per `vsubps` + `vfmadd231ps`. Portable
//! equivalent: `std::simd::f32x8` — one SIMD accumulator updated per
//! exact 8-lane chunk, which lowers to the same instruction sequence
//! under `-C target-cpu=native` (the paper's `-march=native`; verified
//! by disassembly, EXPERIMENTS.md §Perf). An earlier array-of-lanes
//! formulation relied on LLVM's loop vectorizer and left the
//! accumulators spilled — 3.5× slower; see the §Perf log.
//!
//! Inputs must be padded rows (length divisible by 8, zero tails), which
//! [`AlignedMatrix`](crate::dataset::AlignedMatrix) guarantees.

use std::simd::f32x8;
use std::simd::num::SimdFloat;
use std::simd::StdFloat;

/// Squared L2 over padded rows using one 8-lane SIMD accumulator.
#[inline]
pub fn sq_l2_unrolled(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len() % 8, 0, "rows must be padded to 8 lanes");
    let mut acc = f32x8::splat(0.0);
    for (ca, cb) in a.chunks_exact(8).zip(b.chunks_exact(8)) {
        let d = f32x8::from_slice(ca) - f32x8::from_slice(cb);
        acc = d.mul_add(d, acc);
    }
    acc.reduce_sum()
}

/// Horizontal sum of 8 lanes (exposed for the blocked kernel/tests).
#[inline]
pub fn horizontal_sum(acc: &[f32; 8]) -> f32 {
    f32x8::from_array(*acc).reduce_sum()
}

/// Squared norm of a padded row — used by the PJRT batcher to validate
/// kernel outputs and by tests.
pub fn sq_norm(a: &[f32]) -> f32 {
    debug_assert_eq!(a.len() % 8, 0);
    let mut acc = f32x8::splat(0.0);
    for ca in a.chunks_exact(8) {
        let v = f32x8::from_slice(ca);
        acc = v.mul_add(v, acc);
    }
    acc.reduce_sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::scalar::{sq_l2_f64, sq_l2_scalar};
    use crate::testing::{check, Config};

    #[test]
    fn matches_scalar_on_fixed_inputs() {
        let a: Vec<f32> = (0..32).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..32).map(|i| -(i as f32) * 0.25 + 1.0).collect();
        let u = sq_l2_unrolled(&a, &b);
        let s = sq_l2_scalar(&a, &b);
        assert!((u - s).abs() <= 1e-3 * s.abs().max(1.0), "u={u} s={s}");
    }

    #[test]
    fn prop_matches_f64_oracle() {
        check(Config::cases(200), "unrolled ≈ f64 oracle", |g| {
            let chunks = g.usize_in(1..64);
            let a = g.vec_f32(chunks * 8, 10.0);
            let b = g.vec_f32(chunks * 8, 10.0);
            let u = sq_l2_unrolled(&a, &b) as f64;
            let o = sq_l2_f64(&a, &b);
            (u - o).abs() <= 1e-4 * (1.0 + o)
        });
    }

    #[test]
    fn zero_distance_and_padding_neutrality() {
        let a = [1.0f32; 16];
        assert_eq!(sq_l2_unrolled(&a, &a), 0.0);
        // zero-padded tails contribute nothing
        let mut x = vec![2.0f32; 8];
        x.extend([0.0; 8]);
        let mut y = vec![-1.0f32; 8];
        y.extend([0.0; 8]);
        assert_eq!(sq_l2_unrolled(&x, &y), sq_l2_unrolled(&x[..8], &y[..8]));
    }

    #[test]
    fn sq_norm_matches_self_distance_to_zero() {
        check(Config::cases(100), "sq_norm", |g| {
            let a = g.vec_f32(24, 4.0);
            let z = vec![0.0f32; 24];
            (sq_norm(&a) - sq_l2_unrolled(&a, &z)).abs() < 1e-3
        });
    }

    #[test]
    fn horizontal_sum_exact() {
        let acc = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        assert_eq!(horizontal_sum(&acc), 36.0);
    }
}
