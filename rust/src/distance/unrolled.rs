//! The paper's `l2intrinsics` + `mem-align` kernel (§3.3), routed
//! through the runtime-dispatched kernel engine.
//!
//! Historically this file held the fixed `f32x8` loop: one SIMD
//! register of accumulators, 8 single-precision components per
//! `vsubps` + `vfmadd231ps`. That loop now lives width-generically in
//! [`kernel::sq_l2_w`](super::kernel::sq_l2_w) (8 or 16 lanes, selected
//! once per process by [`dispatch`](super::dispatch)); `sq_l2_unrolled`
//! is the stable name the crate's ~25 call sites keep using. At the
//! default `w8` width the instruction sequence is unchanged from the
//! original (verified by disassembly, EXPERIMENTS.md §Perf).
//!
//! Inputs must be padded rows (length divisible by 8, zero tails), which
//! [`AlignedMatrix`](crate::dataset::AlignedMatrix) guarantees.

use super::dispatch;

/// Squared L2 over padded rows at the dispatched kernel width (one SIMD
/// accumulator per pair; scalar when forced). Every blocked kernel is
/// bit-equal to this function at the same width.
#[inline]
pub fn sq_l2_unrolled(a: &[f32], b: &[f32]) -> f32 {
    (dispatch::active().pair)(a, b)
}

/// Squared norm of a padded row at the dispatched kernel width — used
/// by the norm-trick serving path (`search::GraphIndex` precomputes one
/// per corpus row), the PJRT batcher, and tests. Bitwise identical to
/// the dot product of a row with itself at the same width.
#[inline]
pub fn sq_norm(a: &[f32]) -> f32 {
    (dispatch::active().sq_norm)(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::scalar::{sq_l2_f64, sq_l2_scalar};
    use crate::testing::{check, Config};

    #[test]
    fn matches_scalar_on_fixed_inputs() {
        let a: Vec<f32> = (0..32).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..32).map(|i| -(i as f32) * 0.25 + 1.0).collect();
        let u = sq_l2_unrolled(&a, &b);
        let s = sq_l2_scalar(&a, &b);
        assert!((u - s).abs() <= 1e-3 * s.abs().max(1.0), "u={u} s={s}");
    }

    #[test]
    fn prop_matches_f64_oracle() {
        check(Config::cases(200), "unrolled ≈ f64 oracle", |g| {
            let chunks = g.usize_in(1..64);
            let a = g.vec_f32(chunks * 8, 10.0);
            let b = g.vec_f32(chunks * 8, 10.0);
            let u = sq_l2_unrolled(&a, &b) as f64;
            let o = sq_l2_f64(&a, &b);
            (u - o).abs() <= 1e-4 * (1.0 + o)
        });
    }

    #[test]
    fn zero_distance_and_padding_neutrality() {
        let a = [1.0f32; 16];
        assert_eq!(sq_l2_unrolled(&a, &a), 0.0);
        // zero-padded tails contribute nothing
        let mut x = vec![2.0f32; 8];
        x.extend([0.0; 8]);
        let mut y = vec![-1.0f32; 8];
        y.extend([0.0; 8]);
        assert_eq!(sq_l2_unrolled(&x, &y), sq_l2_unrolled(&x[..8], &y[..8]));
    }

    #[test]
    fn sq_norm_matches_self_distance_to_zero() {
        check(Config::cases(100), "sq_norm", |g| {
            let a = g.vec_f32(24, 4.0);
            let z = vec![0.0f32; 24];
            (sq_norm(&a) - sq_l2_unrolled(&a, &z)).abs() < 1e-3
        });
    }
}
