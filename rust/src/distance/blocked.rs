//! Blocked mutual squared-L2 evaluation — the paper's `blocked` tag
//! (§3.3, Fig 2), served by the width-generic kernel engine.
//!
//! The compute step needs *all* pairwise distances inside a candidate
//! set (≤ 50 vectors). Evaluating them pair-by-pair loads every vector
//! once per distance; evaluating a 5×5 block of vector pairs at once
//! loads 10 vectors per SIMD chunk and produces 25 distances — a 1 vs
//! 25 loads-per-component reduction that dominates in high dimensions.
//!
//! Since the kernel engine landed, this module is the *stable surface*:
//! [`PairwiseBuf`], the [`BLOCK`] constant, and thin shims that route
//! each shape through [`dispatch::active`](super::dispatch::active) —
//! the ~25 call sites across the crate keep compiling unchanged while
//! the actual loops live width-generically in
//! [`kernel`](super::kernel). Per pair, every routed kernel performs
//! the same floating-point sequence as
//! [`sq_l2_unrolled`](super::unrolled::sq_l2_unrolled) at the active
//! width, so the historical guarantee stands: blocked results are
//! **bit-equal** to the pairwise kernel, whatever width the dispatcher
//! picked.

use crate::dataset::AlignedMatrix;

use super::dispatch;

/// Block edge in vectors (paper: 5 — 25 accumulators fit registers).
pub const BLOCK: usize = 5;

/// Dense m×m symmetric distance buffer for one candidate set.
///
/// Reused across nodes to avoid per-node allocation on the hot path;
/// only entries `i < j` are stored canonically (accessor swaps).
#[derive(Debug, Clone)]
pub struct PairwiseBuf {
    m: usize,
    buf: Vec<f32>,
}

impl PairwiseBuf {
    /// Create with a given capacity hint (max candidate-set size).
    pub fn with_capacity(cap: usize) -> Self {
        Self { m: 0, buf: vec![0.0; cap * cap] }
    }

    /// Prepare for a set of `m` vectors (no allocation if within cap).
    pub fn reset(&mut self, m: usize) {
        self.m = m;
        if self.buf.len() < m * m {
            self.buf.resize(m * m, 0.0);
        }
    }

    /// Number of vectors in the current set.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Distance between set members `i` and `j` (i ≠ j).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i != j && i < self.m && j < self.m);
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        self.buf[lo * self.m + hi]
    }

    #[inline]
    fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < j && j < self.m);
        self.buf[i * self.m + j] = v;
    }

    /// Store a distance for pair (i, j), i ≠ j — the write door used by
    /// the kernel engine and external engines (e.g. the PJRT runtime)
    /// filling the buffer from a batch result.
    #[inline]
    pub fn put(&mut self, i: usize, j: usize, v: f32) {
        let (lo, hi) = if i < j { (i, j) } else { (j, i) };
        self.set(lo, hi, v);
    }
}

/// Compute all pairwise distances among `ids` (rows of `data`) into
/// `out`, using 5×5 blocking at the dispatched width. Returns the
/// number of distance evaluations performed (m·(m−1)/2).
pub fn pairwise_blocked(data: &AlignedMatrix, ids: &[u32], out: &mut PairwiseBuf) -> u64 {
    pairwise_blocked_active(data, ids, ids.len(), out)
}

/// Like [`pairwise_blocked`] but only guarantees entries `(i, j)` with
/// `i < active` (and `i < j`). NN-Descent's compute step never consumes
/// old×old pairs, so passing `active = |new|` skips those blocks
/// entirely — ~25% of the kernel work at default parameters — while
/// keeping the blocked load-amortization for everything consumed.
/// Returns the number of distances actually evaluated.
pub fn pairwise_blocked_active(
    data: &AlignedMatrix,
    ids: &[u32],
    active: usize,
    out: &mut PairwiseBuf,
) -> u64 {
    (dispatch::active().pairwise_active)(data, ids, active, out)
}

/// Distances from one padded query row to the `ids` rows of `data`,
/// written into `out[j]` (cleared and resized). 1×5 blocking at the
/// dispatched width: each SIMD step loads the query chunk once and five
/// row chunks — 6 loads feed 5 accumulations, vs 2 loads per 1 for
/// pair-at-a-time — the serving-path analogue of the build kernel's
/// Fig-2 amortization.
///
/// Per pair, the floating-point operation sequence is identical to
/// [`sq_l2_unrolled`](super::unrolled::sq_l2_unrolled) at the active
/// width, so results are **bit-equal** to the pairwise kernel — batched
/// query serving can match sequential search exactly. Returns the
/// number of distance evaluations (`ids.len()`).
pub fn one_to_many_blocked(q: &[f32], data: &AlignedMatrix, ids: &[u32], out: &mut Vec<f32>) -> u64 {
    (dispatch::active().one_to_many)(q, data, ids, out)
}

/// All distances from the rows of `queries` to the `ids` rows of `data`,
/// row-major into `out[qi · ids.len() + j]`. 5×5 tiles across the two
/// matrices at the dispatched width — the paper's blocked kernel applied
/// to the batched query×corpus workload. Like [`one_to_many_blocked`],
/// every pair is bit-equal to the pairwise kernel. Returns the number of
/// distance evaluations.
pub fn cross_blocked(
    queries: &AlignedMatrix,
    data: &AlignedMatrix,
    ids: &[u32],
    out: &mut [f32],
) -> u64 {
    (dispatch::active().cross)(queries, data, ids, out)
}

/// Unblocked reference: same contract as [`pairwise_blocked`] but one
/// pair at a time (used by the `scalar`/`unrolled` compute backends and
/// as the oracle for the blocked path).
pub fn pairwise_flat(data: &AlignedMatrix, ids: &[u32], out: &mut PairwiseBuf, use_unrolled: bool) -> u64 {
    // Resolve the dispatched pair kernel once, not per pair — the
    // indirect call amortizes poorly at small d. Same function the
    // `sq_l2_unrolled` shim would reach, so bit-equality holds.
    let pair: fn(&[f32], &[f32]) -> f32 =
        if use_unrolled { dispatch::active().pair } else { super::scalar::sq_l2_scalar };
    let m = ids.len();
    out.reset(m);
    for i in 0..m {
        for j in (i + 1)..m {
            let a = data.row(ids[i] as usize);
            let b = data.row(ids[j] as usize);
            out.set(i, j, pair(a, b));
        }
    }
    (m * m.saturating_sub(1) / 2) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::AlignedMatrix;
    use crate::distance::unrolled::sq_l2_unrolled;
    use crate::testing::{check, Config};

    fn random_matrix(g: &mut crate::testing::Gen, n: usize, dim: usize) -> AlignedMatrix {
        let data = g.vec_f32(n * dim, 8.0);
        AlignedMatrix::from_rows(n, dim, &data)
    }

    #[test]
    fn blocked_matches_flat_exact_sizes() {
        // m = 5, 10 (pure blocks), 3 (pure remainder), 13 (mixed)
        for m in [2, 3, 5, 7, 10, 13, 25, 26] {
            let mut g = crate::testing::Gen::new_for_test(m as u64);
            let data = random_matrix(&mut g, 30, 24);
            let ids: Vec<u32> = (0..m as u32).collect();
            let mut a = PairwiseBuf::with_capacity(32);
            let mut b = PairwiseBuf::with_capacity(32);
            let evals = pairwise_blocked(&data, &ids, &mut a);
            pairwise_flat(&data, &ids, &mut b, true);
            assert_eq!(evals, (m * (m - 1) / 2) as u64);
            for i in 0..m {
                for j in 0..m {
                    if i == j {
                        continue;
                    }
                    let (x, y) = (a.get(i, j), b.get(i, j));
                    assert!(
                        (x - y).abs() <= 1e-3 * (1.0 + y.abs()),
                        "m={m} ({i},{j}): blocked {x} vs flat {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_blocked_equals_scalar() {
        check(Config::cases(60), "blocked == scalar pairwise", |g| {
            let n = g.usize_in(2..40);
            let dim = 8 * g.usize_in(1..8);
            let data = random_matrix(g, n, dim);
            let m = g.usize_in(2..n.min(30) + 1);
            // ids may repeat rows — kernel must not care
            let ids: Vec<u32> = (0..m).map(|_| g.u32_in(0..n as u32)).collect();
            let mut a = PairwiseBuf::with_capacity(32);
            let mut b = PairwiseBuf::with_capacity(32);
            pairwise_blocked(&data, &ids, &mut a);
            pairwise_flat(&data, &ids, &mut b, false);
            (0..m).all(|i| {
                (0..m).filter(|&j| j != i).all(|j| {
                    let (x, y) = (a.get(i, j), b.get(i, j));
                    (x - y).abs() <= 2e-3 * (1.0 + y.abs())
                })
            })
        });
    }

    #[test]
    fn symmetry_accessor() {
        let mut g = crate::testing::Gen::new_for_test(7);
        let data = random_matrix(&mut g, 12, 16);
        let ids: Vec<u32> = (0..12).collect();
        let mut buf = PairwiseBuf::with_capacity(12);
        pairwise_blocked(&data, &ids, &mut buf);
        for i in 0..12 {
            for j in 0..12 {
                if i != j {
                    assert_eq!(buf.get(i, j), buf.get(j, i));
                }
            }
        }
    }

    #[test]
    fn empty_and_single() {
        let data = AlignedMatrix::zeroed(4, 8);
        let mut buf = PairwiseBuf::with_capacity(4);
        assert_eq!(pairwise_blocked(&data, &[], &mut buf), 0);
        assert_eq!(pairwise_blocked(&data, &[2], &mut buf), 0);
    }

    #[test]
    fn active_subset_fills_required_pairs() {
        check(Config::cases(60), "active pairs complete + eval count sane", |g| {
            let n = g.usize_in(5..40);
            let dim = 8 * g.usize_in(1..5);
            let data = random_matrix(g, n, dim);
            let m = g.usize_in(2..n.min(25) + 1);
            let active = g.usize_in(1..m + 1);
            let ids: Vec<u32> = (0..m as u32).collect();
            let mut full = PairwiseBuf::with_capacity(32);
            let mut part = PairwiseBuf::with_capacity(32);
            let full_evals = pairwise_blocked(&data, &ids, &mut full);
            let part_evals = pairwise_blocked_active(&data, &ids, active, &mut part);
            if part_evals > full_evals {
                return false;
            }
            // every required (i<active, i<j) pair matches the full result
            (0..active).all(|i| {
                ((i + 1)..m).all(|j| (part.get(i, j) - full.get(i, j)).abs() < 1e-5)
            })
        });
    }

    #[test]
    fn active_zero_is_empty() {
        let data = AlignedMatrix::zeroed(10, 8);
        let ids: Vec<u32> = (0..10).collect();
        let mut buf = PairwiseBuf::with_capacity(10);
        assert_eq!(pairwise_blocked_active(&data, &ids, 0, &mut buf), 0);
    }

    #[test]
    fn one_to_many_bit_equals_unrolled() {
        // the serving path's exact-equivalence guarantee rests on this
        check(Config::cases(60), "one_to_many == unrolled bitwise", |g| {
            let n = g.usize_in(2..40);
            let dim = 8 * g.usize_in(1..8);
            let data = random_matrix(g, n, dim);
            let q = g.vec_f32(dim, 8.0);
            let m = g.usize_in(0..n + 1);
            let ids: Vec<u32> = (0..m).map(|_| g.u32_in(0..n as u32)).collect();
            let mut out = Vec::new();
            let evals = one_to_many_blocked(&q, &data, &ids, &mut out);
            evals == m as u64
                && out.len() == m
                && ids.iter().enumerate().all(|(j, &v)| {
                    out[j].to_bits() == sq_l2_unrolled(&q, data.row(v as usize)).to_bits()
                })
        });
    }

    #[test]
    fn cross_bit_equals_unrolled() {
        check(Config::cases(60), "cross == unrolled bitwise", |g| {
            let n = g.usize_in(2..30);
            let dim = 8 * g.usize_in(1..6);
            let data = random_matrix(g, n, dim);
            let nq = g.usize_in(1..14);
            let queries = random_matrix(g, nq, dim);
            let m = g.usize_in(0..n.min(17) + 1);
            let ids: Vec<u32> = (0..m).map(|_| g.u32_in(0..n as u32)).collect();
            let mut out = vec![0f32; nq * m];
            let evals = cross_blocked(&queries, &data, &ids, &mut out);
            evals == (nq * m) as u64
                && (0..nq).all(|qi| {
                    ids.iter().enumerate().all(|(j, &v)| {
                        out[qi * m + j].to_bits()
                            == sq_l2_unrolled(queries.row(qi), data.row(v as usize)).to_bits()
                    })
                })
        });
    }

    #[test]
    fn cross_covers_all_remainder_shapes() {
        // pure tiles (5,10), pure remainders (1..4), mixed (7, 13)
        for (nq, m) in [(5, 10), (3, 3), (7, 13), (1, 1), (6, 5), (10, 4)] {
            let mut g = crate::testing::Gen::new_for_test((nq * 31 + m) as u64);
            let data = random_matrix(&mut g, 20, 16);
            let queries = random_matrix(&mut g, nq, 16);
            let ids: Vec<u32> = (0..m as u32).collect();
            let mut out = vec![0f32; nq * m];
            cross_blocked(&queries, &data, &ids, &mut out);
            for qi in 0..nq {
                for (j, &v) in ids.iter().enumerate() {
                    let expect = sq_l2_unrolled(queries.row(qi), data.row(v as usize));
                    assert_eq!(
                        out[qi * m + j].to_bits(),
                        expect.to_bits(),
                        "nq={nq} m={m} ({qi},{j})"
                    );
                }
            }
        }
    }

    #[test]
    fn buffer_reuse_grows() {
        let mut g = crate::testing::Gen::new_for_test(3);
        let data = random_matrix(&mut g, 20, 8);
        let mut buf = PairwiseBuf::with_capacity(2); // deliberately small
        let ids: Vec<u32> = (0..20).collect();
        pairwise_blocked(&data, &ids, &mut buf);
        assert_eq!(buf.m(), 20);
        assert!(buf.get(0, 19) > 0.0 || buf.get(0, 19) == 0.0); // no panic
    }
}
