//! Width-generic distance micro-kernels — the compute core behind the
//! [`dispatch`](super::dispatch) engine.
//!
//! Every hot distance shape exists here three times: monomorphized at
//! `LANES = 8` (AVX2-class `f32x8`, the paper's configuration),
//! `LANES = 16` (AVX-512-class `f32x16`), and as a scalar reference.
//! The shapes are the ones the paper's §3.3 blocking argument covers:
//!
//! * **pair** — one squared-L2 evaluation (`sq_l2_w`), the flexible
//!   kernel every remainder path shares.
//! * **pairwise 5×5** — all mutual distances of a candidate set
//!   (`pairwise_w`), NN-Descent's compute step (paper Fig 2).
//! * **one-to-many 1×5** — one query against a strip of corpus rows
//!   (`one_to_many_w`), the beam search's expansion shape.
//! * **cross 5×5** — a query tile against a corpus tile (`cross_w`),
//!   the batched serving probe shape.
//! * **norm-trick dot variants** (`one_to_many_dot_w`, `cross_dot_w`) —
//!   the GEMM-style factorization ‖q−y‖² = ‖q‖² + ‖y‖² − 2⟨q,y⟩ with
//!   precomputed norms, leaving only register-tiled dot products on the
//!   batch hot path (one fused multiply-add per component instead of a
//!   subtract + fused multiply-add).
//!
//! ## Bit-equality contract
//!
//! At a fixed width, every shape performs the *identical* per-pair
//! floating-point sequence: ascending `LANES`-wide chunks into one SIMD
//! accumulator via `mul_add`, one lane reduction, then (16-lane widths
//! on rows padded to 8) one shared 8-wide tail step. Blocking changes
//! only the load schedule, never the per-accumulator op order, so
//! results of the pair, strip, and tile kernels agree **bitwise** —
//! the property the serving layer's batch-equals-sequential guarantee
//! and the tests in `blocked.rs` pin down. The two dot kernels obey the
//! same contract with each other (and `sq_norm_w(q)` ≡ `dot_w(q, q)`
//! bitwise, which is what makes self-distances exactly zero on the
//! norm-trick path).
//!
//! Rows must be padded to a multiple of 8 zero-tailed lanes
//! ([`AlignedMatrix`] guarantees it); with `LANES = 16` a padded width
//! of `16m + 8` leaves exactly one 8-wide tail chunk.

use crate::dataset::AlignedMatrix;
use std::simd::num::SimdFloat;
use std::simd::{f32x8, LaneCount, Simd, StdFloat, SupportedLaneCount};

use super::blocked::{PairwiseBuf, BLOCK};
use super::scalar::sq_l2_scalar;

/// Reduce a spilled accumulator register to its lane sum — the engine's
/// one horizontal-sum helper (any supported width).
#[inline]
pub fn reduce_lanes<const L: usize>(acc: &[f32; L]) -> f32
where
    LaneCount<L>: SupportedLaneCount,
{
    Simd::from_array(*acc).reduce_sum()
}

/// Finish one norm-trick evaluation: ‖q‖² + ‖y‖² − 2⟨q,y⟩, clamped at
/// zero (catastrophic cancellation on near-identical rows can produce a
/// tiny negative). Both dot shapes share this exact expression, so the
/// sequential and batched probe paths stay bit-equal.
#[inline]
pub fn finish_norm_trick(q2: f32, y2: f32, dot: f32) -> f32 {
    ((q2 + y2) - 2.0 * dot).max(0.0)
}

/// Shared 8-wide tail step for squared-L2 (see module docs: rows are
/// padded to 8 lanes, so a 16-lane main loop leaves 0 or 1 such chunks).
#[inline]
fn sq_tail8(a: &[f32], b: &[f32], c: usize) -> f32 {
    let d = f32x8::from_slice(&a[c..c + 8]) - f32x8::from_slice(&b[c..c + 8]);
    d.mul_add(d, f32x8::splat(0.0)).reduce_sum()
}

/// Shared 8-wide tail step for dot products.
#[inline]
fn dot_tail8(a: &[f32], b: &[f32], c: usize) -> f32 {
    let x = f32x8::from_slice(&a[c..c + 8]);
    x.mul_add(f32x8::from_slice(&b[c..c + 8]), f32x8::splat(0.0)).reduce_sum()
}

/// Squared L2 over padded rows with one `L`-lane SIMD accumulator.
#[inline]
pub fn sq_l2_w<const L: usize>(a: &[f32], b: &[f32]) -> f32
where
    LaneCount<L>: SupportedLaneCount,
{
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len() % 8, 0, "rows must be padded to 8 lanes");
    let mut acc = Simd::<f32, L>::splat(0.0);
    let mut c = 0;
    while c + L <= a.len() {
        let d = Simd::<f32, L>::from_slice(&a[c..c + L]) - Simd::<f32, L>::from_slice(&b[c..c + L]);
        acc = d.mul_add(d, acc);
        c += L;
    }
    let mut s = acc.reduce_sum();
    if c < a.len() {
        s += sq_tail8(a, b, c);
    }
    s
}

/// Dot product over padded rows, same loop shape as [`sq_l2_w`].
#[inline]
pub fn dot_w<const L: usize>(a: &[f32], b: &[f32]) -> f32
where
    LaneCount<L>: SupportedLaneCount,
{
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len() % 8, 0, "rows must be padded to 8 lanes");
    let mut acc = Simd::<f32, L>::splat(0.0);
    let mut c = 0;
    while c + L <= a.len() {
        let x = Simd::<f32, L>::from_slice(&a[c..c + L]);
        acc = x.mul_add(Simd::<f32, L>::from_slice(&b[c..c + L]), acc);
        c += L;
    }
    let mut s = acc.reduce_sum();
    if c < a.len() {
        s += dot_tail8(a, b, c);
    }
    s
}

/// Squared norm of a padded row — bitwise identical to `dot_w(a, a)`.
#[inline]
pub fn sq_norm_w<const L: usize>(a: &[f32]) -> f32
where
    LaneCount<L>: SupportedLaneCount,
{
    dot_w::<L>(a, a)
}

#[inline]
fn round_up_block(x: usize) -> usize {
    x.div_ceil(BLOCK) * BLOCK
}

/// All mutual distances among `ids` with entries `(i, j)`, `i < active`,
/// `i < j` guaranteed — the 5×5-blocked compute-step kernel at width
/// `L`. Same fill pattern and evaluation accounting as the original
/// `f32x8` implementation (see `blocked::pairwise_blocked_active`).
pub fn pairwise_w<const L: usize>(
    data: &AlignedMatrix,
    ids: &[u32],
    active: usize,
    out: &mut PairwiseBuf,
) -> u64
where
    LaneCount<L>: SupportedLaneCount,
{
    let m = ids.len();
    let active = active.min(m);
    out.reset(m);
    if m < 2 || active == 0 {
        return 0;
    }
    let full = (m / BLOCK) * BLOCK;
    let dpad = data.dim_pad();
    let mut evals = 0u64;

    // Block rows that contain at least one active row.
    for ib in (0..full.min(round_up_block(active))).step_by(BLOCK) {
        diag_block_w::<L>(data, ids, ib, dpad, out);
        evals += (BLOCK * (BLOCK - 1) / 2) as u64;
        for jb in ((ib + BLOCK)..full).step_by(BLOCK) {
            off_diag_block_w::<L>(data, ids, ib, jb, dpad, out);
            evals += (BLOCK * BLOCK) as u64;
        }
    }

    // Remainder rows (m % 5): flexible pairwise kernel vs everything
    // with an index below them that could be consumed.
    for i in full..m {
        for j in 0..i {
            if j >= active && i >= active {
                continue;
            }
            let d = sq_l2_w::<L>(data.row(ids[i] as usize), data.row(ids[j] as usize));
            out.put(j, i, d);
            evals += 1;
        }
    }
    evals
}

/// One full 5×5 block: rows `ib..ib+5` × cols `jb..jb+5`. 25 `L`-lane
/// accumulators stay register-resident across the whole d-loop; per
/// step 10 loads feed 25 sub+fma pairs (paper Fig 2).
#[inline]
fn off_diag_block_w<const L: usize>(
    data: &AlignedMatrix,
    ids: &[u32],
    ib: usize,
    jb: usize,
    dpad: usize,
    out: &mut PairwiseBuf,
) where
    LaneCount<L>: SupportedLaneCount,
{
    let rows: [&[f32]; BLOCK] = std::array::from_fn(|a| data.row(ids[ib + a] as usize));
    let cols: [&[f32]; BLOCK] = std::array::from_fn(|b| data.row(ids[jb + b] as usize));

    let mut acc = [[Simd::<f32, L>::splat(0.0); BLOCK]; BLOCK];
    let mut c = 0;
    while c + L <= dpad {
        // Load the 5 column chunks once; they feed 25 accumulations.
        let cv: [Simd<f32, L>; BLOCK] =
            std::array::from_fn(|b| Simd::from_slice(&cols[b][c..c + L]));
        for a in 0..BLOCK {
            let ra = Simd::<f32, L>::from_slice(&rows[a][c..c + L]);
            for b in 0..BLOCK {
                let d = ra - cv[b];
                acc[a][b] = d.mul_add(d, acc[a][b]);
            }
        }
        c += L;
    }
    for a in 0..BLOCK {
        for b in 0..BLOCK {
            let mut s = acc[a][b].reduce_sum();
            if c < dpad {
                s += sq_tail8(rows[a], cols[b], c);
            }
            out.put(ib + a, jb + b, s);
        }
    }
}

/// Diagonal 5×5 block: the 10 unordered pairs within `ib..ib+5`.
#[inline]
fn diag_block_w<const L: usize>(
    data: &AlignedMatrix,
    ids: &[u32],
    ib: usize,
    dpad: usize,
    out: &mut PairwiseBuf,
) where
    LaneCount<L>: SupportedLaneCount,
{
    let rows: [&[f32]; BLOCK] = std::array::from_fn(|a| data.row(ids[ib + a] as usize));
    // 10 pair slots: (a,b) with a<b, flattened.
    const PAIRS: [(usize, usize); 10] =
        [(0, 1), (0, 2), (0, 3), (0, 4), (1, 2), (1, 3), (1, 4), (2, 3), (2, 4), (3, 4)];
    let mut acc = [Simd::<f32, L>::splat(0.0); 10];
    let mut c = 0;
    while c + L <= dpad {
        let chunk: [Simd<f32, L>; BLOCK] =
            std::array::from_fn(|a| Simd::from_slice(&rows[a][c..c + L]));
        for (p, &(a, b)) in PAIRS.iter().enumerate() {
            let d = chunk[a] - chunk[b];
            acc[p] = d.mul_add(d, acc[p]);
        }
        c += L;
    }
    for (p, &(a, b)) in PAIRS.iter().enumerate() {
        let mut s = acc[p].reduce_sum();
        if c < dpad {
            s += sq_tail8(rows[a], rows[b], c);
        }
        out.put(ib + a, ib + b, s);
    }
}

/// Distances from one padded query row to the `ids` rows of `data` —
/// the 1×5-blocked expansion strip at width `L`. Bit-equal per pair to
/// [`sq_l2_w`]`::<L>`. Returns `ids.len()` evaluations.
pub fn one_to_many_w<const L: usize>(
    q: &[f32],
    data: &AlignedMatrix,
    ids: &[u32],
    out: &mut Vec<f32>,
) -> u64
where
    LaneCount<L>: SupportedLaneCount,
{
    let dpad = data.dim_pad();
    debug_assert_eq!(q.len(), dpad, "query must be padded to the matrix width");
    let m = ids.len();
    out.clear();
    out.resize(m, 0.0);
    let full = (m / BLOCK) * BLOCK;
    for jb in (0..full).step_by(BLOCK) {
        let rows: [&[f32]; BLOCK] = std::array::from_fn(|b| data.row(ids[jb + b] as usize));
        let mut acc = [Simd::<f32, L>::splat(0.0); BLOCK];
        let mut c = 0;
        while c + L <= dpad {
            let qv = Simd::<f32, L>::from_slice(&q[c..c + L]);
            for b in 0..BLOCK {
                let d = qv - Simd::<f32, L>::from_slice(&rows[b][c..c + L]);
                acc[b] = d.mul_add(d, acc[b]);
            }
            c += L;
        }
        for b in 0..BLOCK {
            let mut s = acc[b].reduce_sum();
            if c < dpad {
                s += sq_tail8(q, rows[b], c);
            }
            out[jb + b] = s;
        }
    }
    for j in full..m {
        out[j] = sq_l2_w::<L>(q, data.row(ids[j] as usize));
    }
    m as u64
}

/// Query×corpus 5×5 cross tiles at width `L`, row-major into
/// `out[qi · ids.len() + j]`. Bit-equal per pair to [`sq_l2_w`]`::<L>`.
pub fn cross_w<const L: usize>(
    queries: &AlignedMatrix,
    data: &AlignedMatrix,
    ids: &[u32],
    out: &mut [f32],
) -> u64
where
    LaneCount<L>: SupportedLaneCount,
{
    assert_eq!(queries.dim_pad(), data.dim_pad(), "query/corpus width mismatch");
    let (nq, m) = (queries.n(), ids.len());
    assert_eq!(out.len(), nq * m, "output buffer size mismatch");
    let dpad = data.dim_pad();
    let qfull = (nq / BLOCK) * BLOCK;
    let cfull = (m / BLOCK) * BLOCK;
    for ib in (0..qfull).step_by(BLOCK) {
        let qrows: [&[f32]; BLOCK] = std::array::from_fn(|a| queries.row(ib + a));
        for jb in (0..cfull).step_by(BLOCK) {
            let crows: [&[f32]; BLOCK] = std::array::from_fn(|b| data.row(ids[jb + b] as usize));
            let mut acc = [[Simd::<f32, L>::splat(0.0); BLOCK]; BLOCK];
            let mut c = 0;
            while c + L <= dpad {
                let cv: [Simd<f32, L>; BLOCK] =
                    std::array::from_fn(|b| Simd::from_slice(&crows[b][c..c + L]));
                for a in 0..BLOCK {
                    let qa = Simd::<f32, L>::from_slice(&qrows[a][c..c + L]);
                    for b in 0..BLOCK {
                        let d = qa - cv[b];
                        acc[a][b] = d.mul_add(d, acc[a][b]);
                    }
                }
                c += L;
            }
            for a in 0..BLOCK {
                for b in 0..BLOCK {
                    let mut s = acc[a][b].reduce_sum();
                    if c < dpad {
                        s += sq_tail8(qrows[a], crows[b], c);
                    }
                    out[(ib + a) * m + jb + b] = s;
                }
            }
        }
        for j in cfull..m {
            let row = data.row(ids[j] as usize);
            for (a, q) in qrows.iter().enumerate() {
                out[(ib + a) * m + j] = sq_l2_w::<L>(q, row);
            }
        }
    }
    for qi in qfull..nq {
        let q = queries.row(qi);
        for j in 0..m {
            out[qi * m + j] = sq_l2_w::<L>(q, data.row(ids[j] as usize));
        }
    }
    (nq * m) as u64
}

/// Norm-trick expansion strip: distances from one padded query (norm
/// `q2`) to the `ids` rows, using precomputed per-row `norms` and 1×5
/// register-tiled dot products. Per pair: one fused multiply-add per
/// component (vs subtract + fma on the direct path).
pub fn one_to_many_dot_w<const L: usize>(
    q: &[f32],
    q2: f32,
    data: &AlignedMatrix,
    norms: &[f32],
    ids: &[u32],
    out: &mut Vec<f32>,
) -> u64
where
    LaneCount<L>: SupportedLaneCount,
{
    let dpad = data.dim_pad();
    debug_assert_eq!(q.len(), dpad, "query must be padded to the matrix width");
    debug_assert_eq!(norms.len(), data.n(), "one norm per corpus row");
    let m = ids.len();
    out.clear();
    out.resize(m, 0.0);
    let full = (m / BLOCK) * BLOCK;
    for jb in (0..full).step_by(BLOCK) {
        let rows: [&[f32]; BLOCK] = std::array::from_fn(|b| data.row(ids[jb + b] as usize));
        let mut acc = [Simd::<f32, L>::splat(0.0); BLOCK];
        let mut c = 0;
        while c + L <= dpad {
            let qv = Simd::<f32, L>::from_slice(&q[c..c + L]);
            for b in 0..BLOCK {
                acc[b] = qv.mul_add(Simd::<f32, L>::from_slice(&rows[b][c..c + L]), acc[b]);
            }
            c += L;
        }
        for b in 0..BLOCK {
            let mut dot = acc[b].reduce_sum();
            if c < dpad {
                dot += dot_tail8(q, rows[b], c);
            }
            out[jb + b] = finish_norm_trick(q2, norms[ids[jb + b] as usize], dot);
        }
    }
    for j in full..m {
        let dot = dot_w::<L>(q, data.row(ids[j] as usize));
        out[j] = finish_norm_trick(q2, norms[ids[j] as usize], dot);
    }
    m as u64
}

/// Norm-trick cross tiles: query×corpus distances via 5×5 register-tiled
/// dot products plus precomputed norms (`qnorms[qi]`, `norms[row]`).
/// Bit-equal per pair to [`one_to_many_dot_w`]`::<L>` — the batched
/// probe stage matches the sequential one exactly.
pub fn cross_dot_w<const L: usize>(
    queries: &AlignedMatrix,
    qnorms: &[f32],
    data: &AlignedMatrix,
    norms: &[f32],
    ids: &[u32],
    out: &mut [f32],
) -> u64
where
    LaneCount<L>: SupportedLaneCount,
{
    assert_eq!(queries.dim_pad(), data.dim_pad(), "query/corpus width mismatch");
    debug_assert_eq!(qnorms.len(), queries.n(), "one norm per query row");
    debug_assert_eq!(norms.len(), data.n(), "one norm per corpus row");
    let (nq, m) = (queries.n(), ids.len());
    assert_eq!(out.len(), nq * m, "output buffer size mismatch");
    let dpad = data.dim_pad();
    let qfull = (nq / BLOCK) * BLOCK;
    let cfull = (m / BLOCK) * BLOCK;
    for ib in (0..qfull).step_by(BLOCK) {
        let qrows: [&[f32]; BLOCK] = std::array::from_fn(|a| queries.row(ib + a));
        for jb in (0..cfull).step_by(BLOCK) {
            let crows: [&[f32]; BLOCK] = std::array::from_fn(|b| data.row(ids[jb + b] as usize));
            let mut acc = [[Simd::<f32, L>::splat(0.0); BLOCK]; BLOCK];
            let mut c = 0;
            while c + L <= dpad {
                let cv: [Simd<f32, L>; BLOCK] =
                    std::array::from_fn(|b| Simd::from_slice(&crows[b][c..c + L]));
                for a in 0..BLOCK {
                    let qa = Simd::<f32, L>::from_slice(&qrows[a][c..c + L]);
                    for b in 0..BLOCK {
                        acc[a][b] = qa.mul_add(cv[b], acc[a][b]);
                    }
                }
                c += L;
            }
            for a in 0..BLOCK {
                for b in 0..BLOCK {
                    let mut dot = acc[a][b].reduce_sum();
                    if c < dpad {
                        dot += dot_tail8(qrows[a], crows[b], c);
                    }
                    out[(ib + a) * m + jb + b] =
                        finish_norm_trick(qnorms[ib + a], norms[ids[jb + b] as usize], dot);
                }
            }
        }
        for j in cfull..m {
            let row = data.row(ids[j] as usize);
            let y2 = norms[ids[j] as usize];
            for (a, q) in qrows.iter().enumerate() {
                let dot = dot_w::<L>(q, row);
                out[(ib + a) * m + j] = finish_norm_trick(qnorms[ib + a], y2, dot);
            }
        }
    }
    for qi in qfull..nq {
        let q = queries.row(qi);
        for j in 0..m {
            let dot = dot_w::<L>(q, data.row(ids[j] as usize));
            out[qi * m + j] = finish_norm_trick(qnorms[qi], norms[ids[j] as usize], dot);
        }
    }
    (nq * m) as u64
}

// ---------------------------------------------------------------------
// Scalar reference set (the `PALLAS_KERNEL=scalar` forced path): same
// contracts, same fill patterns, same evaluation accounting — one pair
// at a time through `scalar::sq_l2_scalar` / plain-loop dot products.
// ---------------------------------------------------------------------

/// Scalar squared norm (plain loop).
pub fn sq_norm_scalar(a: &[f32]) -> f32 {
    dot_scalar(a, a)
}

/// Scalar dot product (plain loop).
pub fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc += a[i] * b[i];
    }
    acc
}

/// Scalar pairwise: exactly the pairs `(i, j)`, `i < j` with at least
/// one endpoint below `active` (the minimal fill the contract requires).
pub fn pairwise_scalar(
    data: &AlignedMatrix,
    ids: &[u32],
    active: usize,
    out: &mut PairwiseBuf,
) -> u64 {
    let m = ids.len();
    let active = active.min(m);
    out.reset(m);
    if m < 2 || active == 0 {
        return 0;
    }
    let mut evals = 0u64;
    for i in 0..m {
        for j in (i + 1)..m {
            if i >= active && j >= active {
                continue;
            }
            let d = sq_l2_scalar(data.row(ids[i] as usize), data.row(ids[j] as usize));
            out.put(i, j, d);
            evals += 1;
        }
    }
    evals
}

/// Scalar one-to-many.
pub fn one_to_many_scalar(
    q: &[f32],
    data: &AlignedMatrix,
    ids: &[u32],
    out: &mut Vec<f32>,
) -> u64 {
    debug_assert_eq!(q.len(), data.dim_pad(), "query must be padded to the matrix width");
    out.clear();
    out.extend(ids.iter().map(|&v| sq_l2_scalar(q, data.row(v as usize))));
    ids.len() as u64
}

/// Scalar cross.
pub fn cross_scalar(
    queries: &AlignedMatrix,
    data: &AlignedMatrix,
    ids: &[u32],
    out: &mut [f32],
) -> u64 {
    assert_eq!(queries.dim_pad(), data.dim_pad(), "query/corpus width mismatch");
    let (nq, m) = (queries.n(), ids.len());
    assert_eq!(out.len(), nq * m, "output buffer size mismatch");
    for qi in 0..nq {
        let q = queries.row(qi);
        for (j, &v) in ids.iter().enumerate() {
            out[qi * m + j] = sq_l2_scalar(q, data.row(v as usize));
        }
    }
    (nq * m) as u64
}

/// Scalar norm-trick one-to-many.
pub fn one_to_many_dot_scalar(
    q: &[f32],
    q2: f32,
    data: &AlignedMatrix,
    norms: &[f32],
    ids: &[u32],
    out: &mut Vec<f32>,
) -> u64 {
    debug_assert_eq!(q.len(), data.dim_pad(), "query must be padded to the matrix width");
    debug_assert_eq!(norms.len(), data.n(), "one norm per corpus row");
    out.clear();
    out.extend(ids.iter().map(|&v| {
        finish_norm_trick(q2, norms[v as usize], dot_scalar(q, data.row(v as usize)))
    }));
    ids.len() as u64
}

/// Scalar norm-trick cross.
pub fn cross_dot_scalar(
    queries: &AlignedMatrix,
    qnorms: &[f32],
    data: &AlignedMatrix,
    norms: &[f32],
    ids: &[u32],
    out: &mut [f32],
) -> u64 {
    assert_eq!(queries.dim_pad(), data.dim_pad(), "query/corpus width mismatch");
    debug_assert_eq!(qnorms.len(), queries.n(), "one norm per query row");
    debug_assert_eq!(norms.len(), data.n(), "one norm per corpus row");
    let (nq, m) = (queries.n(), ids.len());
    assert_eq!(out.len(), nq * m, "output buffer size mismatch");
    for qi in 0..nq {
        let q = queries.row(qi);
        for (j, &v) in ids.iter().enumerate() {
            let dot = dot_scalar(q, data.row(v as usize));
            out[qi * m + j] = finish_norm_trick(qnorms[qi], norms[v as usize], dot);
        }
    }
    (nq * m) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::scalar::sq_l2_f64;
    use crate::testing::{check, Config};

    #[test]
    fn reduce_lanes_exact() {
        // the engine's one horizontal-sum helper (absorbed the old
        // `unrolled::horizontal_sum`): exactness at both widths
        let acc8 = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        assert_eq!(reduce_lanes::<8>(&acc8), 36.0);
        let acc16: [f32; 16] = std::array::from_fn(|i| (i + 1) as f32);
        assert_eq!(reduce_lanes::<16>(&acc16), 136.0);
    }

    #[test]
    fn w16_tail_handles_odd_chunk_counts() {
        // dpad % 16 == 8 is the interesting case: one 8-wide tail chunk
        for chunks in [1usize, 2, 3, 5] {
            let len = chunks * 8;
            let mut g = crate::testing::Gen::new_for_test(chunks as u64);
            let a = g.vec_f32(len, 6.0);
            let b = g.vec_f32(len, 6.0);
            let w16 = sq_l2_w::<16>(&a, &b) as f64;
            let o = sq_l2_f64(&a, &b);
            assert!((w16 - o).abs() <= 1e-4 * (1.0 + o), "chunks={chunks}: {w16} vs {o}");
        }
    }

    #[test]
    fn dot_and_norm_consistency() {
        check(Config::cases(100), "sq_norm_w == dot_w(a,a) bitwise", |g| {
            let len = 8 * g.usize_in(1..12);
            let a = g.vec_f32(len, 5.0);
            sq_norm_w::<8>(&a).to_bits() == dot_w::<8>(&a, &a).to_bits()
                && sq_norm_w::<16>(&a).to_bits() == dot_w::<16>(&a, &a).to_bits()
                && sq_norm_scalar(&a).to_bits() == dot_scalar(&a, &a).to_bits()
        });
    }

    #[test]
    fn norm_trick_self_distance_is_exactly_zero() {
        // the clamp + shared-sequence argument: q2 == y2 == dot bitwise
        // for identical rows, so the finish expression is exactly 0
        let mut g = crate::testing::Gen::new_for_test(9);
        for len in [8usize, 24, 40] {
            let a = g.vec_f32(len, 100.0);
            for (q2, dot) in [
                (sq_norm_w::<8>(&a), dot_w::<8>(&a, &a)),
                (sq_norm_w::<16>(&a), dot_w::<16>(&a, &a)),
                (sq_norm_scalar(&a), dot_scalar(&a, &a)),
            ] {
                assert_eq!(finish_norm_trick(q2, q2, dot), 0.0, "len={len}");
            }
        }
    }

    #[test]
    fn finish_norm_trick_clamps_negative() {
        assert_eq!(finish_norm_trick(1.0, 1.0, 1.0000001), 0.0);
    }
}
