//! Cross-width parity suite: every kernel shape of every selectable
//! width ([`KernelWidth::ALL`]) against the f64 scalar oracle, on random
//! and adversarial inputs.
//!
//! This is the gate behind the dispatcher: forcing `PALLAS_KERNEL` to
//! any width must never change *correctness*, only speed. The suite
//! exercises each width's [`KernelSet`] directly (not through the
//! process-global dispatch), so one test run covers the scalar, w8, and
//! w16 paths regardless of what the current machine/env selected —
//! including the 16-lane tail step on rows whose padded width is
//! `8 mod 16`. CI additionally re-runs the whole `distance::` module
//! with `PALLAS_KERNEL=scalar` and `=w8` so the env override and the
//! narrow fallback stay exercised end-to-end on runners without
//! AVX-512 (w16 needs no hardware gate: portable SIMD keeps it correct
//! everywhere, so it is tested unconditionally here).
//!
//! Tolerances: direct kernels are compared at `1e-3` relative to the
//! oracle distance. Norm-trick results compare at `1e-3` relative to
//! the *magnitude scale* (`1 + ‖q‖² + ‖y‖²`): the factorization
//! ‖q‖² + ‖y‖² − 2⟨q,y⟩ inherently loses the low bits of the norms to
//! cancellation when the true distance is far smaller than the norms —
//! that is the documented trade-off of the GEMM-style path, not a bug.

use crate::dataset::AlignedMatrix;
use crate::testing::{check, Config, Gen};

use super::dispatch::{kernel_set, KernelSet, KernelWidth};
use super::scalar::sq_l2_f64;
use super::PairwiseBuf;

fn dot_f64(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Direct-kernel tolerance: relative to the oracle distance.
fn close_direct(got: f32, oracle: f64) -> bool {
    (got as f64 - oracle).abs() <= 1e-3 * (1.0 + oracle.abs())
}

/// Norm-trick tolerance: relative to the magnitude scale of the inputs.
fn close_norm_trick(got: f32, oracle: f64, a: &[f32], b: &[f32]) -> bool {
    let scale = 1.0 + dot_f64(a, a) + dot_f64(b, b);
    (got as f64 - oracle).abs() <= 1e-3 * scale
}

/// Run every shape of one kernel set over (queries × corpus[ids]) and
/// compare each produced distance to the f64 oracle.
fn check_set(set: &KernelSet, queries: &AlignedMatrix, data: &AlignedMatrix, ids: &[u32]) {
    let w = set.width.name();
    let m = ids.len();
    let nq = queries.n();

    // pair + sq_norm
    for qi in 0..nq {
        let q = queries.row(qi);
        let n2 = (set.sq_norm)(q);
        assert!(
            close_direct(n2, dot_f64(q, q)),
            "{w}: sq_norm q{qi}: {n2} vs {}",
            dot_f64(q, q)
        );
        for &v in ids {
            let o = sq_l2_f64(q, data.row(v as usize));
            let d = (set.pair)(q, data.row(v as usize));
            assert!(close_direct(d, o), "{w}: pair q{qi}×{v}: {d} vs {o}");
        }
    }

    // pairwise 5×5 over the corpus subset (full active)
    let mut buf = PairwiseBuf::with_capacity(m.max(1));
    let evals = (set.pairwise_active)(data, ids, m, &mut buf);
    if m >= 2 {
        assert_eq!(evals, (m * (m - 1) / 2) as u64, "{w}: pairwise eval count");
        for i in 0..m {
            for j in (i + 1)..m {
                let o = sq_l2_f64(data.row(ids[i] as usize), data.row(ids[j] as usize));
                let d = buf.get(i, j);
                assert!(close_direct(d, o), "{w}: pairwise ({i},{j}): {d} vs {o}");
            }
        }
    }

    // one-to-many strips + cross tiles
    let mut strip = Vec::new();
    let mut tile = vec![0f32; nq * m];
    (set.cross)(queries, data, ids, &mut tile);
    for qi in 0..nq {
        let q = queries.row(qi);
        (set.one_to_many)(q, data, ids, &mut strip);
        for (j, &v) in ids.iter().enumerate() {
            let o = sq_l2_f64(q, data.row(v as usize));
            assert!(close_direct(strip[j], o), "{w}: one_to_many q{qi}×{v}: {} vs {o}", strip[j]);
            assert!(
                close_direct(tile[qi * m + j], o),
                "{w}: cross q{qi}×{v}: {} vs {o}",
                tile[qi * m + j]
            );
        }
    }

    // norm-trick path: precomputed norms, strips and tiles
    let norms: Vec<f32> = (0..data.n()).map(|i| (set.sq_norm)(data.row(i))).collect();
    let qnorms: Vec<f32> = (0..nq).map(|qi| (set.sq_norm)(queries.row(qi))).collect();
    let mut ntile = vec![0f32; nq * m];
    (set.cross_norms)(queries, &qnorms, data, &norms, ids, &mut ntile);
    for qi in 0..nq {
        let q = queries.row(qi);
        (set.one_to_many_norms)(q, qnorms[qi], data, &norms, ids, &mut strip);
        for (j, &v) in ids.iter().enumerate() {
            let y = data.row(v as usize);
            let o = sq_l2_f64(q, y);
            assert!(
                close_norm_trick(strip[j], o, q, y),
                "{w}: one_to_many_norms q{qi}×{v}: {} vs {o}",
                strip[j]
            );
            assert!(
                close_norm_trick(ntile[qi * m + j], o, q, y),
                "{w}: cross_norms q{qi}×{v}: {} vs {o}",
                ntile[qi * m + j]
            );
            // sequential and batched norm-trick paths must agree bitwise
            assert_eq!(
                strip[j].to_bits(),
                ntile[qi * m + j].to_bits(),
                "{w}: norm-trick strip/tile divergence at q{qi}×{v}"
            );
        }
    }
}

fn random_matrix(g: &mut Gen, n: usize, dim: usize, scale: f32) -> AlignedMatrix {
    let data = g.vec_f32(n * dim, scale);
    AlignedMatrix::from_rows(n, dim, &data)
}

#[test]
fn parity_random_inputs_all_widths_all_shapes() {
    // dims chosen to hit every 16-lane layout: pad % 16 == 8 (pure-tail
    // and mixed) and pad % 16 == 0 (no tail)
    check(Config::cases(40), "kernel parity vs f64 oracle", |g| {
        let dim = [8, 9, 16, 17, 24, 40, 48][g.usize_in(0..7)];
        let n = g.usize_in(6..28);
        let nq = g.usize_in(1..9);
        let data = random_matrix(g, n, dim, 8.0);
        let queries = random_matrix(g, nq, dim, 8.0);
        let m = g.usize_in(1..n + 1);
        // ids may repeat rows — kernels must not care
        let ids: Vec<u32> = (0..m).map(|_| g.u32_in(0..n as u32)).collect();
        for width in KernelWidth::ALL {
            check_set(kernel_set(width), &queries, &data, &ids);
        }
        true
    });
}

#[test]
fn parity_adversarial_inputs() {
    // zero rows, exact duplicates, large magnitudes, and tail-exercising
    // padded widths — the cases where summation-order bugs would hide
    for dim in [8usize, 17, 24] {
        let mut g = Gen::new_for_test(dim as u64);
        let n = 12;
        let mut rows: Vec<f32> = Vec::new();
        for i in 0..n {
            let row: Vec<f32> = match i {
                0 => vec![0.0; dim],                        // zero row
                1 => vec![1e4; dim],                        // large constant
                2 => vec![-1e4; dim],                       // large negative
                3 => (0..dim).map(|j| j as f32 * 1e3).collect(), // large ramp
                _ => g.vec_f32(dim, 50.0),
            };
            rows.extend(row);
        }
        // row 4 duplicates row 1 exactly (self-distance stress)
        let dup = rows[dim..2 * dim].to_vec();
        rows.splice(4 * dim..5 * dim, dup);
        let data = AlignedMatrix::from_rows(n, dim, &rows);
        // queries: the adversarial rows themselves + one random row
        let qrows: Vec<f32> = rows[..5 * dim]
            .iter()
            .copied()
            .chain(g.vec_f32(dim, 50.0))
            .collect();
        let queries = AlignedMatrix::from_rows(6, dim, &qrows);
        let ids: Vec<u32> = (0..n as u32).collect();
        for width in KernelWidth::ALL {
            check_set(kernel_set(width), &queries, &data, &ids);
        }
    }
}

#[test]
fn parity_norm_trick_exact_zero_on_duplicates() {
    // querying with a corpus row must give exactly 0 on the norm-trick
    // path at every width (the bit-identity argument in kernel.rs)
    for dim in [8usize, 16, 17] {
        let mut g = Gen::new_for_test(0xD0 + dim as u64);
        let data = random_matrix(&mut g, 10, dim, 1e3);
        let ids: Vec<u32> = (0..10).collect();
        for width in KernelWidth::ALL {
            let set = kernel_set(width);
            let norms: Vec<f32> = (0..10).map(|i| (set.sq_norm)(data.row(i))).collect();
            let mut out = Vec::new();
            for qi in 0..10usize {
                let q = data.row(qi);
                (set.one_to_many_norms)(q, norms[qi], &data, &norms, &ids, &mut out);
                assert_eq!(
                    out[qi],
                    0.0,
                    "{}: self distance of row {qi} (dim {dim}) not exactly zero",
                    width.name()
                );
            }
        }
    }
}

#[test]
fn parity_empty_id_sets() {
    let mut g = Gen::new_for_test(77);
    let data = random_matrix(&mut g, 4, 16, 2.0);
    let queries = random_matrix(&mut g, 2, 16, 2.0);
    for width in KernelWidth::ALL {
        let set = kernel_set(width);
        let mut out = Vec::new();
        assert_eq!((set.one_to_many)(queries.row(0), &data, &[], &mut out), 0);
        assert!(out.is_empty());
        let mut tile: Vec<f32> = Vec::new();
        assert_eq!((set.cross)(&queries, &data, &[], &mut tile), 0);
        let mut buf = PairwiseBuf::with_capacity(4);
        assert_eq!((set.pairwise_active)(&data, &[], 0, &mut buf), 0);
    }
}
