//! Runtime kernel dispatch: pick a distance-kernel width once per
//! process and route every hot shape through it.
//!
//! The engine exposes three [`KernelSet`]s — `scalar`, `w8` (the
//! paper's `f32x8` configuration), and `w16` (`f32x16`, which lowers to
//! AVX-512 instructions where available) — each a table of function
//! pointers into the monomorphized micro-kernels of
//! [`kernel`](super::kernel). Selection order:
//!
//! 1. a programmatic override ([`force`], set by the CLI's `--kernel`
//!    flag or by benches doing per-width A/B comparisons), else
//! 2. the `PALLAS_KERNEL` environment variable (`scalar` | `w8` |
//!    `w16`), read once, else
//! 3. CPU detection: x86 with `avx512f` → `w16`; everything else → `w8`.
//!
//! Forcing `w16` on hardware without AVX-512 is *allowed*: the kernels
//! are portable SIMD, so they stay correct everywhere — the width is a
//! performance choice, never a safety one. All shapes in one process
//! always share one active width, which is what keeps the engine's
//! bit-equality guarantees (see `kernel.rs`) intact across the
//! sequential and batched serving paths.
//!
//! `active()` costs one relaxed atomic load — negligible next to any
//! distance evaluation — so the thin shims in `unrolled.rs`/`blocked.rs`
//! can consult it per call without a measurable hot-path tax.

use crate::dataset::AlignedMatrix;
use crate::distance::blocked::PairwiseBuf;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

use super::kernel;

/// A selectable distance-kernel width.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelWidth {
    /// Plain-loop reference kernels (forced-path testing, oracles).
    Scalar,
    /// 8-lane portable SIMD (`f32x8`; AVX2-class — the paper's config).
    W8,
    /// 16-lane portable SIMD (`f32x16`; AVX-512-class).
    W16,
}

impl KernelWidth {
    /// Parse a `PALLAS_KERNEL` / `--kernel` value (case-insensitive).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(Self::Scalar),
            "w8" | "8" => Some(Self::W8),
            "w16" | "16" => Some(Self::W16),
            _ => None,
        }
    }

    /// Stable label used in reports, bench rows, and counters.
    pub fn name(self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::W8 => "w8",
            Self::W16 => "w16",
        }
    }

    /// SIMD lanes per accumulator (1 for the scalar reference).
    pub fn lanes(self) -> usize {
        match self {
            Self::Scalar => 1,
            Self::W8 => 8,
            Self::W16 => 16,
        }
    }

    /// All selectable widths, narrowest reference first.
    pub const ALL: [KernelWidth; 3] = [Self::Scalar, Self::W8, Self::W16];
}

/// One width's complete kernel table — every hot distance shape plus
/// the norm-trick (GEMM-style) batch variants.
pub struct KernelSet {
    pub width: KernelWidth,
    /// One squared-L2 evaluation over padded rows.
    pub pair: fn(&[f32], &[f32]) -> f32,
    /// Squared norm of one padded row.
    pub sq_norm: fn(&[f32]) -> f32,
    /// 5×5-blocked mutual distances (compute-step shape).
    pub pairwise_active: fn(&AlignedMatrix, &[u32], usize, &mut PairwiseBuf) -> u64,
    /// 1×5-blocked one-to-many strip (expansion shape).
    pub one_to_many: fn(&[f32], &AlignedMatrix, &[u32], &mut Vec<f32>) -> u64,
    /// 5×5 query×corpus cross tiles (batch probe shape).
    pub cross: fn(&AlignedMatrix, &AlignedMatrix, &[u32], &mut [f32]) -> u64,
    /// Norm-trick one-to-many: `(q, ‖q‖², data, norms, ids, out)`.
    pub one_to_many_norms: fn(&[f32], f32, &AlignedMatrix, &[f32], &[u32], &mut Vec<f32>) -> u64,
    /// Norm-trick cross: `(queries, qnorms, data, norms, ids, out)`.
    pub cross_norms: fn(&AlignedMatrix, &[f32], &AlignedMatrix, &[f32], &[u32], &mut [f32]) -> u64,
}

static SCALAR_SET: KernelSet = KernelSet {
    width: KernelWidth::Scalar,
    pair: crate::distance::scalar::sq_l2_scalar,
    sq_norm: kernel::sq_norm_scalar,
    pairwise_active: kernel::pairwise_scalar,
    one_to_many: kernel::one_to_many_scalar,
    cross: kernel::cross_scalar,
    one_to_many_norms: kernel::one_to_many_dot_scalar,
    cross_norms: kernel::cross_dot_scalar,
};

static W8_SET: KernelSet = KernelSet {
    width: KernelWidth::W8,
    pair: kernel::sq_l2_w::<8>,
    sq_norm: kernel::sq_norm_w::<8>,
    pairwise_active: kernel::pairwise_w::<8>,
    one_to_many: kernel::one_to_many_w::<8>,
    cross: kernel::cross_w::<8>,
    one_to_many_norms: kernel::one_to_many_dot_w::<8>,
    cross_norms: kernel::cross_dot_w::<8>,
};

static W16_SET: KernelSet = KernelSet {
    width: KernelWidth::W16,
    pair: kernel::sq_l2_w::<16>,
    sq_norm: kernel::sq_norm_w::<16>,
    pairwise_active: kernel::pairwise_w::<16>,
    one_to_many: kernel::one_to_many_w::<16>,
    cross: kernel::cross_w::<16>,
    one_to_many_norms: kernel::one_to_many_dot_w::<16>,
    cross_norms: kernel::cross_dot_w::<16>,
};

/// The static kernel table of a given width (width-explicit access for
/// parity tests and A/B harnesses; production code uses [`active`]).
pub fn kernel_set(w: KernelWidth) -> &'static KernelSet {
    match w {
        KernelWidth::Scalar => &SCALAR_SET,
        KernelWidth::W8 => &W8_SET,
        KernelWidth::W16 => &W16_SET,
    }
}

// Programmatic override: 0 = none, else KernelWidth discriminant + 1.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);
// Env/CPU default, resolved once on first use.
static DEFAULT: OnceLock<KernelWidth> = OnceLock::new();

fn code(w: KernelWidth) -> u8 {
    match w {
        KernelWidth::Scalar => 1,
        KernelWidth::W8 => 2,
        KernelWidth::W16 => 3,
    }
}

fn from_code(c: u8) -> Option<KernelWidth> {
    match c {
        1 => Some(KernelWidth::Scalar),
        2 => Some(KernelWidth::W8),
        3 => Some(KernelWidth::W16),
        _ => None,
    }
}

/// True when the CPU exposes AVX-512 foundation instructions.
#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
pub fn avx512_supported() -> bool {
    is_x86_feature_detected!("avx512f")
}

/// True when the CPU exposes AVX-512 foundation instructions.
#[cfg(not(any(target_arch = "x86", target_arch = "x86_64")))]
pub fn avx512_supported() -> bool {
    false
}

/// The width CPU detection alone would pick (ignores overrides).
pub fn detect() -> KernelWidth {
    if avx512_supported() {
        KernelWidth::W16
    } else {
        KernelWidth::W8
    }
}

/// The `PALLAS_KERNEL` environment override, if present and valid.
pub fn env_override() -> Option<KernelWidth> {
    std::env::var("PALLAS_KERNEL").ok().and_then(|v| KernelWidth::parse(&v))
}

fn resolve_default() -> KernelWidth {
    match std::env::var("PALLAS_KERNEL") {
        Ok(v) => KernelWidth::parse(&v).unwrap_or_else(|| {
            eprintln!(
                "warning: PALLAS_KERNEL=`{v}` is not one of scalar|w8|w16 — \
                 falling back to CPU detection"
            );
            detect()
        }),
        Err(_) => detect(),
    }
}

/// Force a kernel width process-wide (`None` clears the override and
/// returns to env/CPU selection). Meant for startup configuration (the
/// CLI's `--kernel` flag) and single-threaded A/B harnesses: switching
/// widths while other threads run distance kernels breaks the
/// bit-equality guarantees *between* their calls (each call is still
/// individually correct).
pub fn force(w: Option<KernelWidth>) {
    OVERRIDE.store(w.map_or(0, code), Ordering::Relaxed);
    if let Some(w) = w {
        if w == KernelWidth::W16 && !avx512_supported() {
            eprintln!(
                "note: w16 kernels forced without AVX-512 — portable SIMD keeps them \
                 correct, but expect no speedup on this CPU"
            );
        }
    }
}

/// The active kernel width (override → `PALLAS_KERNEL` → CPU detection).
#[inline]
pub fn active_width() -> KernelWidth {
    match from_code(OVERRIDE.load(Ordering::Relaxed)) {
        Some(w) => w,
        None => *DEFAULT.get_or_init(resolve_default),
    }
}

/// The active kernel table — what every shim in `unrolled.rs` /
/// `blocked.rs` routes through.
#[inline]
pub fn active() -> &'static KernelSet {
    kernel_set(active_width())
}

/// Human-readable description of the current selection (CLI `info`,
/// bench headers).
pub fn describe() -> String {
    let w = active_width();
    let source = if from_code(OVERRIDE.load(Ordering::Relaxed)).is_some() {
        "forced"
    } else if env_override().is_some() {
        "PALLAS_KERNEL"
    } else {
        "cpu-detect"
    };
    format!(
        "{} ({} lanes, via {source}; avx512f {})",
        w.name(),
        w.lanes(),
        if avx512_supported() { "available" } else { "unavailable" }
    )
}

/// Dispatch-routed norm-trick one-to-many (see
/// [`KernelSet::one_to_many_norms`]).
#[inline]
pub fn one_to_many_norms(
    q: &[f32],
    q2: f32,
    data: &AlignedMatrix,
    norms: &[f32],
    ids: &[u32],
    out: &mut Vec<f32>,
) -> u64 {
    (active().one_to_many_norms)(q, q2, data, norms, ids, out)
}

/// Dispatch-routed norm-trick cross (see [`KernelSet::cross_norms`]).
#[inline]
pub fn cross_norms(
    queries: &AlignedMatrix,
    qnorms: &[f32],
    data: &AlignedMatrix,
    norms: &[f32],
    ids: &[u32],
    out: &mut [f32],
) -> u64 {
    (active().cross_norms)(queries, qnorms, data, norms, ids, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_names() {
        for w in KernelWidth::ALL {
            assert_eq!(KernelWidth::parse(w.name()), Some(w));
        }
        assert_eq!(KernelWidth::parse("W16"), Some(KernelWidth::W16));
        assert_eq!(KernelWidth::parse("8"), Some(KernelWidth::W8));
        assert_eq!(KernelWidth::parse("avx512"), None);
    }

    #[test]
    fn lanes_match_widths() {
        assert_eq!(KernelWidth::Scalar.lanes(), 1);
        assert_eq!(KernelWidth::W8.lanes(), 8);
        assert_eq!(KernelWidth::W16.lanes(), 16);
    }

    #[test]
    fn kernel_sets_carry_their_width() {
        for w in KernelWidth::ALL {
            assert_eq!(kernel_set(w).width, w);
        }
    }

    #[test]
    fn active_honors_env_when_no_override() {
        // No override is ever set by lib tests (forcing is process-global
        // and would race concurrently-running kernel tests), so `active`
        // must equal the env override when one is present, and a SIMD
        // width from detection otherwise.
        let w = active_width();
        match env_override() {
            Some(e) => assert_eq!(w, e, "env override must win"),
            None => assert!(matches!(w, KernelWidth::W8 | KernelWidth::W16)),
        }
        assert_eq!(active().width, w);
    }

    #[test]
    fn describe_mentions_active_width() {
        let d = describe();
        assert!(d.contains(active_width().name()), "{d}");
    }
}
