//! Squared-L2 distance kernels (paper §3.3).
//!
//! The implementation is restricted to (squared) L2 — exactly the
//! trade-off the paper makes: giving up generic metrics buys blocked
//! evaluation. Three native tiers mirror the paper's version tags:
//!
//! | paper tag       | function                 | idea |
//! |-----------------|--------------------------|------|
//! | (baseline)      | [`scalar::sq_l2_scalar`] | plain loop |
//! | `l2intrinsics` + `mem-align` | [`unrolled::sq_l2_unrolled`] | 8 independent accumulator lanes over the padded row (compiles to 8-wide FMA SIMD) |
//! | `blocked`       | [`blocked::pairwise_blocked`] | 5×5-vector blocks: 10 row loads feed 25 distance accumulations |
//!
//! All kernels consume **padded** rows from
//! [`AlignedMatrix`](crate::dataset::AlignedMatrix) (width a multiple of
//! 8, zero tail), so no remainder handling exists anywhere — the same
//! simplification the paper gets from requiring `d % 8 == 0`.
//!
//! The fourth backend (`pjrt`) lives in [`crate::runtime`]: it executes
//! the AOT-lowered Pallas kernel instead of native code.

pub mod blocked;
pub mod scalar;
pub mod unrolled;

pub use blocked::{cross_blocked, one_to_many_blocked, pairwise_blocked, PairwiseBuf};
pub use scalar::sq_l2_scalar;
pub use unrolled::sq_l2_unrolled;

use crate::config::schema::ComputeKind;

/// Evaluate one squared-L2 distance with the given native backend.
/// (`Pjrt` is handled a level up, in the compute step — it is a batch
/// backend; per-pair it falls back to `unrolled`.)
#[inline]
pub fn sq_l2(kind: ComputeKind, a: &[f32], b: &[f32]) -> f32 {
    match kind {
        ComputeKind::Scalar => sq_l2_scalar(a, b),
        ComputeKind::Unrolled | ComputeKind::Blocked | ComputeKind::Pjrt => sq_l2_unrolled(a, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Config};

    #[test]
    fn dispatch_consistency() {
        check(Config::cases(100), "sq_l2 dispatch agrees", |g| {
            let lanes = 8 * g.usize_in(1..12);
            let a = g.vec_f32(lanes, 5.0);
            let b = g.vec_f32(lanes, 5.0);
            let s = sq_l2(ComputeKind::Scalar, &a, &b);
            let u = sq_l2(ComputeKind::Unrolled, &a, &b);
            let tol = 1e-4 * (1.0 + s.abs());
            (s - u).abs() <= tol
        });
    }
}
