//! Squared-L2 distance kernels (paper §3.3) behind a width-generic
//! kernel engine with runtime CPU dispatch.
//!
//! The implementation is restricted to (squared) L2 — exactly the
//! trade-off the paper makes: giving up generic metrics buys blocked
//! evaluation. The engine is organised in three layers:
//!
//! | layer | module | role |
//! |-------|--------|------|
//! | micro-kernels | [`kernel`] | width-generic loops (`Simd<f32, L>`, `L ∈ {8, 16}`) + scalar references for every hot shape |
//! | dispatch | [`dispatch`] | one process-wide width pick: `--kernel`/[`dispatch::force`] → `PALLAS_KERNEL` env → CPU detection (`avx512f` → 16 lanes) |
//! | stable shims | [`unrolled`], [`blocked`] | the historical free functions, now one indirect call into the active [`dispatch::KernelSet`] |
//!
//! The paper's version tags map onto the shims unchanged:
//!
//! | paper tag       | function                 | idea |
//! |-----------------|--------------------------|------|
//! | (baseline)      | [`scalar::sq_l2_scalar`] | plain loop |
//! | `l2intrinsics` + `mem-align` | [`unrolled::sq_l2_unrolled`] | one SIMD accumulator over the padded row (8- or 16-wide FMA) |
//! | `blocked`       | [`blocked::pairwise_blocked`] | 5×5-vector blocks: 10 row loads feed 25 distance accumulations |
//!
//! Serving additionally uses the engine's **norm-trick** shapes
//! ([`dispatch::one_to_many_norms`], [`dispatch::cross_norms`]):
//! ‖q−y‖² = ‖q‖² + ‖y‖² − 2⟨q,y⟩ with per-index precomputed corpus
//! norms, reducing the batch probe stage to register-tiled dot products.
//!
//! All kernels consume **padded** rows from
//! [`AlignedMatrix`](crate::dataset::AlignedMatrix) (width a multiple of
//! 8, zero tail); 16-lane kernels absorb the possible `8 mod 16` rest
//! with one shared 8-wide tail step, so no general remainder handling
//! exists anywhere — the same simplification the paper gets from
//! requiring `d % 8 == 0`.
//!
//! The fourth backend (`pjrt`) lives in [`crate::runtime`]: it executes
//! the AOT-lowered Pallas kernel instead of native code.

pub mod blocked;
pub mod dispatch;
pub mod kernel;
pub mod scalar;
pub mod unrolled;

#[cfg(test)]
mod parity;

pub use blocked::{cross_blocked, one_to_many_blocked, pairwise_blocked, PairwiseBuf};
pub use dispatch::KernelWidth;
pub use scalar::sq_l2_scalar;
pub use unrolled::{sq_l2_unrolled, sq_norm};

use crate::config::schema::ComputeKind;

/// Evaluate one squared-L2 distance with the given native backend.
/// (`Pjrt` is handled a level up, in the compute step — it is a batch
/// backend; per-pair it falls back to `unrolled`.)
#[inline]
pub fn sq_l2(kind: ComputeKind, a: &[f32], b: &[f32]) -> f32 {
    match kind {
        ComputeKind::Scalar => sq_l2_scalar(a, b),
        ComputeKind::Unrolled | ComputeKind::Blocked | ComputeKind::Pjrt => sq_l2_unrolled(a, b),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Config};

    #[test]
    fn dispatch_consistency() {
        check(Config::cases(100), "sq_l2 dispatch agrees", |g| {
            let lanes = 8 * g.usize_in(1..12);
            let a = g.vec_f32(lanes, 5.0);
            let b = g.vec_f32(lanes, 5.0);
            let s = sq_l2(ComputeKind::Scalar, &a, &b);
            let u = sq_l2(ComputeKind::Unrolled, &a, &b);
            let tol = 1e-4 * (1.0 + s.abs());
            (s - u).abs() <= tol
        });
    }
}
