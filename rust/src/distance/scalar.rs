//! Baseline scalar squared-L2 kernel — the reference implementation and
//! correctness oracle for every other distance path (native and Pallas).

/// Squared L2 distance between two equal-length slices, plain loop.
///
/// The square root is omitted throughout the crate (paper §3.3): NN
/// comparisons are monotone in the squared distance.
#[inline]
pub fn sq_l2_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// f64-accumulated variant used by tests as a high-precision oracle.
pub fn sq_l2_f64(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_values() {
        assert_eq!(sq_l2_scalar(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(sq_l2_scalar(&[1.0], &[1.0]), 0.0);
        assert_eq!(sq_l2_scalar(&[], &[]), 0.0);
    }

    #[test]
    fn symmetry_and_nonnegativity() {
        let a = [1.5f32, -2.0, 0.25, 7.0];
        let b = [0.5f32, 3.0, -1.0, 2.0];
        assert_eq!(sq_l2_scalar(&a, &b), sq_l2_scalar(&b, &a));
        assert!(sq_l2_scalar(&a, &b) >= 0.0);
    }

    #[test]
    fn matches_f64_oracle() {
        let a: Vec<f32> = (0..64).map(|i| (i as f32) * 0.37 - 5.0).collect();
        let b: Vec<f32> = (0..64).map(|i| (i as f32) * -0.11 + 2.0).collect();
        let s = sq_l2_scalar(&a, &b) as f64;
        let o = sq_l2_f64(&a, &b);
        assert!((s - o).abs() / o < 1e-5);
    }
}
