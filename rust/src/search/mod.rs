//! Approximate nearest-neighbor *serving* over a built K-NN graph —
//! what downstream consumers (UMAP and friends, §1 of the paper) do
//! with the graph once NN-Descent has produced it.
//!
//! * [`GraphIndex`] wraps the finished graph + data (plus precomputed
//!   per-row corpus norms for the norm-trick probe kernels) and answers
//!   queries with the standard greedy beam search (best-first expansion
//!   over the graph with a bounded candidate pool, PyNNDescent-style),
//!   one query at a time ([`GraphIndex::search`]) or as a batch tiled
//!   through the dispatched blocked kernels
//!   ([`GraphIndex::search_batch`]).
//! * [`IndexBundle`] + [`save_index`]/[`load_index`] persist everything
//!   a serving process needs — graph, aligned data matrix, reordering,
//!   corpus norms, build parameters — as one checksummed `KNNIv1`
//!   artifact (pre-norms bundles load fine; norms are recomputed).
//! * [`SearchScratch`] makes the per-query working state an owned,
//!   reusable value: `GraphIndex` is `Send + Sync` (plain owned data,
//!   `&self` search entry points), and each worker thread of the
//!   concurrent serving runtime (`api::serve`) holds its own scratch —
//!   the ownership discipline that keeps multi-threaded fan-out
//!   lock-free and bit-identical to sequential serving.

pub mod beam;
pub mod bundle;

pub use beam::{BatchStats, GraphIndex, QueryStats, SearchParams, SearchScratch};
pub use bundle::{load_index, save_index, save_index_parts, IndexBundle};
