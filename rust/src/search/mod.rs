//! Approximate nearest-neighbor *queries* over a built K-NN graph —
//! what downstream consumers (UMAP and friends, §1 of the paper) do
//! with the graph once NN-Descent has produced it.
//!
//! [`GraphIndex`] wraps the finished graph + data and answers queries
//! with the standard greedy beam search (best-first expansion over the
//! graph with a bounded candidate pool, PyNNDescent-style): start from
//! a few seed nodes, repeatedly expand the closest unexpanded candidate,
//! keep the best `ef` seen, stop when the pool stops improving.

pub mod beam;

pub use beam::{GraphIndex, QueryStats, SearchParams};
