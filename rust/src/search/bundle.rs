//! `KNNIv1` index bundles: one persistent, checksummed artifact holding
//! everything a serving process needs — the built graph, the aligned
//! data matrix it refers to (working layout), the reordering that maps
//! working ids back to original ids, and the build parameters. Extends
//! the `KNNGv1` discipline of `graph::io` (magic, little-endian fixed
//! header, FNV-1a trailer, corruption detection) from "a graph" to "a
//! servable index".
//!
//! Layout (little-endian):
//!
//! ```text
//! magic    8 B   "KNNIv1\0\0"
//! n        8 B   u64  points
//! dim      8 B   u64  logical dimensionality
//! k        8 B   u64  neighbors per node in the stored graph
//! flags    8 B   u64  bit 0: reordering present · bit 1: norms present
//!                bit 2: centroids present
//!                bits 8–15: SIMD lane count the norms were computed at
//!                bits 16–31: centroid count (0 iff bit 2 clear)
//! params  64 B   build parameters:
//!                k, max_iters, seed, reorder_iter, max_candidates (u64)
//!                rho, delta (f64)
//!                selection, compute, reorder (u8) + 5 B zero padding
//! ids      n·k·4 B   u32 neighbor ids, heap order (EMPTY_ID = open)
//! dists    n·k·4 B   f32 neighbor distances, heap order
//! data     n·dim·4 B f32 row-major logical rows (padding rebuilt on load)
//! sigma    n·4 B  u32 node → working position   (iff flags bit 0)
//! inv      n·4 B  u32 working position → node   (iff flags bit 0)
//! norms    n·4 B  f32 per-row squared corpus norms (iff flags bit 1)
//! centroids c·dim·4 B f32 partition centroid rows (iff flags bit 2;
//!                c from flags bits 16–31)
//! crc      8 B   FNV-1a over everything above
//! ```
//!
//! The norms section feeds the serving layer's norm-trick probe
//! kernels. It is optional so every pre-existing `KNNIv1` file stays
//! loadable: when the flag is absent, [`IndexBundle::into_index`]
//! recomputes the norms from the data section at the active kernel
//! width. Norm values depend on the summation order of the kernel
//! width that produced them, so the width is recorded in flags bits
//! 8–15 and the loader *discards* stored norms computed at a different
//! width than the active one (recomputing preserves the exact-zero
//! self-distance guarantee of the norm-trick path across machines).
//!
//! The centroids section carries the partition centroids of a
//! cluster-aware sharded build (`api::partition`), so a per-shard
//! bundle can reconstruct query routing without re-planning. It is
//! optional exactly like norms: legacy bundles load unchanged, and the
//! centroid count lives in the flags word (bits 16–31) so the exact
//! expected file size stays header-derivable.
//!
//! Like `KNNGv1`, a bundle is a finished artifact, not a resumable
//! build: graph flags/counters are rebuilt on load.

use super::beam::GraphIndex;
use crate::dataset::AlignedMatrix;
use crate::graph::io::Fnv;
use crate::graph::KnnGraph;
use crate::nndescent::reorder::Reordering;
use crate::nndescent::{BuildResult, Params};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"KNNIv1\0\0";
const FLAG_REORDERING: u64 = 1;
const FLAG_NORMS: u64 = 2;
const FLAG_CENTROIDS: u64 = 4;
/// Bits 8–15 of `flags`: lane count of the kernel width the norms
/// section was computed at (1 = scalar, 8, 16; 0 only in legacy files).
const FLAG_NORM_LANES_SHIFT: u64 = 8;
const FLAG_NORM_LANES_MASK: u64 = 0xFF << FLAG_NORM_LANES_SHIFT;
/// Bits 16–31 of `flags`: number of centroid rows in the centroids
/// section (0 iff the section is absent). Kept in the header so the
/// exact-file-size check can account for the section before any reads.
const FLAG_CENTROID_COUNT_SHIFT: u64 = 16;
const FLAG_CENTROID_COUNT_MASK: u64 = 0xFFFF << FLAG_CENTROID_COUNT_SHIFT;

/// A loaded (or about-to-be-saved) index bundle. `data` and `graph`
/// share one id space — the *working* layout of the build, so a served
/// index keeps the locality the greedy reordering bought.
pub struct IndexBundle {
    /// Data matrix in the graph's id space.
    pub data: AlignedMatrix,
    /// The built K-NN graph.
    pub graph: KnnGraph,
    /// σ/σ⁻¹ mapping original ↔ working ids (present iff the build
    /// reordered). `inv[working]` is the original dataset id.
    pub reordering: Option<Reordering>,
    /// Parameters the graph was built with.
    pub params: Params,
    /// Per-row squared corpus norms for the norm-trick serving path
    /// (absent in legacy bundles; recomputed by
    /// [`into_index`](Self::into_index)).
    pub norms: Option<Vec<f32>>,
    /// Lane count of the kernel width `norms` was computed at
    /// (0 when `norms` is `None`).
    pub norm_lanes: usize,
    /// Partition centroids of a cluster-aware sharded build (one row
    /// per shard of the *whole* sharded index, so every shard's bundle
    /// carries the full routing table). Absent in legacy bundles and
    /// unsharded builds.
    pub centroids: Option<AlignedMatrix>,
}

impl IndexBundle {
    /// Assemble a bundle from a finished build. `data_original` is the
    /// dataset in its original id space (as fed to `NnDescent::build`);
    /// it is permuted into the working layout when the build reordered.
    pub fn from_build(
        data_original: &AlignedMatrix,
        result: &BuildResult,
        params: &Params,
    ) -> Self {
        let data = result.working_data_ref(data_original);
        let norms = Some(GraphIndex::compute_norms(&data));
        let norm_lanes = crate::distance::dispatch::active_width().lanes();
        Self {
            data,
            graph: result.graph.clone(),
            reordering: result.reordering.clone(),
            params: params.clone(),
            norms,
            norm_lanes,
            centroids: None,
        }
    }

    /// Turn the bundle into a servable index plus the id mapping and
    /// build parameters. Norms absent from the bundle (legacy files)
    /// are recomputed here.
    pub fn into_index(self) -> (GraphIndex, Option<Reordering>, Params) {
        let index = match self.norms {
            Some(norms) => GraphIndex::with_norms(self.data, self.graph, norms),
            None => GraphIndex::new(self.data, self.graph),
        };
        (index, self.reordering, self.params)
    }

    /// Map a working-space result id back to the original dataset id.
    pub fn original_id(reordering: &Option<Reordering>, working: u32) -> u32 {
        match reordering {
            Some(r) => r.inv[working as usize],
            None => working,
        }
    }
}

/// Encode build parameters into the fixed 64-byte block shared by the
/// `KNNIv1` bundle and the store engine's `KNNIv2` segment headers.
pub(crate) fn encode_params(p: &Params) -> [u8; 64] {
    let mut out = [0u8; 64];
    out[0..8].copy_from_slice(&(p.k as u64).to_le_bytes());
    out[8..16].copy_from_slice(&(p.max_iters as u64).to_le_bytes());
    out[16..24].copy_from_slice(&p.seed.to_le_bytes());
    out[24..32].copy_from_slice(&(p.reorder_iter as u64).to_le_bytes());
    out[32..40].copy_from_slice(&(p.max_candidates as u64).to_le_bytes());
    out[40..48].copy_from_slice(&p.rho.to_le_bytes());
    out[48..56].copy_from_slice(&p.delta.to_le_bytes());
    out[56] = p.selection.code();
    out[57] = p.compute.code();
    out[58] = p.reorder as u8;
    out
}

/// Decode the fixed 64-byte parameter block (see [`encode_params`]).
pub(crate) fn decode_params(b: &[u8; 64]) -> Result<Params> {
    let u64_at = |o: usize| u64::from_le_bytes(b[o..o + 8].try_into().unwrap());
    let f64_at = |o: usize| f64::from_le_bytes(b[o..o + 8].try_into().unwrap());
    let selection = crate::config::schema::SelectionKind::from_code(b[56])
        .with_context(|| format!("unknown selection code {}", b[56]))?;
    let compute = crate::config::schema::ComputeKind::from_code(b[57])
        .with_context(|| format!("unknown compute code {}", b[57]))?;
    Ok(Params {
        k: u64_at(0) as usize,
        max_iters: u64_at(8) as usize,
        seed: u64_at(16),
        reorder_iter: u64_at(24) as usize,
        max_candidates: u64_at(32) as usize,
        rho: f64_at(40),
        delta: f64_at(48),
        selection,
        compute,
        reorder: b[58] != 0,
        // build-time knob, not persisted: loaded bundles report "auto"
        threads: 0,
    })
}

/// Serialize an index bundle.
pub fn save_index(path: &Path, bundle: &IndexBundle) -> Result<()> {
    save_index_parts(
        path,
        &bundle.data,
        &bundle.graph,
        bundle.reordering.as_ref(),
        &bundle.params,
        bundle.norms.as_deref().map(|ns| (ns, bundle.norm_lanes)),
        bundle.centroids.as_ref(),
    )
}

/// Serialize an index bundle from borrowed components (avoids cloning
/// the data matrix when the caller — e.g. `api::Index::save` — owns the
/// parts separately). `norms` pairs the per-row squared norms with the
/// lane count of the kernel width that *computed* them (the tag the
/// loader's width-mismatch guard trusts — pass the recorded width, not
/// the current one). Passing `None` writes the legacy layout without a
/// norms section (the loader recomputes them). `centroids` optionally
/// persists the partition centroids of a sharded build (rows must share
/// the data's logical dimensionality).
pub fn save_index_parts(
    path: &Path,
    data: &AlignedMatrix,
    graph: &KnnGraph,
    reordering: Option<&Reordering>,
    params: &Params,
    norms: Option<(&[f32], usize)>,
    centroids: Option<&AlignedMatrix>,
) -> Result<()> {
    assert_eq!(data.n(), graph.n(), "bundle graph/data size mismatch");
    if let Some(r) = reordering {
        r.validate().map_err(|e| anyhow::anyhow!("invalid reordering: {e}"))?;
        assert_eq!(r.sigma.len(), data.n(), "reordering length mismatch");
    }
    if let Some((ns, lanes)) = norms {
        assert_eq!(ns.len(), data.n(), "norms length mismatch");
        assert!(lanes > 0 && lanes <= 0xFF, "implausible norm lane count {lanes}");
    }
    if let Some(c) = centroids {
        assert_eq!(c.dim(), data.dim(), "centroid/data dim mismatch");
        assert!(c.n() >= 1 && c.n() <= u16::MAX as usize, "implausible centroid count {}", c.n());
    }
    let f = std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    let mut crc = Fnv::new();
    let mut emit = |w: &mut BufWriter<std::fs::File>, bytes: &[u8]| -> Result<()> {
        crc.update(bytes);
        w.write_all(bytes)?;
        Ok(())
    };
    emit(&mut w, MAGIC)?;
    emit(&mut w, &(data.n() as u64).to_le_bytes())?;
    emit(&mut w, &(data.dim() as u64).to_le_bytes())?;
    emit(&mut w, &(graph.k() as u64).to_le_bytes())?;
    let mut flags = 0u64;
    if reordering.is_some() {
        flags |= FLAG_REORDERING;
    }
    if let Some((_, lanes)) = norms {
        // norm values are summation-order-dependent: record the width
        // that computed them so a different-width loader recomputes
        flags |= FLAG_NORMS;
        flags |= (lanes as u64) << FLAG_NORM_LANES_SHIFT;
    }
    if let Some(c) = centroids {
        flags |= FLAG_CENTROIDS;
        flags |= (c.n() as u64) << FLAG_CENTROID_COUNT_SHIFT;
    }
    emit(&mut w, &flags.to_le_bytes())?;
    emit(&mut w, &encode_params(params))?;
    for u in 0..graph.n() {
        for &v in graph.ids(u) {
            emit(&mut w, &v.to_le_bytes())?;
        }
    }
    for u in 0..graph.n() {
        for &d in graph.dists(u) {
            emit(&mut w, &d.to_le_bytes())?;
        }
    }
    let mut row_buf = Vec::with_capacity(data.dim() * 4);
    for i in 0..data.n() {
        row_buf.clear();
        for &x in data.row_logical(i) {
            row_buf.extend_from_slice(&x.to_le_bytes());
        }
        emit(&mut w, &row_buf)?;
    }
    if let Some(r) = reordering {
        for &s in &r.sigma {
            emit(&mut w, &s.to_le_bytes())?;
        }
        for &p in &r.inv {
            emit(&mut w, &p.to_le_bytes())?;
        }
    }
    if let Some((ns, _)) = norms {
        for &x in ns {
            emit(&mut w, &x.to_le_bytes())?;
        }
    }
    if let Some(c) = centroids {
        for i in 0..c.n() {
            row_buf.clear();
            for &x in c.row_logical(i) {
                row_buf.extend_from_slice(&x.to_le_bytes());
            }
            emit(&mut w, &row_buf)?;
        }
    }
    w.write_all(&crc.0.to_le_bytes())?;
    w.flush()?;
    Ok(())
}

/// Deserialize an index bundle (validates magic/version, header
/// plausibility, edge sanity, reordering consistency, and checksum).
pub fn load_index(path: &Path) -> Result<IndexBundle> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut crc = Fnv::new();

    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("reading magic")?;
    if &magic != MAGIC {
        if &magic == crate::store::format::MAGIC_V2 {
            bail!(
                "this is a KNNIv2 storage-engine segment — open it with store::MutableIndex \
                 (or `knng store`), not the KNNIv1 bundle loader"
            );
        }
        if magic.starts_with(b"KNNI") {
            bail!(
                "unsupported index bundle version {:?} (this build reads KNNIv1)",
                String::from_utf8_lossy(&magic[..6])
            );
        }
        bail!("not a KNNIv1 index bundle (magic {:02x?})", magic);
    }
    crc.update(&magic);

    let mut buf8 = [0u8; 8];
    let mut read_u64 = |r: &mut BufReader<std::fs::File>, crc: &mut Fnv| -> Result<u64> {
        r.read_exact(&mut buf8)?;
        crc.update(&buf8);
        Ok(u64::from_le_bytes(buf8))
    };
    let n = read_u64(&mut r, &mut crc)? as usize;
    let dim = read_u64(&mut r, &mut crc)? as usize;
    let k = read_u64(&mut r, &mut crc)? as usize;
    let flags = read_u64(&mut r, &mut crc)?;
    if n < 2 || k < 1 || dim < 1 || dim > 1_000_000 {
        bail!("implausible index header: n={n}, dim={dim}, k={k}");
    }
    // KnnGraph invariants (checked here so corrupt headers error instead
    // of panicking in the constructor)
    if k > u16::MAX as usize || n > u32::MAX as usize - 1 {
        bail!("implausible index header: n={n}, k={k}");
    }
    if n.checked_mul(k).is_none() || n * k > (1 << 34) {
        bail!("implausible graph size: n={n}, k={k}");
    }
    if n.checked_mul(dim).is_none() || n * dim > (1 << 36) {
        bail!("implausible data size: n={n}, dim={dim}");
    }
    let known = FLAG_REORDERING
        | FLAG_NORMS
        | FLAG_CENTROIDS
        | FLAG_NORM_LANES_MASK
        | FLAG_CENTROID_COUNT_MASK;
    if flags & !known != 0 {
        bail!("unknown flag bits {flags:#x}");
    }
    // The lane tag can only be a width this engine ever computes norms
    // at (1 = scalar, 8, 16); anything else is corruption or a future
    // format, and silently recomputing would mask it. Without a norms
    // section the tag must be zero.
    let stored_lanes = ((flags & FLAG_NORM_LANES_MASK) >> FLAG_NORM_LANES_SHIFT) as usize;
    if flags & FLAG_NORMS != 0 {
        if !matches!(stored_lanes, 1 | 8 | 16) {
            bail!("implausible norm lane count {stored_lanes} (valid widths: 1, 8, 16)");
        }
    } else if stored_lanes != 0 {
        bail!("norm lane count {stored_lanes} recorded without a norms section");
    }
    // Centroid count and flag must agree: a count without the section
    // (or the section without a count) is corruption, not a default.
    let cent_count = ((flags & FLAG_CENTROID_COUNT_MASK) >> FLAG_CENTROID_COUNT_SHIFT) as usize;
    if flags & FLAG_CENTROIDS != 0 {
        if cent_count == 0 {
            bail!("centroids section recorded with a zero centroid count");
        }
    } else if cent_count != 0 {
        bail!("centroid count {cent_count} recorded without a centroids section");
    }

    // The format is fixed-size given the header, so the exact file
    // length is known up front. Checking it here (a) catches truncation
    // early and (b) keeps a corrupt header from driving the strip
    // allocations below to absurd sizes before the CRC could object.
    let actual = std::fs::metadata(path)?.len();
    let reorder_bytes = if flags & FLAG_REORDERING != 0 { 2 * n as u64 * 4 } else { 0 };
    let norm_bytes = if flags & FLAG_NORMS != 0 { n as u64 * 4 } else { 0 };
    let cent_bytes = cent_count as u64 * dim as u64 * 4;
    let expected = 8 + 32 + 64 // magic + header + params
        + 2 * (n as u64 * k as u64 * 4) // ids + dists
        + n as u64 * dim as u64 * 4 // data rows
        + reorder_bytes
        + norm_bytes
        + cent_bytes
        + 8; // crc
    if actual != expected {
        bail!(
            "index bundle size mismatch: file is {actual} bytes, header implies {expected} \
             — truncated or corrupt"
        );
    }

    let mut params_buf = [0u8; 64];
    r.read_exact(&mut params_buf).context("reading build params")?;
    crc.update(&params_buf);
    let params = decode_params(&params_buf)?;

    let mut buf4 = [0u8; 4];
    let mut ids = vec![0u32; n * k];
    for slot in ids.iter_mut() {
        r.read_exact(&mut buf4)?;
        crc.update(&buf4);
        *slot = u32::from_le_bytes(buf4);
    }
    let mut dists = vec![0f32; n * k];
    for slot in dists.iter_mut() {
        r.read_exact(&mut buf4)?;
        crc.update(&buf4);
        *slot = f32::from_le_bytes(buf4);
    }

    let mut data = AlignedMatrix::zeroed(n, dim);
    let mut row_buf = vec![0u8; dim * 4];
    for i in 0..n {
        r.read_exact(&mut row_buf).with_context(|| format!("reading data row {i}"))?;
        crc.update(&row_buf);
        let row = data.row_mut(i);
        for (j, chunk) in row_buf.chunks_exact(4).enumerate() {
            row[j] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
    }

    let reordering = if flags & FLAG_REORDERING != 0 {
        let mut sigma = vec![0u32; n];
        for slot in sigma.iter_mut() {
            r.read_exact(&mut buf4)?;
            crc.update(&buf4);
            *slot = u32::from_le_bytes(buf4);
        }
        let mut inv = vec![0u32; n];
        for slot in inv.iter_mut() {
            r.read_exact(&mut buf4)?;
            crc.update(&buf4);
            *slot = u32::from_le_bytes(buf4);
        }
        Some(Reordering { sigma, inv })
    } else {
        None
    };

    let norms = if flags & FLAG_NORMS != 0 {
        let mut ns = vec![0f32; n];
        for slot in ns.iter_mut() {
            r.read_exact(&mut buf4)?;
            crc.update(&buf4);
            *slot = f32::from_le_bytes(buf4);
        }
        // Stored norms carry the summation order of the width that
        // computed them. Keep them only when it matches the active
        // width; otherwise drop the section (into_index recomputes) so
        // the norm-trick path keeps its exact-zero self-distance
        // guarantee on this machine.
        if stored_lanes == crate::distance::dispatch::active_width().lanes() {
            Some(ns)
        } else {
            None
        }
    } else {
        None
    };
    let norm_lanes = if norms.is_some() { stored_lanes } else { 0 };

    let centroids = if flags & FLAG_CENTROIDS != 0 {
        let mut c = AlignedMatrix::zeroed(cent_count, dim);
        for i in 0..cent_count {
            r.read_exact(&mut row_buf).with_context(|| format!("reading centroid row {i}"))?;
            crc.update(&row_buf);
            let row = c.row_mut(i);
            for (j, chunk) in row_buf.chunks_exact(4).enumerate() {
                row[j] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
        }
        Some(c)
    } else {
        None
    };

    let mut trailer = [0u8; 8];
    r.read_exact(&mut trailer).context("reading checksum")?;
    if u64::from_le_bytes(trailer) != crc.0 {
        bail!("checksum mismatch — index bundle corrupt");
    }

    // semantic validation after the integrity check, so corruption is
    // reported as corruption rather than as a structural error
    if let Some(r) = &reordering {
        r.validate().map_err(|e| anyhow::anyhow!("corrupt reordering: {e}"))?;
    }
    let graph = crate::graph::io::rebuild_graph(n, k, &ids, &dists)?;

    Ok(IndexBundle { data, graph, reordering, params, norms, norm_lanes, centroids })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::clustered::SynthClustered;
    use crate::nndescent::NnDescent;
    use crate::search::SearchParams;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("knng_bundle_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn build_bundle(n: usize, seed: u64, reorder: bool) -> (IndexBundle, AlignedMatrix, Params) {
        let (data, _) = SynthClustered::new(n, 16, 6, seed).generate_labeled();
        let params = Params::default().with_k(10).with_seed(seed).with_reorder(reorder);
        let result = NnDescent::new(params.clone()).build(&data).unwrap();
        (IndexBundle::from_build(&data, &result, &params), data, params)
    }

    #[test]
    fn roundtrip_preserves_graph_data_reordering_params() {
        let (bundle, _, params) = build_bundle(500, 11, true);
        assert!(bundle.reordering.is_some(), "reorder build must carry σ");
        let path = tmp("rt.knni");
        save_index(&path, &bundle).unwrap();
        let loaded = load_index(&path).unwrap();

        assert_eq!(loaded.params, params);
        loaded.graph.validate().unwrap();
        assert_eq!(loaded.graph.n(), bundle.graph.n());
        assert_eq!(loaded.graph.k(), bundle.graph.k());
        for u in 0..bundle.graph.n() {
            assert_eq!(bundle.graph.sorted(u), loaded.graph.sorted(u), "node {u}");
        }
        // data rows bit-exact
        assert_eq!(loaded.data.n(), bundle.data.n());
        assert_eq!(loaded.data.dim(), bundle.data.dim());
        for i in 0..bundle.data.n() {
            assert_eq!(bundle.data.row(i), loaded.data.row(i), "row {i}");
        }
        let (rs, ls) = (bundle.reordering.as_ref().unwrap(), loaded.reordering.as_ref().unwrap());
        assert_eq!(rs.sigma, ls.sigma);
        assert_eq!(rs.inv, ls.inv);
        // persisted norms come back bit-exact
        let (ns, ln) = (bundle.norms.as_ref().unwrap(), loaded.norms.as_ref().unwrap());
        assert_eq!(ns.len(), ln.len());
        for (a, b) in ns.iter().zip(ln) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn legacy_bundle_without_norms_loads_and_serves_identically() {
        // a file written without the norms section (every pre-norms
        // KNNIv1 artifact) must load, recompute norms, and serve exactly
        // like a with-norms bundle of the same build
        let (bundle, data, _) = build_bundle(400, 31, true);
        let with = tmp("with_norms.knni");
        let without = tmp("without_norms.knni");
        save_index(&with, &bundle).unwrap();
        save_index_parts(
            &without,
            &bundle.data,
            &bundle.graph,
            bundle.reordering.as_ref(),
            &bundle.params,
            None,
            None,
        )
        .unwrap();
        assert!(
            std::fs::metadata(&with).unwrap().len()
                > std::fs::metadata(&without).unwrap().len(),
            "norms section must add bytes"
        );
        let legacy = load_index(&without).unwrap();
        assert!(legacy.norms.is_none(), "legacy file carries no norms");
        let (idx_legacy, _, _) = legacy.into_index();
        let (idx_with, _, _) = load_index(&with).unwrap().into_index();
        // recomputed norms equal persisted ones (same width, same data)
        assert_eq!(idx_legacy.norms().len(), idx_with.norms().len());
        for (a, b) in idx_legacy.norms().iter().zip(idx_with.norms()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let sp = SearchParams::default();
        for qi in (0..400).step_by(37) {
            let (a, sa) = idx_legacy.search(data.row_logical(qi), 5, &sp);
            let (b, sb) = idx_with.search(data.row_logical(qi), 5, &sp);
            assert_eq!(a, b, "query {qi}");
            assert_eq!(sa, sb);
        }
    }

    #[test]
    fn loaded_index_serves_identically() {
        let (bundle, data, _) = build_bundle(600, 5, false);
        let path = tmp("serve.knni");
        save_index(&path, &bundle).unwrap();
        let (orig, _, _) = bundle.into_index();
        let (loaded, reord, _) = load_index(&path).unwrap().into_index();
        assert!(reord.is_none());
        let sp = SearchParams::default();
        for qi in (0..600).step_by(61) {
            let (a, sa) = orig.search(data.row_logical(qi), 10, &sp);
            let (b, sb) = loaded.search(data.row_logical(qi), 10, &sp);
            assert_eq!(a, b, "query {qi}");
            assert_eq!(sa, sb);
        }
    }

    #[test]
    fn reordered_bundle_maps_ids_back_to_original() {
        let (bundle, data, _) = build_bundle(500, 7, true);
        let path = tmp("map.knni");
        save_index(&path, &bundle).unwrap();
        let (index, reordering, _) = load_index(&path).unwrap().into_index();
        let sp = SearchParams::default();
        for qi in (0..500).step_by(53) {
            // query with an original-space row: the top hit, mapped back
            // through σ⁻¹, must be the point itself
            let (res, _) = index.search(data.row_logical(qi), 3, &sp);
            let top = IndexBundle::original_id(&reordering, res[0].0);
            assert_eq!(top as usize, qi, "self hit must map back to original id");
            assert!(res[0].1 < 1e-6);
        }
    }

    #[test]
    fn norms_from_a_different_kernel_width_are_discarded_on_load() {
        // simulate a bundle written on a machine with another active
        // width: patch the recorded lane count in the flags word (and
        // refresh the CRC) — the loader must drop the stored norms and
        // serve from recomputed ones, identically to a legacy bundle
        let (bundle, data, _) = build_bundle(300, 41, false);
        let path = tmp("xwidth.knni");
        save_index(&path, &bundle).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let lanes_off = 33; // flags u64 at 32..40, lane count in byte 1
        let other = if bytes[lanes_off] == 16 { 8 } else { 16 };
        bytes[lanes_off] = other;
        let mut crc = Fnv::new();
        crc.update(&bytes[..bytes.len() - 8]);
        let crc_off = bytes.len() - 8;
        bytes[crc_off..].copy_from_slice(&crc.0.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();

        let loaded = load_index(&path).unwrap();
        assert!(loaded.norms.is_none(), "foreign-width norms must be dropped");
        let (idx, _, _) = loaded.into_index();
        let (orig, _, _) = bundle.into_index();
        let sp = SearchParams::default();
        for qi in (0..300).step_by(41) {
            let (a, _) = orig.search(data.row_logical(qi), 5, &sp);
            let (b, _) = idx.search(data.row_logical(qi), 5, &sp);
            assert_eq!(a, b, "query {qi}");
        }
    }

    #[test]
    fn huge_header_on_a_tiny_file_fails_before_allocating() {
        // a corrupt header with n near u32::MAX - 1 passes the
        // plausibility caps (n·k and n·dim stay under their limits when
        // k = dim = 1) — the file-length check must reject it *before*
        // the multi-GB strip allocations are reached
        let path = tmp("huge_n.knni");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(u32::MAX as u64 - 1).to_le_bytes()); // n
        bytes.extend_from_slice(&1u64.to_le_bytes()); // dim
        bytes.extend_from_slice(&1u64.to_le_bytes()); // k
        bytes.extend_from_slice(&0u64.to_le_bytes()); // flags
        std::fs::write(&path, &bytes).unwrap();
        let err = load_index(&path).unwrap_err().to_string();
        assert!(err.contains("size mismatch"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_nonsense_norm_lane_counts() {
        // only 1/8/16 are widths this engine computes norms at; a
        // corrupt tag must be an error, not a silent recompute
        let (bundle, _, _) = build_bundle(200, 17, false);
        let path = tmp("badlanes.knni");
        let lanes_off = 33; // flags u64 at 32..40, lane count in byte 1
        for bad in [3u8, 0, 0xFF] {
            save_index(&path, &bundle).unwrap();
            let mut bytes = std::fs::read(&path).unwrap();
            bytes[lanes_off] = bad;
            let mut crc = Fnv::new();
            crc.update(&bytes[..bytes.len() - 8]);
            let crc_off = bytes.len() - 8;
            bytes[crc_off..].copy_from_slice(&crc.0.to_le_bytes());
            std::fs::write(&path, &bytes).unwrap();
            let err = load_index(&path).unwrap_err().to_string();
            assert!(err.contains("norm lane count"), "lanes={bad}: unexpected error: {err}");
        }
    }

    #[test]
    fn rejects_lane_tag_without_norms_section() {
        // legacy layout (no norms section) with lane bits smuggled into
        // the flags word: structurally consistent, semantically nonsense
        let (bundle, _, _) = build_bundle(200, 19, false);
        let path = tmp("lanes_no_norms.knni");
        save_index_parts(&path, &bundle.data, &bundle.graph, None, &bundle.params, None, None)
            .unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[33] = 8; // lane tag without FLAG_NORMS
        let mut crc = Fnv::new();
        crc.update(&bytes[..bytes.len() - 8]);
        let crc_off = bytes.len() - 8;
        bytes[crc_off..].copy_from_slice(&crc.0.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load_index(&path).unwrap_err().to_string();
        assert!(err.contains("without a norms section"), "unexpected error: {err}");
    }

    /// Rewrite the FNV trailer after a byte patch (the corruption tests
    /// that target *semantic* checks must get past the CRC first).
    fn refresh_crc(bytes: &mut [u8]) {
        let mut crc = Fnv::new();
        let crc_off = bytes.len() - 8;
        crc.update(&bytes[..crc_off]);
        bytes[crc_off..].copy_from_slice(&crc.0.to_le_bytes());
    }

    /// A small centroid matrix sharing the bundle data's dim.
    fn test_centroids(data: &AlignedMatrix, count: usize) -> AlignedMatrix {
        let rows: Vec<f32> =
            (0..count).flat_map(|i| data.row_logical(i * 7).to_vec()).collect();
        AlignedMatrix::from_rows(count, data.dim(), &rows)
    }

    #[test]
    fn centroids_roundtrip_bit_exact() {
        let (mut bundle, data, _) = build_bundle(300, 43, true);
        bundle.centroids = Some(test_centroids(&data, 4));
        let path = tmp("cent_rt.knni");
        save_index(&path, &bundle).unwrap();
        let loaded = load_index(&path).unwrap();
        let (want, got) = (bundle.centroids.as_ref().unwrap(), loaded.centroids.as_ref().unwrap());
        assert_eq!((got.n(), got.dim()), (4, data.dim()));
        for i in 0..4 {
            let (a, b) = (want.row_logical(i), got.row_logical(i));
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.to_bits(), y.to_bits(), "centroid row {i}");
            }
        }
        // everything else must survive the new section untouched
        assert_eq!(loaded.params, bundle.params);
        for u in 0..bundle.graph.n() {
            assert_eq!(bundle.graph.sorted(u), loaded.graph.sorted(u), "node {u}");
        }
    }

    #[test]
    fn legacy_bundle_without_centroids_loads_with_none() {
        let (bundle, _, _) = build_bundle(250, 47, false);
        assert!(bundle.centroids.is_none());
        let path = tmp("cent_legacy.knni");
        save_index(&path, &bundle).unwrap();
        let loaded = load_index(&path).unwrap();
        assert!(loaded.centroids.is_none(), "no-centroids bundle must load with None");
    }

    #[test]
    fn oversized_centroid_count_fails_before_allocating() {
        // inflate the recorded centroid count: the expected-size check
        // must reject the file before any section read or allocation
        let (mut bundle, data, _) = build_bundle(250, 51, false);
        bundle.centroids = Some(test_centroids(&data, 2));
        let path = tmp("cent_oversize.knni");
        save_index(&path, &bundle).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // flags u64 at 32..40; count bits 16–31 are bytes 34–35
        bytes[34] = 0xFF;
        bytes[35] = 0xFF;
        refresh_crc(&mut bytes);
        std::fs::write(&path, &bytes).unwrap();
        let err = load_index(&path).unwrap_err().to_string();
        assert!(err.contains("size mismatch"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_centroid_flag_with_zero_count() {
        let (mut bundle, data, _) = build_bundle(250, 53, false);
        bundle.centroids = Some(test_centroids(&data, 2));
        let path = tmp("cent_zero.knni");
        save_index(&path, &bundle).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[34] = 0;
        bytes[35] = 0;
        refresh_crc(&mut bytes);
        std::fs::write(&path, &bytes).unwrap();
        let err = load_index(&path).unwrap_err().to_string();
        assert!(err.contains("zero centroid count"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_centroid_count_without_section() {
        let (bundle, _, _) = build_bundle(250, 57, false);
        let path = tmp("cent_no_flag.knni");
        save_index(&path, &bundle).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[34] = 2; // count bits without FLAG_CENTROIDS
        refresh_crc(&mut bytes);
        std::fs::write(&path, &bytes).unwrap();
        let err = load_index(&path).unwrap_err().to_string();
        assert!(err.contains("without a centroids section"), "unexpected error: {err}");
    }

    #[test]
    fn detects_corruption() {
        let (bundle, _, _) = build_bundle(200, 3, true);
        let path = tmp("corrupt.knni");
        save_index(&path, &bundle).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_index(&path).unwrap_err().to_string();
        assert!(
            err.contains("checksum") || err.contains("corrupt") || err.contains("implausible"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn detects_truncation() {
        let (bundle, _, _) = build_bundle(200, 9, false);
        let path = tmp("trunc.knni");
        save_index(&path, &bundle).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        for keep in [4usize, 8, 40, 104, bytes.len() / 2, bytes.len() - 1] {
            std::fs::write(&path, &bytes[..keep]).unwrap();
            assert!(load_index(&path).is_err(), "truncated at {keep} bytes must fail");
        }
    }

    #[test]
    fn truncation_at_every_section_boundary_is_a_typed_error() {
        // the full layout, all optional sections present — truncate the
        // file at the start of every section, one byte into it, and one
        // byte before its end. Every case must return Err (never panic,
        // never read past the end); from the params section on, the
        // exact-size check names the mismatch before any strip is read.
        let (mut bundle, data, _) = build_bundle(120, 61, true);
        let cent = 3usize;
        bundle.centroids = Some(test_centroids(&data, cent));
        assert!(bundle.reordering.is_some() && bundle.norms.is_some());
        let path = tmp("boundary_trunc.knni");
        save_index(&path, &bundle).unwrap();
        let bytes = std::fs::read(&path).unwrap();

        let (n, dim, k) = (data.n(), data.dim(), bundle.graph.k());
        let sections: &[(&str, usize)] = &[
            ("magic", 8),
            ("header", 32),
            ("params", 64),
            ("ids", n * k * 4),
            ("dists", n * k * 4),
            ("data", n * dim * 4),
            ("sigma", n * 4),
            ("inv", n * 4),
            ("norms", n * 4),
            ("centroids", cent * dim * 4),
            ("crc", 8),
        ];
        assert_eq!(
            sections.iter().map(|(_, len)| len).sum::<usize>(),
            bytes.len(),
            "section table out of sync with the writer"
        );

        let mut offset = 0usize;
        for &(name, len) in sections {
            for (what, keep) in
                [("start", offset), ("one byte in", offset + 1), ("one short", offset + len - 1)]
            {
                if keep == 0 || keep >= bytes.len() {
                    continue; // empty file / no truncation — not this test
                }
                std::fs::write(&path, &bytes[..keep]).unwrap();
                let err = load_index(&path)
                    .map(|_| ())
                    .expect_err(&format!("{name}: truncated at {what} ({keep} B) must fail"));
                let msg = err.to_string();
                if keep >= 8 + 32 {
                    // magic + header readable: the exact-size check fires
                    assert!(
                        msg.contains("size mismatch"),
                        "{name} at {what}: expected a size-mismatch error, got: {msg}"
                    );
                }
            }
            offset += len;
        }
    }

    #[test]
    fn rejects_wrong_magic_and_future_version() {
        let path = tmp("magic.knni");
        std::fs::write(&path, b"NOTANIDXaaaaaaaa").unwrap();
        let err = load_index(&path).unwrap_err().to_string();
        assert!(err.contains("not a KNNIv1"), "unexpected error: {err}");

        // same family, newer version: the message must say "version"
        let (bundle, _, _) = build_bundle(200, 13, false);
        save_index(&path, &bundle).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[5] = b'9'; // "KNNIv1" -> "KNNIv9"
        std::fs::write(&path, &bytes).unwrap();
        let err = load_index(&path).unwrap_err().to_string();
        assert!(err.contains("version"), "unexpected error: {err}");
    }
}
