//! Greedy beam search over the K-NN graph.

use crate::dataset::AlignedMatrix;
use crate::distance::sq_l2_unrolled;
use crate::graph::heap::EMPTY_ID;
use crate::graph::KnnGraph;
use crate::util::rng::Pcg64;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Search-time knobs.
#[derive(Debug, Clone, Copy)]
pub struct SearchParams {
    /// Candidate-pool width (≥ k); larger = better recall, slower.
    pub ef: usize,
    /// Number of entry points kept after probing.
    pub seeds: usize,
    /// Number of random probe evaluations used to pick entry points.
    /// Defaults to `0`, meaning `max(32, 4·√n)` at query time. On
    /// clustered data the K-NN graph has (almost) no cross-cluster
    /// edges, so beam search cannot escape a wrong entry cluster —
    /// probing restores a high chance of starting near the query.
    pub probes: usize,
    /// Seed for entry-point sampling (deterministic queries).
    pub rng_seed: u64,
}

impl Default for SearchParams {
    fn default() -> Self {
        Self { ef: 64, seeds: 8, probes: 0, rng_seed: 0x5EA7C4 }
    }
}

/// Per-query diagnostics.
#[derive(Debug, Clone, Copy, Default)]
pub struct QueryStats {
    /// Distance evaluations performed.
    pub dist_evals: u64,
    /// Graph nodes expanded.
    pub expansions: u64,
}

/// An immutable ANN index: the built graph + the (possibly reordered)
/// data matrix it refers to.
pub struct GraphIndex {
    data: AlignedMatrix,
    graph: KnnGraph,
}

/// Ordered f32 wrapper (distances are never NaN here).
#[derive(PartialEq)]
struct Ord32(f32);
impl Eq for Ord32 {}
impl PartialOrd for Ord32 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ord32 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).unwrap()
    }
}

impl GraphIndex {
    /// Build an index from a finished graph and its data (both in the
    /// same id space — pass the *working* layout from a reordered build).
    pub fn new(data: AlignedMatrix, graph: KnnGraph) -> Self {
        assert_eq!(data.n(), graph.n(), "graph/data size mismatch");
        Self { data, graph }
    }

    pub fn n(&self) -> usize {
        self.data.n()
    }

    pub fn graph(&self) -> &KnnGraph {
        &self.graph
    }

    pub fn data(&self) -> &AlignedMatrix {
        &self.data
    }

    /// k nearest neighbors of `query` (padded or logical length),
    /// ascending by distance.
    pub fn search(&self, query: &[f32], k: usize, params: &SearchParams) -> (Vec<(u32, f32)>, QueryStats) {
        let n = self.data.n();
        let mut stats = QueryStats::default();
        let ef = params.ef.max(k);

        // pad query to the matrix's lane width
        let q = self.pad_query(query);

        let mut rng = Pcg64::new_stream(params.rng_seed, 0x5EED5);
        let mut visited = vec![false; n];

        // candidate frontier: min-heap by distance (Reverse for min)
        let mut frontier: BinaryHeap<Reverse<(Ord32, u32)>> = BinaryHeap::new();
        // result pool: max-heap by distance, bounded at ef
        let mut pool: BinaryHeap<(Ord32, u32)> = BinaryHeap::new();

        // Probe: evaluate a spread of random points, keep the best
        // `seeds` as entry points (cheap: probes ≪ n, and every probe's
        // distance is reused via the pool).
        let probes = if params.probes > 0 {
            params.probes
        } else {
            (4.0 * (n as f64).sqrt()) as usize
        }
        .clamp(32.min(n), n);
        let mut probe_best: BinaryHeap<(Ord32, u32)> = BinaryHeap::new();
        for _ in 0..probes {
            let v = rng.gen_index(n) as u32;
            if visited[v as usize] {
                continue;
            }
            visited[v as usize] = true;
            let d = sq_l2_unrolled(&q, self.data.row(v as usize));
            stats.dist_evals += 1;
            // feed the result pool too — probes are legitimate results
            if pool.len() < ef {
                pool.push((Ord32(d), v));
            } else if d < pool.peek().unwrap().0 .0 {
                pool.pop();
                pool.push((Ord32(d), v));
            }
            if probe_best.len() < params.seeds.max(1) {
                probe_best.push((Ord32(d), v));
            } else if d < probe_best.peek().unwrap().0 .0 {
                probe_best.pop();
                probe_best.push((Ord32(d), v));
            }
        }
        for (d, v) in probe_best {
            frontier.push(Reverse((d, v)));
        }

        while let Some(Reverse((Ord32(d), u))) = frontier.pop() {
            // stop when the closest frontier node is worse than the
            // worst pooled result and the pool is full
            if pool.len() >= ef && d > pool.peek().unwrap().0 .0 {
                break;
            }
            stats.expansions += 1;
            for &v in self.graph.ids(u as usize) {
                if v == EMPTY_ID || visited[v as usize] {
                    continue;
                }
                visited[v as usize] = true;
                let dv = sq_l2_unrolled(&q, self.data.row(v as usize));
                stats.dist_evals += 1;
                if pool.len() < ef {
                    pool.push((Ord32(dv), v));
                    frontier.push(Reverse((Ord32(dv), v)));
                } else if dv < pool.peek().unwrap().0 .0 {
                    pool.pop();
                    pool.push((Ord32(dv), v));
                    frontier.push(Reverse((Ord32(dv), v)));
                }
            }
        }

        let mut results: Vec<(u32, f32)> = pool.into_iter().map(|(Ord32(d), v)| (v, d)).collect();
        results.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
        results.truncate(k);
        (results, stats)
    }

    fn pad_query(&self, query: &[f32]) -> Vec<f32> {
        let dp = self.data.dim_pad();
        assert!(
            query.len() == self.data.dim() || query.len() == dp,
            "query length {} matches neither dim {} nor padded {}",
            query.len(),
            self.data.dim(),
            dp
        );
        let mut q = vec![0f32; dp];
        q[..query.len()].copy_from_slice(query);
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::brute::brute_force_knn_sampled;
    use crate::dataset::clustered::SynthClustered;
    use crate::nndescent::{NnDescent, Params};

    fn index(n: usize, dim: usize, seed: u64) -> (GraphIndex, AlignedMatrix) {
        let (data, _) = SynthClustered::new(n, dim, 8, seed).generate_labeled();
        let result = NnDescent::new(Params::default().with_k(16).with_seed(seed)).build(&data);
        (GraphIndex::new(data.clone(), result.graph), data)
    }

    #[test]
    fn query_with_database_points_finds_themselves() {
        let (idx, data) = index(800, 16, 3);
        for u in (0..800).step_by(97) {
            let (res, _) = idx.search(data.row_logical(u), 5, &SearchParams::default());
            assert_eq!(res[0].0 as usize, u, "self must be the top hit");
            assert!(res[0].1 < 1e-6);
        }
    }

    #[test]
    fn heldout_queries_reach_high_recall() {
        // build on the first 1000 points, query with fresh points from
        // the same distribution; compare to brute force over the index set
        let (data, _) = SynthClustered::new(1200, 16, 8, 9).generate_labeled();
        let index_data = {
            let rows: Vec<f32> =
                (0..1000).flat_map(|i| data.row_logical(i).to_vec()).collect();
            AlignedMatrix::from_rows(1000, 16, &rows)
        };
        let result =
            NnDescent::new(Params::default().with_k(16).with_seed(9)).build(&index_data);
        let idx = GraphIndex::new(index_data.clone(), result.graph);

        let k = 10;
        let mut hits = 0usize;
        let mut total = 0usize;
        for qi in 1000..1200 {
            let q = data.row_logical(qi);
            let (res, _) = idx.search(q, k, &SearchParams::default());
            // brute force over the index set
            let mut exact: Vec<(u32, f32)> = (0..1000u32)
                .map(|v| {
                    let mut qp = vec![0f32; index_data.dim_pad()];
                    qp[..16].copy_from_slice(q);
                    (v, sq_l2_unrolled(&qp, index_data.row(v as usize)))
                })
                .collect();
            exact.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let truth: std::collections::HashSet<u32> =
                exact[..k].iter().map(|p| p.0).collect();
            hits += res.iter().filter(|(v, _)| truth.contains(v)).count();
            total += k;
        }
        let recall = hits as f64 / total as f64;
        assert!(recall > 0.95, "query recall {recall}");
    }

    #[test]
    fn ef_trades_evals_for_recall() {
        let (idx, data) = index(1500, 16, 5);
        let q = data.row_logical(42);
        let (_, cheap) = idx.search(q, 10, &SearchParams { ef: 16, ..Default::default() });
        let (_, thorough) = idx.search(q, 10, &SearchParams { ef: 128, ..Default::default() });
        assert!(thorough.dist_evals > cheap.dist_evals);
    }

    #[test]
    fn beam_visits_fraction_of_graph() {
        // the whole point: far fewer evals than brute force
        let (idx, data) = index(2000, 16, 7);
        let (_, stats) = idx.search(data.row_logical(0), 10, &SearchParams::default());
        assert!(
            stats.dist_evals < 2000 / 2,
            "beam search touched {} of 2000 nodes",
            stats.dist_evals
        );
    }

    #[test]
    fn recall_validated_against_sampled_truth() {
        let (idx, data) = index(1000, 16, 13);
        let truth = brute_force_knn_sampled(&data, 10, 60, 21);
        let mut total = 0.0;
        for (q, exact) in &truth.queries {
            let (res, _) = idx.search(data.row_logical(*q as usize), 11, &SearchParams::default());
            // drop the self-hit
            let found: Vec<u32> =
                res.iter().filter(|(v, _)| v != q).map(|(v, _)| *v).take(10).collect();
            let hits = exact.iter().filter(|(v, _)| found.contains(v)).count();
            total += hits as f64 / exact.len() as f64;
        }
        let recall = total / truth.queries.len() as f64;
        assert!(recall > 0.9, "search recall {recall}");
    }
}
