//! Greedy beam search over the K-NN graph — single-query and batched.
//!
//! Both entry points share one search core, and all candidate distances
//! flow through the dispatched kernel engine (`distance::kernel` via
//! `distance::dispatch`). The **probe stage** uses the engine's
//! norm-trick shapes: the index precomputes ‖y‖² once per corpus row
//! (persisted in `KNNIv1` bundles, recomputed on load when absent), the
//! query side contributes ‖q‖² once per query, and the query×probe
//! evaluations reduce to register-tiled dot products — the GEMM-style
//! factorization of the batch kernel. The **expansion stage** stays on
//! the direct 1×5 strips (short, latency-bound, and exact).
//!
//! Because the sequential and batched variants of each shape are
//! bit-equal per pair at the active width,
//! [`GraphIndex::search_batch`] returns *exactly* the results of the
//! equivalent sequence of [`GraphIndex::search`] calls while doing its
//! probe evaluations as one query×corpus tile and its expansion
//! evaluations as 1×5 strips, and reusing all per-query scratch
//! (visited map, heaps, candidate buffers) across the batch.

use crate::dataset::AlignedMatrix;
use crate::distance::blocked::one_to_many_blocked;
use crate::distance::dispatch;
use crate::distance::sq_norm;
use crate::graph::heap::EMPTY_ID;
use crate::graph::KnnGraph;
use crate::util::rng::Pcg64;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

/// Search-time knobs.
#[derive(Debug, Clone, Copy)]
pub struct SearchParams {
    /// Candidate-pool width (≥ k); larger = better recall, slower.
    pub ef: usize,
    /// Number of entry points kept after probing.
    pub seeds: usize,
    /// Number of random probe evaluations used to pick entry points.
    /// Defaults to `0`, meaning `max(32, 4·√n)` at query time. On
    /// clustered data the K-NN graph has (almost) no cross-cluster
    /// edges, so beam search cannot escape a wrong entry cluster —
    /// probing restores a high chance of starting near the query.
    pub probes: usize,
    /// Seed for entry-point sampling (deterministic queries).
    pub rng_seed: u64,
}

impl Default for SearchParams {
    fn default() -> Self {
        Self { ef: 64, seeds: 8, probes: 0, rng_seed: 0x5EA7C4 }
    }
}

/// Per-query diagnostics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct QueryStats {
    /// Distance evaluations performed.
    pub dist_evals: u64,
    /// Graph nodes expanded.
    pub expansions: u64,
}

/// Aggregate diagnostics for one [`GraphIndex::search_batch`] call.
#[derive(Debug, Clone, Copy, Default)]
pub struct BatchStats {
    /// Queries served.
    pub queries: usize,
    /// Total distance evaluations across the batch.
    pub dist_evals: u64,
    /// Total graph-node expansions across the batch.
    pub expansions: u64,
    /// Wall time for the whole batch, seconds.
    pub secs: f64,
    /// Active distance-kernel width the batch ran on (`scalar`/`w8`/
    /// `w16`; empty only for default-constructed stats).
    pub kernel: &'static str,
    /// Shards visited across the batch: `queries × S` under full
    /// fan-out, fewer under centroid routing. Zero for single-index
    /// (unsharded) searches, which have no fan-out to count.
    pub shard_visits: u64,
}

impl BatchStats {
    /// Throughput, queries per second.
    pub fn qps(&self) -> f64 {
        if self.secs > 0.0 {
            self.queries as f64 / self.secs
        } else {
            0.0
        }
    }
    /// Mean distance evaluations per query.
    pub fn dist_evals_per_query(&self) -> f64 {
        if self.queries > 0 {
            self.dist_evals as f64 / self.queries as f64
        } else {
            0.0
        }
    }
    /// Mean graph expansions per query.
    pub fn expansions_per_query(&self) -> f64 {
        if self.queries > 0 {
            self.expansions as f64 / self.queries as f64
        } else {
            0.0
        }
    }
}

/// An immutable ANN index: the built graph + the (possibly reordered)
/// data matrix it refers to, plus the per-row squared norms the
/// norm-trick probe kernels consume.
pub struct GraphIndex {
    data: AlignedMatrix,
    graph: KnnGraph,
    /// ‖row‖² per corpus row, computed once at construction (or loaded
    /// from a `KNNIv1` bundle) at the active kernel width.
    norms: Vec<f32>,
    /// Lane count of the kernel width `norms` was computed at — the
    /// truthful tag persisted into bundles, so a save after a mid-
    /// process `dispatch::force` (without [`refresh_norms`]) cannot
    /// defeat the loader's width-mismatch guard.
    ///
    /// [`refresh_norms`]: GraphIndex::refresh_norms
    norm_lanes: usize,
}

/// Ordered f32 wrapper (distances are never NaN here).
#[derive(PartialEq)]
struct Ord32(f32);
impl Eq for Ord32 {}
impl PartialOrd for Ord32 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ord32 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).unwrap()
    }
}

/// Per-query working state, allocated once and reused across a batch
/// (the `PairwiseBuf` discipline applied to serving). The visited map
/// is reset sparsely via the `touched` journal, so a batch of q queries
/// costs one O(n) allocation total instead of q.
struct QueryScratch {
    visited: Vec<bool>,
    touched: Vec<u32>,
    frontier: BinaryHeap<Reverse<(Ord32, u32)>>,
    pool: BinaryHeap<(Ord32, u32)>,
    probe_best: BinaryHeap<(Ord32, u32)>,
    cand_ids: Vec<u32>,
    cand_dists: Vec<f32>,
}

/// Owned, reusable search scratch for one [`GraphIndex`] — the
/// per-worker state of the thread-per-shard serving runtime
/// (`api::serve`). Every search entry point resets it before use, so a
/// long-lived worker can serve any number of queries/batches through
/// one scratch with results identical to fresh allocations, while two
/// workers never share buffers (the probe path's scratch is owned, not
/// shared — which is what makes `GraphIndex` safely `Sync`).
///
/// Sized for a specific index (`O(n)` visited map): obtain one from
/// [`GraphIndex::scratch`] and only pass it back to the same index
/// (enforced by an assert).
pub struct SearchScratch {
    inner: QueryScratch,
}

impl QueryScratch {
    fn new(n: usize) -> Self {
        Self {
            visited: vec![false; n],
            touched: Vec::new(),
            frontier: BinaryHeap::new(),
            pool: BinaryHeap::new(),
            probe_best: BinaryHeap::new(),
            cand_ids: Vec::new(),
            cand_dists: Vec::new(),
        }
    }

    /// Make the scratch equivalent to freshly allocated.
    fn reset(&mut self) {
        for v in self.touched.drain(..) {
            self.visited[v as usize] = false;
        }
        self.frontier.clear();
        self.pool.clear();
        self.probe_best.clear();
    }

    #[inline]
    fn visit(&mut self, v: u32) {
        self.visited[v as usize] = true;
        self.touched.push(v);
    }
}

/// The deterministic probe id sequence for an index of `n` points: the
/// first occurrence of each drawn id, in draw order. This depends only
/// on (`n`, `params`), never on the query, so a batch evaluates the
/// whole query×probe tile with the blocked kernel up front. Dedup
/// borrows the scratch's visited map (journaled, reset afterwards)
/// instead of allocating its own.
fn probe_ids(n: usize, params: &SearchParams, scratch: &mut QueryScratch) -> Vec<u32> {
    let probes = if params.probes > 0 {
        params.probes
    } else {
        (4.0 * (n as f64).sqrt()) as usize
    }
    .clamp(32.min(n), n);
    let mut rng = Pcg64::new_stream(params.rng_seed, 0x5EED5);
    scratch.reset();
    let mut ids = Vec::with_capacity(probes);
    for _ in 0..probes {
        let v = rng.gen_index(n) as u32;
        if scratch.visited[v as usize] {
            continue;
        }
        scratch.visit(v);
        ids.push(v);
    }
    scratch.reset();
    ids
}

/// A borrowed view of everything the beam-search core reads: the padded
/// data matrix, the flat neighbor-id strip (`n·k`, heap order,
/// `EMPTY_ID` = open slot), and the per-row squared norms. Both
/// [`GraphIndex`] (owned build results) and the store engine's mmap'd
/// `KNNIv2` segments search through this one view, so a segment-backed
/// search is **bit-identical** to the owned path by construction — there
/// is exactly one search core.
pub(crate) struct IndexView<'a> {
    pub(crate) data: &'a AlignedMatrix,
    pub(crate) edges: &'a [u32],
    pub(crate) k: usize,
    pub(crate) norms: &'a [f32],
}

impl<'a> IndexView<'a> {
    pub(crate) fn new(
        data: &'a AlignedMatrix,
        edges: &'a [u32],
        k: usize,
        norms: &'a [f32],
    ) -> Self {
        assert_eq!(edges.len(), data.n() * k, "edge strip must be n·k");
        assert_eq!(norms.len(), data.n(), "one norm per corpus row");
        Self { data, edges, k, norms }
    }

    /// Neighbor ids of node `u` (heap order, may contain `EMPTY_ID`).
    #[inline]
    fn neighbors(&self, u: usize) -> &[u32] {
        &self.edges[u * self.k..(u + 1) * self.k]
    }

    /// Allocate a reusable [`SearchScratch`] sized for this view.
    pub(crate) fn scratch(&self) -> SearchScratch {
        SearchScratch { inner: QueryScratch::new(self.data.n()) }
    }

    #[inline]
    fn check_scratch(&self, scratch: &SearchScratch) {
        assert_eq!(
            scratch.inner.visited.len(),
            self.data.n(),
            "scratch was built for a different index size"
        );
    }

    pub(crate) fn search_with(
        &self,
        query: &[f32],
        k: usize,
        params: &SearchParams,
        scratch: &mut SearchScratch,
    ) -> (Vec<(u32, f32)>, QueryStats) {
        self.check_scratch(scratch);
        let q = self.pad_query(query);
        let q2 = sq_norm(&q);
        let probes = probe_ids(self.data.n(), params, &mut scratch.inner);
        let mut probe_dists = Vec::new();
        dispatch::one_to_many_norms(&q, q2, self.data, self.norms, &probes, &mut probe_dists);
        self.search_core(&q, k, params, &probes, &probe_dists, &mut scratch.inner)
    }

    pub(crate) fn search_batch_with(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
        scratch: &mut SearchScratch,
    ) -> (Vec<Vec<(u32, f32)>>, BatchStats) {
        assert_eq!(
            queries.dim(),
            self.data.dim(),
            "query batch dim {} does not match index dim {}",
            queries.dim(),
            self.data.dim()
        );
        self.check_scratch(scratch);
        let t0 = Instant::now();
        let n = self.data.n();
        let scratch = &mut scratch.inner;
        let probes = probe_ids(n, params, scratch);
        let p = probes.len();
        // Norm-trick probe tile: ‖q‖² per batch row, ‖y‖² from the
        // index, register-tiled dot products for the whole query×probe
        // tile — the GEMM-style batch kernel.
        let qnorms: Vec<f32> = (0..queries.n()).map(|qi| sq_norm(queries.row(qi))).collect();
        let mut probe_dists = vec![0f32; queries.n() * p];
        dispatch::cross_norms(queries, &qnorms, self.data, self.norms, &probes, &mut probe_dists);
        let mut results = Vec::with_capacity(queries.n());
        let mut agg = BatchStats {
            queries: queries.n(),
            kernel: dispatch::active_width().name(),
            ..Default::default()
        };
        for qi in 0..queries.n() {
            let (res, stats) = self.search_core(
                queries.row(qi),
                k,
                params,
                &probes,
                &probe_dists[qi * p..(qi + 1) * p],
                scratch,
            );
            agg.dist_evals += stats.dist_evals;
            agg.expansions += stats.expansions;
            results.push(res);
        }
        agg.secs = t0.elapsed().as_secs_f64();
        (results, agg)
    }

    /// Shared beam-search core. `probes`/`probe_dists` carry the
    /// precomputed entry-point evaluations (same set and order the
    /// sequential path would produce); `q` is a padded query row.
    fn search_core(
        &self,
        q: &[f32],
        k: usize,
        params: &SearchParams,
        probes: &[u32],
        probe_dists: &[f32],
        scratch: &mut QueryScratch,
    ) -> (Vec<(u32, f32)>, QueryStats) {
        debug_assert_eq!(probes.len(), probe_dists.len());
        scratch.reset();
        let mut stats = QueryStats::default();
        let ef = params.ef.max(k);

        // Probe: the precomputed spread of random points; keep the best
        // `seeds` as entry points, and feed every probe into the result
        // pool (probes are legitimate results).
        for (i, &v) in probes.iter().enumerate() {
            scratch.visit(v);
            let d = probe_dists[i];
            stats.dist_evals += 1;
            if scratch.pool.len() < ef {
                scratch.pool.push((Ord32(d), v));
            } else if d < scratch.pool.peek().unwrap().0 .0 {
                scratch.pool.pop();
                scratch.pool.push((Ord32(d), v));
            }
            if scratch.probe_best.len() < params.seeds.max(1) {
                scratch.probe_best.push((Ord32(d), v));
            } else if d < scratch.probe_best.peek().unwrap().0 .0 {
                scratch.probe_best.pop();
                scratch.probe_best.push((Ord32(d), v));
            }
        }
        while let Some((d, v)) = scratch.probe_best.pop() {
            scratch.frontier.push(Reverse((d, v)));
        }

        while let Some(Reverse((Ord32(d), u))) = scratch.frontier.pop() {
            // stop when the closest frontier node is worse than the
            // worst pooled result and the pool is full
            if scratch.pool.len() >= ef && d > scratch.pool.peek().unwrap().0 .0 {
                break;
            }
            stats.expansions += 1;
            // gather this expansion's unvisited neighbors, then evaluate
            // them as one 1×5-blocked strip
            scratch.cand_ids.clear();
            for &v in self.neighbors(u as usize) {
                if v == EMPTY_ID || scratch.visited[v as usize] {
                    continue;
                }
                scratch.visit(v);
                scratch.cand_ids.push(v);
            }
            one_to_many_blocked(q, self.data, &scratch.cand_ids, &mut scratch.cand_dists);
            stats.dist_evals += scratch.cand_ids.len() as u64;
            for (i, &v) in scratch.cand_ids.iter().enumerate() {
                let dv = scratch.cand_dists[i];
                if scratch.pool.len() < ef {
                    scratch.pool.push((Ord32(dv), v));
                    scratch.frontier.push(Reverse((Ord32(dv), v)));
                } else if dv < scratch.pool.peek().unwrap().0 .0 {
                    scratch.pool.pop();
                    scratch.pool.push((Ord32(dv), v));
                    scratch.frontier.push(Reverse((Ord32(dv), v)));
                }
            }
        }

        let mut results: Vec<(u32, f32)> =
            scratch.pool.drain().map(|(Ord32(d), v)| (v, d)).collect();
        results.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
        results.truncate(k);
        (results, stats)
    }

    fn pad_query(&self, query: &[f32]) -> Vec<f32> {
        let dp = self.data.dim_pad();
        assert!(
            query.len() == self.data.dim() || query.len() == dp,
            "query length {} matches neither dim {} nor padded {}",
            query.len(),
            self.data.dim(),
            dp
        );
        let mut q = vec![0f32; dp];
        q[..query.len()].copy_from_slice(query);
        q
    }
}

impl GraphIndex {
    /// Build an index from a finished graph and its data (both in the
    /// same id space — pass the *working* layout from a reordered build).
    /// Corpus norms for the norm-trick probe path are computed here,
    /// once, at the active kernel width.
    pub fn new(data: AlignedMatrix, graph: KnnGraph) -> Self {
        let norms = Self::compute_norms(&data);
        Self::with_norms(data, graph, norms)
    }

    /// Like [`new`](Self::new) with precomputed per-row squared norms.
    /// The norms **must** have been computed at the currently active
    /// kernel width (the bundle loader guarantees this by discarding
    /// foreign-width sections before calling here).
    pub fn with_norms(data: AlignedMatrix, graph: KnnGraph, norms: Vec<f32>) -> Self {
        assert_eq!(data.n(), graph.n(), "graph/data size mismatch");
        assert_eq!(norms.len(), data.n(), "one norm per corpus row");
        let norm_lanes = dispatch::active_width().lanes();
        Self { data, graph, norms, norm_lanes }
    }

    /// ‖row‖² for every row of `data` at the active kernel width.
    pub fn compute_norms(data: &AlignedMatrix) -> Vec<f32> {
        (0..data.n()).map(|i| sq_norm(data.row(i))).collect()
    }

    /// Recompute the corpus norms at the *current* active kernel width.
    /// Call after `dispatch::force` switches widths mid-process (A/B
    /// harnesses) so the norm-trick path measures the same
    /// configuration a fresh build/load at that width would serve.
    pub fn refresh_norms(&mut self) {
        self.norms = Self::compute_norms(&self.data);
        self.norm_lanes = dispatch::active_width().lanes();
    }

    /// Per-row squared corpus norms (working id space).
    pub fn norms(&self) -> &[f32] {
        &self.norms
    }

    /// Lane count of the kernel width [`norms`](Self::norms) was
    /// computed at.
    pub fn norm_lanes(&self) -> usize {
        self.norm_lanes
    }

    pub fn n(&self) -> usize {
        self.data.n()
    }

    pub fn graph(&self) -> &KnnGraph {
        &self.graph
    }

    pub fn data(&self) -> &AlignedMatrix {
        &self.data
    }

    /// Decompose into the owned data matrix and graph (consumes the
    /// index; used by the `api` facade to reassemble build results).
    pub fn into_parts(self) -> (AlignedMatrix, KnnGraph) {
        (self.data, self.graph)
    }

    /// Allocate a reusable [`SearchScratch`] sized for this index (one
    /// `O(n)` visited map). Long-lived serving workers hold one per
    /// index and thread it through [`search_with`]/[`search_batch_with`]
    /// so the per-call allocation disappears from the hot path.
    ///
    /// [`search_with`]: GraphIndex::search_with
    /// [`search_batch_with`]: GraphIndex::search_batch_with
    pub fn scratch(&self) -> SearchScratch {
        self.view().scratch()
    }

    /// The borrowed [`IndexView`] every search entry point runs on —
    /// the same view a store-engine segment constructs over its mmap'd
    /// sections, so both paths share one search core.
    #[inline]
    pub(crate) fn view(&self) -> IndexView<'_> {
        IndexView {
            data: &self.data,
            edges: self.graph.flat_ids(),
            k: self.graph.k(),
            norms: &self.norms,
        }
    }

    /// k nearest neighbors of `query` (padded or logical length),
    /// ascending by distance. The probe evaluations run on the
    /// norm-trick path (precomputed corpus norms + ‖q‖² computed here),
    /// bit-equal per pair to the batched probe tile.
    pub fn search(
        &self,
        query: &[f32],
        k: usize,
        params: &SearchParams,
    ) -> (Vec<(u32, f32)>, QueryStats) {
        self.search_with(query, k, params, &mut self.scratch())
    }

    /// [`search`](GraphIndex::search) through a caller-owned
    /// [`SearchScratch`] (reset here; results are identical to a fresh
    /// scratch).
    pub fn search_with(
        &self,
        query: &[f32],
        k: usize,
        params: &SearchParams,
        scratch: &mut SearchScratch,
    ) -> (Vec<(u32, f32)>, QueryStats) {
        self.view().search_with(query, k, params, scratch)
    }

    /// Serve a batch of queries (rows of `queries`, logical width equal
    /// to the index's). Results are **identical** to calling [`search`]
    /// once per row with the same `params`: the probe stage runs as one
    /// query×probe blocked tile and expansions as 1×5 blocked strips,
    /// both bit-equal to the sequential kernel, and the per-query
    /// control flow is shared. Returns per-query results plus aggregate
    /// [`BatchStats`].
    ///
    /// [`search`]: GraphIndex::search
    pub fn search_batch(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
    ) -> (Vec<Vec<(u32, f32)>>, BatchStats) {
        self.search_batch_with(queries, k, params, &mut self.scratch())
    }

    /// [`search_batch`](GraphIndex::search_batch) through a
    /// caller-owned [`SearchScratch`] — the serving runtime's entry
    /// point: each shard worker owns one scratch for its shard and
    /// serves every incoming batch through it, with results identical
    /// to fresh per-call allocations.
    pub fn search_batch_with(
        &self,
        queries: &AlignedMatrix,
        k: usize,
        params: &SearchParams,
        scratch: &mut SearchScratch,
    ) -> (Vec<Vec<(u32, f32)>>, BatchStats) {
        self.view().search_batch_with(queries, k, params, scratch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::brute::brute_force_knn_sampled;
    use crate::dataset::clustered::SynthClustered;
    use crate::distance::sq_l2_unrolled;
    use crate::nndescent::{NnDescent, Params};

    fn index(n: usize, dim: usize, seed: u64) -> (GraphIndex, AlignedMatrix) {
        let (data, _) = SynthClustered::new(n, dim, 8, seed).generate_labeled();
        let result =
            NnDescent::new(Params::default().with_k(16).with_seed(seed)).build(&data).unwrap();
        (GraphIndex::new(data.clone(), result.graph), data)
    }

    #[test]
    fn query_with_database_points_finds_themselves() {
        let (idx, data) = index(800, 16, 3);
        for u in (0..800).step_by(97) {
            let (res, _) = idx.search(data.row_logical(u), 5, &SearchParams::default());
            assert_eq!(res[0].0 as usize, u, "self must be the top hit");
            assert!(res[0].1 < 1e-6);
        }
    }

    #[test]
    fn heldout_queries_reach_high_recall() {
        // build on the first 1000 points, query with fresh points from
        // the same distribution; compare to brute force over the index set
        let (data, _) = SynthClustered::new(1200, 16, 8, 9).generate_labeled();
        let index_data = {
            let rows: Vec<f32> =
                (0..1000).flat_map(|i| data.row_logical(i).to_vec()).collect();
            AlignedMatrix::from_rows(1000, 16, &rows)
        };
        let result =
            NnDescent::new(Params::default().with_k(16).with_seed(9)).build(&index_data).unwrap();
        let idx = GraphIndex::new(index_data.clone(), result.graph);

        let k = 10;
        let mut hits = 0usize;
        let mut total = 0usize;
        for qi in 1000..1200 {
            let q = data.row_logical(qi);
            let (res, _) = idx.search(q, k, &SearchParams::default());
            // brute force over the index set
            let mut exact: Vec<(u32, f32)> = (0..1000u32)
                .map(|v| {
                    let mut qp = vec![0f32; index_data.dim_pad()];
                    qp[..16].copy_from_slice(q);
                    (v, sq_l2_unrolled(&qp, index_data.row(v as usize)))
                })
                .collect();
            exact.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let truth: std::collections::HashSet<u32> =
                exact[..k].iter().map(|p| p.0).collect();
            hits += res.iter().filter(|(v, _)| truth.contains(v)).count();
            total += k;
        }
        let recall = hits as f64 / total as f64;
        assert!(recall > 0.95, "query recall {recall}");
    }

    #[test]
    fn ef_trades_evals_for_recall() {
        let (idx, data) = index(1500, 16, 5);
        let q = data.row_logical(42);
        let (_, cheap) = idx.search(q, 10, &SearchParams { ef: 16, ..Default::default() });
        let (_, thorough) = idx.search(q, 10, &SearchParams { ef: 128, ..Default::default() });
        assert!(thorough.dist_evals > cheap.dist_evals);
    }

    #[test]
    fn beam_visits_fraction_of_graph() {
        // the whole point: far fewer evals than brute force
        let (idx, data) = index(2000, 16, 7);
        let (_, stats) = idx.search(data.row_logical(0), 10, &SearchParams::default());
        assert!(
            stats.dist_evals < 2000 / 2,
            "beam search touched {} of 2000 nodes",
            stats.dist_evals
        );
    }

    #[test]
    fn recall_validated_against_sampled_truth() {
        let (idx, data) = index(1000, 16, 13);
        let truth = brute_force_knn_sampled(&data, 10, 60, 21);
        let mut total = 0.0;
        for (q, exact) in &truth.queries {
            let (res, _) = idx.search(data.row_logical(*q as usize), 11, &SearchParams::default());
            // drop the self-hit
            let found: Vec<u32> =
                res.iter().filter(|(v, _)| v != q).map(|(v, _)| *v).take(10).collect();
            let hits = exact.iter().filter(|(v, _)| found.contains(v)).count();
            total += hits as f64 / exact.len() as f64;
        }
        let recall = total / truth.queries.len() as f64;
        assert!(recall > 0.9, "search recall {recall}");
    }

    /// Queries as an AlignedMatrix from held-out rows of `data`.
    fn query_matrix(data: &AlignedMatrix, from: usize, count: usize) -> AlignedMatrix {
        let rows: Vec<f32> =
            (from..from + count).flat_map(|i| data.row_logical(i).to_vec()).collect();
        AlignedMatrix::from_rows(count, data.dim(), &rows)
    }

    #[test]
    fn batch_matches_sequential_exactly() {
        // the acceptance criterion: identical ids AND identical distance
        // bits, for every query, under several param settings
        let (data, _) = SynthClustered::new(1400, 16, 8, 17).generate_labeled();
        let index_data = query_matrix(&data, 0, 1200);
        let result =
            NnDescent::new(Params::default().with_k(16).with_seed(17)).build(&index_data).unwrap();
        let idx = GraphIndex::new(index_data, result.graph);
        let queries = query_matrix(&data, 1200, 200);

        for params in [
            SearchParams::default(),
            SearchParams { ef: 16, ..Default::default() },
            SearchParams { ef: 128, seeds: 4, ..Default::default() },
            SearchParams { probes: 64, ..Default::default() },
        ] {
            let (batch, agg) = idx.search_batch(&queries, 10, &params);
            assert_eq!(batch.len(), 200);
            assert_eq!(agg.queries, 200);
            let mut sum = QueryStats::default();
            for qi in 0..200 {
                let (seq, stats) = idx.search(queries.row_logical(qi), 10, &params);
                assert_eq!(batch[qi], seq, "ef={} query {qi} diverged", params.ef);
                sum.dist_evals += stats.dist_evals;
                sum.expansions += stats.expansions;
            }
            assert_eq!(agg.dist_evals, sum.dist_evals, "aggregate evals");
            assert_eq!(agg.expansions, sum.expansions, "aggregate expansions");
            assert!(agg.secs > 0.0 && agg.qps() > 0.0);
            assert!(agg.dist_evals_per_query() > 0.0);
            assert!(agg.expansions_per_query() > 0.0);
        }
    }

    #[test]
    fn batch_self_queries_find_themselves() {
        let (idx, data) = index(900, 16, 23);
        let queries = query_matrix(&data, 0, 60);
        let (res, _) = idx.search_batch(&queries, 3, &SearchParams::default());
        for (qi, r) in res.iter().enumerate() {
            assert_eq!(r[0].0 as usize, qi, "self must be the top hit");
            assert!(r[0].1 < 1e-6);
        }
    }

    #[test]
    fn empty_batch_is_fine() {
        let (idx, data) = index(300, 16, 29);
        let queries = AlignedMatrix::zeroed(0, data.dim());
        let (res, agg) = idx.search_batch(&queries, 5, &SearchParams::default());
        assert!(res.is_empty());
        assert_eq!(agg.queries, 0);
        assert_eq!(agg.dist_evals, 0);
        assert_eq!(agg.qps(), 0.0);
        assert_eq!(agg.dist_evals_per_query(), 0.0);
        // batches are tagged with the kernel width that served them
        assert_eq!(agg.kernel, crate::distance::dispatch::active_width().name());
    }

    #[test]
    fn index_and_scratch_are_thread_mobile() {
        // the Send/Sync audit behind the thread-per-shard runtime:
        // GraphIndex owns plain data (matrix, graph, norms) and every
        // search entry point takes &self + an owned scratch, so sharing
        // an index across workers is safe by construction. If a future
        // change sneaks interior mutability into the probe path, this
        // stops compiling.
        fn assert_send_sync<T: Send + Sync>() {}
        fn assert_send<T: Send>() {}
        assert_send_sync::<GraphIndex>();
        assert_send::<SearchScratch>();
    }

    #[test]
    fn reused_scratch_serves_identically_to_fresh() {
        // a long-lived worker's scratch must be equivalent to fresh
        // allocations no matter what ran through it before
        let (idx, data) = index(700, 16, 31);
        let mut scratch = idx.scratch();
        let sp = SearchParams::default();
        let batch_a = query_matrix(&data, 0, 40);
        let batch_b = query_matrix(&data, 300, 25);

        // interleave single queries and batches through ONE scratch
        let (w1, s1) = idx.search_with(data.row_logical(5), 7, &sp, &mut scratch);
        let (b1, a1) = idx.search_batch_with(&batch_a, 7, &sp, &mut scratch);
        let (b2, a2) = idx.search_batch_with(&batch_b, 7, &sp, &mut scratch);
        let (w2, s2) = idx.search_with(data.row_logical(5), 7, &sp, &mut scratch);

        let (fw, fs) = idx.search(data.row_logical(5), 7, &sp);
        let (fb1, fa1) = idx.search_batch(&batch_a, 7, &sp);
        let (fb2, fa2) = idx.search_batch(&batch_b, 7, &sp);
        assert_eq!(w1, fw);
        assert_eq!(w2, fw);
        assert_eq!(s1, fs);
        assert_eq!(s2, fs);
        assert_eq!(b1, fb1);
        assert_eq!(b2, fb2);
        assert_eq!((a1.dist_evals, a1.expansions), (fa1.dist_evals, fa1.expansions));
        assert_eq!((a2.dist_evals, a2.expansions), (fa2.dist_evals, fa2.expansions));
    }

    #[test]
    #[should_panic(expected = "different index size")]
    fn scratch_is_pinned_to_its_index_size() {
        let (idx_a, _) = index(300, 16, 33);
        let (idx_b, data_b) = index(400, 16, 34);
        let mut scratch = idx_a.scratch();
        let _ = idx_b.search_with(data_b.row_logical(0), 3, &SearchParams::default(), &mut scratch);
    }

    #[test]
    fn probe_ids_deterministic_and_deduped() {
        let p = SearchParams::default();
        let mut scratch = QueryScratch::new(2000);
        let a = probe_ids(2000, &p, &mut scratch);
        let b = probe_ids(2000, &p, &mut scratch);
        assert_eq!(a, b);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), a.len(), "probe ids must be unique");
        assert!(a.len() <= (4.0 * (2000f64).sqrt()) as usize);
    }
}
