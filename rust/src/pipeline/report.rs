//! Run reports: the structured result of one pipeline execution, with
//! human-readable and machine-readable (TSV) renderings.

use crate::dataset::Dataset;
use crate::nndescent::driver::BuildResult;
use crate::nndescent::Params;
use crate::util::counters::IterStats;

/// Everything EXPERIMENTS.md records about one run.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub name: String,
    pub dataset: String,
    pub n: usize,
    pub dim: usize,
    pub k: usize,
    pub selection: &'static str,
    pub compute: &'static str,
    /// Distance-kernel width the build's evaluations ran on.
    pub kernel: &'static str,
    pub reordered: bool,
    pub iterations: usize,
    pub total_secs: f64,
    pub dist_evals: u64,
    pub flops: u64,
    pub updates: u64,
    pub recall: Option<f64>,
    pub per_iter: Vec<IterStats>,
}

impl RunReport {
    pub fn new(
        name: &str,
        ds: &Dataset,
        params: &Params,
        result: &BuildResult,
        recall: Option<f64>,
    ) -> Self {
        Self {
            name: name.to_string(),
            dataset: ds.name.clone(),
            n: ds.n(),
            dim: ds.dim(),
            k: params.k,
            selection: params.selection.name(),
            compute: params.compute.name(),
            // the tag names what executed the evals: the PJRT runtime
            // is its own backend, not a native SIMD width
            kernel: if params.compute == crate::config::schema::ComputeKind::Pjrt {
                "pjrt"
            } else {
                result.stats.kernel
            },
            reordered: result.reordering.is_some(),
            iterations: result.iterations,
            total_secs: result.total_secs,
            dist_evals: result.stats.dist_evals,
            flops: result.stats.flops(),
            updates: result.total_updates(),
            recall,
            per_iter: result.per_iter.clone(),
        }
    }

    /// Human-readable multi-line rendering.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("run       : {}\n", self.name));
        s.push_str(&format!("dataset   : {} (n={}, d={})\n", self.dataset, self.n, self.dim));
        s.push_str(&format!(
            "variant   : k={} selection={} compute={} kernel={} reorder={}\n",
            self.k, self.selection, self.compute, self.kernel, self.reordered
        ));
        s.push_str(&format!(
            "result    : {} iterations, {:.3}s total, {} dist evals ({:.2e} flops), {} updates\n",
            self.iterations, self.total_secs, self.dist_evals, self.flops as f64, self.updates
        ));
        if let Some(r) = self.recall {
            s.push_str(&format!("recall    : {:.4}\n", r));
        }
        s.push_str("per-iter  : iter  select      compute     reorder     evals       updates\n");
        for it in &self.per_iter {
            s.push_str(&format!(
                "            {:<5} {:<11.4} {:<11.4} {:<11.4} {:<11} {}\n",
                it.iter, it.select_secs, it.compute_secs, it.reorder_secs, it.dist_evals, it.updates
            ));
        }
        s
    }

    /// Single TSV row (header via [`RunReport::tsv_header`]).
    pub fn tsv_row(&self) -> String {
        format!(
            "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{:.4}\t{}\t{}\t{}\t{}",
            self.name,
            self.dataset,
            self.n,
            self.dim,
            self.k,
            self.selection,
            self.compute,
            self.kernel,
            self.reordered,
            self.iterations,
            self.total_secs,
            self.dist_evals,
            self.flops,
            self.updates,
            self.recall.map(|r| format!("{r:.4}")).unwrap_or_else(|| "-".into()),
        )
    }

    pub fn tsv_header() -> &'static str {
        "name\tdataset\tn\tdim\tk\tselection\tcompute\tkernel\treordered\titerations\tsecs\tdist_evals\tflops\tupdates\trecall"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunReport {
        RunReport {
            name: "r".into(),
            dataset: "d".into(),
            n: 10,
            dim: 8,
            k: 5,
            selection: "turbo",
            compute: "blocked",
            kernel: "w8",
            reordered: true,
            iterations: 3,
            total_secs: 1.5,
            dist_evals: 1000,
            flops: 23000,
            updates: 50,
            recall: Some(0.99),
            per_iter: vec![IterStats { iter: 0, updates: 50, ..Default::default() }],
        }
    }

    #[test]
    fn render_contains_key_fields() {
        let text = sample().render();
        for needle in ["turbo", "blocked", "0.9900", "iterations", "per-iter"] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }

    #[test]
    fn tsv_row_matches_header_arity() {
        let header_cols = RunReport::tsv_header().split('\t').count();
        let row_cols = sample().tsv_row().split('\t').count();
        assert_eq!(header_cols, row_cols);
    }

    #[test]
    fn missing_recall_renders_dash() {
        let mut r = sample();
        r.recall = None;
        assert!(r.tsv_row().ends_with("\t-"));
    }
}
