//! End-to-end pipeline orchestration: config → dataset → graph build →
//! evaluation → report. This is the layer the CLI, the examples, and
//! the benches share, so every entry point exercises the same code path.

pub mod report;

pub use report::RunReport;

use crate::baseline::brute::brute_force_knn_sampled;
use crate::config::schema::ComputeKind;
use crate::config::ExperimentConfig;
use crate::dataset::{self, Dataset};
use crate::metrics::recall::recall_against_truth;
use crate::nndescent::{NnDescent, Params};

/// Options controlling the evaluation stage.
#[derive(Debug, Clone, Copy)]
pub struct EvalOptions {
    /// Number of sampled ground-truth queries (0 = skip recall).
    pub recall_queries: usize,
    /// Seed for query sampling.
    pub seed: u64,
}

impl Default for EvalOptions {
    fn default() -> Self {
        Self { recall_queries: 500, seed: 0xE7A1 }
    }
}

/// Run a full experiment from a parsed config.
pub fn run_experiment(cfg: &ExperimentConfig, eval: EvalOptions) -> anyhow::Result<RunReport> {
    Ok(run_experiment_full(cfg, eval)?.0)
}

/// Like [`run_experiment`] but also returns the build result (graph,
/// permutation, stats) and the materialized dataset, for callers that
/// persist or serve the graph.
pub fn run_experiment_full(
    cfg: &ExperimentConfig,
    eval: EvalOptions,
) -> anyhow::Result<(RunReport, crate::nndescent::BuildResult, Dataset)> {
    let ds = dataset::from_spec(&cfg.dataset)?;
    let (report, result) =
        run_on_dataset(&ds, &Params::from(&cfg.run), &cfg.run.artifacts_dir, eval, &cfg.name)?;
    Ok((report, result, ds))
}

/// Run on an already-materialized dataset.
pub fn run_on_dataset(
    ds: &Dataset,
    params: &Params,
    artifacts_dir: &str,
    eval: EvalOptions,
    name: &str,
) -> anyhow::Result<(RunReport, crate::nndescent::BuildResult)> {
    crate::log_info!(
        "pipeline `{name}`: dataset {} (n={}, d={}), selection={}, compute={}, reorder={}",
        ds.name,
        ds.n(),
        ds.dim(),
        params.selection.name(),
        params.compute.name(),
        params.reorder
    );

    let nnd = NnDescent::new(params.clone());
    let result = if params.compute == ComputeKind::Pjrt {
        build_pjrt(&nnd, ds, artifacts_dir)?
    } else {
        nnd.build(&ds.data)
    };

    let recall = if eval.recall_queries > 0 {
        let truth =
            brute_force_knn_sampled(&ds.data, params.k, eval.recall_queries, eval.seed);
        Some(recall_against_truth(&result, &truth))
    } else {
        None
    };

    let report = RunReport::new(name, ds, params, &result, recall);
    Ok((report, result))
}

/// Build through the PJRT engine (pjrt feature on).
#[cfg(feature = "pjrt")]
fn build_pjrt(
    nnd: &NnDescent,
    ds: &Dataset,
    artifacts_dir: &str,
) -> anyhow::Result<crate::nndescent::BuildResult> {
    let mut engine = crate::runtime::PjrtEngine::open(artifacts_dir)?;
    let r = nnd.build_with_engine(&ds.data, &mut engine, &mut crate::cachesim::trace::NoTracer);
    crate::log_info!(
        "pjrt engine: {} executions, {} rows gathered",
        engine.executions,
        engine.rows_gathered
    );
    Ok(r)
}

/// The pjrt feature is off: fail with an actionable message instead of
/// a missing-module compile error.
#[cfg(not(feature = "pjrt"))]
fn build_pjrt(
    _nnd: &NnDescent,
    _ds: &Dataset,
    _artifacts_dir: &str,
) -> anyhow::Result<crate::nndescent::BuildResult> {
    anyhow::bail!(
        "compute backend `pjrt` requires the `pjrt` cargo feature \
         (rebuild with `--features pjrt` and vendor the `xla` crate); \
         the native backends are scalar|unrolled|blocked"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::SelectionKind;
    use crate::config::DatasetSpec;

    #[test]
    fn pipeline_end_to_end_native() {
        let cfg = ExperimentConfig {
            name: "test-pipeline".into(),
            dataset: DatasetSpec::Clustered { n: 400, dim: 8, clusters: 4, seed: 3 },
            run: crate::config::RunConfig {
                k: 8,
                max_iters: 10,
                ..Default::default()
            },
        };
        let report = run_experiment(&cfg, EvalOptions { recall_queries: 50, seed: 1 }).unwrap();
        assert_eq!(report.n, 400);
        assert!(report.recall.unwrap() > 0.9, "recall {:?}", report.recall);
        assert!(report.total_secs > 0.0);
        let text = report.render();
        assert!(text.contains("test-pipeline"));
        assert!(text.contains("recall"));
    }

    #[test]
    fn pipeline_skips_recall_when_disabled() {
        let cfg = ExperimentConfig {
            name: "no-recall".into(),
            dataset: DatasetSpec::Gaussian { n: 200, dim: 8, single: true, seed: 1 },
            run: crate::config::RunConfig { k: 5, ..Default::default() },
        };
        let report = run_experiment(&cfg, EvalOptions { recall_queries: 0, seed: 1 }).unwrap();
        assert!(report.recall.is_none());
    }

    #[test]
    fn reorder_flag_flows_through() {
        let cfg = ExperimentConfig {
            name: "reorder".into(),
            dataset: DatasetSpec::Clustered { n: 300, dim: 8, clusters: 4, seed: 5 },
            run: crate::config::RunConfig {
                k: 6,
                reorder: true,
                selection: SelectionKind::Turbo,
                ..Default::default()
            },
        };
        let report = run_experiment(&cfg, EvalOptions { recall_queries: 30, seed: 2 }).unwrap();
        assert!(report.reordered);
        assert!(report.recall.unwrap() > 0.85);
    }
}
