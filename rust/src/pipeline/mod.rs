//! End-to-end pipeline orchestration: config → dataset → graph build →
//! evaluation → report.
//!
//! **Deprecated surface.** The free functions here predate the
//! [`api`](crate::api) facade and now delegate to it; they are kept as
//! thin shims so old callers keep compiling. New code should use
//! [`api::IndexBuilder`](crate::api::IndexBuilder) (build) and
//! [`api::Index::evaluate`](crate::api::Index::evaluate) (report):
//! the facade returns a sealed [`Index`](crate::api::Index) instead of
//! this module's bare `(RunReport, BuildResult, Dataset)` tuple, and
//! its search results are typed in the original id space.
//!
//! [`EvalOptions`] and [`RunReport`] remain first-class: the facade
//! shares them.

pub mod report;

pub use report::RunReport;

use crate::api::IndexBuilder;
use crate::config::ExperimentConfig;
use crate::dataset::{self, Dataset};
use crate::nndescent::Params;

/// Default seed for ground-truth query sampling — the single home of
/// the magic value (see [`EvalOptions::default`]).
pub const DEFAULT_EVAL_SEED: u64 = 0xE7A1;

/// Options controlling the evaluation stage. Construct with the
/// builder-style methods so defaults stay in one place:
///
/// ```
/// use knng::pipeline::EvalOptions;
///
/// let eval = EvalOptions::new().with_recall_queries(100).with_seed(7);
/// assert_eq!(eval.recall_queries, 100);
/// assert_eq!(EvalOptions::skip_recall().recall_queries, 0);
/// assert_eq!(EvalOptions::new().seed, knng::pipeline::DEFAULT_EVAL_SEED);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct EvalOptions {
    /// Number of sampled ground-truth queries (0 = skip recall).
    pub recall_queries: usize,
    /// Seed for query sampling.
    pub seed: u64,
}

impl Default for EvalOptions {
    fn default() -> Self {
        Self { recall_queries: 500, seed: DEFAULT_EVAL_SEED }
    }
}

impl EvalOptions {
    /// The defaults: 500 sampled queries, seed [`DEFAULT_EVAL_SEED`].
    pub fn new() -> Self {
        Self::default()
    }

    /// Evaluation disabled (no ground-truth sampling, recall `None`).
    pub fn skip_recall() -> Self {
        Self::new().with_recall_queries(0)
    }

    /// Set the number of sampled ground-truth queries (0 disables).
    pub fn with_recall_queries(mut self, queries: usize) -> Self {
        self.recall_queries = queries;
        self
    }

    /// Set the query-sampling seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Run a full experiment from a parsed config.
#[deprecated(note = "use api::IndexBuilder::from_config(cfg).build() + Index::evaluate(eval)")]
pub fn run_experiment(cfg: &ExperimentConfig, eval: EvalOptions) -> anyhow::Result<RunReport> {
    #[allow(deprecated)]
    let (report, _result, _ds) = run_experiment_full(cfg, eval)?;
    Ok(report)
}

/// Like [`run_experiment`] but also returns the build result (graph,
/// permutation, stats) and the materialized dataset, for callers that
/// persist or serve the graph.
#[deprecated(
    note = "use api::IndexBuilder::from_config(cfg).build(): the Index owns what this \
            tuple leaked (graph, σ, telemetry) and serves queries in original ids"
)]
pub fn run_experiment_full(
    cfg: &ExperimentConfig,
    eval: EvalOptions,
) -> anyhow::Result<(RunReport, crate::nndescent::BuildResult, Dataset)> {
    let ds = dataset::from_spec(&cfg.dataset)?;
    #[allow(deprecated)]
    let (report, result) =
        run_on_dataset(&ds, &Params::from(&cfg.run), &cfg.run.artifacts_dir, eval, &cfg.name)?;
    Ok((report, result, ds))
}

/// Run on an already-materialized dataset.
#[deprecated(note = "use api::IndexBuilder::data_named(..).build() + Index::evaluate(eval)")]
pub fn run_on_dataset(
    ds: &Dataset,
    params: &Params,
    artifacts_dir: &str,
    eval: EvalOptions,
    name: &str,
) -> anyhow::Result<(RunReport, crate::nndescent::BuildResult)> {
    crate::log_info!(
        "pipeline `{name}`: dataset {} (n={}, d={}), selection={}, compute={}, reorder={}",
        ds.name,
        ds.n(),
        ds.dim(),
        params.selection.name(),
        params.compute.name(),
        params.reorder
    );
    // The builder takes ownership of the corpus, so this shim pays one
    // O(n·dim) copy the old borrow-based path didn't; migrate to
    // IndexBuilder::data(..) to hand the matrix over instead.
    let index = IndexBuilder::new()
        .data_named(ds.data.clone(), &ds.name)
        .params(params.clone())
        .artifacts_dir(artifacts_dir)
        .name(name)
        .log_progress()
        .build()?;
    let report = index.evaluate(&eval);
    Ok((report, index.into_build_result()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Searcher;
    use crate::config::schema::SelectionKind;
    use crate::config::DatasetSpec;

    #[test]
    fn pipeline_end_to_end_native() {
        let cfg = ExperimentConfig {
            name: "test-pipeline".into(),
            dataset: DatasetSpec::Clustered { n: 400, dim: 8, clusters: 4, seed: 3 },
            run: crate::config::RunConfig {
                k: 8,
                max_iters: 10,
                ..Default::default()
            },
        };
        let index = IndexBuilder::from_config(&cfg).build().unwrap();
        let report = index.evaluate(&EvalOptions::new().with_recall_queries(50).with_seed(1));
        assert_eq!(report.n, 400);
        assert!(report.recall.unwrap() > 0.9, "recall {:?}", report.recall);
        assert!(report.total_secs > 0.0);
        let text = report.render();
        assert!(text.contains("test-pipeline"));
        assert!(text.contains("recall"));
    }

    #[test]
    fn pipeline_skips_recall_when_disabled() {
        let cfg = ExperimentConfig {
            name: "no-recall".into(),
            dataset: DatasetSpec::Gaussian { n: 200, dim: 8, single: true, seed: 1 },
            run: crate::config::RunConfig { k: 5, ..Default::default() },
        };
        let index = IndexBuilder::from_config(&cfg).build().unwrap();
        let report = index.evaluate(&EvalOptions::skip_recall().with_seed(1));
        assert!(report.recall.is_none());
    }

    #[test]
    fn reorder_flag_flows_through() {
        let cfg = ExperimentConfig {
            name: "reorder".into(),
            dataset: DatasetSpec::Clustered { n: 300, dim: 8, clusters: 4, seed: 5 },
            run: crate::config::RunConfig {
                k: 6,
                reorder: true,
                selection: SelectionKind::Turbo,
                ..Default::default()
            },
        };
        let index = IndexBuilder::from_config(&cfg).build().unwrap();
        let report = index.evaluate(&EvalOptions::new().with_recall_queries(30).with_seed(2));
        assert!(report.reordered);
        assert!(report.recall.unwrap() > 0.85);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_still_answer_like_the_facade() {
        // the migration contract: the old tuple API keeps working and
        // its pieces agree with the facade-built index
        let cfg = ExperimentConfig {
            name: "shim".into(),
            dataset: DatasetSpec::Clustered { n: 350, dim: 8, clusters: 4, seed: 11 },
            run: crate::config::RunConfig { k: 8, reorder: true, ..Default::default() },
        };
        let eval = EvalOptions::new().with_recall_queries(40).with_seed(9);
        let (report, result, ds) = run_experiment_full(&cfg, eval).unwrap();
        assert_eq!(report.n, 350);
        assert!(result.reordering.is_some());
        assert_eq!(ds.n(), 350);
        assert!(report.recall.unwrap() > 0.85);

        let index = IndexBuilder::from_config(&cfg).build().unwrap();
        assert_eq!(index.len(), 350);
        // same build → same graph workload
        let t = index.telemetry().unwrap();
        assert_eq!(t.iterations, result.iterations);
        assert_eq!(t.stats.dist_evals, result.stats.dist_evals);
        // and the facade's neighbors match the tuple's original-space view
        for u in (0..350).step_by(53) {
            let shim = result.neighbors_original(u);
            let facade = index.neighbors(crate::api::OriginalId(u as u32));
            assert_eq!(shim.len(), facade.len());
            for (s, f) in shim.iter().zip(&facade) {
                assert_eq!((s.0, s.1.to_bits()), (f.id.get(), f.dist.to_bits()), "node {u}");
            }
        }
        let report2 = run_experiment(&cfg, eval).unwrap();
        assert_eq!(report.recall, report2.recall);
        assert_eq!(report.dist_evals, report2.dist_evals);
    }

    #[test]
    fn facade_search_serves_the_built_graph() {
        let cfg = ExperimentConfig {
            name: "serve".into(),
            dataset: DatasetSpec::Clustered { n: 300, dim: 8, clusters: 4, seed: 21 },
            run: crate::config::RunConfig { k: 8, ..Default::default() },
        };
        let index = IndexBuilder::from_config(&cfg).build().unwrap();
        let q = index.data().row_logical(5).to_vec();
        let (res, _) = index.search(&q, 3, &Default::default());
        assert_eq!(res[0].id.get(), 5);
    }
}
