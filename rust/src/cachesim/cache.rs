//! Set-associative cache model: LRU replacement, write-back +
//! write-allocate, configurable size/associativity/line size — the same
//! model cachegrind simulates.

/// One cache level.
#[derive(Debug, Clone)]
pub struct Cache {
    /// Human label ("D1", "LL").
    pub name: &'static str,
    line_bits: u32,
    set_count: usize,
    assoc: usize,
    /// tags per set, most-recently-used LAST (simple Vec-based LRU —
    /// assoc ≤ 16, shifts are cheap and branch-free enough).
    sets: Vec<Vec<u64>>,
    /// dirty bit per (set, way), parallel to `sets`.
    dirty: Vec<Vec<bool>>,
    pub read_hits: u64,
    pub read_misses: u64,
    pub write_hits: u64,
    pub write_misses: u64,
    pub writebacks: u64,
}

impl Cache {
    /// `size` bytes total, `assoc`-way, `line` bytes per line.
    /// Non-power-of-two set counts are supported (e.g. the i7-9700K's
    /// 12 MiB LL has 12288 sets) via modulo indexing, exactly as
    /// cachegrind models them.
    pub fn new(name: &'static str, size: usize, assoc: usize, line: usize) -> Self {
        assert!(line.is_power_of_two() && line >= 8);
        assert!(size % (assoc * line) == 0, "size must be assoc×line aligned");
        let set_count = size / (assoc * line);
        assert!(set_count >= 1);
        Self {
            name,
            line_bits: line.trailing_zeros(),
            set_count,
            assoc,
            sets: vec![Vec::with_capacity(assoc); set_count],
            dirty: vec![Vec::with_capacity(assoc); set_count],
            read_hits: 0,
            read_misses: 0,
            write_hits: 0,
            write_misses: 0,
            writebacks: 0,
        }
    }

    #[inline]
    fn set_and_tag(&self, addr: usize) -> (usize, u64) {
        let line_addr = (addr as u64) >> self.line_bits;
        if self.set_count.is_power_of_two() {
            (
                (line_addr as usize) & (self.set_count - 1),
                line_addr >> self.set_count.trailing_zeros(),
            )
        } else {
            (
                (line_addr % self.set_count as u64) as usize,
                line_addr / self.set_count as u64,
            )
        }
    }

    /// Access one line-aligned address. Returns `true` on hit.
    /// On miss, the line is allocated (write-allocate) and the LRU
    /// victim evicted (counting a writeback if dirty).
    pub fn access_line(&mut self, addr: usize, write: bool) -> bool {
        let (set_idx, tag) = self.set_and_tag(addr);
        let set = &mut self.sets[set_idx];
        let dirty = &mut self.dirty[set_idx];
        if let Some(pos) = set.iter().position(|&t| t == tag) {
            // hit: move to MRU (back)
            let t = set.remove(pos);
            let d = dirty.remove(pos);
            set.push(t);
            dirty.push(d || write);
            if write {
                self.write_hits += 1;
            } else {
                self.read_hits += 1;
            }
            true
        } else {
            if write {
                self.write_misses += 1;
            } else {
                self.read_misses += 1;
            }
            if set.len() == self.assoc {
                set.remove(0);
                if dirty.remove(0) {
                    self.writebacks += 1;
                }
            }
            set.push(tag);
            dirty.push(write);
            false
        }
    }

    /// Line size in bytes.
    pub fn line_bytes(&self) -> usize {
        1 << self.line_bits
    }

    /// Total accesses.
    pub fn accesses(&self) -> u64 {
        self.read_hits + self.read_misses + self.write_hits + self.write_misses
    }

    /// Miss ratio over all accesses.
    pub fn miss_ratio(&self) -> f64 {
        let m = (self.read_misses + self.write_misses) as f64;
        let a = self.accesses() as f64;
        if a == 0.0 {
            0.0
        } else {
            m / a
        }
    }

    /// Reset counters (not contents).
    pub fn reset_counters(&mut self) {
        self.read_hits = 0;
        self.read_misses = 0;
        self.write_hits = 0;
        self.write_misses = 0;
        self.writebacks = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Cache {
        // 4 sets × 2 ways × 64B = 512B
        Cache::new("t", 512, 2, 64)
    }

    #[test]
    fn cold_miss_then_hit() {
        let mut c = tiny();
        assert!(!c.access_line(0x1000, false));
        assert!(c.access_line(0x1000, false));
        assert!(c.access_line(0x1010, false), "same line");
        assert_eq!(c.read_misses, 1);
        assert_eq!(c.read_hits, 2);
    }

    #[test]
    fn lru_eviction() {
        let mut c = tiny();
        // three tags mapping to the same set (set stride = 4 lines = 256B)
        let a = 0x0000;
        let b = 0x0100; // +4 lines → same set, different tag? set = (addr>>6) & 3
        let d = 0x0200;
        assert!(!c.access_line(a, false));
        assert!(!c.access_line(b, false));
        // touch a → b becomes LRU
        assert!(c.access_line(a, false));
        assert!(!c.access_line(d, false)); // evicts b
        assert!(c.access_line(a, false), "a still resident");
        assert!(!c.access_line(b, false), "b was evicted");
    }

    #[test]
    fn writeback_counted_only_when_dirty() {
        let mut c = tiny();
        let (a, b, d) = (0x0000, 0x0100, 0x0200);
        c.access_line(a, true); // dirty
        c.access_line(b, false); // clean
        c.access_line(d, false); // evicts a (LRU) → writeback
        assert_eq!(c.writebacks, 1);
        c.access_line(a, false); // evicts b (clean) → no writeback
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn working_set_smaller_than_cache_never_misses_after_warmup() {
        let mut c = Cache::new("c", 4096, 4, 64); // 64 lines
        for round in 0..3 {
            for i in 0..32 {
                let hit = c.access_line(i * 64, false);
                if round > 0 {
                    assert!(hit, "line {i} missed after warmup");
                }
            }
        }
        assert_eq!(c.read_misses, 32);
    }

    #[test]
    fn streaming_overflows() {
        let mut c = Cache::new("c", 4096, 4, 64);
        // stream 1000 distinct lines twice: capacity misses both rounds
        for _ in 0..2 {
            for i in 0..1000usize {
                c.access_line(i * 64, false);
            }
        }
        assert!(c.read_misses >= 1900, "expected ~2000 misses, got {}", c.read_misses);
    }

    #[test]
    fn non_pow2_set_count_works() {
        // 12 MiB / (16 × 64) = 12288 sets — the i7-9700K LL geometry
        let mut c = Cache::new("LL", 12 << 20, 16, 64);
        assert!(!c.access_line(0x1000, false));
        assert!(c.access_line(0x1000, false));
        // two addresses that differ by exactly set_count lines map to
        // the same set with different tags
        let stride = 12288 * 64;
        assert!(!c.access_line(0x40, false));
        assert!(!c.access_line(0x40 + stride, false));
        assert!(c.access_line(0x40, false), "both resident (assoc 16)");
        assert!(c.access_line(0x40 + stride, false));
    }

    #[test]
    fn miss_ratio_and_reset() {
        let mut c = tiny();
        c.access_line(0, false);
        c.access_line(0, false);
        assert!((c.miss_ratio() - 0.5).abs() < 1e-12);
        c.reset_counters();
        assert_eq!(c.accesses(), 0);
        assert!(c.access_line(0, false), "contents survive counter reset");
    }
}
