//! Memory-trace instrumentation points.
//!
//! The algorithm's hot functions are generic over a [`Tracer`]; the
//! default [`NoTracer`] monomorphizes every event to nothing (zero cost
//! on the real hot path — verified by identical bench timings), while
//! [`super::CacheTracer`] feeds a simulated cache hierarchy to
//! regenerate the paper's cachegrind measurements (Table 1).
//!
//! Events are emitted at *array-access* granularity (a data row read, a
//! heap-strip touch), mirroring what cachegrind would observe from the
//! compiled loads/stores of the same structures.

/// Receives the algorithm's memory accesses.
pub trait Tracer {
    /// A read of `bytes` bytes starting at `addr`.
    #[inline(always)]
    fn read(&mut self, _addr: usize, _bytes: u32) {}
    /// A write of `bytes` bytes starting at `addr`.
    #[inline(always)]
    fn write(&mut self, _addr: usize, _bytes: u32) {}
}

/// The zero-cost default tracer.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoTracer;

impl Tracer for NoTracer {}

/// A tracer that records events into a vector (testing / debugging).
#[derive(Debug, Default)]
pub struct RecordingTracer {
    pub events: Vec<(bool, usize, u32)>, // (is_write, addr, bytes)
}

impl Tracer for RecordingTracer {
    #[inline]
    fn read(&mut self, addr: usize, bytes: u32) {
        self.events.push((false, addr, bytes));
    }
    #[inline]
    fn write(&mut self, addr: usize, bytes: u32) {
        self.events.push((true, addr, bytes));
    }
}

/// Counting tracer: totals only (cheap sanity instrument).
#[derive(Debug, Default, Clone, Copy)]
pub struct CountingTracer {
    pub reads: u64,
    pub read_bytes: u64,
    pub writes: u64,
    pub write_bytes: u64,
}

impl Tracer for CountingTracer {
    #[inline]
    fn read(&mut self, _addr: usize, bytes: u32) {
        self.reads += 1;
        self.read_bytes += bytes as u64;
    }
    #[inline]
    fn write(&mut self, _addr: usize, bytes: u32) {
        self.writes += 1;
        self.write_bytes += bytes as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recording_tracer_captures_events() {
        let mut t = RecordingTracer::default();
        t.read(0x1000, 64);
        t.write(0x2000, 4);
        assert_eq!(t.events, vec![(false, 0x1000, 64), (true, 0x2000, 4)]);
    }

    #[test]
    fn counting_tracer_totals() {
        let mut t = CountingTracer::default();
        t.read(0, 32);
        t.read(64, 32);
        t.write(0, 8);
        assert_eq!((t.reads, t.read_bytes, t.writes, t.write_bytes), (2, 64, 1, 8));
    }

    #[test]
    fn no_tracer_is_inert() {
        let mut t = NoTracer;
        t.read(123, 4);
        t.write(456, 8); // nothing observable; must compile + not panic
    }
}
