//! Cache-hierarchy simulator — the substrate standing in for cachegrind
//! (paper §4.2, Table 1).
//!
//! cachegrind models a first-level data cache (D1) and a last-level
//! cache (LL); so do we. The default geometry matches the paper's
//! i7-9700K: D1 = 32 KiB 8-way (per-core; the paper's "L1: 256 KiB" is
//! the 8-core aggregate), LL = 12 MiB 16-way, 64-byte lines.
//!
//! [`CacheTracer`] implements [`trace::Tracer`], so any algorithm
//! function generic over a tracer can be replayed through the hierarchy:
//! every simulated access goes to D1; D1 misses propagate to LL;
//! LL read/write misses are the numbers Table 1 reports.

pub mod cache;
pub mod trace;

pub use cache::Cache;
pub use trace::{CountingTracer, NoTracer, RecordingTracer, Tracer};

/// Geometry of a two-level (D1 + LL) hierarchy.
#[derive(Debug, Clone, Copy)]
pub struct Geometry {
    pub d1_size: usize,
    pub d1_assoc: usize,
    pub ll_size: usize,
    pub ll_assoc: usize,
    pub line: usize,
}

impl Default for Geometry {
    fn default() -> Self {
        // i7-9700K per-core D1 + shared LL (paper's machine)
        Self { d1_size: 32 << 10, d1_assoc: 8, ll_size: 12 << 20, ll_assoc: 16, line: 64 }
    }
}

/// Summary counters in cachegrind's vocabulary.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub d1_read_misses: u64,
    pub d1_write_misses: u64,
    /// DLmr: last-level data read misses (Table 1, column 1).
    pub ll_read_misses: u64,
    /// DLmw: last-level data write misses (Table 1, column 2).
    pub ll_write_misses: u64,
    pub reads: u64,
    pub writes: u64,
}

impl CacheStats {
    /// Bytes moved from DRAM (LL misses + writebacks × line), the Q(n)
    /// input to the roofline model.
    pub fn dram_bytes(&self, line: usize, writebacks: u64) -> u64 {
        (self.ll_read_misses + self.ll_write_misses + writebacks) * line as u64
    }
}

/// Tracer feeding a simulated D1+LL hierarchy.
#[derive(Debug)]
pub struct CacheTracer {
    pub d1: Cache,
    pub ll: Cache,
    line: usize,
    reads: u64,
    writes: u64,
}

impl CacheTracer {
    pub fn new(geom: Geometry) -> Self {
        Self {
            d1: Cache::new("D1", geom.d1_size, geom.d1_assoc, geom.line),
            ll: Cache::new("LL", geom.ll_size, geom.ll_assoc, geom.line),
            line: geom.line,
            reads: 0,
            writes: 0,
        }
    }

    /// Simulate an access of `bytes` bytes at `addr` (possibly spanning
    /// several lines).
    #[inline]
    fn access(&mut self, addr: usize, bytes: u32, write: bool) {
        let first = addr & !(self.line - 1);
        let last = (addr + bytes.max(1) as usize - 1) & !(self.line - 1);
        let mut a = first;
        loop {
            if !self.d1.access_line(a, write) {
                // D1 miss → LL (allocation in both, as cachegrind does)
                self.ll.access_line(a, write);
            }
            if a == last {
                break;
            }
            a += self.line;
        }
    }

    /// Extract the cachegrind-style counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            d1_read_misses: self.d1.read_misses,
            d1_write_misses: self.d1.write_misses,
            ll_read_misses: self.ll.read_misses,
            ll_write_misses: self.ll.write_misses,
            reads: self.reads,
            writes: self.writes,
        }
    }

    /// LL writebacks (for DRAM-byte accounting).
    pub fn ll_writebacks(&self) -> u64 {
        self.ll.writebacks
    }
}

impl Tracer for CacheTracer {
    #[inline]
    fn read(&mut self, addr: usize, bytes: u32) {
        self.reads += 1;
        self.access(addr, bytes, false);
    }
    #[inline]
    fn write(&mut self, addr: usize, bytes: u32) {
        self.writes += 1;
        self.access(addr, bytes, true);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_geom() -> Geometry {
        Geometry { d1_size: 1 << 10, d1_assoc: 2, ll_size: 8 << 10, ll_assoc: 4, line: 64 }
    }

    #[test]
    fn d1_miss_propagates_to_ll() {
        let mut t = CacheTracer::new(small_geom());
        t.read(0x1000, 4);
        let s = t.stats();
        assert_eq!(s.d1_read_misses, 1);
        assert_eq!(s.ll_read_misses, 1);
        // second read: D1 hit, LL untouched
        t.read(0x1000, 4);
        let s = t.stats();
        assert_eq!(s.d1_read_misses, 1);
        assert_eq!(s.ll_read_misses, 1);
    }

    #[test]
    fn ll_absorbs_d1_capacity_misses() {
        let mut t = CacheTracer::new(small_geom());
        // stream 32 lines (2 KiB): overflows 1 KiB D1, fits 8 KiB LL
        for round in 0..2 {
            for i in 0..32usize {
                t.read(i * 64, 4);
            }
            if round == 0 {
                assert_eq!(t.stats().ll_read_misses, 32, "cold LL misses");
            }
        }
        let s = t.stats();
        assert_eq!(s.ll_read_misses, 32, "round 2 D1 misses must hit in LL");
        assert!(s.d1_read_misses > 32, "D1 too small to hold the stream");
    }

    #[test]
    fn multi_line_access_touches_every_line() {
        let mut t = CacheTracer::new(small_geom());
        t.read(0x100, 256); // 4 lines, aligned
        assert_eq!(t.stats().d1_read_misses, 4);
        let mut t = CacheTracer::new(small_geom());
        t.read(0x13c, 8); // straddles a line boundary
        assert_eq!(t.stats().d1_read_misses, 2);
    }

    #[test]
    fn working_set_vs_ll_size_controls_misses() {
        // the effect Table 1 rests on: a working set that fits LL stops
        // missing after warmup; one that doesn't keeps missing.
        let geom = small_geom(); // LL = 8 KiB = 128 lines
        let mut fits = CacheTracer::new(geom);
        let mut thrash = CacheTracer::new(geom);
        for _ in 0..5 {
            for i in 0..64usize {
                fits.read(i * 64, 4);
            }
            for i in 0..512usize {
                thrash.read(i * 64, 4);
            }
        }
        assert_eq!(fits.stats().ll_read_misses, 64, "fits: cold misses only");
        assert!(
            thrash.stats().ll_read_misses > 2000,
            "thrash: every round re-misses, got {}",
            thrash.stats().ll_read_misses
        );
    }

    #[test]
    fn prop_bigger_cache_never_misses_more() {
        use crate::testing::{check, Config};
        check(Config::cases(30), "LL misses monotone in cache size", |g| {
            // random trace over a modest address range
            let trace: Vec<(usize, u32, bool)> = (0..2000)
                .map(|_| (g.usize_in(0..1 << 16) & !3, 4u32, g.bool(0.3)))
                .collect();
            let run = |ll_size: usize| {
                let mut t = CacheTracer::new(Geometry {
                    d1_size: 1 << 10,
                    d1_assoc: 2,
                    ll_size,
                    ll_assoc: 4,
                    line: 64,
                });
                for &(a, b, w) in &trace {
                    if w {
                        t.write(a, b);
                    } else {
                        t.read(a, b);
                    }
                }
                let s = t.stats();
                s.ll_read_misses + s.ll_write_misses
            };
            // LRU inclusion property: strictly larger same-assoc cache
            // cannot miss more on the same trace
            run(16 << 10) >= run(64 << 10)
        });
    }

    #[test]
    fn prop_trace_determinism() {
        use crate::testing::{check, Config};
        check(Config::cases(20), "simulation deterministic", |g| {
            let trace: Vec<(usize, u32)> =
                (0..500).map(|_| (g.usize_in(0..1 << 14), 1 + g.u32_in(0..64))).collect();
            let run = || {
                let mut t = CacheTracer::new(small_geom());
                for &(a, b) in &trace {
                    t.read(a, b);
                }
                t.stats()
            };
            run() == run()
        });
    }

    #[test]
    fn dram_bytes_accounting() {
        let s = CacheStats { ll_read_misses: 10, ll_write_misses: 5, ..Default::default() };
        assert_eq!(s.dram_bytes(64, 3), (10 + 5 + 3) * 64);
    }
}
