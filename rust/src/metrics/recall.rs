//! Recall of an approximate K-NN graph against exact ground truth.
//!
//! recall(u) = |approx(u) ∩ exact(u)| / k, averaged over query nodes.
//! Ties at the k-th distance are handled by id-set intersection on the
//! exact list as computed (deterministic tie-break by id), which matches
//! how the paper's ≥99% numbers are normally measured.

use crate::api::ids::Neighbor;
use crate::baseline::brute::GroundTruth;
use crate::dataset::AlignedMatrix;
use crate::graph::heap::EMPTY_ID;
use crate::graph::KnnGraph;
use crate::nndescent::driver::BuildResult;

/// Mean recall of a build result (handles reordered id spaces).
pub fn recall_against_truth(result: &BuildResult, truth: &GroundTruth) -> f64 {
    let mut total = 0.0;
    for (q, exact) in &truth.queries {
        let approx = result.neighbors_original(*q as usize);
        total += overlap(&approx, exact);
    }
    total / truth.queries.len() as f64
}

/// Mean recall of a raw graph in the same id space as the truth.
pub fn recall_of_graph(graph: &KnnGraph, truth: &GroundTruth) -> f64 {
    let mut total = 0.0;
    for (q, exact) in &truth.queries {
        let ids: Vec<(u32, f32)> = graph
            .ids(*q as usize)
            .iter()
            .zip(graph.dists(*q as usize))
            .filter(|(&v, _)| v != EMPTY_ID)
            .map(|(&v, &d)| (v, d))
            .collect();
        total += overlap(&ids, exact);
    }
    total / truth.queries.len() as f64
}

/// Exact top-`k` neighbor ids of each held-out query, by brute force
/// over the whole `corpus` (ties at the k-th distance break by id).
/// Compute this once and score several result sets against it with
/// [`recall_vs_exact`] — the exact scan is the expensive half.
pub fn exact_neighbor_ids(
    corpus: &AlignedMatrix,
    queries: &AlignedMatrix,
    k: usize,
) -> Vec<Vec<u32>> {
    assert_eq!(corpus.dim(), queries.dim(), "corpus/query dim mismatch");
    let k = k.min(corpus.n());
    // resolve the dispatched pair kernel once for the full scan
    let pair = crate::distance::dispatch::active().pair;
    (0..queries.n())
        .map(|qi| {
            let mut exact: Vec<(u32, f32)> = (0..corpus.n() as u32)
                .map(|v| (v, pair(queries.row(qi), corpus.row(v as usize))))
                .collect();
            exact.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
            exact[..k].iter().map(|&(v, _)| v).collect()
        })
        .collect()
}

/// Mean recall of per-query [`Searcher`](crate::api::Searcher) results
/// against precomputed per-query exact id lists
/// (see [`exact_neighbor_ids`]).
pub fn recall_vs_exact(results: &[Vec<Neighbor>], exact: &[Vec<u32>]) -> f64 {
    assert_eq!(results.len(), exact.len(), "one result list per query");
    let denom: usize = exact.iter().map(|e| e.len()).sum();
    if denom == 0 {
        return 1.0;
    }
    let hits: usize = results
        .iter()
        .zip(exact)
        .map(|(res, ex)| ex.iter().filter(|v| res.iter().any(|nb| nb.id.get() == **v)).count())
        .sum();
    hits as f64 / denom as f64
}

/// One-shot convenience over [`exact_neighbor_ids`] + [`recall_vs_exact`]:
/// mean recall@k of held-out-query results against brute force over the
/// corpus (both in the same — original — id space). One shared
/// definition, so the facade's sharded-vs-single acceptance gates in
/// tests and benches measure the same thing.
pub fn recall_of_results(
    results: &[Vec<Neighbor>],
    corpus: &AlignedMatrix,
    queries: &AlignedMatrix,
    k: usize,
) -> f64 {
    recall_vs_exact(results, &exact_neighbor_ids(corpus, queries, k))
}

fn overlap(approx: &[(u32, f32)], exact: &[(u32, f32)]) -> f64 {
    if exact.is_empty() {
        return 1.0;
    }
    let hits = exact
        .iter()
        .filter(|(v, _)| approx.iter().any(|(a, _)| a == v))
        .count();
    hits as f64 / exact.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::brute::brute_force_knn;
    use crate::dataset::AlignedMatrix;

    #[test]
    fn perfect_graph_has_recall_one() {
        let data = AlignedMatrix::from_rows(6, 1, &[0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
        let truth = brute_force_knn(&data, 2);
        let mut graph = KnnGraph::new(6, 2);
        for (q, list) in &truth.queries {
            for &(v, d) in list {
                graph.push(*q as usize, v, d, false);
            }
        }
        assert_eq!(recall_of_graph(&graph, &truth), 1.0);
    }

    #[test]
    fn results_recall_scores_held_out_queries() {
        let corpus = AlignedMatrix::from_rows(6, 1, &[0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
        let queries = AlignedMatrix::from_rows(2, 1, &[0.1, 11.1]);
        // exact top-2 for q0 is {0, 1}; for q1 it's {4, 5}
        let perfect = vec![
            vec![Neighbor::new(0, 0.01), Neighbor::new(1, 0.81)],
            vec![Neighbor::new(4, 0.01), Neighbor::new(5, 0.81)],
        ];
        assert_eq!(recall_of_results(&perfect, &corpus, &queries, 2), 1.0);
        let half = vec![
            vec![Neighbor::new(0, 0.01), Neighbor::new(5, 141.61)],
            vec![Neighbor::new(4, 0.01), Neighbor::new(0, 123.21)],
        ];
        assert_eq!(recall_of_results(&half, &corpus, &queries, 2), 0.5);
        assert_eq!(recall_of_results(&[], &corpus, &AlignedMatrix::zeroed(0, 1), 2), 1.0);
    }

    #[test]
    fn wrong_graph_has_low_recall() {
        let data = AlignedMatrix::from_rows(6, 1, &[0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
        let truth = brute_force_knn(&data, 2);
        let mut graph = KnnGraph::new(6, 2);
        // deliberately connect each node to the *farthest* points
        for u in 0..3usize {
            graph.push(u, 4, 100.0, false);
            graph.push(u, 5, 101.0, false);
        }
        for u in 3..6usize {
            graph.push(u, 0, 100.0, false);
            graph.push(u, 1, 101.0, false);
        }
        let r = recall_of_graph(&graph, &truth);
        assert!(r < 0.5, "recall {r} should be poor");
    }
}
