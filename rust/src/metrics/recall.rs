//! Recall of an approximate K-NN graph against exact ground truth.
//!
//! recall(u) = |approx(u) ∩ exact(u)| / k, averaged over query nodes.
//! Ties at the k-th distance are handled by id-set intersection on the
//! exact list as computed (deterministic tie-break by id), which matches
//! how the paper's ≥99% numbers are normally measured.

use crate::baseline::brute::GroundTruth;
use crate::graph::heap::EMPTY_ID;
use crate::graph::KnnGraph;
use crate::nndescent::driver::BuildResult;

/// Mean recall of a build result (handles reordered id spaces).
pub fn recall_against_truth(result: &BuildResult, truth: &GroundTruth) -> f64 {
    let mut total = 0.0;
    for (q, exact) in &truth.queries {
        let approx = result.neighbors_original(*q as usize);
        total += overlap(&approx, exact);
    }
    total / truth.queries.len() as f64
}

/// Mean recall of a raw graph in the same id space as the truth.
pub fn recall_of_graph(graph: &KnnGraph, truth: &GroundTruth) -> f64 {
    let mut total = 0.0;
    for (q, exact) in &truth.queries {
        let ids: Vec<(u32, f32)> = graph
            .ids(*q as usize)
            .iter()
            .zip(graph.dists(*q as usize))
            .filter(|(&v, _)| v != EMPTY_ID)
            .map(|(&v, &d)| (v, d))
            .collect();
        total += overlap(&ids, exact);
    }
    total / truth.queries.len() as f64
}

fn overlap(approx: &[(u32, f32)], exact: &[(u32, f32)]) -> f64 {
    if exact.is_empty() {
        return 1.0;
    }
    let hits = exact
        .iter()
        .filter(|(v, _)| approx.iter().any(|(a, _)| a == v))
        .count();
    hits as f64 / exact.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::brute::brute_force_knn;
    use crate::dataset::AlignedMatrix;

    #[test]
    fn perfect_graph_has_recall_one() {
        let data = AlignedMatrix::from_rows(6, 1, &[0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
        let truth = brute_force_knn(&data, 2);
        let mut graph = KnnGraph::new(6, 2);
        for (q, list) in &truth.queries {
            for &(v, d) in list {
                graph.push(*q as usize, v, d, false);
            }
        }
        assert_eq!(recall_of_graph(&graph, &truth), 1.0);
    }

    #[test]
    fn wrong_graph_has_low_recall() {
        let data = AlignedMatrix::from_rows(6, 1, &[0.0, 1.0, 2.0, 10.0, 11.0, 12.0]);
        let truth = brute_force_knn(&data, 2);
        let mut graph = KnnGraph::new(6, 2);
        // deliberately connect each node to the *farthest* points
        for u in 0..3usize {
            graph.push(u, 4, 100.0, false);
            graph.push(u, 5, 101.0, false);
        }
        for u in 3..6usize {
            graph.push(u, 0, 100.0, false);
            graph.push(u, 1, 101.0, false);
        }
        let r = recall_of_graph(&graph, &truth);
        assert!(r < 0.5, "recall {r} should be poor");
    }
}
