//! Quality and behaviour metrics: recall vs exact ground truth, the
//! Fig-4 sliding-window cluster-distribution analysis, and run reports.

pub mod recall;
pub mod window;

pub use recall::{recall_against_truth, recall_of_graph};
pub use window::cluster_window_fractions;
