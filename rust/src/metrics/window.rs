//! Sliding-window cluster-distribution analysis (paper Fig 4).
//!
//! After the greedy reordering, slide a window of `w` memory positions
//! over the dataset; at each start position report, per cluster, the
//! fraction of window occupants belonging to that cluster. Successful
//! reordering shows near-1.0 spikes cluster by cluster early in memory
//! and a mixed ≈1/c tail.

/// For each window start (stride `step`), the per-cluster occupancy
/// fraction. `order[p]` = original node at memory position `p`;
/// `labels[v]` = cluster of original node v.
pub fn cluster_window_fractions(
    order: &[u32],
    labels: &[u32],
    clusters: usize,
    window: usize,
    step: usize,
) -> Vec<(usize, Vec<f64>)> {
    assert!(window >= 1 && step >= 1);
    let n = order.len();
    let mut out = Vec::new();
    if n < window {
        return out;
    }
    // initial window counts
    let mut counts = vec![0usize; clusters];
    for p in 0..window {
        counts[labels[order[p] as usize] as usize] += 1;
    }
    let emit = |start: usize, counts: &[usize]| {
        (start, counts.iter().map(|&c| c as f64 / window as f64).collect::<Vec<f64>>())
    };
    out.push(emit(0, &counts));
    let mut start = 0;
    while start + step + window <= n {
        // slide by `step`: remove leading, add trailing
        for p in start..start + step {
            counts[labels[order[p] as usize] as usize] -= 1;
        }
        for p in start + window..start + window + step {
            counts[labels[order[p] as usize] as usize] += 1;
        }
        start += step;
        out.push(emit(start, &counts));
    }
    out
}

/// Scalar summary of clustering quality: mean, over window positions, of
/// the *max* cluster fraction (1.0 = perfectly contiguous clusters,
/// 1/c = random order).
pub fn mean_max_fraction(fracs: &[(usize, Vec<f64>)]) -> f64 {
    if fracs.is_empty() {
        return 0.0;
    }
    fracs
        .iter()
        .map(|(_, f)| f.iter().cloned().fold(0.0, f64::max))
        .sum::<f64>()
        / fracs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfectly_sorted_clusters() {
        // 4 clusters of 25, laid out contiguously
        let order: Vec<u32> = (0..100).collect();
        let labels: Vec<u32> = (0..100).map(|i| i / 25).collect();
        let fr = cluster_window_fractions(&order, &labels, 4, 10, 5);
        // first window fully cluster 0
        assert_eq!(fr[0].1[0], 1.0);
        let mm = mean_max_fraction(&fr);
        assert!(mm > 0.9, "contiguous layout should score high, got {mm}");
    }

    #[test]
    fn interleaved_clusters_score_low() {
        let order: Vec<u32> = (0..100).collect();
        let labels: Vec<u32> = (0..100).map(|i| i % 4).collect(); // round robin
        let fr = cluster_window_fractions(&order, &labels, 4, 20, 10);
        let mm = mean_max_fraction(&fr);
        assert!(mm < 0.35, "interleaved layout should be ≈1/c, got {mm}");
    }

    #[test]
    fn sliding_counts_match_recomputation() {
        let order: Vec<u32> = (0..60).rev().collect();
        let labels: Vec<u32> = (0..60).map(|i| (i * 7 % 3) as u32).collect();
        let fr = cluster_window_fractions(&order, &labels, 3, 7, 4);
        for (start, fracs) in &fr {
            let mut counts = vec![0usize; 3];
            for p in *start..*start + 7 {
                counts[labels[order[p] as usize] as usize] += 1;
            }
            for c in 0..3 {
                assert!((fracs[c] - counts[c] as f64 / 7.0).abs() < 1e-12);
            }
            let sum: f64 = fracs.iter().sum();
            assert!((sum - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn degenerate_inputs() {
        assert!(cluster_window_fractions(&[], &[], 2, 5, 1).is_empty());
        assert_eq!(mean_max_fraction(&[]), 0.0);
    }
}
