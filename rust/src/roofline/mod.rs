//! Roofline model (paper §4.2, Fig 3).
//!
//! Performance P = W/T [flops/cycle] is bounded by min(π, β·I) where
//! I = W/Q is operational intensity, π the peak compute rate and β the
//! memory bandwidth in bytes/cycle. The paper measures:
//!
//! * π = 24 flops/cycle (8-wide FMA + 8-wide SUB mix on Coffee Lake),
//! * β = 4.77 bytes/cycle (STREAM),
//! * W from counted distance evaluations × (3d−1),
//! * Q from cachegrind LL misses × line size.
//!
//! We use the same constants by default (the *shape* of the plot — which
//! side of the ridge a configuration sits on — is machine-independent)
//! and derive cycles from wall time at a configurable nominal clock.

use crate::cachesim::CacheStats;
use crate::util::counters::FlopCounter;
use crate::util::timer::DEFAULT_NOMINAL_HZ;

/// Machine model for the roofline plot.
#[derive(Debug, Clone, Copy)]
pub struct Machine {
    /// Peak performance π [flops/cycle].
    pub pi: f64,
    /// Memory bandwidth β [bytes/cycle].
    pub beta: f64,
    /// Clock used to convert seconds → cycles.
    pub nominal_hz: f64,
    /// Cache line size [bytes] for Q accounting.
    pub line: usize,
}

impl Default for Machine {
    fn default() -> Self {
        Self { pi: 24.0, beta: 4.77, nominal_hz: DEFAULT_NOMINAL_HZ, line: 64 }
    }
}

/// One measured point on the roofline plot.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    pub label: String,
    /// Work W [flops].
    pub flops: f64,
    /// Traffic Q [bytes] (from simulated LL misses + writebacks).
    pub bytes: f64,
    /// Measured runtime [seconds].
    pub secs: f64,
}

impl RooflinePoint {
    /// Build from the crate's counters.
    pub fn from_counters(
        label: impl Into<String>,
        counter: &FlopCounter,
        cache: &CacheStats,
        writebacks: u64,
        secs: f64,
        machine: &Machine,
    ) -> Self {
        Self {
            label: label.into(),
            flops: counter.flops() as f64,
            bytes: cache.dram_bytes(machine.line, writebacks) as f64,
            secs,
        }
    }

    /// Operational intensity I = W/Q [flops/byte].
    pub fn intensity(&self) -> f64 {
        if self.bytes == 0.0 {
            f64::INFINITY
        } else {
            self.flops / self.bytes
        }
    }

    /// Achieved performance [flops/cycle] at the machine's clock.
    pub fn perf(&self, machine: &Machine) -> f64 {
        let cycles = self.secs * machine.nominal_hz;
        if cycles == 0.0 {
            0.0
        } else {
            self.flops / cycles
        }
    }

    /// Roofline bound at this point's intensity: min(π, β·I).
    pub fn bound(&self, machine: &Machine) -> f64 {
        machine.pi.min(machine.beta * self.intensity())
    }

    /// Whether the bound at this intensity is the memory slope.
    pub fn memory_bound(&self, machine: &Machine) -> bool {
        machine.beta * self.intensity() < machine.pi
    }

    /// Achieved fraction of the applicable roofline (≤ 1 in a sound
    /// measurement; > 1 indicates the model's Q or clock is off).
    pub fn efficiency(&self, machine: &Machine) -> f64 {
        let b = self.bound(machine);
        if b == 0.0 {
            0.0
        } else {
            self.perf(machine) / b
        }
    }
}

/// The ridge point I* = π/β where the roofline transitions from
/// memory- to compute-bound.
pub fn ridge_intensity(machine: &Machine) -> f64 {
    machine.pi / machine.beta
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine() -> Machine {
        Machine::default()
    }

    #[test]
    fn ridge_matches_paper_constants() {
        // π/β = 24/4.77 ≈ 5.03 flops/byte
        let r = ridge_intensity(&machine());
        assert!((r - 5.031).abs() < 0.01, "ridge {r}");
    }

    #[test]
    fn low_intensity_is_memory_bound() {
        let p = RooflinePoint { label: "d8".into(), flops: 1e9, bytes: 1e9, secs: 1.0 };
        assert!(p.memory_bound(&machine()), "I=1 < ridge ⇒ memory bound");
        assert!((p.bound(&machine()) - 4.77).abs() < 1e-9);
    }

    #[test]
    fn high_intensity_is_compute_bound() {
        let p = RooflinePoint { label: "d256".into(), flops: 1e12, bytes: 1e9, secs: 1.0 };
        assert!(!p.memory_bound(&machine()), "I=1000 ⇒ compute bound");
        assert_eq!(p.bound(&machine()), 24.0);
    }

    #[test]
    fn perf_and_efficiency() {
        let m = Machine { pi: 10.0, beta: 1.0, nominal_hz: 1e9, line: 64 };
        // 5e9 flops in 1s at 1 GHz = 5 flops/cycle; I = 50 ⇒ compute bound (10)
        let p = RooflinePoint { label: "x".into(), flops: 5e9, bytes: 1e8, secs: 1.0 };
        assert!((p.perf(&m) - 5.0).abs() < 1e-9);
        assert!((p.efficiency(&m) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn dimension_raises_intensity() {
        // the paper's §4.2 observation: increasing d by 32× increases W
        // by 32× but LL misses by less ⇒ intensity rises.
        let d8 = RooflinePoint { label: "d8".into(), flops: 23.0 * 1e6, bytes: 64.0 * 122e6, secs: 1.0 };
        let d256 = RooflinePoint {
            label: "d256".into(),
            flops: 767.0 * 1e6,
            bytes: 64.0 * 450e6,
            secs: 1.0,
        };
        assert!(d256.intensity() > d8.intensity());
    }
}
