//! MNIST dataset: real IDX(.gz) loader with a documented synthetic
//! fallback (DESIGN.md §4 substitution table).
//!
//! The paper benchmarks on the 70'000 × 784 MNIST pixel vectors. This
//! sandbox has no network access, so:
//!
//! 1. If IDX files are present (`data/train-images-idx3-ubyte[.gz]` and
//!    `data/t10k-images-idx3-ubyte[.gz]`, or an explicit `--path`), we
//!    load the real thing.
//! 2. Otherwise we generate **MNIST-like** data: 10 anisotropic Gaussian
//!    clusters in 784-d ("digits"), sparse activations arranged in
//!    2-D blob templates, values clipped to [0, 255] — same n, d,
//!    clusteredness, value range, and therefore the same memory/compute
//!    behaviour in every code path the paper measures.

use super::matrix::AlignedMatrix;
use super::Dataset;
use crate::util::rng::Pcg64;
use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

/// MNIST image side length; vectors are SIDE² = 784-dimensional.
pub const SIDE: usize = 28;
/// Dimensionality of MNIST vectors.
pub const DIM: usize = SIDE * SIDE;
/// Full dataset size (train + test, as the paper uses).
pub const FULL_N: usize = 70_000;

/// Load real MNIST if available, else synthesize. `n` caps the number of
/// points (the paper uses all 70k).
pub fn load_or_synthesize(n: usize, path: Option<&str>, seed: u64) -> Result<Dataset> {
    if let Some(p) = path {
        let data = load_idx_images(Path::new(p), n)?;
        return Ok(Dataset { name: format!("mnist:{p}"), data, labels: None });
    }
    for candidate in [
        "data/train-images-idx3-ubyte",
        "data/train-images-idx3-ubyte.gz",
        "data/mnist-images-idx3-ubyte",
    ] {
        if Path::new(candidate).exists() {
            let data = load_idx_images(Path::new(candidate), n)?;
            return Ok(Dataset { name: format!("mnist:{candidate}"), data, labels: None });
        }
    }
    let (data, labels) = synthesize(n.min(FULL_N), seed);
    Ok(Dataset { name: format!("mnist-like-n{}", data.n()), data, labels: Some(labels) })
}

/// Parse an IDX3 image file (optionally gzipped) into an AlignedMatrix.
pub fn load_idx_images(path: &Path, limit: usize) -> Result<AlignedMatrix> {
    let raw = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let bytes = if path.extension().is_some_and(|e| e == "gz") || raw.starts_with(&[0x1f, 0x8b]) {
        let mut out = Vec::new();
        flate2::read::GzDecoder::new(&raw[..])
            .read_to_end(&mut out)
            .context("gunzip IDX file")?;
        out
    } else {
        raw
    };
    parse_idx3(&bytes, limit)
}

/// Parse IDX3 bytes: magic 0x00000803, then n/rows/cols big-endian u32s.
pub fn parse_idx3(bytes: &[u8], limit: usize) -> Result<AlignedMatrix> {
    if bytes.len() < 16 {
        bail!("IDX file truncated: {} bytes", bytes.len());
    }
    let be32 = |o: usize| u32::from_be_bytes([bytes[o], bytes[o + 1], bytes[o + 2], bytes[o + 3]]);
    let magic = be32(0);
    if magic != 0x0000_0803 {
        bail!("bad IDX3 magic {magic:#010x} (expected 0x00000803)");
    }
    let n = be32(4) as usize;
    let rows = be32(8) as usize;
    let cols = be32(12) as usize;
    let dim = rows * cols;
    let take = n.min(limit);
    let need = 16 + take * dim;
    if bytes.len() < need {
        bail!("IDX payload truncated: have {}, need {need}", bytes.len());
    }
    let mut m = AlignedMatrix::zeroed(take, dim);
    for i in 0..take {
        let src = &bytes[16 + i * dim..16 + (i + 1) * dim];
        let row = m.row_mut(i);
        for (j, &b) in src.iter().enumerate() {
            row[j] = b as f32;
        }
    }
    Ok(m)
}

/// Serialize a matrix back to IDX3 bytes (used by tests and `knng gen`).
pub fn write_idx3(m: &AlignedMatrix, rows: usize, cols: usize) -> Vec<u8> {
    assert_eq!(rows * cols, m.dim());
    let mut out = Vec::with_capacity(16 + m.n() * m.dim());
    out.extend_from_slice(&0x0000_0803u32.to_be_bytes());
    out.extend_from_slice(&(m.n() as u32).to_be_bytes());
    out.extend_from_slice(&(rows as u32).to_be_bytes());
    out.extend_from_slice(&(cols as u32).to_be_bytes());
    for i in 0..m.n() {
        for &v in m.row_logical(i) {
            out.push(v.clamp(0.0, 255.0) as u8);
        }
    }
    out
}

/// Generate MNIST-like data: 10 digit-class templates built from random
/// 2-D Gaussian "strokes", with **low-rank** within-class variation
/// (a handful of smooth deformation modes per class) plus small pixel
/// noise, clipped to [0,255].
///
/// The low-rank structure matters: real MNIST classes live on a
/// low-intrinsic-dimension manifold (~10–20), which is what makes
/// NN-Descent's neighbor-of-neighbor heuristic effective on it. An
/// earlier iid-jitter generator had intrinsic dimension ≈784 and
/// depressed recall far below the paper's MNIST numbers.
pub fn synthesize(n: usize, seed: u64) -> (AlignedMatrix, Vec<u32>) {
    let mut rng = Pcg64::new_stream(seed, 0x3A15);
    // Empirical MNIST digit frequencies (train+test, ‰).
    let freq = [9.87, 11.24, 9.93, 10.22, 9.74, 9.02, 9.83, 10.44, 9.75, 9.96];
    let total: f64 = freq.iter().sum();
    const MODES: usize = 12; // within-class manifold dimension

    // A smooth random blob image (shared helper for templates and modes).
    let blob = |amp_lo: f64, amp_hi: f64, rng: &mut Pcg64| {
        let mut img = vec![0f32; DIM];
        let cx = 6.0 + 16.0 * rng.gen_f64();
        let cy = 6.0 + 16.0 * rng.gen_f64();
        let sx = 1.5 + 2.5 * rng.gen_f64();
        let sy = 1.5 + 2.5 * rng.gen_f64();
        let amp = amp_lo + (amp_hi - amp_lo) * rng.gen_f64();
        for y in 0..SIDE {
            for x in 0..SIDE {
                let dx = (x as f64 - cx) / sx;
                let dy = (y as f64 - cy) / sy;
                img[y * SIDE + x] = (amp * (-0.5 * (dx * dx + dy * dy)).exp()) as f32;
            }
        }
        img
    };

    // Per class: a stroke template + MODES smooth deformation directions.
    let mut templates = Vec::with_capacity(10);
    let mut modes: Vec<Vec<Vec<f32>>> = Vec::with_capacity(10);
    for _ in 0..10 {
        let strokes = 3 + rng.gen_index(4);
        let mut tpl = vec![0f32; DIM];
        for _ in 0..strokes {
            let b = blob(120.0, 240.0, &mut rng);
            for (t, v) in tpl.iter_mut().zip(&b) {
                *t += v;
            }
        }
        templates.push(tpl);
        let class_modes: Vec<Vec<f32>> = (0..MODES)
            .map(|_| {
                // signed smooth fields: difference of two blobs
                let a = blob(30.0, 70.0, &mut rng);
                let b = blob(30.0, 70.0, &mut rng);
                a.iter().zip(&b).map(|(x, y)| x - y).collect()
            })
            .collect();
        modes.push(class_modes);
    }

    let mut m = AlignedMatrix::zeroed(n, DIM);
    let mut labels = vec![0u32; n];
    for i in 0..n {
        // sample class by frequency
        let mut u = rng.gen_f64() * total;
        let mut class = 9usize;
        for (c, &f) in freq.iter().enumerate() {
            if u < f {
                class = c;
                break;
            }
            u -= f;
        }
        labels[i] = class as u32;
        // low-rank coefficients: the sample's position on the manifold
        let coeff: Vec<f32> = (0..MODES).map(|_| rng.gen_normal() as f32).collect();
        let pixel_noise = 2.0;
        let row = m.row_mut(i);
        for j in 0..DIM {
            let mut v = templates[class][j] as f64;
            for (p, c) in coeff.iter().enumerate() {
                v += (modes[class][p][j] * c) as f64;
            }
            v += pixel_noise * rng.gen_normal();
            // MNIST is mostly zeros: squash small background values.
            row[j] = if templates[class][j] < 8.0 && v < 24.0 {
                0.0
            } else {
                v.clamp(0.0, 255.0) as f32
            };
        }
    }
    (m, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idx3_roundtrip() {
        let (m, _) = synthesize(32, 1);
        let bytes = write_idx3(&m, SIDE, SIDE);
        let back = parse_idx3(&bytes, usize::MAX).unwrap();
        assert_eq!(back.n(), 32);
        assert_eq!(back.dim(), DIM);
        for i in 0..32 {
            for j in 0..DIM {
                assert!((back.row(i)[j] - m.row(i)[j].clamp(0.0, 255.0).floor()).abs() <= 1.0);
            }
        }
    }

    #[test]
    fn idx3_limit_and_errors() {
        let (m, _) = synthesize(10, 2);
        let bytes = write_idx3(&m, SIDE, SIDE);
        let back = parse_idx3(&bytes, 4).unwrap();
        assert_eq!(back.n(), 4);
        assert!(parse_idx3(&[0u8; 8], 1).is_err(), "truncated header");
        let mut bad = bytes.clone();
        bad[3] = 0x05;
        assert!(parse_idx3(&bad, 1).is_err(), "bad magic");
        let short = &bytes[..100];
        assert!(parse_idx3(short, usize::MAX).is_err(), "truncated payload");
    }

    #[test]
    fn gzipped_roundtrip() {
        use flate2::{write::GzEncoder, Compression};
        use std::io::Write;
        let (m, _) = synthesize(8, 3);
        let bytes = write_idx3(&m, SIDE, SIDE);
        let mut enc = GzEncoder::new(Vec::new(), Compression::fast());
        enc.write_all(&bytes).unwrap();
        let gz = enc.finish().unwrap();
        let dir = std::env::temp_dir().join("knng_mnist_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("imgs-idx3-ubyte.gz");
        std::fs::write(&path, &gz).unwrap();
        let back = load_idx_images(&path, usize::MAX).unwrap();
        assert_eq!(back.n(), 8);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn synthetic_value_range_and_sparsity() {
        let (m, labels) = synthesize(200, 42);
        let mut zeros = 0usize;
        for i in 0..m.n() {
            for &v in m.row_logical(i) {
                assert!((0.0..=255.0).contains(&v));
                if v == 0.0 {
                    zeros += 1;
                }
            }
        }
        let frac = zeros as f64 / (m.n() * m.dim()) as f64;
        assert!(frac > 0.3, "MNIST-like data should be sparse-ish, zero frac {frac}");
        assert!(labels.iter().all(|&l| l < 10));
    }

    #[test]
    fn class_structure_exists() {
        // same-class points should usually be closer than cross-class
        use crate::distance::scalar::sq_l2_scalar;
        let (m, labels) = synthesize(300, 9);
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in (0..300).step_by(7) {
            for j in (1..300).step_by(11) {
                if i == j {
                    continue;
                }
                let d = sq_l2_scalar(m.row(i), m.row(j)) as f64;
                if labels[i] == labels[j] {
                    same.push(d);
                } else {
                    diff.push(d);
                }
            }
        }
        let ms = same.iter().sum::<f64>() / same.len() as f64;
        let md = diff.iter().sum::<f64>() / diff.len() as f64;
        assert!(ms < md, "same-class mean {ms} should be < cross-class {md}");
    }
}
