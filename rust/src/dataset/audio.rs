//! Audio-like dataset (DESIGN.md §4 substitution for Dong et al.'s
//! 54'387 × 192 audio feature set, which is not redistributable).
//!
//! The original vectors are concatenated MFCC-style frames extracted
//! from spoken English. The substitute mimics their statistical shape:
//! features are produced by an AR(1) process along the feature axis
//! (adjacent coefficients correlate, like real spectral envelopes),
//! modulated by one of a small number of "speaker" archetypes providing
//! mild-but-not-crisp cluster structure.

use super::matrix::AlignedMatrix;
use crate::util::rng::Pcg64;

/// Default point count — matches Dong et al.'s audio dataset.
pub const DEFAULT_N: usize = 54_387;
/// Default feature count.
pub const DEFAULT_DIM: usize = 192;

/// Generator for audio-like feature vectors.
#[derive(Debug, Clone)]
pub struct AudioLike {
    pub n: usize,
    pub dim: usize,
    pub seed: u64,
    /// AR(1) coefficient along the feature axis.
    pub ar: f64,
    /// Number of speaker archetypes (soft clusters).
    pub speakers: usize,
}

impl AudioLike {
    pub fn new(n: usize, dim: usize, seed: u64) -> Self {
        Self { n, dim, seed, ar: 0.82, speakers: 24 }
    }

    /// Generate the matrix.
    pub fn generate(&self) -> AlignedMatrix {
        let mut rng = Pcg64::new_stream(self.seed, 0xAD10);
        // Speaker archetypes: smooth random envelopes.
        let mut archetypes: Vec<Vec<f64>> = Vec::with_capacity(self.speakers);
        for _ in 0..self.speakers {
            let mut env = vec![0f64; self.dim];
            let mut v = rng.gen_normal() * 2.0;
            for cell in env.iter_mut() {
                v = 0.9 * v + 0.6 * rng.gen_normal();
                *cell = v;
            }
            archetypes.push(env);
        }
        let innovation = (1.0 - self.ar * self.ar).sqrt();
        let mut m = AlignedMatrix::zeroed(self.n, self.dim);
        for i in 0..self.n {
            let spk = rng.gen_index(self.speakers);
            let row = m.row_mut(i);
            let mut x = rng.gen_normal();
            for (j, cell) in row.iter_mut().take(self.dim).enumerate() {
                x = self.ar * x + innovation * rng.gen_normal();
                *cell = (archetypes[spk][j] + x) as f32;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::pearson;

    #[test]
    fn shape_and_determinism() {
        let g = AudioLike::new(128, 24, 5);
        let a = g.generate();
        let b = g.generate();
        assert_eq!(a.n(), 128);
        assert_eq!(a.dim(), 24);
        assert_eq!(a.as_slice(), b.as_slice());
    }

    #[test]
    fn adjacent_features_correlate() {
        let m = AudioLike::new(4000, 32, 11).generate();
        let f0: Vec<f64> = (0..m.n()).map(|i| m.row(i)[10] as f64).collect();
        let f1: Vec<f64> = (0..m.n()).map(|i| m.row(i)[11] as f64).collect();
        let f_far: Vec<f64> = (0..m.n()).map(|i| m.row(i)[30] as f64).collect();
        let near = pearson(&f0, &f1);
        let far = pearson(&f0, &f_far);
        assert!(near > 0.5, "adjacent-feature correlation {near} too low");
        assert!(near > far, "correlation should decay with lag: near {near} far {far}");
    }

    #[test]
    fn default_shape_is_papers() {
        assert_eq!(DEFAULT_N, 54_387);
        assert_eq!(DEFAULT_DIM, 192);
        assert_eq!(DEFAULT_DIM % 8, 0, "paper requires d divisible by 8");
    }
}
