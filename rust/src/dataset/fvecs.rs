//! TEXMEX `.fvecs` reader/writer — the interchange format used by most
//! ANN benchmark corpora (SIFT1M etc.). Each vector is stored as a
//! little-endian `i32` dimension header followed by `d` `f32` values.

use super::matrix::AlignedMatrix;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

/// Read up to `limit` vectors from an `.fvecs` file.
pub fn read_fvecs(path: &Path, limit: usize) -> Result<AlignedMatrix> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse_fvecs(&bytes, limit)
}

/// Parse `.fvecs` bytes.
pub fn parse_fvecs(bytes: &[u8], limit: usize) -> Result<AlignedMatrix> {
    if bytes.len() < 4 {
        bail!("fvecs: file too small ({} bytes)", bytes.len());
    }
    let dim = i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]);
    if dim <= 0 || dim > 1_000_000 {
        bail!("fvecs: implausible dimension {dim}");
    }
    let dim = dim as usize;
    let rec = 4 + dim * 4;
    if bytes.len() % rec != 0 {
        bail!("fvecs: size {} not a multiple of record size {rec}", bytes.len());
    }
    let count = (bytes.len() / rec).min(limit);
    let mut m = AlignedMatrix::zeroed(count, dim);
    for i in 0..count {
        let off = i * rec;
        let d_i = i32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]]);
        if d_i as usize != dim {
            bail!("fvecs: inconsistent dimension at record {i}: {d_i} != {dim}");
        }
        let row = m.row_mut(i);
        for j in 0..dim {
            let o = off + 4 + j * 4;
            row[j] = f32::from_le_bytes([bytes[o], bytes[o + 1], bytes[o + 2], bytes[o + 3]]);
        }
    }
    Ok(m)
}

/// Write a matrix as `.fvecs`.
pub fn write_fvecs(path: &Path, m: &AlignedMatrix) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?,
    );
    for i in 0..m.n() {
        f.write_all(&(m.dim() as i32).to_le_bytes())?;
        for &v in m.row_logical(i) {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    f.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AlignedMatrix {
        AlignedMatrix::from_rows(3, 5, &(0..15).map(|x| x as f32 * 0.5).collect::<Vec<_>>())
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("knng_fvecs_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.fvecs");
        let m = sample();
        write_fvecs(&path, &m).unwrap();
        let back = read_fvecs(&path, usize::MAX).unwrap();
        assert_eq!(back.n(), 3);
        assert_eq!(back.dim(), 5);
        for i in 0..3 {
            assert_eq!(back.row_logical(i), m.row_logical(i));
        }
        let limited = read_fvecs(&path, 2).unwrap();
        assert_eq!(limited.n(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_fvecs(&[1, 2], usize::MAX).is_err(), "too small");
        // negative dim
        let mut bad = Vec::new();
        bad.extend_from_slice(&(-3i32).to_le_bytes());
        bad.extend_from_slice(&[0u8; 12]);
        assert!(parse_fvecs(&bad, usize::MAX).is_err());
        // inconsistent dims
        let mut bad = Vec::new();
        bad.extend_from_slice(&2i32.to_le_bytes());
        bad.extend_from_slice(&1.0f32.to_le_bytes());
        bad.extend_from_slice(&2.0f32.to_le_bytes());
        bad.extend_from_slice(&3i32.to_le_bytes()); // wrong dim header
        bad.extend_from_slice(&[0u8; 8]);
        assert!(parse_fvecs(&bad, usize::MAX).is_err());
        // size not multiple of record
        let mut bad = Vec::new();
        bad.extend_from_slice(&2i32.to_le_bytes());
        bad.extend_from_slice(&[0u8; 9]);
        assert!(parse_fvecs(&bad, usize::MAX).is_err());
    }
}
