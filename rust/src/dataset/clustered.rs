//! Synthetic Clustered dataset (paper §4).
//!
//! "A dataset designed to fulfill our clustered assumption. For every
//! cluster we draw its points from a multivariate Gaussian. Mean and
//! covariance are chosen such that the clustered assumption holds with
//! high probability."
//!
//! We place cluster means on a scaled random lattice with pairwise
//! separation ≫ within-cluster spread, so each point's k nearest
//! neighbors are within its own cluster w.h.p. Points are emitted in a
//! *shuffled* order — the reorder heuristic must not be able to cheat off
//! generation order (paper §3.2 requires "the input is not ordered in any
//! way revealing information about the structure").

use super::matrix::AlignedMatrix;
use crate::util::rng::Pcg64;

/// Generator for the clustered dataset.
#[derive(Debug, Clone)]
pub struct SynthClustered {
    pub n: usize,
    pub dim: usize,
    pub clusters: usize,
    pub seed: u64,
    /// Within-cluster stddev.
    pub sigma: f64,
    /// Center separation scale (≫ sigma for the clustered assumption).
    pub spread: f64,
}

impl SynthClustered {
    pub fn new(n: usize, dim: usize, clusters: usize, seed: u64) -> Self {
        assert!(clusters >= 1 && clusters <= n);
        Self { n, dim, clusters, seed, sigma: 1.0, spread: 40.0 }
    }

    /// Generate data + ground-truth labels (label = cluster id).
    pub fn generate_labeled(&self) -> (AlignedMatrix, Vec<u32>) {
        let mut rng = Pcg64::new_stream(self.seed, 0xC1A5);

        // Cluster centers: random directions scaled to `spread`, kept
        // pairwise-distant by rejection (cheap for practical c).
        let mut centers: Vec<Vec<f64>> = Vec::with_capacity(self.clusters);
        while centers.len() < self.clusters {
            let cand: Vec<f64> = (0..self.dim).map(|_| rng.gen_normal()).collect();
            let norm = cand.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-9);
            let cand: Vec<f64> = cand.iter().map(|x| x / norm * self.spread).collect();
            let min_sep = 2.0 * self.spread / (self.clusters as f64).sqrt().max(2.0);
            let ok = centers.iter().all(|c| {
                let d2: f64 = c.iter().zip(&cand).map(|(a, b)| (a - b) * (a - b)).sum();
                d2.sqrt() > min_sep
            });
            if ok || centers.len() > 64 {
                centers.push(cand);
            }
        }

        // Assign points near-evenly, then shuffle emission order.
        let mut order: Vec<u32> = (0..self.n as u32).collect();
        rng.shuffle(&mut order);

        let mut m = AlignedMatrix::zeroed(self.n, self.dim);
        let mut labels = vec![0u32; self.n];
        for (slot, &point_id) in order.iter().enumerate() {
            let cluster = slot % self.clusters; // even sizes pre-shuffle
            labels[point_id as usize] = cluster as u32;
            let row = m.row_mut(point_id as usize);
            for (j, cell) in row.iter_mut().take(self.dim).enumerate() {
                *cell = (centers[cluster][j] + self.sigma * rng.gen_normal()) as f32;
            }
        }
        (m, labels)
    }

    /// Generate only the matrix.
    pub fn generate(&self) -> AlignedMatrix {
        self.generate_labeled().0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distance::scalar::sq_l2_scalar;

    #[test]
    fn labels_cover_all_clusters_evenly() {
        let g = SynthClustered::new(1000, 8, 10, 5);
        let (_, labels) = g.generate_labeled();
        let mut counts = [0usize; 10];
        for &l in &labels {
            counts[l as usize] += 1;
        }
        for c in counts {
            assert!((90..=110).contains(&c), "cluster sizes should be even, got {counts:?}");
        }
    }

    #[test]
    fn clustered_assumption_holds() {
        // For a sample of points, the nearest other point must share the
        // label (necessary condition of the paper's clustered assumption).
        let g = SynthClustered::new(600, 8, 6, 11);
        let (m, labels) = g.generate_labeled();
        for i in (0..m.n()).step_by(13) {
            let mut best = (f32::INFINITY, usize::MAX);
            for j in 0..m.n() {
                if i == j {
                    continue;
                }
                let d = sq_l2_scalar(m.row(i), m.row(j));
                if d < best.0 {
                    best = (d, j);
                }
            }
            assert_eq!(labels[i], labels[best.1], "nearest neighbor of {i} crosses clusters");
        }
    }

    #[test]
    fn emission_order_is_shuffled() {
        // Consecutive points should not all share a label (generation
        // order must not leak cluster structure).
        let g = SynthClustered::new(512, 8, 8, 2);
        let (_, labels) = g.generate_labeled();
        let same_as_next = labels.windows(2).filter(|w| w[0] == w[1]).count();
        // Random order ⇒ P(same) = 1/8 ⇒ ~64 of 511; sorted order would be ~504.
        assert!(same_as_next < 150, "labels look sorted: {same_as_next} adjacent repeats");
    }

    #[test]
    fn deterministic() {
        let a = SynthClustered::new(100, 8, 4, 7).generate();
        let b = SynthClustered::new(100, 8, 4, 7).generate();
        assert_eq!(a.as_slice(), b.as_slice());
    }
}
