//! Synthetic Gaussian datasets (paper §4, "Synthetic Gaussian Dataset").
//!
//! * **Single** variant: all points drawn from one Gaussian centered at
//!   the origin, covariance `2·I_d`.
//! * **Multi** (non-single) variant: "for each dimension a gaussian is
//!   created and centered around the canonical basis vector" — i.e. `d`
//!   components, component `j` centered at `e_j`, covariance `2·I_d`,
//!   points assigned round-robin across components.

use super::matrix::AlignedMatrix;
use crate::util::rng::Pcg64;

/// Generator for the paper's synthetic Gaussian families.
#[derive(Debug, Clone)]
pub struct SynthGaussian {
    pub n: usize,
    pub dim: usize,
    pub single: bool,
    pub seed: u64,
    /// Isotropic covariance scale (paper: 2).
    pub sigma2: f64,
}

impl SynthGaussian {
    /// Single-blob variant (Fig 7's "Synthetic Single Gaussian Dataset").
    pub fn single(n: usize, dim: usize, seed: u64) -> Self {
        Self { n, dim, single: true, seed, sigma2: 2.0 }
    }

    /// One-Gaussian-per-dimension variant (Fig 3/6's dataset).
    pub fn multi(n: usize, dim: usize, seed: u64) -> Self {
        Self { n, dim, single: false, seed, sigma2: 2.0 }
    }

    /// Generate the data matrix.
    pub fn generate(&self) -> AlignedMatrix {
        let mut m = AlignedMatrix::zeroed(self.n, self.dim);
        let sd = self.sigma2.sqrt();
        let mut rng = Pcg64::new_stream(self.seed, 0xA117);
        for i in 0..self.n {
            let center = if self.single { usize::MAX } else { i % self.dim };
            let row = m.row_mut(i);
            for (j, cell) in row.iter_mut().take(self.dim).enumerate() {
                let mean = if j == center { 1.0 } else { 0.0 };
                *cell = (mean + sd * rng.gen_normal()) as f32;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = SynthGaussian::single(64, 8, 7).generate();
        let b = SynthGaussian::single(64, 8, 7).generate();
        assert_eq!(a.as_slice(), b.as_slice());
        let c = SynthGaussian::single(64, 8, 8).generate();
        assert_ne!(a.as_slice(), c.as_slice());
    }

    #[test]
    fn single_moments_match() {
        let m = SynthGaussian::single(20_000, 4, 42).generate();
        for j in 0..4 {
            let vals: Vec<f64> = (0..m.n()).map(|i| m.row(i)[j] as f64).collect();
            let mean = vals.iter().sum::<f64>() / vals.len() as f64;
            let var = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / vals.len() as f64;
            assert!(mean.abs() < 0.05, "dim {j} mean {mean}");
            assert!((var - 2.0).abs() < 0.1, "dim {j} var {var}");
        }
    }

    #[test]
    fn multi_has_shifted_means() {
        // component j (points with i % dim == j) has mean e_j
        let dim = 4;
        let m = SynthGaussian::multi(40_000, dim, 9).generate();
        for comp in 0..dim {
            for j in 0..dim {
                let vals: Vec<f64> = (0..m.n())
                    .filter(|i| i % dim == comp)
                    .map(|i| m.row(i)[j] as f64)
                    .collect();
                let mean = vals.iter().sum::<f64>() / vals.len() as f64;
                let expect = if j == comp { 1.0 } else { 0.0 };
                assert!((mean - expect).abs() < 0.1, "comp {comp} dim {j}: mean {mean} vs {expect}");
            }
        }
    }

    #[test]
    fn padding_stays_zero() {
        let m = SynthGaussian::single(16, 5, 3).generate();
        for i in 0..16 {
            assert!(m.row(i)[5..].iter().all(|&x| x == 0.0));
        }
    }
}
