//! Datasets: the aligned data matrix plus every generator/loader the
//! paper's evaluation uses (§4): Synthetic Gaussian, Synthetic Clustered,
//! MNIST, Audio — and the TEXMEX `.fvecs` interchange format.
//!
//! The central type is [`AlignedMatrix`]: row-major `f32` with rows
//! padded to a multiple of 8 floats and the allocation aligned to 64
//! bytes. This reproduces the paper's `mem-align` optimization (§3.3):
//! dimensionality restricted to multiples of 8 and data aligned so wide
//! loads never split cache lines; padding lanes are zero, so they
//! contribute nothing to squared-L2 distances.

pub mod audio;
pub mod clustered;
pub mod fvecs;
pub mod matrix;
pub mod mnist;
pub mod synth;

pub use matrix::AlignedMatrix;

use crate::config::DatasetSpec;

/// A named dataset: the matrix plus optional generator-truth cluster
/// labels (used by Fig-4-style cluster-recovery evaluation).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub data: AlignedMatrix,
    /// Ground-truth cluster id per point, when the generator knows them.
    pub labels: Option<Vec<u32>>,
}

impl Dataset {
    /// Number of points.
    pub fn n(&self) -> usize {
        self.data.n()
    }
    /// Logical dimensionality.
    pub fn dim(&self) -> usize {
        self.data.dim()
    }
}

/// Materialize a dataset from its config description.
pub fn from_spec(spec: &DatasetSpec) -> anyhow::Result<Dataset> {
    match spec {
        DatasetSpec::Gaussian { n, dim, single, seed } => {
            let g = if *single {
                synth::SynthGaussian::single(*n, *dim, *seed)
            } else {
                synth::SynthGaussian::multi(*n, *dim, *seed)
            };
            Ok(Dataset { name: format!("gaussian-n{n}-d{dim}"), data: g.generate(), labels: None })
        }
        DatasetSpec::Clustered { n, dim, clusters, seed } => {
            let g = clustered::SynthClustered::new(*n, *dim, *clusters, *seed);
            let (data, labels) = g.generate_labeled();
            Ok(Dataset {
                name: format!("clustered-n{n}-d{dim}-c{clusters}"),
                data,
                labels: Some(labels),
            })
        }
        DatasetSpec::Mnist { n, path, seed } => mnist::load_or_synthesize(*n, path.as_deref(), *seed),
        DatasetSpec::Audio { n, dim, seed } => {
            Ok(Dataset {
                name: format!("audio-n{n}-d{dim}"),
                data: audio::AudioLike::new(*n, *dim, *seed).generate(),
                labels: None,
            })
        }
        DatasetSpec::Fvecs { path, limit } => {
            let data = fvecs::read_fvecs(std::path::Path::new(path), *limit)?;
            Ok(Dataset { name: format!("fvecs:{path}"), data, labels: None })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_spec_all_generators() {
        let specs = [
            DatasetSpec::Gaussian { n: 100, dim: 9, single: true, seed: 1 },
            DatasetSpec::Gaussian { n: 100, dim: 8, single: false, seed: 1 },
            DatasetSpec::Clustered { n: 120, dim: 8, clusters: 4, seed: 1 },
            DatasetSpec::Mnist { n: 64, path: None, seed: 1 },
            DatasetSpec::Audio { n: 50, dim: 24, seed: 1 },
        ];
        for spec in specs {
            let ds = from_spec(&spec).unwrap();
            assert!(ds.n() > 0, "{}", ds.name);
            assert_eq!(ds.data.dim_pad() % 8, 0);
        }
    }

    #[test]
    fn clustered_has_labels() {
        let ds = from_spec(&DatasetSpec::Clustered { n: 64, dim: 8, clusters: 4, seed: 3 }).unwrap();
        let labels = ds.labels.unwrap();
        assert_eq!(labels.len(), 64);
        assert!(labels.iter().all(|&c| c < 4));
    }
}
