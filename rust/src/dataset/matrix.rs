//! 64-byte-aligned, 8-float-padded row-major matrix.
//!
//! Paper §3.3 (`mem-align`): restricting d to multiples of 8 and aligning
//! rows lets every 8-wide load hit a single cache line pair and removes
//! tail-handling code from the distance kernels. We go one step further
//! and align rows to 64 B (one cache line), which also makes the
//! cache-simulator traces clean.

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};

use crate::util::round_up;

/// Alignment of the backing allocation and of each row, in bytes.
pub const ROW_ALIGN: usize = 64;
/// Rows are padded to a multiple of this many f32 lanes (paper: 8).
pub const LANE_PAD: usize = 8;

/// Row-major `n × dim` f32 matrix with padded, aligned rows.
///
/// `dim_pad = 8⌈dim/8⌉` floats per row; padding lanes are always zero
/// (maintained by all mutating APIs), so squared-L2 over `dim_pad` lanes
/// equals squared-L2 over the logical `dim`.
///
/// The backing storage is usually an owned allocation, but a matrix can
/// also borrow *foreign* memory (a `KNNIv2` segment mapped or loaded by
/// the store engine) through [`from_foreign`](Self::from_foreign): the
/// rows live in the mapped file and a keepalive `Arc` pins the mapping
/// for the matrix's lifetime, so serving never copies the corpus.
pub struct AlignedMatrix {
    ptr: *mut f32,
    n: usize,
    dim: usize,
    dim_pad: usize,
    backing: Backing,
}

/// Who owns the bytes behind `ptr`.
enum Backing {
    /// Allocated by this matrix; deallocated on drop.
    Owned,
    /// Borrowed read-only from elsewhere (an mmap'd or heap-loaded
    /// segment); the keepalive pins the true owner alive. Never
    /// deallocated here, and never handed out mutably.
    Foreign(std::sync::Arc<dyn std::any::Any + Send + Sync>),
}

// Safety: owned allocations are exclusive; foreign backings are
// read-only shared bytes pinned by an Arc. f32 is Send/Sync.
unsafe impl Send for AlignedMatrix {}
unsafe impl Sync for AlignedMatrix {}

impl AlignedMatrix {
    /// Allocate an all-zero matrix.
    pub fn zeroed(n: usize, dim: usize) -> Self {
        assert!(dim > 0, "dim must be positive");
        let dim_pad = round_up(dim, LANE_PAD);
        let bytes = n.checked_mul(dim_pad).and_then(|e| e.checked_mul(4)).expect("size overflow");
        let layout = Layout::from_size_align(bytes.max(ROW_ALIGN), ROW_ALIGN).expect("layout");
        // Safety: layout has nonzero size (max'd with ROW_ALIGN).
        let ptr = unsafe { alloc_zeroed(layout) } as *mut f32;
        if ptr.is_null() {
            handle_alloc_error(layout);
        }
        Self { ptr, n, dim, dim_pad, backing: Backing::Owned }
    }

    /// Borrow an already-padded, already-aligned row block as a matrix
    /// without copying it. `ptr` must point at `n · 8⌈dim/8⌉` f32 values
    /// laid out exactly like an owned matrix (row stride `dim_pad`,
    /// padding lanes zero), be [`ROW_ALIGN`]-aligned, and stay valid and
    /// unmodified for as long as `keepalive` is alive — the store engine
    /// passes the segment's mapped (or heap-loaded) byte region here.
    ///
    /// The returned matrix is read-only: mutating accessors panic.
    ///
    /// # Safety
    /// The caller guarantees the pointed-at memory matches the layout
    /// above and outlives `keepalive`.
    pub(crate) unsafe fn from_foreign(
        ptr: *const f32,
        n: usize,
        dim: usize,
        keepalive: std::sync::Arc<dyn std::any::Any + Send + Sync>,
    ) -> Self {
        assert!(dim > 0, "dim must be positive");
        assert_eq!(ptr as usize % ROW_ALIGN, 0, "foreign backing must be {ROW_ALIGN}-byte aligned");
        let dim_pad = round_up(dim, LANE_PAD);
        Self { ptr: ptr as *mut f32, n, dim, dim_pad, backing: Backing::Foreign(keepalive) }
    }

    /// Whether this matrix owns its allocation (false for segment-backed
    /// matrices, whose rows live in a mapped file).
    #[inline]
    pub fn is_owned(&self) -> bool {
        matches!(self.backing, Backing::Owned)
    }

    /// Build from row-major data of logical width `dim`.
    pub fn from_rows(n: usize, dim: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), n * dim, "data length mismatch");
        let mut m = Self::zeroed(n, dim);
        for i in 0..n {
            m.row_mut(i)[..dim].copy_from_slice(&data[i * dim..(i + 1) * dim]);
        }
        m
    }

    /// Number of rows (points).
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Logical dimensionality.
    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Padded row width in f32 lanes (multiple of 8).
    #[inline]
    pub fn dim_pad(&self) -> usize {
        self.dim_pad
    }

    /// Padded row `i` (length `dim_pad`; tail lanes are zero).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.n);
        // Safety: allocation covers n*dim_pad floats; i bounds-checked in debug.
        unsafe { std::slice::from_raw_parts(self.ptr.add(i * self.dim_pad), self.dim_pad) }
    }

    /// Mutable padded row `i`. Callers must keep tail lanes zero.
    /// Panics on a foreign-backed (read-only, possibly mmap'd) matrix.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.n);
        assert!(self.is_owned(), "cannot mutate a foreign-backed (segment) matrix");
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(i * self.dim_pad), self.dim_pad) }
    }

    /// Logical (unpadded) view of row `i`.
    #[inline]
    pub fn row_logical(&self, i: usize) -> &[f32] {
        &self.row(i)[..self.dim]
    }

    /// Whole backing buffer (n × dim_pad).
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.n * self.dim_pad) }
    }

    /// Base address (for the cache-simulator trace generator).
    #[inline]
    pub fn base_addr(&self) -> usize {
        self.ptr as usize
    }

    /// Bytes per padded row.
    #[inline]
    pub fn row_bytes(&self) -> usize {
        self.dim_pad * 4
    }

    /// Apply a permutation: new row `j` = old row `perm[j]`.
    ///
    /// This is the paper's "copy all at once using σ" after the greedy
    /// clustering heuristic (§3.2). O(n·dim_pad) single pass into a fresh
    /// aligned allocation (the reorder is not on the per-iteration hot
    /// path — it runs once).
    pub fn permuted(&self, perm: &[u32]) -> Self {
        assert_eq!(perm.len(), self.n, "permutation length mismatch");
        let mut out = Self::zeroed(self.n, self.dim);
        for (j, &src) in perm.iter().enumerate() {
            let src = src as usize;
            assert!(src < self.n, "permutation index out of range");
            out.row_mut(j).copy_from_slice(self.row(src));
        }
        out
    }

    /// Deep copy.
    pub fn clone_matrix(&self) -> Self {
        let out = Self::zeroed(self.n, self.dim);
        unsafe {
            std::ptr::copy_nonoverlapping(self.ptr, out.ptr, self.n * self.dim_pad);
        }
        out
    }
}

impl Clone for AlignedMatrix {
    fn clone(&self) -> Self {
        self.clone_matrix()
    }
}

impl Drop for AlignedMatrix {
    fn drop(&mut self) {
        if let Backing::Owned = self.backing {
            let bytes = (self.n * self.dim_pad * 4).max(ROW_ALIGN);
            let layout = Layout::from_size_align(bytes, ROW_ALIGN).expect("layout");
            unsafe { dealloc(self.ptr as *mut u8, layout) };
        }
        // Foreign: the keepalive Arc drops with `backing`; the true
        // owner (the segment's byte region) deallocates/unmaps.
    }
}

impl std::fmt::Debug for AlignedMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AlignedMatrix({}×{} pad {})", self.n, self.dim, self.dim_pad)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Config};

    #[test]
    fn padding_and_alignment() {
        for dim in [1, 7, 8, 9, 192, 784, 3144] {
            let m = AlignedMatrix::zeroed(3, dim);
            assert_eq!(m.dim_pad() % LANE_PAD, 0);
            assert!(m.dim_pad() >= dim);
            assert!(m.dim_pad() < dim + LANE_PAD);
            assert_eq!(m.base_addr() % ROW_ALIGN, 0, "base alignment");
            assert_eq!(m.row(0).as_ptr() as usize % 32, 0, "row 0 32B-aligned");
        }
    }

    #[test]
    fn from_rows_preserves_data_zero_padding() {
        let data: Vec<f32> = (0..6).map(|x| x as f32).collect();
        let m = AlignedMatrix::from_rows(2, 3, &data);
        assert_eq!(m.row_logical(0), &[0.0, 1.0, 2.0]);
        assert_eq!(m.row_logical(1), &[3.0, 4.0, 5.0]);
        assert!(m.row(0)[3..].iter().all(|&x| x == 0.0), "tail lanes zero");
        assert!(m.row(1)[3..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn permuted_moves_rows() {
        let data: Vec<f32> = (0..8).map(|x| x as f32).collect();
        let m = AlignedMatrix::from_rows(4, 2, &data);
        let p = m.permuted(&[2, 0, 3, 1]);
        assert_eq!(p.row_logical(0), m.row_logical(2));
        assert_eq!(p.row_logical(1), m.row_logical(0));
        assert_eq!(p.row_logical(2), m.row_logical(3));
        assert_eq!(p.row_logical(3), m.row_logical(1));
    }

    #[test]
    fn prop_permutation_preserves_multiset_of_rows() {
        check(Config::cases(50), "permute preserves rows", |g| {
            let n = g.usize_in(1..40);
            let dim = g.usize_in(1..20);
            let data = g.vec_f32(n * dim, 10.0);
            let m = AlignedMatrix::from_rows(n, dim, &data);
            let perm = g.permutation(n);
            let p = m.permuted(&perm);
            // every permuted row equals its source row exactly
            perm.iter().enumerate().all(|(j, &src)| p.row(j) == m.row(src as usize))
        });
    }

    #[test]
    fn clone_is_deep() {
        let mut m = AlignedMatrix::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let c = m.clone();
        m.row_mut(0)[0] = 99.0;
        assert_eq!(c.row_logical(0), &[1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "data length mismatch")]
    fn from_rows_rejects_bad_len() {
        AlignedMatrix::from_rows(2, 3, &[0.0; 5]);
    }

    /// A foreign view over an owned matrix's buffer: rows bit-identical,
    /// no double free, clone deep-copies back into owned memory.
    #[test]
    fn foreign_view_shares_rows_without_owning() {
        let data: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let owner = std::sync::Arc::new(AlignedMatrix::from_rows(4, 3, &data));
        let view = unsafe {
            AlignedMatrix::from_foreign(
                owner.as_slice().as_ptr(),
                4,
                3,
                owner.clone() as std::sync::Arc<dyn std::any::Any + Send + Sync>,
            )
        };
        assert!(!view.is_owned());
        assert!(owner.is_owned());
        assert_eq!(view.dim_pad(), owner.dim_pad());
        for i in 0..4 {
            assert_eq!(view.row(i), owner.row(i), "row {i}");
            assert_eq!(view.row(i).as_ptr(), owner.row(i).as_ptr(), "row {i} must be shared");
        }
        let copy = view.clone();
        assert!(copy.is_owned(), "clone of a view is a real copy");
        assert_ne!(copy.row(0).as_ptr(), view.row(0).as_ptr());
        assert_eq!(copy.row(2), view.row(2));
        drop(view); // must not free the owner's buffer
        assert_eq!(owner.row_logical(3), &[9.0, 10.0, 11.0]);
    }

    #[test]
    #[should_panic(expected = "foreign-backed")]
    fn foreign_view_rejects_mutation() {
        let owner = std::sync::Arc::new(AlignedMatrix::zeroed(2, 4));
        let mut view = unsafe {
            AlignedMatrix::from_foreign(
                owner.as_slice().as_ptr(),
                2,
                4,
                owner.clone() as std::sync::Arc<dyn std::any::Any + Send + Sync>,
            )
        };
        let _ = view.row_mut(0);
    }
}
