//! 64-byte-aligned, 8-float-padded row-major matrix.
//!
//! Paper §3.3 (`mem-align`): restricting d to multiples of 8 and aligning
//! rows lets every 8-wide load hit a single cache line pair and removes
//! tail-handling code from the distance kernels. We go one step further
//! and align rows to 64 B (one cache line), which also makes the
//! cache-simulator traces clean.

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};

use crate::util::round_up;

/// Alignment of the backing allocation and of each row, in bytes.
pub const ROW_ALIGN: usize = 64;
/// Rows are padded to a multiple of this many f32 lanes (paper: 8).
pub const LANE_PAD: usize = 8;

/// Row-major `n × dim` f32 matrix with padded, aligned rows.
///
/// `dim_pad = 8⌈dim/8⌉` floats per row; padding lanes are always zero
/// (maintained by all mutating APIs), so squared-L2 over `dim_pad` lanes
/// equals squared-L2 over the logical `dim`.
pub struct AlignedMatrix {
    ptr: *mut f32,
    n: usize,
    dim: usize,
    dim_pad: usize,
}

// Safety: the matrix owns its allocation exclusively; f32 is Send/Sync.
unsafe impl Send for AlignedMatrix {}
unsafe impl Sync for AlignedMatrix {}

impl AlignedMatrix {
    /// Allocate an all-zero matrix.
    pub fn zeroed(n: usize, dim: usize) -> Self {
        assert!(dim > 0, "dim must be positive");
        let dim_pad = round_up(dim, LANE_PAD);
        let bytes = n.checked_mul(dim_pad).and_then(|e| e.checked_mul(4)).expect("size overflow");
        let layout = Layout::from_size_align(bytes.max(ROW_ALIGN), ROW_ALIGN).expect("layout");
        // Safety: layout has nonzero size (max'd with ROW_ALIGN).
        let ptr = unsafe { alloc_zeroed(layout) } as *mut f32;
        if ptr.is_null() {
            handle_alloc_error(layout);
        }
        Self { ptr, n, dim, dim_pad }
    }

    /// Build from row-major data of logical width `dim`.
    pub fn from_rows(n: usize, dim: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), n * dim, "data length mismatch");
        let mut m = Self::zeroed(n, dim);
        for i in 0..n {
            m.row_mut(i)[..dim].copy_from_slice(&data[i * dim..(i + 1) * dim]);
        }
        m
    }

    /// Number of rows (points).
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Logical dimensionality.
    #[inline]
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Padded row width in f32 lanes (multiple of 8).
    #[inline]
    pub fn dim_pad(&self) -> usize {
        self.dim_pad
    }

    /// Padded row `i` (length `dim_pad`; tail lanes are zero).
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.n);
        // Safety: allocation covers n*dim_pad floats; i bounds-checked in debug.
        unsafe { std::slice::from_raw_parts(self.ptr.add(i * self.dim_pad), self.dim_pad) }
    }

    /// Mutable padded row `i`. Callers must keep tail lanes zero.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.n);
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(i * self.dim_pad), self.dim_pad) }
    }

    /// Logical (unpadded) view of row `i`.
    #[inline]
    pub fn row_logical(&self, i: usize) -> &[f32] {
        &self.row(i)[..self.dim]
    }

    /// Whole backing buffer (n × dim_pad).
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.n * self.dim_pad) }
    }

    /// Base address (for the cache-simulator trace generator).
    #[inline]
    pub fn base_addr(&self) -> usize {
        self.ptr as usize
    }

    /// Bytes per padded row.
    #[inline]
    pub fn row_bytes(&self) -> usize {
        self.dim_pad * 4
    }

    /// Apply a permutation: new row `j` = old row `perm[j]`.
    ///
    /// This is the paper's "copy all at once using σ" after the greedy
    /// clustering heuristic (§3.2). O(n·dim_pad) single pass into a fresh
    /// aligned allocation (the reorder is not on the per-iteration hot
    /// path — it runs once).
    pub fn permuted(&self, perm: &[u32]) -> Self {
        assert_eq!(perm.len(), self.n, "permutation length mismatch");
        let mut out = Self::zeroed(self.n, self.dim);
        for (j, &src) in perm.iter().enumerate() {
            let src = src as usize;
            assert!(src < self.n, "permutation index out of range");
            out.row_mut(j).copy_from_slice(self.row(src));
        }
        out
    }

    /// Deep copy.
    pub fn clone_matrix(&self) -> Self {
        let out = Self::zeroed(self.n, self.dim);
        unsafe {
            std::ptr::copy_nonoverlapping(self.ptr, out.ptr, self.n * self.dim_pad);
        }
        out
    }
}

impl Clone for AlignedMatrix {
    fn clone(&self) -> Self {
        self.clone_matrix()
    }
}

impl Drop for AlignedMatrix {
    fn drop(&mut self) {
        let bytes = (self.n * self.dim_pad * 4).max(ROW_ALIGN);
        let layout = Layout::from_size_align(bytes, ROW_ALIGN).expect("layout");
        unsafe { dealloc(self.ptr as *mut u8, layout) };
    }
}

impl std::fmt::Debug for AlignedMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AlignedMatrix({}×{} pad {})", self.n, self.dim, self.dim_pad)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Config};

    #[test]
    fn padding_and_alignment() {
        for dim in [1, 7, 8, 9, 192, 784, 3144] {
            let m = AlignedMatrix::zeroed(3, dim);
            assert_eq!(m.dim_pad() % LANE_PAD, 0);
            assert!(m.dim_pad() >= dim);
            assert!(m.dim_pad() < dim + LANE_PAD);
            assert_eq!(m.base_addr() % ROW_ALIGN, 0, "base alignment");
            assert_eq!(m.row(0).as_ptr() as usize % 32, 0, "row 0 32B-aligned");
        }
    }

    #[test]
    fn from_rows_preserves_data_zero_padding() {
        let data: Vec<f32> = (0..6).map(|x| x as f32).collect();
        let m = AlignedMatrix::from_rows(2, 3, &data);
        assert_eq!(m.row_logical(0), &[0.0, 1.0, 2.0]);
        assert_eq!(m.row_logical(1), &[3.0, 4.0, 5.0]);
        assert!(m.row(0)[3..].iter().all(|&x| x == 0.0), "tail lanes zero");
        assert!(m.row(1)[3..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn permuted_moves_rows() {
        let data: Vec<f32> = (0..8).map(|x| x as f32).collect();
        let m = AlignedMatrix::from_rows(4, 2, &data);
        let p = m.permuted(&[2, 0, 3, 1]);
        assert_eq!(p.row_logical(0), m.row_logical(2));
        assert_eq!(p.row_logical(1), m.row_logical(0));
        assert_eq!(p.row_logical(2), m.row_logical(3));
        assert_eq!(p.row_logical(3), m.row_logical(1));
    }

    #[test]
    fn prop_permutation_preserves_multiset_of_rows() {
        check(Config::cases(50), "permute preserves rows", |g| {
            let n = g.usize_in(1..40);
            let dim = g.usize_in(1..20);
            let data = g.vec_f32(n * dim, 10.0);
            let m = AlignedMatrix::from_rows(n, dim, &data);
            let perm = g.permutation(n);
            let p = m.permuted(&perm);
            // every permuted row equals its source row exactly
            perm.iter().enumerate().all(|(j, &src)| p.row(j) == m.row(src as usize))
        });
    }

    #[test]
    fn clone_is_deep() {
        let mut m = AlignedMatrix::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let c = m.clone();
        m.row_mut(0)[0] = 99.0;
        assert_eq!(c.row_logical(0), &[1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "data length mismatch")]
    fn from_rows_rejects_bad_len() {
        AlignedMatrix::from_rows(2, 3, &[0.0; 5]);
    }
}
