//! PJRT-backed pairwise engine and tile scanner.
//!
//! [`PjrtEngine`] implements the compute step's [`PairwiseEngine`]
//! contract by gathering candidate rows into a fixed (B, D) batch,
//! executing the AOT-compiled Pallas `pairwise` artifact, and scattering
//! the (B, B) result into the caller's [`PairwiseBuf`]. Padding rows are
//! zero; their pairs are never read back.
//!
//! [`TileScanner`] drives the `tilescan` artifact for bulk cross-set
//! distances (PJRT-side brute force / ground truth).

use super::artifacts::{ArtifactKey, ArtifactStore};
use crate::cachesim::trace::Tracer;
use crate::dataset::AlignedMatrix;
use crate::distance::blocked::PairwiseBuf;
use crate::nndescent::compute::PairwiseEngine;
use anyhow::{Context, Result};

/// Pairwise-distance engine executing the AOT Pallas kernel via PJRT.
pub struct PjrtEngine {
    store: ArtifactStore,
    /// Gather buffer reused across calls (B × D floats).
    batch: Vec<f32>,
    /// Statistics: number of artifact executions.
    pub executions: u64,
    /// Statistics: total rows gathered.
    pub rows_gathered: u64,
}

impl PjrtEngine {
    /// Open over an artifact directory (usually "artifacts").
    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(Self { store: ArtifactStore::open(dir)?, batch: Vec::new(), executions: 0, rows_gathered: 0 })
    }

    pub fn store(&self) -> &ArtifactStore {
        &self.store
    }

    /// Execute the pairwise artifact for `ids`; fills `out[i][j]` for all
    /// i≠j < m. Errors if no artifact covers (m, dim_pad).
    pub fn pairwise_checked(
        &mut self,
        data: &AlignedMatrix,
        ids: &[u32],
        out: &mut PairwiseBuf,
    ) -> Result<u64> {
        let m = ids.len();
        out.reset(m);
        if m < 2 {
            return Ok(0);
        }
        let d = data.dim_pad();
        let (b, _) = self.store.find_pairwise(m, d).ok_or_else(|| {
            anyhow::anyhow!(
                "no pairwise artifact for candidate set m={m}, d_pad={d}; \
                 available: {:?}",
                self.store.pairwise_shapes()
            )
        })?;

        // gather rows into the padded batch
        self.batch.clear();
        self.batch.resize(b * d, 0.0);
        for (i, &id) in ids.iter().enumerate() {
            self.batch[i * d..(i + 1) * d].copy_from_slice(data.row(id as usize));
        }
        self.rows_gathered += m as u64;

        let key = ArtifactKey { kind: "pairwise", dims: vec![b, d] };
        let exe = self.store.executable(&key)?;
        let x = xla::Literal::vec1(&self.batch).reshape(&[b as i64, d as i64])?;
        let result = exe.execute::<xla::Literal>(&[x])?[0][0]
            .to_literal_sync()
            .context("fetching pairwise result")?;
        self.executions += 1;
        let tuple = result.to_tuple1().context("unwrapping 1-tuple result")?;
        let dists: Vec<f32> = tuple.to_vec()?;
        debug_assert_eq!(dists.len(), b * b);

        for i in 0..m {
            for j in (i + 1)..m {
                // symmetric kernel output; store canonical i<j entry
                out.put(i, j, dists[i * b + j]);
            }
        }
        // the executable evaluated the full b×b block
        Ok((b * (b - 1) / 2) as u64)
    }
}

impl PairwiseEngine for PjrtEngine {
    fn pairwise<T: Tracer>(
        &mut self,
        data: &AlignedMatrix,
        ids: &[u32],
        _active: usize, // fixed-shape batch computes the full block anyway
        out: &mut PairwiseBuf,
        tracer: &mut T,
    ) -> u64 {
        // trace: every candidate row is read once into the batch
        let rb = data.row_bytes() as u32;
        for &id in ids {
            tracer.read(data.base_addr() + id as usize * data.row_bytes(), rb);
        }
        self.pairwise_checked(data, ids, out)
            .expect("PJRT pairwise execution failed (see artifact manifest)")
    }

    fn is_blocked(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Bulk cross-set distance scanner over the `tilescan` artifact.
pub struct TileScanner {
    store: ArtifactStore,
    m: usize,
    n: usize,
    d: usize,
}

impl TileScanner {
    /// Open for a fixed artifact shape (M queries × N corpus × D).
    pub fn open(dir: impl AsRef<std::path::Path>, m: usize, n: usize, d: usize) -> Result<Self> {
        let store = ArtifactStore::open(dir)?;
        Ok(Self { store, m, n, d })
    }

    pub fn shape(&self) -> (usize, usize, usize) {
        (self.m, self.n, self.d)
    }

    /// Distances from `queries` (≤ M rows) to `corpus` (≤ N rows), both
    /// zero-padded to the artifact shape. Returns a row-major
    /// `queries.len() × corpus.len()` matrix.
    pub fn scan(
        &mut self,
        data: &AlignedMatrix,
        queries: &[u32],
        corpus: &[u32],
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(queries.len() <= self.m, "too many queries");
        anyhow::ensure!(corpus.len() <= self.n, "corpus tile too large");
        anyhow::ensure!(data.dim_pad() == self.d, "dim mismatch");
        let (m, n, d) = (self.m, self.n, self.d);
        let mut qbuf = vec![0f32; m * d];
        for (i, &q) in queries.iter().enumerate() {
            qbuf[i * d..(i + 1) * d].copy_from_slice(data.row(q as usize));
        }
        let mut xbuf = vec![0f32; n * d];
        for (i, &v) in corpus.iter().enumerate() {
            xbuf[i * d..(i + 1) * d].copy_from_slice(data.row(v as usize));
        }
        let key = ArtifactKey { kind: "tilescan", dims: vec![m, n, d] };
        let exe = self.store.executable(&key)?;
        let q = xla::Literal::vec1(&qbuf).reshape(&[m as i64, d as i64])?;
        let x = xla::Literal::vec1(&xbuf).reshape(&[n as i64, d as i64])?;
        let result = exe.execute::<xla::Literal>(&[q, x])?[0][0].to_literal_sync()?;
        let full: Vec<f32> = result.to_tuple1()?.to_vec()?;
        debug_assert_eq!(full.len(), m * n);
        let mut out = Vec::with_capacity(queries.len() * corpus.len());
        for qi in 0..queries.len() {
            out.extend_from_slice(&full[qi * n..qi * n + corpus.len()]);
        }
        Ok(out)
    }
}

// Integration tests (require `make artifacts`) live in
// rust/tests/runtime_integration.rs.
