//! PJRT runtime: load the AOT-compiled Pallas/XLA artifacts and execute
//! them from the L3 hot path. Python never runs here — the artifacts
//! are plain HLO text produced once by `make artifacts`.
//!
//! Pipeline: `PjRtClient::cpu()` → [`ArtifactStore`] parses
//! `artifacts/manifest.tsv` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` (lazily, cached per
//! shape) → [`PjrtEngine`]/[`TileScanner`] execute with gathered inputs.

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactKey, ArtifactStore, ManifestEntry};
pub use engine::{PjrtEngine, TileScanner};
