//! Artifact manifest parsing and lazy executable compilation.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One manifest line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestEntry {
    pub kind: String,
    /// Shape args in manifest order (pairwise: [B, D]; tilescan: [M, N, D]).
    pub dims: Vec<usize>,
    pub file: String,
}

/// Lookup key for a compiled executable.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    pub kind: &'static str,
    pub dims: Vec<usize>,
}

/// Loads `manifest.tsv`, compiles artifacts on demand, and caches the
/// resulting PJRT executables per shape.
pub struct ArtifactStore {
    dir: PathBuf,
    entries: Vec<ManifestEntry>,
    client: xla::PjRtClient,
    cache: HashMap<ArtifactKey, xla::PjRtLoadedExecutable>,
}

impl ArtifactStore {
    /// Open a store rooted at `dir` (must contain `manifest.tsv`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                manifest.display()
            )
        })?;
        let entries = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { dir, entries, client, cache: HashMap::new() })
    }

    /// All manifest entries.
    pub fn entries(&self) -> &[ManifestEntry] {
        &self.entries
    }

    /// The PJRT client (platform introspection, tests).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Pairwise artifact shapes available, sorted by (D, B).
    pub fn pairwise_shapes(&self) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .entries
            .iter()
            .filter(|e| e.kind == "pairwise")
            .map(|e| (e.dims[0], e.dims[1]))
            .collect();
        v.sort_by_key(|&(b, d)| (d, b));
        v
    }

    /// Smallest pairwise artifact with `B >= m` and `D == d_pad`.
    pub fn find_pairwise(&self, m: usize, d_pad: usize) -> Option<(usize, usize)> {
        self.pairwise_shapes()
            .into_iter()
            .filter(|&(b, d)| d == d_pad && b >= m)
            .min_by_key(|&(b, _)| b)
    }

    /// Get (compiling + caching on first use) the executable for a key.
    pub fn executable(&mut self, key: &ArtifactKey) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(key) {
            let entry = self
                .entries
                .iter()
                .find(|e| e.kind == key.kind && e.dims == key.dims)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "no artifact `{}` with dims {:?} in {} — regenerate with \
                         `cd python && python -m compile.aot` and the right shape list",
                        key.kind,
                        key.dims,
                        self.dir.display()
                    )
                })?;
            let path = self.dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", entry.file))?;
            self.cache.insert(key.clone(), exe);
        }
        Ok(self.cache.get(key).unwrap())
    }

    /// Number of compiled executables held.
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}

fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = line.split('\t').collect();
        if parts.len() < 3 {
            bail!("manifest line {}: expected kind<TAB>dims...<TAB>file, got `{line}`", i + 1);
        }
        let kind = parts[0].to_string();
        let file = parts[parts.len() - 1].to_string();
        let dims = parts[1..parts.len() - 1]
            .iter()
            .map(|s| s.parse::<usize>().map_err(|_| anyhow::anyhow!("bad dim `{s}` at line {}", i + 1)))
            .collect::<Result<Vec<usize>>>()?;
        let expected = match kind.as_str() {
            "pairwise" => 2,
            "tilescan" => 3,
            _ => dims.len(), // future kinds: accept as-is
        };
        if dims.len() != expected {
            bail!("manifest line {}: `{kind}` expects {expected} dims, got {}", i + 1, dims.len());
        }
        out.push(ManifestEntry { kind, dims, file });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_lines() {
        let text = "pairwise\t64\t256\tpairwise_b64_d256.hlo.txt\n\
                    tilescan\t128\t1024\t64\ttilescan.hlo.txt\n\
                    # comment\n\n";
        let entries = parse_manifest(text).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].kind, "pairwise");
        assert_eq!(entries[0].dims, vec![64, 256]);
        assert_eq!(entries[1].dims, vec![128, 1024, 64]);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse_manifest("pairwise\t64").is_err());
        assert!(parse_manifest("pairwise\tx\t8\tf.txt").is_err());
        assert!(parse_manifest("pairwise\t64\t8\t16\tf.txt").is_err(), "wrong arity");
    }

    // Store-level tests that need real artifacts live in
    // rust/tests/runtime_integration.rs (they require `make artifacts`).
}
