#![feature(portable_simd)]
//! # knng — Fast Single-Core K-Nearest Neighbor Graph Computation
//!
//! A production-oriented reproduction of *"Fast Single-Core K-Nearest
//! Neighbor Graph Computation"* (Kluser, Bokstaller, Rutz, Buner; ETH
//! Zurich, 2021): a runtime-optimized implementation of the NN-Descent
//! algorithm (Dong et al., WWW'11) for the squared-L2 metric, plus every
//! substrate needed to regenerate the paper's evaluation — synthetic and
//! real-world dataset handling, a cache-hierarchy simulator standing in
//! for cachegrind, a roofline model, baselines, and a full benchmark
//! harness.
//!
//! ## Architecture (three layers)
//!
//! * **L3 (this crate)** — the single-core NN-Descent pipeline: selection
//!   strategies (`nndescent::selection`), the greedy memory-reordering
//!   heuristic (`nndescent::reorder`), blocked distance kernels
//!   (`distance`), graph state (`graph`), datasets (`dataset`), and the
//!   iteration driver (`nndescent::driver`).
//! * **L2/L1 (python/, build-time only)** — the blocked pairwise-L2
//!   compute hot-spot expressed as a Pallas kernel inside a JAX graph,
//!   AOT-lowered to HLO text artifacts.
//! * **runtime** — loads those artifacts through PJRT (`xla` crate) so the
//!   compute step can be offloaded without any Python on the request path.
//!   Gated behind the off-by-default `pjrt` cargo feature because the
//!   `xla` crate is unavailable offline.
//!
//! ## Quickstart
//!
//! The [`api`] module is the crate's public face: a typed builder, a
//! sealed index, and searchers that always answer in the caller's
//! original id space.
//!
//! ```no_run
//! use knng::api::{IndexBuilder, Searcher};
//! use knng::config::DatasetSpec;
//! use knng::nndescent::Params;
//!
//! let index = IndexBuilder::new()
//!     .dataset(DatasetSpec::Gaussian { n: 4096, dim: 32, single: true, seed: 0x5eed })
//!     .params(Params::default().with_k(20).with_reorder(true))
//!     .build()?;
//! let telemetry = index.telemetry().unwrap();
//! println!("graph built in {} iterations, {} distance evals",
//!          telemetry.iterations, telemetry.stats.dist_evals);
//! let (neighbors, _stats) = index.search(index.data().row_logical(0), 10, &Default::default());
//! println!("nearest neighbor of node 0: {}", neighbors[1].id);
//! # Ok::<(), anyhow::Error>(())
//! ```

pub mod api;
pub mod baseline;
pub mod bench;
pub mod cachesim;
pub mod cli;
pub mod config;
pub mod dataset;
pub mod distance;
pub mod graph;
pub mod metrics;
pub mod net;
pub mod nndescent;
pub mod pipeline;
pub mod roofline;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod search;
pub mod store;
pub mod testing;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Version string reported by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
