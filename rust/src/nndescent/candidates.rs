//! Bounded per-node candidate lists (selection-step output).
//!
//! Flat `n × cap` storage — no per-node allocation, reused across
//! iterations. "New" candidates are those carrying the incremental-
//! search flag; "old" are established neighbors. The compute step
//! evaluates new×new and new×old pairs (old×old were compared in an
//! earlier iteration).

/// Flat candidate lists for all nodes.
#[derive(Debug, Clone)]
pub struct CandidateLists {
    n: usize,
    cap: usize,
    new_ids: Vec<u32>,
    new_len: Vec<u16>,
    old_ids: Vec<u32>,
    old_len: Vec<u16>,
}

impl CandidateLists {
    /// Allocate for `n` nodes with per-list capacity `cap`.
    pub fn new(n: usize, cap: usize) -> Self {
        assert!(cap >= 1 && cap <= u16::MAX as usize);
        Self {
            n,
            cap,
            new_ids: vec![0; n * cap],
            new_len: vec![0; n],
            old_ids: vec![0; n * cap],
            old_len: vec![0; n],
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Clear all lists (O(n), lengths only).
    pub fn clear(&mut self) {
        self.new_len.fill(0);
        self.old_len.fill(0);
    }

    /// New-candidate slice of node `u`.
    #[inline]
    pub fn new_slice(&self, u: usize) -> &[u32] {
        &self.new_ids[u * self.cap..u * self.cap + self.new_len[u] as usize]
    }

    /// Old-candidate slice of node `u`.
    #[inline]
    pub fn old_slice(&self, u: usize) -> &[u32] {
        &self.old_ids[u * self.cap..u * self.cap + self.old_len[u] as usize]
    }

    /// Append `v` to `u`'s new list; returns false when full.
    #[inline]
    pub fn push_new(&mut self, u: usize, v: u32) -> bool {
        let len = self.new_len[u] as usize;
        if len >= self.cap {
            return false;
        }
        self.new_ids[u * self.cap + len] = v;
        self.new_len[u] = (len + 1) as u16;
        true
    }

    /// Append `v` to `u`'s old list; returns false when full.
    #[inline]
    pub fn push_old(&mut self, u: usize, v: u32) -> bool {
        let len = self.old_len[u] as usize;
        if len >= self.cap {
            return false;
        }
        self.old_ids[u * self.cap + len] = v;
        self.old_len[u] = (len + 1) as u16;
        true
    }

    /// Overwrite slot `slot` of `u`'s new list (reservoir replacement;
    /// list must already contain `slot`).
    #[inline]
    pub fn replace_new(&mut self, u: usize, slot: usize, v: u32) {
        debug_assert!(slot < self.new_len[u] as usize);
        self.new_ids[u * self.cap + slot] = v;
    }

    /// Overwrite slot `slot` of `u`'s old list.
    #[inline]
    pub fn replace_old(&mut self, u: usize, slot: usize, v: u32) {
        debug_assert!(slot < self.old_len[u] as usize);
        self.old_ids[u * self.cap + slot] = v;
    }

    #[inline]
    pub fn new_len(&self, u: usize) -> usize {
        self.new_len[u] as usize
    }

    #[inline]
    pub fn old_len(&self, u: usize) -> usize {
        self.old_len[u] as usize
    }

    /// Direct store into the new list at `idx` and set length (heap
    /// selector finalization).
    pub(crate) fn set_new(&mut self, u: usize, ids: &[u32]) {
        debug_assert!(ids.len() <= self.cap);
        self.new_ids[u * self.cap..u * self.cap + ids.len()].copy_from_slice(ids);
        self.new_len[u] = ids.len() as u16;
    }

    pub(crate) fn set_old(&mut self, u: usize, ids: &[u32]) {
        debug_assert!(ids.len() <= self.cap);
        self.old_ids[u * self.cap..u * self.cap + ids.len()].copy_from_slice(ids);
        self.old_len[u] = ids.len() as u16;
    }

    /// Base address of the new-id array (for the cache-sim trace).
    pub fn new_ids_addr(&self) -> usize {
        self.new_ids.as_ptr() as usize
    }

    /// Base address of the old-id array.
    pub fn old_ids_addr(&self) -> usize {
        self.old_ids.as_ptr() as usize
    }

    /// Total candidates across all nodes (diagnostics).
    pub fn total(&self) -> usize {
        self.new_len.iter().map(|&l| l as usize).sum::<usize>()
            + self.old_len.iter().map(|&l| l as usize).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_bounds() {
        let mut c = CandidateLists::new(3, 2);
        assert!(c.push_new(0, 5));
        assert!(c.push_new(0, 6));
        assert!(!c.push_new(0, 7), "full");
        assert_eq!(c.new_slice(0), &[5, 6]);
        assert_eq!(c.new_slice(1), &[] as &[u32]);
        assert!(c.push_old(2, 9));
        assert_eq!(c.old_slice(2), &[9]);
        assert_eq!(c.total(), 3);
    }

    #[test]
    fn clear_resets_lengths() {
        let mut c = CandidateLists::new(2, 4);
        c.push_new(0, 1);
        c.push_old(1, 2);
        c.clear();
        assert_eq!(c.total(), 0);
        assert_eq!(c.new_slice(0), &[] as &[u32]);
    }

    #[test]
    fn replace_slots() {
        let mut c = CandidateLists::new(1, 3);
        c.push_new(0, 1);
        c.push_new(0, 2);
        c.replace_new(0, 0, 42);
        assert_eq!(c.new_slice(0), &[42, 2]);
        c.push_old(0, 7);
        c.replace_old(0, 0, 8);
        assert_eq!(c.old_slice(0), &[8]);
    }

    #[test]
    fn set_bulk() {
        let mut c = CandidateLists::new(2, 4);
        c.set_new(1, &[3, 4, 5]);
        c.set_old(1, &[6]);
        assert_eq!(c.new_slice(1), &[3, 4, 5]);
        assert_eq!(c.old_slice(1), &[6]);
    }
}
