//! Bounded per-node candidate lists (selection-step output).
//!
//! Flat `n × cap` storage — no per-node allocation, reused across
//! iterations. "New" candidates are those carrying the incremental-
//! search flag; "old" are established neighbors. The compute step
//! evaluates new×new and new×old pairs (old×old were compared in an
//! earlier iteration).

/// Flat candidate lists for all nodes.
#[derive(Debug, Clone)]
pub struct CandidateLists {
    n: usize,
    cap: usize,
    new_ids: Vec<u32>,
    new_len: Vec<u16>,
    old_ids: Vec<u32>,
    old_len: Vec<u16>,
}

impl CandidateLists {
    /// Allocate for `n` nodes with per-list capacity `cap`.
    pub fn new(n: usize, cap: usize) -> Self {
        assert!(cap >= 1 && cap <= u16::MAX as usize);
        Self {
            n,
            cap,
            new_ids: vec![0; n * cap],
            new_len: vec![0; n],
            old_ids: vec![0; n * cap],
            old_len: vec![0; n],
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Clear all lists (O(n), lengths only).
    pub fn clear(&mut self) {
        self.new_len.fill(0);
        self.old_len.fill(0);
    }

    /// New-candidate slice of node `u`.
    #[inline]
    pub fn new_slice(&self, u: usize) -> &[u32] {
        &self.new_ids[u * self.cap..u * self.cap + self.new_len[u] as usize]
    }

    /// Old-candidate slice of node `u`.
    #[inline]
    pub fn old_slice(&self, u: usize) -> &[u32] {
        &self.old_ids[u * self.cap..u * self.cap + self.old_len[u] as usize]
    }

    /// Append `v` to `u`'s new list; returns false when full.
    #[inline]
    pub fn push_new(&mut self, u: usize, v: u32) -> bool {
        let len = self.new_len[u] as usize;
        if len >= self.cap {
            return false;
        }
        self.new_ids[u * self.cap + len] = v;
        self.new_len[u] = (len + 1) as u16;
        true
    }

    /// Append `v` to `u`'s old list; returns false when full.
    #[inline]
    pub fn push_old(&mut self, u: usize, v: u32) -> bool {
        let len = self.old_len[u] as usize;
        if len >= self.cap {
            return false;
        }
        self.old_ids[u * self.cap + len] = v;
        self.old_len[u] = (len + 1) as u16;
        true
    }

    /// Overwrite slot `slot` of `u`'s new list (reservoir replacement;
    /// list must already contain `slot`).
    #[inline]
    pub fn replace_new(&mut self, u: usize, slot: usize, v: u32) {
        debug_assert!(slot < self.new_len[u] as usize);
        self.new_ids[u * self.cap + slot] = v;
    }

    /// Overwrite slot `slot` of `u`'s old list.
    #[inline]
    pub fn replace_old(&mut self, u: usize, slot: usize, v: u32) {
        debug_assert!(slot < self.old_len[u] as usize);
        self.old_ids[u * self.cap + slot] = v;
    }

    #[inline]
    pub fn new_len(&self, u: usize) -> usize {
        self.new_len[u] as usize
    }

    #[inline]
    pub fn old_len(&self, u: usize) -> usize {
        self.old_len[u] as usize
    }

    /// Direct store into the new list at `idx` and set length (heap
    /// selector finalization).
    pub(crate) fn set_new(&mut self, u: usize, ids: &[u32]) {
        debug_assert!(ids.len() <= self.cap);
        self.new_ids[u * self.cap..u * self.cap + ids.len()].copy_from_slice(ids);
        self.new_len[u] = ids.len() as u16;
    }

    pub(crate) fn set_old(&mut self, u: usize, ids: &[u32]) {
        debug_assert!(ids.len() <= self.cap);
        self.old_ids[u * self.cap..u * self.cap + ids.len()].copy_from_slice(ids);
        self.old_len[u] = ids.len() as u16;
    }

    /// Base address of the new-id array (for the cache-sim trace).
    pub fn new_ids_addr(&self) -> usize {
        self.new_ids.as_ptr() as usize
    }

    /// Base address of the old-id array.
    pub fn old_ids_addr(&self) -> usize {
        self.old_ids.as_ptr() as usize
    }

    /// Total candidates across all nodes (diagnostics).
    pub fn total(&self) -> usize {
        self.new_len.iter().map(|&l| l as usize).sum::<usize>()
            + self.old_len.iter().map(|&l| l as usize).sum::<usize>()
    }

    /// Clear all lists and split the storage into per-range mutable
    /// chunks — one per entry of `bounds`, which must be ascending,
    /// disjoint, and cover `0..n` exactly. Node ranges map to contiguous
    /// slices of the flat arrays, so the chunks borrow disjoint storage
    /// and can be handed to different worker threads (the parallel
    /// selection phase's write decomposition).
    pub(crate) fn split_ranges(&mut self, bounds: &[std::ops::Range<usize>]) -> Vec<CandChunk<'_>> {
        self.clear();
        let cap = self.cap;
        let mut out = Vec::with_capacity(bounds.len());
        let mut new_ids: &mut [u32] = &mut self.new_ids;
        let mut new_len: &mut [u16] = &mut self.new_len;
        let mut old_ids: &mut [u32] = &mut self.old_ids;
        let mut old_len: &mut [u16] = &mut self.old_len;
        let mut prev = 0usize;
        for r in bounds {
            assert_eq!(r.start, prev, "ranges must be ascending and gap-free");
            let len = r.end - r.start;
            let (ni, rest) = std::mem::take(&mut new_ids).split_at_mut(len * cap);
            new_ids = rest;
            let (nl, rest) = std::mem::take(&mut new_len).split_at_mut(len);
            new_len = rest;
            let (oi, rest) = std::mem::take(&mut old_ids).split_at_mut(len * cap);
            old_ids = rest;
            let (ol, rest) = std::mem::take(&mut old_len).split_at_mut(len);
            old_len = rest;
            out.push(CandChunk {
                range: r.clone(),
                cap,
                new_ids: ni,
                new_len: nl,
                old_ids: oi,
                old_len: ol,
            });
            prev = r.end;
        }
        assert_eq!(prev, self.n, "ranges must cover every node");
        out
    }
}

/// Mutable view over one contiguous node range of a [`CandidateLists`]:
/// the same bounded-list operations, restricted to `range` so disjoint
/// chunks can be written concurrently. Indices are *global* node ids —
/// the chunk translates internally.
#[derive(Debug)]
pub(crate) struct CandChunk<'a> {
    range: std::ops::Range<usize>,
    cap: usize,
    new_ids: &'a mut [u32],
    new_len: &'a mut [u16],
    old_ids: &'a mut [u32],
    old_len: &'a mut [u16],
}

impl CandChunk<'_> {
    /// The global node range this chunk owns.
    pub(crate) fn range(&self) -> std::ops::Range<usize> {
        self.range.clone()
    }

    #[inline]
    fn local(&self, u: usize) -> usize {
        debug_assert!(self.range.contains(&u), "node {u} outside chunk {:?}", self.range);
        u - self.range.start
    }

    #[inline]
    pub(crate) fn new_slice(&self, u: usize) -> &[u32] {
        let l = self.local(u);
        &self.new_ids[l * self.cap..l * self.cap + self.new_len[l] as usize]
    }

    #[inline]
    pub(crate) fn old_slice(&self, u: usize) -> &[u32] {
        let l = self.local(u);
        &self.old_ids[l * self.cap..l * self.cap + self.old_len[l] as usize]
    }

    #[inline]
    pub(crate) fn new_len(&self, u: usize) -> usize {
        self.new_len[self.local(u)] as usize
    }

    #[inline]
    pub(crate) fn old_len(&self, u: usize) -> usize {
        self.old_len[self.local(u)] as usize
    }

    /// Append `v` to `u`'s new list; returns false when full.
    #[inline]
    pub(crate) fn push_new(&mut self, u: usize, v: u32) -> bool {
        let l = self.local(u);
        let len = self.new_len[l] as usize;
        if len >= self.cap {
            return false;
        }
        self.new_ids[l * self.cap + len] = v;
        self.new_len[l] = (len + 1) as u16;
        true
    }

    /// Append `v` to `u`'s old list; returns false when full.
    #[inline]
    pub(crate) fn push_old(&mut self, u: usize, v: u32) -> bool {
        let l = self.local(u);
        let len = self.old_len[l] as usize;
        if len >= self.cap {
            return false;
        }
        self.old_ids[l * self.cap + len] = v;
        self.old_len[l] = (len + 1) as u16;
        true
    }

    /// Overwrite slot `slot` of `u`'s new list (reservoir replacement).
    #[inline]
    pub(crate) fn replace_new(&mut self, u: usize, slot: usize, v: u32) {
        let l = self.local(u);
        debug_assert!(slot < self.new_len[l] as usize);
        self.new_ids[l * self.cap + slot] = v;
    }

    /// Overwrite slot `slot` of `u`'s old list.
    #[inline]
    pub(crate) fn replace_old(&mut self, u: usize, slot: usize, v: u32) {
        let l = self.local(u);
        debug_assert!(slot < self.old_len[l] as usize);
        self.old_ids[l * self.cap + slot] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_bounds() {
        let mut c = CandidateLists::new(3, 2);
        assert!(c.push_new(0, 5));
        assert!(c.push_new(0, 6));
        assert!(!c.push_new(0, 7), "full");
        assert_eq!(c.new_slice(0), &[5, 6]);
        assert_eq!(c.new_slice(1), &[] as &[u32]);
        assert!(c.push_old(2, 9));
        assert_eq!(c.old_slice(2), &[9]);
        assert_eq!(c.total(), 3);
    }

    #[test]
    fn clear_resets_lengths() {
        let mut c = CandidateLists::new(2, 4);
        c.push_new(0, 1);
        c.push_old(1, 2);
        c.clear();
        assert_eq!(c.total(), 0);
        assert_eq!(c.new_slice(0), &[] as &[u32]);
    }

    #[test]
    fn replace_slots() {
        let mut c = CandidateLists::new(1, 3);
        c.push_new(0, 1);
        c.push_new(0, 2);
        c.replace_new(0, 0, 42);
        assert_eq!(c.new_slice(0), &[42, 2]);
        c.push_old(0, 7);
        c.replace_old(0, 0, 8);
        assert_eq!(c.old_slice(0), &[8]);
    }

    #[test]
    fn set_bulk() {
        let mut c = CandidateLists::new(2, 4);
        c.set_new(1, &[3, 4, 5]);
        c.set_old(1, &[6]);
        assert_eq!(c.new_slice(1), &[3, 4, 5]);
        assert_eq!(c.old_slice(1), &[6]);
    }

    #[test]
    fn split_ranges_gives_disjoint_global_indexed_chunks() {
        let mut c = CandidateLists::new(10, 3);
        c.push_new(0, 99); // split must clear leftovers from prior use
        {
            let mut chunks = c.split_ranges(&[0..4, 4..7, 7..10]);
            assert_eq!(chunks.len(), 3);
            assert_eq!(chunks[1].range(), 4..7);
            // writes through a chunk use global node ids
            assert!(chunks[0].push_new(0, 5));
            assert!(chunks[1].push_new(4, 8));
            assert!(chunks[1].push_old(6, 2));
            assert!(chunks[2].push_new(9, 1));
            // cap respected per list
            assert!(chunks[2].push_old(7, 1) && chunks[2].push_old(7, 2) && chunks[2].push_old(7, 3));
            assert!(!chunks[2].push_old(7, 4), "full");
            chunks[2].replace_old(7, 1, 6);
            assert_eq!(chunks[2].old_slice(7), &[1, 6, 3]);
            assert_eq!(chunks[1].new_len(4), 1);
            assert_eq!(chunks[1].old_len(4), 0);
        }
        // the writes landed in the parent structure at the same ids
        assert_eq!(c.new_slice(0), &[5]);
        assert_eq!(c.new_slice(4), &[8]);
        assert_eq!(c.old_slice(6), &[2]);
        assert_eq!(c.new_slice(9), &[1]);
        assert_eq!(c.old_slice(7), &[1, 6, 3]);
        assert_eq!(c.new_slice(1), &[] as &[u32], "split cleared the stale entry");
    }

    #[test]
    #[should_panic(expected = "cover every node")]
    fn split_ranges_rejects_partial_cover() {
        let mut c = CandidateLists::new(6, 2);
        let _ = c.split_ranges(&[0..3]);
    }
}
