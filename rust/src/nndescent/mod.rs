//! NN-Descent (Dong et al., WWW'11) with the paper's single-core
//! optimizations.
//!
//! The algorithm alternates two steps until convergence (paper §2):
//!
//! 1. **Selection** ([`selection`]) — per node, gather a bounded sample
//!    of "new"/"old" candidates from forward and reverse edges of the
//!    current approximation. Three implementations with identical
//!    semantics but very different constants: `naive` (three passes,
//!    unbounded reverse lists), `heap` (PyNNDescent's fused one-pass,
//!    ≈16×), `turbo` (the paper's heap-free counter sampling, ≈1.12×
//!    more).
//! 2. **Compute** ([`compute`]) — evaluate candidate pairs' distances
//!    (new×new and new×old) and push improvements into both endpoint
//!    heaps.
//!
//! Optionally, after the first iteration, the **greedy reordering
//! heuristic** ([`reorder`], paper §3.2 Algorithm 1) permutes the data
//! matrix and graph so data-space neighbors become memory neighbors.
//!
//! [`driver::NnDescent`] owns the loop, timing, convergence, and the
//! permutation bookkeeping. With [`Params::threads`] > 1 (or
//! `PALLAS_BUILD_THREADS` set) the driver routes the build through the
//! phased multi-threaded engine in [`parallel`]; `threads = 1` stays on
//! the bit-exact sequential path.

pub mod candidates;
pub mod compute;
pub mod driver;
pub mod init;
pub mod observer;
pub mod parallel;
pub mod params;
pub mod reorder;
pub mod reorder_alt;
pub mod selection;

pub use candidates::CandidateLists;
pub use driver::{BuildResult, NnDescent, RepairStats};
pub use observer::{BuildEvent, BuildObserver, FnObserver, LoggingObserver, NoopObserver};
pub use parallel::{effective_build_threads, resolve_build_threads};
pub use params::Params;
