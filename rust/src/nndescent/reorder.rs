//! Greedy memory-reordering heuristic (paper §3.2, Algorithm 1).
//!
//! After the first NN-Descent iteration the graph approximation is good
//! enough that "graph neighbor" correlates strongly with "data-space
//! neighbor". Under the clustered assumption, a single greedy pass can
//! then recover most clusters: walk positions left to right; for the
//! node occupying position `i`, place its nearest not-yet-placed graph
//! neighbor at position `i+1`. The result is a permutation σ (node id →
//! memory position) used to physically reorder the data matrix, graph,
//! and ancillary arrays all at once.
//!
//! σ and σ⁻¹ are maintained together so no inversion pass is needed —
//! one pass over the K-NN graph total, as required by the paper.
//!
//! Note on the pseudocode: Algorithm 1 writes `a_i ← sorted(adj_G(i))`.
//! Taken literally (adjacency of *node id* `i`) the heuristic would not
//! chain through clusters, because after the first swap node `i` no
//! longer occupies position `i`. The text ("whichever node σ maps onto
//! i+1 should be close in data space to node i", where positions are
//! being filled in order) and the reported behaviour (Fig 4: clusters
//! recovered contiguously) require the adjacency of the node *currently
//! at position i*, i.e. `adj_G(σ⁻¹(i))`. We implement that reading; at
//! i = 0 (σ = id) the two coincide.

use crate::cachesim::trace::Tracer;
use crate::graph::heap::EMPTY_ID;
use crate::graph::KnnGraph;

/// Result of the greedy pass: σ (node → position) and σ⁻¹.
#[derive(Debug, Clone)]
pub struct Reordering {
    /// σ: `sigma[v]` = memory position assigned to node `v`.
    pub sigma: Vec<u32>,
    /// σ⁻¹: `inv[p]` = node assigned to memory position `p`.
    pub inv: Vec<u32>,
}

impl Reordering {
    /// Validate that σ and σ⁻¹ are mutually inverse permutations.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.sigma.len();
        if self.inv.len() != n {
            return Err("length mismatch".into());
        }
        for v in 0..n {
            let p = self.sigma[v] as usize;
            if p >= n || self.inv[p] as usize != v {
                return Err(format!("σ/σ⁻¹ inconsistent at node {v}"));
            }
        }
        Ok(())
    }
}

/// Algorithm 1: one pass over the K-NN graph, producing σ.
pub fn greedy_permutation<T: Tracer>(graph: &KnnGraph, tracer: &mut T) -> Reordering {
    let n = graph.n();
    let k = graph.k();
    let mut sigma: Vec<u32> = (0..n as u32).collect();
    let mut inv: Vec<u32> = (0..n as u32).collect();
    // scratch for one node's sorted adjacency
    let mut adj: Vec<(f32, u32)> = Vec::with_capacity(k);

    for i in 0..n.saturating_sub(1) {
        // the node currently occupying position i (see module docs)
        let u = inv[i] as usize;
        tracer.read(graph.ids(u).as_ptr() as usize, (k * 4) as u32);
        tracer.read(graph.dists(u).as_ptr() as usize, (k * 4) as u32);
        adj.clear();
        for (&v, &d) in graph.ids(u).iter().zip(graph.dists(u)) {
            if v != EMPTY_ID {
                adj.push((d, v));
            }
        }
        adj.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));

        for &(_, cand) in adj.iter() {
            let pos = sigma[cand as usize] as usize;
            if pos < i + 1 {
                // already well placed (closer to the front) — try next
                continue;
            }
            if pos == i + 1 {
                // already exactly where we want it
                break;
            }
            // move `cand` to position i+1 via the paired swap:
            // swap σ entries of `cand` and σ⁻¹(i+1); mirror in σ⁻¹.
            let displaced = inv[i + 1] as usize; // node currently at i+1
            sigma.swap(cand as usize, displaced);
            inv.swap(i + 1, pos);
            tracer.write(sigma.as_ptr() as usize + cand as usize * 4, 4);
            tracer.write(sigma.as_ptr() as usize + displaced * 4, 4);
            tracer.write(inv.as_ptr() as usize + (i + 1) * 4, 4);
            tracer.write(inv.as_ptr() as usize + pos * 4, 4);
            break;
        }
    }
    Reordering { sigma, inv }
}

/// Segment length of the parallel reorder pass: segments this size keep
/// the greedy chain long enough to recover clusters (paper Fig 4 uses
/// corpora well under this per cluster) while giving big corpora real
/// parallelism. Fixed — never derived from the thread count — so the
/// permutation is thread-count invariant.
pub const REORDER_SEGMENT_LEN: usize = 4096;

/// One segment's greedy pass, restricted to the ids *and* positions in
/// `[lo, hi)`: the walk is Algorithm 1 verbatim except that adjacency
/// entries outside the segment are ignored (their positions belong to
/// other segments and must not move). Returns segment-local σ and σ⁻¹
/// (`sigma[j]` = local position of node `lo + j`). With `lo = 0,
/// hi = n` the swap sequence is *identical* to [`greedy_permutation`].
fn segment_pass(graph: &KnnGraph, lo: usize, hi: usize) -> (Vec<u32>, Vec<u32>) {
    let len = hi - lo;
    let mut sigma: Vec<u32> = (0..len as u32).collect();
    let mut inv: Vec<u32> = (0..len as u32).collect();
    let mut adj: Vec<(f32, u32)> = Vec::with_capacity(graph.k());

    for i in 0..len.saturating_sub(1) {
        let u = lo + inv[i] as usize;
        adj.clear();
        for (&v, &d) in graph.ids(u).iter().zip(graph.dists(u)) {
            if v != EMPTY_ID && (v as usize) >= lo && (v as usize) < hi {
                adj.push((d, v));
            }
        }
        adj.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));

        for &(_, cand) in adj.iter() {
            let cl = cand as usize - lo;
            let pos = sigma[cl] as usize;
            if pos < i + 1 {
                continue;
            }
            if pos == i + 1 {
                break;
            }
            let displaced = inv[i + 1] as usize;
            sigma.swap(cl, displaced);
            inv.swap(i + 1, pos);
            break;
        }
    }
    (sigma, inv)
}

/// Parallel greedy reorder: cut the id/position space into fixed
/// [`REORDER_SEGMENT_LEN`] segments, run [`segment_pass`] on each
/// (`threads` workers, contiguous segment groups), and stitch the local
/// permutations back into one global σ/σ⁻¹ (segments never exchange
/// positions, so the stitch is a plain offset shift).
///
/// Corpora with `n ≤` [`REORDER_SEGMENT_LEN`] form a single segment, so
/// the result is **bit-identical** to the sequential
/// [`greedy_permutation`] there — which keeps the T>1 engine's output
/// unchanged for every corpus the determinism tests pin. Larger corpora
/// lose only the cross-segment chain links (at most one boundary per
/// 4096 positions); within each segment the cluster-recovery behaviour
/// is the sequential heuristic's.
pub fn greedy_permutation_segmented(
    graph: &KnnGraph,
    seg_len: usize,
    threads: usize,
) -> Reordering {
    assert!(seg_len >= 1, "segments must hold at least one position");
    let n = graph.n();
    let segs: Vec<(usize, usize)> =
        (0..n).step_by(seg_len).map(|lo| (lo, (lo + seg_len).min(n))).collect();

    let locals: Vec<(Vec<u32>, Vec<u32>)> = if threads <= 1 || segs.len() <= 1 {
        segs.iter().map(|&(lo, hi)| segment_pass(graph, lo, hi)).collect()
    } else {
        let workers = threads.min(segs.len());
        let mut groups: Vec<Vec<usize>> = (0..workers).map(|_| Vec::new()).collect();
        for si in 0..segs.len() {
            groups[si * workers / segs.len()].push(si);
        }
        let mut slots: Vec<Option<(Vec<u32>, Vec<u32>)>> = Vec::new();
        slots.resize_with(segs.len(), || None);
        std::thread::scope(|s| {
            let handles: Vec<_> = groups
                .into_iter()
                .map(|group| {
                    let segs = &segs;
                    s.spawn(move || {
                        group
                            .into_iter()
                            .map(|si| {
                                let (lo, hi) = segs[si];
                                (si, segment_pass(graph, lo, hi))
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            for h in handles {
                for (si, local) in h.join().expect("reorder worker panicked") {
                    slots[si] = Some(local);
                }
            }
        });
        slots.into_iter().map(|s| s.expect("every segment computed")).collect()
    };

    let mut sigma = vec![0u32; n];
    let mut inv = vec![0u32; n];
    for (&(lo, _), (ls, li)) in segs.iter().zip(&locals) {
        for (j, &p) in ls.iter().enumerate() {
            sigma[lo + j] = (lo + p as usize) as u32;
        }
        for (i, &v) in li.iter().enumerate() {
            inv[lo + i] = (lo + v as usize) as u32;
        }
    }
    Reordering { sigma, inv }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::trace::NoTracer;
    use crate::config::schema::{ComputeKind, SelectionKind};
    use crate::dataset::clustered::SynthClustered;
    use crate::nndescent::{NnDescent, Params};

    fn graph_for(n: usize, clusters: usize, seed: u64) -> (KnnGraph, Vec<u32>) {
        let g = SynthClustered::new(n, 8, clusters, seed);
        let (data, labels) = g.generate_labeled();
        let params = Params::default()
            .with_k(10)
            .with_seed(seed)
            .with_selection(SelectionKind::Turbo)
            .with_compute(ComputeKind::Blocked)
            .with_max_iters(2); // early approximation, like the real use
        let result = NnDescent::new(params).build(&data).unwrap();
        (result.graph, labels)
    }

    #[test]
    fn produces_valid_permutation() {
        let (graph, _) = graph_for(400, 4, 3);
        let r = greedy_permutation(&graph, &mut NoTracer);
        r.validate().unwrap();
        let mut seen = vec![false; 400];
        for &p in &r.sigma {
            assert!(!seen[p as usize]);
            seen[p as usize] = true;
        }
    }

    #[test]
    fn recovers_cluster_contiguity() {
        // After reordering, adjacent memory positions should mostly hold
        // same-cluster nodes (paper Fig 4) — far above the random
        // baseline of 1/c.
        let clusters = 8;
        let (graph, labels) = graph_for(1600, clusters, 7);
        let r = greedy_permutation(&graph, &mut NoTracer);
        r.validate().unwrap();
        let same_adjacent = (0..1599)
            .filter(|&p| labels[r.inv[p] as usize] == labels[r.inv[p + 1] as usize])
            .count();
        let frac = same_adjacent as f64 / 1599.0;
        let random_baseline = 1.0 / clusters as f64;
        assert!(
            frac > 3.0 * random_baseline,
            "cluster contiguity {frac:.3} not much better than random {random_baseline:.3}"
        );
    }

    #[test]
    fn identity_on_degenerate_graph() {
        // A graph with no edges (all EMPTY) must leave σ = id.
        let graph = KnnGraph::new(10, 3);
        let r = greedy_permutation(&graph, &mut NoTracer);
        assert_eq!(r.sigma, (0..10).collect::<Vec<u32>>());
    }

    #[test]
    fn single_segment_matches_sequential_exactly() {
        // n ≤ seg_len ⇒ one segment ⇒ the identical swap sequence
        let (graph, _) = graph_for(800, 4, 9);
        let seq = greedy_permutation(&graph, &mut NoTracer);
        for threads in [1usize, 4] {
            let seg = greedy_permutation_segmented(&graph, REORDER_SEGMENT_LEN, threads);
            assert_eq!(seq.sigma, seg.sigma, "threads={threads}");
            assert_eq!(seq.inv, seg.inv, "threads={threads}");
        }
    }

    #[test]
    fn segmented_is_a_valid_thread_invariant_permutation() {
        // force many segments with a small seg_len: still a valid
        // permutation, identical for every worker count, and each
        // segment's ids stay inside its own position range
        let (graph, _) = graph_for(1000, 4, 13);
        let seg_len = 128;
        let base = greedy_permutation_segmented(&graph, seg_len, 1);
        base.validate().unwrap();
        for threads in [2usize, 3, 8] {
            let other = greedy_permutation_segmented(&graph, seg_len, threads);
            assert_eq!(base.sigma, other.sigma, "threads={threads}");
            assert_eq!(base.inv, other.inv, "threads={threads}");
        }
        for (v, &p) in base.sigma.iter().enumerate() {
            assert_eq!(v / seg_len, p as usize / seg_len, "node {v} left its segment");
        }
    }

    #[test]
    fn segmented_keeps_cluster_contiguity() {
        // segment boundaries cost at most one adjacency per 4096 — the
        // recovery property must survive comfortably
        let clusters = 8;
        let (graph, labels) = graph_for(1600, clusters, 7);
        let r = greedy_permutation_segmented(&graph, 400, 4);
        r.validate().unwrap();
        let same_adjacent = (0..1599)
            .filter(|&p| labels[r.inv[p] as usize] == labels[r.inv[p + 1] as usize])
            .count();
        let frac = same_adjacent as f64 / 1599.0;
        assert!(
            frac > 2.0 / clusters as f64,
            "segmented contiguity {frac:.3} barely better than random"
        );
    }

    #[test]
    fn one_pass_cost() {
        // smoke: runtime linear-ish in n (no quadratic blowup) — run big
        // once to make accidental O(n²) obvious in test time.
        let (graph, _) = graph_for(4000, 16, 1);
        let t0 = std::time::Instant::now();
        let r = greedy_permutation(&graph, &mut NoTracer);
        assert!(t0.elapsed().as_secs_f64() < 1.0, "greedy pass too slow");
        r.validate().unwrap();
    }
}
