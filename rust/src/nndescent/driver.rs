//! The NN-Descent iteration driver: init → (select → [reorder] →
//! compute)* → converged. Owns timing, counters, convergence, and the
//! reordering bookkeeping.

use super::candidates::CandidateLists;
use super::compute::{compute_step, ComputeScratch, NativeEngine, PairwiseEngine};
use super::init::init_random;
use super::observer::{BuildEvent, BuildObserver, NoopObserver};
use super::params::Params;
use super::reorder::{greedy_permutation, Reordering};
use super::selection::Selector;
use crate::cachesim::trace::{NoTracer, Tracer};
use crate::config::schema::ComputeKind;
use crate::dataset::AlignedMatrix;
use crate::graph::KnnGraph;
use crate::util::counters::{FlopCounter, IterStats};
use crate::util::rng::Pcg64;
use crate::util::timer::Timer;

/// Outcome of a graph build.
#[derive(Debug)]
pub struct BuildResult {
    /// Final graph, in the *working* id space (permuted if `reorder`).
    pub graph: KnnGraph,
    /// Number of NN-Descent iterations executed.
    pub iterations: usize,
    /// Per-iteration timing/work breakdown (paper Fig 5 data).
    pub per_iter: Vec<IterStats>,
    /// Total distance-evaluation / flop accounting (paper's W(n)).
    pub stats: FlopCounter,
    /// σ: original node id → working id (present iff reorder ran).
    pub reordering: Option<Reordering>,
    /// Wall time of the whole build, seconds.
    pub total_secs: f64,
}

impl BuildResult {
    /// Neighbor ids of original node `u`, mapped back to original ids
    /// and sorted ascending by distance.
    pub fn neighbors_original(&self, u: usize) -> Vec<(u32, f32)> {
        match &self.reordering {
            None => self.graph.sorted(u),
            Some(r) => {
                let wu = r.sigma[u] as usize;
                self.graph
                    .sorted(wu)
                    .into_iter()
                    .map(|(v, d)| (r.inv[v as usize], d))
                    .collect()
            }
        }
    }

    /// Total updates across iterations.
    pub fn total_updates(&self) -> u64 {
        self.per_iter.iter().map(|s| s.updates).sum()
    }

    /// `data_original` brought into this build's *working* layout: row
    /// `w` becomes original row σ⁻¹(w) when the build reordered, the
    /// matrix passes through untouched otherwise. The single home of
    /// the permute-to-working convention (facade and bundle both use
    /// it), so graph and data can never disagree about the layout.
    pub fn working_data(&self, data_original: AlignedMatrix) -> AlignedMatrix {
        match &self.reordering {
            Some(r) => data_original.permuted(&r.inv),
            None => data_original,
        }
    }

    /// Borrowing [`working_data`](Self::working_data): always produces
    /// a fresh matrix (permuted copy, or a plain clone when the build
    /// did not reorder).
    pub fn working_data_ref(&self, data_original: &AlignedMatrix) -> AlignedMatrix {
        match &self.reordering {
            Some(r) => data_original.permuted(&r.inv),
            None => data_original.clone(),
        }
    }
}

/// Outcome of a bounded [`NnDescent::repair`] pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct RepairStats {
    /// Repair iterations executed (≤ the budget).
    pub iterations: usize,
    /// Distance evaluations across the pass.
    pub dist_evals: u64,
    /// Graph updates across the pass.
    pub updates: u64,
    /// True when the pass hit the δ·n·k convergence threshold before
    /// exhausting its budget.
    pub converged: bool,
    /// Wall time, seconds.
    pub secs: f64,
}

/// NN-Descent builder. Construct with [`Params`], call [`build`].
///
/// [`build`]: NnDescent::build
#[derive(Debug, Clone)]
pub struct NnDescent {
    params: Params,
}

impl NnDescent {
    pub fn new(params: Params) -> Self {
        Self { params }
    }

    pub fn params(&self) -> &Params {
        &self.params
    }

    /// Build with the configured native backend. Fails (instead of the
    /// historical panic) when params ask for the `pjrt` backend, which
    /// needs an explicit engine — use [`build_with_engine`] for that, or
    /// the [`api::IndexBuilder`] facade which routes both cases.
    ///
    /// [`build_with_engine`]: NnDescent::build_with_engine
    /// [`api::IndexBuilder`]: crate::api::IndexBuilder
    pub fn build(&self, data: &AlignedMatrix) -> crate::Result<BuildResult> {
        self.build_observed(data, &mut NoopObserver)
    }

    /// Like [`build`], reporting progress through a [`BuildObserver`].
    ///
    /// When the resolved thread count ([`Params::threads`], then the
    /// `PALLAS_BUILD_THREADS` environment, then 1) exceeds 1, the build
    /// runs on the phased multi-threaded engine
    /// ([`parallel`](super::parallel)); `T = 1` takes the unchanged
    /// sequential path below, so single-threaded builds stay
    /// bit-identical across versions of this knob.
    ///
    /// [`build`]: NnDescent::build
    pub fn build_observed(
        &self,
        data: &AlignedMatrix,
        observer: &mut dyn BuildObserver,
    ) -> crate::Result<BuildResult> {
        anyhow::ensure!(
            self.params.compute != ComputeKind::Pjrt,
            "pjrt backend needs an engine: enable the `pjrt` cargo feature and use \
             build_with_engine(runtime::PjrtEngine); native builds use scalar|unrolled|blocked"
        );
        let threads = super::parallel::effective_build_threads(&self.params, data.n());
        if threads > 1 {
            // The parallel engine implements exactly one sampling
            // scheme (the paper's turbosampling). Substituting it for a
            // requested naive/heap run would silently change the
            // algorithm under test, so those ablation selections keep
            // their configured (sequential) implementation instead.
            if self.params.selection == crate::config::schema::SelectionKind::Turbo {
                return Ok(super::parallel::build(&self.params, data, threads, observer));
            }
            crate::log_info!(
                "build threads={threads} requested, but selection `{}` has no parallel \
                 implementation (only turbo does) — running the sequential engine",
                self.params.selection.name()
            );
        }
        let mut engine = NativeEngine::new(self.params.compute);
        Ok(self.build_with_engine_observed(data, &mut engine, &mut NoTracer, observer))
    }

    /// Build with an explicit pairwise engine and memory tracer.
    pub fn build_with_engine<E: PairwiseEngine, T: Tracer>(
        &self,
        data: &AlignedMatrix,
        engine: &mut E,
        tracer: &mut T,
    ) -> BuildResult {
        self.build_with_engine_observed(data, engine, tracer, &mut NoopObserver)
    }

    /// Build with an explicit pairwise engine, memory tracer, and
    /// progress observer — the fully-general *sequential* entry point.
    /// Explicit-engine builds (cache-simulation runs, the PJRT backend)
    /// always run single-threaded: an engine is `&mut` shared state and
    /// a tracer records a serial access stream, so [`Params::threads`]
    /// is deliberately ignored here (`build_observed` owns the parallel
    /// routing for native backends).
    pub fn build_with_engine_observed<E: PairwiseEngine, T: Tracer>(
        &self,
        data: &AlignedMatrix,
        engine: &mut E,
        tracer: &mut T,
        observer: &mut dyn BuildObserver,
    ) -> BuildResult {
        let p = &self.params;
        let n = data.n();
        assert!(n >= 2, "need at least two points");
        let k = p.k.min(n - 1);
        let cap = p.cand_cap();

        let mut total = Timer::new();
        total.start();

        let mut rng = Pcg64::new_stream(p.seed, 0xD00D);
        let mut graph = KnnGraph::new(n, k);
        let mut counter = FlopCounter::new(data.dim());
        let mut selector = Selector::new(p.selection, n, cap);
        let mut cands = CandidateLists::new(n, cap);
        let mut scratch = ComputeScratch::new(cap);

        observer.on_event(&BuildEvent::Started { n, dim: data.dim(), k });
        init_random(&mut graph, data, &mut rng, &mut counter, tracer);

        // After a reorder we own the permuted matrix; start borrowed.
        let mut owned: Option<AlignedMatrix> = None;
        let mut reordering: Option<Reordering> = None;

        let mut per_iter = Vec::new();
        let threshold = (p.delta * n as f64 * k as f64) as u64;
        let mut iterations = 0;
        let mut converged = false;

        for it in 0..p.max_iters {
            iterations = it + 1;
            let mut stats = IterStats { iter: it, ..Default::default() };
            let active: &AlignedMatrix = owned.as_ref().unwrap_or(data);

            // ---- greedy reorder (once, before iteration `reorder_iter`) ----
            if p.reorder && it == p.reorder_iter && reordering.is_none() {
                let mut t = Timer::new();
                t.start();
                let r = greedy_permutation(&graph, tracer);
                // permute data (new row p = old row inv[p]) and graph
                let permuted = active.permuted(&r.inv);
                graph = graph.apply_permutation(&r.sigma);
                owned = Some(permuted);
                reordering = Some(r);
                t.stop();
                stats.reorder_secs = t.secs();
                observer.on_event(&BuildEvent::Reordered { secs: stats.reorder_secs });
            }
            let active: &AlignedMatrix = owned.as_ref().unwrap_or(data);

            // ---- selection -------------------------------------------------
            let mut t = Timer::new();
            t.start();
            selector.select(&mut graph, &mut rng, &mut cands, tracer);
            t.stop();
            stats.select_secs = t.secs();

            // ---- compute ---------------------------------------------------
            let evals_before = counter.dist_evals;
            let mut t = Timer::new();
            t.start();
            let updates =
                compute_step(&mut graph, active, &cands, engine, &mut counter, &mut scratch, tracer);
            t.stop();
            stats.compute_secs = t.secs();
            stats.dist_evals = counter.dist_evals - evals_before;
            stats.updates = updates;
            observer.on_event(&BuildEvent::from_iter_stats(&stats));
            per_iter.push(stats);

            if updates <= threshold {
                converged = true;
                break;
            }
        }

        total.stop();
        observer.on_event(&BuildEvent::Finished {
            iterations,
            converged,
            total_secs: total.secs(),
        });
        BuildResult {
            graph,
            iterations,
            per_iter,
            stats: counter,
            reordering,
            total_secs: total.secs(),
        }
    }

    /// Run at most `budget` NN-Descent iterations over an *existing*
    /// graph — the incremental half of a full build: no random init, no
    /// reorder, just select → compute until convergence or the budget
    /// runs out. The store engine's compactor seeds a fresh graph from
    /// the surviving edges of the old segment (new rows get random
    /// edges) and calls this instead of rebuilding from scratch.
    ///
    /// `graph` must cover exactly `data` (same `n`); its `k` is used
    /// as-is. Runs the sequential engine with the configured native
    /// backend; deterministic given ([`Params::seed`], the input graph).
    pub fn repair(
        &self,
        data: &AlignedMatrix,
        mut graph: KnnGraph,
        budget: usize,
    ) -> (KnnGraph, RepairStats) {
        let p = &self.params;
        let n = data.n();
        assert_eq!(graph.n(), n, "repair graph/data size mismatch");
        let k = graph.k();
        let cap = p.cand_cap();

        let mut total = Timer::new();
        total.start();

        // A distinct stream from the build's 0xD00D: repair draws must
        // not replay the build's sampling sequence.
        let mut rng = Pcg64::new_stream(p.seed, 0x4EFA12);
        let mut engine = NativeEngine::new(p.compute);
        let mut counter = FlopCounter::new(data.dim());
        let mut selector = Selector::new(p.selection, n, cap);
        let mut cands = CandidateLists::new(n, cap);
        let mut scratch = ComputeScratch::new(cap);

        let threshold = (p.delta * n as f64 * k as f64) as u64;
        let mut stats = RepairStats::default();
        for _ in 0..budget {
            stats.iterations += 1;
            selector.select(&mut graph, &mut rng, &mut cands, &mut NoTracer);
            let updates = compute_step(
                &mut graph,
                data,
                &cands,
                &mut engine,
                &mut counter,
                &mut scratch,
                &mut NoTracer,
            );
            stats.updates += updates;
            if updates <= threshold {
                stats.converged = true;
                break;
            }
        }

        total.stop();
        stats.dist_evals = counter.dist_evals;
        stats.secs = total.secs();
        (graph, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::brute::brute_force_knn;
    use crate::config::schema::SelectionKind;
    use crate::dataset::clustered::SynthClustered;
    use crate::dataset::synth::SynthGaussian;
    use crate::metrics::recall::recall_against_truth;

    fn build(
        data: &AlignedMatrix,
        sel: SelectionKind,
        comp: ComputeKind,
        reorder: bool,
        seed: u64,
    ) -> BuildResult {
        let params = Params::default()
            .with_k(10)
            .with_seed(seed)
            .with_selection(sel)
            .with_compute(comp)
            .with_reorder(reorder);
        NnDescent::new(params).build(data).unwrap()
    }

    #[test]
    fn converges_and_achieves_high_recall_all_variants() {
        // d=8 is the paper's low-dim synthetic setting; NN-Descent's
        // recall degrades with intrinsic dimension (d=16 iid Gaussian at
        // k=10 plateaus near 0.94 for all implementations — see dbg logs
        // in EXPERIMENTS.md), so the ≥0.95 gate uses d=8.
        let data = SynthGaussian::single(800, 8, 21).generate();
        let truth = brute_force_knn(&data, 10);
        for sel in [SelectionKind::Naive, SelectionKind::Heap, SelectionKind::Turbo] {
            for comp in [ComputeKind::Scalar, ComputeKind::Blocked] {
                let r = build(&data, sel, comp, false, 21);
                assert!(r.iterations >= 2, "{sel:?}/{comp:?}: suspiciously fast convergence");
                r.graph.validate().unwrap();
                let rec = recall_against_truth(&r, &truth);
                assert!(rec > 0.95, "{sel:?}/{comp:?}: recall {rec} < 0.95");
            }
        }
    }

    #[test]
    fn reorder_preserves_result_semantics() {
        let (data, _) = SynthClustered::new(600, 8, 6, 33).generate_labeled();
        let truth = brute_force_knn(&data, 10);
        let plain = build(&data, SelectionKind::Turbo, ComputeKind::Blocked, false, 5);
        let reordered = build(&data, SelectionKind::Turbo, ComputeKind::Blocked, true, 5);
        assert!(reordered.reordering.is_some(), "reorder must have run");
        reordered.reordering.as_ref().unwrap().validate().unwrap();
        let rp = recall_against_truth(&plain, &truth);
        let rr = recall_against_truth(&reordered, &truth);
        assert!(rr > 0.95, "reordered recall {rr}");
        assert!((rp - rr).abs() < 0.05, "reorder should not change quality: {rp} vs {rr}");
    }

    #[test]
    fn neighbors_original_maps_ids_back() {
        let (data, _) = SynthClustered::new(300, 8, 4, 9).generate_labeled();
        let r = build(&data, SelectionKind::Turbo, ComputeKind::Blocked, true, 9);
        let reord = r.reordering.as_ref().unwrap();
        for u in (0..300).step_by(37) {
            for (v, d) in r.neighbors_original(u) {
                // distance must match the original-space rows
                let expect =
                    crate::distance::sq_l2_unrolled(data.row(u), data.row(v as usize));
                assert!((d - expect).abs() < 1e-4, "u={u} v={v}: {d} vs {expect}");
            }
            // and working-space graph must agree through σ
            let wu = reord.sigma[u] as usize;
            assert_eq!(r.graph.sorted(wu).len(), r.neighbors_original(u).len());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let data = SynthGaussian::single(300, 8, 4).generate();
        let a = build(&data, SelectionKind::Turbo, ComputeKind::Blocked, false, 77);
        let b = build(&data, SelectionKind::Turbo, ComputeKind::Blocked, false, 77);
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.stats.dist_evals, b.stats.dist_evals);
        for u in 0..300 {
            assert_eq!(a.graph.sorted(u), b.graph.sorted(u));
        }
    }

    #[test]
    fn convergence_threshold_respected() {
        // δ = 0.9 → stop after the first iteration whose updates fall
        // below 0.9·n·k, i.e. almost immediately.
        let data = SynthGaussian::single(400, 8, 6).generate();
        let fast = NnDescent::new(Params::default().with_k(8).with_delta(0.9)).build(&data).unwrap();
        let slow =
            NnDescent::new(Params::default().with_k(8).with_delta(0.0001)).build(&data).unwrap();
        assert!(fast.iterations <= slow.iterations);
    }

    #[test]
    fn pjrt_without_engine_is_an_error_not_a_panic() {
        let data = SynthGaussian::single(100, 8, 2).generate();
        let nnd = NnDescent::new(Params::default().with_k(5).with_compute(ComputeKind::Pjrt));
        let err = nnd.build(&data).unwrap_err().to_string();
        assert!(err.contains("pjrt"), "unexpected error: {err}");
    }

    #[test]
    fn observer_sees_ordered_lifecycle_events() {
        use crate::nndescent::observer::FnObserver;
        let data = SynthGaussian::single(300, 8, 11).generate();
        let mut events: Vec<BuildEvent> = Vec::new();
        let params = Params::default().with_k(8).with_seed(11).with_reorder(true);
        let result = NnDescent::new(params)
            .build_observed(&data, &mut FnObserver(|e: &BuildEvent| events.push(*e)))
            .unwrap();

        assert!(matches!(events.first(), Some(BuildEvent::Started { n: 300, dim: 8, k: 8 })));
        assert!(matches!(events.last(), Some(BuildEvent::Finished { .. })));
        let iters: Vec<_> =
            events.iter().filter(|e| matches!(e, BuildEvent::Iteration { .. })).collect();
        assert_eq!(iters.len(), result.iterations, "one Iteration event per iteration");
        assert_eq!(
            events.iter().filter(|e| matches!(e, BuildEvent::Reordered { .. })).count(),
            1,
            "reorder runs exactly once"
        );
        // per-iteration events must mirror the returned stats
        for (e, s) in iters.iter().zip(&result.per_iter) {
            if let BuildEvent::Iteration { iter, updates, dist_evals, .. } = e {
                assert_eq!((*iter, *updates, *dist_evals), (s.iter, s.updates, s.dist_evals));
            }
        }
        if let Some(BuildEvent::Finished { iterations, total_secs, .. }) = events.last() {
            assert_eq!(*iterations, result.iterations);
            assert!((*total_secs - result.total_secs).abs() < 1e-9);
        }
    }

    #[test]
    fn empirical_cost_scales_subquadratically() {
        // Dong et al. report ~O(n^1.14) distance evals; allow generous
        // slack but reject anything close to quadratic.
        let mut ns = Vec::new();
        let mut evals = Vec::new();
        for &n in &[500usize, 1000, 2000, 4000] {
            let data = SynthGaussian::single(n, 8, 13).generate();
            let r = build(&data, SelectionKind::Turbo, ComputeKind::Scalar, false, 13);
            ns.push(n as f64);
            evals.push(r.stats.dist_evals as f64);
        }
        let (_, exponent) = crate::util::stats::powerlaw_fit(&ns, &evals);
        assert!(
            exponent < 1.6,
            "distance evals scale as n^{exponent:.2}; expected well below quadratic"
        );
    }
}
