//! Three-pass selection, faithful to Dong et al.'s pseudocode
//! (`nndescent-full` baseline; paper §3.1).
//!
//! Pass 1 (*reverse*): materialize the reverse graph G′ into per-node
//! dynamically grown vectors (the unbounded structure the paper calls
//! out as the problem — `adj_{G'}(u)` can reach n entries).
//! Pass 2 (*union*): N(u) = adj_G(u) ∪ adj_{G'}(u), deduplicated.
//! Pass 3 (*sample*): Fisher–Yates shuffle, truncate to ρ·k.
//!
//! Each pass walks the whole K-NN graph again, which is exactly why this
//! version loses: three full sweeps over ~n·k entries plus dynamic
//! allocation churn.

use super::super::candidates::CandidateLists;
use super::clear_sampled_flags;
use crate::cachesim::trace::Tracer;
use crate::graph::heap::EMPTY_ID;
use crate::graph::KnnGraph;
use crate::util::rng::Pcg64;

/// The naive selector deliberately keeps the pseudocode's structure:
/// *every* pass materializes its full intermediate result in freshly
/// grown memory before the next pass starts (this is precisely the cost
/// the paper's fused one-pass version eliminates — do not "optimize"
/// this implementation).
#[derive(Debug, Default)]
pub struct NaiveSelector;

impl NaiveSelector {
    pub fn new(_n: usize) -> Self {
        Self
    }

    pub fn select<T: Tracer>(
        &mut self,
        graph: &mut KnnGraph,
        rng: &mut Pcg64,
        out: &mut CandidateLists,
        tracer: &mut T,
    ) {
        let n = graph.n();
        let k = graph.k();
        out.clear();

        // Intermediate elements are full (id, dist, flag) tuples — Dong's
        // pseudocode copies whole neighborhood entries B[v] between
        // passes, tripling the traffic compared to bare ids.
        type Entry = (u32, f32, bool);
        const ENTRY: u32 = std::mem::size_of::<Entry>() as u32;

        // ---- pass 1: reverse — materialize G' = (V, E') ----------------------
        let mut rev_new: Vec<Vec<Entry>> = vec![Vec::new(); n];
        let mut rev_old: Vec<Vec<Entry>> = vec![Vec::new(); n];
        for u in 0..n {
            tracer.read(graph.ids(u).as_ptr() as usize, (k * 4) as u32);
            tracer.read(graph.flags(u).as_ptr() as usize, k as u32);
            for ((&v, &d), &f) in graph.ids(u).iter().zip(graph.dists(u)).zip(graph.flags(u)) {
                if v == EMPTY_ID {
                    continue;
                }
                let lst = if f { &mut rev_new[v as usize] } else { &mut rev_old[v as usize] };
                lst.push((u as u32, d, f));
                tracer.write(lst.as_ptr() as usize + (lst.len() - 1) * ENTRY as usize, ENTRY);
            }
        }

        // ---- pass 2: union — materialize N(u) for every node -----------------
        let mut union_new: Vec<Vec<Entry>> = vec![Vec::new(); n];
        let mut union_old: Vec<Vec<Entry>> = vec![Vec::new(); n];
        for u in 0..n {
            tracer.read(graph.ids(u).as_ptr() as usize, (k * 4) as u32);
            let (un, uo) = (&mut union_new[u], &mut union_old[u]);
            for ((&v, &d), &f) in graph.ids(u).iter().zip(graph.dists(u)).zip(graph.flags(u)) {
                if v == EMPTY_ID {
                    continue;
                }
                if f {
                    un.push((v, d, f));
                } else {
                    uo.push((v, d, f));
                }
            }
            tracer.read(rev_new[u].as_ptr() as usize, rev_new[u].len() as u32 * ENTRY);
            tracer.read(rev_old[u].as_ptr() as usize, rev_old[u].len() as u32 * ENTRY);
            un.extend_from_slice(&rev_new[u]);
            uo.extend_from_slice(&rev_old[u]);

            // set-union semantics: dedup by id, drop self, keep "new" on
            // conflict
            for list in [&mut *un, &mut *uo] {
                list.sort_unstable_by_key(|e| e.0);
                list.dedup_by_key(|e| e.0);
                if let Ok(pos) = list.binary_search_by_key(&(u as u32), |e| e.0) {
                    list.remove(pos);
                }
            }
            uo.retain(|e| un.binary_search_by_key(&e.0, |x| x.0).is_err());
            tracer.write(un.as_ptr() as usize, un.len() as u32 * ENTRY);
            tracer.write(uo.as_ptr() as usize, uo.len() as u32 * ENTRY);
        }

        // ---- pass 3: sample — uniform ρ·k subset of every N(u) ---------------
        let cap = out.cap();
        for u in 0..n {
            tracer.read(union_new[u].as_ptr() as usize, union_new[u].len() as u32 * ENTRY);
            tracer.read(union_old[u].as_ptr() as usize, union_old[u].len() as u32 * ENTRY);
            rng.shuffle(&mut union_new[u]);
            rng.shuffle(&mut union_old[u]);
            for e in union_new[u].iter().take(cap) {
                out.push_new(u, e.0);
                tracer.write(out.new_ids_addr() + (u * cap + out.new_len(u) - 1) * 4, 4);
            }
            for e in union_old[u].iter().take(cap) {
                out.push_old(u, e.0);
                tracer.write(out.old_ids_addr() + (u * cap + out.old_len(u) - 1) * 4, 4);
            }
        }

        clear_sampled_flags(graph, out, tracer);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::trace::NoTracer;
    use crate::dataset::synth::SynthGaussian;
    use crate::nndescent::init::init_random;
    use crate::util::counters::FlopCounter;

    #[test]
    fn reverse_pass_is_complete() {
        // Every forward edge (u,v) must make u a candidate source for v:
        // with cap >= n the sample step cannot drop anything, so v's new
        // list must contain u (first round: all edges flagged).
        let n = 40;
        let data = SynthGaussian::single(n, 8, 2).generate();
        let mut graph = KnnGraph::new(n, 4);
        let mut rng = Pcg64::new(3);
        init_random(&mut graph, &data, &mut rng, &mut FlopCounter::new(8), &mut NoTracer);
        let edges: Vec<(u32, u32)> = graph.edges().map(|(u, v, _)| (u, v)).collect();

        let mut sel = NaiveSelector::new(n);
        let mut out = CandidateLists::new(n, n); // cap = n → no sampling loss
        sel.select(&mut graph, &mut rng, &mut out, &mut NoTracer);
        for (u, v) in edges {
            assert!(
                out.new_slice(v as usize).contains(&u),
                "reverse edge {u}→{v} missing from {v}'s candidates"
            );
            assert!(out.new_slice(u as usize).contains(&v), "forward edge missing");
        }
    }

    #[test]
    fn sampling_bounds_lists() {
        let n = 100;
        let data = SynthGaussian::single(n, 8, 4).generate();
        let mut graph = KnnGraph::new(n, 10);
        let mut rng = Pcg64::new(5);
        init_random(&mut graph, &data, &mut rng, &mut FlopCounter::new(8), &mut NoTracer);
        let mut sel = NaiveSelector::new(n);
        let mut out = CandidateLists::new(n, 3);
        sel.select(&mut graph, &mut rng, &mut out, &mut NoTracer);
        for u in 0..n {
            assert!(out.new_slice(u).len() <= 3);
            assert!(out.old_slice(u).len() <= 3);
        }
    }
}
