//! Partitioned selection for the multi-threaded build: turbosampling
//! with counter-based randomness, restricted to a node range.
//!
//! The sequential selectors are inherently serial in two ways: they
//! draw from one PRNG stream (so the sample depends on visit order) and
//! they write candidate lists of *both* endpoints of every edge (so a
//! node-range partition of the scan still writes anywhere). This module
//! removes both obstacles:
//!
//! * **Counter-based coins** — every edge `(u, slot)` gets its own
//!   [`SplitMix64`] draw at stream position `u·k + slot`
//!   ([`SplitMix64::at`]), keyed by a per-iteration seed. Any worker
//!   computing any edge gets the same coins, so the sampled candidate
//!   sets are a pure function of `(seed, iteration, graph)` —
//!   independent of the thread count *and* of scheduling.
//! * **Owner-writes decomposition** — each worker scans the whole edge
//!   list (a cheap `n·k` id/flag sweep next to the distance work) but
//!   applies only the insertions whose **target** node falls in its
//!   range, writing through a disjoint [`CandChunk`]. Reservoir
//!   replacement slots are keyed by `(seed, target, #replacements)`,
//!   again counter-based, so they too are partition-invariant.
//!
//! The output contract matches the sequential selectors: new/old lists
//! bounded by `cap`, duplicates excluded, only graph-adjacent
//! candidates, every edge endpoint sampled with probability
//! `min(1, cap/|N(u)|)` per direction. This is the parallel engine's
//! only sampler (the paper's turbosampling scheme, its best variant);
//! builds configured with `naive`/`heap` selection keep their
//! configured algorithm and run sequentially instead — the driver never
//! silently substitutes the scheme under test.

use super::super::candidates::CandChunk;
use super::turbo::to_threshold;
use crate::graph::heap::EMPTY_ID;
use crate::graph::KnnGraph;
use crate::util::rng::SplitMix64;

/// Per-node inclusion thresholds for one iteration, computed once from
/// the graph's neighborhood-size counters and shared read-only with
/// every worker.
#[derive(Debug)]
pub(crate) struct SelectionThresholds {
    new: Vec<u32>,
    old: Vec<u32>,
}

impl SelectionThresholds {
    /// `O(n)` threshold pass over the counters (the turbosampling trick:
    /// the graph already knows every |N(u)|).
    pub(crate) fn compute(graph: &KnnGraph, cap: usize) -> Self {
        let n = graph.n();
        Self {
            new: (0..n).map(|u| to_threshold(cap, graph.new_size(u))).collect(),
            old: (0..n).map(|u| to_threshold(cap, graph.old_size(u))).collect(),
        }
    }
}

/// Per-iteration selection seed: one hop of a SplitMix64 stream keyed
/// by the build seed, so iterations draw disjoint coin sequences.
pub(crate) fn selection_seed(seed: u64, iter: usize) -> u64 {
    SplitMix64::at(seed ^ 0x5E1E_C7ED_BAD5_EED5, iter as u64).next_u64()
}

/// One worker's selection pass: scan every edge of the frozen graph in
/// global order, apply only the insertions targeting this chunk's
/// range. See the module docs for why this is deterministic and
/// thread-count invariant.
pub(crate) fn select_into_chunk(
    graph: &KnnGraph,
    thr: &SelectionThresholds,
    iter_seed: u64,
    chunk: &mut CandChunk<'_>,
) {
    let n = graph.n();
    let k = graph.k();
    let range = chunk.range();
    // replacement-draw counters, per target in range × {new, old}
    let mut repl_new = vec![0u32; range.len()];
    let mut repl_old = vec![0u32; range.len()];
    for u in 0..n {
        let u_in = range.contains(&u);
        for (slot, (&v, &f)) in graph.ids(u).iter().zip(graph.flags(u)).enumerate() {
            if v == EMPTY_ID {
                continue;
            }
            let v_in = range.contains(&(v as usize));
            if !u_in && !v_in {
                continue;
            }
            // one u64 draw per edge = both directions' coins, at the
            // edge's fixed stream position
            let r = SplitMix64::at(iter_seed, (u * k + slot) as u64).next_u64();
            let (r_fwd, r_rev) = (r as u32, (r >> 32) as u32);
            let (thr_u, thr_v) = if f {
                (thr.new[u], thr.new[v as usize])
            } else {
                (thr.old[u], thr.old[v as usize])
            };
            // forward direction: v into the lists of u
            if u_in && r_fwd < thr_u {
                insert(chunk, &mut repl_new, &mut repl_old, u, v, f, iter_seed);
            }
            // reverse direction: u into the lists of v
            if v_in && r_rev < thr_v {
                insert(chunk, &mut repl_new, &mut repl_old, v as usize, u as u32, f, iter_seed);
            }
        }
    }
}

/// Append-or-reservoir-replace with duplicate rejection — the
/// sequential turbo selector's `insert`, with the replacement slot
/// drawn from a counter-based stream keyed by (seed, target, list,
/// #replacements) so it does not depend on which worker runs it.
fn insert(
    chunk: &mut CandChunk<'_>,
    repl_new: &mut [u32],
    repl_old: &mut [u32],
    u: usize,
    v: u32,
    new: bool,
    iter_seed: u64,
) {
    let local = u - chunk.range().start;
    if new {
        if chunk.new_slice(u).contains(&v) {
            return;
        }
        if !chunk.push_new(u, v) {
            let slot = replacement_slot(iter_seed, u, true, repl_new[local], chunk.new_len(u));
            repl_new[local] += 1;
            chunk.replace_new(u, slot, v);
        }
    } else {
        if chunk.old_slice(u).contains(&v) {
            return;
        }
        if !chunk.push_old(u, v) {
            let slot = replacement_slot(iter_seed, u, false, repl_old[local], chunk.old_len(u));
            repl_old[local] += 1;
            chunk.replace_old(u, slot, v);
        }
    }
}

/// Uniform slot in `0..len` from a counter-based draw. Two SplitMix64
/// hops: the first decorrelates (seed, target, list), the second indexes
/// the replacement counter. Modulo bias over `len ≤ 25` is ≪ 2⁻²⁵.
#[inline]
fn replacement_slot(iter_seed: u64, target: usize, new: bool, count: u32, len: usize) -> usize {
    let stream = SplitMix64::at(iter_seed ^ 0x9E1E_C7_0000_0001, (target as u64) << 1 | new as u64)
        .next_u64();
    let r = SplitMix64::at(stream, count as u64).next_u64();
    (r % len as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::trace::NoTracer;
    use crate::dataset::synth::SynthGaussian;
    use crate::nndescent::candidates::CandidateLists;
    use crate::nndescent::init::init_random;
    use crate::nndescent::selection::clear_sampled_flags;
    use crate::util::counters::FlopCounter;
    use crate::util::rng::Pcg64;

    fn initialized(n: usize, k: usize, seed: u64) -> KnnGraph {
        let data = SynthGaussian::single(n, 8, seed).generate();
        let mut graph = KnnGraph::new(n, k);
        let mut rng = Pcg64::new(seed);
        init_random(&mut graph, &data, &mut rng, &mut FlopCounter::new(8), &mut NoTracer);
        graph
    }

    fn run_partitioned(graph: &KnnGraph, cap: usize, seed: u64, parts: usize) -> CandidateLists {
        let n = graph.n();
        let mut out = CandidateLists::new(n, cap);
        let thr = SelectionThresholds::compute(graph, cap);
        let iter_seed = selection_seed(seed, 0);
        let bounds: Vec<std::ops::Range<usize>> =
            (0..parts).map(|w| w * n / parts..(w + 1) * n / parts).collect();
        for mut chunk in out.split_ranges(&bounds) {
            select_into_chunk(graph, &thr, iter_seed, &mut chunk);
        }
        out
    }

    #[test]
    fn output_contract_matches_sequential_selectors() {
        let n = 300;
        let cap = 5;
        let mut graph = initialized(n, 10, 42);
        let out = run_partitioned(&graph, cap, 9, 4);
        let mut total_new = 0usize;
        for u in 0..n {
            let newc = out.new_slice(u);
            let oldc = out.old_slice(u);
            assert!(newc.len() <= cap && oldc.len() <= cap, "cap respected");
            total_new += newc.len();
            assert!(!newc.contains(&(u as u32)) && !oldc.contains(&(u as u32)), "self in list");
            for list in [newc, oldc] {
                let mut s = list.to_vec();
                s.sort_unstable();
                let before = s.len();
                s.dedup();
                assert_eq!(before, s.len(), "duplicates in node {u}: {list:?}");
            }
            for &v in newc {
                let fwd = graph.ids(u).contains(&v);
                let rev = graph.ids(v as usize).contains(&(u as u32));
                assert!(fwd || rev, "candidate {v} of {u} not adjacent");
            }
        }
        assert!(total_new > 0, "first-round selection must produce new candidates");
        // the driver's flag-clear pass composes with the output
        clear_sampled_flags(&mut graph, &out, &mut NoTracer);
        graph.validate().unwrap();
    }

    #[test]
    fn partitioning_does_not_change_the_sample() {
        // 1, 2, 3, and 7 ranges must produce byte-identical lists —
        // the property that makes T>1 builds thread-count invariant
        let graph = initialized(200, 8, 7);
        let reference = run_partitioned(&graph, 4, 11, 1);
        for parts in [2usize, 3, 7] {
            let got = run_partitioned(&graph, 4, 11, parts);
            for u in 0..200 {
                assert_eq!(reference.new_slice(u), got.new_slice(u), "parts={parts} node {u}");
                assert_eq!(reference.old_slice(u), got.old_slice(u), "parts={parts} node {u}");
            }
        }
    }

    #[test]
    fn different_iterations_draw_different_coins() {
        assert_ne!(selection_seed(1, 0), selection_seed(1, 1));
        assert_ne!(selection_seed(1, 0), selection_seed(2, 0));
        let graph = initialized(200, 8, 3);
        let a = run_partitioned(&graph, 4, selection_seed(5, 0), 2);
        let b = run_partitioned(&graph, 4, selection_seed(5, 1), 2);
        let differs = (0..200).any(|u| a.new_slice(u) != b.new_slice(u));
        assert!(differs, "two iterations should not sample identically");
    }

    #[test]
    fn small_neighborhoods_sample_everything() {
        // cap ≥ |N(u)| ⇒ p = 1 ⇒ every edge endpoint present (mod dups)
        let graph = initialized(30, 3, 4);
        let out = run_partitioned(&graph, 30, 6, 3);
        for (u, v, _) in graph.edges() {
            assert!(
                out.new_slice(u as usize).contains(&v) || out.old_slice(u as usize).contains(&v),
                "edge {u}→{v} lost despite p=1"
            );
        }
    }
}
