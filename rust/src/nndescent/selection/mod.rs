//! Selection step (paper §3.1): build per-node bounded new/old
//! candidate lists from forward + reverse edges of the current graph.
//!
//! Three interchangeable implementations, in increasing order of the
//! paper's optimization story:
//!
//! * [`naive`] — the three-pass reverse → union → sample composition
//!   from Dong et al.'s pseudocode, with unbounded intermediate reverse
//!   lists (`nndescent-full` baseline).
//! * [`heap`] — PyNNDescent's fused single pass: one random weight per
//!   edge endpoint, bounded random-weight heaps (≈16× over naive).
//! * [`turbo`] — the paper's contribution: no heaps; the graph already
//!   tracks |N(u)| (reverse-degree counters maintained on every update),
//!   so each edge endpoint is sampled with probability `cap/|N(u)|`
//!   into a plain array (≈1.12× over heap).
//!
//! All three have the same output contract: new/old lists bounded by
//! `cap`, duplicates excluded, and the incremental-search flag cleared
//! for forward neighbors that were sampled into their node's new list.
//!
//! A fourth, crate-internal implementation ([`partitioned`]) re-derives
//! the turbo scheme with counter-based randomness and an owner-writes
//! node-range decomposition — the selection phase of the multi-threaded
//! build (`nndescent::parallel`). Same output contract.

pub mod heap;
pub mod naive;
pub(crate) mod partitioned;
pub mod turbo;

use super::candidates::CandidateLists;
use crate::cachesim::trace::Tracer;
use crate::config::schema::SelectionKind;
use crate::graph::KnnGraph;
use crate::util::rng::Pcg64;

/// Stateful selector (owns scratch reused across iterations).
#[derive(Debug)]
pub enum Selector {
    Naive(naive::NaiveSelector),
    Heap(heap::HeapSelector),
    Turbo(turbo::TurboSelector),
}

impl Selector {
    /// Construct a selector for `n` nodes with candidate capacity `cap`.
    pub fn new(kind: SelectionKind, n: usize, cap: usize) -> Self {
        match kind {
            SelectionKind::Naive => Self::Naive(naive::NaiveSelector::new(n)),
            SelectionKind::Heap => Self::Heap(heap::HeapSelector::new(n, cap)),
            SelectionKind::Turbo => Self::Turbo(turbo::TurboSelector::new()),
        }
    }

    /// Run one selection pass: fill `out` and clear sampled flags.
    pub fn select<T: Tracer>(
        &mut self,
        graph: &mut KnnGraph,
        rng: &mut Pcg64,
        out: &mut CandidateLists,
        tracer: &mut T,
    ) {
        match self {
            Self::Naive(s) => s.select(graph, rng, out, tracer),
            Self::Heap(s) => s.select(graph, rng, out, tracer),
            Self::Turbo(s) => s.select(graph, rng, out, tracer),
        }
    }

    pub fn kind(&self) -> SelectionKind {
        match self {
            Self::Naive(_) => SelectionKind::Naive,
            Self::Heap(_) => SelectionKind::Heap,
            Self::Turbo(_) => SelectionKind::Turbo,
        }
    }
}

/// Shared post-pass: clear the `new` flag of every forward neighbor that
/// made it into its node's sampled new list (it will be evaluated this
/// iteration; unsampled neighbors stay flagged for the next round).
pub(crate) fn clear_sampled_flags<T: Tracer>(graph: &mut KnnGraph, cands: &CandidateLists, tracer: &mut T) {
    let n = graph.n();
    let k = graph.k();
    for u in 0..n {
        tracer.read(cands.new_ids_addr() + u * cands.cap() * 4, (cands.new_len(u) * 4) as u32);
        for i in 0..k {
            let v = graph.ids(u)[i];
            if graph.flags(u)[i] && cands.new_slice(u).contains(&v) {
                graph.clear_flag(u, i);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::trace::NoTracer;
    use crate::dataset::synth::SynthGaussian;
    use crate::nndescent::init::init_random;
    use crate::util::counters::FlopCounter;

    fn initialized(n: usize, k: usize, seed: u64) -> (KnnGraph, crate::dataset::AlignedMatrix) {
        let data = SynthGaussian::single(n, 8, seed).generate();
        let mut graph = KnnGraph::new(n, k);
        let mut rng = Pcg64::new(seed);
        init_random(&mut graph, &data, &mut rng, &mut FlopCounter::new(8), &mut NoTracer);
        (graph, data)
    }

    /// Contract checks shared by all three selectors.
    fn check_contract(kind: SelectionKind) {
        let (mut graph, _) = initialized(300, 10, 42);
        let cap = 5;
        let mut sel = Selector::new(kind, 300, cap);
        let mut out = CandidateLists::new(300, cap);
        let mut rng = Pcg64::new(9);
        sel.select(&mut graph, &mut rng, &mut out, &mut NoTracer);

        let mut total_new = 0usize;
        for u in 0..300 {
            let newc = out.new_slice(u);
            let oldc = out.old_slice(u);
            assert!(newc.len() <= cap && oldc.len() <= cap, "{kind:?}: cap respected");
            total_new += newc.len();
            // no self references
            assert!(!newc.contains(&(u as u32)) && !oldc.contains(&(u as u32)), "{kind:?}: self in list");
            // no duplicates within a list
            for list in [newc, oldc] {
                let mut s = list.to_vec();
                s.sort_unstable();
                let before = s.len();
                s.dedup();
                assert_eq!(before, s.len(), "{kind:?}: duplicates in node {u}: {list:?}");
            }
            // every new candidate of u must be graph-adjacent to u in
            // some direction (forward or reverse edge)
            for &v in newc {
                let fwd = graph.ids(u).contains(&v);
                let rev = graph.ids(v as usize).contains(&(u as u32));
                assert!(fwd || rev, "{kind:?}: candidate {v} of {u} not adjacent");
            }
        }
        assert!(total_new > 0, "{kind:?}: first-round selection must produce new candidates");
        // flags: sampled forward neighbors cleared
        for u in 0..300 {
            let sampled = out.new_slice(u);
            for (i, &v) in graph.ids(u).iter().enumerate() {
                if sampled.contains(&v) {
                    assert!(!graph.flags(u)[i], "{kind:?}: sampled flag not cleared (node {u})");
                }
            }
        }
        graph.validate().unwrap();
    }

    #[test]
    fn naive_contract() {
        check_contract(SelectionKind::Naive);
    }

    #[test]
    fn heap_contract() {
        check_contract(SelectionKind::Heap);
    }

    #[test]
    fn turbo_contract() {
        check_contract(SelectionKind::Turbo);
    }

    #[test]
    fn second_round_has_old_candidates() {
        for kind in [SelectionKind::Naive, SelectionKind::Heap, SelectionKind::Turbo] {
            let (mut graph, _) = initialized(200, 8, 5);
            let mut sel = Selector::new(kind, 200, 4);
            let mut out = CandidateLists::new(200, 4);
            let mut rng = Pcg64::new(11);
            sel.select(&mut graph, &mut rng, &mut out, &mut NoTracer);
            // after round 1 some flags are cleared → round 2 must see "old"
            sel.select(&mut graph, &mut rng, &mut out, &mut NoTracer);
            let total_old: usize = (0..200).map(|u| out.old_slice(u).len()).sum();
            assert!(total_old > 0, "{kind:?}: no old candidates in round 2");
        }
    }
}
