//! Fused one-pass selection with bounded random-weight heaps —
//! PyNNDescent's approach, adopted by the paper (§3.1) before being
//! superseded by turbosampling.
//!
//! For each edge e=(u,v) one weight r_e ~ U[0,1] is drawn. `v` is pushed
//! into N(u)'s heap keyed by r_e and `u` into N(v)'s (covering forward
//! and reverse in the same pass). A bounded max-heap keeps the ρ·k
//! smallest weights — selecting the ρ·k elements with the smallest
//! u.a.r. weights is exactly a uniform ρ·k-subset, so one pass replaces
//! reverse+union+sample. The cost the paper then attacks: every push
//! touches a heap (pointer-chasing sift operations → cache misses).

use super::super::candidates::CandidateLists;
use super::clear_sampled_flags;
use crate::cachesim::trace::Tracer;
use crate::graph::heap::EMPTY_ID;
use crate::graph::KnnGraph;
use crate::util::rng::Pcg64;

/// Scratch heaps: SoA weight/id arrays, `n × cap` each for new and old.
#[derive(Debug)]
pub struct HeapSelector {
    cap: usize,
    new_wt: Vec<f32>,
    new_id: Vec<u32>,
    new_len: Vec<u16>,
    old_wt: Vec<f32>,
    old_id: Vec<u32>,
    old_len: Vec<u16>,
}

impl HeapSelector {
    pub fn new(n: usize, cap: usize) -> Self {
        Self {
            cap,
            new_wt: vec![0.0; n * cap],
            new_id: vec![0; n * cap],
            new_len: vec![0; n],
            old_wt: vec![0.0; n * cap],
            old_id: vec![0; n * cap],
            old_len: vec![0; n],
        }
    }

    pub fn select<T: Tracer>(
        &mut self,
        graph: &mut KnnGraph,
        rng: &mut Pcg64,
        out: &mut CandidateLists,
        tracer: &mut T,
    ) {
        let n = graph.n();
        let k = graph.k();
        let cap = self.cap.min(out.cap());
        out.clear();
        self.new_len.fill(0);
        self.old_len.fill(0);

        // ---- single pass over all edges -------------------------------------
        for u in 0..n {
            tracer.read(graph.ids(u).as_ptr() as usize, (k * 4) as u32);
            tracer.read(graph.flags(u).as_ptr() as usize, k as u32);
            for (&v, &f) in graph.ids(u).iter().zip(graph.flags(u)) {
                if v == EMPTY_ID {
                    continue;
                }
                let w = rng.gen_f32();
                if f {
                    self.push_new(u, v, w, cap, tracer);
                    self.push_new(v as usize, u as u32, w, cap, tracer);
                } else {
                    self.push_old(u, v, w, cap, tracer);
                    self.push_old(v as usize, u as u32, w, cap, tracer);
                }
            }
        }

        // ---- emit into the shared candidate-list structure -------------------
        for u in 0..n {
            let nl = self.new_len[u] as usize;
            out.set_new(u, &self.new_id[u * self.cap..u * self.cap + nl]);
            let ol = self.old_len[u] as usize;
            out.set_old(u, &self.old_id[u * self.cap..u * self.cap + ol]);
        }

        clear_sampled_flags(graph, out, tracer);
    }

    #[inline]
    fn push_new<T: Tracer>(&mut self, u: usize, id: u32, w: f32, cap: usize, tracer: &mut T) {
        let base = u * self.cap;
        let len = self.new_len[u] as usize;
        tracer.read(self.new_wt.as_ptr() as usize + base * 4, (len.max(1) * 4) as u32);
        wheap_push(
            &mut self.new_id[base..base + cap],
            &mut self.new_wt[base..base + cap],
            &mut self.new_len[u],
            id,
            w,
        );
        tracer.write(self.new_id.as_ptr() as usize + base * 4, 4);
    }

    #[inline]
    fn push_old<T: Tracer>(&mut self, u: usize, id: u32, w: f32, cap: usize, tracer: &mut T) {
        let base = u * self.cap;
        let len = self.old_len[u] as usize;
        tracer.read(self.old_wt.as_ptr() as usize + base * 4, (len.max(1) * 4) as u32);
        wheap_push(
            &mut self.old_id[base..base + cap],
            &mut self.old_wt[base..base + cap],
            &mut self.old_len[u],
            id,
            w,
        );
        tracer.write(self.old_id.as_ptr() as usize + base * 4, 4);
    }
}

/// Bounded max-heap-by-weight push with duplicate rejection: keeps the
/// `cap` smallest-weight ids seen so far. The cheap weight test runs
/// *before* the O(cap) duplicate scan — once the heap is warm, most
/// pushes die on the single root comparison.
#[inline]
fn wheap_push(ids: &mut [u32], wts: &mut [f32], len: &mut u16, id: u32, w: f32) {
    let l = *len as usize;
    if l == ids.len() && w >= wts[0] {
        return; // cannot qualify — skip the duplicate scan entirely
    }
    if ids[..l].contains(&id) {
        return;
    }
    if l < ids.len() {
        // insert at tail, sift up
        let mut i = l;
        ids[i] = id;
        wts[i] = w;
        while i > 0 {
            let p = (i - 1) / 2;
            if wts[p] < wts[i] {
                ids.swap(p, i);
                wts.swap(p, i);
                i = p;
            } else {
                break;
            }
        }
        *len += 1;
    } else if w < wts[0] {
        // replace root (largest weight), sift down
        ids[0] = id;
        wts[0] = w;
        let k = ids.len();
        let mut i = 0;
        loop {
            let l_ = 2 * i + 1;
            let r = l_ + 1;
            let mut m = i;
            if l_ < k && wts[l_] > wts[m] {
                m = l_;
            }
            if r < k && wts[r] > wts[m] {
                m = r;
            }
            if m == i {
                break;
            }
            ids.swap(i, m);
            wts.swap(i, m);
            i = m;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Config};

    #[test]
    fn wheap_keeps_smallest_weights() {
        check(Config::cases(100), "wheap = cap smallest weights", |g| {
            let cap = g.usize_in(1..8);
            let m = g.usize_in(1..60);
            let mut ids = vec![0u32; cap];
            let mut wts = vec![0.0f32; cap];
            let mut len = 0u16;
            let mut pushed: Vec<(u32, f32)> = Vec::new();
            for id in 0..m as u32 {
                let w = g.f32_unit();
                wheap_push(&mut ids, &mut wts, &mut len, id, w);
                pushed.push((id, w));
            }
            pushed.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let expect: std::collections::BTreeSet<u32> =
                pushed.iter().take(cap).map(|p| p.0).collect();
            let got: std::collections::BTreeSet<u32> =
                ids[..len as usize].iter().copied().collect();
            got == expect
        });
    }

    #[test]
    fn wheap_rejects_duplicates() {
        let mut ids = vec![0u32; 4];
        let mut wts = vec![0.0f32; 4];
        let mut len = 0u16;
        wheap_push(&mut ids, &mut wts, &mut len, 9, 0.5);
        wheap_push(&mut ids, &mut wts, &mut len, 9, 0.1);
        assert_eq!(len, 1);
    }

    #[test]
    fn uniformity_smoke() {
        // selecting cap-of-m via random weights should be ~uniform:
        // every id selected with probability cap/m
        let cap = 4;
        let m = 16u32;
        let trials = 4000;
        let mut counts = vec![0usize; m as usize];
        let mut rng = crate::util::rng::Pcg64::new(77);
        for _ in 0..trials {
            let mut ids = vec![0u32; cap];
            let mut wts = vec![0.0f32; cap];
            let mut len = 0u16;
            for id in 0..m {
                wheap_push(&mut ids, &mut wts, &mut len, id, rng.gen_f32());
            }
            for &id in &ids[..len as usize] {
                counts[id as usize] += 1;
            }
        }
        let expect = trials * cap / m as usize; // 1000
        for (id, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64 - expect as f64).abs() < expect as f64 * 0.15,
                "id {id}: count {c} vs expect {expect}"
            );
        }
    }
}
