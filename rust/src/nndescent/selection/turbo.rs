//! "Turbosampling" — the paper's heap-free selection (§3.1).
//!
//! The fused heap selection still pays for heap sift operations and
//! their cache misses. The paper's observation: the graph *already*
//! knows how large every neighborhood is, because every K-NN update
//! touches the affected node anyway — [`KnnGraph`] maintains
//! reverse-degree counters at zero marginal cache cost. Knowing
//! |N(u)| = k + rev_deg(u) up front, a uniform ρ·k-subset can be drawn
//! in one pass by independent coin flips: insert each element with
//! probability ρ·k/|N(u)| — equal in expectation to the heap scheme,
//! with plain array appends instead of sift operations.
//!
//! When a coin flip succeeds but the bounded array is already full, a
//! uniformly random occupant is replaced, keeping the marginal inclusion
//! probability uniform across edge positions.

use super::super::candidates::CandidateLists;
use super::clear_sampled_flags;
use crate::cachesim::trace::Tracer;
use crate::graph::heap::EMPTY_ID;
use crate::graph::KnnGraph;
use crate::util::rng::Pcg64;

/// Heap-free selector. The only state is a pair of per-node coin-flip
/// thresholds recomputed once per iteration from the graph's counters —
/// O(n) integer work replacing the per-edge divisions a literal
/// implementation would pay (and far cheaper than the heap version's
/// per-edge sift operations).
#[derive(Debug, Default)]
pub struct TurboSelector {
    /// `P[v] = min(1, cap/|N_new(v)|)` as a u32 threshold: include an
    /// edge endpoint iff `rng_u32 < thr_new[v]`.
    thr_new: Vec<u32>,
    thr_old: Vec<u32>,
}

/// Convert an inclusion probability to a 32-bit comparison threshold.
/// Shared with the partitioned (parallel-build) selector, which samples
/// the same `cap/|N|` coin flips from counter-based streams.
#[inline]
pub(crate) fn to_threshold(cap: usize, size: u32) -> u32 {
    if size <= cap as u32 {
        u32::MAX
    } else {
        ((cap as f64 / size as f64) * 2f64.powi(32)) as u32
    }
}

impl TurboSelector {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn select<T: Tracer>(
        &mut self,
        graph: &mut KnnGraph,
        rng: &mut Pcg64,
        out: &mut CandidateLists,
        tracer: &mut T,
    ) {
        let n = graph.n();
        let k = graph.k();
        let cap = out.cap();
        out.clear();

        // one pass over the counters: per-direction inclusion thresholds
        // (cap / |N_new(u)|, cap / |N_old(u)| — the new/old candidate
        // lists sample disjoint edge populations)
        self.thr_new.clear();
        self.thr_old.clear();
        self.thr_new.extend((0..n).map(|u| to_threshold(cap, graph.new_size(u))));
        self.thr_old.extend((0..n).map(|u| to_threshold(cap, graph.old_size(u))));

        for u in 0..n {
            tracer.read(graph.ids(u).as_ptr() as usize, (k * 4) as u32);
            tracer.read(graph.flags(u).as_ptr() as usize, k as u32);
            for (&v, &f) in graph.ids(u).iter().zip(graph.flags(u)) {
                if v == EMPTY_ID {
                    continue;
                }
                // one u64 draw = both directions' coins
                let r = rng.next_u64();
                let (r_fwd, r_rev) = (r as u32, (r >> 32) as u32);
                let (thr_u, thr_v) = if f {
                    (self.thr_new[u], self.thr_new[v as usize])
                } else {
                    (self.thr_old[u], self.thr_old[v as usize])
                };
                // forward direction: v into N(u)
                if r_fwd < thr_u {
                    insert(out, u, v, f, rng, tracer);
                }
                // reverse direction: u into N(v)
                if r_rev < thr_v {
                    insert(out, v as usize, u as u32, f, rng, tracer);
                }
            }
        }

        clear_sampled_flags(graph, out, tracer);
    }
}

/// Append-or-reservoir-replace with duplicate rejection.
#[inline]
fn insert<T: Tracer>(out: &mut CandidateLists, u: usize, v: u32, new: bool, rng: &mut Pcg64, tracer: &mut T) {
    if new {
        if out.new_slice(u).contains(&v) {
            return;
        }
        if out.push_new(u, v) {
            tracer.write(out.new_ids_addr() + (u * out.cap() + out.new_len(u) - 1) * 4, 4);
        } else {
            let slot = rng.gen_index(out.new_len(u));
            out.replace_new(u, slot, v);
            tracer.write(out.new_ids_addr() + (u * out.cap() + slot) * 4, 4);
        }
    } else {
        if out.old_slice(u).contains(&v) {
            return;
        }
        if out.push_old(u, v) {
            tracer.write(out.old_ids_addr() + (u * out.cap() + out.old_len(u) - 1) * 4, 4);
        } else {
            let slot = rng.gen_index(out.old_len(u));
            out.replace_old(u, slot, v);
            tracer.write(out.old_ids_addr() + (u * out.cap() + slot) * 4, 4);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::trace::NoTracer;
    use crate::dataset::synth::SynthGaussian;
    use crate::nndescent::init::init_random;
    use crate::util::counters::FlopCounter;

    #[test]
    fn expected_list_size_is_near_cap() {
        // With |N(u)| >> cap, E[|new(u)|] ≈ cap (minus dup rejections).
        let n = 2000;
        let k = 20;
        let data = SynthGaussian::single(n, 8, 1).generate();
        let mut graph = KnnGraph::new(n, k);
        let mut rng = Pcg64::new(2);
        init_random(&mut graph, &data, &mut rng, &mut FlopCounter::new(8), &mut NoTracer);
        let cap = 10;
        let mut sel = TurboSelector::new();
        let mut out = CandidateLists::new(n, cap);
        sel.select(&mut graph, &mut rng, &mut out, &mut NoTracer);
        let mean: f64 = (0..n).map(|u| out.new_slice(u).len() as f64).sum::<f64>() / n as f64;
        // |N(u)| ≈ 2k = 40, 40 trials at p=0.25 → mean 10 capped; allow slack
        assert!(mean > cap as f64 * 0.6, "mean new-list size {mean} too small");
    }

    #[test]
    fn threshold_conversion() {
        // size ≤ cap ⇒ always include
        assert_eq!(to_threshold(10, 5), u32::MAX);
        assert_eq!(to_threshold(10, 10), u32::MAX);
        // cap/size = 1/2 ⇒ threshold ≈ 2^31
        let t = to_threshold(10, 20);
        assert!((t as f64 / 2f64.powi(32) - 0.5).abs() < 1e-6, "t={t}");
        // tiny probability stays > 0 proportional
        let t = to_threshold(1, 1000);
        assert!((t as f64 / 2f64.powi(32) - 0.001).abs() < 1e-6);
    }

    #[test]
    fn respects_probability_one_when_small_neighborhood() {
        // cap ≥ |N(u)| ⇒ p = 1 ⇒ every edge endpoint sampled (mod dups)
        let n = 30;
        let k = 3;
        let data = SynthGaussian::single(n, 8, 3).generate();
        let mut graph = KnnGraph::new(n, k);
        let mut rng = Pcg64::new(4);
        init_random(&mut graph, &data, &mut rng, &mut FlopCounter::new(8), &mut NoTracer);
        let mut sel = TurboSelector::new();
        let mut out = CandidateLists::new(n, n); // cap = n ⇒ p = 1
        sel.select(&mut graph, &mut rng, &mut out, &mut NoTracer);
        for (u, v, _) in graph.edges() {
            assert!(
                out.new_slice(u as usize).contains(&v) || out.old_slice(u as usize).contains(&v),
                "edge {u}→{v} lost despite p=1"
            );
        }
    }
}
