//! Compute step (paper §3.3): evaluate candidate pair distances and push
//! improvements into both endpoint heaps.
//!
//! The candidate set of a node is `new ∪ old` (≤ 2·ρ·k ≤ paper's 50).
//! Pairs evaluated: new×new (i<j) and new×old — old×old pairs were
//! evaluated in an earlier iteration (Dong et al.'s incremental search).
//!
//! Distance evaluation is pluggable via [`PairwiseEngine`]:
//! * [`NativeEngine`] — scalar / unrolled / 5×5-blocked kernels. The
//!   unrolled and blocked tiers route through the runtime-dispatched
//!   kernel engine (`distance::dispatch`), so the same compute step
//!   runs 8- or 16-lane SIMD depending on the CPU (or a forced
//!   `PALLAS_KERNEL` width); the `FlopCounter` the driver hands in is
//!   tagged with that width.
//! * `runtime::PjrtEngine` — the AOT-compiled Pallas kernel via PJRT.
//!
//! With the blocked/PJRT engines, *all* mutual distances of the set are
//! computed (that is what makes blocking possible — paper Fig 2); the
//! flop counter counts what the hardware actually evaluated.

use super::candidates::CandidateLists;
use crate::cachesim::trace::{NoTracer, Tracer};
use crate::config::schema::ComputeKind;
use crate::dataset::AlignedMatrix;
use crate::distance::blocked::{pairwise_blocked_active, pairwise_flat, PairwiseBuf, BLOCK};
use crate::graph::{GraphUpdate, KnnGraph};
use crate::util::counters::FlopCounter;

/// A batch pairwise-distance backend.
pub trait PairwiseEngine {
    /// Compute mutual distances among `ids` into `out`; every pair
    /// `(i, j)` with `i < active`, `i < j` must be filled (engines may
    /// compute more — e.g. the fixed-shape PJRT batch computes all).
    /// Returns the number of distance evaluations performed.
    fn pairwise<T: Tracer>(
        &mut self,
        data: &AlignedMatrix,
        ids: &[u32],
        active: usize,
        out: &mut PairwiseBuf,
        tracer: &mut T,
    ) -> u64;

    /// Whether this engine computes full mutual blocks (true) or should
    /// be driven pair-by-pair over the new×new/new×old subsets (false).
    fn is_blocked(&self) -> bool;

    /// Backend label for reports.
    fn name(&self) -> &'static str;
}

/// CPU-native engine over the paper's three kernel tiers.
#[derive(Debug, Clone, Copy)]
pub struct NativeEngine {
    pub kind: ComputeKind,
}

impl NativeEngine {
    pub fn new(kind: ComputeKind) -> Self {
        debug_assert!(kind != ComputeKind::Pjrt, "use runtime::PjrtEngine");
        Self { kind }
    }
}

impl PairwiseEngine for NativeEngine {
    fn pairwise<T: Tracer>(
        &mut self,
        data: &AlignedMatrix,
        ids: &[u32],
        active: usize,
        out: &mut PairwiseBuf,
        tracer: &mut T,
    ) -> u64 {
        let rb = data.row_bytes() as u32;
        let base = data.base_addr();
        match self.kind {
            ComputeKind::Blocked => {
                // Trace at block granularity: each 5×5 step loads 10 rows.
                let m = ids.len();
                let active = active.min(m);
                let full = (m / BLOCK) * BLOCK;
                let active_full = full.min(active.div_ceil(BLOCK) * BLOCK);
                for ib in (0..active_full).step_by(BLOCK) {
                    for jb in (ib..full).step_by(BLOCK) {
                        for a in 0..BLOCK {
                            tracer.read(base + ids[ib + a] as usize * data.row_bytes(), rb);
                        }
                        if jb > ib {
                            for b in 0..BLOCK {
                                tracer.read(base + ids[jb + b] as usize * data.row_bytes(), rb);
                            }
                        }
                    }
                }
                for i in full..m {
                    for j in 0..i {
                        if j >= active && i >= active {
                            continue;
                        }
                        tracer.read(base + ids[i] as usize * data.row_bytes(), rb);
                        tracer.read(base + ids[j] as usize * data.row_bytes(), rb);
                    }
                }
                pairwise_blocked_active(data, ids, active, out)
            }
            _ => {
                // Pair-at-a-time: both rows touched per evaluation.
                let m = ids.len();
                for i in 0..m {
                    for j in (i + 1)..m {
                        tracer.read(base + ids[i] as usize * data.row_bytes(), rb);
                        tracer.read(base + ids[j] as usize * data.row_bytes(), rb);
                    }
                }
                pairwise_flat(data, ids, out, self.kind != ComputeKind::Scalar)
            }
        }
    }

    fn is_blocked(&self) -> bool {
        self.kind == ComputeKind::Blocked
    }

    fn name(&self) -> &'static str {
        self.kind.name()
    }
}

/// Scratch reused across nodes/iterations by the compute step.
#[derive(Debug)]
pub struct ComputeScratch {
    set: Vec<u32>,
    buf: PairwiseBuf,
    /// Per-set-member cached improvement thresholds (current heap
    /// worst): turns the two random-strip reads per *pair* into one
    /// sequential array read, refreshed only on successful pushes.
    thresholds: Vec<f32>,
}

impl ComputeScratch {
    pub fn new(max_set: usize) -> Self {
        Self {
            set: Vec::with_capacity(2 * max_set),
            buf: PairwiseBuf::with_capacity(2 * max_set),
            thresholds: Vec::with_capacity(2 * max_set),
        }
    }
}

/// Run the compute step for every node; returns the number of graph
/// updates (the convergence signal `c` in Dong et al.).
pub fn compute_step<E: PairwiseEngine, T: Tracer>(
    graph: &mut KnnGraph,
    data: &AlignedMatrix,
    cands: &CandidateLists,
    engine: &mut E,
    counter: &mut FlopCounter,
    scratch: &mut ComputeScratch,
    tracer: &mut T,
) -> u64 {
    let n = graph.n();
    let mut updates = 0u64;
    let blocked = engine.is_blocked();
    // Flat-path pair kernel, resolved once — the per-pair dispatch
    // indirection is measurable at small d (same function the
    // `sq_l2_unrolled` shim reaches, so numerics are unchanged).
    let flat_pair: fn(&[f32], &[f32]) -> f32 = match native_kind(engine) {
        ComputeKind::Scalar => crate::distance::sq_l2_scalar,
        _ => crate::distance::dispatch::active().pair,
    };

    for u in 0..n {
        let newc = cands.new_slice(u);
        if newc.is_empty() {
            continue;
        }
        let oldc = cands.old_slice(u);
        let n_new = newc.len();
        let m = n_new + oldc.len();
        if m < 2 {
            continue;
        }
        scratch.set.clear();
        scratch.set.extend_from_slice(newc);
        scratch.set.extend_from_slice(oldc);

        if blocked {
            // Full mutual block (this is what enables 5×5 blocking).
            // Perf note (EXPERIMENTS.md §Perf): restricting to
            // `active = n_new` rows cuts evaluations ~25% but wall time
            // only ~3% — old×old blocks reuse rows already resident from
            // the needed blocks — so the paper-faithful full block is
            // kept as the default accounting.
            counter.add_evals(engine.pairwise(data, &scratch.set, m, &mut scratch.buf, tracer));
            scratch.thresholds.clear();
            scratch
                .thresholds
                .extend(scratch.set.iter().map(|&v| graph.worst(v as usize)));
            for i in 0..n_new {
                for j in (i + 1)..m {
                    let d = scratch.buf.get(i, j);
                    // cheap local screen before touching the graph strips
                    if d >= scratch.thresholds[i] && d >= scratch.thresholds[j] {
                        continue;
                    }
                    let (a, b) = (scratch.set[i], scratch.set[j]);
                    if a == b {
                        continue;
                    }
                    if d < scratch.thresholds[i] {
                        tracer.read(graph.dists(a as usize).as_ptr() as usize, 4);
                        if graph.push(a as usize, b, d, true) {
                            tracer.write(graph.ids(a as usize).as_ptr() as usize, (graph.k() * 4) as u32);
                            updates += 1;
                            scratch.thresholds[i] = graph.worst(a as usize);
                        }
                    }
                    if d < scratch.thresholds[j] {
                        tracer.read(graph.dists(b as usize).as_ptr() as usize, 4);
                        if graph.push(b as usize, a, d, true) {
                            tracer.write(graph.ids(b as usize).as_ptr() as usize, (graph.k() * 4) as u32);
                            updates += 1;
                            scratch.thresholds[j] = graph.worst(b as usize);
                        }
                    }
                }
            }
        } else {
            // pair-at-a-time over exactly the new×new + new×old pairs
            let base = data.base_addr();
            let rb = data.row_bytes() as u32;
            for i in 0..n_new {
                let a = scratch.set[i] as usize;
                for j in (i + 1)..m {
                    let b = scratch.set[j] as usize;
                    if scratch.set[i] == scratch.set[j] {
                        continue;
                    }
                    tracer.read(base + a * data.row_bytes(), rb);
                    tracer.read(base + b * data.row_bytes(), rb);
                    let d = flat_pair(data.row(a), data.row(b));
                    counter.add_evals(1);
                    let s = &scratch.set;
                    apply_update_pair(graph, s[i], s[j], d, &mut updates, tracer);
                }
            }
        }
    }
    updates
}

/// Frozen-graph compute step over a node range — the parallel build's
/// worker body. Like [`compute_step`] restricted to `range`, except the
/// graph is read-only: improvements are screened against the
/// *phase-start* heap thresholds and buffered as [`GraphUpdate`]s
/// instead of pushed, so T workers over disjoint ranges share the graph
/// without locks and [`KnnGraph::apply_updates`] replays the merged
/// buffer deterministically afterwards.
///
/// Because the screen never tightens mid-phase (the sequential step
/// tightens after every successful push), the buffer can contain
/// records the apply phase will reject — that is the phased-update
/// relaxation of NN-Descent, and it is what makes the buffered set a
/// pure function of `(graph, candidates)`, independent of the range
/// partitioning. Returns the number of distance evaluations performed —
/// unchanged from the sequential step (the same candidate sets run
/// through the same kernels); the caller folds it into its counter.
///
/// Memory: early iterations would otherwise buffer most evaluated
/// pairs (a random heap's worst-of-k is easy to beat), so the buffer is
/// periodically compacted with [`compact_updates`] — an
/// outcome-preserving reduction, see its proof sketch — keeping the
/// footprint at O(k · targets) instead of O(dist_evals).
pub(crate) fn compute_step_frozen(
    graph: &KnnGraph,
    data: &AlignedMatrix,
    cands: &CandidateLists,
    range: std::ops::Range<usize>,
    engine: &mut NativeEngine,
    scratch: &mut ComputeScratch,
    out: &mut Vec<GraphUpdate>,
) -> u64 {
    // compact every ~64k appended records (~768 KB of buffer)
    const COMPACT_CHUNK: usize = 1 << 16;
    let keep = 2 * graph.k();
    let mut next_compact = out.len() + COMPACT_CHUNK;
    let mut evals = 0u64;
    let blocked = engine.is_blocked();
    let flat_pair: fn(&[f32], &[f32]) -> f32 = match engine.kind {
        ComputeKind::Scalar => crate::distance::sq_l2_scalar,
        _ => crate::distance::dispatch::active().pair,
    };

    for u in range {
        let newc = cands.new_slice(u);
        if newc.is_empty() {
            continue;
        }
        let oldc = cands.old_slice(u);
        let n_new = newc.len();
        let m = n_new + oldc.len();
        if m < 2 {
            continue;
        }
        scratch.set.clear();
        scratch.set.extend_from_slice(newc);
        scratch.set.extend_from_slice(oldc);
        scratch.thresholds.clear();
        scratch.thresholds.extend(scratch.set.iter().map(|&v| graph.worst(v as usize)));

        if blocked {
            // full mutual block, same accounting as the sequential step
            evals += engine.pairwise(data, &scratch.set, m, &mut scratch.buf, &mut NoTracer);
            for i in 0..n_new {
                for j in (i + 1)..m {
                    let d = scratch.buf.get(i, j);
                    if d >= scratch.thresholds[i] && d >= scratch.thresholds[j] {
                        continue;
                    }
                    let (a, b) = (scratch.set[i], scratch.set[j]);
                    if a == b {
                        continue;
                    }
                    if d < scratch.thresholds[i] {
                        out.push(GraphUpdate { target: a, nb: b, dist: d });
                    }
                    if d < scratch.thresholds[j] {
                        out.push(GraphUpdate { target: b, nb: a, dist: d });
                    }
                }
            }
        } else {
            // pair-at-a-time over exactly the new×new + new×old pairs
            for i in 0..n_new {
                let a = scratch.set[i];
                for j in (i + 1)..m {
                    let b = scratch.set[j];
                    if a == b {
                        continue;
                    }
                    let d = flat_pair(data.row(a as usize), data.row(b as usize));
                    evals += 1;
                    if d < scratch.thresholds[i] {
                        out.push(GraphUpdate { target: a, nb: b, dist: d });
                    }
                    if d < scratch.thresholds[j] {
                        out.push(GraphUpdate { target: b, nb: a, dist: d });
                    }
                }
            }
        }
        if out.len() >= next_compact {
            compact_updates(out, keep);
            next_compact = out.len() + COMPACT_CHUNK;
        }
    }
    evals
}

/// Shrink an update buffer to the `keep` best distinct-neighbor records
/// per target (sorted by the apply comparator, exact duplicates
/// removed) without changing what [`KnnGraph::apply_updates`] will do
/// with it.
///
/// Why `keep = 2k` is lossless: the apply phase replays records
/// best-first per target, so it can perform at most `k` successful
/// pushes (after the k-th, the heap's worst is ≤ every later record)
/// and at most `k` duplicate-rejections against pre-existing neighbors
/// (each distinct id once — same-buffer duplicates are removed here).
/// Every record beyond that 2k-long active prefix is distance-rejected
/// with no effect on the graph *or* the update count, so dropping it is
/// invisible. This also keeps per-worker compaction consistent with the
/// global merge: a record outside its own worker's per-target 2k prefix
/// is outside the merged prefix too.
pub(crate) fn compact_updates(buf: &mut Vec<GraphUpdate>, keep: usize) {
    buf.sort_unstable_by(GraphUpdate::order);
    // same (target, nb) ⇒ same pair ⇒ bit-equal distance: true duplicates
    buf.dedup_by(|a, b| a.target == b.target && a.nb == b.nb);
    let mut cur = u32::MAX; // no valid target (ids are < n ≤ u32::MAX − 1)
    let mut count = 0usize;
    buf.retain(|r| {
        if r.target != cur {
            cur = r.target;
            count = 0;
        }
        count += 1;
        count <= keep
    });
}

#[inline]
fn native_kind<E: PairwiseEngine>(e: &E) -> ComputeKind {
    match e.name() {
        "scalar" => ComputeKind::Scalar,
        _ => ComputeKind::Unrolled,
    }
}

#[inline]
fn apply_update_pair<T: Tracer>(graph: &mut KnnGraph, a: u32, b: u32, d: f32, updates: &mut u64, tracer: &mut T) {
    // both heap roots are read; a successful push rewrites ~the strip
    tracer.read(graph.dists(a as usize).as_ptr() as usize, 4);
    if graph.push(a as usize, b, d, true) {
        tracer.write(graph.ids(a as usize).as_ptr() as usize, (graph.k() * 4) as u32);
        *updates += 1;
    }
    tracer.read(graph.dists(b as usize).as_ptr() as usize, 4);
    if graph.push(b as usize, a, d, true) {
        tracer.write(graph.ids(b as usize).as_ptr() as usize, (graph.k() * 4) as u32);
        *updates += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::trace::NoTracer;
    use crate::config::schema::SelectionKind;
    use crate::dataset::synth::SynthGaussian;
    use crate::nndescent::init::init_random;
    use crate::nndescent::selection::Selector;
    use crate::util::rng::Pcg64;

    fn one_iteration(kind: ComputeKind, seed: u64) -> (KnnGraph, u64, u64) {
        let n = 400;
        let k = 8;
        let cap = 6;
        let data = SynthGaussian::single(n, 16, seed).generate();
        let mut graph = KnnGraph::new(n, k);
        let mut rng = Pcg64::new(seed);
        let mut counter = FlopCounter::new(16);
        init_random(&mut graph, &data, &mut rng, &mut counter, &mut NoTracer);
        let mut sel = Selector::new(SelectionKind::Turbo, n, cap);
        let mut cands = CandidateLists::new(n, cap);
        sel.select(&mut graph, &mut rng, &mut cands, &mut NoTracer);
        let mut engine = NativeEngine::new(kind);
        let mut scratch = ComputeScratch::new(cap);
        let updates = compute_step(
            &mut graph,
            &data,
            &cands,
            &mut engine,
            &mut counter,
            &mut scratch,
            &mut NoTracer,
        );
        (graph, updates, counter.dist_evals)
    }

    #[test]
    fn makes_progress_and_stays_valid() {
        for kind in [ComputeKind::Scalar, ComputeKind::Unrolled, ComputeKind::Blocked] {
            let (graph, updates, evals) = one_iteration(kind, 7);
            assert!(updates > 0, "{kind:?}: first iteration must improve the random graph");
            assert!(evals > 400 * 8, "{kind:?}: must evaluate beyond init");
            graph.validate().unwrap();
        }
    }

    #[test]
    fn all_backends_reduce_mean_distance_similarly() {
        // identical seeds → identical candidate sets → identical updates
        // for flat kinds; blocked evaluates (and may improve) more, so we
        // compare final mean neighbor distance instead of update counts.
        let mean_dist = |g: &KnnGraph| {
            let mut s = 0.0f64;
            let mut c = 0usize;
            for u in 0..g.n() {
                for &d in g.dists(u) {
                    if d.is_finite() {
                        s += d as f64;
                        c += 1;
                    }
                }
            }
            s / c as f64
        };
        let (g_scalar, _, _) = one_iteration(ComputeKind::Scalar, 11);
        let (g_unrolled, _, _) = one_iteration(ComputeKind::Unrolled, 11);
        let (g_blocked, _, _) = one_iteration(ComputeKind::Blocked, 11);
        let (ms, mu, mb) = (mean_dist(&g_scalar), mean_dist(&g_unrolled), mean_dist(&g_blocked));
        assert!((ms - mu).abs() / ms < 1e-5, "scalar {ms} vs unrolled {mu}");
        // blocked can only be ≤ flat quality-wise (it evaluates a superset)
        assert!(mb <= ms * 1.001, "blocked {mb} should be at least as good as scalar {ms}");
    }

    /// One selection's worth of shared state for the frozen-vs-live
    /// comparison below.
    fn graph_and_candidates(seed: u64) -> (KnnGraph, crate::dataset::AlignedMatrix, CandidateLists) {
        let n = 400;
        let k = 8;
        let cap = 6;
        let data = SynthGaussian::single(n, 16, seed).generate();
        let mut graph = KnnGraph::new(n, k);
        let mut rng = Pcg64::new(seed);
        let mut counter = FlopCounter::new(16);
        init_random(&mut graph, &data, &mut rng, &mut counter, &mut NoTracer);
        let mut sel = Selector::new(SelectionKind::Turbo, n, cap);
        let mut cands = CandidateLists::new(n, cap);
        sel.select(&mut graph, &mut rng, &mut cands, &mut NoTracer);
        (graph, data, cands)
    }

    #[test]
    fn frozen_step_plus_apply_matches_live_step() {
        // the phased relaxation must land on the same neighbor lists as
        // the in-place step: both are top-k over the same evaluated
        // pairs (ties at the k-th boundary could differ, but are
        // measure-zero on continuous data) — and the evaluation counts
        // must be identical
        for kind in [ComputeKind::Scalar, ComputeKind::Unrolled, ComputeKind::Blocked] {
            let (graph0, data, cands) = graph_and_candidates(13);
            let mut scratch = ComputeScratch::new(6);

            let mut live = graph0.clone();
            let mut live_counter = FlopCounter::new(16);
            let mut engine = NativeEngine::new(kind);
            compute_step(
                &mut live,
                &data,
                &cands,
                &mut engine,
                &mut live_counter,
                &mut scratch,
                &mut NoTracer,
            );

            let mut frozen = graph0.clone();
            let mut engine = NativeEngine::new(kind);
            let mut buf = Vec::new();
            // two disjoint ranges, as two workers would cover them
            let mut frozen_evals =
                compute_step_frozen(&graph0, &data, &cands, 0..200, &mut engine, &mut scratch, &mut buf);
            frozen_evals +=
                compute_step_frozen(&graph0, &data, &cands, 200..400, &mut engine, &mut scratch, &mut buf);
            let applied = frozen.apply_updates(&mut buf);
            assert!(applied > 0, "{kind:?}: phase must make progress");
            assert_eq!(
                live_counter.dist_evals, frozen_evals,
                "{kind:?}: same candidate sets ⇒ same evaluation count"
            );
            frozen.validate().unwrap();
            for u in 0..400 {
                assert_eq!(live.sorted(u), frozen.sorted(u), "{kind:?}: node {u}");
            }
        }
    }

    #[test]
    fn compaction_is_invisible_to_the_apply_phase() {
        // full buffer vs aggressively compacted buffer: same graph,
        // same update count — the losslessness claim of compact_updates
        let (graph0, data, cands) = graph_and_candidates(29);
        let mut scratch = ComputeScratch::new(6);
        let mut engine = NativeEngine::new(ComputeKind::Blocked);
        let mut full = Vec::new();
        compute_step_frozen(&graph0, &data, &cands, 0..400, &mut engine, &mut scratch, &mut full);
        assert!(!full.is_empty());
        let mut compacted = full.clone();
        compact_updates(&mut compacted, 2 * graph0.k());
        assert!(compacted.len() <= full.len());

        let mut a = graph0.clone();
        let mut b = graph0.clone();
        let applied_full = a.apply_updates(&mut full);
        let applied_compacted = b.apply_updates(&mut compacted);
        assert_eq!(applied_full, applied_compacted, "update counts must match");
        for u in 0..400 {
            assert_eq!(a.sorted(u), b.sorted(u), "node {u}");
        }
        b.validate().unwrap();
    }

    #[test]
    fn no_candidates_no_updates() {
        let data = SynthGaussian::single(50, 8, 3).generate();
        let mut graph = KnnGraph::new(50, 4);
        let mut rng = Pcg64::new(3);
        let mut counter = FlopCounter::new(8);
        init_random(&mut graph, &data, &mut rng, &mut counter, &mut NoTracer);
        let cands = CandidateLists::new(50, 4); // empty
        let mut engine = NativeEngine::new(ComputeKind::Blocked);
        let mut scratch = ComputeScratch::new(4);
        let updates = compute_step(
            &mut graph,
            &data,
            &cands,
            &mut engine,
            &mut counter,
            &mut scratch,
            &mut NoTracer,
        );
        assert_eq!(updates, 0);
    }
}
