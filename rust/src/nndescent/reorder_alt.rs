//! Alternative memory-reordering heuristics — the paper's future-work
//! direction ("An interesting topic for future work would be to further
//! explore heuristics for reordering the data", §5).
//!
//! All produce the same artifact as Algorithm 1 (a [`Reordering`]) and
//! plug into the same driver slot, so `bench_reorder_ablation` can
//! compare them like-for-like:
//!
//! * [`bfs_permutation`] — breadth-first traversal of the K-NN graph
//!   from the lowest-id unvisited node; groups whole neighborhoods
//!   instead of chaining single nearest neighbors. More passes over the
//!   adjacency than Algorithm 1 (queue churn) but no dead-end problem.
//! * [`degree_permutation`] — orders by reverse degree (hub-first);
//!   cheap (one counting pass + sort) and clusters "popular" rows that
//!   the selection step touches most often, but ignores data-space
//!   locality within equal-degree runs.
//! * [`dfs_permutation`] — depth-first analogue of BFS: follows the
//!   nearest unvisited neighbor chain like Algorithm 1, but backtracks
//!   instead of restarting arbitrarily on dead ends.

use super::reorder::Reordering;
use crate::graph::heap::EMPTY_ID;
use crate::graph::KnnGraph;

/// BFS over the K-NN graph, visiting each component's nodes in
/// distance-sorted neighborhood order.
pub fn bfs_permutation(graph: &KnnGraph) -> Reordering {
    let n = graph.n();
    let mut order: Vec<u32> = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    let mut queue = std::collections::VecDeque::new();
    let mut adj: Vec<(f32, u32)> = Vec::new();

    for start in 0..n {
        if visited[start] {
            continue;
        }
        visited[start] = true;
        queue.push_back(start as u32);
        while let Some(u) = queue.pop_front() {
            order.push(u);
            adj.clear();
            for (&v, &d) in graph.ids(u as usize).iter().zip(graph.dists(u as usize)) {
                if v != EMPTY_ID && !visited[v as usize] {
                    adj.push((d, v));
                }
            }
            adj.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for &(_, v) in &adj {
                if !visited[v as usize] {
                    visited[v as usize] = true;
                    queue.push_back(v);
                }
            }
        }
    }
    from_order(order)
}

/// Depth-first nearest-unvisited-neighbor walk with backtracking.
pub fn dfs_permutation(graph: &KnnGraph) -> Reordering {
    let n = graph.n();
    let mut order: Vec<u32> = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    let mut stack: Vec<u32> = Vec::new();
    let mut adj: Vec<(f32, u32)> = Vec::new();

    for start in 0..n {
        if visited[start] {
            continue;
        }
        visited[start] = true;
        stack.push(start as u32);
        order.push(start as u32);
        while let Some(&u) = stack.last() {
            adj.clear();
            for (&v, &d) in graph.ids(u as usize).iter().zip(graph.dists(u as usize)) {
                if v != EMPTY_ID && !visited[v as usize] {
                    adj.push((d, v));
                }
            }
            if adj.is_empty() {
                stack.pop();
                continue;
            }
            let next = adj
                .iter()
                .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
                .unwrap()
                .1;
            visited[next as usize] = true;
            order.push(next);
            stack.push(next);
        }
    }
    from_order(order)
}

/// Hub-first ordering: descending reverse degree, id tiebreak.
pub fn degree_permutation(graph: &KnnGraph) -> Reordering {
    let n = graph.n();
    let mut nodes: Vec<u32> = (0..n as u32).collect();
    nodes.sort_by_key(|&v| (std::cmp::Reverse(graph.reverse_degree(v as usize)), v));
    from_order(nodes)
}

/// Build σ/σ⁻¹ from a visit order (`order[p]` = node at position p).
fn from_order(order: Vec<u32>) -> Reordering {
    let n = order.len();
    let mut sigma = vec![0u32; n];
    for (p, &v) in order.iter().enumerate() {
        sigma[v as usize] = p as u32;
    }
    Reordering { sigma, inv: order }
}

/// Named heuristic selector for benches/CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReorderKind {
    Greedy,
    Bfs,
    Dfs,
    Degree,
}

impl ReorderKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "greedy" => Some(Self::Greedy),
            "bfs" => Some(Self::Bfs),
            "dfs" => Some(Self::Dfs),
            "degree" => Some(Self::Degree),
            _ => None,
        }
    }
    pub fn name(self) -> &'static str {
        match self {
            Self::Greedy => "greedy",
            Self::Bfs => "bfs",
            Self::Dfs => "dfs",
            Self::Degree => "degree",
        }
    }
    /// Run the heuristic.
    pub fn permutation(self, graph: &KnnGraph) -> Reordering {
        match self {
            Self::Greedy => super::reorder::greedy_permutation(graph, &mut crate::cachesim::trace::NoTracer),
            Self::Bfs => bfs_permutation(graph),
            Self::Dfs => dfs_permutation(graph),
            Self::Degree => degree_permutation(graph),
        }
    }
    pub const ALL: [ReorderKind; 4] = [Self::Greedy, Self::Bfs, Self::Dfs, Self::Degree];
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::clustered::SynthClustered;
    use crate::metrics::window::{cluster_window_fractions, mean_max_fraction};
    use crate::nndescent::{NnDescent, Params};
    use crate::testing::{check, Config};

    fn graph_and_labels(n: usize, c: usize, seed: u64) -> (KnnGraph, Vec<u32>) {
        let (data, labels) = SynthClustered::new(n, 8, c, seed).generate_labeled();
        let params = Params::default().with_k(10).with_seed(seed).with_max_iters(3);
        (NnDescent::new(params).build(&data).unwrap().graph, labels)
    }

    #[test]
    fn all_heuristics_produce_valid_permutations() {
        let (graph, _) = graph_and_labels(500, 5, 3);
        for kind in ReorderKind::ALL {
            let r = kind.permutation(&graph);
            r.validate().unwrap_or_else(|e| panic!("{}: {e}", kind.name()));
        }
    }

    #[test]
    fn graph_traversals_beat_random_on_clustered_data() {
        let (graph, labels) = graph_and_labels(1600, 8, 7);
        let baseline = 1.0 / 8.0;
        for kind in [ReorderKind::Bfs, ReorderKind::Dfs, ReorderKind::Greedy] {
            let r = kind.permutation(&graph);
            let fr = cluster_window_fractions(&r.inv, &labels, 8, 200, 100);
            let mm = mean_max_fraction(&fr);
            assert!(
                mm > 2.5 * baseline,
                "{}: contiguity {mm:.3} not better than random {baseline:.3}",
                kind.name()
            );
        }
    }

    #[test]
    fn degree_orders_by_reverse_degree() {
        let (graph, _) = graph_and_labels(300, 3, 11);
        let r = degree_permutation(&graph);
        let degs: Vec<u32> =
            r.inv.iter().map(|&v| graph.reverse_degree(v as usize)).collect();
        assert!(degs.windows(2).all(|w| w[0] >= w[1]), "must be non-increasing");
    }

    #[test]
    fn prop_from_order_roundtrips() {
        check(Config::cases(50), "order → σ/σ⁻¹ bijection", |g| {
            let n = g.usize_in(1..200);
            let order = g.permutation(n);
            let r = from_order(order.clone());
            r.validate().is_ok() && r.inv == order
        });
    }

    #[test]
    fn dfs_and_bfs_visit_everything_even_with_empty_slots() {
        // graph with unfilled slots (k > what init provides)
        let mut graph = KnnGraph::new(10, 3);
        graph.push(0, 1, 1.0, false);
        graph.push(1, 2, 1.0, false);
        // nodes 3..9 isolated
        for kind in [ReorderKind::Bfs, ReorderKind::Dfs] {
            let r = kind.permutation(&graph);
            r.validate().unwrap();
            assert_eq!(r.inv.len(), 10);
        }
    }
}
