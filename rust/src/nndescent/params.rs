//! NN-Descent run parameters.

use crate::config::schema::{ComputeKind, RunConfig, SelectionKind};

/// Tunables for one graph build. Defaults match the paper's evaluation
/// setup: k=20, ρ=0.5, δ=0.001, candidate cap 50, squared-L2.
#[derive(Debug, Clone, PartialEq)]
pub struct Params {
    /// Number of neighbors per node.
    pub k: usize,
    /// Sample rate ρ: per-node candidate lists hold ⌈ρ·k⌉ entries.
    pub rho: f64,
    /// Convergence threshold δ: stop when an iteration makes fewer than
    /// δ·n·k graph updates.
    pub delta: f64,
    /// Hard iteration cap (safety net; convergence normally fires first).
    pub max_iters: usize,
    /// PRNG seed (all randomness derives from this).
    pub seed: u64,
    /// Selection-step implementation.
    pub selection: SelectionKind,
    /// Distance backend for the compute step.
    pub compute: ComputeKind,
    /// Run the greedy reordering heuristic (paper §3.2).
    pub reorder: bool,
    /// Iteration *before which* the reorder runs (paper: after the first
    /// iteration, i.e. 1).
    pub reorder_iter: usize,
    /// Hard cap on candidate-set size (paper: 50).
    pub max_candidates: usize,
    /// Build worker threads. `0` (the default) resolves from the
    /// `PALLAS_BUILD_THREADS` environment variable, falling back to 1;
    /// an explicit value wins over the environment. `1` is the exact
    /// sequential engine (bit-identical to builds before the knob
    /// existed); `> 1` runs the phased parallel engine
    /// ([`nndescent::parallel`](crate::nndescent::parallel)) — still
    /// deterministic for a fixed seed, but a different (equally valid)
    /// graph than the sequential one. The parallel engine implements
    /// turbo selection only: `naive`/`heap` builds keep their
    /// configured algorithm and run sequentially (with a log notice).
    /// Ignored by `build_with_engine*` (explicit-engine builds stay
    /// sequential) and not persisted in `KNNIv1` bundles (build-time
    /// knob; loaded params report 0).
    pub threads: usize,
}

impl Default for Params {
    fn default() -> Self {
        Self {
            k: 20,
            rho: 0.5,
            delta: 0.001,
            max_iters: 40,
            seed: 1,
            selection: SelectionKind::Turbo,
            compute: ComputeKind::Blocked,
            reorder: false,
            reorder_iter: 1,
            max_candidates: 50,
            threads: 0,
        }
    }
}

impl Params {
    /// Per-node candidate-list capacity (each of new/old): ρ·k sampled
    /// from the forward edges plus ρ·k from the reverse edges (Dong et
    /// al.'s sampling), bounded so new+old never exceeds the paper's
    /// candidate-set cap of `max_candidates` (50).
    pub fn cand_cap(&self) -> usize {
        let per_dir = (2.0 * (self.rho * self.k as f64).ceil()) as usize;
        per_dir.clamp(1, (self.max_candidates / 2).max(1))
    }

    pub fn with_k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }
    pub fn with_rho(mut self, rho: f64) -> Self {
        self.rho = rho;
        self
    }
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
    pub fn with_selection(mut self, s: SelectionKind) -> Self {
        self.selection = s;
        self
    }
    pub fn with_compute(mut self, c: ComputeKind) -> Self {
        self.compute = c;
        self
    }
    pub fn with_reorder(mut self, on: bool) -> Self {
        self.reorder = on;
        self
    }
    pub fn with_max_iters(mut self, m: usize) -> Self {
        self.max_iters = m;
        self
    }
    pub fn with_delta(mut self, d: f64) -> Self {
        self.delta = d;
        self
    }
    /// Build worker threads (see [`Params::threads`]; 0 = resolve from
    /// the `PALLAS_BUILD_THREADS` environment, else 1).
    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }
}

impl From<&RunConfig> for Params {
    fn from(rc: &RunConfig) -> Self {
        Self {
            k: rc.k,
            rho: rc.rho,
            delta: rc.delta,
            max_iters: rc.max_iters,
            seed: rc.seed,
            selection: rc.selection,
            compute: rc.compute,
            reorder: rc.reorder,
            reorder_iter: 1,
            max_candidates: rc.max_candidates,
            threads: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let p = Params::default();
        assert_eq!(p.k, 20);
        assert_eq!(p.rho, 0.5);
        assert_eq!(p.delta, 0.001);
        assert_eq!(p.max_candidates, 50);
        assert_eq!(p.cand_cap(), 20, "2·⌈0.5·20⌉ = 20 per direction");
    }

    #[test]
    fn cand_cap_clamps() {
        let p = Params::default().with_k(200); // 2ρk = 200 > 50/2
        assert_eq!(p.cand_cap(), 25, "bounded by max_candidates/2");
        let p = Params::default().with_k(1).with_rho(0.01);
        assert_eq!(p.cand_cap(), 2, "2·⌈0.01⌉ = 2");
        let p = Params { max_candidates: 2, ..Params::default() };
        assert_eq!(p.cand_cap(), 1, "max_candidates/2 floor");
    }

    #[test]
    fn from_run_config() {
        let rc = RunConfig::default();
        let p = Params::from(&rc);
        assert_eq!(p.k, rc.k);
        assert_eq!(p.selection, rc.selection);
    }
}
