//! Multi-threaded NN-Descent: partitioned select/compute phases with a
//! deterministic phased update merge.
//!
//! The sequential driver's iteration is `select → [reorder] → compute`,
//! every step mutating one `KnnGraph` in place. This engine keeps the
//! same skeleton but runs the two heavy phases data-parallel over
//! contiguous working-id ranges, one range per worker (Baron & Darling,
//! arXiv:2202.00517: NN-Descent parallelizes via partitioned candidate
//! generation with phased update application):
//!
//! * **Select** — each worker fills its range's candidate lists through
//!   a disjoint `CandChunk`, using the counter-based partitioned
//!   sampler (`selection::partitioned`); the graph is read-only. The
//!   driver then runs the sequential flag-clear pass (cheap `O(n·k)`,
//!   and it touches cross-range reverse counters).
//! * **Compute** — each worker evaluates its range's candidate pairs
//!   through its own [`ComputeScratch`] and kernel engine
//!   ([`compute_step_frozen`]), buffering `(target, nb, dist)` records
//!   instead of touching the heaps. The driver concatenates the buffers
//!   and replays them in one deterministic merge, sorted by (target,
//!   distance, id) ([`KnnGraph::apply_updates`]).
//!
//! ## Determinism contract
//!
//! Coin flips and reservoir slots are counter-based (keyed by seed,
//! iteration, and edge/target — never by worker), the frozen compute
//! screen never depends on phase progress, and the update merge sorts
//! before applying. The built graph is therefore a pure function of
//! `(params, data)` — independent of thread interleaving **and of the
//! thread count**: `threads = 2` and `threads = 8` produce bit-identical
//! results. `threads = 1` does not enter this engine at all; the driver
//! routes it to the unchanged sequential path, so T=1 stays bit-identical
//! to historical builds. The phased merge relaxes Dong et al.'s
//! immediate updates (a worker cannot see improvements buffered in the
//! same phase), so the T>1 graph differs from the sequential one — same
//! algorithm family, equal quality (gated within 0.02 recall by the
//! integration tests), typically ±1 iteration to converge.
//!
//! ## Threading model
//!
//! Worker *state* (scratch, buffers, counters) is long-lived — allocated
//! once per build and reused across every phase of every iteration. The
//! OS threads are scoped per phase (`std::thread::scope`): the graph
//! alternates between shared (phases) and exclusive (merge, reorder)
//! access, which scoped borrows express safely where a persistent
//! channel/worker pool (the `api::serve` idiom) would need the phase
//! lifetimes erased. Spawn cost is a few µs per phase — noise next to a
//! compute phase. Std threads only, no dependencies.

use super::candidates::CandidateLists;
use super::compute::{compute_step_frozen, ComputeScratch, NativeEngine};
use super::driver::BuildResult;
use super::init::init_random_parallel;
use super::observer::{BuildEvent, BuildObserver};
use super::params::Params;
use super::reorder::{greedy_permutation_segmented, Reordering, REORDER_SEGMENT_LEN};
use super::selection::clear_sampled_flags;
use super::selection::partitioned::{select_into_chunk, selection_seed, SelectionThresholds};
use crate::cachesim::trace::NoTracer;
use crate::dataset::AlignedMatrix;
use crate::graph::{GraphUpdate, KnnGraph};
use crate::util::counters::{FlopCounter, IterStats};
use crate::util::timer::Timer;
use std::ops::Range;

/// Smallest node range worth a worker: below this the spawn + merge
/// overhead dominates and the thread count is clamped down.
const MIN_NODES_PER_WORKER: usize = 8;

/// Resolve the configured thread count against the environment:
/// explicit `Params::threads` wins, then `PALLAS_BUILD_THREADS`, then 1.
/// (Unparseable or zero environment values fall back to 1 rather than
/// erroring: the env var is an operator override, not an API surface.)
pub fn resolve_build_threads(params_threads: usize) -> usize {
    if params_threads > 0 {
        return params_threads;
    }
    std::env::var("PALLAS_BUILD_THREADS")
        .ok()
        .and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or(1)
}

/// Worker count actually used for a corpus of `n` points: the resolved
/// count clamped so every range keeps at least [`MIN_NODES_PER_WORKER`]
/// nodes. A result of 1 means "run the sequential engine".
pub fn effective_build_threads(params: &Params, n: usize) -> usize {
    resolve_build_threads(params.threads).clamp(1, (n / MIN_NODES_PER_WORKER).max(1))
}

/// Long-lived per-worker build state, reused across every phase of
/// every iteration (see module docs: only the OS threads are scoped).
struct WorkerState {
    scratch: ComputeScratch,
    counter: FlopCounter,
    updates: Vec<GraphUpdate>,
    stats: IterStats,
}

impl WorkerState {
    fn new(cap: usize, dim: usize) -> Self {
        Self {
            scratch: ComputeScratch::new(cap),
            counter: FlopCounter::new(dim),
            updates: Vec::new(),
            stats: IterStats::default(),
        }
    }

    /// Compute-phase body: evaluate this range's candidate pairs against
    /// the frozen graph, buffering improvement records.
    fn compute_phase(
        &mut self,
        iter: usize,
        graph: &KnnGraph,
        data: &AlignedMatrix,
        cands: &CandidateLists,
        range: Range<usize>,
        kind: crate::config::schema::ComputeKind,
    ) {
        let mut t = Timer::new();
        t.start();
        self.updates.clear();
        // per-phase counter: the driver folds it into the build total
        // through FlopCounter::merge after the workers join
        self.counter.dist_evals = 0;
        let mut engine = NativeEngine::new(kind);
        let evals = compute_step_frozen(
            graph,
            data,
            cands,
            range,
            &mut engine,
            &mut self.scratch,
            &mut self.updates,
        );
        self.counter.add_evals(evals);
        t.stop();
        self.stats =
            IterStats { iter, compute_secs: t.secs(), dist_evals: evals, ..Default::default() };
    }
}

/// Build a K-NN graph with `threads ≥ 2` workers. The caller (the
/// driver) resolves the thread count and routes `threads == 1` to the
/// sequential engine; `params.compute` must be a native backend.
pub(crate) fn build(
    params: &Params,
    data: &AlignedMatrix,
    threads: usize,
    observer: &mut dyn BuildObserver,
) -> BuildResult {
    let p = params;
    let n = data.n();
    assert!(n >= 2, "need at least two points");
    debug_assert!(threads >= 2, "the driver routes T=1 to the sequential engine");
    debug_assert_eq!(
        p.selection,
        crate::config::schema::SelectionKind::Turbo,
        "the driver routes non-turbo selections to their sequential implementations"
    );
    let k = p.k.min(n - 1);
    let cap = p.cand_cap();

    let mut total = Timer::new();
    total.start();

    let mut graph = KnnGraph::new(n, k);
    let mut counter = FlopCounter::new(data.dim());
    let mut cands = CandidateLists::new(n, cap);

    let bounds: Vec<Range<usize>> =
        (0..threads).map(|w| w * n / threads..(w + 1) * n / threads).collect();

    observer.on_event(&BuildEvent::Started { n, dim: data.dim(), k });
    // per-node counter-based streams: the starting graph is a pure
    // function of (seed, data), thread-count invariant like every other
    // phase of this engine
    init_random_parallel(&mut graph, data, p.seed, &bounds, &mut counter);

    let mut workers: Vec<WorkerState> =
        (0..threads).map(|_| WorkerState::new(cap, data.dim())).collect();
    let mut merged: Vec<GraphUpdate> = Vec::new();

    let mut owned: Option<AlignedMatrix> = None;
    let mut reordering: Option<Reordering> = None;
    let mut per_iter = Vec::new();
    let threshold = (p.delta * n as f64 * k as f64) as u64;
    let mut iterations = 0;
    let mut converged = false;

    for it in 0..p.max_iters {
        iterations = it + 1;
        let mut stats = IterStats { iter: it, ..Default::default() };

        // ---- greedy reorder (segmented, once) --------------------------
        // fixed-length segments run on the worker threads; corpora with
        // n ≤ REORDER_SEGMENT_LEN form one segment and reproduce the
        // sequential pass bit for bit
        if p.reorder && it == p.reorder_iter && reordering.is_none() {
            let mut t = Timer::new();
            t.start();
            let active: &AlignedMatrix = owned.as_ref().unwrap_or(data);
            let r = greedy_permutation_segmented(&graph, REORDER_SEGMENT_LEN, threads);
            let permuted = active.permuted(&r.inv);
            graph = graph.apply_permutation(&r.sigma);
            owned = Some(permuted);
            reordering = Some(r);
            t.stop();
            stats.reorder_secs = t.secs();
            observer.on_event(&BuildEvent::Reordered { secs: stats.reorder_secs });
        }
        let active: &AlignedMatrix = owned.as_ref().unwrap_or(data);

        // ---- selection (parallel, owner-writes partition) --------------
        let mut t = Timer::new();
        t.start();
        let iter_seed = selection_seed(p.seed, it);
        let thr = SelectionThresholds::compute(&graph, cap);
        {
            let graph_ref = &graph;
            let thr_ref = &thr;
            std::thread::scope(|s| {
                for mut chunk in cands.split_ranges(&bounds) {
                    s.spawn(move || select_into_chunk(graph_ref, thr_ref, iter_seed, &mut chunk));
                }
            });
        }
        clear_sampled_flags(&mut graph, &cands, &mut NoTracer);
        t.stop();
        stats.select_secs = t.secs();

        // ---- compute (parallel, frozen graph) + phased merge -----------
        let mut t = Timer::new();
        t.start();
        {
            let graph_ref = &graph;
            let cands_ref = &cands;
            std::thread::scope(|s| {
                for (state, range) in workers.iter_mut().zip(&bounds) {
                    let range = range.clone();
                    s.spawn(move || {
                        state.compute_phase(it, graph_ref, active, cands_ref, range, p.compute)
                    });
                }
            });
        }
        for state in &mut workers {
            stats.merge(&state.stats);
            counter.merge(&state.counter);
            merged.append(&mut state.updates);
        }
        let updates = graph.apply_updates(&mut merged);
        t.stop();
        // the phase wall-clock (workers + merge), not the max worker span
        stats.compute_secs = t.secs();
        stats.updates = updates;
        observer.on_event(&BuildEvent::from_iter_stats(&stats));
        per_iter.push(stats);

        if updates <= threshold {
            converged = true;
            break;
        }
    }

    total.stop();
    observer.on_event(&BuildEvent::Finished { iterations, converged, total_secs: total.secs() });
    BuildResult {
        graph,
        iterations,
        per_iter,
        stats: counter,
        reordering,
        total_secs: total.secs(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::schema::ComputeKind;
    use crate::dataset::synth::SynthGaussian;
    use crate::nndescent::observer::NoopObserver;

    #[test]
    fn worker_state_and_shared_refs_are_thread_safe() {
        // Send/Sync audit: the spawn sites require exactly these bounds;
        // a field change that breaks them should fail here, loudly, not
        // deep inside a scope (“the new worker state stays shippable”).
        fn assert_send<T: Send>() {}
        fn assert_sync<T: Sync>() {}
        assert_send::<WorkerState>();
        assert_send::<&mut WorkerState>();
        assert_sync::<KnnGraph>();
        assert_sync::<CandidateLists>();
        assert_sync::<AlignedMatrix>();
        assert_sync::<SelectionThresholds>();
        assert_send::<Vec<GraphUpdate>>();
    }

    #[test]
    fn resolve_prefers_explicit_over_default() {
        // explicit values win unconditionally (the env path is covered
        // by the integration suite, which owns process-global state)
        assert_eq!(resolve_build_threads(3), 3);
        assert_eq!(resolve_build_threads(1), 1);
    }

    #[test]
    fn effective_threads_clamps_to_corpus_size() {
        let p = Params::default().with_threads(16);
        assert_eq!(effective_build_threads(&p, 10_000), 16);
        assert_eq!(effective_build_threads(&p, 64), 8, "ranges keep ≥ 8 nodes");
        assert_eq!(effective_build_threads(&p, 9), 1, "tiny corpora run sequentially");
        let p1 = Params::default().with_threads(1);
        assert_eq!(effective_build_threads(&p1, 10_000), 1);
    }

    #[test]
    fn parallel_build_is_valid_and_deterministic() {
        let data = SynthGaussian::single(400, 8, 21).generate();
        let params = Params::default()
            .with_k(8)
            .with_seed(21)
            .with_compute(ComputeKind::Blocked)
            .with_threads(2);
        let a = build(&params, &data, 2, &mut NoopObserver);
        let b = build(&params, &data, 2, &mut NoopObserver);
        a.graph.validate().unwrap();
        assert!(a.iterations >= 2);
        assert_eq!(a.iterations, b.iterations);
        assert_eq!(a.stats.dist_evals, b.stats.dist_evals);
        assert_eq!(a.total_updates(), b.total_updates());
        for u in 0..400 {
            assert_eq!(a.graph.sorted(u), b.graph.sorted(u), "node {u}");
        }
    }

    #[test]
    fn thread_count_does_not_change_the_result() {
        // the counter-based phases make T a pure performance knob:
        // 2, 3, and 4 workers produce bit-identical graphs and stats
        let data = SynthGaussian::single(500, 8, 5).generate();
        let params =
            Params::default().with_k(10).with_seed(5).with_compute(ComputeKind::Blocked);
        let base = build(&params, &data, 2, &mut NoopObserver);
        for t in [3usize, 4] {
            let other = build(&params, &data, t, &mut NoopObserver);
            assert_eq!(base.iterations, other.iterations, "T={t}");
            assert_eq!(base.stats.dist_evals, other.stats.dist_evals, "T={t}");
            for u in 0..500 {
                assert_eq!(base.graph.sorted(u), other.graph.sorted(u), "T={t} node {u}");
            }
        }
    }

    #[test]
    fn per_iter_stats_account_for_all_evaluations() {
        let data = SynthGaussian::single(300, 8, 9).generate();
        let params = Params::default().with_k(8).with_seed(9);
        let r = build(&params, &data, 4, &mut NoopObserver);
        let per_iter_evals: u64 = r.per_iter.iter().map(|s| s.dist_evals).sum();
        // total = init (n·k) + per-iteration compute phases
        assert_eq!(r.stats.dist_evals, 300 * 8 + per_iter_evals);
        assert!(r.per_iter.iter().all(|s| s.updates > 0 || s.dist_evals > 0));
    }
}
