//! Build progress events: a typed channel replacing ad-hoc `log_info!`
//! calls as the way callers watch an index build.
//!
//! The NN-Descent driver emits one [`BuildEvent`] per lifecycle step
//! through a [`BuildObserver`]. Three implementations ship with the
//! crate: [`NoopObserver`] (the default), [`LoggingObserver`] (renders
//! events through the crate logger, the CLI's choice), and
//! [`FnObserver`] (wraps a closure, convenient for tests and
//! embedders).
//!
//! The types live here — next to the driver that emits them — so the
//! engine layer stays independent of the [`api`](crate::api) facade;
//! the facade re-exports them (`knng::api::BuildEvent` etc.) as its
//! public spelling.

use crate::util::counters::IterStats;

/// One step of an index build, emitted in order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BuildEvent {
    /// A sharded build is about to report shard `shard`'s events: every
    /// event until the next `ShardStarted` belongs to that shard (`n`
    /// is the shard's slice size). Emitted only by
    /// [`ShardedSearcher`](crate::api::ShardedSearcher) builds, in
    /// slice order — including when the shards themselves were built
    /// concurrently (each shard's events are buffered and replayed in
    /// order, so observers never see interleaving).
    ShardStarted { shard: usize, n: usize },
    /// The build started: graph of `n` points, `dim` logical dimensions,
    /// `k` neighbors per node.
    Started { n: usize, dim: usize, k: usize },
    /// The greedy reorder heuristic ran (at most once per build).
    Reordered { secs: f64 },
    /// One NN-Descent iteration finished.
    Iteration {
        /// Iteration index (0-based).
        iter: usize,
        /// Graph updates this iteration (the convergence signal).
        updates: u64,
        /// Distance evaluations this iteration.
        dist_evals: u64,
        /// Seconds in the selection step.
        select_secs: f64,
        /// Seconds in the compute step.
        compute_secs: f64,
    },
    /// The build finished. `converged` is false when the iteration cap
    /// stopped it instead of the δ·n·k update threshold.
    Finished { iterations: usize, converged: bool, total_secs: f64 },
}

impl BuildEvent {
    /// Event for a finished iteration, from the driver's per-iteration
    /// stats record.
    pub(crate) fn from_iter_stats(s: &IterStats) -> Self {
        BuildEvent::Iteration {
            iter: s.iter,
            updates: s.updates,
            dist_evals: s.dist_evals,
            select_secs: s.select_secs,
            compute_secs: s.compute_secs,
        }
    }
}

/// Receiver for [`BuildEvent`]s. Implementations must be cheap: the
/// driver calls `on_event` from the build loop (once per iteration, not
/// per distance evaluation, so allocation is acceptable but blocking
/// I/O should be buffered).
pub trait BuildObserver {
    fn on_event(&mut self, event: &BuildEvent);
}

/// Ignores all events (the default when no observer is installed).
#[derive(Debug, Default, Clone, Copy)]
pub struct NoopObserver;

impl BuildObserver for NoopObserver {
    fn on_event(&mut self, _event: &BuildEvent) {}
}

/// Renders events through the crate logger (`log_info!`/`log_debug!`),
/// reproducing the progress lines the pipeline used to hard-code.
#[derive(Debug, Default, Clone, Copy)]
pub struct LoggingObserver;

impl BuildObserver for LoggingObserver {
    fn on_event(&mut self, event: &BuildEvent) {
        match *event {
            BuildEvent::ShardStarted { shard, n } => {
                crate::log_info!("shard {shard}: build starting ({n} points)");
            }
            BuildEvent::Started { n, dim, k } => {
                crate::log_info!("build started: n={n}, d={dim}, k={k}");
            }
            BuildEvent::Reordered { secs } => {
                crate::log_info!("greedy reorder ran in {secs:.3}s");
            }
            BuildEvent::Iteration { iter, updates, dist_evals, select_secs, compute_secs } => {
                crate::log_debug!(
                    "iter {iter}: {updates} updates, {dist_evals} dist evals \
                     (select {select_secs:.3}s, compute {compute_secs:.3}s)"
                );
            }
            BuildEvent::Finished { iterations, converged, total_secs } => {
                crate::log_info!(
                    "build {} after {iterations} iterations in {total_secs:.3}s",
                    if converged { "converged" } else { "hit the iteration cap" }
                );
            }
        }
    }
}

/// Adapts a closure into a [`BuildObserver`]:
/// `FnObserver(|e| events.push(*e))`.
pub struct FnObserver<F: FnMut(&BuildEvent)>(pub F);

impl<F: FnMut(&BuildEvent)> BuildObserver for FnObserver<F> {
    fn on_event(&mut self, event: &BuildEvent) {
        (self.0)(event)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fn_observer_records() {
        let mut seen = Vec::new();
        {
            let mut obs = FnObserver(|e: &BuildEvent| seen.push(*e));
            obs.on_event(&BuildEvent::Started { n: 10, dim: 8, k: 3 });
            obs.on_event(&BuildEvent::Finished { iterations: 2, converged: true, total_secs: 0.1 });
        }
        assert_eq!(seen.len(), 2);
        assert!(matches!(seen[0], BuildEvent::Started { n: 10, .. }));
        assert!(matches!(seen[1], BuildEvent::Finished { converged: true, .. }));
    }

    #[test]
    fn noop_and_logging_accept_all_events() {
        let events = [
            BuildEvent::ShardStarted { shard: 0, n: 4 },
            BuildEvent::Started { n: 4, dim: 8, k: 2 },
            BuildEvent::Reordered { secs: 0.01 },
            BuildEvent::Iteration {
                iter: 0,
                updates: 5,
                dist_evals: 10,
                select_secs: 0.0,
                compute_secs: 0.0,
            },
            BuildEvent::Finished { iterations: 1, converged: false, total_secs: 0.02 },
        ];
        let mut noop = NoopObserver;
        let mut logging = LoggingObserver;
        for e in &events {
            noop.on_event(e);
            logging.on_event(e);
        }
    }

    #[test]
    fn iteration_event_mirrors_iter_stats() {
        let s = IterStats {
            iter: 3,
            select_secs: 0.5,
            compute_secs: 1.5,
            reorder_secs: 0.0,
            dist_evals: 77,
            updates: 9,
        };
        let e = BuildEvent::from_iter_stats(&s);
        assert_eq!(
            e,
            BuildEvent::Iteration {
                iter: 3,
                updates: 9,
                dist_evals: 77,
                select_secs: 0.5,
                compute_secs: 1.5,
            }
        );
    }
}
