//! Random K-NN graph initialization (paper §2): every node starts with
//! k neighbors sampled uniformly at random, real distances attached,
//! all flagged "new".

use crate::cachesim::trace::Tracer;
use crate::dataset::AlignedMatrix;
use crate::graph::KnnGraph;
use crate::util::counters::FlopCounter;
use crate::util::rng::Pcg64;

/// Fill `graph` with k uniformly sampled neighbors per node.
pub fn init_random<T: Tracer>(
    graph: &mut KnnGraph,
    data: &AlignedMatrix,
    rng: &mut Pcg64,
    counter: &mut FlopCounter,
    tracer: &mut T,
) {
    let n = graph.n();
    let k = graph.k().min(n - 1);
    let row_bytes = data.row_bytes() as u32;
    // resolve the dispatched pair kernel once for the n·k init scan
    let pair = crate::distance::dispatch::active().pair;
    let mut sample: Vec<u32> = Vec::with_capacity(k);
    for u in 0..n {
        // k distinct ids ≠ u by rejection (k ≪ n, expected O(k) draws;
        // falls back to dense reservoir sampling for tiny n where
        // rejection would thrash)
        sample.clear();
        if n <= 2 * k + 2 {
            rng.sample_indices(n - 1, k, &mut sample);
            for raw in sample.iter_mut() {
                if (*raw as usize) >= u {
                    *raw += 1;
                }
            }
        } else {
            while sample.len() < k {
                let v = rng.gen_index(n) as u32;
                if v as usize != u && !sample.contains(&v) {
                    sample.push(v);
                }
            }
        }
        tracer.read(data.base_addr() + u * data.row_bytes(), row_bytes);
        let a = data.row(u);
        for &v in sample.iter() {
            tracer.read(data.base_addr() + v as usize * data.row_bytes(), row_bytes);
            let d = pair(a, data.row(v as usize));
            counter.add_evals(1);
            graph.push(u, v, d, true);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::trace::NoTracer;
    use crate::dataset::synth::SynthGaussian;
    use crate::distance::sq_l2_unrolled;
    use crate::graph::heap::EMPTY_ID;

    fn setup(n: usize, k: usize, dim: usize) -> (KnnGraph, AlignedMatrix, FlopCounter) {
        let data = SynthGaussian::single(n, dim, 3).generate();
        let mut graph = KnnGraph::new(n, k);
        let mut rng = Pcg64::new(7);
        let mut counter = FlopCounter::new(dim);
        init_random(&mut graph, &data, &mut rng, &mut counter, &mut NoTracer);
        (graph, data, counter)
    }

    #[test]
    fn fills_every_slot_with_distinct_neighbors() {
        let (graph, _, counter) = setup(100, 10, 8);
        for u in 0..100 {
            let ids = graph.ids(u);
            assert!(ids.iter().all(|&v| v != EMPTY_ID && v as usize != u));
            let mut s: Vec<u32> = ids.to_vec();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 10, "node {u} has duplicate neighbors");
        }
        assert_eq!(counter.dist_evals, 100 * 10);
        graph.validate().unwrap();
    }

    #[test]
    fn distances_are_correct() {
        let (graph, data, _) = setup(50, 5, 16);
        for u in 0..50 {
            for (&v, &d) in graph.ids(u).iter().zip(graph.dists(u)) {
                let expect = sq_l2_unrolled(data.row(u), data.row(v as usize));
                assert!((d - expect).abs() < 1e-5, "node {u} → {v}: {d} vs {expect}");
            }
        }
    }

    #[test]
    fn all_flags_start_new() {
        let (graph, _, _) = setup(30, 4, 8);
        for u in 0..30 {
            assert!(graph.flags(u).iter().all(|&f| f));
        }
    }

    #[test]
    fn k_clamped_when_n_small() {
        let data = SynthGaussian::single(4, 8, 1).generate();
        let mut graph = KnnGraph::new(4, 6); // k > n-1
        let mut rng = Pcg64::new(1);
        let mut c = FlopCounter::new(8);
        init_random(&mut graph, &data, &mut rng, &mut c, &mut NoTracer);
        for u in 0..4 {
            let filled = graph.ids(u).iter().filter(|&&v| v != EMPTY_ID).count();
            assert_eq!(filled, 3, "only n-1 distinct neighbors exist");
        }
        graph.validate().unwrap();
    }
}
